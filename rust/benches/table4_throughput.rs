//! Bench target for **Table IV**: throughput of single-channel DDR4-1600
//! across R/W × Seq/Rnd × {single, short, medium, long} — measures the
//! wall time of each configuration point and prints the reproduced table
//! (paper values alongside for comparison).
//!
//! Run: `cargo bench --bench table4_throughput` (add `--quick` for CI).

use ddr4bench::benchkit::Bench;
use ddr4bench::config::{AddrMode, OpMix};
use ddr4bench::config::{DesignConfig, SpeedBin};
use ddr4bench::platform::Platform;
use ddr4bench::report::campaign::{self, TABLE4_LENGTHS};

/// Paper's Table IV ground truth, same layout as `Table4Data::gbs`.
const PAPER: [[[f64; 4]; 2]; 2] = [
    [[3.08, 6.20, 6.27, 6.29], [0.56, 2.24, 6.08, 6.30]], // read seq / rnd
    [[3.03, 6.00, 6.03, 6.04], [0.42, 1.66, 5.79, 6.04]], // write seq / rnd
];

fn main() {
    let scale = 0.25;
    let mut bench = Bench::new("table4_throughput").with_samples(5, 1);

    // Per-point wall-time benchmarks (simulator speed per configuration).
    for (op, olabel) in [(OpMix::ReadOnly, "read"), (OpMix::WriteOnly, "write")] {
        for (addr, alabel) in
            [(AddrMode::Sequential, "seq"), (AddrMode::Random { seed: 0xBEEF }, "rnd")]
        {
            for (len, _) in TABLE4_LENGTHS {
                let mut platform =
                    Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
                let txns = campaign::batch_for(len, scale) as f64;
                bench.bench_throughput(
                    &format!("table4/{olabel}/{alabel}/burst{len}"),
                    txns,
                    "txn",
                    || {
                        let s = campaign::run_point(&mut platform, op, &addr, len, scale);
                        std::hint::black_box(campaign::gbs_of(op, &s));
                    },
                );
            }
        }
    }

    // The reproduced table with paper deltas.
    let d = campaign::table4_data(scale);
    println!("\nTable IV reproduction (GB/s) — measured (paper) [delta]");
    for (oi, op) in ["Read ", "Write"].iter().enumerate() {
        for (ai, addr) in ["Seq", "Rnd"].iter().enumerate() {
            print!("  {op} {addr}: ");
            for (li, (len, _)) in TABLE4_LENGTHS.iter().enumerate() {
                let m = d.gbs[oi][ai][li];
                let p = PAPER[oi][ai][li];
                print!("b{len}={m:.2} ({p:.2}) [{:+.0}%]  ", (m - p) / p * 100.0);
            }
            println!();
        }
    }
    bench.finish();
}
