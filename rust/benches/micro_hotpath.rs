//! Microbenchmarks of the simulator's hot paths — the profile targets of
//! the performance pass (EXPERIMENTS.md SPerf):
//!
//! - DDR4 device command legality + issue (inner loop of every tick);
//! - controller tick under saturated sequential and random load;
//! - scheduler pick under deep queues, one series per policy — documents
//!   that the `controller::sched` trait dispatch + wake fast path does
//!   not slow the hot loop relative to the monolithic scheduler;
//! - saturated deep-queue scheduling, per policy and window width, with
//!   `_oracle` twins running the frozen scan scheduler
//!   (`controller.sched_oracle`) — the indexed-fast-path speedup the CI
//!   perf smoke reads out of `BENCH_micro.json`;
//! - end-to-end simulated-cycles-per-second (the SPerf headline), plus a
//!   telemetry-armed twin that prices the windowed sampler's probe;
//! - PRBS payload expansion, Rust mirror vs the AOT XLA kernel;
//! - batched verification, Rust mirror vs XLA.
//!
//! Run: `cargo bench --bench micro_hotpath` (add `--quick` for CI).

use ddr4bench::benchkit::Bench;
use ddr4bench::config::{ControllerParams, DesignConfig, EngineKind, PatternConfig, SpeedBin};
use ddr4bench::controller::{MemController, MemRequest, SchedKind};
use ddr4bench::ddr4::{Cmd, DdrDevice, DramGeometry, TimingParams};
use ddr4bench::platform::Platform;
use ddr4bench::rng::SplitMix64;
use ddr4bench::runtime::XlaRuntime;
use ddr4bench::trafficgen::payload;

/// One saturated deep-queue scheduling run: depth-64 queues kept
/// brimming (refill whenever more than 8 slots open) under a
/// `lookahead`-wide reorder window, over a small working set thick with
/// bank conflicts and same-address revisits. `oracle` selects the frozen
/// scan scheduler instead of the incremental indexes — the `_oracle`
/// bench twins make the fast-path speedup directly readable.
fn run_satq(kind: SchedKind, lookahead: usize, oracle: bool) {
    let geo = DramGeometry::profpga_board();
    let params = ControllerParams {
        sched: kind,
        sched_oracle: oracle,
        lookahead,
        read_queue_depth: 64,
        write_queue_depth: 64,
        write_drain_high: 48,
        write_drain_low: 8,
        ..Default::default()
    };
    let mut ctrl = MemController::new(params, TimingParams::for_bin(SpeedBin::Ddr4_1600), geo);
    let mut rng = SplitMix64::new(7);
    let mut comps = Vec::new();
    let mut id = 0u64;
    for now in 0..60_000u64 {
        while ctrl.read_slots() > 8 || ctrl.write_slots() > 8 {
            let is_write = if ctrl.write_slots() == 0 {
                false
            } else if ctrl.read_slots() == 0 {
                true
            } else {
                rng.percent(40)
            };
            let addr = rng.below(1 << 14) * 64;
            let pushed = ctrl.try_push(MemRequest {
                txn_id: id,
                is_write,
                addr: geo.decode(addr),
                burst_addr: addr,
                beats: 2,
                arrival: now,
                last_of_txn: true,
            });
            if pushed.is_err() {
                break;
            }
            id += 1;
        }
        ctrl.tick(now);
        if now % 64 == 0 {
            comps.clear();
            ctrl.pop_completions(now, &mut comps);
        }
    }
    std::hint::black_box(ctrl.device().stats().reads);
}

fn main() {
    let mut bench = Bench::new("micro_hotpath");

    // --- device: earliest_issue/issue inner loop
    bench.bench_throughput("device/act_rd_pre_cycle", 300_000.0, "cmd", || {
        let mut dev = DdrDevice::new(
            TimingParams::for_bin(SpeedBin::Ddr4_1600),
            DramGeometry::profpga_board(),
        );
        let mut now = 0;
        for i in 0..100_000u64 {
            let bank = (i % 8) as u32;
            let act = Cmd::Act { bank, row: (i % 1024) as u32 };
            now = dev.earliest_issue(act).max(now + 1);
            dev.issue(act, now);
            let rd = Cmd::Rd { bank, col: 0, auto_pre: false };
            now = dev.earliest_issue(rd).max(now + 1);
            dev.issue(rd, now);
            let pre = Cmd::Pre { bank };
            now = dev.earliest_issue(pre).max(now + 1);
            dev.issue(pre, now);
        }
        std::hint::black_box(dev.stats().reads);
    });

    // --- controller tick under load
    for (name, random) in [("seq", false), ("rnd", true)] {
        bench.bench_throughput(&format!("controller/tick_{name}"), 200_000.0, "tick", || {
            let geo = DramGeometry::profpga_board();
            let mut ctrl = MemController::new(
                ControllerParams::default(),
                TimingParams::for_bin(SpeedBin::Ddr4_1600),
                geo,
            );
            let mut rng = SplitMix64::new(1);
            let mut comps = Vec::new();
            let mut id = 0u64;
            for now in 0..200_000u64 {
                if ctrl.read_slots() > 0 {
                    let addr = if random {
                        rng.below(1 << 25) * 64
                    } else {
                        (id % (1 << 20)) * 64
                    };
                    let _ = ctrl.try_push(MemRequest {
                        txn_id: id,
                        is_write: false,
                        addr: geo.decode(addr),
                        burst_addr: addr,
                        beats: 2,
                        arrival: now,
                        last_of_txn: true,
                    });
                    id += 1;
                }
                ctrl.tick(now);
                if now % 64 == 0 {
                    comps.clear();
                    ctrl.pop_completions(now, &mut comps);
                }
            }
            std::hint::black_box(ctrl.device().stats().reads);
        });
    }

    // --- scheduler pick: deep queues (depth 64, window 16), every policy
    for kind in SchedKind::ALL {
        let name = format!("controller/sched_pick_{}", kind.name());
        bench.bench_throughput(&name, 150_000.0, "tick", move || {
            let geo = DramGeometry::profpga_board();
            let params = ControllerParams {
                sched: kind,
                read_queue_depth: 64,
                write_queue_depth: 64,
                write_drain_high: 48,
                write_drain_low: 8,
                lookahead: 16,
                ..Default::default()
            };
            let mut ctrl =
                MemController::new(params, TimingParams::for_bin(SpeedBin::Ddr4_1600), geo);
            let mut rng = SplitMix64::new(11);
            let mut comps = Vec::new();
            let mut id = 0u64;
            for now in 0..150_000u64 {
                // keep both queues deep so every pick scans a full window
                // (steer pushes away from a full queue so one full side
                // cannot starve the refill of the other)
                while ctrl.read_slots() > 32 || ctrl.write_slots() > 32 {
                    let is_write = if ctrl.write_slots() == 0 {
                        false
                    } else if ctrl.read_slots() == 0 {
                        true
                    } else {
                        rng.percent(40)
                    };
                    let addr = rng.below(1 << 22) * 64;
                    let pushed = ctrl.try_push(MemRequest {
                        txn_id: id,
                        is_write,
                        addr: geo.decode(addr),
                        burst_addr: addr,
                        beats: 2,
                        arrival: now,
                        last_of_txn: true,
                    });
                    if pushed.is_err() {
                        break;
                    }
                    id += 1;
                }
                ctrl.tick(now);
                if now % 64 == 0 {
                    comps.clear();
                    ctrl.pop_completions(now, &mut comps);
                }
            }
            std::hint::black_box(ctrl.device().stats().reads);
        });
    }

    // --- saturated deep-queue scheduling: the indexed fast path against
    // its frozen scan-oracle twin, per policy and window width. The CI
    // perf smoke reads these series out of BENCH_micro.json and checks
    // (advisorily) that each `satq_*_la32` sustains >= 1.5x the cycle
    // rate of its `_oracle` twin.
    for kind in SchedKind::ALL {
        for lookahead in [8usize, 32] {
            for oracle in [false, true] {
                let name = format!(
                    "controller/satq_{}_la{lookahead}{}",
                    kind.name(),
                    if oracle { "_oracle" } else { "" }
                );
                bench.bench_throughput(&name, 60_000.0, "cycle", move || {
                    run_satq(kind, lookahead, oracle);
                });
            }
        }
    }

    // --- end-to-end: simulated DRAM cycles per wall second
    let cfg = PatternConfig::seq_read_burst(32, 4096);
    let mut platform = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
    // one batch = rd_cycles axi cycles; measure sim throughput
    let probe = platform.run_batch(0, &cfg).unwrap();
    let dram_cycles = probe.counters.total_cycles * 4;
    bench.bench_throughput("platform/sim_dram_cycles", dram_cycles as f64, "cycle", || {
        std::hint::black_box(platform.run_batch(0, &cfg).unwrap().read_throughput_gbs());
    });

    // --- same workload with the telemetry sampler armed: the `_telem`
    // series documents the observer's cost, and the plain series above is
    // the telemetry-off hot path the acceptance gate watches — a
    // regression there means the disabled probe is no longer free.
    let mut telem_cfg = PatternConfig::seq_read_burst(32, 4096);
    telem_cfg.telemetry = Some(1024);
    let mut telem_platform = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
    bench.bench_throughput("platform/sim_dram_cycles_telem", dram_cycles as f64, "cycle", || {
        std::hint::black_box(telem_platform.run_batch(0, &telem_cfg).unwrap().counters.rd_bytes);
    });

    // --- engine duel: cycle-stepped oracle vs event-driven time-skip core
    // on an idle-heavy workload (single-beat reads throttled to one AR per
    // 64 fabric cycles — long quiet gaps between commands), the regime the
    // event engine exists for. The differential suite pins both engines
    // bit-identical; this pair pins the wall-clock win (acceptance: the
    // `_event` series sustains >=5x the `_cycle` rate here).
    let mut idle_design = DesignConfig::single_channel(SpeedBin::Ddr4_1600);
    idle_design.controller.addr_cmd_interval_axi = 64;
    for engine in EngineKind::ALL {
        let idle_cfg = PatternConfig::seq_read_burst(1, 2048);
        let mut design = idle_design.clone();
        design.engine = engine;
        let mut p = Platform::new(design);
        let probe = p.run_batch(0, &idle_cfg).unwrap();
        let idle_dram_cycles = probe.counters.total_cycles * 4;
        let name = format!("platform/idle_dram_cycles_{engine}");
        bench.bench_throughput(&name, idle_dram_cycles as f64, "cycle", move || {
            std::hint::black_box(p.run_batch(0, &idle_cfg).unwrap().read_throughput_gbs());
        });
    }

    // --- data path: rust mirror vs XLA artifacts
    let seeds: Vec<u32> = (0..4096u32).map(|i| i.wrapping_mul(2654435761)).collect();
    bench.bench_throughput("payload/expand_rust_4096", 4096.0 * 16.0, "word", || {
        std::hint::black_box(payload::expand_batch(&seeds));
    });
    let data = payload::expand_batch(&seeds);
    bench.bench_throughput("payload/verify_rust_4096", 4096.0 * 16.0, "word", || {
        std::hint::black_box(payload::verify_batch(&seeds, &data));
    });

    let dir = ddr4bench::artifacts_dir();
    if XlaRuntime::artifacts_present(&dir) {
        let rt = XlaRuntime::load(&dir).unwrap();
        bench.bench_throughput("payload/expand_xla_4096", 4096.0 * 16.0, "word", || {
            std::hint::black_box(rt.datagen(&seeds).unwrap());
        });
        bench.bench_throughput("payload/verify_xla_4096", 4096.0 * 16.0, "word", || {
            std::hint::black_box(rt.verify(&seeds, &data).unwrap());
        });
        // analytic model through XLA
        let feats: Vec<f32> = (0..64)
            .flat_map(|i| {
                [1600.0 + (i % 4) as f32 * 266.0, 1.0 + (i % 128) as f32, (i % 2) as f32,
                 1.0, 32.0, 2.0, 4.0, 8.0]
            })
            .collect();
        bench.bench_throughput("analytic/bwmodel_xla_64rows", 64.0, "row", || {
            std::hint::black_box(rt.bwmodel(&feats).unwrap());
        });
    } else {
        println!("(artifacts missing: skipping XLA data-path benches)");
    }

    // machine-readable mirror of everything measured above — the CI perf
    // smoke parses this and uploads it as an artifact
    let json_path = std::path::Path::new("BENCH_micro.json");
    bench.write_json(json_path).expect("write BENCH_micro.json");
    println!("(wrote {})", json_path.display());

    bench.finish();
}
