//! Bench target for **Fig. 2**: throughput vs burst length for DDR4-1600
//! and DDR4-2400, Seq/Rnd x R/W/M. Measures the cost of each full sweep
//! and prints the figure series (plus the data-rate-uplift analysis of
//! SIII-C).
//!
//! Run: `cargo bench --bench fig2_datarates` (add `--quick` for CI).

use ddr4bench::benchkit::Bench;
use ddr4bench::report::campaign;

fn main() {
    let scale = 0.15;
    let mut bench = Bench::new("fig2_datarates").with_samples(3, 1);

    bench.bench_throughput(
        "fig2/full_sweep_both_rates",
        (campaign::FIG2_LENGTHS.len() * 6 * 2) as f64,
        "point",
        || {
            std::hint::black_box(campaign::fig2(scale));
        },
    );

    let figs = campaign::fig2(scale);
    for fig in &figs {
        println!("\n{}", fig.ascii());
    }
    // SIII-C uplift series: 2400/1600 per burst length, seq vs rnd reads.
    let (f16, f24) = (&figs[0], &figs[1]);
    let series = |f: &ddr4bench::report::Figure, label: &str| {
        f.series.iter().find(|s| s.label == label).unwrap().points.clone()
    };
    println!("2400/1600 uplift by burst length (paper: seq to 1.50x, rnd 1.07x@16 -> 1.32x@128):");
    for (key, name) in [("Seq-R", "seq read"), ("Rnd-R", "rnd read")] {
        let a = series(f16, key);
        let b = series(f24, key);
        print!("  {name}: ");
        for ((x, y16), (_, y24)) in a.iter().zip(b.iter()) {
            print!("b{x}={:.2}x ", y24 / y16);
        }
        println!();
    }
    bench.finish();
}
