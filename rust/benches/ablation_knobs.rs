//! Ablation benches over the controller design choices DESIGN.md calls
//! out — each knob is flipped in isolation against the MIG-like default
//! profile and measured on the workloads it affects most:
//!
//! | knob | workloads |
//! |---|---|
//! | `serial_frontend`        | random singles, random short bursts |
//! | `miss_flush`             | random singles |
//! | `lookahead` (1/4/8)      | random medium bursts (intra-txn ACT overlap) |
//! | `mode_dwell_ck` (0/48/192) | mixed sequential medium bursts |
//! | `idle_precharge_cycles`  | random singles (closed-page win) vs sequential (loss) |
//! | address mapping          | sequential streams (bank-group interleave) |
//!
//! Run: `cargo bench --bench ablation_knobs` (add `--quick` for CI).

use ddr4bench::benchkit::Bench;
use ddr4bench::config::{AddrMode, DesignConfig, OpMix, PatternConfig, SpeedBin};
use ddr4bench::ddr4::MappingPolicy;
use ddr4bench::platform::Platform;

fn gbs(design: DesignConfig, cfg: &PatternConfig, op: OpMix) -> f64 {
    let mut p = Platform::new(design);
    let mut c = cfg.clone();
    c.op = op;
    let s = p.run_batch(0, &c).expect("ablation batch");
    match op {
        OpMix::ReadOnly => s.read_throughput_gbs(),
        OpMix::WriteOnly => s.write_throughput_gbs(),
        OpMix::Mixed { .. } => s.total_throughput_gbs(),
    }
}

fn main() {
    let mut bench = Bench::new("ablation_knobs").with_samples(3, 1);
    let base = || DesignConfig::single_channel(SpeedBin::Ddr4_1600);
    let rnd_single = PatternConfig::rnd_read_burst(1, 2048, 7);
    let rnd_sb = PatternConfig::rnd_read_burst(4, 2048, 7);
    let rnd_mb = PatternConfig::rnd_read_burst(32, 1024, 7);
    let seq_mb = PatternConfig::seq_read_burst(32, 2048);
    let mixed_mb = PatternConfig::mixed(AddrMode::Sequential, 32, 2048);

    println!("-- serial front end (MIG-like txn serialization) --");
    for on in [true, false] {
        let mut d = base();
        d.controller.serial_frontend = on;
        let g1 = gbs(d.clone(), &rnd_single, OpMix::ReadOnly);
        let g4 = gbs(d, &rnd_sb, OpMix::ReadOnly);
        println!("  serial_frontend={on}: rnd-single {g1:.2} GB/s, rnd-SB {g4:.2} GB/s");
    }

    println!("-- page-miss pipeline flush --");
    for on in [true, false] {
        let mut d = base();
        d.controller.miss_flush = on;
        let g = gbs(d, &rnd_single, OpMix::ReadOnly);
        println!("  miss_flush={on}: rnd-single {g:.2} GB/s (paper hardware: 0.56)");
    }

    println!("-- scheduler lookahead (FR-FCFS window; 1 = plain FCFS) --");
    for la in [1usize, 4, 8] {
        let mut d = base();
        d.controller.lookahead = la;
        let g = gbs(d, &rnd_mb, OpMix::ReadOnly);
        println!("  lookahead={la}: rnd-MB {g:.2} GB/s");
    }

    println!("-- read/write mode dwell --");
    for dwell in [1u32, 48, 192] {
        let mut d = base();
        d.controller.mode_dwell_ck = dwell;
        let g = gbs(d, &mixed_mb, OpMix::Mixed { read_pct: 50 });
        println!("  mode_dwell_ck={dwell}: mixed-MB {g:.2} GB/s");
    }

    println!("-- page policy (idle-precharge timer; 0 = open page) --");
    for timer in [0u32, 32, 128] {
        let mut d = base();
        d.controller.idle_precharge_cycles = timer;
        let r = gbs(d.clone(), &rnd_single, OpMix::ReadOnly);
        let s = gbs(d, &seq_mb, OpMix::ReadOnly);
        println!("  idle_precharge={timer}: rnd-single {r:.2} GB/s, seq-MB {s:.2} GB/s");
    }

    println!("-- address mapping --");
    for mapping in MappingPolicy::builtins() {
        let mut d = base();
        d.geometry.mapping = mapping;
        let s = gbs(d.clone(), &seq_mb, OpMix::ReadOnly);
        let r = gbs(d, &rnd_single, OpMix::ReadOnly);
        println!("  {mapping}: seq-MB {s:.2} GB/s, rnd-single {r:.2} GB/s");
    }

    // Timed versions of the two most expensive ablations.
    bench.bench("ablation/serial_frontend_sweep", || {
        for on in [true, false] {
            let mut d = base();
            d.controller.serial_frontend = on;
            std::hint::black_box(gbs(d, &rnd_single, OpMix::ReadOnly));
        }
    });
    bench.bench("ablation/mapping_sweep", || {
        for mapping in MappingPolicy::builtins() {
            let mut d = base();
            d.geometry.mapping = mapping;
            std::hint::black_box(gbs(d, &seq_mb, OpMix::ReadOnly));
        }
    });
    bench.finish();
}
