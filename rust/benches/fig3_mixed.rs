//! Bench target for **Fig. 3**: read/write throughput breakdown of mixed
//! workloads (single-channel DDR4-1600, S/SB/MB/LB x Seq/Rnd). Also
//! checks the SIII-C claim that mixed workloads beat read-only maxima.
//!
//! Run: `cargo bench --bench fig3_mixed` (add `--quick` for CI).

use ddr4bench::benchkit::Bench;
use ddr4bench::config::{AddrMode, DesignConfig, OpMix, SpeedBin};
use ddr4bench::platform::Platform;
use ddr4bench::report::campaign;

fn main() {
    let scale = 0.2;
    let mut bench = Bench::new("fig3_mixed").with_samples(3, 1);

    bench.bench_throughput("fig3/full_table", 8.0, "point", || {
        std::hint::black_box(campaign::fig3(scale));
    });

    // per-point benches for the mixed scheduler (the interesting cases)
    for (addr, label) in
        [(AddrMode::Sequential, "seq"), (AddrMode::Random { seed: 0xCAFE }, "rnd")]
    {
        let mut platform = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        bench.bench(&format!("fig3/mixed_{label}_burst128"), || {
            let s = campaign::run_point(
                &mut platform,
                OpMix::Mixed { read_pct: 50 },
                &addr,
                128,
                scale,
            );
            std::hint::black_box(s.total_throughput_gbs());
        });
    }

    println!("\n{}", campaign::fig3(scale).ascii());

    // mixed > pure check (SIII-C)
    let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
    let pure = campaign::run_point(&mut p, OpMix::ReadOnly, &AddrMode::Sequential, 128, scale)
        .read_throughput_gbs();
    let mixed = campaign::run_point(
        &mut p,
        OpMix::Mixed { read_pct: 50 },
        &AddrMode::Sequential,
        128,
        scale,
    )
    .total_throughput_gbs();
    println!("mixed vs pure-read max: {mixed:.2} vs {pure:.2} GB/s (paper: 7.99 vs 6.29)");
    bench.finish();
}
