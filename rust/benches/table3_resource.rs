//! Bench target for **Table III**: the analytical FPGA resource model.
//! Measures model evaluation cost (it sits on the DSE path of
//! `examples/multi_channel.rs`) and prints the reproduced table with the
//! paper's post-implementation numbers for comparison.
//!
//! Run: `cargo bench --bench table3_resource`.

use ddr4bench::benchkit::Bench;
use ddr4bench::config::{DesignConfig, SpeedBin};
use ddr4bench::resource;

/// Paper Table III ground truth: (label, LUT, FF, BRAM, DSP).
const PAPER: [(&str, f64, f64, f64, f64); 6] = [
    ("Memory interface", 12793.0, 17173.0, 25.5, 3.0),
    ("Traffic generator", 108.0, 268.0, 0.0, 0.0),
    ("Host controller", 70.0, 116.0, 0.0, 0.0),
    ("Single-channel design", 12975.0, 17559.0, 25.5, 3.0),
    ("Dual-channel design", 25884.0, 35006.0, 51.0, 6.0),
    ("Triple-channel design", 38797.0, 52457.0, 76.5, 9.0),
];

fn main() {
    let mut bench = Bench::new("table3_resource");
    bench.bench_throughput("table3/full_table", 6.0, "row", || {
        std::hint::black_box(resource::table3());
    });
    bench.bench("table3/design_cost_3ch", || {
        let d = DesignConfig::with_channels(3, SpeedBin::Ddr4_2400);
        std::hint::black_box(resource::design_cost(&d));
    });

    println!("\nTable III reproduction — modeled (paper)");
    let rows = resource::table3();
    let mut worst: f64 = 0.0;
    for (row, (name, lut, ff, bram, dsp)) in rows.iter().zip(PAPER.iter()) {
        let dl = (row.res.lut - lut).abs() / lut.max(1.0);
        let df = (row.res.ff - ff).abs() / ff.max(1.0);
        worst = worst.max(dl).max(df);
        println!(
            concat!(
                "  {:<24} LUT {:>6.0} ({:>6.0})  FF {:>6.0} ({:>6.0})  ",
                "BRAM {:>5} ({:>5})  DSP {:>2} ({:>2})"
            ),
            name, row.res.lut, lut, row.res.ff, ff, row.res.bram, bram, row.res.dsp, dsp
        );
    }
    println!("  worst relative deviation from paper: {:.3}%", worst * 100.0);
    bench.finish();
}
