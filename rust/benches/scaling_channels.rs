//! Bench target for the **channel-scaling** claim (SIII-A): dual- and
//! triple-channel designs deliver 2x and 3x the single-channel
//! throughput. Also measures simulator wall time per channel count (the
//! threaded multi-channel executive).
//!
//! Run: `cargo bench --bench scaling_channels` (add `--quick` for CI).

use ddr4bench::benchkit::Bench;
use ddr4bench::config::{ChannelMix, DesignConfig, PatternConfig, SpeedBin};
use ddr4bench::platform::Platform;
use ddr4bench::report::campaign;

fn main() {
    let scale = 0.25;
    let mut bench = Bench::new("scaling_channels").with_samples(5, 1);

    for n in 1..=3usize {
        for speed in [SpeedBin::Ddr4_1600, SpeedBin::Ddr4_2400] {
            let cfg = PatternConfig::seq_read_burst(32, campaign::batch_for(32, scale));
            let mut platform = Platform::new(DesignConfig::with_channels(n, speed));
            bench.bench_throughput(
                &format!("scaling/{n}ch_{speed}"),
                (cfg.batch_len as usize * n) as f64,
                "txn",
                || {
                    let per = platform.run_batch_all(&cfg).unwrap();
                    std::hint::black_box(Platform::aggregate(&per).read_throughput_gbs());
                },
            );
        }
    }

    // Heterogeneous mix executive: three distinct per-channel workloads
    // on parallel channel threads (the wall-clock cost of the mix path
    // relative to the homogeneous runs above).
    let seq_batch = campaign::batch_for(32, scale);
    let mix = ChannelMix::new(vec![
        PatternConfig::seq_read_burst(32, seq_batch),
        PatternConfig::pointer_chase_read(1 << 20, seq_batch / 4, 7),
        PatternConfig::bank_conflict_read(1, seq_batch / 2, 1),
    ])
    .expect("3-channel mix");
    let txns: u32 = mix.iter().map(|c| c.batch_len).sum();
    let mut platform = Platform::new(DesignConfig::with_channels(3, SpeedBin::Ddr4_2400));
    bench.bench_throughput("scaling/3ch_hetero_seq+chase+bank", txns as f64, "txn", || {
        let per = platform.run_batch_mix(&mix).unwrap();
        std::hint::black_box(Platform::aggregate(&per).total_throughput_gbs());
    });

    println!("\n{}", campaign::scaling(scale).ascii());
    bench.finish();
}
