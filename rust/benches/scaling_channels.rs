//! Bench target for the **channel-scaling** claim (SIII-A): dual- and
//! triple-channel designs deliver 2x and 3x the single-channel
//! throughput. Also measures simulator wall time per channel count (the
//! threaded multi-channel executive).
//!
//! Run: `cargo bench --bench scaling_channels` (add `--quick` for CI).

use ddr4bench::benchkit::Bench;
use ddr4bench::config::{DesignConfig, PatternConfig, SpeedBin};
use ddr4bench::platform::Platform;
use ddr4bench::report::campaign;

fn main() {
    let scale = 0.25;
    let mut bench = Bench::new("scaling_channels").with_samples(5, 1);

    for n in 1..=3usize {
        for speed in [SpeedBin::Ddr4_1600, SpeedBin::Ddr4_2400] {
            let cfg = PatternConfig::seq_read_burst(32, campaign::batch_for(32, scale));
            let mut platform = Platform::new(DesignConfig::with_channels(n, speed));
            bench.bench_throughput(
                &format!("scaling/{n}ch_{speed}"),
                (cfg.batch_len as usize * n) as f64,
                "txn",
                || {
                    let per = platform.run_batch_all(&cfg).unwrap();
                    std::hint::black_box(Platform::aggregate(&per).read_throughput_gbs());
                },
            );
        }
    }

    println!("\n{}", campaign::scaling(scale).ascii());
    bench.finish();
}
