//! # ddr4bench
//!
//! A benchmarking platform for DDR4 memory performance in data-center-class
//! FPGAs — a full reproduction of Galimberti et al., ISCAS 2025
//! (DOI 10.1109/ISCAS56072.2025.11043686), on a simulated substrate.
//!
//! The paper instantiates, per memory channel, a MIG-style DDR4 **memory
//! interface**, an AXI4 **traffic generator** with run-time-configurable
//! access patterns, and a UART-driven **host controller** on an AMD Kintex
//! UltraScale 115. This crate rebuilds every one of those components as a
//! cycle-level model so the paper's entire experimental campaign (Tables
//! III–IV, Figs. 2–3, the channel-scaling and data-rate analyses) can be
//! regenerated on a CPU:
//!
//! - [`ddr4`] — the DDR4 SDRAM device: JEDEC speed-bin timing, bank-group /
//!   bank state machines, refresh, the DDR data bus, and the
//!   runtime-configurable address-mapping engine ([`ddr4::mapping`]:
//!   bit-interleave orders, XOR bank hash, custom `MAP=` bit-order
//!   strings — all bijective and property-tested).
//! - [`controller`] — the memory interface, decomposed into a front end
//!   (read/write queues, write draining, refresh insertion, miss-flush
//!   gates, the 4:1 PHY:AXI clock ratio) and the [`controller::sched`]
//!   subsystem: runtime-selectable command-scheduling/page policies
//!   behind the `SchedPolicy` trait (strict FCFS, FR-FCFS open page —
//!   the default — bypass-capped FR-FCFS, closed page with
//!   auto-precharge, and an adaptive idle-timer policy), swappable live
//!   via the `SCHED=` token and sweepable as a campaign axis.
//! - [`axi`] — the AXI4 on-chip protocol: five independent channels, burst
//!   semantics (FIXED / INCR / WRAP, lengths 1–128), handshakes.
//! - [`trafficgen`] — the paper's instrument: the run-time access-pattern
//!   engine (sequential, random, strided, bank-conflict, pointer-chase and
//!   phased addressing — see [`config::AddrMode`]), signaling modes,
//!   payload generation + read-back verification, hardware-style
//!   performance counters.
//! - [`hostctrl`] — the UART/host-PC command protocol, re-founded on a
//!   typed `Request`/`Response` API ([`hostctrl::proto`]) with one parse
//!   and one render path; transports are thin: the in-memory link, the
//!   legacy serial TCP loop, and the concurrent multi-session bench
//!   server ([`hostctrl::BenchServer`] — per-client isolated platforms,
//!   one shared bounded worker pool, per-session resource limits,
//!   streaming `STATS` heartbeats). Every pattern-engine mode is
//!   selectable live through `CFG`.
//! - [`platform`] — design-time composition: N channels × data rate ×
//!   counter set, the batch-run executive — including the heterogeneous
//!   per-channel workload engine ([`config::ChannelMix`] /
//!   `Platform::run_batch_mix`: an independent pattern per channel on
//!   parallel threads, per-channel error isolation, and the
//!   solo-vs-co-run `interference_matrix` report) — and the
//!   [`platform::sweep`] campaign executive that expands cartesian
//!   (speed × channels × mapping × controller-knob × pattern/mix) grids
//!   into deduplicated job lists and runs them on a work-stealing thread
//!   pool, emitting per-job JSON/CSV artifacts.
//! - [`runtime`] — the PJRT bridge that loads the AOT-compiled JAX/Pallas
//!   artifacts (payload generator, verifier, analytic bandwidth model) and
//!   executes them from the hot path; Python never runs at benchmark time.
//! - [`resource`] — the Table III analytical FPGA resource model.
//! - [`analytic`] — closed-form DDR4 bandwidth model used to cross-check
//!   the simulator.
//! - [`report`] — table / figure-series rendering for the paper artifacts,
//!   plus [`report::compare`]: cross-sweep delta reports over
//!   `BENCH_sweep.json` files (`ddr4bench compare`).
//! - [`check`] — the independent JEDEC protocol-legality analyzer: a
//!   declarative rulebook derived from `ddr4::timing` replayed over the
//!   emitted command stream by a shadow state machine that shares no
//!   code with the controller it audits (`run --audit`,
//!   `ddr4bench audit`, host `AUDIT`).
//!
//! ## Quickstart
//!
//! ```no_run
//! use ddr4bench::config::{DesignConfig, PatternConfig, SpeedBin};
//! use ddr4bench::platform::Platform;
//!
//! let design = DesignConfig::single_channel(SpeedBin::Ddr4_1600);
//! let mut platform = Platform::new(design);
//! let pattern = PatternConfig::seq_read_burst(32, 4096);
//! let stats = platform.run_batch(0, &pattern).unwrap();
//! println!("throughput: {:.2} GB/s", stats.read_throughput_gbs());
//! ```
//!
//! Whole campaigns run through the sweep executive (also reachable from
//! the CLI as `ddr4bench sweep`):
//!
//! ```no_run
//! use ddr4bench::platform::sweep::{run_sweep, SweepSpec};
//!
//! let outcomes = run_sweep(SweepSpec::paper_grid().expand(), 4).unwrap();
//! assert_eq!(outcomes.len(), 12); // 2 speeds x 2 channel counts x 3 patterns
//! ```

#![forbid(unsafe_code)]

pub mod analytic;
pub mod axi;
pub mod benchkit;
pub mod check;
pub mod cli;
pub mod config;
pub mod controller;
pub mod ddr4;
pub mod hostctrl;
pub mod obs;
pub mod platform;
pub mod report;
pub mod resource;
pub mod rng;
pub mod runtime;
pub mod stats;
pub mod testkit;
pub mod trafficgen;

/// Crate version string (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Default location of the AOT artifacts directory, relative to the repo
/// root. Overridable via the `DDR4BENCH_ARTIFACTS` environment variable.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("DDR4BENCH_ARTIFACTS") {
        return std::path::PathBuf::from(dir);
    }
    // Try CARGO_MANIFEST_DIR (tests/benches), then cwd.
    if let Ok(dir) = std::env::var("CARGO_MANIFEST_DIR") {
        let p = std::path::PathBuf::from(dir).join("artifacts");
        if p.exists() {
            return p;
        }
    }
    std::path::PathBuf::from("artifacts")
}
