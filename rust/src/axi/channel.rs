//! Bounded AXI channel FIFOs with handshake accounting.
//!
//! Each of the five AXI channels is a bounded FIFO: `valid && ready`
//! transfers happen when the producer offers an item and the FIFO has
//! space (ready). Occupancy-full models back-pressure; the stall counters
//! feed the platform's fine-grained statistics.

use std::collections::VecDeque;

/// Handshake statistics of one channel.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ChannelStats {
    /// Successful transfers (valid && ready).
    pub transfers: u64,
    /// Producer offered but FIFO was full (valid && !ready).
    pub stalls: u64,
}

/// A bounded FIFO standing in for one AXI channel.
#[derive(Debug, Clone)]
pub struct ChannelFifo<T> {
    items: VecDeque<T>,
    capacity: usize,
    stats: ChannelStats,
}

impl<T> ChannelFifo<T> {
    /// New FIFO with `capacity` entries (must be >= 1).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "channel capacity must be >= 1");
        Self { items: VecDeque::with_capacity(capacity), capacity, stats: ChannelStats::default() }
    }

    /// Is the channel ready to accept (not full)?
    pub fn ready(&self) -> bool {
        self.items.len() < self.capacity
    }

    /// Offer an item (assert valid). Returns true if transferred; false
    /// records a stall and the producer must retry next cycle.
    pub fn offer(&mut self, item: T) -> Result<(), T> {
        if self.ready() {
            self.items.push_back(item);
            self.stats.transfers += 1;
            Ok(())
        } else {
            self.stats.stalls += 1;
            Err(item)
        }
    }

    /// Consumer side: peek the head.
    pub fn peek(&self) -> Option<&T> {
        self.items.front()
    }

    /// Consumer side: pop the head.
    pub fn pop(&mut self) -> Option<T> {
        self.items.pop_front()
    }

    /// Current occupancy.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// Is the FIFO empty?
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Configured capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Handshake statistics so far.
    pub fn stats(&self) -> ChannelStats {
        self.stats
    }

    /// Drop all contents and reset statistics (batch boundary).
    pub fn reset(&mut self) {
        self.items.clear();
        self.stats = ChannelStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfers_until_full_then_stalls() {
        let mut f = ChannelFifo::new(2);
        assert!(f.offer(1).is_ok());
        assert!(f.offer(2).is_ok());
        assert!(!f.ready());
        assert_eq!(f.offer(3), Err(3));
        assert_eq!(f.stats().transfers, 2);
        assert_eq!(f.stats().stalls, 1);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut f = ChannelFifo::new(4);
        for i in 0..4 {
            f.offer(i).unwrap();
        }
        assert_eq!(f.peek(), Some(&0));
        let drained: Vec<_> = std::iter::from_fn(|| f.pop()).collect();
        assert_eq!(drained, vec![0, 1, 2, 3]);
        assert!(f.is_empty());
    }

    #[test]
    fn pop_frees_space() {
        let mut f = ChannelFifo::new(1);
        f.offer('a').unwrap();
        assert!(f.offer('b').is_err());
        assert_eq!(f.pop(), Some('a'));
        assert!(f.offer('b').is_ok());
    }

    #[test]
    fn reset_clears_state() {
        let mut f = ChannelFifo::new(2);
        f.offer(1).unwrap();
        let _ = f.offer(2);
        let _ = f.offer(3);
        f.reset();
        assert!(f.is_empty());
        assert_eq!(f.stats(), ChannelStats::default());
        assert_eq!(f.capacity(), 2);
    }

    #[test]
    #[should_panic]
    fn zero_capacity_panics() {
        let _ = ChannelFifo::<u8>::new(0);
    }
}
