//! AXI4 on-chip bus model (the TG ↔ memory-interface link, §II-B).
//!
//! The traffic generator manages the five independent AXI4 channels — read
//! address (AR), read data (R), write address (AW), write data (W) and
//! write response (B) — which is what lets it issue read and write
//! transactions simultaneously. This module models the protocol at
//! transaction/beat granularity: burst address sequences (FIXED / INCR /
//! WRAP), per-channel FIFOs with back-pressure, and beat bookkeeping.

pub mod burst;
pub mod channel;

pub use burst::{beat_addresses, BurstAddrIter};
pub use channel::{ChannelFifo, ChannelStats};

use crate::config::{BurstKind, BurstSpec};

/// Identifier of an AXI transaction (AxID analogue, unique per TG batch).
pub type TxnId = u64;

/// One AXI4 transaction as issued on an address channel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AxiTxn {
    /// Transaction id (AxID).
    pub id: TxnId,
    /// Write (AW/W/B path) or read (AR/R path)?
    pub is_write: bool,
    /// Start byte address (AxADDR).
    pub addr: u64,
    /// Burst spec: beats per transaction (AxLEN+1) and type (AxBURST).
    pub burst: BurstSpec,
    /// Bytes per beat (decoded AxSIZE).
    pub beat_bytes: u32,
}

impl AxiTxn {
    /// Total payload bytes moved by this transaction.
    pub fn bytes(&self) -> u64 {
        self.burst.len as u64 * self.beat_bytes as u64
    }

    /// Address of beat `i` per the AXI4 burst rules.
    pub fn beat_addr(&self, i: u32) -> u64 {
        burst::beat_addr(self.addr, self.burst, self.beat_bytes, i)
    }

    /// The distinct DRAM-burst-aligned byte addresses this transaction
    /// touches, in beat order with consecutive duplicates collapsed (a
    /// 64-byte DRAM burst covers two 32-byte AXI beats).
    pub fn dram_bursts(&self, dram_burst_bytes: u32) -> Vec<u64> {
        let mask = !(dram_burst_bytes as u64 - 1);
        let mut out: Vec<u64> = Vec::with_capacity(self.burst.len as usize / 2 + 1);
        for i in 0..self.burst.len {
            let a = self.beat_addr(i) & mask;
            if out.last() != Some(&a) {
                out.push(a);
            }
        }
        out
    }
}

/// Validate an AXI4 transaction against protocol rules (A3.4.1): burst
/// length bounds, WRAP power-of-two length and aligned start address, and
/// 4 KiB boundary crossing for INCR.
pub fn validate_txn(txn: &AxiTxn) -> Result<(), String> {
    let len = txn.burst.len;
    if len == 0 || len > 128 {
        return Err(format!("burst length {len} outside 1..=128"));
    }
    if !txn.beat_bytes.is_power_of_two() {
        return Err(format!("beat size {} not a power of two", txn.beat_bytes));
    }
    match txn.burst.kind {
        BurstKind::Wrap => {
            if !len.is_power_of_two() || !(2..=16).contains(&len) {
                return Err(format!("WRAP length {len} must be 2, 4, 8 or 16"));
            }
            if txn.addr % txn.beat_bytes as u64 != 0 {
                return Err("WRAP start address must be size-aligned".into());
            }
        }
        BurstKind::Incr => {
            let end = txn.addr + txn.bytes() - 1;
            if (txn.addr >> 12) != (end >> 12) {
                return Err(format!(
                    "INCR burst {:#x}+{} crosses a 4KiB boundary",
                    txn.addr,
                    txn.bytes()
                ));
            }
        }
        BurstKind::Fixed => {
            if len > 16 {
                return Err(format!("FIXED length {len} must be <= 16"));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BurstKind;

    fn txn(addr: u64, len: u32, kind: BurstKind) -> AxiTxn {
        AxiTxn { id: 0, is_write: false, addr, burst: BurstSpec { len, kind }, beat_bytes: 32 }
    }

    #[test]
    fn txn_bytes() {
        assert_eq!(txn(0, 4, BurstKind::Incr).bytes(), 128);
        assert_eq!(txn(0, 1, BurstKind::Incr).bytes(), 32);
    }

    #[test]
    fn dram_bursts_collapse_pairs() {
        // 4 beats × 32 B from a 64-aligned address = 2 DRAM bursts.
        let t = txn(128, 4, BurstKind::Incr);
        assert_eq!(t.dram_bursts(64), vec![128, 192]);
        // unaligned start straddles 3 bursts
        let t = txn(128 + 32, 4, BurstKind::Incr);
        assert_eq!(t.dram_bursts(64), vec![128, 192, 256]);
    }

    #[test]
    fn dram_bursts_fixed_is_one() {
        let t = txn(96, 8, BurstKind::Fixed);
        assert_eq!(t.dram_bursts(64), vec![64]);
    }

    #[test]
    fn validate_incr_4k_boundary() {
        assert!(validate_txn(&txn(4096 - 64, 4, BurstKind::Incr)).is_err());
        assert!(validate_txn(&txn(4096 - 128, 4, BurstKind::Incr)).is_ok());
    }

    #[test]
    fn validate_wrap_rules() {
        assert!(validate_txn(&txn(0, 8, BurstKind::Wrap)).is_ok());
        assert!(validate_txn(&txn(0, 12, BurstKind::Wrap)).is_err()); // not pow2
        assert!(validate_txn(&txn(0, 32, BurstKind::Wrap)).is_err()); // > 16
        assert!(validate_txn(&txn(7, 8, BurstKind::Wrap)).is_err()); // unaligned
    }

    #[test]
    fn validate_fixed_len_cap() {
        assert!(validate_txn(&txn(0, 16, BurstKind::Fixed)).is_ok());
        assert!(validate_txn(&txn(0, 17, BurstKind::Fixed)).is_err());
    }

    #[test]
    fn validate_len_bounds() {
        assert!(validate_txn(&txn(0, 0, BurstKind::Incr)).is_err());
        let mut t = txn(0, 128, BurstKind::Incr);
        t.addr = 0; // 128*32 = 4096 exactly fills a 4K page
        assert!(validate_txn(&t).is_ok());
    }
}
