//! AXI4 burst address arithmetic (AMBA AXI A3.4.1).
//!
//! Given a start address, burst type, length and beat size, computes the
//! address of every data transfer in the burst:
//!
//! - **FIXED**: every beat targets the start address.
//! - **INCR**: the address increments by the beat size each transfer.
//! - **WRAP**: as INCR, but wraps at an aligned `len × size` boundary.

use crate::config::{BurstKind, BurstSpec};

/// Address of beat `i` (0-based) of a burst.
pub fn beat_addr(start: u64, burst: BurstSpec, beat_bytes: u32, i: u32) -> u64 {
    debug_assert!(i < burst.len);
    let size = beat_bytes as u64;
    match burst.kind {
        BurstKind::Fixed => start,
        BurstKind::Incr => start + i as u64 * size,
        BurstKind::Wrap => {
            let container = burst.len as u64 * size;
            let base = (start / container) * container;
            base + ((start - base) + i as u64 * size) % container
        }
    }
}

/// Iterator over all beat addresses of a burst.
#[derive(Debug, Clone)]
pub struct BurstAddrIter {
    start: u64,
    burst: BurstSpec,
    beat_bytes: u32,
    next: u32,
}

impl BurstAddrIter {
    /// Iterate the beats of the burst starting at `start`.
    pub fn new(start: u64, burst: BurstSpec, beat_bytes: u32) -> Self {
        Self { start, burst, beat_bytes, next: 0 }
    }
}

impl Iterator for BurstAddrIter {
    type Item = u64;

    fn next(&mut self) -> Option<u64> {
        if self.next >= self.burst.len {
            return None;
        }
        let a = beat_addr(self.start, self.burst, self.beat_bytes, self.next);
        self.next += 1;
        Some(a)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let rem = (self.burst.len - self.next) as usize;
        (rem, Some(rem))
    }
}

impl ExactSizeIterator for BurstAddrIter {}

/// Collect all beat addresses of a burst (convenience for tests/tools).
pub fn beat_addresses(start: u64, burst: BurstSpec, beat_bytes: u32) -> Vec<u64> {
    BurstAddrIter::new(start, burst, beat_bytes).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(len: u32, kind: BurstKind) -> BurstSpec {
        BurstSpec { len, kind }
    }

    #[test]
    fn fixed_repeats_start() {
        let a = beat_addresses(0x1000, spec(4, BurstKind::Fixed), 32);
        assert_eq!(a, vec![0x1000; 4]);
    }

    #[test]
    fn incr_steps_by_size() {
        let a = beat_addresses(0x80, spec(4, BurstKind::Incr), 32);
        assert_eq!(a, vec![0x80, 0xA0, 0xC0, 0xE0]);
    }

    #[test]
    fn wrap_from_aligned_start_equals_incr() {
        let w = beat_addresses(0x100, spec(8, BurstKind::Wrap), 32);
        let i = beat_addresses(0x100, spec(8, BurstKind::Incr), 32);
        assert_eq!(w, i);
    }

    #[test]
    fn wrap_wraps_at_container_boundary() {
        // container = 4 beats × 32 B = 128 B; start mid-container.
        let a = beat_addresses(0x140, spec(4, BurstKind::Wrap), 32);
        assert_eq!(a, vec![0x140, 0x160, 0x100, 0x120]);
    }

    #[test]
    fn wrap_visits_every_slot_once() {
        let a = beat_addresses(0x1E0, spec(8, BurstKind::Wrap), 32);
        let mut sorted = a.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 8, "each slot exactly once: {a:?}");
        // all inside the aligned 256B container
        let base = 0x1E0 / 256 * 256;
        assert!(a.iter().all(|&x| (base..base + 256).contains(&x)));
    }

    #[test]
    fn iterator_len_and_exhaustion() {
        let mut it = BurstAddrIter::new(0, spec(3, BurstKind::Incr), 16);
        assert_eq!(it.len(), 3);
        it.next();
        assert_eq!(it.len(), 2);
        assert_eq!(it.by_ref().count(), 2);
        assert_eq!(it.next(), None);
    }
}
