//! Cross-sweep comparison reports (`ddr4bench compare`).
//!
//! Loads several `BENCH_sweep.json` campaign summaries (the current
//! `ddr4bench.sweep.v4` schema plus the older `v3` — which predates the
//! heterogeneous-mix axis — `v2` — which predates the scheduler axis and
//! the latency percentiles — and `v1`, which also predates the
//! mapping/knob axes), matches jobs across files by their axis key (data
//! rate, channels, pattern, mapping, knobs, sched, mix), and renders:
//!
//! - a **delta table** — per job point, the first file's throughput as
//!   the baseline and every other file's absolute value plus percentage
//!   delta against it, alongside the read-p99 latency delta when both
//!   files carry percentiles (v3+);
//! - a **per-axis extremes table** — for each sweep axis and file, the
//!   best and worst value by mean total throughput;
//! - a **regression list** — job points whose delta against the baseline
//!   falls below a configurable threshold.
//!
//! The loader uses a self-contained minimal JSON reader (the crate builds
//! fully offline, without serde — DESIGN.md §9).

use std::path::Path;

use anyhow::{anyhow, Result};

use super::Table;

// ------------------------------------------------------------ JSON reader

/// Minimal JSON value — just enough for the sweep artifact schema.
#[derive(Debug, Clone, PartialEq)]
enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn err(&self, msg: &str) -> String {
        format!("json: {msg} at byte {}", self.i)
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        self.ws();
        if self.i < self.b.len() && self.b[self.i] == c {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.ws();
        self.b.get(self.i).copied()
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => {
                self.eat(b'{')?;
                let mut fields = Vec::new();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                loop {
                    self.ws();
                    let key = match self.string()? {
                        Json::Str(s) => s,
                        _ => unreachable!(),
                    };
                    self.eat(b':')?;
                    fields.push((key, self.value()?));
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(fields));
                        }
                        _ => return Err(self.err("expected `,` or `}`")),
                    }
                }
            }
            b'[' => {
                self.eat(b'[')?;
                let mut items = Vec::new();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                loop {
                    items.push(self.value()?);
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(items));
                        }
                        _ => return Err(self.err("expected `,` or `]`")),
                    }
                }
            }
            b'"' => self.string(),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<Json, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        while self.i < self.b.len() {
            match self.b[self.i] {
                b'"' => {
                    self.i += 1;
                    return Ok(Json::Str(s));
                }
                b'\\' => {
                    self.i += 1;
                    let e = *self.b.get(self.i).ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // multi-byte UTF-8 sequences pass through untouched
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && self.b[self.i] & 0xC0 == 0x80 {
                        self.i += 1;
                    }
                    let chunk = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                }
            }
        }
        Err(self.err("unterminated string"))
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("number"))?;
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }
}

fn parse_json(text: &str) -> Result<Json, String> {
    let mut r = Reader { b: text.as_bytes(), i: 0 };
    let v = r.value()?;
    r.ws();
    if r.i != r.b.len() {
        return Err(r.err("trailing garbage"));
    }
    Ok(v)
}

// ------------------------------------------------------------ sweep files

/// One job point of a loaded sweep summary.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepRecord {
    /// Speed-bin name ("DDR4-1600").
    pub speed: String,
    /// Data rate in MT/s.
    pub data_rate_mts: u32,
    /// Channel count.
    pub channels: u64,
    /// Pattern label.
    pub pattern: String,
    /// Address-mapping policy name (v1 files default to `row_col_bank`).
    pub mapping: String,
    /// Controller-knob profile label (v1 files default to `mig`).
    pub knobs: String,
    /// Scheduler/page-policy name (v1/v2 files default to `frfcfs`).
    pub sched: String,
    /// Heterogeneous per-channel mix spec (empty for uniform jobs and
    /// for pre-v4 files).
    pub mix: String,
    /// Aggregate throughput of the job.
    pub total_gbs: f64,
    /// Read-latency p99 in nanoseconds (None before schema v3).
    pub rd_p99_ns: Option<f64>,
}

impl SweepRecord {
    /// The cross-file matching key. For heterogeneous jobs the mix spec
    /// is authoritative and the pattern label is dropped from the key:
    /// auto-generated mix labels can carry invocation-dependent collision
    /// suffixes (`seq+chase_2`), and keying on them would stop the same
    /// mix from matching itself across two sweeps.
    fn key(&self) -> (u32, u64, String, String, String, String, String) {
        let pattern = if self.mix.is_empty() { self.pattern.clone() } else { String::new() };
        (
            self.data_rate_mts,
            self.channels,
            pattern,
            self.mapping.clone(),
            self.knobs.clone(),
            self.sched.clone(),
            self.mix.clone(),
        )
    }

    /// Human-readable key ("1600MT/1ch/bank/row_col_bank/mig/frfcfs");
    /// heterogeneous jobs append their mix spec.
    fn key_label(&self) -> String {
        let mut s = format!(
            "{}MT/{}ch/{}/{}/{}/{}",
            self.data_rate_mts, self.channels, self.pattern, self.mapping, self.knobs, self.sched
        );
        if !self.mix.is_empty() {
            s.push_str(&format!("/[{}]", self.mix));
        }
        s
    }
}

/// A loaded campaign summary (`BENCH_sweep.json`).
#[derive(Debug, Clone)]
pub struct SweepFile {
    /// Display label (the file stem by default).
    pub label: String,
    /// The summary's `source` field.
    pub source: String,
    /// Its job points.
    pub records: Vec<SweepRecord>,
}

impl SweepFile {
    fn find(
        &self,
        key: &(u32, u64, String, String, String, String, String),
    ) -> Option<&SweepRecord> {
        self.records.iter().find(|r| &r.key() == key)
    }
}

/// Parse a campaign summary document. Accepts every `ddr4bench.sweep.*`
/// schema version; axis fields missing from older versions get defaults.
pub fn parse_summary(text: &str, label: &str) -> Result<SweepFile> {
    let doc = parse_json(text).map_err(|e| anyhow!("{label}: {e}"))?;
    let schema = doc.get("schema").and_then(Json::as_str).unwrap_or("");
    if !schema.starts_with("ddr4bench.sweep.") {
        return Err(anyhow!("{label}: not a sweep summary (schema `{schema}`)"));
    }
    let source = doc.get("source").and_then(Json::as_str).unwrap_or("unknown").to_string();
    let jobs = match doc.get("jobs") {
        Some(Json::Arr(jobs)) => jobs,
        _ => return Err(anyhow!("{label}: missing `jobs` array")),
    };
    let mut records = Vec::with_capacity(jobs.len());
    for (i, job) in jobs.iter().enumerate() {
        let str_of = |k: &str, default: &str| -> String {
            job.get(k).and_then(Json::as_str).unwrap_or(default).to_string()
        };
        let num_of = |k: &str| -> Result<f64> {
            job.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("{label}: job {i}: missing numeric `{k}`"))
        };
        records.push(SweepRecord {
            speed: str_of("speed", "?"),
            data_rate_mts: num_of("data_rate_mts")? as u32,
            channels: num_of("channels")? as u64,
            pattern: str_of("pattern", "?"),
            mapping: str_of("mapping", "row_col_bank"),
            knobs: str_of("knobs", "mig"),
            sched: str_of("sched", "frfcfs"),
            mix: str_of("mix", ""),
            total_gbs: num_of("total_gbs")?,
            rd_p99_ns: job.get("rd_p99_ns").and_then(Json::as_f64),
        });
    }
    Ok(SweepFile { label: label.to_string(), source, records })
}

/// Load a `BENCH_sweep.json` from disk; the display label is the parent
/// directory + file stem (enough to tell `a/BENCH_sweep` from `b/…`).
pub fn load_sweep(path: &Path) -> Result<SweepFile> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("cannot read {}: {e}", path.display()))?;
    let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("sweep");
    let parent = path
        .parent()
        .and_then(|p| p.file_name())
        .and_then(|s| s.to_str())
        .filter(|p| !p.is_empty());
    let label = match parent {
        Some(p) => format!("{p}/{stem}"),
        None => stem.to_string(),
    };
    parse_summary(&text, &label)
}

// -------------------------------------------------------------- comparison

/// A rendered cross-sweep comparison.
#[derive(Debug, Clone)]
pub struct CompareReport {
    /// Per-job delta table (baseline = first file).
    pub delta: Table,
    /// Best/worst value per sweep axis per file.
    pub axes: Table,
    /// Flagged regressions (delta below `-threshold_pct` vs baseline).
    pub regressions: Vec<String>,
}

/// Compare sweep summaries; `files[0]` is the baseline.
pub fn compare(files: &[SweepFile], threshold_pct: f64) -> CompareReport {
    assert!(!files.is_empty(), "compare needs at least one sweep file");
    let base = &files[0];

    // ordered union of job keys: baseline order first, then new keys in
    // the order later files introduce them
    let mut keys: Vec<(u32, u64, String, String, String, String, String)> = Vec::new();
    for f in files {
        for r in &f.records {
            if !keys.contains(&r.key()) {
                keys.push(r.key());
            }
        }
    }

    let mut headers: Vec<String> = ["Rate", "Ch", "Pattern", "Map", "Knobs", "Sched"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    headers.push(format!("{} GB/s", base.label));
    headers.push("p99 ns".to_string());
    for f in &files[1..] {
        headers.push(format!("{} GB/s", f.label));
        headers.push(format!("{} %", f.label));
        headers.push(format!("{} p99 %", f.label));
    }
    let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut delta = Table::new(
        format!("Cross-sweep comparison (baseline: {})", base.label),
        &header_refs,
    );

    let mut regressions = Vec::new();
    for key in &keys {
        // mix jobs key on the spec, not the label — display whichever
        // label a file actually carries for the point
        let pattern_cell = if key.2.is_empty() && !key.6.is_empty() {
            files
                .iter()
                .find_map(|f| f.find(key).map(|r| r.pattern.clone()))
                .unwrap_or_default()
        } else {
            key.2.clone()
        };
        let mut cells = vec![
            key.0.to_string(),
            key.1.to_string(),
            pattern_cell,
            key.3.clone(),
            key.4.clone(),
            key.5.clone(),
        ];
        let base_rec = base.find(key);
        cells.push(match base_rec {
            Some(r) => format!("{:.3}", r.total_gbs),
            None => "-".to_string(),
        });
        cells.push(match base_rec.and_then(|r| r.rd_p99_ns) {
            Some(p99) => format!("{p99:.0}"),
            None => "-".to_string(),
        });
        for f in &files[1..] {
            match (base_rec, f.find(key)) {
                (Some(b), Some(r)) => {
                    let pct = if b.total_gbs.abs() > f64::EPSILON {
                        (r.total_gbs - b.total_gbs) / b.total_gbs * 100.0
                    } else {
                        0.0
                    };
                    cells.push(format!("{:.3}", r.total_gbs));
                    cells.push(format!("{pct:+.1}"));
                    cells.push(match (b.rd_p99_ns, r.rd_p99_ns) {
                        (Some(bp), Some(rp)) if bp > 0.0 => {
                            format!("{:+.1}", (rp - bp) / bp * 100.0)
                        }
                        _ => "-".to_string(),
                    });
                    if pct < -threshold_pct {
                        regressions.push(format!(
                            "{}: {} {:.3} -> {:.3} GB/s ({pct:+.1}%)",
                            f.label,
                            b.key_label(),
                            b.total_gbs,
                            r.total_gbs
                        ));
                    }
                }
                (_, Some(r)) => {
                    cells.push(format!("{:.3}", r.total_gbs));
                    cells.push("new".to_string());
                    cells.push("-".to_string());
                }
                (_, None) => {
                    cells.push("-".to_string());
                    cells.push("-".to_string());
                    cells.push("-".to_string());
                }
            }
        }
        delta.row(cells);
    }

    CompareReport { delta, axes: axis_extremes(files), regressions }
}

/// Best/worst mean throughput per axis value, per file.
pub fn axis_extremes(files: &[SweepFile]) -> Table {
    let mut t = Table::new(
        "Per-axis extremes (mean total GB/s)",
        &["Axis", "File", "Best", "Worst"],
    );
    let axes: [(&str, fn(&SweepRecord) -> String); 6] = [
        ("rate", |r| r.data_rate_mts.to_string()),
        ("channels", |r| r.channels.to_string()),
        ("pattern", |r| r.pattern.clone()),
        ("mapping", |r| r.mapping.clone()),
        ("knobs", |r| r.knobs.clone()),
        ("sched", |r| r.sched.clone()),
    ];
    for (axis, value_of) in axes {
        for f in files {
            // mean throughput per axis value, in first-seen order
            let mut means: Vec<(String, f64, u32)> = Vec::new();
            for r in &f.records {
                let v = value_of(r);
                match means.iter_mut().find(|(name, _, _)| *name == v) {
                    Some((_, sum, n)) => {
                        *sum += r.total_gbs;
                        *n += 1;
                    }
                    None => means.push((v, r.total_gbs, 1)),
                }
            }
            if means.len() < 2 {
                continue; // a one-value axis has no best/worst contrast
            }
            let mean = |(name, sum, n): &(String, f64, u32)| (name.clone(), sum / *n as f64);
            let best = means
                .iter()
                .map(mean)
                .fold((String::new(), f64::MIN), |a, b| if b.1 > a.1 { b } else { a });
            let worst = means
                .iter()
                .map(mean)
                .fold((String::new(), f64::MAX), |a, b| if b.1 < a.1 { b } else { a });
            t.row(vec![
                axis.to_string(),
                f.label.clone(),
                format!("{} ({:.3})", best.0, best.1),
                format!("{} ({:.3})", worst.0, worst.1),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn summary_sched(
        label: &str,
        jobs: &[(&str, u32, u64, &str, &str, &str, &str, f64, f64)],
    ) -> SweepFile {
        let body: Vec<String> = jobs
            .iter()
            .map(|(speed, rate, ch, pat, map, knob, sched, gbs, p99)| {
                format!(
                    "{{\"schema\": \"ddr4bench.sweep.v3\", \"speed\": \"{speed}\", \
                     \"data_rate_mts\": {rate}, \"channels\": {ch}, \"pattern\": \"{pat}\", \
                     \"mapping\": \"{map}\", \"knobs\": \"{knob}\", \"sched\": \"{sched}\", \
                     \"total_gbs\": {gbs}, \"rd_p99_ns\": {p99}}}"
                )
            })
            .collect();
        let text = format!(
            "{{\"schema\": \"ddr4bench.sweep.v3\", \"source\": \"test\", \"jobs\": [{}]}}",
            body.join(", ")
        );
        parse_summary(&text, label).unwrap()
    }

    fn summary(label: &str, jobs: &[(&str, u32, u64, &str, &str, &str, f64)]) -> SweepFile {
        let with_sched: Vec<(&str, u32, u64, &str, &str, &str, &str, f64, f64)> = jobs
            .iter()
            .map(|&(speed, rate, ch, pat, map, knob, gbs)| {
                (speed, rate, ch, pat, map, knob, "frfcfs", gbs, 100.0)
            })
            .collect();
        summary_sched(label, &with_sched)
    }

    #[test]
    fn json_reader_handles_the_artifact_subset() {
        let v = parse_json(
            "{\"a\": [1, -2.5e1, null, true], \"s\": \"x\\n\\\"y\\u0041\", \"o\": {}}",
        )
        .unwrap();
        assert_eq!(
            v.get("a"),
            Some(&Json::Arr(vec![
                Json::Num(1.0),
                Json::Num(-25.0),
                Json::Null,
                Json::Bool(true)
            ]))
        );
        assert_eq!(v.get("s").and_then(Json::as_str), Some("x\n\"yA"));
        assert_eq!(v.get("o"), Some(&Json::Obj(vec![])));
        assert!(parse_json("{\"a\": }").is_err());
        assert!(parse_json("[1, 2").is_err());
        assert!(parse_json("{} trailing").is_err());
    }

    #[test]
    fn v1_summaries_get_axis_defaults_and_tolerate_nulls() {
        let text = "{\n  \"schema\": \"ddr4bench.sweep.v1\",\n  \"source\": \"analytic\",\n \
                    \"jobs\": [{\"schema\": \"ddr4bench.sweep.v1\", \"id\": 0, \"speed\": \
                    \"DDR4-1600\", \"data_rate_mts\": 1600, \"channels\": 1, \"pattern\": \
                    \"bank\", \"cfg\": \"OP=R\", \"rd_lat_ns\": null, \"total_gbs\": 0.476, \
                    \"per_channel_total_gbs\": [0.476]}]\n}\n";
        let f = parse_summary(text, "baseline").unwrap();
        assert_eq!(f.records.len(), 1);
        assert_eq!(f.records[0].mapping, "row_col_bank");
        assert_eq!(f.records[0].knobs, "mig");
        assert_eq!(f.records[0].sched, "frfcfs", "pre-v3 files get the default policy");
        assert_eq!(f.records[0].rd_p99_ns, None, "pre-v3 files carry no percentiles");
        assert_eq!(f.records[0].data_rate_mts, 1600);
        assert!(parse_summary("{\"schema\": \"other\", \"jobs\": []}", "x").is_err());
    }

    #[test]
    fn sched_axis_distinguishes_jobs_and_p99_deltas_render() {
        let a = summary_sched(
            "base",
            &[
                ("DDR4-1600", 1600, 1, "seq", "row_col_bank", "mig", "frfcfs", 6.0, 200.0),
                ("DDR4-1600", 1600, 1, "seq", "row_col_bank", "mig", "fcfs", 5.8, 220.0),
            ],
        );
        let b = summary_sched(
            "next",
            &[
                ("DDR4-1600", 1600, 1, "seq", "row_col_bank", "mig", "frfcfs", 6.0, 300.0),
                ("DDR4-1600", 1600, 1, "seq", "row_col_bank", "mig", "fcfs", 5.8, 220.0),
            ],
        );
        let rep = compare(&[a, b], 2.0);
        assert_eq!(rep.delta.rows.len(), 2, "policies are distinct job points");
        let ascii = rep.delta.ascii();
        assert!(ascii.contains("Sched"), "{ascii}");
        assert!(ascii.contains("fcfs"), "{ascii}");
        assert!(ascii.contains("+50.0"), "p99 delta rendered: {ascii}");
        assert!(rep.regressions.is_empty(), "p99 shifts alone are not regressions");
    }

    #[test]
    fn v4_mix_field_distinguishes_jobs_and_defaults_empty() {
        // two jobs identical on every axis except the mix spec must stay
        // distinct job points; pre-v4 records default to no mix
        let text = "{\"schema\": \"ddr4bench.sweep.v4\", \"source\": \"test\", \"jobs\": [\
                    {\"data_rate_mts\": 1600, \"channels\": 2, \"pattern\": \"hetero\", \
                     \"mix\": \"0:OP=R,ADDR=SEQ 1:OP=R,ADDR=CHASE\", \"total_gbs\": 6.5}, \
                    {\"data_rate_mts\": 1600, \"channels\": 2, \"pattern\": \"hetero\", \
                     \"mix\": \"0:OP=R,ADDR=SEQ 1:OP=R,ADDR=BANK\", \"total_gbs\": 6.8}]}";
        let f = parse_summary(text, "mixes").unwrap();
        assert_eq!(f.records.len(), 2);
        assert_ne!(f.records[0].key(), f.records[1].key(), "mix is part of the key");
        assert!(f.records[0].key_label().contains("[0:OP=R,ADDR=SEQ"), "mix in the label");
        let rep = compare(&[f.clone(), f], 2.0);
        assert_eq!(rep.delta.rows.len(), 2, "mix jobs do not collapse");
        assert!(rep.delta.ascii().contains("hetero"), "label still displayed");
        // the label is NOT part of a mix job's key: the same spec under a
        // collision-suffixed auto label still matches itself across files
        let a_text = "{\"schema\": \"ddr4bench.sweep.v4\", \"source\": \"t\", \"jobs\": [\
                      {\"data_rate_mts\": 1600, \"channels\": 2, \"pattern\": \"seq+chase_2\", \
                       \"mix\": \"0:OP=R,ADDR=SEQ 1:OP=R,ADDR=CHASE\", \"total_gbs\": 6.0}]}";
        let b_text = "{\"schema\": \"ddr4bench.sweep.v4\", \"source\": \"t\", \"jobs\": [\
                      {\"data_rate_mts\": 1600, \"channels\": 2, \"pattern\": \"seq+chase\", \
                       \"mix\": \"0:OP=R,ADDR=SEQ 1:OP=R,ADDR=CHASE\", \"total_gbs\": 3.0}]}";
        let a = parse_summary(a_text, "a").unwrap();
        let b = parse_summary(b_text, "b").unwrap();
        let rep = compare(&[a, b], 2.0);
        assert_eq!(rep.delta.rows.len(), 1, "same spec matches despite differing labels");
        assert_eq!(rep.regressions.len(), 1, "-50% regression caught: {:?}", rep.regressions);
        // v3 records (no mix field) load with the empty default
        let v3 = summary("old", &[("DDR4-1600", 1600, 1, "seq", "row_col_bank", "mig", 6.0)]);
        assert_eq!(v3.records[0].mix, "");
    }

    #[test]
    fn compare_renders_deltas_and_flags_regressions() {
        let a = summary(
            "base",
            &[
                ("DDR4-1600", 1600, 1, "bank", "row_col_bank", "mig", 1.0),
                ("DDR4-1600", 1600, 1, "seq", "row_col_bank", "mig", 6.0),
            ],
        );
        let b = summary(
            "next",
            &[
                ("DDR4-1600", 1600, 1, "bank", "row_col_bank", "mig", 0.5),
                ("DDR4-1600", 1600, 1, "seq", "row_col_bank", "mig", 6.3),
                ("DDR4-1600", 1600, 1, "seq", "xor_hash", "mig", 6.1),
            ],
        );
        let rep = compare(&[a, b], 2.0);
        assert_eq!(rep.delta.rows.len(), 3, "union of job keys");
        let ascii = rep.delta.ascii();
        assert!(ascii.contains("-50.0"), "{ascii}");
        assert!(ascii.contains("+5.0"), "{ascii}");
        assert!(ascii.contains("new"), "{ascii}");
        assert_eq!(rep.regressions.len(), 1, "{:?}", rep.regressions);
        assert!(rep.regressions[0].contains("bank"), "{:?}", rep.regressions);
        assert!(rep.regressions[0].contains("-50.0%"), "{:?}", rep.regressions);
        // small dips below the threshold are not flagged
        assert!(compare(
            &[
                summary("x", &[("DDR4-1600", 1600, 1, "seq", "row_col_bank", "mig", 6.0)]),
                summary("y", &[("DDR4-1600", 1600, 1, "seq", "row_col_bank", "mig", 5.95)]),
            ],
            2.0,
        )
        .regressions
        .is_empty());
    }

    #[test]
    fn axis_extremes_pick_best_and_worst_per_axis() {
        let f = summary(
            "only",
            &[
                ("DDR4-1600", 1600, 1, "seq", "row_col_bank", "mig", 6.0),
                ("DDR4-1600", 1600, 1, "bank", "row_col_bank", "mig", 0.5),
                ("DDR4-1600", 1600, 1, "seq", "row_bank_col", "mig", 4.0),
                ("DDR4-1600", 1600, 1, "bank", "row_bank_col", "mig", 0.4),
            ],
        );
        let t = axis_extremes(&[f]);
        let ascii = t.ascii();
        // pattern axis: seq best, bank worst; mapping axis: MIG order best
        assert!(ascii.contains("pattern"), "{ascii}");
        assert!(ascii.contains("seq (5.000)"), "{ascii}");
        assert!(ascii.contains("bank (0.450)"), "{ascii}");
        assert!(ascii.contains("row_col_bank (3.250)"), "{ascii}");
        // single-value axes (rate, channels, knobs) produce no rows
        assert!(!ascii.contains("rate"), "{ascii}");
    }

    #[test]
    fn the_committed_repo_baseline_loads() {
        // the analytic-model v1 baseline at the repo root must stay
        // loadable so CI can diff fresh sweeps against it
        let root = std::env::var("CARGO_MANIFEST_DIR").unwrap();
        let path = std::path::Path::new(&root).join("BENCH_sweep.json");
        let f = load_sweep(&path).unwrap();
        assert_eq!(f.records.len(), 12, "12-job paper grid");
        assert!(f.records.iter().all(|r| r.mapping == "row_col_bank"));
        assert!(f.records.iter().all(|r| r.sched == "frfcfs"));
        assert!(f.records.iter().all(|r| r.total_gbs > 0.0));
    }
}
