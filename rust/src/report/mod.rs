//! Table / figure-series rendering and the paper-campaign drivers.
//!
//! [`Table`] renders ASCII and CSV; [`campaign`] holds the drivers that
//! regenerate every table and figure of the paper's evaluation (shared by
//! `examples/paper_campaign.rs` and the `cargo bench` targets so the
//! numbers always come from one code path); [`compare`] loads several
//! `BENCH_sweep.json` campaign summaries and renders cross-sweep delta
//! tables (the `ddr4bench compare` subcommand);
//! [`interference_tables`] renders the solo-vs-co-run channel
//! interference matrix (the `ddr4bench interference` subcommand);
//! [`timeline_table`] renders a telemetry series as a
//! bandwidth-over-time table (the `ddr4bench run --telemetry` report).

pub mod campaign;
pub mod compare;

use crate::obs::export::window_bw_gbs;
use crate::obs::TelemetrySeries;
use crate::platform::InterferenceMatrix;

/// A rendered results table.
#[derive(Debug, Clone)]
pub struct Table {
    /// Caption (printed above the table).
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Data rows.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New empty table.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render as an aligned ASCII table.
    pub fn ascii(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "+";
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("| {:<w$} ", c, w = widths[i]))
                .collect::<String>()
                + "|"
        };
        let mut out = format!("{}\n{sep}\n{}\n{sep}\n", self.title, fmt_row(&self.headers));
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Render as CSV (headers first).
    pub fn csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = self.headers.iter().map(esc).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write the CSV beside other campaign outputs.
    pub fn write_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.csv())
    }
}

/// Percentage delta of `co` against `solo` (`+0.0%` when solo is zero —
/// nothing to degrade).
fn delta_pct(solo: f64, co: f64) -> f64 {
    if solo.abs() > f64::EPSILON {
        (co - solo) / solo * 100.0
    } else {
        0.0
    }
}

/// Render an [`InterferenceMatrix`] as two `compare`-style delta tables:
/// per-pair total bandwidth and p99 latency under co-scheduling, each
/// cell annotated with its percentage degradation against the workload's
/// solo run. Rows are the measured workload, columns its co-runner.
pub fn interference_tables(m: &InterferenceMatrix) -> (Table, Table) {
    let mut headers: Vec<String> = vec!["Workload".into(), "Solo".into()];
    for label in &m.labels {
        headers.push(format!("vs {label}"));
    }
    let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut bw = Table::new(
        "Channel-interference matrix: total GB/s co-run (delta% vs solo)",
        &header_refs,
    );
    let mut lat = Table::new(
        "Channel-interference matrix: p99 latency ns co-run (delta% vs solo)",
        &header_refs,
    );
    for (i, label) in m.labels.iter().enumerate() {
        let mut bw_cells = vec![label.clone(), format!("{:.3}", m.solo_gbs[i])];
        let mut lat_cells = vec![label.clone(), format!("{:.0}", m.solo_p99_ns[i])];
        for j in 0..m.labels.len() {
            bw_cells.push(format!(
                "{:.3} ({:+.1}%)",
                m.co_gbs[i][j],
                delta_pct(m.solo_gbs[i], m.co_gbs[i][j])
            ));
            lat_cells.push(format!(
                "{:.0} ({:+.1}%)",
                m.co_p99_ns[i][j],
                delta_pct(m.solo_p99_ns[i], m.co_p99_ns[i][j])
            ));
        }
        bw.row(bw_cells);
        lat.row(lat_cells);
    }
    (bw, lat)
}

/// Render one channel's telemetry series as a bandwidth-over-time table
/// (the `ddr4bench run --telemetry` report). Window stamps stay in AXI
/// cycles (the series' native, engine-identical unit); bandwidth and the
/// p99 latencies convert through `axi_ns` (the AXI clock period).
pub fn timeline_table(label: &str, series: &TelemetrySeries, axi_ns: f64) -> Table {
    let mut t = Table::new(
        format!(
            "Telemetry timeline [{label}]: {} window(s) x {} AXI cycles ({} dropped)",
            series.windows.len(),
            series.window,
            series.dropped
        ),
        &[
            "Win", "Start", "End", "BW GB/s", "RD B", "WR B", "QD", "Banks", "ACT", "PRE",
            "RefStall", "p99 ns",
        ],
    );
    for (i, w) in series.windows.iter().enumerate() {
        t.row(vec![
            i.to_string(),
            w.start.to_string(),
            w.end.to_string(),
            format!("{:.2}", window_bw_gbs(w, axi_ns)),
            w.rd_bytes.to_string(),
            w.wr_bytes.to_string(),
            w.queue_depth.to_string(),
            w.open_banks.to_string(),
            w.acts.to_string(),
            w.pres.to_string(),
            w.refresh_stall.to_string(),
            format!("{:.0}", w.rd_p99.max(w.wr_p99) as f64 * axi_ns),
        ]);
    }
    t
}

/// A figure data series: (x, y) points with a label — the reproduction of
/// a paper plot line. Rendered as CSV columns plus a coarse ASCII chart.
#[derive(Debug, Clone)]
pub struct Series {
    /// Legend label (e.g. "Seq-R 1600").
    pub label: String,
    /// (x, y) points.
    pub points: Vec<(f64, f64)>,
}

/// A figure = several series over a shared x axis.
#[derive(Debug, Clone)]
pub struct Figure {
    /// Caption.
    pub title: String,
    /// X-axis label.
    pub x_label: String,
    /// Y-axis label.
    pub y_label: String,
    /// The series.
    pub series: Vec<Series>,
}

impl Figure {
    /// New empty figure.
    pub fn new(title: impl Into<String>, x_label: &str, y_label: &str) -> Self {
        Self {
            title: title.into(),
            x_label: x_label.into(),
            y_label: y_label.into(),
            series: Vec::new(),
        }
    }

    /// Add a series.
    pub fn push(&mut self, label: impl Into<String>, points: Vec<(f64, f64)>) {
        self.series.push(Series { label: label.into(), points });
    }

    /// CSV: x column then one column per series.
    pub fn csv(&self) -> String {
        let mut xs: Vec<f64> =
            self.series.iter().flat_map(|s| s.points.iter().map(|p| p.0)).collect();
        xs.sort_by(f64::total_cmp);
        xs.dedup();
        let mut out = format!(
            "{},{}\n",
            self.x_label,
            self.series.iter().map(|s| s.label.clone()).collect::<Vec<_>>().join(",")
        );
        for x in xs {
            out.push_str(&format!("{x}"));
            for s in &self.series {
                match s.points.iter().find(|p| p.0 == x) {
                    Some((_, y)) => out.push_str(&format!(",{y:.4}")),
                    None => out.push(','),
                }
            }
            out.push('\n');
        }
        out
    }

    /// Coarse ASCII bar chart per series (terminal-friendly).
    pub fn ascii(&self) -> String {
        let ymax = self
            .series
            .iter()
            .flat_map(|s| s.points.iter().map(|p| p.1))
            .fold(0.0f64, f64::max)
            .max(1e-9);
        let mut out = format!("{}  [{} vs {}]\n", self.title, self.y_label, self.x_label);
        for s in &self.series {
            out.push_str(&format!("  {}\n", s.label));
            for (x, y) in &s.points {
                let bar = "#".repeat(((y / ymax) * 50.0).round() as usize);
                out.push_str(&format!("    {x:>8} | {bar} {y:.2}\n"));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_ascii_alignment() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(vec!["xxxx".into(), "1".into()]);
        let s = t.ascii();
        assert!(s.contains("| a    | bbbb |"));
        assert!(s.contains("| xxxx | 1    |"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn table_csv_escaping() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"q".into()]);
        let csv = t.csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"q\""));
    }

    #[test]
    fn figure_csv_merges_x() {
        let mut f = Figure::new("F", "x", "gbs");
        f.push("s1", vec![(1.0, 2.0), (2.0, 3.0)]);
        f.push("s2", vec![(2.0, 5.0)]);
        let csv = f.csv();
        assert!(csv.starts_with("x,s1,s2\n"));
        assert!(csv.contains("1,2.0000,\n"));
        assert!(csv.contains("2,3.0000,5.0000\n"));
    }

    #[test]
    fn figure_ascii_renders_bars() {
        let mut f = Figure::new("F", "len", "GB/s");
        f.push("a", vec![(1.0, 1.0), (2.0, 2.0)]);
        let a = f.ascii();
        assert!(a.contains("##"));
    }

    #[test]
    fn timeline_table_renders_bandwidth_over_time() {
        let series = TelemetrySeries {
            window: 100,
            windows: vec![crate::obs::TelemetryWindow {
                start: 0,
                end: 100,
                rd_bytes: 32,
                wr_bytes: 32,
                queue_depth: 2,
                open_banks: 1,
                acts: 3,
                pres: 2,
                refresh_stall: 0,
                rd_p50: 8,
                rd_p99: 16,
                wr_p50: 0,
                wr_p99: 0,
            }],
            dropped: 0,
        };
        let t = timeline_table("seq", &series, 5.0);
        assert!(t.title.contains("1 window(s) x 100 AXI cycles"), "{}", t.title);
        assert_eq!(t.rows.len(), 1);
        assert_eq!(t.rows[0][1], "0", "start stamp in AXI cycles");
        assert_eq!(t.rows[0][3], "0.13", "64 bytes over 500 ns");
        assert_eq!(t.rows[0][11], "80", "p99 = max(rd, wr) x axi_ns");
    }

    #[test]
    fn interference_tables_render_deltas() {
        let m = InterferenceMatrix {
            labels: vec!["seq".into(), "bank".into()],
            solo_gbs: vec![6.0, 0.5],
            solo_p99_ns: vec![200.0, 2000.0],
            co_gbs: vec![vec![6.0, 3.0], vec![0.5, 0.4]],
            co_p99_ns: vec![vec![200.0, 400.0], vec![2000.0, 2500.0]],
        };
        let (bw, lat) = interference_tables(&m);
        assert_eq!(bw.rows.len(), 2);
        let a = bw.ascii();
        assert!(a.contains("vs bank"), "{a}");
        assert!(a.contains("3.000 (-50.0%)"), "bandwidth degradation cell: {a}");
        assert!(a.contains("6.000 (+0.0%)"), "self pair unchanged: {a}");
        let l = lat.ascii();
        assert!(l.contains("400 (+100.0%)"), "p99 inflation cell: {l}");
        // zero-solo guard: no NaN/inf in the rendering
        let z = InterferenceMatrix {
            labels: vec!["a".into(), "b".into()],
            solo_gbs: vec![0.0, 1.0],
            solo_p99_ns: vec![0.0, 1.0],
            co_gbs: vec![vec![0.0, 0.0], vec![1.0, 1.0]],
            co_p99_ns: vec![vec![0.0, 0.0], vec![1.0, 1.0]],
        };
        let (bw, _) = interference_tables(&z);
        assert!(!bw.ascii().contains("NaN"), "{}", bw.ascii());
    }
}
