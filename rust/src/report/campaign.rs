//! Drivers that regenerate every table and figure of the paper's
//! evaluation section (the experiment index of DESIGN.md §4).
//!
//! All drivers run the same [`Platform`] executive the host controller
//! uses, so `examples/paper_campaign.rs`, the bench targets, and the
//! integration tests all report the same numbers.

use crate::analytic;
use crate::config::{AddrMode, DesignConfig, OpMix, PatternConfig, SpeedBin};
use crate::platform::Platform;
use crate::report::{Figure, Table};
use crate::stats::BatchStats;

/// Burst lengths used by the figures (x axis of Fig. 2).
pub const FIG2_LENGTHS: [u32; 8] = [1, 2, 4, 8, 16, 32, 64, 128];

/// Campaign sizing: how many transactions to run per configuration point.
/// Scaled so every point moves roughly the same number of bytes; `scale`
/// shrinks everything for quick runs (benches use 0.25, tests 0.1).
pub fn batch_for(burst_len: u32, scale: f64) -> u32 {
    let target_bytes = (8.0 * (1 << 20) as f64 * scale).max(64.0 * 1024.0);
    let txn_bytes = (burst_len * 32) as f64;
    ((target_bytes / txn_bytes) as u32).clamp(256, 16384)
}

/// Run one configuration point and return its stats.
pub fn run_point(
    platform: &mut Platform,
    op: OpMix,
    addr: &AddrMode,
    burst_len: u32,
    scale: f64,
) -> BatchStats {
    let mut cfg = PatternConfig::seq_read_burst(burst_len, batch_for(burst_len, scale));
    cfg.op = op;
    cfg.addr = addr.clone();
    platform.run_batch(0, &cfg).expect("campaign batch failed")
}

/// Throughput of a point using the paper's reporting convention: R = read
/// counter, W = write counter, M = combined.
pub fn gbs_of(op: OpMix, s: &BatchStats) -> f64 {
    match op {
        OpMix::ReadOnly => s.read_throughput_gbs(),
        OpMix::WriteOnly => s.write_throughput_gbs(),
        OpMix::Mixed { .. } => s.total_throughput_gbs(),
    }
}

/// Measured data behind Table IV: throughput (GB/s) of single-channel
/// DDR4-1600 for R/W × Seq/Rnd × {1, 4, 32, 128}.
#[derive(Debug, Clone)]
pub struct Table4Data {
    /// `[read=0|write=1][seq=0|rnd=1][len index over {1,4,32,128}]`
    pub gbs: [[[f64; 4]; 2]; 2],
}

/// Table IV burst lengths with the paper's labels.
pub const TABLE4_LENGTHS: [(u32, &str); 4] =
    [(1, "Single"), (4, "Short (4)"), (32, "Medium (32)"), (128, "Long (128)")];

/// Run the Table IV campaign (single-channel DDR4-1600).
pub fn table4_data(scale: f64) -> Table4Data {
    let mut platform = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
    let mut gbs = [[[0.0; 4]; 2]; 2];
    for (oi, op) in [OpMix::ReadOnly, OpMix::WriteOnly].iter().enumerate() {
        for (ai, addr) in
            [AddrMode::Sequential, AddrMode::Random { seed: 0xBEEF }].iter().enumerate()
        {
            for (li, (len, _)) in TABLE4_LENGTHS.iter().enumerate() {
                let s = run_point(&mut platform, *op, addr, *len, scale);
                gbs[oi][ai][li] = gbs_of(*op, &s);
            }
        }
    }
    Table4Data { gbs }
}

/// Render Table IV in the paper's layout.
pub fn table4(scale: f64) -> (Table, Table4Data) {
    let d = table4_data(scale);
    let mut t = Table::new(
        "Table IV: Throughput (GB/s), single-channel DDR4-1600",
        &["Operation", "Mode", "Length (#)", "Sequential", "Random"],
    );
    for (oi, op) in ["Read", "Write"].iter().enumerate() {
        for (li, (_, label)) in TABLE4_LENGTHS.iter().enumerate() {
            let mode = if li == 0 { "Single" } else { "Burst" };
            t.row(vec![
                if li == 0 { op.to_string() } else { String::new() },
                mode.into(),
                if li == 0 { String::new() } else { label.to_string() },
                format!("{:.2}", d.gbs[oi][0][li]),
                format!("{:.2}", d.gbs[oi][1][li]),
            ]);
        }
    }
    (t, d)
}

/// Fig. 2: throughput vs burst length for DDR4-1600 and DDR4-2400,
/// Seq/Rnd × R/W/M. Returns one figure per data rate plus the raw points.
pub fn fig2(scale: f64) -> Vec<Figure> {
    let mut figs = Vec::new();
    for speed in [SpeedBin::Ddr4_1600, SpeedBin::Ddr4_2400] {
        let mut platform = Platform::new(DesignConfig::single_channel(speed));
        let mut fig = Figure::new(
            format!("Fig. 2: throughput, single-channel {speed}"),
            "burst length",
            "GB/s",
        );
        for (addr, alabel) in
            [(AddrMode::Sequential, "Seq"), (AddrMode::Random { seed: 0xF00D }, "Rnd")]
        {
            for (op, olabel) in [
                (OpMix::ReadOnly, "R"),
                (OpMix::WriteOnly, "W"),
                (OpMix::Mixed { read_pct: 50 }, "M"),
            ] {
                let pts = FIG2_LENGTHS
                    .iter()
                    .map(|&len| {
                        let s = run_point(&mut platform, op, &addr, len, scale);
                        (len as f64, gbs_of(op, &s))
                    })
                    .collect();
                fig.push(format!("{alabel}-{olabel}"), pts);
            }
        }
        figs.push(fig);
    }
    figs
}

/// Fig. 3: read/write throughput breakdown of mixed workloads,
/// single-channel DDR4-1600, S/SB/MB/LB × Seq/Rnd.
pub fn fig3(scale: f64) -> Table {
    let mut platform = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
    let mut t = Table::new(
        "Fig. 3: mixed R/W throughput breakdown (GB/s), single-channel DDR4-1600",
        &["Addressing", "Transactions", "Read", "Write", "Combined"],
    );
    for (addr, alabel) in
        [(AddrMode::Sequential, "Sequential"), (AddrMode::Random { seed: 0xCAFE }, "Random")]
    {
        for (len, label) in [(1, "S"), (4, "SB"), (32, "MB"), (128, "LB")] {
            let s = run_point(&mut platform, OpMix::Mixed { read_pct: 50 }, &addr, len, scale);
            t.row(vec![
                alabel.into(),
                label.into(),
                format!("{:.2}", s.read_throughput_gbs()),
                format!("{:.2}", s.write_throughput_gbs()),
                format!("{:.2}", s.total_throughput_gbs()),
            ]);
        }
    }
    t
}

/// §III-A channel-scaling claim: dual/triple channels deliver 2x/3x.
pub fn scaling(scale: f64) -> Table {
    let mut t = Table::new(
        "Channel scaling (seq read, burst 32, DDR4-1600)",
        &["Channels", "Aggregate GB/s", "Per-channel GB/s", "Scaling"],
    );
    let mut base = 0.0;
    for n in 1..=3usize {
        let mut p = Platform::new(DesignConfig::with_channels(n, SpeedBin::Ddr4_1600));
        let cfg = PatternConfig::seq_read_burst(32, batch_for(32, scale));
        let per = p.run_batch_all(&cfg).expect("scaling batch");
        let agg = Platform::aggregate(&per);
        let total = agg.read_throughput_gbs();
        if n == 1 {
            base = total;
        }
        t.row(vec![
            n.to_string(),
            format!("{total:.2}"),
            format!("{:.2}", total / n as f64),
            format!("{:.2}x", total / base),
        ]);
    }
    t
}

/// §III-C analysis: the paper's headline ratios, paper value vs measured.
pub fn analysis(scale: f64) -> Table {
    let d1600 = table4_data(scale);
    // DDR4-2400 equivalents for the uplift rows.
    let mut p2400 = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_2400));
    let mut p1600 = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
    let seq_r = |p: &mut Platform, len| {
        gbs_of(OpMix::ReadOnly, &run_point(p, OpMix::ReadOnly, &AddrMode::Sequential, len, scale))
    };
    let rnd_r = |p: &mut Platform, len| {
        gbs_of(
            OpMix::ReadOnly,
            &run_point(p, OpMix::ReadOnly, &AddrMode::Random { seed: 0xF00D }, len, scale),
        )
    };
    let mix_seq = |p: &mut Platform, len| {
        gbs_of(
            OpMix::Mixed { read_pct: 50 },
            &run_point(p, OpMix::Mixed { read_pct: 50 }, &AddrMode::Sequential, len, scale),
        )
    };

    let mut t = Table::new(
        "§III analysis: paper claim vs measured",
        &["Claim", "Paper", "Measured"],
    );
    let rd_drop = d1600.gbs[0][0][0] / d1600.gbs[0][1][0];
    let wr_drop = d1600.gbs[1][0][0] / d1600.gbs[1][1][0];
    t.row(vec![
        "Seq→Rnd read drop (singles)".into(),
        "5.5x".into(),
        format!("{rd_drop:.1}x"),
    ]);
    t.row(vec![
        "Seq→Rnd write drop (singles)".into(),
        "7.2x".into(),
        format!("{wr_drop:.1}x"),
    ]);
    t.row(vec![
        "Short-burst speedup vs single (seq read)".into(),
        "~2x".into(),
        format!("{:.1}x", d1600.gbs[0][0][1] / d1600.gbs[0][0][0]),
    ]);
    t.row(vec![
        "Short-burst speedup vs single (rnd read)".into(),
        "~4x".into(),
        format!("{:.1}x", d1600.gbs[0][1][1] / d1600.gbs[0][1][0]),
    ]);
    let seq_uplift = seq_r(&mut p2400, 128) / seq_r(&mut p1600, 128);
    t.row(vec![
        "2400/1600 uplift, seq read (long burst)".into(),
        "up to 1.50x".into(),
        format!("{seq_uplift:.2}x"),
    ]);
    let rnd_uplift_16 = rnd_r(&mut p2400, 16) / rnd_r(&mut p1600, 16);
    let rnd_uplift_128 = rnd_r(&mut p2400, 128) / rnd_r(&mut p1600, 128);
    t.row(vec![
        "2400/1600 uplift, rnd read burst 16".into(),
        "1.07x".into(),
        format!("{rnd_uplift_16:.2}x"),
    ]);
    t.row(vec![
        "2400/1600 uplift, rnd read burst 128".into(),
        "1.32x".into(),
        format!("{rnd_uplift_128:.2}x"),
    ]);
    let mix_1600 = mix_seq(&mut p1600, 128);
    let mix_2400 = mix_seq(&mut p2400, 128);
    t.row(vec![
        "Mixed seq max, DDR4-1600".into(),
        "7.99 GB/s".into(),
        format!("{mix_1600:.2} GB/s"),
    ]);
    t.row(vec![
        "Mixed seq max, DDR4-2400".into(),
        "12.02 GB/s".into(),
        format!("{mix_2400:.2} GB/s"),
    ]);
    t
}

/// Simulator-vs-analytic-model cross-check over the Table IV grid; returns
/// (table, mean absolute relative error).
pub fn model_check(scale: f64) -> (Table, f64) {
    let d = table4_data(scale);
    let mut t = Table::new(
        "Analytic model vs simulator (Table IV grid, DDR4-1600)",
        &["Op", "Addr", "Len", "Simulated", "Model", "Rel err"],
    );
    let mut errs = Vec::new();
    for (oi, op) in [OpMix::ReadOnly, OpMix::WriteOnly].iter().enumerate() {
        for (ai, addr) in
            [AddrMode::Sequential, AddrMode::Random { seed: 0 }].iter().enumerate()
        {
            for (li, (len, _)) in TABLE4_LENGTHS.iter().enumerate() {
                let sim = d.gbs[oi][ai][li];
                let mut cfg = PatternConfig::seq_read_burst(*len, 1);
                cfg.op = *op;
                cfg.addr = addr.clone();
                // mapping-aware prediction: the derate is exactly 1.0 on
                // the default bank-interleaved geometry this grid uses,
                // and kicks in when a design re-maps to a row-major order
                let geo = crate::config::DesignConfig::default().geometry;
                let model =
                    analytic::predict_pattern_mapped(SpeedBin::Ddr4_1600, &cfg, 32, &geo) as f64;
                let err = (model - sim).abs() / sim.max(1e-9);
                errs.push(err);
                t.row(vec![
                    op.label().into(),
                    addr.label().into(),
                    len.to_string(),
                    format!("{sim:.2}"),
                    format!("{model:.2}"),
                    format!("{:.0}%", err * 100.0),
                ]);
            }
        }
    }
    let mae = errs.iter().sum::<f64>() / errs.len() as f64;
    (t, mae)
}
