//! Minimal config-file / key-value parser (offline substitute for
//! serde + toml; see DESIGN.md §9).
//!
//! Format: `key = value` lines, `#` comments, optional `[section]` headers
//! that prefix keys as `section.key`. The same `KEY=VALUE` tokens are also
//! what the host-controller protocol uses inline in `CFG` commands, so both
//! paths share the conversion functions here.

use super::{
    AddrMode, BurstKind, ChannelMix, ControllerParams, CounterSet, DataPattern, DesignConfig,
    EngineKind, OpMix, PatternConfig, SchedKind, Signaling, SpeedBin,
};
use crate::ddr4::mapping::MappingPolicy;
use std::collections::BTreeMap;

/// Error produced when parsing or validating a configuration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConfigError {
    msg: String,
}

impl ConfigError {
    /// Build an error from any printable message.
    pub fn new(msg: impl Into<String>) -> Self {
        Self { msg: msg.into() }
    }
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config error: {}", self.msg)
    }
}

impl std::error::Error for ConfigError {}

/// Parse `key = value` text (with `[section]` support) into a flat map of
/// `section.key -> value`. Later keys override earlier ones.
pub fn parse_kv_text(text: &str) -> Result<BTreeMap<String, String>, ConfigError> {
    let mut map = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix('[') {
            let name = inner.strip_suffix(']').ok_or_else(|| {
                ConfigError::new(format!("line {}: unterminated section header", lineno + 1))
            })?;
            section = name.trim().to_ascii_lowercase();
            continue;
        }
        let (k, v) = line.split_once('=').ok_or_else(|| {
            ConfigError::new(format!("line {}: expected `key = value`", lineno + 1))
        })?;
        let key = if section.is_empty() {
            k.trim().to_ascii_lowercase()
        } else {
            format!("{}.{}", section, k.trim().to_ascii_lowercase())
        };
        map.insert(key, v.trim().to_string());
    }
    Ok(map)
}

fn get_usize(
    map: &BTreeMap<String, String>,
    key: &str,
    default: usize,
) -> Result<usize, ConfigError> {
    match map.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|_| ConfigError::new(format!("{key}: expected integer, got `{v}`"))),
    }
}

fn get_u32(map: &BTreeMap<String, String>, key: &str, default: u32) -> Result<u32, ConfigError> {
    Ok(get_usize(map, key, default as usize)? as u32)
}

fn get_bool(map: &BTreeMap<String, String>, key: &str, default: bool) -> Result<bool, ConfigError> {
    match map.get(key).map(|s| s.to_ascii_lowercase()) {
        None => Ok(default),
        Some(v) => match v.as_str() {
            "true" | "1" | "yes" | "on" => Ok(true),
            "false" | "0" | "no" | "off" => Ok(false),
            _ => Err(ConfigError::new(format!("{key}: expected bool, got `{v}`"))),
        },
    }
}

/// Parse `123`, `4k`, `16m`, `2g` (binary suffixes) into bytes/counts.
/// Values whose suffixed product exceeds `u64::MAX` parse as `None`.
pub fn parse_u64_with_suffix(s: &str) -> Option<u64> {
    let s = s.trim().to_ascii_lowercase();
    let (num, mult) = if let Some(n) = s.strip_suffix('k') {
        (n, 1u64 << 10)
    } else if let Some(n) = s.strip_suffix('m') {
        (n, 1u64 << 20)
    } else if let Some(n) = s.strip_suffix('g') {
        (n, 1u64 << 30)
    } else {
        (s.as_str(), 1)
    };
    num.trim().parse::<u64>().ok().and_then(|v| v.checked_mul(mult))
}

/// Build a [`DesignConfig`] from config text. Recognized keys (all
/// optional; defaults = single-channel DDR4-1600 MIG-like profile):
///
/// ```text
/// channels = 3
/// speed = 2400                 # or "ddr4-2400"
/// axi_width = 256              # bits
/// mapping = row_col_bank       # address-mapping policy (or e.g. RoBaBgCo)
/// telemetry = 4096             # time-series window in AXI cycles (off if absent)
/// [counters]  batch_cycles/latency/refresh/integrity = true|false
/// [controller] read_queue_depth / write_queue_depth / lookahead /
///              write_drain_high / write_drain_low / outstanding_cap /
///              idle_precharge_cycles / addr_cmd_interval_axi /
///              sched = fcfs|frfcfs|frfcfs-cap[N]|closed|adaptive
/// ```
pub fn parse_design_config(text: &str) -> Result<DesignConfig, ConfigError> {
    let map = parse_kv_text(text)?;
    let mut cfg = DesignConfig::default();
    if let Some(v) = map.get("speed") {
        cfg.speed = SpeedBin::parse(v)
            .ok_or_else(|| ConfigError::new(format!("speed: unknown bin `{v}`")))?;
    }
    cfg.channels = get_usize(&map, "channels", cfg.channels)?;
    if let Some(v) = map.get("mapping") {
        cfg.geometry.mapping = MappingPolicy::parse(v)
            .ok_or_else(|| ConfigError::new(format!("mapping: unknown policy `{v}`")))?;
    }
    if let Some(v) = map.get("engine") {
        cfg.engine = EngineKind::parse(v)
            .ok_or_else(|| ConfigError::new(format!("engine: unknown engine `{v}`")))?;
    }
    if let Some(v) = map.get("telemetry") {
        cfg.telemetry = Some(parse_u64_with_suffix(v).ok_or_else(|| {
            ConfigError::new(format!("telemetry: expected window cycles, got `{v}`"))
        })?);
    }
    cfg.axi_data_width_bits = get_u32(&map, "axi_width", cfg.axi_data_width_bits)?;
    cfg.counters = CounterSet {
        batch_cycles: get_bool(&map, "counters.batch_cycles", true)?,
        latency: get_bool(&map, "counters.latency", true)?,
        refresh: get_bool(&map, "counters.refresh", true)?,
        integrity: get_bool(&map, "counters.integrity", true)?,
    };
    let d = ControllerParams::default();
    cfg.controller = ControllerParams {
        read_queue_depth: get_usize(&map, "controller.read_queue_depth", d.read_queue_depth)?,
        write_queue_depth: get_usize(&map, "controller.write_queue_depth", d.write_queue_depth)?,
        lookahead: get_usize(&map, "controller.lookahead", d.lookahead)?,
        write_drain_high: get_usize(&map, "controller.write_drain_high", d.write_drain_high)?,
        write_drain_low: get_usize(&map, "controller.write_drain_low", d.write_drain_low)?,
        outstanding_cap: get_usize(&map, "controller.outstanding_cap", d.outstanding_cap)?,
        idle_precharge_cycles: get_u32(
            &map,
            "controller.idle_precharge_cycles",
            d.idle_precharge_cycles,
        )?,
        addr_cmd_interval_axi: get_u32(
            &map,
            "controller.addr_cmd_interval_axi",
            d.addr_cmd_interval_axi,
        )?,
        serial_frontend: get_bool(&map, "controller.serial_frontend", d.serial_frontend)?,
        miss_flush: get_bool(&map, "controller.miss_flush", d.miss_flush)?,
        mode_dwell_ck: get_u32(&map, "controller.mode_dwell_ck", d.mode_dwell_ck)?,
        sched: match map.get("controller.sched") {
            None => d.sched,
            Some(v) => SchedKind::parse(v).ok_or_else(|| {
                ConfigError::new(format!("controller.sched: unknown policy `{v}`"))
            })?,
        },
        sched_oracle: get_bool(&map, "controller.sched_oracle", d.sched_oracle)?,
    };
    cfg.validate()?;
    Ok(cfg)
}

/// Build a [`PatternConfig`] from `KEY=VALUE` tokens — the exact syntax the
/// host-controller `CFG` command uses (§II-C):
///
/// ```text
/// OP=R|W|M  RDPCT=50  ADDR=SEQ|RND|STRIDE|BANK|CHASE|PHASED  SEED=7
/// STRIDE=8k  WSET=1m  PHASES=SEQ@512,RND@512  BURST=32
/// TYPE=FIXED|INCR|WRAP  SIG=NB|BLK|AGR  BATCH=4096  START=0  REGION=256m
/// DATA=PRBS|ZEROS|<hex>  VERIFY=0|1
/// MAP=row_col_bank|row_bank_col|bank_row_col|xor_hash|<order, e.g. RoBaBgCo>
/// SCHED=fcfs|frfcfs|frfcfs-cap[N]|closed|adaptive
/// ENGINE=cycle|event  TELEM=4096
/// ```
///
/// Pattern parameters are order-independent: `SEED`, `STRIDE` and `WSET`
/// apply to whichever `ADDR` mode is selected (and to every phase of
/// `ADDR=PHASED`, whose `PHASES` list is comma-separated `MODE@TXNS`
/// entries using the same mode names, `PHASED` itself excluded).
pub fn parse_pattern_config(tokens: &[&str]) -> Result<PatternConfig, ConfigError> {
    let mut p = PatternConfig::default();
    let mut read_pct: Option<u32> = None;
    let mut seed: u64 = 0xD0D0_CAFE;
    let mut data_seed: u32 = 1;
    let mut stride: u64 = 4096;
    let mut wset: u64 = 1 << 20;
    let mut addr_kind: Option<String> = None;
    let mut phases_spec: Option<String> = None;
    for tok in tokens {
        let (k, v) = tok
            .split_once('=')
            .ok_or_else(|| ConfigError::new(format!("expected KEY=VALUE, got `{tok}`")))?;
        let key = k.to_ascii_uppercase();
        let val = v.trim();
        let upval = val.to_ascii_uppercase();
        match key.as_str() {
            "OP" => {
                p.op = match upval.as_str() {
                    "R" | "READ" => OpMix::ReadOnly,
                    "W" | "WRITE" => OpMix::WriteOnly,
                    "M" | "MIX" | "MIXED" => OpMix::Mixed { read_pct: read_pct.unwrap_or(50) },
                    _ => return Err(ConfigError::new(format!("OP: unknown `{val}`"))),
                }
            }
            "RDPCT" => {
                let pct: u32 = val
                    .parse()
                    .map_err(|_| ConfigError::new(format!("RDPCT: expected int, got `{val}`")))?;
                read_pct = Some(pct);
                if let OpMix::Mixed { .. } = p.op {
                    p.op = OpMix::Mixed { read_pct: pct };
                }
            }
            // Mode-name validation happens once, in `build_addr_mode`.
            "ADDR" => addr_kind = Some(upval.clone()),
            "SEED" => {
                seed = parse_u64_with_suffix(val)
                    .ok_or_else(|| ConfigError::new(format!("SEED: expected int, got `{val}`")))?;
            }
            "STRIDE" => {
                stride = parse_u64_with_suffix(val).ok_or_else(|| {
                    ConfigError::new(format!("STRIDE: expected bytes, got `{val}`"))
                })?;
            }
            "WSET" => {
                wset = parse_u64_with_suffix(val).ok_or_else(|| {
                    ConfigError::new(format!("WSET: expected bytes, got `{val}`"))
                })?;
            }
            "PHASES" => {
                phases_spec = Some(val.to_string());
            }
            "BURST" | "LEN" => {
                p.burst.len = val
                    .parse()
                    .map_err(|_| ConfigError::new(format!("BURST: expected int, got `{val}`")))?;
            }
            "TYPE" => {
                p.burst.kind = match upval.as_str() {
                    "FIXED" => BurstKind::Fixed,
                    "INCR" => BurstKind::Incr,
                    "WRAP" => BurstKind::Wrap,
                    _ => return Err(ConfigError::new(format!("TYPE: unknown `{val}`"))),
                }
            }
            "SIG" => {
                p.signaling = match upval.as_str() {
                    "NB" | "NONBLOCKING" => Signaling::NonBlocking,
                    "BLK" | "B" | "BLOCKING" => Signaling::Blocking,
                    "AGR" | "AG" | "AGGRESSIVE" => Signaling::Aggressive,
                    _ => return Err(ConfigError::new(format!("SIG: unknown `{val}`"))),
                }
            }
            "BATCH" => {
                p.batch_len = parse_u64_with_suffix(val)
                    .ok_or_else(|| ConfigError::new(format!("BATCH: expected int, got `{val}`")))?
                    as u32;
            }
            "START" => {
                p.start_addr = parse_u64_with_suffix(val).ok_or_else(|| {
                    ConfigError::new(format!("START: expected int, got `{val}`"))
                })?;
            }
            "REGION" => {
                p.region_bytes = parse_u64_with_suffix(val).ok_or_else(|| {
                    ConfigError::new(format!("REGION: expected int, got `{val}`"))
                })?;
            }
            "DATA" => {
                p.data = match upval.as_str() {
                    "PRBS" => DataPattern::Prbs { seed: data_seed },
                    "ZEROS" => DataPattern::Zeros,
                    hex => {
                        let w = u32::from_str_radix(hex.trim_start_matches("0X"), 16)
                            .map_err(|_| {
                                ConfigError::new(format!(
                                    "DATA: expected PRBS|ZEROS|hex, got `{val}`"
                                ))
                            })?;
                        DataPattern::Constant(w)
                    }
                }
            }
            "DSEED" => {
                data_seed = val
                    .parse()
                    .map_err(|_| ConfigError::new(format!("DSEED: expected int, got `{val}`")))?;
                if let DataPattern::Prbs { .. } = p.data {
                    p.data = DataPattern::Prbs { seed: data_seed };
                }
            }
            "VERIFY" => {
                p.verify = matches!(upval.as_str(), "1" | "TRUE" | "ON" | "YES");
            }
            "MAP" => {
                p.mapping = Some(MappingPolicy::parse(val).ok_or_else(|| {
                    ConfigError::new(format!("MAP: unknown mapping policy `{val}`"))
                })?);
            }
            "SCHED" => {
                p.sched = Some(SchedKind::parse(val).ok_or_else(|| {
                    ConfigError::new(format!("SCHED: unknown scheduler policy `{val}`"))
                })?);
            }
            "ENGINE" => {
                p.engine = Some(EngineKind::parse(val).ok_or_else(|| {
                    ConfigError::new(format!("ENGINE: unknown engine `{val}`"))
                })?);
            }
            "TELEM" => {
                p.telemetry = Some(parse_u64_with_suffix(val).ok_or_else(|| {
                    ConfigError::new(format!("TELEM: expected window cycles, got `{val}`"))
                })?);
            }
            _ => return Err(ConfigError::new(format!("unknown pattern key `{k}`"))),
        }
    }
    if let Some(kind) = &addr_kind {
        if phases_spec.is_some() && kind != "PHASED" {
            return Err(ConfigError::new(format!(
                "PHASES requires ADDR=PHASED, not ADDR={kind}"
            )));
        }
        p.addr = build_addr_mode(kind, seed, stride, wset, phases_spec.as_deref())?;
    } else if phases_spec.is_some() {
        return Err(ConfigError::new("PHASES requires ADDR=PHASED"));
    }
    p.validate()?;
    Ok(p)
}

/// Construct an [`AddrMode`] from its (uppercased) syntax name and the
/// shared pattern parameters.
fn build_addr_mode(
    kind: &str,
    seed: u64,
    stride: u64,
    wset: u64,
    phases: Option<&str>,
) -> Result<AddrMode, ConfigError> {
    Ok(match kind {
        "SEQ" | "SEQUENTIAL" => AddrMode::Sequential,
        "RND" | "RANDOM" => AddrMode::Random { seed },
        "STRIDE" | "STRIDED" => AddrMode::Strided { stride },
        "BANK" | "BANKCONFLICT" => AddrMode::BankConflict { seed },
        "CHASE" | "POINTERCHASE" => AddrMode::PointerChase { seed, working_set: wset },
        "PHASED" => {
            let spec = phases
                .ok_or_else(|| ConfigError::new("ADDR=PHASED requires PHASES=MODE@TXNS,.."))?;
            let mut list = Vec::new();
            for part in spec.split(',') {
                let (m, n) = part.split_once('@').ok_or_else(|| {
                    ConfigError::new(format!("PHASES: expected MODE@TXNS, got `{part}`"))
                })?;
                let sub = m.trim().to_ascii_uppercase();
                if sub == "PHASED" {
                    return Err(ConfigError::new("PHASES: phases cannot nest"));
                }
                let txns = parse_u64_with_suffix(n).ok_or_else(|| {
                    ConfigError::new(format!("PHASES: bad transaction count `{n}`"))
                })?;
                if txns == 0 || txns > u32::MAX as u64 {
                    return Err(ConfigError::new(format!(
                        "PHASES: transaction count `{n}` out of range 1..={}",
                        u32::MAX
                    )));
                }
                list.push((build_addr_mode(&sub, seed, stride, wset, None)?, txns as u32));
            }
            AddrMode::Phased(list)
        }
        other => return Err(ConfigError::new(format!("ADDR: unknown `{other}`"))),
    })
}

/// The syntax name of an address mode (phase-list entries use the same
/// names).
fn addr_kind_name(mode: &AddrMode) -> &'static str {
    match mode {
        AddrMode::Sequential => "SEQ",
        AddrMode::Random { .. } => "RND",
        AddrMode::Strided { .. } => "STRIDE",
        AddrMode::BankConflict { .. } => "BANK",
        AddrMode::PointerChase { .. } => "CHASE",
        AddrMode::Phased(_) => "PHASED",
    }
}

/// Append the `ADDR=..` (and parameter) tokens for `mode` to `s`. For
/// `Phased`, the shared `SEED`/`STRIDE`/`WSET` values are taken from the
/// first phase that uses each — the host syntax shares one value of each
/// parameter across phases, so phased configs whose phases disagree on a
/// parameter cannot be represented exactly and format to the first
/// phase's value.
fn format_addr_mode(s: &mut String, mode: &AddrMode) {
    match mode {
        AddrMode::Sequential => s.push_str(" ADDR=SEQ"),
        AddrMode::Random { seed } => s.push_str(&format!(" ADDR=RND SEED={seed}")),
        AddrMode::Strided { stride } => s.push_str(&format!(" ADDR=STRIDE STRIDE={stride}")),
        AddrMode::BankConflict { seed } => s.push_str(&format!(" ADDR=BANK SEED={seed}")),
        AddrMode::PointerChase { seed, working_set } => {
            s.push_str(&format!(" ADDR=CHASE SEED={seed} WSET={working_set}"));
        }
        AddrMode::Phased(phases) => {
            let list: Vec<String> = phases
                .iter()
                .map(|(m, n)| format!("{}@{}", addr_kind_name(m), n))
                .collect();
            s.push_str(&format!(" ADDR=PHASED PHASES={}", list.join(",")));
            let seed = phases.iter().find_map(|(m, _)| match m {
                AddrMode::Random { seed }
                | AddrMode::BankConflict { seed }
                | AddrMode::PointerChase { seed, .. } => Some(*seed),
                _ => None,
            });
            if let Some(seed) = seed {
                s.push_str(&format!(" SEED={seed}"));
            }
            let stride = phases.iter().find_map(|(m, _)| match m {
                AddrMode::Strided { stride } => Some(*stride),
                _ => None,
            });
            if let Some(stride) = stride {
                s.push_str(&format!(" STRIDE={stride}"));
            }
            let wset = phases.iter().find_map(|(m, _)| match m {
                AddrMode::PointerChase { working_set, .. } => Some(*working_set),
                _ => None,
            });
            if let Some(wset) = wset {
                s.push_str(&format!(" WSET={wset}"));
            }
        }
    }
}

/// Render a [`PatternConfig`] back to the `CFG` token syntax (used by the
/// host protocol echo and for logging). `parse_pattern_config` of the
/// output reproduces the config (round-trip property-tested; the one
/// exception is `Phased` whose phases disagree on a shared parameter —
/// see [`format_addr_mode`]).
pub fn format_pattern_config(p: &PatternConfig) -> String {
    let mut s = String::new();
    match p.op {
        OpMix::ReadOnly => s.push_str("OP=R"),
        OpMix::WriteOnly => s.push_str("OP=W"),
        OpMix::Mixed { read_pct } => {
            s.push_str("OP=M");
            s.push_str(&format!(" RDPCT={read_pct}"));
        }
    }
    format_addr_mode(&mut s, &p.addr);
    s.push_str(&format!(" BURST={} TYPE={}", p.burst.len, p.burst.kind.label()));
    s.push_str(&format!(" SIG={}", p.signaling.label()));
    s.push_str(&format!(" BATCH={}", p.batch_len));
    s.push_str(&format!(" START={} REGION={}", p.start_addr, p.region_bytes));
    match p.data {
        DataPattern::Prbs { seed } => s.push_str(&format!(" DATA=PRBS DSEED={seed}")),
        DataPattern::Zeros => s.push_str(" DATA=ZEROS"),
        DataPattern::Constant(w) => s.push_str(&format!(" DATA={w:08x}")),
    }
    s.push_str(&format!(" VERIFY={}", u8::from(p.verify)));
    if let Some(m) = &p.mapping {
        s.push_str(&format!(" MAP={}", m.name()));
    }
    if let Some(k) = p.sched {
        s.push_str(&format!(" SCHED={}", k.name()));
    }
    if let Some(e) = p.engine {
        s.push_str(&format!(" ENGINE={}", e.name()));
    }
    if let Some(w) = p.telemetry {
        s.push_str(&format!(" TELEM={w}"));
    }
    s
}

/// Parse one per-channel workload spec of a heterogeneous mix:
/// `N:TOKEN[,TOKEN...]` — channel index, a colon, then comma-separated
/// pattern tokens in the [`parse_pattern_config`] syntax. A bare token
/// without `=` is shorthand for `ADDR=<token>`, so `0:SEQ,BURST=32` and
/// `0:ADDR=SEQ,BURST=32` are the same spec. `PHASES=` values are
/// themselves comma-separated `MODE@TXNS` entries; a chunk with `@` and
/// no `=` therefore continues the preceding token instead of starting a
/// new one, so `0:PHASED,PHASES=SEQ@512,RND@512` carries the whole
/// phase list. This is the syntax of the CLI `--ch` option, the sweep
/// `--mixes`/`[mixes]` axis and the host protocol's `CHCFG` command.
pub fn parse_channel_spec(spec: &str) -> Result<(usize, PatternConfig), ConfigError> {
    let (idx, rest) = spec
        .split_once(':')
        .ok_or_else(|| ConfigError::new(format!("channel spec `{spec}`: expected N:TOKENS")))?;
    let ch: usize = idx
        .trim()
        .parse()
        .map_err(|_| ConfigError::new(format!("channel spec `{spec}`: bad channel `{idx}`")))?;
    let mut toks: Vec<String> = Vec::new();
    for chunk in rest.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        if chunk.contains('=') {
            toks.push(chunk.to_string());
        } else if chunk.contains('@') {
            // continuation of a comma-separated PHASES= list
            match toks.last_mut() {
                Some(prev) if prev.to_ascii_uppercase().starts_with("PHASES=") => {
                    prev.push(',');
                    prev.push_str(chunk);
                }
                _ => {
                    return Err(ConfigError::new(format!(
                        "channel spec `{spec}`: `{chunk}` continues no PHASES= token"
                    )));
                }
            }
        } else {
            toks.push(format!("ADDR={chunk}"));
        }
    }
    if toks.is_empty() {
        return Err(ConfigError::new(format!("channel spec `{spec}`: no pattern tokens")));
    }
    let refs: Vec<&str> = toks.iter().map(String::as_str).collect();
    let cfg = parse_pattern_config(&refs)
        .map_err(|e| ConfigError::new(format!("channel {ch}: {e}")))?;
    Ok((ch, cfg))
}

/// Build a [`ChannelMix`] from per-channel specs (`N:TOKENS,...` each —
/// see [`parse_channel_spec`]). Channel indices must be dense from 0 and
/// free of duplicates so the mix unambiguously covers channels `0..K`.
pub fn parse_channel_mix(specs: &[&str]) -> Result<ChannelMix, ConfigError> {
    let mut slots: Vec<Option<PatternConfig>> = Vec::new();
    for spec in specs {
        let (ch, cfg) = parse_channel_spec(spec)?;
        if ch >= 3 {
            return Err(ConfigError::new(format!(
                "channel {ch} out of range (mixes cover channels 0..=2)"
            )));
        }
        if slots.len() <= ch {
            slots.resize(ch + 1, None);
        }
        if slots[ch].is_some() {
            return Err(ConfigError::new(format!("channel {ch} configured twice")));
        }
        slots[ch] = Some(cfg);
    }
    let mut channels = Vec::with_capacity(slots.len());
    for (ch, slot) in slots.into_iter().enumerate() {
        channels.push(slot.ok_or_else(|| {
            ConfigError::new(format!("channel {ch} missing: mix channels must be dense from 0"))
        })?);
    }
    ChannelMix::new(channels)
}

/// Parse a heterogeneous mix from config-file text with one `[channel.N]`
/// section per channel, each holding a `pattern =` key in the
/// [`parse_pattern_config`] token syntax:
///
/// ```text
/// [channel.0]
/// pattern = OP=R ADDR=SEQ BURST=32 BATCH=4096
/// [channel.1]
/// pattern = OP=R ADDR=CHASE WSET=1m SIG=BLK BURST=1 BATCH=1024
/// ```
pub fn parse_mix_file(text: &str) -> Result<ChannelMix, ConfigError> {
    // parse_kv_text is documented last-wins, but a duplicated
    // [channel.N] section is the copy-paste typo the CLI (`--ch 0:..
    // --ch 0:..`) and the CHCFG command both reject — reject it here
    // too instead of silently dropping the first workload
    let mut sections: Vec<String> = Vec::new();
    for raw in text.lines() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            let name = name.trim().to_ascii_lowercase();
            if sections.contains(&name) {
                return Err(ConfigError::new(format!(
                    "section `[{name}]` appears twice (mix channels may be configured once)"
                )));
            }
            sections.push(name);
        }
    }
    let map = parse_kv_text(text)?;
    let mut specs: Vec<String> = Vec::new();
    for (key, value) in &map {
        let Some(rest) = key.strip_prefix("channel.") else {
            return Err(ConfigError::new(format!(
                "unknown mix key `{key}` (expected `[channel.N]` sections with `pattern =`)"
            )));
        };
        let Some(ch) = rest.strip_suffix(".pattern") else {
            return Err(ConfigError::new(format!(
                "unknown mix key `{key}` (each `[channel.N]` section takes one `pattern =`)"
            )));
        };
        specs.push(format!("{}:{}", ch, value.split_whitespace().collect::<Vec<_>>().join(",")));
    }
    if specs.is_empty() {
        return Err(ConfigError::new("mix file has no `[channel.N]` sections"));
    }
    let refs: Vec<&str> = specs.iter().map(String::as_str).collect();
    parse_channel_mix(&refs)
}

/// Render one channel's config as a `N:TOKEN,TOKEN,...` channel spec
/// ([`parse_channel_spec`] reproduces the config — the single place the
/// "join pattern tokens with commas" rendering lives, shared by
/// [`format_channel_mix`] and the host protocol's `CHCFG` echo).
pub fn format_channel_spec(ch: usize, cfg: &PatternConfig) -> String {
    let echo = format_pattern_config(cfg);
    format!("{ch}:{}", echo.split_whitespace().collect::<Vec<_>>().join(","))
}

/// Render a [`ChannelMix`] back to the whitespace-separated channel-spec
/// syntax (`0:OP=R,ADDR=SEQ,... 1:...`); [`parse_channel_mix`] of the
/// split output reproduces the mix (same round-trip caveats as
/// [`format_pattern_config`]).
pub fn format_channel_mix(mix: &ChannelMix) -> String {
    mix.iter()
        .enumerate()
        .map(|(ch, cfg)| format_channel_spec(ch, cfg))
        .collect::<Vec<_>>()
        .join(" ")
}

/// Apply `KEY=VALUE` controller-knob tokens on top of `base` — the syntax
/// of the sweep spec's `[knobs]` section and the CLI `--knobs` axis.
/// Recognized keys (short aliases in parentheses): `lookahead` (`la`),
/// `read_queue_depth` (`rq`), `write_queue_depth` (`wq`),
/// `write_drain_high` (`whi`), `write_drain_low` (`wlo`),
/// `outstanding_cap` (`cap`), `idle_precharge_cycles` (`idle_pre`),
/// `addr_cmd_interval_axi` (`addr_interval`), `serial_frontend`,
/// `miss_flush`, `mode_dwell_ck` (`dwell`), `sched` (`policy`),
/// `sched_oracle` (`oracle` — run the frozen scan scheduler instead of
/// the indexed fast path; a differential/debug knob, not a perf one).
pub fn parse_controller_tokens(
    base: ControllerParams,
    tokens: &[&str],
) -> Result<ControllerParams, ConfigError> {
    let mut p = base;
    for tok in tokens {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        let (k, v) = tok
            .split_once('=')
            .ok_or_else(|| ConfigError::new(format!("knob: expected KEY=VALUE, got `{tok}`")))?;
        let key = k.trim().to_ascii_lowercase();
        let val = v.trim();
        let as_usize = || -> Result<usize, ConfigError> {
            val.parse()
                .map_err(|_| ConfigError::new(format!("knob {key}: expected int, got `{val}`")))
        };
        let as_u32 = || -> Result<u32, ConfigError> {
            val.parse()
                .map_err(|_| ConfigError::new(format!("knob {key}: expected int, got `{val}`")))
        };
        let as_bool = || -> Result<bool, ConfigError> {
            match val.to_ascii_lowercase().as_str() {
                "true" | "1" | "yes" | "on" => Ok(true),
                "false" | "0" | "no" | "off" => Ok(false),
                _ => Err(ConfigError::new(format!("knob {key}: expected bool, got `{val}`"))),
            }
        };
        match key.as_str() {
            "lookahead" | "la" => p.lookahead = as_usize()?,
            "read_queue_depth" | "rq" => p.read_queue_depth = as_usize()?,
            "write_queue_depth" | "wq" => p.write_queue_depth = as_usize()?,
            "write_drain_high" | "whi" => p.write_drain_high = as_usize()?,
            "write_drain_low" | "wlo" => p.write_drain_low = as_usize()?,
            "outstanding_cap" | "cap" => p.outstanding_cap = as_usize()?,
            "idle_precharge_cycles" | "idle_pre" => p.idle_precharge_cycles = as_u32()?,
            "addr_cmd_interval_axi" | "addr_interval" => p.addr_cmd_interval_axi = as_u32()?,
            "serial_frontend" => p.serial_frontend = as_bool()?,
            "miss_flush" => p.miss_flush = as_bool()?,
            "mode_dwell_ck" | "dwell" => p.mode_dwell_ck = as_u32()?,
            "sched_oracle" | "oracle" => p.sched_oracle = as_bool()?,
            "sched" | "policy" => {
                p.sched = SchedKind::parse(val).ok_or_else(|| {
                    ConfigError::new(format!("knob sched: unknown policy `{val}`"))
                })?;
            }
            other => return Err(ConfigError::new(format!("unknown controller knob `{other}`"))),
        }
    }
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_text_sections_and_comments() {
        let m = parse_kv_text(
            "# top\nchannels = 2\n[controller]\nlookahead = 8 # window\n\n[counters]\nlatency=off\n",
        )
        .unwrap();
        assert_eq!(m.get("channels").unwrap(), "2");
        assert_eq!(m.get("controller.lookahead").unwrap(), "8");
        assert_eq!(m.get("counters.latency").unwrap(), "off");
    }

    #[test]
    fn kv_text_rejects_garbage() {
        assert!(parse_kv_text("not a kv line").is_err());
        assert!(parse_kv_text("[unterminated").is_err());
    }

    #[test]
    fn design_config_full_text() {
        let cfg = parse_design_config(
            "channels = 3\nspeed = ddr4-2400\naxi_width = 256\n\
             [controller]\nlookahead = 6\noutstanding_cap = 16\n\
             [counters]\nrefresh = false\n",
        )
        .unwrap();
        assert_eq!(cfg.channels, 3);
        assert_eq!(cfg.speed, SpeedBin::Ddr4_2400);
        assert_eq!(cfg.controller.lookahead, 6);
        assert_eq!(cfg.controller.outstanding_cap, 16);
        assert!(!cfg.counters.refresh);
        assert!(cfg.counters.latency);
    }

    #[test]
    fn design_config_invalid_rejected() {
        assert!(parse_design_config("channels = 9").is_err());
        assert!(parse_design_config("speed = 3200").is_err());
    }

    #[test]
    fn suffix_parsing() {
        assert_eq!(parse_u64_with_suffix("64"), Some(64));
        assert_eq!(parse_u64_with_suffix("4k"), Some(4096));
        assert_eq!(parse_u64_with_suffix("16M"), Some(16 << 20));
        assert_eq!(parse_u64_with_suffix("2g"), Some(2 << 30));
        assert_eq!(parse_u64_with_suffix("x"), None);
        // suffixed overflow must be rejected, not wrapped
        assert_eq!(parse_u64_with_suffix(&u64::MAX.to_string()), Some(u64::MAX));
        assert_eq!(parse_u64_with_suffix("18446744073709551615k"), None);
        assert_eq!(parse_u64_with_suffix("18014398509481985g"), None);
    }

    #[test]
    fn pattern_tokens_full() {
        let p = parse_pattern_config(&[
            "OP=M", "RDPCT=70", "ADDR=RND", "SEED=99", "BURST=16", "TYPE=WRAP", "SIG=AGR",
            "BATCH=2k", "START=4096", "REGION=64m", "DATA=PRBS", "DSEED=5", "VERIFY=1",
        ])
        .unwrap();
        assert_eq!(p.op, OpMix::Mixed { read_pct: 70 });
        assert_eq!(p.addr, AddrMode::Random { seed: 99 });
        assert_eq!(p.burst, super::super::BurstSpec { len: 16, kind: BurstKind::Wrap });
        assert_eq!(p.signaling, Signaling::Aggressive);
        assert_eq!(p.batch_len, 2048);
        assert_eq!(p.start_addr, 4096);
        assert_eq!(p.region_bytes, 64 << 20);
        assert_eq!(p.data, DataPattern::Prbs { seed: 5 });
        assert!(p.verify);
    }

    #[test]
    fn pattern_rdpct_order_independent() {
        let a = parse_pattern_config(&["RDPCT=30", "OP=M"]).unwrap();
        let b = parse_pattern_config(&["OP=M", "RDPCT=30"]).unwrap();
        assert_eq!(a.op, OpMix::Mixed { read_pct: 30 });
        assert_eq!(b.op, OpMix::Mixed { read_pct: 30 });
    }

    #[test]
    fn pattern_rejects_invalid() {
        assert!(parse_pattern_config(&["OP=X"]).is_err());
        assert!(parse_pattern_config(&["BURST=500"]).is_err());
        assert!(parse_pattern_config(&["BURST=12", "TYPE=WRAP"]).is_err());
        assert!(parse_pattern_config(&["NOPE=1"]).is_err());
        assert!(parse_pattern_config(&["OP"]).is_err());
    }

    #[test]
    fn pattern_new_modes_parse() {
        let p = parse_pattern_config(&["ADDR=STRIDE", "STRIDE=8k"]).unwrap();
        assert_eq!(p.addr, AddrMode::Strided { stride: 8192 });
        // order-independent: STRIDE may come first
        let p = parse_pattern_config(&["STRIDE=8k", "ADDR=STRIDED"]).unwrap();
        assert_eq!(p.addr, AddrMode::Strided { stride: 8192 });
        let p = parse_pattern_config(&["ADDR=BANK", "SEED=5"]).unwrap();
        assert_eq!(p.addr, AddrMode::BankConflict { seed: 5 });
        let p = parse_pattern_config(&["ADDR=CHASE", "SEED=9", "WSET=2m"]).unwrap();
        assert_eq!(p.addr, AddrMode::PointerChase { seed: 9, working_set: 2 << 20 });
        // defaults: stride 4096, wset 1 MiB, shared seed default
        let p = parse_pattern_config(&["ADDR=STRIDE"]).unwrap();
        assert_eq!(p.addr, AddrMode::Strided { stride: 4096 });
        let p = parse_pattern_config(&["ADDR=CHASE"]).unwrap();
        assert_eq!(p.addr, AddrMode::PointerChase { seed: 0xD0D0_CAFE, working_set: 1 << 20 });
    }

    #[test]
    fn pattern_phased_parses_and_shares_params() {
        let p = parse_pattern_config(&[
            "ADDR=PHASED",
            "PHASES=SEQ@512,RND@256,STRIDE@2k",
            "SEED=3",
            "STRIDE=64k",
        ])
        .unwrap();
        assert_eq!(
            p.addr,
            AddrMode::Phased(vec![
                (AddrMode::Sequential, 512),
                (AddrMode::Random { seed: 3 }, 256),
                (AddrMode::Strided { stride: 64 << 10 }, 2048),
            ])
        );
    }

    #[test]
    fn pattern_phased_rejects_bad_specs() {
        assert!(parse_pattern_config(&["ADDR=PHASED"]).is_err(), "PHASES required");
        assert!(parse_pattern_config(&["ADDR=PHASED", "PHASES=SEQ"]).is_err(), "missing @txns");
        assert!(
            parse_pattern_config(&["ADDR=PHASED", "PHASES=SEQ@0"]).is_err(),
            "zero-count phase"
        );
        assert!(
            parse_pattern_config(&["ADDR=PHASED", "PHASES=PHASED@4"]).is_err(),
            "nested phases"
        );
        assert!(
            parse_pattern_config(&["ADDR=PHASED", "PHASES=SEQ@8g"]).is_err(),
            "count beyond u32 range"
        );
        assert!(parse_pattern_config(&["PHASES=SEQ@4"]).is_err(), "PHASES without ADDR=PHASED");
        assert!(
            parse_pattern_config(&["ADDR=STRIDE", "PHASES=SEQ@4"]).is_err(),
            "PHASES with a non-phased ADDR mode"
        );
        assert!(parse_pattern_config(&["ADDR=NOPE"]).is_err(), "unknown mode name");
        assert!(parse_pattern_config(&["ADDR=STRIDE", "STRIDE=0"]).is_err(), "zero stride");
        assert!(parse_pattern_config(&["ADDR=CHASE", "WSET=0"]).is_err(), "zero working set");
    }

    #[test]
    fn pattern_new_modes_format_roundtrip() {
        for toks in [
            &["ADDR=STRIDE", "STRIDE=65536"][..],
            &["ADDR=BANK", "SEED=11"][..],
            &["ADDR=CHASE", "SEED=4", "WSET=1m"][..],
            &["ADDR=PHASED", "PHASES=SEQ@128,BANK@64,CHASE@32", "SEED=8", "WSET=64k"][..],
        ] {
            let p = parse_pattern_config(toks).unwrap();
            let text = format_pattern_config(&p);
            let toks2: Vec<&str> = text.split_whitespace().collect();
            let q = parse_pattern_config(&toks2).unwrap();
            assert_eq!(p, q, "round-trip through `{text}`");
        }
    }

    #[test]
    fn map_token_parses_and_roundtrips() {
        let p = parse_pattern_config(&["ADDR=SEQ", "MAP=row_bank_col"]).unwrap();
        assert_eq!(p.mapping, Some(MappingPolicy::row_bank_col()));
        let p = parse_pattern_config(&["MAP=XOR"]).unwrap();
        assert_eq!(p.mapping, Some(MappingPolicy::xor_hash()));
        let p = parse_pattern_config(&["MAP=RoBaBgCo"]).unwrap();
        assert_eq!(p.mapping, Some(MappingPolicy::parse("RoBaBgCo").unwrap()));
        assert!(parse_pattern_config(&["MAP=frobnicate"]).is_err());
        // MAP= survives the format/parse round trip
        for map in ["row_col_bank", "bank_row_col", "xor_hash", "XorRoBaBgCo"] {
            let p = parse_pattern_config(&["ADDR=BANK", "SEED=5", &format!("MAP={map}")]).unwrap();
            let text = format_pattern_config(&p);
            assert!(text.contains("MAP="), "{text}");
            let toks: Vec<&str> = text.split_whitespace().collect();
            assert_eq!(parse_pattern_config(&toks).unwrap(), p, "`{text}`");
        }
    }

    #[test]
    fn design_config_mapping_key() {
        let cfg = parse_design_config("mapping = bank_row_col\n").unwrap();
        assert_eq!(cfg.geometry.mapping, MappingPolicy::bank_row_col());
        assert!(parse_design_config("mapping = nope\n").is_err());
    }

    #[test]
    fn sched_token_parses_and_roundtrips() {
        let p = parse_pattern_config(&["ADDR=SEQ", "SCHED=fcfs"]).unwrap();
        assert_eq!(p.sched, Some(SchedKind::Fcfs));
        let p = parse_pattern_config(&["SCHED=frfcfs-cap8"]).unwrap();
        assert_eq!(p.sched, Some(SchedKind::FrFcfsCap { cap: 8 }));
        assert!(parse_pattern_config(&["SCHED=frobnicate"]).is_err());
        assert!(parse_pattern_config(&["SCHED=frfcfs-cap0"]).is_err());
        // SCHED= survives the format/parse round trip, alone and with MAP=
        for sched in ["fcfs", "frfcfs", "frfcfs-cap", "frfcfs-cap16", "closed", "adaptive"] {
            let toks = ["ADDR=BANK", "SEED=5", "MAP=xor_hash", &format!("SCHED={sched}")];
            let p = parse_pattern_config(&toks).unwrap();
            let text = format_pattern_config(&p);
            assert!(text.contains("SCHED="), "{text}");
            let toks2: Vec<&str> = text.split_whitespace().collect();
            assert_eq!(parse_pattern_config(&toks2).unwrap(), p, "`{text}`");
        }
        // no override: the echo stays silent about scheduling
        let p = parse_pattern_config(&["ADDR=SEQ"]).unwrap();
        assert_eq!(p.sched, None);
        assert!(!format_pattern_config(&p).contains("SCHED="));
    }

    #[test]
    fn design_config_sched_key() {
        let cfg = parse_design_config("[controller]\nsched = closed\n").unwrap();
        assert_eq!(cfg.controller.sched, SchedKind::Closed);
        let cfg = parse_design_config("[controller]\nsched = frfcfs-cap=2\n").unwrap();
        assert_eq!(cfg.controller.sched, SchedKind::FrFcfsCap { cap: 2 });
        assert_eq!(parse_design_config("").unwrap().controller.sched, SchedKind::FrFcfs);
        assert!(parse_design_config("[controller]\nsched = nope\n").is_err());
    }

    #[test]
    fn engine_token_parses_and_roundtrips() {
        let p = parse_pattern_config(&["ADDR=SEQ", "ENGINE=event"]).unwrap();
        assert_eq!(p.engine, Some(EngineKind::Event));
        let p = parse_pattern_config(&["ENGINE=Cycle"]).unwrap();
        assert_eq!(p.engine, Some(EngineKind::Cycle));
        let err = parse_pattern_config(&["ENGINE=wheel"]).unwrap_err().to_string();
        assert!(err.contains("ENGINE: unknown engine `wheel`"), "{err}");
        // ENGINE= survives the format/parse round trip alongside the
        // other overrides, and stays silent when unset
        let toks = ["ADDR=SEQ", "MAP=xor_hash", "SCHED=closed", "ENGINE=event"];
        let p = parse_pattern_config(&toks).unwrap();
        let text = format_pattern_config(&p);
        assert!(text.contains("ENGINE=event"), "{text}");
        let toks2: Vec<&str> = text.split_whitespace().collect();
        assert_eq!(parse_pattern_config(&toks2).unwrap(), p, "`{text}`");
        let p = parse_pattern_config(&["ADDR=SEQ"]).unwrap();
        assert_eq!(p.engine, None);
        assert!(!format_pattern_config(&p).contains("ENGINE="));
    }

    #[test]
    fn telem_token_parses_and_roundtrips() {
        let p = parse_pattern_config(&["ADDR=SEQ", "TELEM=4096"]).unwrap();
        assert_eq!(p.telemetry, Some(4096));
        // size suffixes work like every other cycle/byte count token
        let p = parse_pattern_config(&["TELEM=4k"]).unwrap();
        assert_eq!(p.telemetry, Some(4096));
        let err = parse_pattern_config(&["TELEM=abc"]).unwrap_err().to_string();
        assert!(err.contains("TELEM: expected window cycles"), "{err}");
        assert!(parse_pattern_config(&["TELEM=0"]).is_err(), "zero window rejected");
        // TELEM= survives the format/parse round trip alongside the
        // other overrides, and stays silent when unset
        let toks = ["ADDR=SEQ", "ENGINE=event", "TELEM=2048"];
        let p = parse_pattern_config(&toks).unwrap();
        let text = format_pattern_config(&p);
        assert!(text.contains("TELEM=2048"), "{text}");
        let toks2: Vec<&str> = text.split_whitespace().collect();
        assert_eq!(parse_pattern_config(&toks2).unwrap(), p, "`{text}`");
        let p = parse_pattern_config(&["ADDR=SEQ"]).unwrap();
        assert_eq!(p.telemetry, None);
        assert!(!format_pattern_config(&p).contains("TELEM="));
    }

    #[test]
    fn design_config_telemetry_key() {
        let cfg = parse_design_config("telemetry = 8192\n").unwrap();
        assert_eq!(cfg.telemetry, Some(8192));
        let cfg = parse_design_config("telemetry = 16k\nspeed = 2400\n").unwrap();
        assert_eq!(cfg.telemetry, Some(16384));
        assert_eq!(parse_design_config("").unwrap().telemetry, None);
        assert!(parse_design_config("telemetry = 0\n").is_err(), "zero window rejected");
        let err = parse_design_config("telemetry = abc\n").unwrap_err().to_string();
        assert!(err.contains("telemetry: expected window cycles"), "{err}");
    }

    #[test]
    fn design_config_engine_key() {
        let cfg = parse_design_config("engine = event\n").unwrap();
        assert_eq!(cfg.engine, EngineKind::Event);
        let cfg = parse_design_config("engine = cycle\nspeed = 2400\n").unwrap();
        assert_eq!(cfg.engine, EngineKind::Cycle);
        assert_eq!(parse_design_config("").unwrap().engine, EngineKind::Cycle);
        let err = parse_design_config("engine = wheel\n").unwrap_err().to_string();
        assert!(err.contains("engine: unknown engine `wheel`"), "{err}");
    }

    #[test]
    fn controller_knob_tokens() {
        let d = ControllerParams::default();
        let p = parse_controller_tokens(d, &["lookahead=8", "wq=32", "serial_frontend=off"])
            .unwrap();
        assert_eq!(p.lookahead, 8);
        assert_eq!(p.write_queue_depth, 32);
        assert!(!p.serial_frontend);
        assert_eq!(p.read_queue_depth, d.read_queue_depth, "untouched knobs keep defaults");
        assert!(parse_controller_tokens(d, &["nope=1"]).is_err());
        assert!(parse_controller_tokens(d, &["lookahead=abc"]).is_err());
        assert!(parse_controller_tokens(d, &["lookahead"]).is_err());
    }

    #[test]
    fn channel_spec_parses_bare_modes_and_tokens() {
        let (ch, cfg) = parse_channel_spec("0:SEQ,BURST=32,BATCH=128").unwrap();
        assert_eq!(ch, 0);
        assert_eq!(cfg.addr, AddrMode::Sequential);
        assert_eq!(cfg.burst.len, 32);
        assert_eq!(cfg.batch_len, 128);
        // bare first token is ADDR= shorthand; explicit tokens equal it
        let (_, explicit) = parse_channel_spec("0:ADDR=SEQ,BURST=32,BATCH=128").unwrap();
        assert_eq!(cfg, explicit);
        let (ch, cfg) = parse_channel_spec("2:CHASE,WSET=64k,SIG=BLK,BURST=1").unwrap();
        assert_eq!(ch, 2);
        assert!(matches!(cfg.addr, AddrMode::PointerChase { working_set: 65536, .. }));
        assert!(parse_channel_spec("0:").is_err(), "no tokens");
        assert!(parse_channel_spec("SEQ").is_err(), "missing N:");
        assert!(parse_channel_spec("x:SEQ").is_err(), "bad index");
        assert!(parse_channel_spec("0:NOPE").is_err(), "unknown mode");
    }

    #[test]
    fn channel_mix_requires_dense_unique_channels() {
        let mix = parse_channel_mix(&["1:CHASE,BURST=1", "0:SEQ,BURST=32"]).unwrap();
        assert_eq!(mix.len(), 2, "order-independent, indexed by channel");
        assert_eq!(mix.channel_label(0), "seq");
        assert_eq!(mix.channel_label(1), "chase");
        assert!(parse_channel_mix(&["1:SEQ"]).is_err(), "channel 0 missing");
        assert!(parse_channel_mix(&["0:SEQ", "0:RND"]).is_err(), "duplicate channel");
        assert!(parse_channel_mix(&["0:SEQ", "1:SEQ", "3:SEQ"]).is_err(), "out of range");
        assert!(parse_channel_mix(&[]).is_err(), "empty");
    }

    #[test]
    fn mix_file_sections_parse_and_reject_garbage() {
        let mix = parse_mix_file(
            "[channel.0]\npattern = OP=R ADDR=SEQ BURST=32 BATCH=256\n\
             [channel.1]\npattern = OP=W ADDR=BANK SEED=3 BURST=1 BATCH=128\n",
        )
        .unwrap();
        assert_eq!(mix.len(), 2);
        assert_eq!(mix.get(0).unwrap().op, OpMix::ReadOnly);
        assert_eq!(mix.get(1).unwrap().addr, AddrMode::BankConflict { seed: 3 });
        assert!(parse_mix_file("").is_err(), "no sections");
        assert!(parse_mix_file("[channel.0]\nfrob = 1\n").is_err(), "unknown section key");
        assert!(parse_mix_file("stray = 1\n").is_err(), "key outside channel sections");
        assert!(parse_mix_file("[channel.1]\npattern = OP=R\n").is_err(), "sparse channels");
        // a duplicated section is a typo, not a last-wins override
        let dup = "[channel.0]\npattern = OP=R ADDR=SEQ\n[channel.0]\npattern = OP=W ADDR=RND\n";
        let err = parse_mix_file(dup).unwrap_err().to_string();
        assert!(err.contains("appears twice"), "{err}");
    }

    #[test]
    fn channel_spec_carries_phased_patterns() {
        // PHASES= values are themselves comma-separated: chunks with `@`
        // and no `=` continue the PHASES= token instead of starting one
        let (_, cfg) = parse_channel_spec("0:PHASED,PHASES=SEQ@512,RND@256,BURST=4").unwrap();
        assert_eq!(
            cfg.addr,
            AddrMode::Phased(vec![
                (AddrMode::Sequential, 512),
                (AddrMode::Random { seed: 0xD0D0_CAFE }, 256),
            ])
        );
        assert_eq!(cfg.burst.len, 4, "tokens after the phase list still apply");
        // the format side emits the same embedded-comma spec and round-trips
        let spec = format_channel_spec(0, &cfg);
        assert!(spec.contains("PHASES=SEQ@512,RND@256"), "{spec}");
        let (_, again) = parse_channel_spec(&spec).unwrap();
        assert_eq!(again, cfg);
        // ...and so does a [channel.N] mix file using the file syntax
        let mix = parse_mix_file(
            "[channel.0]\npattern = OP=R ADDR=PHASED PHASES=SEQ@64,RND@64 BATCH=128\n\
             [channel.1]\npattern = OP=R ADDR=SEQ BURST=32 BATCH=128\n",
        )
        .unwrap();
        assert!(matches!(mix.get(0).unwrap().addr, AddrMode::Phased(_)));
        // a dangling phase chunk with nothing to continue is rejected
        assert!(parse_channel_spec("0:SEQ@512").is_err());
        assert!(parse_channel_spec("0:SEQ,RND@4").is_err(), "ADDR=SEQ is not a PHASES=");
    }

    #[test]
    fn channel_mix_format_roundtrip() {
        let mix = parse_channel_mix(&[
            "0:SEQ,BURST=32,BATCH=256",
            "1:CHASE,WSET=1m,SIG=BLK,BURST=1,BATCH=128",
            "2:BANK,SEED=5,MAP=xor_hash,SCHED=closed,BATCH=64",
        ])
        .unwrap();
        let text = format_channel_mix(&mix);
        let specs: Vec<&str> = text.split_whitespace().collect();
        assert_eq!(parse_channel_mix(&specs).unwrap(), mix, "round-trip through `{text}`");
        assert!(text.contains("MAP=xor_hash") && text.contains("SCHED=closed"), "{text}");
    }

    #[test]
    fn pattern_format_roundtrip() {
        let p = parse_pattern_config(&[
            "OP=M", "RDPCT=25", "ADDR=RND", "SEED=3", "BURST=8", "TYPE=INCR", "SIG=BLK",
            "BATCH=100", "DATA=ZEROS", "VERIFY=1",
        ])
        .unwrap();
        let text = format_pattern_config(&p);
        let toks: Vec<&str> = text.split_whitespace().collect();
        let q = parse_pattern_config(&toks).unwrap();
        assert_eq!(p, q);
    }
}
