//! Minimal config-file / key-value parser (offline substitute for
//! serde + toml; see DESIGN.md §9).
//!
//! Format: `key = value` lines, `#` comments, optional `[section]` headers
//! that prefix keys as `section.key`. The same `KEY=VALUE` tokens are also
//! what the host-controller protocol uses inline in `CFG` commands, so both
//! paths share the conversion functions here.

use super::{
    AddrMode, BurstKind, ControllerParams, CounterSet, DataPattern, DesignConfig, OpMix,
    PatternConfig, Signaling, SpeedBin,
};
use std::collections::BTreeMap;

/// Error produced when parsing or validating a configuration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConfigError {
    msg: String,
}

impl ConfigError {
    /// Build an error from any printable message.
    pub fn new(msg: impl Into<String>) -> Self {
        Self { msg: msg.into() }
    }
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config error: {}", self.msg)
    }
}

impl std::error::Error for ConfigError {}

/// Parse `key = value` text (with `[section]` support) into a flat map of
/// `section.key -> value`. Later keys override earlier ones.
pub fn parse_kv_text(text: &str) -> Result<BTreeMap<String, String>, ConfigError> {
    let mut map = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix('[') {
            let name = inner.strip_suffix(']').ok_or_else(|| {
                ConfigError::new(format!("line {}: unterminated section header", lineno + 1))
            })?;
            section = name.trim().to_ascii_lowercase();
            continue;
        }
        let (k, v) = line.split_once('=').ok_or_else(|| {
            ConfigError::new(format!("line {}: expected `key = value`", lineno + 1))
        })?;
        let key = if section.is_empty() {
            k.trim().to_ascii_lowercase()
        } else {
            format!("{}.{}", section, k.trim().to_ascii_lowercase())
        };
        map.insert(key, v.trim().to_string());
    }
    Ok(map)
}

fn get_usize(map: &BTreeMap<String, String>, key: &str, default: usize) -> Result<usize, ConfigError> {
    match map.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|_| ConfigError::new(format!("{key}: expected integer, got `{v}`"))),
    }
}

fn get_u32(map: &BTreeMap<String, String>, key: &str, default: u32) -> Result<u32, ConfigError> {
    Ok(get_usize(map, key, default as usize)? as u32)
}

fn get_bool(map: &BTreeMap<String, String>, key: &str, default: bool) -> Result<bool, ConfigError> {
    match map.get(key).map(|s| s.to_ascii_lowercase()) {
        None => Ok(default),
        Some(v) => match v.as_str() {
            "true" | "1" | "yes" | "on" => Ok(true),
            "false" | "0" | "no" | "off" => Ok(false),
            _ => Err(ConfigError::new(format!("{key}: expected bool, got `{v}`"))),
        },
    }
}

/// Parse `123`, `4k`, `16m`, `2g` (binary suffixes) into bytes/counts.
pub fn parse_u64_with_suffix(s: &str) -> Option<u64> {
    let s = s.trim().to_ascii_lowercase();
    let (num, mult) = if let Some(n) = s.strip_suffix('k') {
        (n, 1u64 << 10)
    } else if let Some(n) = s.strip_suffix('m') {
        (n, 1u64 << 20)
    } else if let Some(n) = s.strip_suffix('g') {
        (n, 1u64 << 30)
    } else {
        (s.as_str(), 1)
    };
    num.trim().parse::<u64>().ok().map(|v| v * mult)
}

/// Build a [`DesignConfig`] from config text. Recognized keys (all
/// optional; defaults = single-channel DDR4-1600 MIG-like profile):
///
/// ```text
/// channels = 3
/// speed = 2400                 # or "ddr4-2400"
/// axi_width = 256              # bits
/// [counters]  batch_cycles/latency/refresh/integrity = true|false
/// [controller] read_queue_depth / write_queue_depth / lookahead /
///              write_drain_high / write_drain_low / outstanding_cap /
///              idle_precharge_cycles / addr_cmd_interval_axi
/// ```
pub fn parse_design_config(text: &str) -> Result<DesignConfig, ConfigError> {
    let map = parse_kv_text(text)?;
    let mut cfg = DesignConfig::default();
    cfg.channels = get_usize(&map, "channels", cfg.channels)?;
    if let Some(v) = map.get("speed") {
        cfg.speed = SpeedBin::parse(v)
            .ok_or_else(|| ConfigError::new(format!("speed: unknown bin `{v}`")))?;
    }
    cfg.axi_data_width_bits = get_u32(&map, "axi_width", cfg.axi_data_width_bits)?;
    cfg.counters = CounterSet {
        batch_cycles: get_bool(&map, "counters.batch_cycles", true)?,
        latency: get_bool(&map, "counters.latency", true)?,
        refresh: get_bool(&map, "counters.refresh", true)?,
        integrity: get_bool(&map, "counters.integrity", true)?,
    };
    let d = ControllerParams::default();
    cfg.controller = ControllerParams {
        read_queue_depth: get_usize(&map, "controller.read_queue_depth", d.read_queue_depth)?,
        write_queue_depth: get_usize(&map, "controller.write_queue_depth", d.write_queue_depth)?,
        lookahead: get_usize(&map, "controller.lookahead", d.lookahead)?,
        write_drain_high: get_usize(&map, "controller.write_drain_high", d.write_drain_high)?,
        write_drain_low: get_usize(&map, "controller.write_drain_low", d.write_drain_low)?,
        outstanding_cap: get_usize(&map, "controller.outstanding_cap", d.outstanding_cap)?,
        idle_precharge_cycles: get_u32(
            &map,
            "controller.idle_precharge_cycles",
            d.idle_precharge_cycles,
        )?,
        addr_cmd_interval_axi: get_u32(
            &map,
            "controller.addr_cmd_interval_axi",
            d.addr_cmd_interval_axi,
        )?,
        serial_frontend: get_bool(&map, "controller.serial_frontend", d.serial_frontend)?,
        miss_flush: get_bool(&map, "controller.miss_flush", d.miss_flush)?,
        mode_dwell_ck: get_u32(&map, "controller.mode_dwell_ck", d.mode_dwell_ck)?,
    };
    cfg.validate()?;
    Ok(cfg)
}

/// Build a [`PatternConfig`] from `KEY=VALUE` tokens — the exact syntax the
/// host-controller `CFG` command uses (§II-C):
///
/// ```text
/// OP=R|W|M  RDPCT=50  ADDR=SEQ|RND  SEED=7  BURST=32  TYPE=FIXED|INCR|WRAP
/// SIG=NB|BLK|AGR  BATCH=4096  START=0  REGION=256m  DATA=PRBS|ZEROS|<hex>
/// VERIFY=0|1
/// ```
pub fn parse_pattern_config(tokens: &[&str]) -> Result<PatternConfig, ConfigError> {
    let mut p = PatternConfig::default();
    let mut read_pct: Option<u32> = None;
    let mut seed: u64 = 0xD0D0_CAFE;
    let mut data_seed: u32 = 1;
    for tok in tokens {
        let (k, v) = tok
            .split_once('=')
            .ok_or_else(|| ConfigError::new(format!("expected KEY=VALUE, got `{tok}`")))?;
        let key = k.to_ascii_uppercase();
        let val = v.trim();
        let upval = val.to_ascii_uppercase();
        match key.as_str() {
            "OP" => {
                p.op = match upval.as_str() {
                    "R" | "READ" => OpMix::ReadOnly,
                    "W" | "WRITE" => OpMix::WriteOnly,
                    "M" | "MIX" | "MIXED" => OpMix::Mixed { read_pct: read_pct.unwrap_or(50) },
                    _ => return Err(ConfigError::new(format!("OP: unknown `{val}`"))),
                }
            }
            "RDPCT" => {
                let pct: u32 = val
                    .parse()
                    .map_err(|_| ConfigError::new(format!("RDPCT: expected int, got `{val}`")))?;
                read_pct = Some(pct);
                if let OpMix::Mixed { .. } = p.op {
                    p.op = OpMix::Mixed { read_pct: pct };
                }
            }
            "ADDR" => {
                p.addr = match upval.as_str() {
                    "SEQ" | "SEQUENTIAL" => AddrMode::Sequential,
                    "RND" | "RANDOM" => AddrMode::Random { seed },
                    _ => return Err(ConfigError::new(format!("ADDR: unknown `{val}`"))),
                }
            }
            "SEED" => {
                seed = parse_u64_with_suffix(val)
                    .ok_or_else(|| ConfigError::new(format!("SEED: expected int, got `{val}`")))?;
                if let AddrMode::Random { .. } = p.addr {
                    p.addr = AddrMode::Random { seed };
                }
            }
            "BURST" | "LEN" => {
                p.burst.len = val
                    .parse()
                    .map_err(|_| ConfigError::new(format!("BURST: expected int, got `{val}`")))?;
            }
            "TYPE" => {
                p.burst.kind = match upval.as_str() {
                    "FIXED" => BurstKind::Fixed,
                    "INCR" => BurstKind::Incr,
                    "WRAP" => BurstKind::Wrap,
                    _ => return Err(ConfigError::new(format!("TYPE: unknown `{val}`"))),
                }
            }
            "SIG" => {
                p.signaling = match upval.as_str() {
                    "NB" | "NONBLOCKING" => Signaling::NonBlocking,
                    "BLK" | "B" | "BLOCKING" => Signaling::Blocking,
                    "AGR" | "AG" | "AGGRESSIVE" => Signaling::Aggressive,
                    _ => return Err(ConfigError::new(format!("SIG: unknown `{val}`"))),
                }
            }
            "BATCH" => {
                p.batch_len = parse_u64_with_suffix(val)
                    .ok_or_else(|| ConfigError::new(format!("BATCH: expected int, got `{val}`")))?
                    as u32;
            }
            "START" => {
                p.start_addr = parse_u64_with_suffix(val).ok_or_else(|| {
                    ConfigError::new(format!("START: expected int, got `{val}`"))
                })?;
            }
            "REGION" => {
                p.region_bytes = parse_u64_with_suffix(val).ok_or_else(|| {
                    ConfigError::new(format!("REGION: expected int, got `{val}`"))
                })?;
            }
            "DATA" => {
                p.data = match upval.as_str() {
                    "PRBS" => DataPattern::Prbs { seed: data_seed },
                    "ZEROS" => DataPattern::Zeros,
                    hex => {
                        let w = u32::from_str_radix(hex.trim_start_matches("0X"), 16)
                            .map_err(|_| {
                                ConfigError::new(format!("DATA: expected PRBS|ZEROS|hex, got `{val}`"))
                            })?;
                        DataPattern::Constant(w)
                    }
                }
            }
            "DSEED" => {
                data_seed = val
                    .parse()
                    .map_err(|_| ConfigError::new(format!("DSEED: expected int, got `{val}`")))?;
                if let DataPattern::Prbs { .. } = p.data {
                    p.data = DataPattern::Prbs { seed: data_seed };
                }
            }
            "VERIFY" => {
                p.verify = matches!(upval.as_str(), "1" | "TRUE" | "ON" | "YES");
            }
            _ => return Err(ConfigError::new(format!("unknown pattern key `{k}`"))),
        }
    }
    p.validate()?;
    Ok(p)
}

/// Render a [`PatternConfig`] back to the `CFG` token syntax (used by the
/// host protocol echo and for logging). `parse_pattern_config` of the
/// output reproduces the config (round-trip property-tested).
pub fn format_pattern_config(p: &PatternConfig) -> String {
    let mut s = String::new();
    match p.op {
        OpMix::ReadOnly => s.push_str("OP=R"),
        OpMix::WriteOnly => s.push_str("OP=W"),
        OpMix::Mixed { read_pct } => {
            s.push_str("OP=M");
            s.push_str(&format!(" RDPCT={read_pct}"));
        }
    }
    match p.addr {
        AddrMode::Sequential => s.push_str(" ADDR=SEQ"),
        AddrMode::Random { seed } => s.push_str(&format!(" ADDR=RND SEED={seed}")),
    }
    s.push_str(&format!(" BURST={} TYPE={}", p.burst.len, p.burst.kind.label()));
    s.push_str(&format!(" SIG={}", p.signaling.label()));
    s.push_str(&format!(" BATCH={}", p.batch_len));
    s.push_str(&format!(" START={} REGION={}", p.start_addr, p.region_bytes));
    match p.data {
        DataPattern::Prbs { seed } => s.push_str(&format!(" DATA=PRBS DSEED={seed}")),
        DataPattern::Zeros => s.push_str(" DATA=ZEROS"),
        DataPattern::Constant(w) => s.push_str(&format!(" DATA={w:08x}")),
    }
    s.push_str(&format!(" VERIFY={}", u8::from(p.verify)));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_text_sections_and_comments() {
        let m = parse_kv_text(
            "# top\nchannels = 2\n[controller]\nlookahead = 8 # window\n\n[counters]\nlatency=off\n",
        )
        .unwrap();
        assert_eq!(m.get("channels").unwrap(), "2");
        assert_eq!(m.get("controller.lookahead").unwrap(), "8");
        assert_eq!(m.get("counters.latency").unwrap(), "off");
    }

    #[test]
    fn kv_text_rejects_garbage() {
        assert!(parse_kv_text("not a kv line").is_err());
        assert!(parse_kv_text("[unterminated").is_err());
    }

    #[test]
    fn design_config_full_text() {
        let cfg = parse_design_config(
            "channels = 3\nspeed = ddr4-2400\naxi_width = 256\n\
             [controller]\nlookahead = 6\noutstanding_cap = 16\n\
             [counters]\nrefresh = false\n",
        )
        .unwrap();
        assert_eq!(cfg.channels, 3);
        assert_eq!(cfg.speed, SpeedBin::Ddr4_2400);
        assert_eq!(cfg.controller.lookahead, 6);
        assert_eq!(cfg.controller.outstanding_cap, 16);
        assert!(!cfg.counters.refresh);
        assert!(cfg.counters.latency);
    }

    #[test]
    fn design_config_invalid_rejected() {
        assert!(parse_design_config("channels = 9").is_err());
        assert!(parse_design_config("speed = 3200").is_err());
    }

    #[test]
    fn suffix_parsing() {
        assert_eq!(parse_u64_with_suffix("64"), Some(64));
        assert_eq!(parse_u64_with_suffix("4k"), Some(4096));
        assert_eq!(parse_u64_with_suffix("16M"), Some(16 << 20));
        assert_eq!(parse_u64_with_suffix("2g"), Some(2 << 30));
        assert_eq!(parse_u64_with_suffix("x"), None);
    }

    #[test]
    fn pattern_tokens_full() {
        let p = parse_pattern_config(&[
            "OP=M", "RDPCT=70", "ADDR=RND", "SEED=99", "BURST=16", "TYPE=WRAP", "SIG=AGR",
            "BATCH=2k", "START=4096", "REGION=64m", "DATA=PRBS", "DSEED=5", "VERIFY=1",
        ])
        .unwrap();
        assert_eq!(p.op, OpMix::Mixed { read_pct: 70 });
        assert_eq!(p.addr, AddrMode::Random { seed: 99 });
        assert_eq!(p.burst, super::super::BurstSpec { len: 16, kind: BurstKind::Wrap });
        assert_eq!(p.signaling, Signaling::Aggressive);
        assert_eq!(p.batch_len, 2048);
        assert_eq!(p.start_addr, 4096);
        assert_eq!(p.region_bytes, 64 << 20);
        assert_eq!(p.data, DataPattern::Prbs { seed: 5 });
        assert!(p.verify);
    }

    #[test]
    fn pattern_rdpct_order_independent() {
        let a = parse_pattern_config(&["RDPCT=30", "OP=M"]).unwrap();
        let b = parse_pattern_config(&["OP=M", "RDPCT=30"]).unwrap();
        assert_eq!(a.op, OpMix::Mixed { read_pct: 30 });
        assert_eq!(b.op, OpMix::Mixed { read_pct: 30 });
    }

    #[test]
    fn pattern_rejects_invalid() {
        assert!(parse_pattern_config(&["OP=X"]).is_err());
        assert!(parse_pattern_config(&["BURST=500"]).is_err());
        assert!(parse_pattern_config(&["BURST=12", "TYPE=WRAP"]).is_err());
        assert!(parse_pattern_config(&["NOPE=1"]).is_err());
        assert!(parse_pattern_config(&["OP"]).is_err());
    }

    #[test]
    fn pattern_format_roundtrip() {
        let p = parse_pattern_config(&[
            "OP=M", "RDPCT=25", "ADDR=RND", "SEED=3", "BURST=8", "TYPE=INCR", "SIG=BLK",
            "BATCH=100", "DATA=ZEROS", "VERIFY=1",
        ])
        .unwrap();
        let text = format_pattern_config(&p);
        let toks: Vec<&str> = text.split_whitespace().collect();
        let q = parse_pattern_config(&toks).unwrap();
        assert_eq!(p, q);
    }
}
