//! Design-time and run-time configuration of the benchmarking platform.
//!
//! Mirrors Table I of the paper:
//!
//! | Design-time                | Run-time                          |
//! |----------------------------|-----------------------------------|
//! | Number of memory channels  | Mix of read and write operations  |
//! | Memory data rate           | Sequential or random accesses     |
//! | Performance counters       | Length and type of bursts         |
//! |                            | Signaling mode                    |
//! |                            | Length of transaction batches     |
//!
//! Design-time parameters ([`DesignConfig`]) fix the instantiated hardware:
//! they select what gets "synthesized" (number of memory interfaces + TGs,
//! clock frequencies, which counters exist). Run-time parameters
//! ([`PatternConfig`]) are sent over the host-controller link per batch and
//! can change between batches without reconfiguration.

mod parse;

pub use parse::{
    format_channel_mix, format_channel_spec, format_pattern_config, parse_channel_mix,
    parse_channel_spec, parse_controller_tokens, parse_design_config, parse_kv_text,
    parse_mix_file, parse_pattern_config, parse_u64_with_suffix, ConfigError,
};

use crate::ddr4::geometry::DramGeometry;
use crate::ddr4::mapping::MappingPolicy;

/// Runtime-selectable scheduler / page-policy identifier — a plain
/// configuration value, like [`MappingPolicy`]. The behaviour behind
/// each name is implemented in [`crate::controller::sched`]. Parsed
/// from the `SCHED=` pattern token, the `--sched`/`--scheds` CLI axes,
/// the `[controller] sched =` design key and the host protocol.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum SchedKind {
    /// Strict oldest-first: no reordering at all (reorder window 1).
    Fcfs,
    /// FR-FCFS, open page — the MIG-like default (pre-refactor
    /// behaviour, preserved bit-exactly).
    #[default]
    FrFcfs,
    /// FR-FCFS with a bypass cap: at most `cap` consecutive younger
    /// row hits may overtake the oldest request (starvation bound).
    FrFcfsCap {
        /// Maximum consecutive head bypasses before the scheduler
        /// degrades to strict FCFS until the head issues.
        cap: u32,
    },
    /// Closed page: CAS commands carry auto-precharge (RDA/WRA) unless
    /// another queued request still wants the open row.
    Closed,
    /// Open page with an idle-timer precharge (the pre-existing
    /// `idle_precharge_cycles` heuristic, on by default).
    Adaptive,
}

impl SchedKind {
    /// Default bypass cap of `frfcfs-cap` (chosen so a four-deep reorder
    /// window cannot starve its head for more than one window refill).
    pub const DEFAULT_CAP: u32 = 4;

    /// Every selectable policy, in sweep/report order.
    pub const ALL: [SchedKind; 5] = [
        SchedKind::Fcfs,
        SchedKind::FrFcfs,
        SchedKind::FrFcfsCap { cap: Self::DEFAULT_CAP },
        SchedKind::Closed,
        SchedKind::Adaptive,
    ];

    /// Parse a policy name: `fcfs`, `frfcfs` (or `fr-fcfs`),
    /// `frfcfs-cap` / `frfcfs-cap8` / `frfcfs-cap=8`, `closed`,
    /// `adaptive`. Underscores are accepted in place of dashes.
    pub fn parse(s: &str) -> Option<Self> {
        let s = s.trim().to_ascii_lowercase().replace('_', "-");
        match s.as_str() {
            "fcfs" | "in-order" => return Some(SchedKind::Fcfs),
            "frfcfs" | "fr-fcfs" | "open" => return Some(SchedKind::FrFcfs),
            "closed" | "closed-page" => return Some(SchedKind::Closed),
            "adaptive" | "adaptive-open" => return Some(SchedKind::Adaptive),
            _ => {}
        }
        let rest = s.strip_prefix("frfcfs-cap").or_else(|| s.strip_prefix("fr-fcfs-cap"))?;
        if rest.is_empty() {
            return Some(SchedKind::FrFcfsCap { cap: Self::DEFAULT_CAP });
        }
        let cap: u32 = rest.strip_prefix('=').unwrap_or(rest).parse().ok()?;
        if cap == 0 {
            return None;
        }
        Some(SchedKind::FrFcfsCap { cap })
    }

    /// Canonical name (round-trips through [`Self::parse`]; used for
    /// artifact labels and the `SCHED=` echo).
    pub fn name(self) -> String {
        match self {
            SchedKind::Fcfs => "fcfs".into(),
            SchedKind::FrFcfs => "frfcfs".into(),
            SchedKind::FrFcfsCap { cap } if cap == Self::DEFAULT_CAP => "frfcfs-cap".into(),
            SchedKind::FrFcfsCap { cap } => format!("frfcfs-cap{cap}"),
            SchedKind::Closed => "closed".into(),
            SchedKind::Adaptive => "adaptive".into(),
        }
    }
}

impl std::fmt::Display for SchedKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name())
    }
}

/// Runtime-selectable simulation engine. Both engines drive the exact
/// same controller/device/TG state machines; they differ only in how
/// the batch executive advances time. The cycle engine is the frozen
/// oracle; the event engine leaps over provably idle fabric cycles
/// (see `rust/tests/engine_differential.rs` for the bit-exactness
/// pin). Parsed from the `ENGINE=` pattern token, the `--engine` CLI
/// option, the `engine =` design key and the host protocol.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum EngineKind {
    /// Step every fabric cycle (4 DRAM ticks each) unconditionally.
    #[default]
    Cycle,
    /// Time-skip core: every timing source (controller wake, pending
    /// completions, TG injection) publishes its next-actionable tick
    /// and the loop jumps straight to the earliest one.
    Event,
}

impl EngineKind {
    /// Both engines, in report order.
    pub const ALL: [EngineKind; 2] = [EngineKind::Cycle, EngineKind::Event];

    /// Parse an engine name: `cycle` or `event`.
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "cycle" => Some(EngineKind::Cycle),
            "event" => Some(EngineKind::Event),
            _ => None,
        }
    }

    /// Canonical name (round-trips through [`Self::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            EngineKind::Cycle => "cycle",
            EngineKind::Event => "event",
        }
    }
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// JEDEC DDR4 speed bins supported by the platform — the four the paper's
/// campaign covers (§III, Table II).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SpeedBin {
    /// DDR4-1600 (K bin, 11-11-11): PHY 800 MHz, AXI 200 MHz.
    Ddr4_1600,
    /// DDR4-1866 (M bin, 13-13-13): PHY 933 MHz, AXI 233 MHz.
    Ddr4_1866,
    /// DDR4-2133 (P bin, 15-15-15): PHY 1067 MHz, AXI 267 MHz.
    Ddr4_2133,
    /// DDR4-2400 (R bin, 16-16-16): PHY 1200 MHz, AXI 300 MHz.
    Ddr4_2400,
}

impl SpeedBin {
    /// All bins in ascending data-rate order.
    pub const ALL: [SpeedBin; 4] = [
        SpeedBin::Ddr4_1600,
        SpeedBin::Ddr4_1866,
        SpeedBin::Ddr4_2133,
        SpeedBin::Ddr4_2400,
    ];

    /// Data rate in MT/s.
    pub fn data_rate_mts(self) -> u32 {
        match self {
            SpeedBin::Ddr4_1600 => 1600,
            SpeedBin::Ddr4_1866 => 1866,
            SpeedBin::Ddr4_2133 => 2133,
            SpeedBin::Ddr4_2400 => 2400,
        }
    }

    /// DRAM (PHY) clock frequency in MHz = data rate / 2 (DDR).
    pub fn phy_clock_mhz(self) -> f64 {
        self.data_rate_mts() as f64 / 2.0
    }

    /// AXI / fabric clock frequency in MHz — the paper keeps a strict 4:1
    /// PHY:AXI ratio (Table II: 200/233/267/300 MHz).
    pub fn axi_clock_mhz(self) -> f64 {
        self.phy_clock_mhz() / 4.0
    }

    /// DRAM clock period in nanoseconds (tCK).
    pub fn tck_ns(self) -> f64 {
        1000.0 / self.phy_clock_mhz()
    }

    /// Parse from a "1600"/"ddr4-1600" style string.
    pub fn parse(s: &str) -> Option<Self> {
        let s = s.trim().to_ascii_lowercase();
        let s = s.strip_prefix("ddr4-").unwrap_or(&s);
        match s {
            "1600" => Some(SpeedBin::Ddr4_1600),
            "1866" => Some(SpeedBin::Ddr4_1866),
            "2133" => Some(SpeedBin::Ddr4_2133),
            "2400" => Some(SpeedBin::Ddr4_2400),
            _ => None,
        }
    }

    /// Human-readable name ("DDR4-1600").
    pub fn name(self) -> &'static str {
        match self {
            SpeedBin::Ddr4_1600 => "DDR4-1600",
            SpeedBin::Ddr4_1866 => "DDR4-1866",
            SpeedBin::Ddr4_2133 => "DDR4-2133",
            SpeedBin::Ddr4_2400 => "DDR4-2400",
        }
    }
}

impl std::fmt::Display for SpeedBin {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Which performance counters to instantiate — a design-time choice in the
/// paper (counters cost flip-flops, so unneeded ones are left out of the
/// bitstream).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CounterSet {
    /// Cycle counters for read/write batches (always needed for throughput).
    pub batch_cycles: bool,
    /// Per-transaction latency histogram (min/max/avg + buckets).
    pub latency: bool,
    /// Refresh-stall cycle counter (refresh-related performance degradation,
    /// §II-C "other statistics").
    pub refresh: bool,
    /// Data-integrity mismatch counter.
    pub integrity: bool,
}

impl CounterSet {
    /// Everything on — what the paper's campaign used.
    pub fn full() -> Self {
        Self { batch_cycles: true, latency: true, refresh: true, integrity: true }
    }

    /// Throughput-only (cheapest design).
    pub fn minimal() -> Self {
        Self { batch_cycles: true, latency: false, refresh: false, integrity: false }
    }
}

impl Default for CounterSet {
    fn default() -> Self {
        Self::full()
    }
}

/// Microarchitectural parameters of the MIG-like memory controller. These
/// are the calibration knobs documented in DESIGN.md §5; the defaults are
/// the "MIG-like" profile fitted to the paper's Table IV.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ControllerParams {
    /// Depth of the read request queue (native-interface entries).
    pub read_queue_depth: usize,
    /// Depth of the write request queue.
    pub write_queue_depth: usize,
    /// How many queue entries the FR-FCFS scheduler inspects per decision
    /// (the reorder window; real MIG has a small lookahead).
    pub lookahead: usize,
    /// Write-drain high watermark: switch to write mode at/above this
    /// occupancy.
    pub write_drain_high: usize,
    /// Write-drain low watermark: return to read mode at/below this.
    pub write_drain_low: usize,
    /// Maximum AXI transactions the front end keeps in flight per direction.
    pub outstanding_cap: usize,
    /// Close an open row after this many idle DRAM cycles (0 = pure open
    /// page, never speculatively closed).
    pub idle_precharge_cycles: u32,
    /// Front-end command-path cost: minimum AXI cycles between accepted
    /// AXI transactions on each address channel. Real MIG's address decode
    /// pipeline accepts a new transaction at most every other fabric cycle,
    /// which is what caps single-beat throughput at ~half the bus rate
    /// (paper: 3.08 GB/s vs the 6.4 GB/s bus ceiling).
    pub addr_cmd_interval_axi: u32,
    /// Serial transaction front end (MIG-like): the controller begins
    /// unrolling a new AXI transaction into its native queue only once the
    /// previous transaction's requests have all issued their CAS (queue
    /// drained). Requests *within* a transaction still pipeline freely —
    /// this is what makes random long bursts recover to near-sequential
    /// throughput while random singles pay the whole row cycle per
    /// transaction (the paper's 5.5x/7.2x seq→rnd drops).
    pub serial_frontend: bool,
    /// Page-miss pipeline flush (MIG-like): a row miss (ACT issued on
    /// behalf of direction X) blocks acceptance of the *next* X-direction
    /// transaction until the miss's data phase completes plus a tRP refill
    /// margin. Row hits stream unaffected — sequential singles stay
    /// address-rate-limited while random singles pay the full
    /// PRE+ACT+CAS+data round trip per transaction, reproducing the
    /// paper's 0.56/0.42 GB/s random-single floors.
    pub miss_flush: bool,
    /// Minimum DRAM cycles the scheduler dwells in a direction before a
    /// voluntary read↔write switch (watermark overflows and hazards still
    /// force switches). Amortizes the tWTR/CL bus-turnaround penalties so
    /// mixed workloads time-slice in batches instead of thrashing per
    /// transaction — the behaviour behind the paper's "mixed beats pure"
    /// observation.
    pub mode_dwell_ck: u32,
    /// Command-scheduling / page-management policy
    /// ([`crate::controller::sched`]): `fcfs`, `frfcfs` (the MIG-like
    /// default), `frfcfs-cap[N]`, `closed` or `adaptive`. Selectable at
    /// design time here, per batch via the `SCHED=` pattern token, and
    /// as a sweep axis (`--scheds`).
    pub sched: SchedKind,
    /// Run the frozen scan-based scheduler implementation instead of
    /// the incrementally-indexed fast path
    /// ([`crate::controller::sched_index`]). The two are pinned
    /// bit-exact by `rust/tests/sched_index_differential.rs`; the scans
    /// exist as the differential oracle and for debugging, not as a
    /// tuning knob — leave this off outside tests and benches.
    pub sched_oracle: bool,
}

impl Default for ControllerParams {
    fn default() -> Self {
        Self {
            read_queue_depth: 16,
            write_queue_depth: 16,
            lookahead: 4,
            write_drain_high: 12,
            write_drain_low: 4,
            outstanding_cap: 8,
            idle_precharge_cycles: 0,
            addr_cmd_interval_axi: 2,
            serial_frontend: true,
            miss_flush: true,
            mode_dwell_ck: 48,
            sched: SchedKind::FrFcfs,
            sched_oracle: false,
        }
    }
}

/// Design-time configuration: what gets instantiated on the FPGA.
#[derive(Debug, Clone, PartialEq)]
pub struct DesignConfig {
    /// Number of memory channels (1–3 on the XCKU115; each adds one memory
    /// interface + one traffic generator, per the paper's Fig. 1).
    pub channels: usize,
    /// Memory data rate (fixes PHY and AXI clocks at the 4:1 ratio).
    pub speed: SpeedBin,
    /// Instantiated performance counters.
    pub counters: CounterSet,
    /// AXI data-bus width in bits (the MIG default for a 64-bit DDR4
    /// channel at 4:1 is 256; see DESIGN.md §5 calibration).
    pub axi_data_width_bits: u32,
    /// DRAM geometry of each channel's memory board.
    pub geometry: DramGeometry,
    /// Memory-controller microarchitecture.
    pub controller: ControllerParams,
    /// Simulation engine driving the batch loop (`--engine` / the
    /// `engine =` design key). Semantics are identical either way; the
    /// event engine only skips provably idle fabric cycles.
    pub engine: EngineKind,
    /// Telemetry sampling window in AXI cycles (`telemetry =` design
    /// key). `None` disables the windowed time-series sampler; `Some(w)`
    /// makes every batch record one [`crate::obs::TelemetryWindow`] per
    /// `w` fabric cycles. Observation-only: results are bit-identical
    /// with telemetry on or off.
    pub telemetry: Option<u64>,
}

impl DesignConfig {
    /// Single-channel design at the given data rate — the configuration of
    /// the paper's Table IV and Figs. 2–3.
    pub fn single_channel(speed: SpeedBin) -> Self {
        Self::with_channels(1, speed)
    }

    /// N-channel design (the XCKU115 hosts up to 3 memory controllers).
    pub fn with_channels(channels: usize, speed: SpeedBin) -> Self {
        Self {
            channels,
            speed,
            counters: CounterSet::full(),
            axi_data_width_bits: 256,
            geometry: DramGeometry::profpga_board(),
            controller: ControllerParams::default(),
            engine: EngineKind::default(),
            telemetry: None,
        }
    }

    /// AXI data-bus width in bytes per beat.
    pub fn axi_beat_bytes(&self) -> u32 {
        self.axi_data_width_bits / 8
    }

    /// Validate invariants (channel count, width, watermark ordering, …).
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.channels == 0 || self.channels > 3 {
            return Err(ConfigError::new(format!(
                "channels must be 1..=3 (XCKU115 hosts up to 3 memory controllers), got {}",
                self.channels
            )));
        }
        if !self.axi_data_width_bits.is_power_of_two() || self.axi_data_width_bits < 64 {
            return Err(ConfigError::new(format!(
                "axi_data_width_bits must be a power of two >= 64, got {}",
                self.axi_data_width_bits
            )));
        }
        let c = &self.controller;
        if c.write_drain_low >= c.write_drain_high {
            return Err(ConfigError::new("write_drain_low must be < write_drain_high"));
        }
        if c.write_drain_high > c.write_queue_depth {
            return Err(ConfigError::new("write_drain_high must be <= write_queue_depth"));
        }
        if c.lookahead == 0 || c.outstanding_cap == 0 {
            return Err(ConfigError::new("lookahead and outstanding_cap must be >= 1"));
        }
        if c.addr_cmd_interval_axi == 0 {
            return Err(ConfigError::new("addr_cmd_interval_axi must be >= 1"));
        }
        if let SchedKind::FrFcfsCap { cap } = c.sched {
            if cap == 0 {
                return Err(ConfigError::new("frfcfs-cap requires cap >= 1"));
            }
        }
        if self.telemetry == Some(0) {
            return Err(ConfigError::new("telemetry window must be >= 1 AXI cycle"));
        }
        self.geometry.validate().map_err(ConfigError::new)?;
        Ok(())
    }
}

impl Default for DesignConfig {
    fn default() -> Self {
        Self::single_channel(SpeedBin::Ddr4_1600)
    }
}

/// Operation mix of a batch (run-time parameter).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpMix {
    /// Read-only batch.
    ReadOnly,
    /// Write-only batch.
    WriteOnly,
    /// Interleaved reads and writes; `read_pct` of transactions are reads.
    Mixed { read_pct: u32 },
}

impl OpMix {
    /// Fraction of read transactions, in percent.
    pub fn read_pct(self) -> u32 {
        match self {
            OpMix::ReadOnly => 100,
            OpMix::WriteOnly => 0,
            OpMix::Mixed { read_pct } => read_pct,
        }
    }

    /// Short label used in reports ("R"/"W"/"M", as in the paper's Fig. 2).
    pub fn label(self) -> &'static str {
        match self {
            OpMix::ReadOnly => "R",
            OpMix::WriteOnly => "W",
            OpMix::Mixed { .. } => "M",
        }
    }
}

/// Addressing mode (run-time parameter) — the access-pattern engine.
///
/// The first two variants are the paper's Table I; the rest extend the
/// engine with the pattern families that actually expose controller
/// behaviour (strided walks, adversarial bank conflicts, dependent
/// pointer chases, and multi-phase compositions). All of them are
/// selectable at run time through the config-file/CLI syntax and the
/// host-controller `CFG` command (see [`parse_pattern_config`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AddrMode {
    /// Sequential: consecutive transactions target consecutive addresses.
    Sequential,
    /// Random: each transaction targets a uniformly random, burst-aligned
    /// address in the test region; `seed` makes runs reproducible.
    Random { seed: u64 },
    /// Strided: each transaction advances `stride` bytes (rounded up to
    /// the transaction alignment), wrapping inside the test region.
    /// Strides at or beyond the DRAM row span turn every access into a
    /// row miss while staying perfectly predictable.
    Strided { stride: u64 },
    /// Bank conflict: an adversarial stream derived from the DRAM
    /// geometry — successive transactions hit the *same* bank in
    /// *different* rows, defeating both bank-level parallelism and the
    /// row buffer (the worst case for an open-page controller).
    BankConflict { seed: u64 },
    /// Pointer chase: a dependent, graph-like walk over a `working_set`-
    /// byte region. Each address is derived from the previous one via a
    /// full-period permutation, so the chase visits every slot of the
    /// working set exactly once per cycle. Pair with
    /// [`Signaling::Blocking`] to model true load-to-load dependence.
    PointerChase { seed: u64, working_set: u64 },
    /// Phased: run each inner mode for its transaction count, cycling
    /// through the list (e.g. a sequential warm-up phase followed by a
    /// random steady state). One level deep: phases cannot nest.
    Phased(Vec<(AddrMode, u32)>),
}

impl AddrMode {
    /// Smallest test region on which the bank-conflict stream can honour
    /// its row-miss guarantee: two same-bank row windows, i.e.
    /// `2 × banks × row_bytes` = 2 × 8 × 8 KiB = 128 KiB on the modeled
    /// proFPGA board. Smaller regions would silently degenerate to one
    /// repeated (row-hit) address, so [`PatternConfig::validate`] rejects
    /// them instead.
    pub const BANK_CONFLICT_MIN_REGION: u64 = 128 << 10;

    /// Does this mode (or any phase of it) use bank-conflict addressing?
    pub fn uses_bank_conflict(&self) -> bool {
        match self {
            AddrMode::BankConflict { .. } => true,
            AddrMode::Phased(phases) => phases.iter().any(|(m, _)| m.uses_bank_conflict()),
            _ => false,
        }
    }

    /// Short label used in reports.
    pub fn label(&self) -> &'static str {
        match self {
            AddrMode::Sequential => "Seq",
            AddrMode::Random { .. } => "Rnd",
            AddrMode::Strided { .. } => "Strd",
            AddrMode::BankConflict { .. } => "Bank",
            AddrMode::PointerChase { .. } => "Chase",
            AddrMode::Phased(_) => "Phase",
        }
    }

    /// Is this the uniformly-random mode?
    pub fn is_random(&self) -> bool {
        matches!(self, AddrMode::Random { .. })
    }

    /// Does the mode defeat row-buffer locality? Used by the analytic
    /// bandwidth model to pick the row-miss service time: random, bank
    /// conflicts and pointer chases always do; strides do once they skip
    /// a full DRAM row (8 KiB on the modeled board); phased patterns do
    /// if any phase does.
    pub fn row_hostile(&self) -> bool {
        match self {
            AddrMode::Sequential => false,
            AddrMode::Random { .. }
            | AddrMode::BankConflict { .. }
            | AddrMode::PointerChase { .. } => true,
            AddrMode::Strided { stride } => *stride >= 8192,
            AddrMode::Phased(phases) => phases.iter().any(|(m, _)| m.row_hostile()),
        }
    }

    /// Seed for the op-mix RNG of the transaction planner. Preserves the
    /// historical values for `Sequential`/`Random` so existing plans stay
    /// bit-identical.
    pub fn plan_seed(&self) -> u64 {
        match self {
            AddrMode::Sequential => 0x5EED,
            AddrMode::Random { seed } => seed ^ 0xA5A5_5A5A,
            AddrMode::Strided { stride } => 0x57A1_DE00 ^ stride.rotate_left(17),
            AddrMode::BankConflict { seed } => seed ^ 0x00BA_4C0F,
            AddrMode::PointerChase { seed, working_set } => {
                seed ^ working_set.rotate_left(32) ^ 0xC4A5E
            }
            AddrMode::Phased(phases) => phases
                .iter()
                .fold(0x0F_A5ED, |h, (m, n)| h.rotate_left(7) ^ m.plan_seed() ^ *n as u64),
        }
    }

    /// Validate mode-specific invariants (positive stride/working set,
    /// non-empty single-level phases with non-zero counts).
    pub fn validate(&self) -> Result<(), ConfigError> {
        match self {
            AddrMode::Sequential | AddrMode::Random { .. } | AddrMode::BankConflict { .. } => {
                Ok(())
            }
            AddrMode::Strided { stride } => {
                if *stride == 0 {
                    return Err(ConfigError::new("strided mode requires stride > 0"));
                }
                Ok(())
            }
            AddrMode::PointerChase { working_set, .. } => {
                if *working_set == 0 {
                    return Err(ConfigError::new("pointer chase requires working_set > 0"));
                }
                Ok(())
            }
            AddrMode::Phased(phases) => {
                if phases.is_empty() {
                    return Err(ConfigError::new("phased mode requires at least one phase"));
                }
                for (mode, txns) in phases {
                    if *txns == 0 {
                        return Err(ConfigError::new("phase transaction counts must be >= 1"));
                    }
                    if matches!(mode, AddrMode::Phased(_)) {
                        return Err(ConfigError::new("phases cannot nest"));
                    }
                    mode.validate()?;
                }
                Ok(())
            }
        }
    }
}

/// AXI burst type (AXI4 `AxBURST` encoding).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BurstKind {
    /// FIXED: same address every beat (e.g. FIFO draining).
    Fixed,
    /// INCR: address increments by the beat size each transfer.
    Incr,
    /// WRAP: like INCR but wraps at an aligned boundary of len×size bytes.
    Wrap,
}

impl BurstKind {
    /// AXI4 AxBURST field encoding.
    pub fn axburst(self) -> u8 {
        match self {
            BurstKind::Fixed => 0b00,
            BurstKind::Incr => 0b01,
            BurstKind::Wrap => 0b10,
        }
    }

    /// Report label.
    pub fn label(self) -> &'static str {
        match self {
            BurstKind::Fixed => "FIXED",
            BurstKind::Incr => "INCR",
            BurstKind::Wrap => "WRAP",
        }
    }
}

/// Burst specification: length (beats per transaction, 1–128) and type.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BurstSpec {
    /// Number of data transfers per transaction (1 = "single transaction").
    pub len: u32,
    /// Burst type.
    pub kind: BurstKind,
}

impl BurstSpec {
    /// A single (non-burst) transaction.
    pub fn single() -> Self {
        Self { len: 1, kind: BurstKind::Incr }
    }

    /// An incrementing burst of the given length.
    pub fn incr(len: u32) -> Self {
        Self { len, kind: BurstKind::Incr }
    }

    /// Paper labels: single / short (4) / medium (32) / long (128).
    pub fn paper_label(&self) -> &'static str {
        match self.len {
            1 => "S",
            4 => "SB",
            32 => "MB",
            128 => "LB",
            _ => "B",
        }
    }
}

/// AXI handshake signaling mode of the traffic generator (§II-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Signaling {
    /// Issue new requests as soon as possible, like a generic AXI device
    /// (bounded by the outstanding-transaction window).
    NonBlocking,
    /// Delay new requests until all outstanding transactions complete.
    Blocking,
    /// Always assert `ready`, accepting data transfers immediately.
    Aggressive,
}

impl Signaling {
    /// Report label.
    pub fn label(self) -> &'static str {
        match self {
            Signaling::NonBlocking => "NB",
            Signaling::Blocking => "BLK",
            Signaling::Aggressive => "AGR",
        }
    }
}

/// What data the TG writes (and checks on read-back).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataPattern {
    /// xorshift32 PRBS seeded per transaction — the default; matches the
    /// Pallas kernel so payloads can be generated/verified via XLA.
    Prbs { seed: u32 },
    /// All-zeros (what Shuhai does; kept for the comparison ablation).
    Zeros,
    /// Constant word.
    Constant(u32),
}

impl Default for DataPattern {
    fn default() -> Self {
        DataPattern::Prbs { seed: 1 }
    }
}

/// Run-time configuration of one traffic-generator batch — everything in
/// the right column of the paper's Table I.
#[derive(Debug, Clone, PartialEq)]
pub struct PatternConfig {
    /// Read/write mix.
    pub op: OpMix,
    /// Sequential or random addressing.
    pub addr: AddrMode,
    /// Burst length and type.
    pub burst: BurstSpec,
    /// Handshake signaling mode.
    pub signaling: Signaling,
    /// Number of transactions in the batch.
    pub batch_len: u32,
    /// First byte address of the test region.
    pub start_addr: u64,
    /// Size of the test region in bytes (addresses wrap inside it).
    pub region_bytes: u64,
    /// Payload pattern.
    pub data: DataPattern,
    /// Verify read data against expected contents (costs nothing in the
    /// model; in hardware it instantiates the checker).
    pub verify: bool,
    /// Address-mapping policy override for this batch (`MAP=` token).
    /// `None` runs under the design geometry's policy; `Some` re-maps the
    /// channel at run time — both the traffic generator's decode and the
    /// geometry-derived adversarial streams follow it.
    pub mapping: Option<MappingPolicy>,
    /// Scheduler/page-policy override for this batch (`SCHED=` token).
    /// `None` runs under the design's [`ControllerParams::sched`];
    /// `Some` re-schedules the channel at run time for the batches that
    /// follow (queued state and open rows carry over).
    pub sched: Option<SchedKind>,
    /// Simulation-engine override for this batch (`ENGINE=` token).
    /// `None` runs under the design's [`DesignConfig::engine`]. Either
    /// way the results are bit-identical; this only selects how the
    /// batch loop advances time.
    pub engine: Option<EngineKind>,
    /// Telemetry window override for this batch (`TELEM=` token): record
    /// one time-series sample every N AXI cycles. `None` falls back to
    /// the design's [`DesignConfig::telemetry`]. Observation-only —
    /// counters and results are bit-identical either way.
    pub telemetry: Option<u64>,
}

impl PatternConfig {
    /// Default region: 256 MiB starting at 0.
    pub const DEFAULT_REGION: u64 = 256 << 20;

    fn base(op: OpMix, addr: AddrMode, burst: BurstSpec, batch_len: u32) -> Self {
        Self {
            op,
            addr,
            burst,
            signaling: Signaling::NonBlocking,
            batch_len,
            start_addr: 0,
            region_bytes: Self::DEFAULT_REGION,
            data: DataPattern::default(),
            verify: false,
            mapping: None,
            sched: None,
            engine: None,
            telemetry: None,
        }
    }

    /// Sequential read burst pattern.
    pub fn seq_read_burst(burst_len: u32, batch_len: u32) -> Self {
        Self::base(OpMix::ReadOnly, AddrMode::Sequential, BurstSpec::incr(burst_len), batch_len)
    }

    /// Sequential write burst pattern.
    pub fn seq_write_burst(burst_len: u32, batch_len: u32) -> Self {
        Self::base(OpMix::WriteOnly, AddrMode::Sequential, BurstSpec::incr(burst_len), batch_len)
    }

    /// Random read burst pattern.
    pub fn rnd_read_burst(burst_len: u32, batch_len: u32, seed: u64) -> Self {
        let addr = AddrMode::Random { seed };
        Self::base(OpMix::ReadOnly, addr, BurstSpec::incr(burst_len), batch_len)
    }

    /// Random write burst pattern.
    pub fn rnd_write_burst(burst_len: u32, batch_len: u32, seed: u64) -> Self {
        let addr = AddrMode::Random { seed };
        Self::base(OpMix::WriteOnly, addr, BurstSpec::incr(burst_len), batch_len)
    }

    /// 50/50 mixed pattern.
    pub fn mixed(addr: AddrMode, burst_len: u32, batch_len: u32) -> Self {
        Self::base(OpMix::Mixed { read_pct: 50 }, addr, BurstSpec::incr(burst_len), batch_len)
    }

    /// Strided read pattern (`stride` bytes between transaction starts).
    pub fn strided_read(stride: u64, burst_len: u32, batch_len: u32) -> Self {
        Self::base(
            OpMix::ReadOnly,
            AddrMode::Strided { stride },
            BurstSpec::incr(burst_len),
            batch_len,
        )
    }

    /// Adversarial same-bank row-miss read pattern.
    pub fn bank_conflict_read(burst_len: u32, batch_len: u32, seed: u64) -> Self {
        Self::base(
            OpMix::ReadOnly,
            AddrMode::BankConflict { seed },
            BurstSpec::incr(burst_len),
            batch_len,
        )
    }

    /// Dependent pointer-chase read pattern over `working_set` bytes
    /// (blocking signaling, so each access waits for the previous one —
    /// the load-to-load dependence of a real chase).
    pub fn pointer_chase_read(working_set: u64, batch_len: u32, seed: u64) -> Self {
        let mut p = Self::base(
            OpMix::ReadOnly,
            AddrMode::PointerChase { seed, working_set },
            BurstSpec::single(),
            batch_len,
        );
        p.signaling = Signaling::Blocking;
        p
    }

    /// Bytes moved by one transaction given the AXI beat size.
    pub fn txn_bytes(&self, beat_bytes: u32) -> u64 {
        self.burst.len as u64 * beat_bytes as u64
    }

    /// Validate run-time invariants (burst length 1–128, region alignment,
    /// WRAP power-of-two length, mix percentage, …).
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.burst.len == 0 || self.burst.len > 128 {
            return Err(ConfigError::new(format!(
                "burst length must be 1..=128 (paper §II-B), got {}",
                self.burst.len
            )));
        }
        if self.burst.kind == BurstKind::Wrap && !self.burst.len.is_power_of_two() {
            return Err(ConfigError::new(
                "WRAP bursts require a power-of-two length (AXI4 A3.4.1)",
            ));
        }
        if let OpMix::Mixed { read_pct } = self.op {
            if read_pct > 100 {
                return Err(ConfigError::new("read_pct must be 0..=100"));
            }
        }
        if self.batch_len == 0 {
            return Err(ConfigError::new("batch_len must be >= 1"));
        }
        if self.region_bytes == 0 {
            return Err(ConfigError::new("region_bytes must be > 0"));
        }
        if let Some(SchedKind::FrFcfsCap { cap: 0 }) = self.sched {
            return Err(ConfigError::new("SCHED=frfcfs-cap requires cap >= 1"));
        }
        if self.telemetry == Some(0) {
            return Err(ConfigError::new("TELEM window must be >= 1 AXI cycle"));
        }
        self.addr.validate()?;
        if self.addr.uses_bank_conflict()
            && self.region_bytes < AddrMode::BANK_CONFLICT_MIN_REGION
        {
            return Err(ConfigError::new(format!(
                "bank-conflict mode needs region_bytes >= {} (2 x banks x row_bytes), got {}",
                AddrMode::BANK_CONFLICT_MIN_REGION,
                self.region_bytes
            )));
        }
        Ok(())
    }
}

impl Default for PatternConfig {
    fn default() -> Self {
        PatternConfig::seq_read_burst(32, 1024)
    }
}

/// Heterogeneous multi-channel workload: one independent [`PatternConfig`]
/// per memory channel (index = channel). This is the per-channel runtime
/// axis the paper's "varying traffic configurations" claim needs — each
/// channel can run its own pattern, op mix, `MAP=` and `SCHED=` override
/// simultaneously, instead of [`crate::platform::Platform::run_batch_all`]
/// cloning a single config onto every channel.
///
/// Built from config files (`[channel.N]` sections — [`parse_mix_file`]),
/// the CLI (repeated `--ch N:TOKENS,...` specs — [`parse_channel_mix`]) or
/// the host protocol (`CHCFG` command), and executed by
/// [`crate::platform::Platform::run_batch_mix`].
#[derive(Debug, Clone, PartialEq)]
pub struct ChannelMix {
    /// Per-channel pattern configs (index = channel).
    channels: Vec<PatternConfig>,
}

impl ChannelMix {
    /// Build a mix from per-channel configs (one per channel, channel 0
    /// first). Rejects empty mixes and mixes wider than the 3 channels
    /// the XCKU115 hosts.
    pub fn new(channels: Vec<PatternConfig>) -> Result<Self, ConfigError> {
        if channels.is_empty() {
            return Err(ConfigError::new("channel mix must configure at least one channel"));
        }
        if channels.len() > 3 {
            return Err(ConfigError::new(format!(
                "channel mix configures {} channels; the XCKU115 hosts at most 3",
                channels.len()
            )));
        }
        Ok(Self { channels })
    }

    /// The homogeneous mix: `cfg` cloned onto `n` channels (what
    /// `run_batch_all` historically did).
    pub fn uniform(cfg: &PatternConfig, n: usize) -> Result<Self, ConfigError> {
        Self::new(vec![cfg.clone(); n])
    }

    /// Number of channels the mix configures.
    pub fn len(&self) -> usize {
        self.channels.len()
    }

    /// Is the mix empty? (Never true for a constructed mix; required by
    /// the `len`/`is_empty` convention.)
    pub fn is_empty(&self) -> bool {
        self.channels.is_empty()
    }

    /// Channel `ch`'s config.
    pub fn get(&self, ch: usize) -> Option<&PatternConfig> {
        self.channels.get(ch)
    }

    /// Iterate the per-channel configs, channel 0 first.
    pub fn iter(&self) -> std::slice::Iter<'_, PatternConfig> {
        self.channels.iter()
    }

    /// Validate every per-channel config.
    pub fn validate(&self) -> Result<(), ConfigError> {
        for (ch, cfg) in self.channels.iter().enumerate() {
            cfg.validate().map_err(|e| ConfigError::new(format!("channel {ch}: {e}")))?;
        }
        Ok(())
    }

    /// Short per-channel workload label (the lowercased address-mode
    /// label: `seq`, `rnd`, `strd`, `bank`, `chase`, `phase`).
    pub fn channel_label(&self, ch: usize) -> String {
        self.channels[ch].addr.label().to_ascii_lowercase()
    }

    /// Mix label: per-channel labels joined with `+` (`seq+chase+bank`).
    pub fn label(&self) -> String {
        (0..self.len()).map(|ch| self.channel_label(ch)).collect::<Vec<_>>().join("+")
    }

    /// A copy with every per-channel `MAP=`/`SCHED=`/`ENGINE=`/`TELEM=`
    /// override cleared — the sweep executive uses it so the
    /// mapping/sched/engine/telemetry axes stay authoritative over what
    /// actually runs.
    pub fn without_overrides(&self) -> Self {
        let mut mix = self.clone();
        for cfg in &mut mix.channels {
            cfg.mapping = None;
            cfg.sched = None;
            cfg.engine = None;
            cfg.telemetry = None;
        }
        mix
    }
}

/// Per-session resource limits of the multi-session bench server
/// ([`crate::hostctrl::server`]): how much of the shared machine one
/// client session may claim. Violations surface as named `ERR`
/// diagnostics (`LIMIT_CHANNELS` / `LIMIT_BATCH` / `LIMIT_QUEUE`) so
/// scripted clients can tell a quota rejection from a protocol error.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionLimits {
    /// Highest channel index a session may touch is `max_channels - 1`
    /// (also caps how many jobs one `RUNALL` may enqueue).
    pub max_channels: usize,
    /// Largest `BATCH=` a session may stage on any channel.
    pub max_batch: u32,
    /// Most runs one command may enqueue on the shared pool (a `RUNMIX`
    /// enqueues one per configured channel).
    pub max_queued_runs: usize,
}

impl SessionLimits {
    /// No limits at all — what the single-user serial transports
    /// (in-memory REPL, `serve_tcp`) grant, preserving their historical
    /// behaviour.
    pub const UNLIMITED: SessionLimits = SessionLimits {
        max_channels: usize::MAX,
        max_batch: u32::MAX,
        max_queued_runs: usize::MAX,
    };

    /// Validate invariants (every limit must admit at least one unit).
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.max_channels == 0 {
            return Err(ConfigError::new("max_channels must be >= 1"));
        }
        if self.max_batch == 0 {
            return Err(ConfigError::new("max_batch must be >= 1"));
        }
        if self.max_queued_runs == 0 {
            return Err(ConfigError::new("max_queued_runs must be >= 1"));
        }
        Ok(())
    }
}

impl Default for SessionLimits {
    /// Server defaults: the full 3-channel design, batches up to 1 Mi
    /// transactions, 8 queued runs per command.
    fn default() -> Self {
        Self { max_channels: 3, max_batch: 1 << 20, max_queued_runs: 8 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn session_limits_defaults_and_validation() {
        let d = SessionLimits::default();
        assert!(d.validate().is_ok());
        assert_eq!(d.max_channels, 3);
        assert!(SessionLimits::UNLIMITED.validate().is_ok());
        for bad in [
            SessionLimits { max_channels: 0, ..d },
            SessionLimits { max_batch: 0, ..d },
            SessionLimits { max_queued_runs: 0, ..d },
        ] {
            assert!(bad.validate().is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn speed_bin_clocks_match_table2() {
        // Table II: PHY 800/933/1067/1200 MHz, AXI 200/233/267/300 MHz.
        let phys = [800.0, 933.0, 1066.5, 1200.0];
        let axis = [200.0, 233.25, 266.625, 300.0];
        for (i, bin) in SpeedBin::ALL.iter().enumerate() {
            assert!((bin.phy_clock_mhz() - phys[i]).abs() < 1.0, "{bin}: phy");
            assert!((bin.axi_clock_mhz() - axis[i]).abs() < 0.5, "{bin}: axi");
            // 4:1 ratio always holds
            assert!((bin.phy_clock_mhz() / bin.axi_clock_mhz() - 4.0).abs() < 1e-9);
        }
    }

    #[test]
    fn speed_bin_parse_roundtrip() {
        for bin in SpeedBin::ALL {
            assert_eq!(SpeedBin::parse(bin.name()), Some(bin));
            assert_eq!(SpeedBin::parse(&bin.data_rate_mts().to_string()), Some(bin));
        }
        assert_eq!(SpeedBin::parse("3200"), None);
    }

    #[test]
    fn design_validate_channel_bounds() {
        for n in 1..=3 {
            assert!(DesignConfig::with_channels(n, SpeedBin::Ddr4_2400).validate().is_ok());
        }
        assert!(DesignConfig::with_channels(0, SpeedBin::Ddr4_1600).validate().is_err());
        assert!(DesignConfig::with_channels(4, SpeedBin::Ddr4_1600).validate().is_err());
    }

    #[test]
    fn design_validate_watermarks() {
        let mut d = DesignConfig::default();
        let high = d.controller.write_drain_high;
        d.controller.write_drain_low = high;
        assert!(d.validate().is_err());
    }

    #[test]
    fn pattern_validate_burst_bounds() {
        let mut p = PatternConfig::seq_read_burst(128, 16);
        assert!(p.validate().is_ok());
        p.burst.len = 129;
        assert!(p.validate().is_err());
        p.burst.len = 0;
        assert!(p.validate().is_err());
    }

    #[test]
    fn pattern_validate_wrap_pow2() {
        let mut p = PatternConfig::seq_read_burst(16, 16);
        p.burst.kind = BurstKind::Wrap;
        assert!(p.validate().is_ok());
        p.burst.len = 12;
        assert!(p.validate().is_err());
    }

    #[test]
    fn pattern_txn_bytes() {
        let p = PatternConfig::seq_read_burst(4, 1);
        assert_eq!(p.txn_bytes(32), 128);
        let s = PatternConfig::seq_read_burst(1, 1);
        assert_eq!(s.txn_bytes(32), 32);
    }

    #[test]
    fn op_mix_labels() {
        assert_eq!(OpMix::ReadOnly.label(), "R");
        assert_eq!(OpMix::WriteOnly.label(), "W");
        assert_eq!(OpMix::Mixed { read_pct: 50 }.label(), "M");
        assert_eq!(OpMix::Mixed { read_pct: 30 }.read_pct(), 30);
    }

    #[test]
    fn paper_burst_labels() {
        assert_eq!(BurstSpec::single().paper_label(), "S");
        assert_eq!(BurstSpec::incr(4).paper_label(), "SB");
        assert_eq!(BurstSpec::incr(32).paper_label(), "MB");
        assert_eq!(BurstSpec::incr(128).paper_label(), "LB");
    }

    #[test]
    fn addr_mode_labels_and_row_hostility() {
        assert_eq!(AddrMode::Sequential.label(), "Seq");
        assert_eq!(AddrMode::Strided { stride: 64 }.label(), "Strd");
        assert_eq!(AddrMode::BankConflict { seed: 0 }.label(), "Bank");
        assert_eq!(AddrMode::PointerChase { seed: 0, working_set: 64 }.label(), "Chase");
        assert_eq!(AddrMode::Phased(vec![(AddrMode::Sequential, 1)]).label(), "Phase");
        assert!(!AddrMode::Sequential.row_hostile());
        assert!(!AddrMode::Strided { stride: 64 }.row_hostile());
        assert!(AddrMode::Strided { stride: 8192 }.row_hostile());
        assert!(AddrMode::BankConflict { seed: 0 }.row_hostile());
        assert!(AddrMode::PointerChase { seed: 0, working_set: 64 }.row_hostile());
        assert!(AddrMode::Phased(vec![
            (AddrMode::Sequential, 8),
            (AddrMode::Random { seed: 1 }, 8)
        ])
        .row_hostile());
    }

    #[test]
    fn addr_mode_validation_rules() {
        let mut p = PatternConfig::strided_read(4096, 4, 16);
        assert!(p.validate().is_ok());
        p.addr = AddrMode::Strided { stride: 0 };
        assert!(p.validate().is_err());
        p.addr = AddrMode::PointerChase { seed: 1, working_set: 0 };
        assert!(p.validate().is_err());
        p.addr = AddrMode::Phased(vec![]);
        assert!(p.validate().is_err());
        p.addr = AddrMode::Phased(vec![(AddrMode::Sequential, 0)]);
        assert!(p.validate().is_err());
        p.addr = AddrMode::Phased(vec![(AddrMode::Phased(vec![(AddrMode::Sequential, 1)]), 4)]);
        assert!(p.validate().is_err());
        p.addr = AddrMode::Phased(vec![
            (AddrMode::Sequential, 32),
            (AddrMode::BankConflict { seed: 2 }, 32),
        ]);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn bank_conflict_requires_room_for_two_rows() {
        let mut p = PatternConfig::bank_conflict_read(1, 64, 1);
        assert!(p.validate().is_ok(), "default 256 MiB region is fine");
        p.region_bytes = AddrMode::BANK_CONFLICT_MIN_REGION;
        assert!(p.validate().is_ok(), "exactly two row windows is the floor");
        p.region_bytes = AddrMode::BANK_CONFLICT_MIN_REGION - 1;
        assert!(p.validate().is_err(), "too small to guarantee row misses");
        // the check sees through phases too
        p.addr = AddrMode::Phased(vec![
            (AddrMode::Sequential, 8),
            (AddrMode::BankConflict { seed: 0 }, 8),
        ]);
        assert!(p.validate().is_err());
    }

    #[test]
    fn plan_seed_stable_for_paper_modes() {
        // Historical constants: changing them would silently re-plan every
        // existing Seq/Rnd campaign.
        assert_eq!(AddrMode::Sequential.plan_seed(), 0x5EED);
        assert_eq!(AddrMode::Random { seed: 0 }.plan_seed(), 0xA5A5_5A5A);
        // distinct modes get distinct mix streams
        let a = AddrMode::Strided { stride: 4096 }.plan_seed();
        let b = AddrMode::BankConflict { seed: 0 }.plan_seed();
        assert_ne!(a, b);
    }

    #[test]
    fn pointer_chase_preset_is_blocking_single() {
        let p = PatternConfig::pointer_chase_read(1 << 20, 256, 7);
        assert_eq!(p.signaling, Signaling::Blocking);
        assert_eq!(p.burst.len, 1);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn channel_mix_bounds_and_labels() {
        assert!(ChannelMix::new(vec![]).is_err(), "empty mix rejected");
        assert!(ChannelMix::new(vec![PatternConfig::default(); 4]).is_err(), "4 channels");
        let mix = ChannelMix::new(vec![
            PatternConfig::seq_read_burst(32, 64),
            PatternConfig::pointer_chase_read(1 << 20, 64, 7),
            PatternConfig::bank_conflict_read(1, 64, 1),
        ])
        .unwrap();
        assert_eq!(mix.len(), 3);
        assert!(!mix.is_empty());
        assert_eq!(mix.label(), "seq+chase+bank");
        assert_eq!(mix.channel_label(1), "chase");
        assert!(mix.validate().is_ok());
        assert_eq!(mix.get(2).unwrap().burst.len, 1);
        assert!(mix.get(3).is_none());
    }

    #[test]
    fn channel_mix_uniform_and_override_strip() {
        let mut cfg = PatternConfig::seq_read_burst(4, 32);
        cfg.mapping = Some(MappingPolicy::xor_hash());
        cfg.sched = Some(SchedKind::Closed);
        cfg.engine = Some(EngineKind::Event);
        cfg.telemetry = Some(4096);
        let mix = ChannelMix::uniform(&cfg, 2).unwrap();
        assert_eq!(mix.len(), 2);
        assert_eq!(mix.get(0), mix.get(1));
        let stripped = mix.without_overrides();
        assert!(stripped.iter().all(|c| c.mapping.is_none()
            && c.sched.is_none()
            && c.engine.is_none()
            && c.telemetry.is_none()));
        // everything else is untouched
        assert!(stripped.iter().all(|c| c.burst.len == 4 && c.batch_len == 32));
        assert!(ChannelMix::uniform(&cfg, 0).is_err());
    }

    #[test]
    fn telemetry_window_validates_and_defaults_off() {
        assert_eq!(DesignConfig::default().telemetry, None);
        assert_eq!(PatternConfig::default().telemetry, None);
        let mut d = DesignConfig::default();
        d.telemetry = Some(0);
        assert!(d.validate().is_err(), "zero-cycle design window rejected");
        d.telemetry = Some(1024);
        assert!(d.validate().is_ok());
        let mut p = PatternConfig::default();
        p.telemetry = Some(0);
        assert!(p.validate().is_err(), "zero-cycle TELEM= rejected");
        p.telemetry = Some(1);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn engine_kind_parses_and_round_trips() {
        assert_eq!(EngineKind::parse("cycle"), Some(EngineKind::Cycle));
        assert_eq!(EngineKind::parse(" EVENT "), Some(EngineKind::Event));
        assert_eq!(EngineKind::parse("wheel"), None);
        assert_eq!(EngineKind::default(), EngineKind::Cycle);
        for e in EngineKind::ALL {
            assert_eq!(EngineKind::parse(e.name()), Some(e), "{e} round-trips");
        }
        assert_eq!(DesignConfig::default().engine, EngineKind::Cycle);
        assert_eq!(PatternConfig::default().engine, None);
    }

    #[test]
    fn channel_mix_validate_flags_the_channel() {
        let mut bad = PatternConfig::seq_read_burst(4, 32);
        bad.batch_len = 0;
        let mix = ChannelMix::new(vec![PatternConfig::seq_read_burst(4, 32), bad]).unwrap();
        let err = mix.validate().unwrap_err().to_string();
        assert!(err.contains("channel 1"), "{err}");
    }
}
