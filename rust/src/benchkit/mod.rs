//! Criterion-style measurement harness (in-tree; the offline image only
//! vendors the `xla` closure, DESIGN.md §9).
//!
//! Each `cargo bench` target is a plain `main()` that builds a
//! [`Bench`], registers measured closures, and calls [`Bench::finish`].
//! The harness does warmup, collects N timed samples, reports
//! mean/median/stddev/min/max plus an optional throughput unit, and can
//! attach *result rows* (the reproduced paper tables) that print after
//! the timing block. `--quick` (or `DDR4BENCH_QUICK=1`) cuts sample
//! counts for CI-style runs.

use std::time::{Duration, Instant};

/// Measurement statistics of one benchmark.
#[derive(Debug, Clone)]
pub struct Sample {
    /// Benchmark id.
    pub name: String,
    /// Per-iteration wall times.
    pub times: Vec<Duration>,
    /// Optional elements-per-iteration for throughput reporting.
    pub elements: Option<(f64, &'static str)>,
}

impl Sample {
    fn secs(&self) -> Vec<f64> {
        self.times.iter().map(|d| d.as_secs_f64()).collect()
    }

    /// Mean iteration time in seconds.
    pub fn mean(&self) -> f64 {
        let s = self.secs();
        s.iter().sum::<f64>() / s.len() as f64
    }

    /// Median iteration time in seconds.
    pub fn median(&self) -> f64 {
        let mut s = self.secs();
        s.sort_by(f64::total_cmp);
        s[s.len() / 2]
    }

    /// Standard deviation in seconds.
    pub fn stddev(&self) -> f64 {
        let m = self.mean();
        let s = self.secs();
        (s.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / s.len() as f64).sqrt()
    }

    /// Minimum iteration time in seconds.
    pub fn min(&self) -> f64 {
        self.secs().iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Maximum iteration time in seconds.
    pub fn max(&self) -> f64 {
        self.secs().iter().copied().fold(0.0, f64::max)
    }
}

fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// The bench harness.
pub struct Bench {
    suite: String,
    samples: usize,
    warmup: usize,
    results: Vec<Sample>,
}

impl Bench {
    /// New harness for a suite. Honours `--quick` / `DDR4BENCH_QUICK`.
    pub fn new(suite: &str) -> Self {
        let quick = std::env::args().any(|a| a == "--quick")
            || std::env::var("DDR4BENCH_QUICK").is_ok_and(|v| v == "1");
        let (samples, warmup) = if quick { (3, 1) } else { (10, 2) };
        println!("== bench suite: {suite} ({samples} samples, {warmup} warmup) ==");
        Self { suite: suite.to_string(), samples, warmup, results: Vec::new() }
    }

    /// Override sample counts (long-running end-to-end benches).
    pub fn with_samples(mut self, samples: usize, warmup: usize) -> Self {
        self.samples = samples.max(1);
        self.warmup = warmup;
        self
    }

    /// Measure `f`, which performs one full iteration per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) {
        for _ in 0..self.warmup {
            f();
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed());
        }
        let s = Sample { name: name.to_string(), times, elements: None };
        self.report(&s);
        self.results.push(s);
    }

    /// Measure `f` and report throughput as `elements/iter` of `unit`.
    pub fn bench_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        elements: f64,
        unit: &'static str,
        mut f: F,
    ) {
        for _ in 0..self.warmup {
            f();
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed());
        }
        let s = Sample { name: name.to_string(), times, elements: Some((elements, unit)) };
        self.report(&s);
        self.results.push(s);
    }

    fn report(&self, s: &Sample) {
        let extra = match s.elements {
            Some((n, unit)) => {
                format!("  [{:.3} M{unit}/s]", n / s.median() / 1e6)
            }
            None => String::new(),
        };
        println!(
            "{:<44} median {:>12}  mean {:>12} ± {:>10}  (min {}, max {}){extra}",
            s.name,
            fmt_time(s.median()),
            fmt_time(s.mean()),
            fmt_time(s.stddev()),
            fmt_time(s.min()),
            fmt_time(s.max()),
        );
    }

    /// All collected samples.
    pub fn samples(&self) -> &[Sample] {
        &self.results
    }

    /// Render the collected samples as a machine-readable JSON document
    /// (schema `ddr4bench.micro.v1`). Hand-rendered — the offline image
    /// carries no serde — with every time in seconds so downstream
    /// tooling (the CI perf smoke) needs no unit parsing.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str("  \"schema\": \"ddr4bench.micro.v1\",\n");
        out.push_str(&format!("  \"suite\": \"{}\",\n", json_escape(&self.suite)));
        out.push_str(&format!("  \"samples_per_bench\": {},\n", self.samples));
        out.push_str("  \"benches\": [\n");
        for (i, s) in self.results.iter().enumerate() {
            out.push_str("    {\n");
            out.push_str(&format!("      \"name\": \"{}\",\n", json_escape(&s.name)));
            out.push_str(&format!("      \"median_s\": {:e},\n", s.median()));
            out.push_str(&format!("      \"mean_s\": {:e},\n", s.mean()));
            out.push_str(&format!("      \"stddev_s\": {:e},\n", s.stddev()));
            out.push_str(&format!("      \"min_s\": {:e},\n", s.min()));
            out.push_str(&format!("      \"max_s\": {:e}", s.max()));
            if let Some((n, unit)) = s.elements {
                out.push_str(",\n");
                out.push_str(&format!("      \"elements\": {n:e},\n"));
                out.push_str(&format!("      \"unit\": \"{}\",\n", json_escape(unit)));
                out.push_str(&format!("      \"throughput_per_s\": {:e}\n", n / s.median()));
            } else {
                out.push('\n');
            }
            out.push_str(if i + 1 < self.results.len() { "    },\n" } else { "    }\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write [`Self::to_json`] to `path` (the `BENCH_micro.json`
    /// artifact the CI perf smoke uploads).
    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }

    /// Print the suite footer.
    pub fn finish(self) {
        println!("== {}: {} benchmarks done ==", self.suite, self.results.len());
    }
}

/// Minimal JSON string escaper (quotes, backslashes, control bytes).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_statistics() {
        let s = Sample {
            name: "x".into(),
            times: vec![
                Duration::from_millis(10),
                Duration::from_millis(20),
                Duration::from_millis(30),
            ],
            elements: None,
        };
        assert!((s.mean() - 0.020).abs() < 1e-9);
        assert!((s.median() - 0.020).abs() < 1e-9);
        assert!((s.min() - 0.010).abs() < 1e-9);
        assert!((s.max() - 0.030).abs() < 1e-9);
        assert!(s.stddev() > 0.0);
    }

    #[test]
    fn bench_runs_closure_expected_times() {
        std::env::set_var("DDR4BENCH_QUICK", "1");
        let mut calls = 0usize;
        let mut b = Bench::new("test").with_samples(3, 1);
        b.bench("count", || {
            calls += 1;
        });
        assert_eq!(calls, 4); // 1 warmup + 3 samples
        assert_eq!(b.samples().len(), 1);
        b.finish();
    }

    #[test]
    fn json_rendering_is_well_formed() {
        let mut b = Bench { suite: "micro".into(), samples: 2, warmup: 0, results: Vec::new() };
        b.results.push(Sample {
            name: "controller/satq_frfcfs_la32".into(),
            times: vec![Duration::from_millis(10), Duration::from_millis(20)],
            elements: Some((60_000.0, "cycles")),
        });
        b.results.push(Sample {
            name: "plain \"quoted\"".into(),
            times: vec![Duration::from_millis(5)],
            elements: None,
        });
        let j = b.to_json();
        assert!(j.contains("\"schema\": \"ddr4bench.micro.v1\""));
        assert!(j.contains("\"name\": \"controller/satq_frfcfs_la32\""));
        assert!(j.contains("\"throughput_per_s\""));
        assert!(j.contains("plain \\\"quoted\\\""));
        // crude structural checks: balanced braces/brackets, no trailing
        // comma before a closing bracket
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
        assert!(!j.contains(",\n  ]"));
        assert!(!j.contains(",\n    }"));
    }

    #[test]
    fn json_escape_control_bytes() {
        assert_eq!(json_escape("a\nb"), "a\\u000ab");
        assert_eq!(json_escape("c:\\d"), "c:\\\\d");
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(2.0), "2.000 s");
        assert_eq!(fmt_time(0.002), "2.000 ms");
        assert_eq!(fmt_time(2e-6), "2.000 µs");
        assert_eq!(fmt_time(2e-9), "2.0 ns");
    }
}
