//! Design-space exploration over the platform's design-time axes.
//!
//! The paper's "flexible memory setup" contribution is exactly that the
//! same benchmarking architecture can be instantiated across channel
//! counts and data rates to explore deployments. This module automates
//! the exploration: it enumerates (channels × data rate × workload)
//! points, predicts throughput with the analytic bandwidth model —
//! through the AOT `bwmodel` XLA artifact in one batched call when a
//! runtime is attached, or the Rust mirror otherwise — pairs each point
//! with its modeled FPGA resource cost, and reports the Pareto frontier
//! of aggregate GB/s vs LUTs.

use crate::config::{ControllerParams, DesignConfig, OpMix, PatternConfig, SpeedBin};
use crate::resource;
use crate::runtime::XlaRuntime;

use super::{predict_gbs, BwFeatures};

/// One explored design point.
#[derive(Debug, Clone)]
pub struct DsePoint {
    /// Channels instantiated.
    pub channels: usize,
    /// Data rate.
    pub speed: SpeedBin,
    /// Workload descriptor (label of the pattern used for the figure of
    /// merit).
    pub workload: String,
    /// Predicted aggregate throughput, GB/s.
    pub gbs: f64,
    /// Modeled LUT cost.
    pub lut: f64,
    /// Modeled BRAM cost.
    pub bram: f64,
    /// Throughput per kLUT (the Pareto figure of merit).
    pub gbs_per_klut: f64,
}

/// Workloads the explorer scores (label, pattern, op).
pub fn dse_workloads() -> Vec<(String, PatternConfig, OpMix)> {
    vec![
        ("seq-read-128".into(), PatternConfig::seq_read_burst(128, 1), OpMix::ReadOnly),
        ("rnd-read-4".into(), PatternConfig::rnd_read_burst(4, 1, 0), OpMix::ReadOnly),
        ("mixed-32".into(), {
            let mut c = PatternConfig::mixed(crate::config::AddrMode::Sequential, 32, 1);
            c.op = OpMix::Mixed { read_pct: 50 };
            c
        }, OpMix::Mixed { read_pct: 50 }),
    ]
}

/// Enumerate and score the full design space. `runtime` selects the XLA
/// path (all predictions in one batched `bwmodel` call) vs the Rust
/// mirror.
pub fn explore(runtime: Option<&XlaRuntime>) -> anyhow::Result<Vec<DsePoint>> {
    let knobs = ControllerParams::default();
    let workloads = dse_workloads();
    // assemble feature rows in enumeration order
    let mut rows: Vec<(usize, SpeedBin, String, BwFeatures, OpMix)> = Vec::new();
    for channels in 1..=3usize {
        for speed in SpeedBin::ALL {
            for (label, cfg, op) in &workloads {
                let f = BwFeatures::from_config(
                    speed,
                    cfg,
                    32,
                    knobs.addr_cmd_interval_axi,
                    knobs.lookahead,
                    knobs.outstanding_cap,
                );
                rows.push((channels, speed, label.clone(), f, *op));
            }
        }
    }
    // predict per-channel GB/s
    let preds: Vec<f64> = match runtime {
        Some(rt) if rt.has_bwmodel() => {
            let feats: Vec<f32> = rows.iter().flat_map(|(_, _, _, f, _)| f.to_row()).collect();
            rt.bwmodel(&feats)?.into_iter().map(|v| v as f64).collect()
        }
        _ => rows.iter().map(|(_, _, _, f, op)| predict_gbs(f, *op) as f64).collect(),
    };
    Ok(rows
        .into_iter()
        .zip(preds)
        .map(|((channels, speed, workload, _, _), per_channel)| {
            let design = DesignConfig::with_channels(channels, speed);
            let cost = resource::design_cost(&design);
            let gbs = per_channel * channels as f64;
            DsePoint {
                channels,
                speed,
                workload,
                gbs,
                lut: cost.lut,
                bram: cost.bram,
                gbs_per_klut: gbs / (cost.lut / 1000.0),
            }
        })
        .collect())
}

/// Pareto frontier of `points` for one workload: maximize GB/s, minimize
/// LUTs. Returns points no other point dominates, sorted by LUT cost.
pub fn pareto(points: &[DsePoint], workload: &str) -> Vec<DsePoint> {
    let mut subset: Vec<&DsePoint> = points.iter().filter(|p| p.workload == workload).collect();
    subset.sort_by(|a, b| a.lut.total_cmp(&b.lut).then(b.gbs.total_cmp(&a.gbs)));
    let mut frontier: Vec<DsePoint> = Vec::new();
    let mut best_gbs = f64::NEG_INFINITY;
    for p in subset {
        if p.gbs > best_gbs {
            frontier.push(p.clone());
            best_gbs = p.gbs;
        }
    }
    frontier
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn explore_covers_full_grid() {
        let points = explore(None).unwrap();
        assert_eq!(points.len(), 3 * 4 * 3, "channels x speeds x workloads");
        assert!(points.iter().all(|p| p.gbs > 0.0 && p.lut > 0.0));
    }

    #[test]
    fn throughput_scales_with_channels_in_dse() {
        let points = explore(None).unwrap();
        let find = |ch: usize| {
            points
                .iter()
                .find(|p| {
                    p.channels == ch
                        && p.speed == SpeedBin::Ddr4_2400
                        && p.workload == "seq-read-128"
                })
                .unwrap()
                .gbs
        };
        assert!((find(3) / find(1) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn pareto_is_monotone_and_non_dominated() {
        let points = explore(None).unwrap();
        let front = pareto(&points, "seq-read-128");
        assert!(!front.is_empty());
        for w in front.windows(2) {
            assert!(w[1].lut > w[0].lut, "sorted by cost");
            assert!(w[1].gbs > w[0].gbs, "each step buys throughput");
        }
        // no point in the full set dominates a frontier point
        for f in &front {
            assert!(!points
                .iter()
                .filter(|p| p.workload == "seq-read-128")
                .any(|p| p.gbs > f.gbs && p.lut < f.lut));
        }
    }

    #[test]
    fn random_workload_prefers_fewer_channels_per_klut() {
        // Random short bursts don't saturate a channel, so GB/s-per-kLUT
        // ordering should still be flat-ish across channel counts (linear
        // scaling of both numerator and denominator); sanity-check the
        // figure of merit is finite and positive everywhere.
        let points = explore(None).unwrap();
        assert!(points.iter().all(|p| p.gbs_per_klut.is_finite() && p.gbs_per_klut > 0.0));
    }
}
