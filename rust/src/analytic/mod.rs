//! Closed-form DDR4 bandwidth model.
//!
//! A first-order analytic predictor of channel throughput given the
//! pattern parameters — the same model is lowered through JAX as the
//! `bwmodel` artifact so predictions for whole parameter sweeps run
//! through one XLA call. Used to cross-check the cycle-level simulator
//! (EXPERIMENTS.md records model-vs-simulated deltas) and to seed design
//! space exploration before running full simulations.
//!
//! The model composes the bottlenecks of DESIGN.md §5:
//!
//! 1. **fabric ceiling** — beat_bytes per AXI cycle per direction;
//! 2. **address-channel ceiling** — one transaction per
//!    `addr_cmd_interval` AXI cycles ⇒ `txn_bytes / interval` per cycle;
//! 3. **DRAM service ceiling** — per-transaction DRAM busy time:
//!    `n_bursts × tBURST` plus, for random rows, the row-cycle cost
//!    amortized over the in-flight window (bank parallelism capped by the
//!    reorder lookahead);
//! 4. **refresh derating** — `1 − tRFC/tREFI`.
//!
//! Throughput = min(1, 2, 3) × refresh derate; mixed workloads evaluate
//! both directions with the shared-bus constraint.

pub mod dse;

use crate::config::{OpMix, PatternConfig, SpeedBin};
use crate::ddr4::TimingParams;

/// Model inputs distilled from a (design, pattern) pair — the 8 feature
/// columns of the `bwmodel` artifact, in order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BwFeatures {
    /// Data rate in MT/s (1600..2400).
    pub data_rate_mts: f32,
    /// AXI beats per transaction (1..=128).
    pub burst_len: f32,
    /// 1.0 = random addressing, 0.0 = sequential.
    pub random: f32,
    /// Fraction of read transactions (0..=1).
    pub read_frac: f32,
    /// Bytes per AXI beat.
    pub beat_bytes: f32,
    /// Front-end transaction interval in AXI cycles.
    pub addr_interval: f32,
    /// Effective bank parallelism the controller can extract (lookahead).
    pub lookahead: f32,
    /// Outstanding-transaction window of the TG.
    pub outstanding: f32,
}

impl BwFeatures {
    /// Build features from configs.
    pub fn from_config(
        speed: SpeedBin,
        cfg: &PatternConfig,
        beat_bytes: u32,
        addr_interval: u32,
        lookahead: usize,
        outstanding: usize,
    ) -> Self {
        Self {
            data_rate_mts: speed.data_rate_mts() as f32,
            burst_len: cfg.burst.len as f32,
            // Bank conflicts and pointer chases defeat the row buffer the
            // same way uniform random does; the model folds them into the
            // row-miss service time.
            random: if cfg.addr.row_hostile() { 1.0 } else { 0.0 },
            read_frac: cfg.op.read_pct() as f32 / 100.0,
            beat_bytes: beat_bytes as f32,
            addr_interval: addr_interval as f32,
            lookahead: lookahead as f32,
            outstanding: outstanding as f32,
        }
    }

    /// Flatten to the artifact's feature-row layout.
    pub fn to_row(&self) -> [f32; 8] {
        [
            self.data_rate_mts,
            self.burst_len,
            self.random,
            self.read_frac,
            self.beat_bytes,
            self.addr_interval,
            self.lookahead,
            self.outstanding,
        ]
    }
}

/// Predict one direction's throughput in GB/s (`is_read` selects CAS
/// latency handling; reads and writes differ via recovery overheads).
fn direction_gbs(f: &BwFeatures, t: &TimingParams, is_read: bool, share: f32) -> f32 {
    if share <= 0.0 {
        return 0.0;
    }
    let tck_ns = 2000.0 / f.data_rate_mts; // DRAM clock period
    let axi_ns = tck_ns * 4.0;
    let txn_bytes = f.burst_len * f.beat_bytes;
    let dram_bursts_per_txn = (txn_bytes / 64.0).max(1.0);

    // (1) fabric data-channel ceiling
    let fabric = f.beat_bytes / axi_ns;
    // (2) address-channel ceiling
    let addr = txn_bytes / (f.addr_interval * axi_ns);
    // (3) DRAM service: burst transfer time + row overheads
    let tburst = t.burst_cycles as f32;
    let service_ck = dram_bursts_per_txn * tburst;
    if f.random > 0.5 {
        // Every transaction opens a fresh row and triggers the page-miss
        // pipeline flush (DESIGN.md §5 / `ControllerParams::miss_flush`):
        // the next transaction is not accepted until PRE + ACT + CAS +
        // data (+ recovery) complete. The flush overlaps the CAS stream
        // of the *current* transaction, so long bursts hide it entirely —
        // exactly the paper's "random recovers at long bursts" shape.
        let flush = (t.trp + t.trcd) as f32
            + if is_read {
                (t.cl + t.burst_cycles + t.trp) as f32
            } else {
                (t.cwl + t.burst_cycles + t.twr + t.twtr_l) as f32
            };
        let hidden = (dram_bursts_per_txn - 1.0) * t.tccd_s as f32;
        let service_rnd = service_ck + (flush - hidden).max(0.0);
        let dram = txn_bytes / (service_rnd * tck_ns);
        return fabric.min(addr).min(dram) * share;
    }
    let dram = txn_bytes / (service_ck * tck_ns);
    fabric.min(addr).min(dram) * share
}

/// Predict throughput in GB/s for one channel (matches the jnp model in
/// `python/compile/model.py::bw_model` — the pinned-value tests keep the
/// two in lockstep).
pub fn predict_gbs(f: &BwFeatures, op: OpMix) -> f32 {
    let t = TimingParams::for_bin(match f.data_rate_mts as u32 {
        0..=1700 => SpeedBin::Ddr4_1600,
        1701..=2000 => SpeedBin::Ddr4_1866,
        2001..=2250 => SpeedBin::Ddr4_2133,
        _ => SpeedBin::Ddr4_2400,
    });
    let refresh_derate = 1.0 - t.trfc as f32 / t.trefi as f32;
    let gbs = match op {
        OpMix::ReadOnly => direction_gbs(f, &t, true, 1.0),
        OpMix::WriteOnly => direction_gbs(f, &t, false, 1.0),
        OpMix::Mixed { .. } => {
            // both directions run concurrently on separate AXI channels,
            // sharing the DRAM bus; turnarounds eat ~15%
            let r = direction_gbs(f, &t, true, 1.0) * f.read_frac.max(0.01);
            let w = direction_gbs(f, &t, false, 1.0) * (1.0 - f.read_frac).max(0.01);
            let tck_ns = 2000.0 / f.data_rate_mts;
            let dram_bus = 64.0 / (t.burst_cycles as f32 * tck_ns); // GB/s
            (r + w).min(dram_bus * 0.85)
        }
    };
    gbs * refresh_derate
}

/// Convenience: predict for a (speed, pattern) pair with default knobs.
pub fn predict_pattern(speed: SpeedBin, cfg: &PatternConfig, beat_bytes: u32) -> f32 {
    let p = crate::config::ControllerParams::default();
    let f = BwFeatures::from_config(
        speed,
        cfg,
        beat_bytes,
        p.addr_cmd_interval_axi,
        p.lookahead,
        p.outstanding_cap,
    );
    predict_gbs(&f, cfg.op)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PatternConfig;

    #[test]
    fn seq_long_burst_hits_fabric_ceiling() {
        let g = predict_pattern(SpeedBin::Ddr4_1600, &PatternConfig::seq_read_burst(128, 1), 32);
        assert!((5.8..=6.4).contains(&g), "long seq read ~6.2-6.4, got {g}");
    }

    #[test]
    fn seq_single_is_addr_limited() {
        let g = predict_pattern(SpeedBin::Ddr4_1600, &PatternConfig::seq_read_burst(1, 1), 32);
        assert!((2.5..=3.3).contains(&g), "seq singles ~3.1, got {g}");
    }

    #[test]
    fn random_single_much_slower() {
        let s = predict_pattern(SpeedBin::Ddr4_1600, &PatternConfig::seq_read_burst(1, 1), 32);
        let r = predict_pattern(SpeedBin::Ddr4_1600, &PatternConfig::rnd_read_burst(1, 1, 0), 32);
        assert!(r < s / 2.5, "random singles {r} vs seq {s}");
    }

    #[test]
    fn random_long_burst_recovers() {
        let r128 =
            predict_pattern(SpeedBin::Ddr4_1600, &PatternConfig::rnd_read_burst(128, 1, 0), 32);
        let r1 = predict_pattern(SpeedBin::Ddr4_1600, &PatternConfig::rnd_read_burst(1, 1, 0), 32);
        assert!(r128 > r1 * 4.0, "random recovers with burst length: {r1} -> {r128}");
    }

    #[test]
    fn datarate_scales_sequential_more_than_random() {
        let seq_ratio = predict_pattern(
            SpeedBin::Ddr4_2400,
            &PatternConfig::seq_read_burst(128, 1),
            32,
        ) / predict_pattern(SpeedBin::Ddr4_1600, &PatternConfig::seq_read_burst(128, 1), 32);
        let rnd_ratio = predict_pattern(
            SpeedBin::Ddr4_2400,
            &PatternConfig::rnd_read_burst(4, 1, 0),
            32,
        ) / predict_pattern(SpeedBin::Ddr4_1600, &PatternConfig::rnd_read_burst(4, 1, 0), 32);
        assert!(seq_ratio > 1.35, "sequential uplift {seq_ratio}");
        assert!(rnd_ratio < seq_ratio, "random gains less: {rnd_ratio} < {seq_ratio}");
    }

    #[test]
    fn features_roundtrip_row() {
        let f = BwFeatures::from_config(
            SpeedBin::Ddr4_2400,
            &PatternConfig::seq_read_burst(32, 1),
            32,
            2,
            4,
            8,
        );
        let row = f.to_row();
        assert_eq!(row[0], 2400.0);
        assert_eq!(row[1], 32.0);
        assert_eq!(row[2], 0.0);
        assert_eq!(row[3], 1.0);
    }
}
