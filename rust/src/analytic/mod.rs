//! Closed-form DDR4 bandwidth model.
//!
//! A first-order analytic predictor of channel throughput given the
//! pattern parameters — the same model is lowered through JAX as the
//! `bwmodel` artifact so predictions for whole parameter sweeps run
//! through one XLA call. Used to cross-check the cycle-level simulator
//! (EXPERIMENTS.md records model-vs-simulated deltas) and to seed design
//! space exploration before running full simulations.
//!
//! The model composes the bottlenecks of DESIGN.md §5:
//!
//! 1. **fabric ceiling** — beat_bytes per AXI cycle per direction;
//! 2. **address-channel ceiling** — one transaction per
//!    `addr_cmd_interval` AXI cycles ⇒ `txn_bytes / interval` per cycle;
//! 3. **DRAM service ceiling** — per-transaction DRAM busy time:
//!    `n_bursts × tBURST` plus, for random rows, the row-cycle cost
//!    amortized over the in-flight window (bank parallelism capped by the
//!    reorder lookahead);
//! 4. **refresh derating** — `1 − tRFC/tREFI`.
//!
//! Throughput = min(1, 2, 3) × refresh derate; mixed workloads evaluate
//! both directions with the shared-bus constraint.

pub mod dse;

use crate::config::{ChannelMix, OpMix, PatternConfig, SchedKind, SpeedBin};
use crate::ddr4::{DramGeometry, TimingParams};

/// Model inputs distilled from a (design, pattern) pair — the 8 feature
/// columns of the `bwmodel` artifact, in order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BwFeatures {
    /// Data rate in MT/s (1600..2400).
    pub data_rate_mts: f32,
    /// AXI beats per transaction (1..=128).
    pub burst_len: f32,
    /// 1.0 = random addressing, 0.0 = sequential.
    pub random: f32,
    /// Fraction of read transactions (0..=1).
    pub read_frac: f32,
    /// Bytes per AXI beat.
    pub beat_bytes: f32,
    /// Front-end transaction interval in AXI cycles.
    pub addr_interval: f32,
    /// Effective bank parallelism the controller can extract (lookahead).
    pub lookahead: f32,
    /// Outstanding-transaction window of the TG.
    pub outstanding: f32,
}

impl BwFeatures {
    /// Build features from configs.
    pub fn from_config(
        speed: SpeedBin,
        cfg: &PatternConfig,
        beat_bytes: u32,
        addr_interval: u32,
        lookahead: usize,
        outstanding: usize,
    ) -> Self {
        Self {
            data_rate_mts: speed.data_rate_mts() as f32,
            burst_len: cfg.burst.len as f32,
            // Bank conflicts and pointer chases defeat the row buffer the
            // same way uniform random does; the model folds them into the
            // row-miss service time.
            random: if cfg.addr.row_hostile() { 1.0 } else { 0.0 },
            read_frac: cfg.op.read_pct() as f32 / 100.0,
            beat_bytes: beat_bytes as f32,
            addr_interval: addr_interval as f32,
            lookahead: lookahead as f32,
            outstanding: outstanding as f32,
        }
    }

    /// Flatten to the artifact's feature-row layout.
    pub fn to_row(&self) -> [f32; 8] {
        [
            self.data_rate_mts,
            self.burst_len,
            self.random,
            self.read_frac,
            self.beat_bytes,
            self.addr_interval,
            self.lookahead,
            self.outstanding,
        ]
    }
}

/// Predict one direction's throughput in GB/s (`is_read` selects CAS
/// latency handling; reads and writes differ via recovery overheads).
fn direction_gbs(f: &BwFeatures, t: &TimingParams, is_read: bool, share: f32) -> f32 {
    if share <= 0.0 {
        return 0.0;
    }
    let tck_ns = 2000.0 / f.data_rate_mts; // DRAM clock period
    let axi_ns = tck_ns * 4.0;
    let txn_bytes = f.burst_len * f.beat_bytes;
    let dram_bursts_per_txn = (txn_bytes / 64.0).max(1.0);

    // (1) fabric data-channel ceiling
    let fabric = f.beat_bytes / axi_ns;
    // (2) address-channel ceiling
    let addr = txn_bytes / (f.addr_interval * axi_ns);
    // (3) DRAM service: burst transfer time + row overheads
    let tburst = t.burst_cycles as f32;
    let service_ck = dram_bursts_per_txn * tburst;
    if f.random > 0.5 {
        // Every transaction opens a fresh row and triggers the page-miss
        // pipeline flush (DESIGN.md §5 / `ControllerParams::miss_flush`):
        // the next transaction is not accepted until PRE + ACT + CAS +
        // data (+ recovery) complete. The flush overlaps the CAS stream
        // of the *current* transaction, so long bursts hide it entirely —
        // exactly the paper's "random recovers at long bursts" shape.
        let flush = (t.trp + t.trcd) as f32
            + if is_read {
                (t.cl + t.burst_cycles + t.trp) as f32
            } else {
                (t.cwl + t.burst_cycles + t.twr + t.twtr_l) as f32
            };
        let hidden = (dram_bursts_per_txn - 1.0) * t.tccd_s as f32;
        let service_rnd = service_ck + (flush - hidden).max(0.0);
        let dram = txn_bytes / (service_rnd * tck_ns);
        return fabric.min(addr).min(dram) * share;
    }
    let dram = txn_bytes / (service_ck * tck_ns);
    fabric.min(addr).min(dram) * share
}

/// Predict throughput in GB/s for one channel (matches the jnp model in
/// `python/compile/model.py::bw_model` — the pinned-value tests keep the
/// two in lockstep).
pub fn predict_gbs(f: &BwFeatures, op: OpMix) -> f32 {
    let t = TimingParams::for_bin(match f.data_rate_mts as u32 {
        0..=1700 => SpeedBin::Ddr4_1600,
        1701..=2000 => SpeedBin::Ddr4_1866,
        2001..=2250 => SpeedBin::Ddr4_2133,
        _ => SpeedBin::Ddr4_2400,
    });
    let refresh_derate = 1.0 - t.trfc as f32 / t.trefi as f32;
    let gbs = match op {
        OpMix::ReadOnly => direction_gbs(f, &t, true, 1.0),
        OpMix::WriteOnly => direction_gbs(f, &t, false, 1.0),
        OpMix::Mixed { .. } => {
            // both directions run concurrently on separate AXI channels,
            // sharing the DRAM bus; turnarounds eat ~15%
            let r = direction_gbs(f, &t, true, 1.0) * f.read_frac.max(0.01);
            let w = direction_gbs(f, &t, false, 1.0) * (1.0 - f.read_frac).max(0.01);
            let tck_ns = 2000.0 / f.data_rate_mts;
            let dram_bus = 64.0 / (t.burst_cycles as f32 * tck_ns); // GB/s
            (r + w).min(dram_bus * 0.85)
        }
    };
    gbs * refresh_derate
}

/// Convenience: predict for a (speed, pattern) pair with default knobs.
pub fn predict_pattern(speed: SpeedBin, cfg: &PatternConfig, beat_bytes: u32) -> f32 {
    let p = crate::config::ControllerParams::default();
    let f = BwFeatures::from_config(
        speed,
        cfg,
        beat_bytes,
        p.addr_cmd_interval_axi,
        p.lookahead,
        p.outstanding_cap,
    );
    predict_gbs(&f, cfg.op)
}

/// Throughput derate for the active address-mapping policy — the
/// mapping-aware half of the row-miss accounting.
///
/// Row-hostile patterns already pay the full row-cycle cost inside
/// [`predict_gbs`], and bank-interleaved mappings (sequential bank
/// rotation ≥ 2) overlap their per-row ACT/PRE with the other banks'
/// CAS streams, so both cases derate by 1.0 — which keeps the 8-feature
/// `bwmodel` XLA artifact (and its pinned-value parity tests) untouched.
/// Row-major mappings (`row_bank_col`, `bank_row_col`) confine a
/// sequential stream to a single bank: every row boundary exposes the
/// whole PRE + ACT + CAS turnaround, amortized over the stream's row
/// visit — a full row for page-mode orders, a single burst for
/// row-thrash orders like `CoBaBgRo` where the row field sits below the
/// column field.
pub fn mapping_derate(geo: &DramGeometry, cfg: &PatternConfig, speed: SpeedBin) -> f32 {
    if cfg.addr.row_hostile() {
        return 1.0;
    }
    let sizes = geo.field_sizes();
    let rotation = geo.mapping.seq_bank_rotation(&sizes);
    if rotation >= 2 {
        return 1.0;
    }
    let t = TimingParams::for_bin(speed);
    let reopen = (t.trp + t.trcd + t.cl) as f32;
    let per_visit = geo.mapping.seq_row_visit_bursts(&sizes) as f32 * t.burst_cycles as f32;
    per_visit / (per_visit + reopen)
}

/// Throughput derate for a scheduling/page policy — the policy-aware
/// half of the row-miss/turnaround accounting
/// (`frfcfs` = 1.0 by construction, so the 8-feature `bwmodel` XLA
/// artifact and its pinned-value parity tests stay untouched).
///
/// - `fcfs`: a window-1 scheduler cannot overlap the next miss's
///   PRE/ACT with the current transaction's data phase, so row-hostile
///   patterns repay tRP per transaction on top of the modeled flush.
/// - `frfcfs-cap`: a fairness knob; first-order bandwidth-neutral (the
///   cap only reorders *which* request pays the row cycle, not how many
///   row cycles are paid).
/// - `closed`: row-friendly streams lose their open-row hits — every
///   transaction reopens its row, amortizing tRCD over the transaction's
///   DRAM bursts. Row-hostile traffic already pays the full row cycle
///   (the auto-precharge merely moves the PRE off the command bus), so
///   no derate there.
/// - `adaptive`: the idle timer only fires in idle gaps, which the
///   saturated batches the model describes don't have.
pub fn sched_derate(
    sched: SchedKind,
    cfg: &PatternConfig,
    speed: SpeedBin,
    beat_bytes: u32,
) -> f32 {
    let t = TimingParams::for_bin(speed);
    match sched {
        SchedKind::FrFcfs | SchedKind::FrFcfsCap { .. } | SchedKind::Adaptive => 1.0,
        SchedKind::Fcfs => {
            if cfg.addr.row_hostile() {
                let service = (t.trcd + t.cl + t.burst_cycles) as f32;
                service / (service + t.trp as f32)
            } else {
                1.0
            }
        }
        SchedKind::Closed => {
            if cfg.addr.row_hostile() {
                1.0
            } else {
                let txn_bytes = (cfg.burst.len * beat_bytes) as f32;
                let service = (txn_bytes / 64.0).max(1.0) * t.burst_cycles as f32;
                service / (service + t.trcd as f32)
            }
        }
    }
}

/// Predict throughput for a (speed, pattern) pair under an explicit
/// geometry: the pattern's `MAP=` override (when set) re-maps the
/// geometry before the mapping derate is applied, and the `SCHED=`
/// override (when set) applies the policy derate (`frfcfs` otherwise —
/// derate 1.0, preserving the historical predictions).
pub fn predict_pattern_mapped(
    speed: SpeedBin,
    cfg: &PatternConfig,
    beat_bytes: u32,
    geo: &DramGeometry,
) -> f32 {
    let mut g = *geo;
    if let Some(m) = cfg.mapping {
        g.mapping = m;
    }
    let sched = cfg.sched.unwrap_or(SchedKind::FrFcfs);
    predict_pattern(speed, cfg, beat_bytes)
        * mapping_derate(&g, cfg, speed)
        * sched_derate(sched, cfg, speed, beat_bytes)
}

/// Predict the aggregate throughput of a heterogeneous [`ChannelMix`]:
/// channels are architecturally independent, so the platform prediction
/// is the sum of each channel's [`predict_pattern_mapped`] — including
/// any per-channel `MAP=`/`SCHED=` override the mix carries.
pub fn predict_mix_mapped(
    speed: SpeedBin,
    mix: &ChannelMix,
    beat_bytes: u32,
    geo: &DramGeometry,
) -> f32 {
    mix.iter().map(|cfg| predict_pattern_mapped(speed, cfg, beat_bytes, geo)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PatternConfig;

    #[test]
    fn seq_long_burst_hits_fabric_ceiling() {
        let g = predict_pattern(SpeedBin::Ddr4_1600, &PatternConfig::seq_read_burst(128, 1), 32);
        assert!((5.8..=6.4).contains(&g), "long seq read ~6.2-6.4, got {g}");
    }

    #[test]
    fn seq_single_is_addr_limited() {
        let g = predict_pattern(SpeedBin::Ddr4_1600, &PatternConfig::seq_read_burst(1, 1), 32);
        assert!((2.5..=3.3).contains(&g), "seq singles ~3.1, got {g}");
    }

    #[test]
    fn random_single_much_slower() {
        let s = predict_pattern(SpeedBin::Ddr4_1600, &PatternConfig::seq_read_burst(1, 1), 32);
        let r = predict_pattern(SpeedBin::Ddr4_1600, &PatternConfig::rnd_read_burst(1, 1, 0), 32);
        assert!(r < s / 2.5, "random singles {r} vs seq {s}");
    }

    #[test]
    fn random_long_burst_recovers() {
        let r128 =
            predict_pattern(SpeedBin::Ddr4_1600, &PatternConfig::rnd_read_burst(128, 1, 0), 32);
        let r1 = predict_pattern(SpeedBin::Ddr4_1600, &PatternConfig::rnd_read_burst(1, 1, 0), 32);
        assert!(r128 > r1 * 4.0, "random recovers with burst length: {r1} -> {r128}");
    }

    #[test]
    fn datarate_scales_sequential_more_than_random() {
        let seq_ratio = predict_pattern(
            SpeedBin::Ddr4_2400,
            &PatternConfig::seq_read_burst(128, 1),
            32,
        ) / predict_pattern(SpeedBin::Ddr4_1600, &PatternConfig::seq_read_burst(128, 1), 32);
        let rnd_ratio = predict_pattern(
            SpeedBin::Ddr4_2400,
            &PatternConfig::rnd_read_burst(4, 1, 0),
            32,
        ) / predict_pattern(SpeedBin::Ddr4_1600, &PatternConfig::rnd_read_burst(4, 1, 0), 32);
        assert!(seq_ratio > 1.35, "sequential uplift {seq_ratio}");
        assert!(rnd_ratio < seq_ratio, "random gains less: {rnd_ratio} < {seq_ratio}");
    }

    #[test]
    fn mapping_derate_penalizes_row_major_sequential_only() {
        use crate::ddr4::MappingPolicy;
        let geo = crate::ddr4::DramGeometry::profpga_board();
        let seq = PatternConfig::seq_read_burst(32, 1);
        let rnd = PatternConfig::rnd_read_burst(32, 1, 0);
        // bank-interleaved default and XOR hash: no derate
        assert_eq!(mapping_derate(&geo, &seq, SpeedBin::Ddr4_1600), 1.0);
        let mut g = geo;
        g.mapping = MappingPolicy::xor_hash();
        assert_eq!(mapping_derate(&g, &seq, SpeedBin::Ddr4_1600), 1.0);
        // row-major: sequential pays the amortized row reopen
        g.mapping = MappingPolicy::row_bank_col();
        let d = mapping_derate(&g, &seq, SpeedBin::Ddr4_1600);
        assert!(d < 1.0 && d > 0.5, "row-major seq derate {d}");
        // a row-thrash order (new row every burst, same bank) is far worse
        g.mapping = MappingPolicy::parse("CoBaBgRo").unwrap();
        let thrash = mapping_derate(&g, &seq, SpeedBin::Ddr4_1600);
        assert!(thrash < 0.5 && thrash < d, "thrash derate {thrash} vs row-major {d}");
        // row-hostile traffic already pays full row misses: no derate
        assert_eq!(mapping_derate(&g, &rnd, SpeedBin::Ddr4_1600), 1.0);
        // and the mapped predictor composes base model x derate
        let base = predict_pattern(SpeedBin::Ddr4_1600, &seq, 32);
        let mut cfg = seq.clone();
        cfg.mapping = Some(MappingPolicy::row_bank_col());
        let mapped = predict_pattern_mapped(SpeedBin::Ddr4_1600, &cfg, 32, &geo);
        assert!(mapped < base, "mapped {mapped} vs base {base}");
    }

    #[test]
    fn sched_derates_order_policies_sanely() {
        let geo = crate::ddr4::DramGeometry::profpga_board();
        let seq = PatternConfig::seq_read_burst(32, 1);
        let rnd = PatternConfig::rnd_read_burst(1, 1, 0);
        // frfcfs is the 1.0 baseline everywhere
        for cfg in [&seq, &rnd] {
            assert_eq!(sched_derate(SchedKind::FrFcfs, cfg, SpeedBin::Ddr4_1600, 32), 1.0);
            assert_eq!(
                sched_derate(SchedKind::FrFcfsCap { cap: 4 }, cfg, SpeedBin::Ddr4_1600, 32),
                1.0
            );
            assert_eq!(sched_derate(SchedKind::Adaptive, cfg, SpeedBin::Ddr4_1600, 32), 1.0);
        }
        // fcfs pays on row-hostile traffic only
        let d = sched_derate(SchedKind::Fcfs, &rnd, SpeedBin::Ddr4_1600, 32);
        assert!(d < 1.0 && d > 0.5, "fcfs hostile derate {d}");
        assert_eq!(sched_derate(SchedKind::Fcfs, &seq, SpeedBin::Ddr4_1600, 32), 1.0);
        // closed pays on row-friendly traffic only, less for longer bursts
        let c32 = sched_derate(SchedKind::Closed, &seq, SpeedBin::Ddr4_1600, 32);
        assert!(c32 < 1.0 && c32 > 0.5, "closed seq derate {c32}");
        let c1 = sched_derate(
            SchedKind::Closed,
            &PatternConfig::seq_read_burst(1, 1),
            SpeedBin::Ddr4_1600,
            32,
        );
        assert!(c1 < c32, "short transactions amortize the reopen worse: {c1} vs {c32}");
        assert_eq!(sched_derate(SchedKind::Closed, &rnd, SpeedBin::Ddr4_1600, 32), 1.0);
        // the mapped predictor composes base x mapping x sched; no
        // override keeps the historical prediction bit-identical
        let base = predict_pattern_mapped(SpeedBin::Ddr4_1600, &seq, 32, &geo);
        assert_eq!(base, predict_pattern(SpeedBin::Ddr4_1600, &seq, 32));
        let mut closed = seq.clone();
        closed.sched = Some(SchedKind::Closed);
        let predicted = predict_pattern_mapped(SpeedBin::Ddr4_1600, &closed, 32, &geo);
        assert!((predicted / base - c32).abs() < 1e-6, "{predicted} vs {base} x {c32}");
    }

    #[test]
    fn mix_prediction_sums_independent_channels() {
        let geo = crate::ddr4::DramGeometry::profpga_board();
        let seq = PatternConfig::seq_read_burst(32, 1);
        let chase = PatternConfig::pointer_chase_read(1 << 20, 1, 7);
        // a uniform mix predicts n x the single-channel number
        let uni = ChannelMix::uniform(&seq, 3).unwrap();
        let single = predict_pattern_mapped(SpeedBin::Ddr4_1600, &seq, 32, &geo);
        let triple = predict_mix_mapped(SpeedBin::Ddr4_1600, &uni, 32, &geo);
        assert!((triple - 3.0 * single).abs() < 1e-4, "{triple} vs 3 x {single}");
        // a heterogeneous mix sums its distinct per-channel predictions
        let mix = ChannelMix::new(vec![seq.clone(), chase.clone()]).unwrap();
        let expect = single + predict_pattern_mapped(SpeedBin::Ddr4_1600, &chase, 32, &geo);
        let got = predict_mix_mapped(SpeedBin::Ddr4_1600, &mix, 32, &geo);
        assert!((got - expect).abs() < 1e-4, "{got} vs {expect}");
        // per-channel SCHED= overrides flow through the mix prediction
        let mut closed = seq.clone();
        closed.sched = Some(SchedKind::Closed);
        let mix = ChannelMix::new(vec![seq, closed.clone()]).unwrap();
        let expect = single + predict_pattern_mapped(SpeedBin::Ddr4_1600, &closed, 32, &geo);
        let got = predict_mix_mapped(SpeedBin::Ddr4_1600, &mix, 32, &geo);
        assert!((got - expect).abs() < 1e-4, "{got} vs {expect}");
        assert!(got < 2.0 * single, "the closed-page channel derates the platform sum");
    }

    #[test]
    fn features_roundtrip_row() {
        let f = BwFeatures::from_config(
            SpeedBin::Ddr4_2400,
            &PatternConfig::seq_read_burst(32, 1),
            32,
            2,
            4,
            8,
        );
        let row = f.to_row();
        assert_eq!(row[0], 2400.0);
        assert_eq!(row[1], 32.0);
        assert_eq!(row[2], 0.0);
        assert_eq!(row[3], 1.0);
    }
}
