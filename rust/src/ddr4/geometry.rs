//! DRAM channel geometry and physical address decomposition.
//!
//! Models the proFPGA DDR4 daughter board (Micron EDY4016A 4 Gb x16 parts):
//! four x16 devices in lockstep form a 64-bit data channel (a fifth part on
//! the physical board carries ECC and is not modeled), giving 2 GiB of
//! addressable data per channel. An x16 DDR4 device has 2 bank groups × 4
//! banks; the channel inherits that bank structure since all devices receive
//! the same commands.
//!
//! How a linear address is scattered over (row, bank group, bank, column)
//! is delegated to the runtime-selectable [`MappingPolicy`] engine in
//! [`super::mapping`] (PG150's `MEM_ADDR_ORDER` in hardware);
//! [`MappingPolicy::row_col_bank`] is the MIG default for AXI designs and
//! the profile used in the paper reproduction: consecutive BL8 bursts
//! rotate across banks (and therefore bank groups), which is what lets
//! sequential streams pipeline ACTs and dodge tCCD_L.

use super::mapping::{DramCoord, FieldSizes, MappingPolicy};

/// Burst length of DDR4 (fixed BL8 in this platform, as in MIG).
pub const BURST_LEN: u32 = 8;

/// Geometry of one DRAM channel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DramGeometry {
    /// Data-bus width in bytes (8 = 64-bit channel).
    pub bus_bytes: u32,
    /// Bank groups per channel.
    pub bank_groups: u32,
    /// Banks per bank group.
    pub banks_per_group: u32,
    /// Rows per bank.
    pub rows: u32,
    /// Column addresses per row (per device; BL8 bursts consume 8).
    pub cols: u32,
    /// Address-mapping policy.
    pub mapping: MappingPolicy,
}

/// A fully decoded DRAM location (one BL8 burst's worth of address).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DramAddr {
    /// Flat bank index: `group * banks_per_group + bank`.
    pub bank: u32,
    /// Row within the bank.
    pub row: u32,
    /// Column address of the burst (aligned to BL8, i.e. multiple of 8).
    pub col: u32,
}

impl DramAddr {
    /// Bank-group index of this address.
    pub fn group(&self, geo: &DramGeometry) -> u32 {
        self.bank / geo.banks_per_group
    }
}

impl DramGeometry {
    /// The proFPGA DDR4 board: 4 × Micron EDY4016A (4 Gb x16) in lockstep.
    /// 2 bank groups × 4 banks, 32768 rows, 1024 columns, 64-bit bus,
    /// MIG-default ROW_COLUMN_BANK mapping. 2 GiB data capacity.
    pub fn profpga_board() -> Self {
        Self {
            bus_bytes: 8,
            bank_groups: 2,
            banks_per_group: 4,
            rows: 32768,
            cols: 1024,
            mapping: MappingPolicy::row_col_bank(),
        }
    }

    /// Total banks in the channel.
    pub fn banks(&self) -> u32 {
        self.bank_groups * self.banks_per_group
    }

    /// Bytes transferred by one BL8 DRAM burst (64 B on a 64-bit channel).
    pub fn burst_bytes(&self) -> u32 {
        self.bus_bytes * BURST_LEN
    }

    /// Bytes in one open row across the channel (the "page": 8 KiB here).
    pub fn row_bytes(&self) -> u64 {
        self.cols as u64 * self.bus_bytes as u64
    }

    /// Total channel capacity in bytes.
    pub fn capacity_bytes(&self) -> u64 {
        self.row_bytes() * self.rows as u64 * self.banks() as u64
    }

    /// BL8 bursts per row.
    pub fn bursts_per_row(&self) -> u32 {
        self.cols / BURST_LEN
    }

    /// The radix of each coordinate field, for the mapping engine.
    pub fn field_sizes(&self) -> FieldSizes {
        FieldSizes {
            rows: self.rows as u64,
            groups: self.bank_groups as u64,
            banks_per_group: self.banks_per_group as u64,
            col_bursts: self.bursts_per_row() as u64,
        }
    }

    /// Bytes between consecutive rows of the same bank under the active
    /// mapping policy (the bank-conflict generator's adversarial stride).
    pub fn row_step_bytes(&self) -> u64 {
        self.mapping.row_step_bursts(&self.field_sizes()) * self.burst_bytes() as u64
    }

    /// Validate power-of-two fields and sane sizes.
    pub fn validate(&self) -> Result<(), String> {
        for (name, v) in [
            ("bus_bytes", self.bus_bytes),
            ("bank_groups", self.bank_groups),
            ("banks_per_group", self.banks_per_group),
            ("rows", self.rows),
            ("cols", self.cols),
        ] {
            if !v.is_power_of_two() {
                return Err(format!("{name} must be a power of two, got {v}"));
            }
        }
        if self.cols < BURST_LEN {
            return Err(format!("cols must be >= {BURST_LEN}"));
        }
        Ok(())
    }

    /// Decode a byte address into a structured DRAM coordinate. The
    /// address is first burst-aligned (low `log2(burst_bytes)` bits
    /// dropped) and wrapped to capacity.
    pub fn decode_coord(&self, byte_addr: u64) -> DramCoord {
        let burst_index = (byte_addr % self.capacity_bytes()) / self.burst_bytes() as u64;
        self.mapping.decode_burst(burst_index, &self.field_sizes())
    }

    /// Decode a byte address into a flat-bank DRAM location.
    pub fn decode(&self, byte_addr: u64) -> DramAddr {
        self.decode_coord(byte_addr).to_flat(self.banks_per_group)
    }

    /// Re-encode a DRAM coordinate into the byte address of its burst
    /// (inverse of [`Self::decode_coord`]; bijectivity is property-tested
    /// for every mapping policy).
    pub fn encode_coord(&self, c: DramCoord) -> u64 {
        self.mapping.encode_burst(c, &self.field_sizes()) * self.burst_bytes() as u64
    }

    /// Re-encode a flat-bank DRAM location into its byte address
    /// (inverse of [`Self::decode`]).
    pub fn encode(&self, a: DramAddr) -> u64 {
        self.encode_coord(DramCoord::from_flat(a, self.banks_per_group))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profpga_capacity_is_2gib() {
        let g = DramGeometry::profpga_board();
        assert_eq!(g.capacity_bytes(), 2 << 30);
        assert_eq!(g.banks(), 8);
        assert_eq!(g.burst_bytes(), 64);
        assert_eq!(g.row_bytes(), 8 << 10);
        assert_eq!(g.bursts_per_row(), 128);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn row_col_bank_interleaves_banks() {
        // MIG default: consecutive 64B bursts hit all 8 banks before any
        // repeats, and alternate bank *groups* every burst (tCCD_S path).
        let g = DramGeometry::profpga_board();
        let mut seen = std::collections::HashSet::new();
        let mut prev_group = None;
        for i in 0..8u64 {
            let a = g.decode(i * 64);
            assert_eq!(a.row, 0);
            seen.insert(a.bank);
            let grp = a.group(&g);
            if let Some(p) = prev_group {
                assert_ne!(grp, p, "burst {i} must switch bank group");
            }
            prev_group = Some(grp);
        }
        assert_eq!(seen.len(), 8, "8 consecutive bursts cover all 8 banks");
        // one full row-of-all-banks = 8 banks * 8KiB before row increments
        let a = g.decode(8 * g.row_bytes());
        assert_eq!(a.row, 1);
    }

    #[test]
    fn row_bank_col_streams_within_row() {
        let mut g = DramGeometry::profpga_board();
        g.mapping = MappingPolicy::row_bank_col();
        // first 8KiB stays in bank 0 row 0
        for i in 0..128u64 {
            let a = g.decode(i * 64);
            assert_eq!((a.bank, a.row), (0, 0), "burst {i}");
            assert_eq!(a.col, (i as u32) * 8);
        }
        let a = g.decode(g.row_bytes());
        assert_eq!((a.bank, a.row), (1, 0));
    }

    #[test]
    fn xor_hash_pins_no_bank_to_a_row_stride() {
        // The stride that pins one bank under the MIG order fans out
        // across banks when the XOR hash folds the row into the bank.
        let mut g = DramGeometry::profpga_board();
        g.mapping = MappingPolicy::xor_hash();
        let step = g.row_step_bytes();
        let banks: std::collections::HashSet<u32> =
            (0..8u64).map(|r| g.decode(r * step).bank).collect();
        assert_eq!(banks.len(), 8, "XOR hash spreads the row stride over all banks");
    }

    #[test]
    fn decode_encode_roundtrip_all_mappings() {
        let mut policies = MappingPolicy::builtins().to_vec();
        policies.push(MappingPolicy::parse("RoBaBgCo").unwrap());
        policies.push(MappingPolicy::parse("XorRoBaBgCo").unwrap());
        for mapping in policies {
            let mut g = DramGeometry::profpga_board();
            g.mapping = mapping;
            for addr in [0u64, 64, 4096, 8 << 10, 1 << 20, (2 << 30) - 64] {
                let dec = g.decode(addr);
                assert_eq!(g.encode(dec), addr & !63, "{mapping} addr={addr}");
                let coord = g.decode_coord(addr);
                assert_eq!(coord.to_flat(g.banks_per_group), dec);
                assert_eq!(g.encode_coord(coord), addr & !63);
            }
        }
    }

    #[test]
    fn decode_wraps_at_capacity() {
        let g = DramGeometry::profpga_board();
        assert_eq!(g.decode(g.capacity_bytes() + 64), g.decode(64));
    }

    #[test]
    fn sub_burst_addresses_share_location() {
        let g = DramGeometry::profpga_board();
        assert_eq!(g.decode(0), g.decode(63));
        assert_ne!(g.decode(0), g.decode(64));
    }

    #[test]
    fn group_index() {
        let g = DramGeometry::profpga_board();
        assert_eq!(DramAddr { bank: 0, row: 0, col: 0 }.group(&g), 0);
        assert_eq!(DramAddr { bank: 5, row: 0, col: 0 }.group(&g), 1);
    }

    #[test]
    fn validate_rejects_non_pow2() {
        let mut g = DramGeometry::profpga_board();
        g.rows = 1000;
        assert!(g.validate().is_err());
    }

    #[test]
    fn row_step_bytes_per_policy() {
        let mut g = DramGeometry::profpga_board();
        // Ro is the MSB field: one row step spans all banks' rows (64 KiB)
        assert_eq!(g.row_step_bytes(), 8 * g.row_bytes());
        g.mapping = MappingPolicy::bank_row_col();
        // Ro sits directly above Co: one row step is one row (8 KiB)
        assert_eq!(g.row_step_bytes(), g.row_bytes());
    }
}
