//! DRAM channel geometry and physical address decomposition.
//!
//! Models the proFPGA DDR4 daughter board (Micron EDY4016A 4 Gb x16 parts):
//! four x16 devices in lockstep form a 64-bit data channel (a fifth part on
//! the physical board carries ECC and is not modeled), giving 2 GiB of
//! addressable data per channel. An x16 DDR4 device has 2 bank groups × 4
//! banks; the channel inherits that bank structure since all devices receive
//! the same commands.
//!
//! The address-mapping policy is the memory controller's choice (PG150's
//! `MEM_ADDR_ORDER`); [`AddrMapping::RowColBank`] is the MIG default for
//! AXI designs and the profile used in the paper reproduction: consecutive
//! BL8 bursts rotate across banks (and therefore bank groups), which is
//! what lets sequential streams pipeline ACTs and dodge tCCD_L.

/// Burst length of DDR4 (fixed BL8 in this platform, as in MIG).
pub const BURST_LEN: u32 = 8;

/// How the linear byte address is scattered over (row, bank, column).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AddrMapping {
    /// row | column | bank | burst-offset — MIG default (`ROW_COLUMN_BANK`).
    /// Sequential bursts interleave across banks.
    RowColBank,
    /// row | bank | column | burst-offset (`ROW_BANK_COLUMN`). Sequential
    /// bursts stream within one row of one bank before moving on.
    RowBankCol,
    /// bank | row | column | burst-offset (`BANK_ROW_COLUMN`). Large
    /// regions stay in one bank; worst sequential-ACT behaviour, used in
    /// the mapping ablation.
    BankRowCol,
}

impl AddrMapping {
    /// Parse "row_col_bank" style names.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().replace('-', "_").as_str() {
            "row_col_bank" | "rowcolbank" => Some(AddrMapping::RowColBank),
            "row_bank_col" | "rowbankcol" => Some(AddrMapping::RowBankCol),
            "bank_row_col" | "bankrowcol" => Some(AddrMapping::BankRowCol),
            _ => None,
        }
    }
}

/// Geometry of one DRAM channel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DramGeometry {
    /// Data-bus width in bytes (8 = 64-bit channel).
    pub bus_bytes: u32,
    /// Bank groups per channel.
    pub bank_groups: u32,
    /// Banks per bank group.
    pub banks_per_group: u32,
    /// Rows per bank.
    pub rows: u32,
    /// Column addresses per row (per device; BL8 bursts consume 8).
    pub cols: u32,
    /// Address-mapping policy.
    pub mapping: AddrMapping,
}

/// A fully decoded DRAM location (one BL8 burst's worth of address).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DramAddr {
    /// Flat bank index: `group * banks_per_group + bank`.
    pub bank: u32,
    /// Row within the bank.
    pub row: u32,
    /// Column address of the burst (aligned to BL8, i.e. multiple of 8).
    pub col: u32,
}

impl DramAddr {
    /// Bank-group index of this address.
    pub fn group(&self, geo: &DramGeometry) -> u32 {
        self.bank / geo.banks_per_group
    }
}

impl DramGeometry {
    /// The proFPGA DDR4 board: 4 × Micron EDY4016A (4 Gb x16) in lockstep.
    /// 2 bank groups × 4 banks, 32768 rows, 1024 columns, 64-bit bus,
    /// MIG-default ROW_COLUMN_BANK mapping. 2 GiB data capacity.
    pub fn profpga_board() -> Self {
        Self {
            bus_bytes: 8,
            bank_groups: 2,
            banks_per_group: 4,
            rows: 32768,
            cols: 1024,
            mapping: AddrMapping::RowColBank,
        }
    }

    /// Total banks in the channel.
    pub fn banks(&self) -> u32 {
        self.bank_groups * self.banks_per_group
    }

    /// Bytes transferred by one BL8 DRAM burst (64 B on a 64-bit channel).
    pub fn burst_bytes(&self) -> u32 {
        self.bus_bytes * BURST_LEN
    }

    /// Bytes in one open row across the channel (the "page": 8 KiB here).
    pub fn row_bytes(&self) -> u64 {
        self.cols as u64 * self.bus_bytes as u64
    }

    /// Total channel capacity in bytes.
    pub fn capacity_bytes(&self) -> u64 {
        self.row_bytes() * self.rows as u64 * self.banks() as u64
    }

    /// BL8 bursts per row.
    pub fn bursts_per_row(&self) -> u32 {
        self.cols / BURST_LEN
    }

    /// Validate power-of-two fields and sane sizes.
    pub fn validate(&self) -> Result<(), String> {
        for (name, v) in [
            ("bus_bytes", self.bus_bytes),
            ("bank_groups", self.bank_groups),
            ("banks_per_group", self.banks_per_group),
            ("rows", self.rows),
            ("cols", self.cols),
        ] {
            if !v.is_power_of_two() {
                return Err(format!("{name} must be a power of two, got {v}"));
            }
        }
        if self.cols < BURST_LEN {
            return Err(format!("cols must be >= {BURST_LEN}"));
        }
        Ok(())
    }

    /// Decode a byte address into a DRAM location. The address is first
    /// burst-aligned (low `log2(burst_bytes)` bits dropped) and wrapped to
    /// capacity.
    pub fn decode(&self, byte_addr: u64) -> DramAddr {
        let burst_index =
            (byte_addr % self.capacity_bytes()) / self.burst_bytes() as u64;
        let banks = self.banks() as u64;
        let bursts_per_row = self.bursts_per_row() as u64;
        match self.mapping {
            AddrMapping::RowColBank => {
                // Bank-group bits lowest (MIG's DDR4 default): consecutive
                // bursts alternate bank groups so back-to-back CAS pay
                // tCCD_S, not tCCD_L.
                let group = (burst_index % self.bank_groups as u64) as u32;
                let in_group = ((burst_index / self.bank_groups as u64)
                    % self.banks_per_group as u64) as u32;
                let bank = group * self.banks_per_group + in_group;
                let rest = burst_index / banks;
                let col = ((rest % bursts_per_row) as u32) * BURST_LEN;
                let row = (rest / bursts_per_row) as u32;
                DramAddr { bank, row, col }
            }
            AddrMapping::RowBankCol => {
                let col = ((burst_index % bursts_per_row) as u32) * BURST_LEN;
                let rest = burst_index / bursts_per_row;
                let bank = (rest % banks) as u32;
                let row = (rest / banks) as u32;
                DramAddr { bank, row, col }
            }
            AddrMapping::BankRowCol => {
                let col = ((burst_index % bursts_per_row) as u32) * BURST_LEN;
                let rest = burst_index / bursts_per_row;
                let row = (rest % self.rows as u64) as u32;
                let bank = (rest / self.rows as u64) as u32;
                DramAddr { bank, row, col }
            }
        }
    }

    /// Re-encode a DRAM location into the byte address of its burst
    /// (inverse of [`Self::decode`]; used by the bijectivity property test).
    pub fn encode(&self, a: DramAddr) -> u64 {
        let banks = self.banks() as u64;
        let bursts_per_row = self.bursts_per_row() as u64;
        let col_burst = (a.col / BURST_LEN) as u64;
        let burst_index = match self.mapping {
            AddrMapping::RowColBank => {
                let group = (a.bank / self.banks_per_group) as u64;
                let in_group = (a.bank % self.banks_per_group) as u64;
                let low = in_group * self.bank_groups as u64 + group;
                (a.row as u64 * bursts_per_row + col_burst) * banks + low
            }
            AddrMapping::RowBankCol => {
                (a.row as u64 * banks + a.bank as u64) * bursts_per_row + col_burst
            }
            AddrMapping::BankRowCol => {
                (a.bank as u64 * self.rows as u64 + a.row as u64) * bursts_per_row + col_burst
            }
        };
        burst_index * self.burst_bytes() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profpga_capacity_is_2gib() {
        let g = DramGeometry::profpga_board();
        assert_eq!(g.capacity_bytes(), 2 << 30);
        assert_eq!(g.banks(), 8);
        assert_eq!(g.burst_bytes(), 64);
        assert_eq!(g.row_bytes(), 8 << 10);
        assert_eq!(g.bursts_per_row(), 128);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn row_col_bank_interleaves_banks() {
        // MIG default: consecutive 64B bursts hit all 8 banks before any
        // repeats, and alternate bank *groups* every burst (tCCD_S path).
        let g = DramGeometry::profpga_board();
        let mut seen = std::collections::HashSet::new();
        let mut prev_group = None;
        for i in 0..8u64 {
            let a = g.decode(i * 64);
            assert_eq!(a.row, 0);
            seen.insert(a.bank);
            let grp = a.group(&g);
            if let Some(p) = prev_group {
                assert_ne!(grp, p, "burst {i} must switch bank group");
            }
            prev_group = Some(grp);
        }
        assert_eq!(seen.len(), 8, "8 consecutive bursts cover all 8 banks");
        // one full row-of-all-banks = 8 banks * 8KiB before row increments
        let a = g.decode(8 * g.row_bytes());
        assert_eq!(a.row, 1);
    }

    #[test]
    fn row_bank_col_streams_within_row() {
        let mut g = DramGeometry::profpga_board();
        g.mapping = AddrMapping::RowBankCol;
        // first 8KiB stays in bank 0 row 0
        for i in 0..128u64 {
            let a = g.decode(i * 64);
            assert_eq!((a.bank, a.row), (0, 0), "burst {i}");
            assert_eq!(a.col, (i as u32) * 8);
        }
        let a = g.decode(g.row_bytes());
        assert_eq!((a.bank, a.row), (1, 0));
    }

    #[test]
    fn decode_encode_roundtrip_all_mappings() {
        for mapping in [AddrMapping::RowColBank, AddrMapping::RowBankCol, AddrMapping::BankRowCol]
        {
            let mut g = DramGeometry::profpga_board();
            g.mapping = mapping;
            for addr in [0u64, 64, 4096, 8 << 10, 1 << 20, (2 << 30) - 64] {
                let dec = g.decode(addr);
                assert_eq!(g.encode(dec), addr & !63, "{mapping:?} addr={addr}");
            }
        }
    }

    #[test]
    fn decode_wraps_at_capacity() {
        let g = DramGeometry::profpga_board();
        assert_eq!(g.decode(g.capacity_bytes() + 64), g.decode(64));
    }

    #[test]
    fn sub_burst_addresses_share_location() {
        let g = DramGeometry::profpga_board();
        assert_eq!(g.decode(0), g.decode(63));
        assert_ne!(g.decode(0), g.decode(64));
    }

    #[test]
    fn group_index() {
        let g = DramGeometry::profpga_board();
        assert_eq!(DramAddr { bank: 0, row: 0, col: 0 }.group(&g), 0);
        assert_eq!(DramAddr { bank: 5, row: 0, col: 0 }.group(&g), 1);
    }

    #[test]
    fn validate_rejects_non_pow2() {
        let mut g = DramGeometry::profpga_board();
        g.rows = 1000;
        assert!(g.validate().is_err());
    }

    #[test]
    fn mapping_parse() {
        assert_eq!(AddrMapping::parse("row_col_bank"), Some(AddrMapping::RowColBank));
        assert_eq!(AddrMapping::parse("ROW-BANK-COL"), Some(AddrMapping::RowBankCol));
        assert_eq!(AddrMapping::parse("nope"), None);
    }
}
