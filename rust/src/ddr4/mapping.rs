//! Runtime-configurable DRAM address-mapping policy engine.
//!
//! How a linear byte address is split into bank-group / bank / row /
//! column bits is the memory controller's choice (PG150's
//! `MEM_ADDR_ORDER` in hardware), and it is one of the strongest levers
//! on row-hit rate and bank-level parallelism: bank-interleaved orders
//! pipeline ACTs across banks and dodge tCCD_L, row-major orders maximize
//! open-page streaks, and permutation (XOR) hashes break pathological
//! stride-to-bank resonance. This module makes that choice a *run-time*
//! parameter of the platform.
//!
//! A [`MappingPolicy`] is an MSB→LSB interleave order of the four address
//! [`Field`]s (row `Ro`, bank group `Bg`, bank `Ba`, column `Co`),
//! optionally composed with an XOR bank hash that folds the low row bits
//! into the bank index. Every policy implements a bijective
//! `decode(addr) -> DramCoord` / `encode(coord) -> addr` pair over the
//! channel geometry (property-tested in `rust/tests/proptests.rs`).
//!
//! Built-in policies (all reachable via `MAP=<name>` in the config-file /
//! CLI / host-protocol token syntax, plus arbitrary custom orders like
//! `MAP=RoBaBgCo`):
//!
//! | name           | order (MSB→LSB) | behaviour                         |
//! |----------------|-----------------|-----------------------------------|
//! | `row_col_bank` | Ro Co Ba Bg     | MIG default; bursts rotate banks  |
//! | `row_bank_col` | Ro Bg Ba Co     | open-page row-major streaming     |
//! | `bank_row_col` | Bg Ba Ro Co     | bank-interleaved large regions    |
//! | `xor_hash`     | Ro Co Ba Bg ⊕   | permutation-style XOR bank hash   |

use super::geometry::{DramAddr, BURST_LEN};

/// One field of the DRAM coordinate. The discriminants index the
/// scratch arrays of the mixed-radix decode/encode.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Field {
    /// Row within a bank (`Ro`).
    Row = 0,
    /// Bank group (`Bg`).
    Group = 1,
    /// Bank within its group (`Ba`).
    Bank = 2,
    /// Column, in BL8-burst units (`Co`).
    Col = 3,
}

impl Field {
    /// All fields, in discriminant order.
    pub const ALL: [Field; 4] = [Field::Row, Field::Group, Field::Bank, Field::Col];

    fn idx(self) -> usize {
        self as usize
    }

    /// Two-letter token used in custom bit-order strings.
    pub fn token(self) -> &'static str {
        match self {
            Field::Row => "Ro",
            Field::Group => "Bg",
            Field::Bank => "Ba",
            Field::Col => "Co",
        }
    }

    /// Number of distinct values of this field under the given sizes.
    fn size(self, s: &FieldSizes) -> u64 {
        match self {
            Field::Row => s.rows,
            Field::Group => s.groups,
            Field::Bank => s.banks_per_group,
            Field::Col => s.col_bursts,
        }
    }
}

/// The radix of each coordinate field — derived from a
/// [`DramGeometry`](super::geometry::DramGeometry) via
/// [`field_sizes`](super::geometry::DramGeometry::field_sizes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FieldSizes {
    /// Rows per bank.
    pub rows: u64,
    /// Bank groups per channel.
    pub groups: u64,
    /// Banks per bank group.
    pub banks_per_group: u64,
    /// BL8 bursts per row (columns / 8).
    pub col_bursts: u64,
}

impl FieldSizes {
    /// Total banks in the channel.
    pub fn banks(&self) -> u64 {
        self.groups * self.banks_per_group
    }
}

/// A fully decomposed DRAM location: the structured form of
/// [`DramAddr`], with the bank group split out from the flat bank index.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DramCoord {
    /// Bank-group index.
    pub group: u32,
    /// Bank index *within its group*.
    pub bank: u32,
    /// Row within the bank.
    pub row: u32,
    /// Column address of the burst (aligned to BL8, i.e. multiple of 8).
    pub col: u32,
}

impl DramCoord {
    /// Flat bank index (`group * banks_per_group + bank`).
    pub fn flat_bank(&self, banks_per_group: u32) -> u32 {
        self.group * banks_per_group + self.bank
    }

    /// Build from a flat-bank [`DramAddr`].
    pub fn from_flat(a: DramAddr, banks_per_group: u32) -> Self {
        Self {
            group: a.bank / banks_per_group,
            bank: a.bank % banks_per_group,
            row: a.row,
            col: a.col,
        }
    }

    /// Collapse to the flat-bank [`DramAddr`] the controller queues use.
    pub fn to_flat(self, banks_per_group: u32) -> DramAddr {
        DramAddr { bank: self.flat_bank(banks_per_group), row: self.row, col: self.col }
    }
}

/// A runtime-selectable address-mapping policy: an MSB→LSB order of the
/// four coordinate fields, optionally composed with an XOR bank hash.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MappingPolicy {
    /// Field interleave order, most-significant first.
    order: [Field; 4],
    /// Fold the low row bits into the (flat) bank index with XOR. The
    /// fold is its own inverse, so the policy stays bijective.
    xor_hash: bool,
}

impl MappingPolicy {
    /// MIG's DDR4 default `ROW_COLUMN_BANK` (Ro Co Ba Bg): consecutive
    /// bursts alternate bank groups (tCCD_S path) and rotate all banks.
    pub fn row_col_bank() -> Self {
        Self { order: [Field::Row, Field::Col, Field::Bank, Field::Group], xor_hash: false }
    }

    /// `ROW_BANK_COLUMN` (Ro Bg Ba Co): sequential streams stay inside
    /// one row of one bank before moving on (open-page row-major).
    pub fn row_bank_col() -> Self {
        Self { order: [Field::Row, Field::Group, Field::Bank, Field::Col], xor_hash: false }
    }

    /// `BANK_ROW_COLUMN` (Bg Ba Ro Co): large address regions stay in a
    /// single bank; worst sequential-ACT behaviour, used in ablations.
    pub fn bank_row_col() -> Self {
        Self { order: [Field::Group, Field::Bank, Field::Row, Field::Col], xor_hash: false }
    }

    /// Permutation-style XOR bank hash over the MIG base order: the low
    /// row bits are XOR-folded into the bank index, so strided streams
    /// that would resonate onto one bank get spread across all of them.
    pub fn xor_hash() -> Self {
        Self { order: [Field::Row, Field::Col, Field::Bank, Field::Group], xor_hash: true }
    }

    /// A custom field order (MSB→LSB), optionally XOR-hashed. The XOR
    /// fold swizzles the bank bits with the *row* bits, so it is only
    /// constructible when the row field is more significant than both
    /// bank fields — folding upward would smear one bank's rows across
    /// the whole address space.
    pub fn custom(order: [Field; 4], xor_hash: bool) -> Option<Self> {
        let mut seen = [false; 4];
        for f in order {
            if seen[f.idx()] {
                return None;
            }
            seen[f.idx()] = true;
        }
        if xor_hash {
            let at =
                |f: Field| order.iter().position(|o| *o == f).expect("order holds all four fields");
            if at(Field::Row) > at(Field::Group) || at(Field::Row) > at(Field::Bank) {
                return None;
            }
        }
        Some(Self { order, xor_hash })
    }

    /// The field interleave order in force (MSB→LSB).
    pub fn order(&self) -> [Field; 4] {
        self.order
    }

    /// Is the XOR bank hash enabled?
    pub fn is_xor_hashed(&self) -> bool {
        self.xor_hash
    }

    /// Parse a policy name: a built-in (`row_col_bank`, `row_bank_col`,
    /// `bank_row_col`, `xor_hash`) or a custom bit-order string such as
    /// `RoBaBgCo` / `ba-ro-co` (a bare `Ba` without `Bg` means the flat
    /// bank, i.e. `Bg` immediately above `Ba`), optionally prefixed with
    /// `xor` to enable the bank hash. Case- and separator-insensitive.
    pub fn parse(s: &str) -> Option<Self> {
        let norm: String =
            s.chars().filter(char::is_ascii_alphanumeric).collect::<String>().to_ascii_lowercase();
        match norm.as_str() {
            "rowcolbank" | "rocobabg" | "mig" | "default" => return Some(Self::row_col_bank()),
            "rowbankcol" | "robgbaco" | "openpage" => return Some(Self::row_bank_col()),
            "bankrowcol" | "bgbaroco" => return Some(Self::bank_row_col()),
            "xor" | "xorhash" | "xorbankhash" | "permute" => return Some(Self::xor_hash()),
            _ => {}
        }
        match norm.strip_prefix("xor") {
            Some(rest) if !rest.is_empty() => Self::parse_order(rest, true),
            _ => Self::parse_order(&norm, false),
        }
    }

    /// Parse a lowercase run of 2-letter field tokens into an order.
    fn parse_order(norm: &str, xor_hash: bool) -> Option<Self> {
        if norm.len() % 2 != 0 {
            return None;
        }
        let mut fields = Vec::with_capacity(4);
        for chunk in norm.as_bytes().chunks(2) {
            let f = match chunk {
                b"ro" => Field::Row,
                b"bg" => Field::Group,
                b"ba" => Field::Bank,
                b"co" => Field::Col,
                _ => return None,
            };
            if fields.contains(&f) {
                return None;
            }
            fields.push(f);
        }
        // A 3-token order with a bare `Ba` treats it as the flat bank:
        // the group field slots in directly above the bank field.
        if fields.len() == 3 && fields.contains(&Field::Bank) && !fields.contains(&Field::Group) {
            let at = fields
                .iter()
                .position(|f| *f == Field::Bank)
                .expect("contains(Bank) checked above");
            fields.insert(at, Field::Group);
        }
        if fields.len() != 4 {
            return None;
        }
        Self::custom([fields[0], fields[1], fields[2], fields[3]], xor_hash)
    }

    /// Canonical name: the built-in name when the policy matches one,
    /// otherwise the bit-order string (`RoBaBgCo`, `XorBaRoCo`, …).
    /// `MappingPolicy::parse` of the result reproduces the policy.
    pub fn name(&self) -> String {
        if *self == Self::row_col_bank() {
            return "row_col_bank".into();
        }
        if *self == Self::row_bank_col() {
            return "row_bank_col".into();
        }
        if *self == Self::bank_row_col() {
            return "bank_row_col".into();
        }
        if *self == Self::xor_hash() {
            return "xor_hash".into();
        }
        let mut s = String::with_capacity(11);
        if self.xor_hash {
            s.push_str("Xor");
        }
        for f in self.order {
            s.push_str(f.token());
        }
        s
    }

    /// All built-in policies (the `MAPPINGS` host-protocol listing).
    pub fn builtins() -> [MappingPolicy; 4] {
        [Self::row_col_bank(), Self::row_bank_col(), Self::bank_row_col(), Self::xor_hash()]
    }

    /// Decode a BL8 burst index into a DRAM coordinate (mixed-radix digit
    /// extraction in field order, then the optional XOR bank fold).
    pub fn decode_burst(&self, burst_index: u64, s: &FieldSizes) -> DramCoord {
        let mut rest = burst_index;
        let mut vals = [0u64; 4];
        for f in self.order.iter().rev() {
            let size = f.size(s).max(1);
            vals[f.idx()] = rest % size;
            rest /= size;
        }
        let row = vals[Field::Row.idx()];
        let mut group = vals[Field::Group.idx()];
        let mut bank = vals[Field::Bank.idx()];
        if self.xor_hash {
            let flat = (group * s.banks_per_group + bank) ^ (row & (s.banks() - 1));
            group = flat / s.banks_per_group;
            bank = flat % s.banks_per_group;
        }
        DramCoord {
            group: group as u32,
            bank: bank as u32,
            row: row as u32,
            col: (vals[Field::Col.idx()] as u32) * BURST_LEN,
        }
    }

    /// Re-encode a DRAM coordinate into its BL8 burst index — the exact
    /// inverse of [`Self::decode_burst`] (the XOR fold is self-inverse).
    pub fn encode_burst(&self, c: DramCoord, s: &FieldSizes) -> u64 {
        let mut group = c.group as u64;
        let mut bank = c.bank as u64;
        if self.xor_hash {
            let flat = (group * s.banks_per_group + bank) ^ (c.row as u64 & (s.banks() - 1));
            group = flat / s.banks_per_group;
            bank = flat % s.banks_per_group;
        }
        let mut vals = [0u64; 4];
        vals[Field::Row.idx()] = c.row as u64;
        vals[Field::Group.idx()] = group;
        vals[Field::Bank.idx()] = bank;
        vals[Field::Col.idx()] = (c.col / BURST_LEN) as u64;
        let mut idx = 0u64;
        for f in self.order {
            idx = idx * f.size(s).max(1) + vals[f.idx()];
        }
        idx
    }

    /// Bursts between consecutive rows of the same bank: the product of
    /// the field sizes below `Ro` in the interleave order. The
    /// bank-conflict generator derives its adversarial stride from this.
    pub fn row_step_bursts(&self, s: &FieldSizes) -> u64 {
        let at = self
            .order
            .iter()
            .position(|f| *f == Field::Row)
            .expect("order holds all four fields");
        self.order[at + 1..].iter().map(|f| f.size(s).max(1)).product()
    }

    /// How many distinct banks a sequential burst stream rotates across
    /// before reusing one: the product of the bank/group field sizes that
    /// sit below *both* the column and the row fields (a bank field above
    /// either only changes once that field exhausts, so it contributes no
    /// rotation — 1 for row-major orders, where the whole row streams
    /// from a single bank). The XOR hash always spreads a sequential
    /// stream across every bank. Feeds the analytic model's row-miss
    /// accounting.
    pub fn seq_bank_rotation(&self, s: &FieldSizes) -> u64 {
        if self.xor_hash {
            return s.banks();
        }
        let at = |f: Field| {
            self.order.iter().position(|o| *o == f).expect("order holds all four fields")
        };
        let below = at(Field::Col).max(at(Field::Row));
        self.order[below + 1..]
            .iter()
            .filter(|f| matches!(f, Field::Group | Field::Bank))
            .map(|f| f.size(s).max(1))
            .product()
    }

    /// Consecutive bursts a sequential stream spends in one row of one
    /// bank before that row closes: the full row when the column field
    /// sits below the row field (normal page-mode orders), a single
    /// burst when the row field is less significant than the column —
    /// the pathological row-thrash orders like `CoBaBgRo`. Sets the
    /// amortization window of the analytic model's row-reopen cost.
    pub fn seq_row_visit_bursts(&self, s: &FieldSizes) -> u64 {
        let at = |f: Field| {
            self.order.iter().position(|o| *o == f).expect("order holds all four fields")
        };
        if at(Field::Col) > at(Field::Row) {
            s.col_bursts.max(1)
        } else {
            1
        }
    }
}

impl std::fmt::Display for MappingPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name())
    }
}

impl Default for MappingPolicy {
    fn default() -> Self {
        Self::row_col_bank()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sizes() -> FieldSizes {
        // the proFPGA board: 2 groups x 4 banks, 32768 rows, 128 bursts
        FieldSizes { rows: 32768, groups: 2, banks_per_group: 4, col_bursts: 128 }
    }

    #[test]
    fn builtin_names_roundtrip_through_parse() {
        for p in MappingPolicy::builtins() {
            assert_eq!(MappingPolicy::parse(&p.name()), Some(p), "{}", p.name());
        }
        // legacy geometry names still resolve
        assert_eq!(MappingPolicy::parse("row-col-bank"), Some(MappingPolicy::row_col_bank()));
        assert_eq!(MappingPolicy::parse("ROW_BANK_COL"), Some(MappingPolicy::row_bank_col()));
        assert_eq!(MappingPolicy::parse("XOR"), Some(MappingPolicy::xor_hash()));
    }

    #[test]
    fn custom_orders_parse_and_roundtrip() {
        let p = MappingPolicy::parse("RoBaBgCo").unwrap();
        assert_eq!(p.order(), [Field::Row, Field::Bank, Field::Group, Field::Col]);
        assert_eq!(MappingPolicy::parse(&p.name()), Some(p));
        // 3-token orders expand the bare bank to the flat bank
        assert_eq!(MappingPolicy::parse("BaRoCo"), Some(MappingPolicy::bank_row_col()));
        assert_eq!(MappingPolicy::parse("ro-ba-co"), Some(MappingPolicy::row_bank_col()));
        // xor prefix composes with custom orders whose row sits on top
        let x = MappingPolicy::parse("xor_RoBaBgCo").unwrap();
        assert!(x.is_xor_hashed());
        assert_eq!(MappingPolicy::parse(&x.name()), Some(x));
        // …but not with bank bits above the row bits (nothing to fold)
        assert_eq!(MappingPolicy::parse("xor_BaRoCo"), None);
    }

    #[test]
    fn bad_orders_rejected() {
        for bad in ["nope", "RoRoBaCo", "RoBa", "RoBgBa", "RoBgBaCoCo", "xor"] {
            let p = MappingPolicy::parse(bad);
            // "xor" alone is the builtin hash; everything else must fail
            if bad == "xor" {
                assert_eq!(p, Some(MappingPolicy::xor_hash()));
            } else {
                assert_eq!(p, None, "`{bad}` should not parse");
            }
        }
        assert!(MappingPolicy::custom([Field::Row; 4], false).is_none());
    }

    #[test]
    fn decode_encode_bijective_for_every_builtin_and_a_custom() {
        let s = sizes();
        let total = s.rows * s.groups * s.banks_per_group * s.col_bursts;
        let mut policies = MappingPolicy::builtins().to_vec();
        policies.push(MappingPolicy::parse("XorRoBaBgCo").unwrap());
        for p in policies {
            for idx in [0u64, 1, 7, 127, 128, 1 << 12, total / 2, total - 1] {
                let c = p.decode_burst(idx, &s);
                assert_eq!(p.encode_burst(c, &s), idx, "{} idx={idx}", p.name());
                assert!(c.group < 2 && c.bank < 4 && c.row < 32768 && c.col < 1024);
            }
        }
    }

    #[test]
    fn xor_hash_spreads_rows_of_one_burst_column_across_banks() {
        let s = sizes();
        let p = MappingPolicy::xor_hash();
        let step = p.row_step_bursts(&s); // advance the row field by one
        let banks: std::collections::HashSet<u32> = (0..8u64)
            .map(|r| {
                let c = p.decode_burst(r * step, &s);
                c.flat_bank(s.banks_per_group as u32)
            })
            .collect();
        assert_eq!(banks.len(), 8, "row-stride stream must fan out over all banks");
    }

    #[test]
    fn row_step_and_rotation_match_policy_shape() {
        let s = sizes();
        // Ro is MSB for both row-major policies: stride spans all banks
        assert_eq!(MappingPolicy::row_col_bank().row_step_bursts(&s), 128 * 8);
        assert_eq!(MappingPolicy::row_bank_col().row_step_bursts(&s), 128 * 8);
        // bank-interleaved: the row field sits directly above the column
        assert_eq!(MappingPolicy::bank_row_col().row_step_bursts(&s), 128);
        // sequential bank rotation: all 8 under MIG/XOR, none row-major
        assert_eq!(MappingPolicy::row_col_bank().seq_bank_rotation(&s), 8);
        assert_eq!(MappingPolicy::xor_hash().seq_bank_rotation(&s), 8);
        assert_eq!(MappingPolicy::row_bank_col().seq_bank_rotation(&s), 1);
        assert_eq!(MappingPolicy::bank_row_col().seq_bank_rotation(&s), 1);
        // bank fields above the row field contribute no rotation: the
        // row-thrash order CoBaBgRo reuses its bank on every burst…
        let thrash = MappingPolicy::parse("CoBaBgRo").unwrap();
        assert_eq!(thrash.seq_bank_rotation(&s), 1);
        assert_eq!(thrash.seq_row_visit_bursts(&s), 1, "new row every burst");
        // …while CoRoBaBg genuinely rotates all banks between row steps
        assert_eq!(MappingPolicy::parse("CoRoBaBg").unwrap().seq_bank_rotation(&s), 8);
        // page-mode orders stream a whole row per visit
        assert_eq!(MappingPolicy::row_bank_col().seq_row_visit_bursts(&s), 128);
        assert_eq!(MappingPolicy::bank_row_col().seq_row_visit_bursts(&s), 128);
    }

    #[test]
    fn coord_flat_conversions_roundtrip() {
        let c = DramCoord { group: 1, bank: 3, row: 42, col: 64 };
        let flat = c.to_flat(4);
        assert_eq!(flat.bank, 7);
        assert_eq!(DramCoord::from_flat(flat, 4), c);
    }
}
