//! DDR4 command set (the subset a benchmarking controller issues).

/// A DDR4 command addressed to one channel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmd {
    /// Activate `row` in `bank` (flat bank index): opens the row into the
    /// bank's row buffer.
    Act { bank: u32, row: u32 },
    /// Precharge `bank`: closes its open row.
    Pre { bank: u32 },
    /// Precharge all banks (used before refresh).
    PreAll,
    /// Column read of the BL8 burst at `col` in `bank`'s open row.
    /// `auto_pre` closes the row automatically after the access (RDA).
    Rd { bank: u32, col: u32, auto_pre: bool },
    /// Column write, mirroring [`Cmd::Rd`] (WRA when `auto_pre`).
    Wr { bank: u32, col: u32, auto_pre: bool },
    /// Refresh (REF): all banks must be idle; device is busy for tRFC.
    Ref,
}

impl Cmd {
    /// The flat bank index this command targets, if bank-specific.
    pub fn bank(&self) -> Option<u32> {
        match *self {
            Cmd::Act { bank, .. }
            | Cmd::Pre { bank }
            | Cmd::Rd { bank, .. }
            | Cmd::Wr { bank, .. } => {
                Some(bank)
            }
            Cmd::PreAll | Cmd::Ref => None,
        }
    }

    /// Is this a column (CAS) command?
    pub fn is_cas(&self) -> bool {
        matches!(self, Cmd::Rd { .. } | Cmd::Wr { .. })
    }

    /// Mnemonic for traces ("ACT"/"PRE"/"PREA"/"RD"/"RDA"/"WR"/"WRA"/"REF").
    pub fn mnemonic(&self) -> &'static str {
        match self {
            Cmd::Act { .. } => "ACT",
            Cmd::Pre { .. } => "PRE",
            Cmd::PreAll => "PREA",
            Cmd::Rd { auto_pre: false, .. } => "RD",
            Cmd::Rd { auto_pre: true, .. } => "RDA",
            Cmd::Wr { auto_pre: false, .. } => "WR",
            Cmd::Wr { auto_pre: true, .. } => "WRA",
            Cmd::Ref => "REF",
        }
    }
}

impl std::fmt::Display for Cmd {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            Cmd::Act { bank, row } => write!(f, "ACT b{bank} r{row}"),
            Cmd::Pre { bank } => write!(f, "PRE b{bank}"),
            Cmd::PreAll => write!(f, "PREA"),
            Cmd::Rd { bank, col, auto_pre } => {
                write!(f, "{} b{bank} c{col}", if auto_pre { "RDA" } else { "RD" })
            }
            Cmd::Wr { bank, col, auto_pre } => {
                write!(f, "{} b{bank} c{col}", if auto_pre { "WRA" } else { "WR" })
            }
            Cmd::Ref => write!(f, "REF"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bank_extraction() {
        assert_eq!(Cmd::Act { bank: 3, row: 9 }.bank(), Some(3));
        assert_eq!(Cmd::Pre { bank: 1 }.bank(), Some(1));
        assert_eq!(Cmd::Ref.bank(), None);
        assert_eq!(Cmd::PreAll.bank(), None);
    }

    #[test]
    fn cas_classification() {
        assert!(Cmd::Rd { bank: 0, col: 0, auto_pre: false }.is_cas());
        assert!(Cmd::Wr { bank: 0, col: 8, auto_pre: true }.is_cas());
        assert!(!Cmd::Act { bank: 0, row: 0 }.is_cas());
        assert!(!Cmd::Ref.is_cas());
    }

    #[test]
    fn mnemonics() {
        assert_eq!(Cmd::Rd { bank: 0, col: 0, auto_pre: true }.mnemonic(), "RDA");
        assert_eq!(Cmd::Wr { bank: 0, col: 0, auto_pre: false }.mnemonic(), "WR");
        assert_eq!(format!("{}", Cmd::Act { bank: 2, row: 7 }), "ACT b2 r7");
    }
}
