//! DDR4 SDRAM device model — the memory side of the paper's "memory
//! interface" component.
//!
//! The model is cycle-level at DRAM-clock resolution: every JEDEC timing
//! constraint that shapes the paper's throughput results (tRCD/tRP/CL row
//! cycles behind the sequential-vs-random gap, tCCD_S/L behind bank-group
//! interleaving, tFAW/tRRD behind activate throttling, tWTR/tWR behind the
//! read/write asymmetry, tREFI/tRFC behind refresh degradation) is enforced
//! per command. See `DESIGN.md` §2 for how this substitutes for the
//! physical Micron devices.

pub mod bank;
pub mod command;
pub mod device;
pub mod geometry;
pub mod mapping;
pub mod power;
pub mod timing;

pub use command::Cmd;
pub use device::{DdrDevice, DeviceStats};
pub use geometry::{DramAddr, DramGeometry, BURST_LEN};
pub use mapping::{DramCoord, Field, FieldSizes, MappingPolicy};
pub use timing::TimingParams;

/// Named protocol invariant, checked inside the device/bank state
/// machines. Compiled like `debug_assert!` by default (free in release
/// builds), but the `strict-invariants` cargo feature — which CI enables
/// for the test suite — keeps the checks in optimized builds too, so the
/// model can never silently drift from the JEDEC rules it claims to
/// enforce. The independent `check::` auditor re-derives the same rules
/// from `ddr4::timing` alone and never relies on these assertions.
macro_rules! invariant {
    ($cond:expr, $($arg:tt)+) => {
        if cfg!(any(debug_assertions, feature = "strict-invariants")) {
            assert!($cond, $($arg)+);
        }
    };
}
pub(crate) use invariant;

/// Simulation time in DRAM clock cycles (tCK units).
pub type Cycle = u64;

/// DRAM cycles per AXI fabric cycle — the paper's fixed 4:1 PHY:AXI ratio.
pub const AXI_RATIO: Cycle = 4;
