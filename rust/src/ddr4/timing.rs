//! JEDEC DDR4 speed-bin timing parameters.
//!
//! All values are expressed in DRAM clock cycles (nCK) at the bin's own
//! clock. Parameters specified by JEDEC in nanoseconds are converted with
//! `ceil(ns / tCK)` and clamped to their nCK minima, exactly as a real
//! controller's timing package does. The table covers the four bins of the
//! paper's campaign (Table II): DDR4-1600K, -1866M, -2133P, -2400R, for a
//! 4 Gb x16 device (2 KB page ⇒ the x16 tRRD/tFAW values).

use crate::config::SpeedBin;

/// DDR4 timing parameters in DRAM clock cycles (nCK).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TimingParams {
    /// CAS latency: READ command to first data.
    pub cl: u32,
    /// CAS write latency: WRITE command to first data.
    pub cwl: u32,
    /// ACT to internal READ/WRITE delay.
    pub trcd: u32,
    /// PRE to ACT delay (row precharge time).
    pub trp: u32,
    /// ACT to PRE minimum (row active time).
    pub tras: u32,
    /// ACT to ACT same bank (row cycle): tRAS + tRP.
    pub trc: u32,
    /// CAS to CAS, different bank group.
    pub tccd_s: u32,
    /// CAS to CAS, same bank group.
    pub tccd_l: u32,
    /// ACT to ACT, different bank group.
    pub trrd_s: u32,
    /// ACT to ACT, same bank group.
    pub trrd_l: u32,
    /// Four-activate window: at most 4 ACTs per tFAW.
    pub tfaw: u32,
    /// Write recovery: last write data to PRE.
    pub twr: u32,
    /// Write CAS to read CAS, different bank group (after last write data).
    pub twtr_s: u32,
    /// Write CAS to read CAS, same bank group (after last write data).
    pub twtr_l: u32,
    /// Read to PRE delay.
    pub trtp: u32,
    /// Refresh cycle time (REF to next valid command), 4 Gb: 260 ns.
    pub trfc: u32,
    /// Average refresh interval (7.8 µs).
    pub trefi: u32,
    /// Data burst duration on the bus: BL8 at DDR = 4 nCK.
    pub burst_cycles: u32,
}

/// Round `ns` up to clock cycles at `tck_ns`, with an nCK floor.
fn ck(ns: f64, tck_ns: f64, min_ck: u32) -> u32 {
    ((ns / tck_ns).ceil() as u32).max(min_ck)
}

impl TimingParams {
    /// Timing table for a JEDEC speed bin (4 Gb x16 device).
    pub fn for_bin(bin: SpeedBin) -> Self {
        let tck = bin.tck_ns();
        // Bin-specific latched latencies (nCK by definition).
        let (cl, cwl) = match bin {
            SpeedBin::Ddr4_1600 => (11, 9),
            SpeedBin::Ddr4_1866 => (13, 10),
            SpeedBin::Ddr4_2133 => (15, 11),
            SpeedBin::Ddr4_2400 => (16, 12),
        };
        // tRCD/tRP track CL in these bins (11-11-11 … 16-16-16).
        let trcd = cl;
        let trp = cl;
        // tRAS: 35/34/33/32 ns across the bins.
        let tras_ns = match bin {
            SpeedBin::Ddr4_1600 => 35.0,
            SpeedBin::Ddr4_1866 => 34.0,
            SpeedBin::Ddr4_2133 => 33.0,
            SpeedBin::Ddr4_2400 => 32.0,
        };
        let tras = ck(tras_ns, tck, 0);
        let tccd_s = 4;
        let tccd_l = ck(6.25, tck, 4);
        // x16 (2 KB page) activate spacing.
        let trrd_s = ck(5.3, tck, 4);
        let trrd_l = ck(6.4, tck, 4);
        let tfaw = ck(35.0, tck, 16);
        let twr = ck(15.0, tck, 0);
        let twtr_s = ck(2.5, tck, 2);
        let twtr_l = ck(7.5, tck, 4);
        let trtp = ck(7.5, tck, 4);
        let trfc = ck(260.0, tck, 0); // 4 Gb device
        let trefi = ck(7800.0, tck, 0);
        Self {
            cl,
            cwl,
            trcd,
            trp,
            tras,
            trc: tras + trp,
            tccd_s,
            tccd_l,
            trrd_s,
            trrd_l,
            tfaw,
            twr,
            twtr_s,
            twtr_l,
            trtp,
            trfc,
            trefi,
            burst_cycles: 4,
        }
    }

    /// Write-to-read turnaround on the command bus (same rank): the read
    /// CAS must wait `CWL + BL/2 + tWTR_x` after the write CAS.
    pub fn wr_to_rd(&self, same_group: bool) -> u32 {
        self.cwl
            + self.burst_cycles
            + if same_group { self.twtr_l } else { self.twtr_s }
    }

    /// Read-to-write turnaround: the write CAS must wait
    /// `CL + BL/2 + 2 - CWL` after the read CAS so the data bus switches
    /// direction with a 2-cycle bubble.
    pub fn rd_to_wr(&self) -> u32 {
        (self.cl + self.burst_cycles + 2).saturating_sub(self.cwl)
    }

    /// Minimum READ-to-PRE same-bank spacing.
    pub fn rd_to_pre(&self) -> u32 {
        self.trtp
    }

    /// Minimum WRITE-to-PRE same-bank spacing: CWL + BL/2 + tWR.
    pub fn wr_to_pre(&self) -> u32 {
        self.cwl + self.burst_cycles + self.twr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bin_1600_matches_jedec_k() {
        let t = TimingParams::for_bin(SpeedBin::Ddr4_1600);
        assert_eq!((t.cl, t.trcd, t.trp), (11, 11, 11));
        assert_eq!(t.cwl, 9);
        assert_eq!(t.tras, 28); // 35 ns @ 1.25 ns
        assert_eq!(t.trc, 39);
        assert_eq!(t.tccd_l, 5); // 6.25 ns @ 1.25 ns
        assert_eq!(t.trfc, 208); // 260 ns @ 1.25 ns
        assert_eq!(t.trefi, 6240); // 7.8 µs @ 1.25 ns
        assert_eq!(t.twr, 12); // 15 ns
    }

    #[test]
    fn bin_2400_matches_jedec_r() {
        let t = TimingParams::for_bin(SpeedBin::Ddr4_2400);
        assert_eq!((t.cl, t.trcd, t.trp), (16, 16, 16));
        assert_eq!(t.cwl, 12);
        assert_eq!(t.tras, 39); // 32 ns @ 0.8333 ns
        assert_eq!(t.trfc, 312); // 260 ns @ 0.8333 ns
        assert_eq!(t.tccd_l, 8); // 6.25 ns
    }

    #[test]
    fn latency_in_ns_roughly_constant_across_bins() {
        // The key DDR4 property behind the paper's §III-C analysis: core
        // latencies are ~constant in ns, so higher bins pay *more cycles*
        // of latency and random accesses gain far less than 50%.
        for bin in SpeedBin::ALL {
            let t = TimingParams::for_bin(bin);
            let ns = |c: u32| c as f64 * bin.tck_ns();
            let rc_ns = ns(t.trc);
            assert!((45.0..55.0).contains(&rc_ns), "{bin}: tRC = {rc_ns} ns");
            let miss = ns(t.trp + t.trcd + t.cl);
            assert!((40.0..50.0).contains(&miss), "{bin}: miss latency {miss} ns");
        }
    }

    #[test]
    fn ccd_l_strictly_ge_ccd_s() {
        for bin in SpeedBin::ALL {
            let t = TimingParams::for_bin(bin);
            assert!(t.tccd_l >= t.tccd_s);
            assert!(t.trrd_l >= t.trrd_s);
            assert!(t.twtr_l >= t.twtr_s);
        }
    }

    #[test]
    fn turnarounds_positive_and_ordered() {
        for bin in SpeedBin::ALL {
            let t = TimingParams::for_bin(bin);
            assert!(t.wr_to_rd(true) > t.wr_to_rd(false));
            assert!(t.rd_to_wr() > 0);
            assert!(t.wr_to_pre() > t.rd_to_pre());
        }
    }

    #[test]
    fn monotone_cycles_with_data_rate() {
        // ns-specified params take more cycles at faster clocks.
        let a = TimingParams::for_bin(SpeedBin::Ddr4_1600);
        let b = TimingParams::for_bin(SpeedBin::Ddr4_2400);
        assert!(b.trfc > a.trfc);
        assert!(b.trefi > a.trefi);
        assert!(b.cl > a.cl);
    }
}
