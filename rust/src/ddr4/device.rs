//! Channel-level DDR4 device model: cross-bank timing constraints, the
//! shared command/data buses, and refresh bookkeeping.
//!
//! The controller asks [`DdrDevice::earliest_issue`] when a candidate
//! command becomes legal and commits it with [`DdrDevice::issue`]. Legality
//! covers, beyond the per-bank gates in [`super::bank::Bank`]:
//!
//! - **tCCD_S/L** — CAS-to-CAS spacing, bank-group aware;
//! - **tRRD_S/L + tFAW** — ACT-to-ACT spacing and the four-activate window;
//! - **bus turnarounds** — write→read (CWL + BL/2 + tWTR_x) and read→write
//!   (CL + BL/2 + 2 − CWL) on the shared DQ bus;
//! - **refresh** — tREFI scheduling and the tRFC busy window.
//!
//! All times are in DRAM clock cycles ([`Cycle`]); the controller runs at
//! the same resolution and the AXI fabric at a 4:1 ratio above it.

use std::collections::VecDeque;

use super::bank::Bank;
use super::command::Cmd;
use super::geometry::DramGeometry;
use super::timing::TimingParams;
use super::{invariant, Cycle};

/// Cross-bank device state for one DDR4 channel.
#[derive(Debug, Clone)]
pub struct DdrDevice {
    t: TimingParams,
    geo: DramGeometry,
    banks: Vec<Bank>,
    /// Columnar (SoA-style) mirror of the hot `open_row.is_some()` bit,
    /// one bit per bank: the open/closed scans the scheduler and the
    /// event engine run every evaluation touch one word instead of
    /// striding through `Vec<Bank>`. Kept in sync by [`Self::issue`].
    open_mask: u64,
    /// Issue times of the last 4 ACTs (tFAW window).
    act_window: VecDeque<Cycle>,
    /// Last ACT issue time, any bank (tRRD_S), and per group (tRRD_L).
    last_act_any: Option<Cycle>,
    last_act_group: Vec<Option<Cycle>>,
    /// Last CAS issue time, any bank (tCCD_S), and per group (tCCD_L).
    last_cas_any: Option<Cycle>,
    last_cas_group: Vec<Option<Cycle>>,
    /// Last read / write CAS issue times (bus turnaround).
    last_rd_cas: Option<Cycle>,
    last_wr_cas: Option<(Cycle, u32)>, // (time, group)
    /// Next refresh deadline and the end of an in-progress tRFC window.
    refresh_due: Cycle,
    busy_until: Cycle,
    /// Statistics.
    stats: DeviceStats,
}

/// Command-level statistics the device accumulates (feeds the refresh and
/// row-hit-rate statistics the host controller can report, §II-C).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DeviceStats {
    /// ACT commands issued.
    pub acts: u64,
    /// PRE/PREA commands issued.
    pub pres: u64,
    /// Read CAS commands issued.
    pub reads: u64,
    /// Write CAS commands issued.
    pub writes: u64,
    /// REF commands issued.
    pub refreshes: u64,
}

impl DeviceStats {
    /// Command-count delta since an earlier snapshot (used for per-batch
    /// energy accounting).
    pub fn delta(&self, earlier: &DeviceStats) -> DeviceStats {
        DeviceStats {
            acts: self.acts - earlier.acts,
            pres: self.pres - earlier.pres,
            reads: self.reads - earlier.reads,
            writes: self.writes - earlier.writes,
            refreshes: self.refreshes - earlier.refreshes,
        }
    }

    /// Row-hit rate over all CAS commands, in the open-page sense: every
    /// ACT services exactly one "miss" stream, so hits = CAS − ACTs.
    pub fn row_hit_rate(&self) -> f64 {
        let cas = self.reads + self.writes;
        if cas == 0 {
            0.0
        } else {
            (cas.saturating_sub(self.acts)) as f64 / cas as f64
        }
    }
}

impl DdrDevice {
    /// New idle device. The first refresh falls one tREFI after reset.
    pub fn new(t: TimingParams, geo: DramGeometry) -> Self {
        let banks = vec![Bank::default(); geo.banks() as usize];
        invariant!(banks.len() <= 64, "OPEN_MASK_WIDTH: open_mask packs one bit per bank");
        let groups = geo.bank_groups as usize;
        Self {
            t,
            geo,
            banks,
            open_mask: 0,
            act_window: VecDeque::with_capacity(4),
            last_act_any: None,
            last_act_group: vec![None; groups],
            last_cas_any: None,
            last_cas_group: vec![None; groups],
            last_rd_cas: None,
            last_wr_cas: None,
            refresh_due: t.trefi as Cycle,
            busy_until: 0,
            stats: DeviceStats::default(),
        }
    }

    /// Timing parameters in force.
    pub fn timing(&self) -> &TimingParams {
        &self.t
    }

    /// Channel geometry.
    pub fn geometry(&self) -> &DramGeometry {
        &self.geo
    }

    /// Accumulated command statistics.
    pub fn stats(&self) -> &DeviceStats {
        &self.stats
    }

    /// Bank state (read-only view).
    pub fn bank(&self, bank: u32) -> &Bank {
        &self.banks[bank as usize]
    }

    /// Cycle at which the next REF is due (tREFI cadence).
    pub fn refresh_due(&self) -> Cycle {
        self.refresh_due
    }

    /// Is a refresh overdue at `now`?
    pub fn refresh_needed(&self, now: Cycle) -> bool {
        now >= self.refresh_due
    }

    /// Are all banks precharged? (One-word test on the SoA open column.)
    pub fn all_banks_closed(&self) -> bool {
        self.open_mask == 0
    }

    /// Number of currently open banks (one popcount on the SoA open
    /// column — the telemetry sampler's point snapshot).
    pub fn open_banks(&self) -> u32 {
        self.open_mask.count_ones()
    }

    /// The SoA open column itself: bit `b` is set iff bank `b` has an
    /// open row. The indexed scheduler's idle-precharge path word-scans
    /// this instead of striding `0..banks` through `Vec<Bank>`.
    pub fn open_bank_mask(&self) -> u64 {
        self.open_mask
    }

    /// The row currently open in `bank`, if any (the command tracer's
    /// row annotation for CAS/PRE events).
    pub fn open_row(&self, bank: u32) -> Option<u32> {
        self.banks[bank as usize].open_row
    }

    /// End of an in-progress tRFC window (0 when no refresh is active):
    /// every command class is gated until this cycle.
    pub fn busy_until(&self) -> Cycle {
        self.busy_until
    }

    /// Earliest cycle at which *some* bank could legally accept *some*
    /// command, ignoring cross-bank spacing (tRRD/tFAW/tCCD, bus
    /// turnarounds) — those only push legality later, so this is a safe
    /// lower bound: an event-engine wake hint, never an issue license.
    pub fn next_bank_actionable(&self) -> Cycle {
        let earliest =
            self.banks.iter().map(Bank::next_actionable).min().unwrap_or(Cycle::MAX);
        earliest.max(self.busy_until)
    }

    fn group_of(&self, bank: u32) -> usize {
        (bank / self.geo.banks_per_group) as usize
    }

    /// Earliest cycle at which `cmd` becomes legal. Monotone: issuing other
    /// commands can only push it later, never earlier.
    pub fn earliest_issue(&self, cmd: Cmd) -> Cycle {
        let mut at = self.busy_until;
        match cmd {
            Cmd::Act { bank, .. } => {
                let g = self.group_of(bank);
                at = at.max(self.banks[bank as usize].earliest_act);
                if let Some(t0) = self.last_act_any {
                    at = at.max(t0 + self.t.trrd_s as Cycle);
                }
                if let Some(t0) = self.last_act_group[g] {
                    at = at.max(t0 + self.t.trrd_l as Cycle);
                }
                if self.act_window.len() == 4 {
                    at = at.max(self.act_window[0] + self.t.tfaw as Cycle);
                }
            }
            Cmd::Pre { bank } => {
                at = at.max(self.banks[bank as usize].earliest_pre);
            }
            Cmd::PreAll => {
                for b in &self.banks {
                    if !b.is_closed() {
                        at = at.max(b.earliest_pre);
                    }
                }
            }
            Cmd::Rd { bank, .. } => {
                let g = self.group_of(bank);
                at = at.max(self.banks[bank as usize].earliest_cas);
                if let Some(t0) = self.last_cas_any {
                    at = at.max(t0 + self.t.tccd_s as Cycle);
                }
                if let Some(t0) = self.last_cas_group[g] {
                    at = at.max(t0 + self.t.tccd_l as Cycle);
                }
                if let Some((t0, wg)) = self.last_wr_cas {
                    at = at.max(t0 + self.t.wr_to_rd(wg as usize == g) as Cycle);
                }
            }
            Cmd::Wr { bank, .. } => {
                let g = self.group_of(bank);
                at = at.max(self.banks[bank as usize].earliest_cas);
                if let Some(t0) = self.last_cas_any {
                    at = at.max(t0 + self.t.tccd_s as Cycle);
                }
                if let Some(t0) = self.last_cas_group[g] {
                    at = at.max(t0 + self.t.tccd_l as Cycle);
                }
                if let Some(t0) = self.last_rd_cas {
                    at = at.max(t0 + self.t.rd_to_wr() as Cycle);
                }
            }
            Cmd::Ref => {
                // REF needs every bank precharged; PREs must have landed.
                for b in &self.banks {
                    invariant!(
                        b.is_closed(),
                        "REF_OPEN_BANK: REF legality queried with open banks; issue PREA first"
                    );
                    at = at.max(b.earliest_act.saturating_sub(self.t.trp as Cycle));
                }
                // tRP after the closing PREA is already folded into each
                // bank's earliest_act; approximate REF readiness as the
                // point where every bank could be re-activated minus tRP.
            }
        }
        at
    }

    /// Can `cmd` be issued exactly at `now`?
    pub fn can_issue(&self, cmd: Cmd, now: Cycle) -> bool {
        // Structural preconditions (row state), then timing.
        match cmd {
            Cmd::Act { bank, .. } => {
                if !self.banks[bank as usize].is_closed() {
                    return false;
                }
            }
            Cmd::Pre { bank } => {
                if self.banks[bank as usize].is_closed() {
                    return false;
                }
            }
            Cmd::Rd { bank, .. } | Cmd::Wr { bank, .. } => {
                if self.banks[bank as usize].is_closed() {
                    return false;
                }
            }
            Cmd::Ref => {
                if !self.all_banks_closed() {
                    return false;
                }
            }
            Cmd::PreAll => {}
        }
        now >= self.earliest_issue(cmd)
    }

    /// Issue `cmd` at `now`. Panics (debug) on protocol violations; returns
    /// the cycle at which the command's data phase completes (reads: last
    /// data beat on the bus; writes: end of the write burst; others: `now`).
    pub fn issue(&mut self, cmd: Cmd, now: Cycle) -> Cycle {
        invariant!(self.can_issue(cmd, now), "CMD_LEGALITY: illegal {cmd} at {now}");
        match cmd {
            Cmd::Act { bank, row } => {
                let g = self.group_of(bank);
                self.banks[bank as usize].on_act(row, now, &self.t);
                self.open_mask |= 1u64 << bank;
                self.last_act_any = Some(now);
                self.last_act_group[g] = Some(now);
                if self.act_window.len() == 4 {
                    self.act_window.pop_front();
                }
                self.act_window.push_back(now);
                self.stats.acts += 1;
                now
            }
            Cmd::Pre { bank } => {
                self.banks[bank as usize].on_pre(now, &self.t);
                self.open_mask &= !(1u64 << bank);
                self.stats.pres += 1;
                now
            }
            Cmd::PreAll => {
                for i in 0..self.banks.len() {
                    if !self.banks[i].is_closed() {
                        self.banks[i].on_pre(now, &self.t);
                    }
                }
                self.open_mask = 0;
                self.stats.pres += 1;
                now
            }
            Cmd::Rd { bank, auto_pre, .. } => {
                let g = self.group_of(bank);
                self.banks[bank as usize].on_rd(now, auto_pre, &self.t);
                if auto_pre {
                    self.open_mask &= !(1u64 << bank);
                }
                self.last_cas_any = Some(now);
                self.last_cas_group[g] = Some(now);
                self.last_rd_cas = Some(now);
                self.stats.reads += 1;
                now + (self.t.cl + self.t.burst_cycles) as Cycle
            }
            Cmd::Wr { bank, auto_pre, .. } => {
                let g = self.group_of(bank);
                self.banks[bank as usize].on_wr(now, auto_pre, &self.t);
                if auto_pre {
                    self.open_mask &= !(1u64 << bank);
                }
                self.last_cas_any = Some(now);
                self.last_cas_group[g] = Some(now);
                self.last_wr_cas = Some((now, g as u32));
                self.stats.writes += 1;
                now + (self.t.cwl + self.t.burst_cycles) as Cycle
            }
            Cmd::Ref => {
                invariant!(self.open_mask == 0, "REF_OPEN_BANK: REF requires all banks closed");
                for b in &mut self.banks {
                    b.on_refresh(now, &self.t);
                }
                self.busy_until = now + self.t.trfc as Cycle;
                self.refresh_due += self.t.trefi as Cycle;
                self.stats.refreshes += 1;
                self.busy_until
            }
        }
    }

    /// Row-hit / row-miss classification used by the FR-FCFS scheduler:
    /// `Some(true)` = open-row hit, `Some(false)` = conflict (different row
    /// open), `None` = bank closed (row miss, needs ACT only).
    pub fn row_state(&self, bank: u32, row: u32) -> Option<bool> {
        self.banks[bank as usize].open_row.map(|r| r == row)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SpeedBin;

    fn dev() -> DdrDevice {
        DdrDevice::new(
            TimingParams::for_bin(SpeedBin::Ddr4_1600),
            DramGeometry::profpga_board(),
        )
    }

    #[test]
    fn act_then_read_honours_trcd() {
        let mut d = dev();
        d.issue(Cmd::Act { bank: 0, row: 5 }, 0);
        let rd = Cmd::Rd { bank: 0, col: 0, auto_pre: false };
        assert!(!d.can_issue(rd, 5));
        let trcd = d.timing().trcd as Cycle;
        assert!(d.can_issue(rd, trcd));
        assert_eq!(d.earliest_issue(rd), trcd);
    }

    #[test]
    fn cas_to_closed_bank_illegal() {
        let d = dev();
        assert!(!d.can_issue(Cmd::Rd { bank: 0, col: 0, auto_pre: false }, 1000));
    }

    #[test]
    fn tccd_spacing_depends_on_group() {
        let mut d = dev();
        // open bank 0 (group 0) and bank 4 (group 1) and bank 1 (group 0)
        d.issue(Cmd::Act { bank: 0, row: 1 }, 0);
        let t_rrd = d.earliest_issue(Cmd::Act { bank: 4, row: 1 });
        d.issue(Cmd::Act { bank: 4, row: 1 }, t_rrd);
        let a1 = d.earliest_issue(Cmd::Act { bank: 1, row: 1 });
        d.issue(Cmd::Act { bank: 1, row: 1 }, a1);

        // start well past every bank's tRCD so only tCCD gates the probes
        let t0 = d.earliest_issue(Cmd::Rd { bank: 0, col: 0, auto_pre: false }).max(100);
        d.issue(Cmd::Rd { bank: 0, col: 0, auto_pre: false }, t0);
        // different group: tCCD_S; same group: tCCD_L
        let cross = d.earliest_issue(Cmd::Rd { bank: 4, col: 0, auto_pre: false });
        let same = d.earliest_issue(Cmd::Rd { bank: 1, col: 0, auto_pre: false });
        assert_eq!(cross, t0 + d.timing().tccd_s as Cycle);
        assert_eq!(same, t0 + d.timing().tccd_l as Cycle);
        assert!(same > cross);
    }

    #[test]
    fn trrd_and_tfaw_limit_act_rate() {
        let mut d = dev();
        let t = *d.timing();
        let mut acts = Vec::new();
        // issue 5 ACTs to distinct banks as fast as legal
        for bank in 0..5 {
            let cmd = Cmd::Act { bank, row: 0 };
            let at = d.earliest_issue(cmd);
            d.issue(cmd, at);
            acts.push(at);
        }
        for w in acts.windows(2) {
            assert!(w[1] - w[0] >= t.trrd_s as Cycle);
        }
        // 5th ACT must fall outside the first tFAW window
        assert!(acts[4] - acts[0] >= t.tfaw as Cycle, "{acts:?}");
    }

    #[test]
    fn write_to_read_turnaround() {
        let mut d = dev();
        let t = *d.timing();
        d.issue(Cmd::Act { bank: 0, row: 0 }, 0);
        let a1 = d.earliest_issue(Cmd::Act { bank: 4, row: 0 });
        d.issue(Cmd::Act { bank: 4, row: 0 }, a1);
        let w_at = d.earliest_issue(Cmd::Wr { bank: 0, col: 0, auto_pre: false });
        d.issue(Cmd::Wr { bank: 0, col: 0, auto_pre: false }, w_at);
        // read in the same group waits longer than in the other group
        let rd_same = d.earliest_issue(Cmd::Rd { bank: 0, col: 8, auto_pre: false });
        let rd_cross = d.earliest_issue(Cmd::Rd { bank: 4, col: 8, auto_pre: false });
        assert_eq!(rd_same, w_at + t.wr_to_rd(true) as Cycle);
        assert_eq!(rd_cross, w_at + t.wr_to_rd(false) as Cycle);
    }

    #[test]
    fn read_to_write_turnaround() {
        let mut d = dev();
        let t = *d.timing();
        d.issue(Cmd::Act { bank: 0, row: 0 }, 0);
        let r_at = d.earliest_issue(Cmd::Rd { bank: 0, col: 0, auto_pre: false });
        d.issue(Cmd::Rd { bank: 0, col: 0, auto_pre: false }, r_at);
        let w_earliest = d.earliest_issue(Cmd::Wr { bank: 0, col: 8, auto_pre: false });
        assert!(w_earliest >= r_at + t.rd_to_wr() as Cycle);
    }

    #[test]
    fn refresh_blocks_everything_for_trfc() {
        let mut d = dev();
        let t = *d.timing();
        assert!(d.can_issue(Cmd::Ref, t.trefi as Cycle));
        let end = d.issue(Cmd::Ref, t.trefi as Cycle);
        assert_eq!(end, t.trefi as Cycle + t.trfc as Cycle);
        // ACT before tRFC elapses is illegal
        assert!(!d.can_issue(Cmd::Act { bank: 0, row: 0 }, end - 1));
        assert!(d.can_issue(Cmd::Act { bank: 0, row: 0 }, end));
        // next refresh due one tREFI later
        assert_eq!(d.refresh_due(), 2 * t.trefi as Cycle);
    }

    #[test]
    fn refresh_requires_closed_banks() {
        let mut d = dev();
        d.issue(Cmd::Act { bank: 2, row: 3 }, 0);
        assert!(!d.can_issue(Cmd::Ref, 10_000));
        let pa = d.earliest_issue(Cmd::PreAll);
        d.issue(Cmd::PreAll, pa);
        assert!(d.all_banks_closed());
    }

    #[test]
    fn preall_closes_only_open_banks() {
        let mut d = dev();
        d.issue(Cmd::Act { bank: 1, row: 9 }, 0);
        let at = d.earliest_issue(Cmd::PreAll);
        d.issue(Cmd::PreAll, at);
        assert!(d.all_banks_closed());
        assert_eq!(d.stats().pres, 1);
    }

    #[test]
    fn stats_count_commands() {
        let mut d = dev();
        d.issue(Cmd::Act { bank: 0, row: 0 }, 0);
        let r = d.earliest_issue(Cmd::Rd { bank: 0, col: 0, auto_pre: false });
        d.issue(Cmd::Rd { bank: 0, col: 0, auto_pre: false }, r);
        let w = d.earliest_issue(Cmd::Wr { bank: 0, col: 8, auto_pre: false });
        d.issue(Cmd::Wr { bank: 0, col: 8, auto_pre: false }, w);
        let s = d.stats();
        assert_eq!((s.acts, s.reads, s.writes), (1, 1, 1));
        // 2 CAS served by 1 ACT: hit rate 0.5 in open-page accounting
        assert!((s.row_hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn row_state_classification() {
        let mut d = dev();
        assert_eq!(d.row_state(0, 7), None);
        d.issue(Cmd::Act { bank: 0, row: 7 }, 0);
        assert_eq!(d.row_state(0, 7), Some(true));
        assert_eq!(d.row_state(0, 8), Some(false));
    }

    #[test]
    fn earliest_issue_monotone_under_traffic() {
        // Issuing unrelated commands never makes a pending command legal
        // earlier.
        let mut d = dev();
        d.issue(Cmd::Act { bank: 0, row: 0 }, 0);
        let probe = Cmd::Rd { bank: 0, col: 0, auto_pre: false };
        let before = d.earliest_issue(probe);
        let a = d.earliest_issue(Cmd::Act { bank: 4, row: 2 });
        d.issue(Cmd::Act { bank: 4, row: 2 }, a);
        assert!(d.earliest_issue(probe) >= before);
    }

    /// The SoA open column must agree with the per-bank truth after any
    /// command sequence (the mask is what `all_banks_closed` now reads).
    fn assert_mask_consistent(d: &DdrDevice) {
        let truth = (0..d.geometry().banks()).all(|b| d.bank(b).is_closed());
        assert_eq!(d.all_banks_closed(), truth, "open_mask out of sync");
    }

    #[test]
    fn open_mask_tracks_bank_state_across_commands() {
        let mut d = dev();
        assert_mask_consistent(&d);
        d.issue(Cmd::Act { bank: 2, row: 3 }, 0);
        assert!(!d.all_banks_closed());
        assert_mask_consistent(&d);
        // auto-precharging CAS closes the bank through the mask too
        let r = d.earliest_issue(Cmd::Rd { bank: 2, col: 0, auto_pre: true });
        d.issue(Cmd::Rd { bank: 2, col: 0, auto_pre: true }, r);
        assert!(d.all_banks_closed());
        assert_mask_consistent(&d);
        // explicit PRE path
        let a = d.earliest_issue(Cmd::Act { bank: 5, row: 1 });
        d.issue(Cmd::Act { bank: 5, row: 1 }, a);
        assert_mask_consistent(&d);
        let p = d.earliest_issue(Cmd::Pre { bank: 5 });
        d.issue(Cmd::Pre { bank: 5 }, p);
        assert!(d.all_banks_closed());
        assert_mask_consistent(&d);
    }

    #[test]
    fn next_bank_actionable_is_a_lower_bound() {
        let mut d = dev();
        assert_eq!(d.next_bank_actionable(), 0, "fresh device: ACT legal now");
        d.issue(Cmd::Act { bank: 0, row: 0 }, 0);
        // some other bank is still closed with earliest_act = 0, so the
        // hint stays 0 — conservative, never later than true legality
        assert_eq!(d.next_bank_actionable(), 0);
        let t = *d.timing();
        let pa = d.earliest_issue(Cmd::PreAll);
        d.issue(Cmd::PreAll, pa);
        let ref_at = d.earliest_issue(Cmd::Ref).max(t.trefi as Cycle);
        let end = d.issue(Cmd::Ref, ref_at);
        // during tRFC nothing is actionable before the window ends
        assert_eq!(d.next_bank_actionable(), end);
        assert!(d.next_bank_actionable() <= d.earliest_issue(Cmd::Act { bank: 0, row: 0 }));
    }
}
