//! DDR4 energy/power model (DRAMPower-style, IDD-based).
//!
//! The paper's host controller can collect "a number of statistics"
//! beyond throughput (§II-C); energy per transferred bit is the one a
//! data-center deployment cares most about (§I's "energy and power
//! efficiency" motivation). This model turns the device's command counts
//! and the elapsed time into energy, using the Micron EDY4016A datasheet
//! current specs (IDD0/IDD2N/IDD3N/IDD4R/IDD4W/IDD5B at VDD 1.2 V),
//! scaled to the four-device 64-bit channel.
//!
//! Method (standard DRAMPower decomposition):
//! - **ACT/PRE pair**: `(IDD0 − IDD3N) × tRC × VDD` per activate;
//! - **RD/WR burst**: `(IDD4R/W − IDD3N) × tBURST × VDD` per CAS;
//! - **refresh**: `(IDD5B − IDD3N) × tRFC × VDD` per REF;
//! - **background**: `IDD3N × elapsed × VDD` (active standby; a closed
//!   idle channel would draw IDD2N — the model reports both bounds).

use super::device::DeviceStats;
use super::timing::TimingParams;
use crate::config::SpeedBin;

/// Datasheet currents in milliamps, per device (4 Gb x16, -083E/-075E
/// grades are close enough across the four bins for this model).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IddSpec {
    /// One-bank ACT-PRE current.
    pub idd0_ma: f64,
    /// Precharge standby.
    pub idd2n_ma: f64,
    /// Active standby.
    pub idd3n_ma: f64,
    /// Burst read.
    pub idd4r_ma: f64,
    /// Burst write.
    pub idd4w_ma: f64,
    /// Burst refresh.
    pub idd5b_ma: f64,
    /// Core supply voltage.
    pub vdd: f64,
}

impl IddSpec {
    /// Micron EDY4016A-class x16 device.
    pub fn micron_4gb_x16() -> Self {
        Self {
            idd0_ma: 58.0,
            idd2n_ma: 34.0,
            idd3n_ma: 46.0,
            idd4r_ma: 150.0,
            idd4w_ma: 148.0,
            idd5b_ma: 225.0,
            vdd: 1.2,
        }
    }
}

/// Energy breakdown of a batch, in nanojoules (whole channel = 4 devices).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    /// ACT+PRE row energy.
    pub activate_nj: f64,
    /// Read burst energy.
    pub read_nj: f64,
    /// Write burst energy.
    pub write_nj: f64,
    /// Refresh energy.
    pub refresh_nj: f64,
    /// Active-standby background energy over the elapsed window.
    pub background_nj: f64,
}

impl EnergyBreakdown {
    /// Total channel energy.
    pub fn total_nj(&self) -> f64 {
        self.activate_nj + self.read_nj + self.write_nj + self.refresh_nj + self.background_nj
    }

    /// Energy per transferred bit, in picojoules (None if no data moved).
    pub fn pj_per_bit(&self, bytes: u64) -> Option<f64> {
        if bytes == 0 {
            return None;
        }
        Some(self.total_nj() * 1000.0 / (bytes as f64 * 8.0))
    }

    /// Average power over the window, in milliwatts (1 nJ/ns = 1 W).
    pub fn avg_mw(&self, elapsed_ns: f64) -> f64 {
        if elapsed_ns <= 0.0 {
            return 0.0;
        }
        self.total_nj() / elapsed_ns * 1e3
    }
}

/// Devices ganged per channel (64-bit bus of x16 parts).
pub const DEVICES_PER_CHANNEL: f64 = 4.0;

/// Compute the energy of a window from device command statistics.
///
/// `elapsed_ck` is the window length in DRAM clocks; command counts come
/// from [`DeviceStats`] deltas across the window.
pub fn channel_energy(
    stats: &DeviceStats,
    elapsed_ck: u64,
    speed: SpeedBin,
    t: &TimingParams,
    idd: &IddSpec,
) -> EnergyBreakdown {
    let tck_ns = speed.tck_ns();
    let scale = DEVICES_PER_CHANNEL * idd.vdd; // mA × ns → pJ; ×1e-3 → nJ
    let nj = |ma: f64, ns: f64| ma * ns * scale * 1e-3;

    let trc_ns = t.trc as f64 * tck_ns;
    let tburst_ns = t.burst_cycles as f64 * tck_ns;
    let trfc_ns = t.trfc as f64 * tck_ns;
    let elapsed_ns = elapsed_ck as f64 * tck_ns;

    EnergyBreakdown {
        activate_nj: stats.acts as f64 * nj(idd.idd0_ma - idd.idd3n_ma, trc_ns),
        read_nj: stats.reads as f64 * nj(idd.idd4r_ma - idd.idd3n_ma, tburst_ns),
        write_nj: stats.writes as f64 * nj(idd.idd4w_ma - idd.idd3n_ma, tburst_ns),
        refresh_nj: stats.refreshes as f64 * nj(idd.idd5b_ma - idd.idd3n_ma, trfc_ns),
        background_nj: nj(idd.idd3n_ma, elapsed_ns),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> (TimingParams, IddSpec) {
        (TimingParams::for_bin(SpeedBin::Ddr4_1600), IddSpec::micron_4gb_x16())
    }

    fn stats(acts: u64, reads: u64, writes: u64, refreshes: u64) -> DeviceStats {
        DeviceStats { acts, pres: acts, reads, writes, refreshes }
    }

    #[test]
    fn idle_window_is_background_only() {
        let (t, idd) = spec();
        let e = channel_energy(&stats(0, 0, 0, 0), 800_000, SpeedBin::Ddr4_1600, &t, &idd);
        assert_eq!(e.activate_nj, 0.0);
        assert_eq!(e.read_nj + e.write_nj + e.refresh_nj, 0.0);
        // 1 ms of active standby at 4 × 46 mA × 1.2 V ≈ 220.8 µW·ms = 220.8 nJ... → µJ range
        let expected_nj = 46.0 * 1e6 * 4.0 * 1.2 * 1e-3; // mA × ns × scale
        assert!((e.background_nj - expected_nj).abs() / expected_nj < 1e-9);
        // average power of pure standby ≈ 220 mW for the channel
        let mw = e.avg_mw(1e6);
        assert!((200.0..250.0).contains(&mw), "{mw} mW");
    }

    #[test]
    fn read_energy_scales_with_cas_count() {
        let (t, idd) = spec();
        let e1 = channel_energy(&stats(0, 1000, 0, 0), 1000, SpeedBin::Ddr4_1600, &t, &idd);
        let e2 = channel_energy(&stats(0, 2000, 0, 0), 1000, SpeedBin::Ddr4_1600, &t, &idd);
        assert!((e2.read_nj / e1.read_nj - 2.0).abs() < 1e-12);
    }

    #[test]
    fn random_traffic_costs_more_than_sequential() {
        // Same data moved: sequential streams one ACT per 128 CAS in
        // ~4 ck per burst; random pays one ACT per CAS and takes ~37 ck
        // per access (so the standby window is longer too). Energy per
        // bit must be several times worse for random.
        let (t, idd) = spec();
        let bytes = 10_000u64 * 64;
        let seq = channel_energy(&stats(79, 10_000, 0, 6), 40_000, SpeedBin::Ddr4_1600, &t, &idd);
        let rnd =
            channel_energy(&stats(10_000, 10_000, 0, 60), 370_000, SpeedBin::Ddr4_1600, &t, &idd);
        assert!(rnd.pj_per_bit(bytes).unwrap() > seq.pj_per_bit(bytes).unwrap() * 1.5);
    }

    #[test]
    fn pj_per_bit_in_plausible_ddr4_range() {
        // Streaming reads on DDR4 land in the ~5-40 pJ/bit ballpark.
        let (t, idd) = spec();
        // 100k sequential read bursts over the time they take (~4 ck each)
        let e = channel_energy(
            &stats(800, 100_000, 0, 60),
            400_000,
            SpeedBin::Ddr4_1600,
            &t,
            &idd,
        );
        let pj = e.pj_per_bit(100_000 * 64).unwrap();
        assert!((2.0..60.0).contains(&pj), "{pj} pJ/bit");
    }

    #[test]
    fn zero_bytes_has_no_per_bit_metric() {
        let (t, idd) = spec();
        let e = channel_energy(&stats(0, 0, 0, 0), 100, SpeedBin::Ddr4_1600, &t, &idd);
        assert!(e.pj_per_bit(0).is_none());
    }

    #[test]
    fn refresh_energy_visible_on_long_windows() {
        let (t, idd) = spec();
        let e = channel_energy(&stats(0, 0, 0, 100), 624_000, SpeedBin::Ddr4_1600, &t, &idd);
        assert!(e.refresh_nj > 0.0);
        // 100 refreshes × (225-46)mA × 260ns × 4 × 1.2V
        let expected = 100.0 * 179.0 * 260.0 * 4.0 * 1.2 * 1e-3;
        assert!((e.refresh_nj - expected).abs() / expected < 1e-9);
    }
}
