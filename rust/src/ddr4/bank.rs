//! Per-bank state machine of the DDR4 device model.
//!
//! Each bank tracks its open row and the earliest cycle at which each
//! command class may legally be issued to it. The device layer
//! ([`super::device::DdrDevice`]) adds the cross-bank constraints
//! (tRRD/tFAW/tCCD/turnarounds/refresh).

use super::{invariant, Cycle};
use crate::ddr4::timing::TimingParams;

/// State of one DRAM bank.
#[derive(Debug, Clone, Copy)]
pub struct Bank {
    /// Currently open row, if any.
    pub open_row: Option<u32>,
    /// Issue time of the last ACT (for tRAS/tRC accounting).
    pub last_act: Cycle,
    /// Earliest cycle an ACT to this bank is legal (tRC after the previous
    /// ACT, tRP after a precharge, tRFC after refresh).
    pub earliest_act: Cycle,
    /// Earliest cycle a PRE to this bank is legal (tRAS after ACT,
    /// tRTP after a read, write recovery after a write).
    pub earliest_pre: Cycle,
    /// Earliest cycle a CAS (RD/WR) to this bank is legal (tRCD after ACT).
    pub earliest_cas: Cycle,
}

impl Default for Bank {
    fn default() -> Self {
        Self { open_row: None, last_act: 0, earliest_act: 0, earliest_pre: 0, earliest_cas: 0 }
    }
}

impl Bank {
    /// Does a CAS to `row` hit the open row?
    pub fn is_hit(&self, row: u32) -> bool {
        self.open_row == Some(row)
    }

    /// Is the bank closed (precharged)?
    pub fn is_closed(&self) -> bool {
        self.open_row.is_none()
    }

    /// Record an ACT at `now`.
    pub fn on_act(&mut self, row: u32, now: Cycle, t: &TimingParams) {
        invariant!(self.is_closed(), "ACT_OPEN_BANK: ACT to open bank");
        invariant!(
            now >= self.earliest_act,
            "tRC/tRP: ACT @{now} before bank gate @{}",
            self.earliest_act
        );
        self.open_row = Some(row);
        self.last_act = now;
        self.earliest_act = now + t.trc as Cycle;
        self.earliest_cas = now + t.trcd as Cycle;
        // tRAS lower-bounds the next PRE.
        self.earliest_pre = self.earliest_pre.max(now + t.tras as Cycle);
    }

    /// Record a PRE at `now`.
    pub fn on_pre(&mut self, now: Cycle, t: &TimingParams) {
        invariant!(
            now >= self.earliest_pre,
            "tRAS/tRTP/tWR: PRE @{now} before bank gate @{}",
            self.earliest_pre
        );
        self.open_row = None;
        // next ACT must honour both tRP from this PRE and tRC from last ACT
        self.earliest_act = self.earliest_act.max(now + t.trp as Cycle);
    }

    /// Record a read CAS at `now`. With `auto_pre`, the bank self-closes
    /// and the next ACT is gated by tRTP + tRP.
    pub fn on_rd(&mut self, now: Cycle, auto_pre: bool, t: &TimingParams) {
        invariant!(!self.is_closed(), "CAS_CLOSED_BANK: RD to closed bank");
        invariant!(
            now >= self.earliest_cas,
            "tRCD: RD @{now} before CAS gate @{}",
            self.earliest_cas
        );
        // A later PRE must wait tRTP after this read.
        self.earliest_pre = self.earliest_pre.max(now + t.rd_to_pre() as Cycle);
        if auto_pre {
            self.open_row = None;
            let implicit_pre = now + t.rd_to_pre().max(t.tras.saturating_sub(
                (now - self.last_act) as u32,
            )) as Cycle;
            self.earliest_act = self.earliest_act.max(implicit_pre + t.trp as Cycle);
        }
    }

    /// Record a write CAS at `now` (see [`Self::on_rd`]).
    pub fn on_wr(&mut self, now: Cycle, auto_pre: bool, t: &TimingParams) {
        invariant!(!self.is_closed(), "CAS_CLOSED_BANK: WR to closed bank");
        invariant!(
            now >= self.earliest_cas,
            "tRCD: WR @{now} before CAS gate @{}",
            self.earliest_cas
        );
        self.earliest_pre = self.earliest_pre.max(now + t.wr_to_pre() as Cycle);
        if auto_pre {
            self.open_row = None;
            let implicit_pre = now + t.wr_to_pre().max(t.tras.saturating_sub(
                (now - self.last_act) as u32,
            )) as Cycle;
            self.earliest_act = self.earliest_act.max(implicit_pre + t.trp as Cycle);
        }
    }

    /// Refresh completed at `now` (banks were all precharged before REF):
    /// no ACT until tRFC elapses.
    pub fn on_refresh(&mut self, now: Cycle, t: &TimingParams) {
        invariant!(self.is_closed(), "REF_OPEN_BANK: REF with open bank");
        self.earliest_act = self.earliest_act.max(now + t.trfc as Cycle);
    }

    /// Earliest cycle at which *some* command class could become legal
    /// on this bank given its open/closed state: an ACT when closed, a
    /// CAS or PRE when open. Cross-bank constraints (tRRD/tFAW/tCCD,
    /// bus turnarounds, tRFC) can only push the true legality later, so
    /// this is a safe lower bound — the per-bank wake hint behind
    /// [`super::device::DdrDevice::next_bank_actionable`].
    pub fn next_actionable(&self) -> Cycle {
        if self.is_closed() {
            self.earliest_act
        } else {
            self.earliest_cas.min(self.earliest_pre)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SpeedBin;

    fn t() -> TimingParams {
        TimingParams::for_bin(SpeedBin::Ddr4_1600)
    }

    #[test]
    fn act_opens_row_and_sets_gates() {
        let t = t();
        let mut b = Bank::default();
        b.on_act(42, 100, &t);
        assert!(b.is_hit(42));
        assert!(!b.is_hit(43));
        assert_eq!(b.earliest_cas, 100 + t.trcd as Cycle);
        assert_eq!(b.earliest_pre, 100 + t.tras as Cycle);
        assert_eq!(b.earliest_act, 100 + t.trc as Cycle);
    }

    #[test]
    fn pre_closes_and_gates_act_by_trp() {
        let t = t();
        let mut b = Bank::default();
        b.on_act(1, 0, &t);
        let pre_at = b.earliest_pre;
        b.on_pre(pre_at, &t);
        assert!(b.is_closed());
        // tRC from ACT@0 is 39; tRP from PRE@28 is 28+11=39: equal here.
        assert_eq!(b.earliest_act, (t.tras + t.trp) as Cycle);
    }

    #[test]
    fn read_extends_pre_gate_by_trtp() {
        let t = t();
        let mut b = Bank::default();
        b.on_act(1, 0, &t);
        let rd_at = b.earliest_cas + 20; // read late in the row's life
        b.on_rd(rd_at, false, &t);
        assert!(b.earliest_pre >= rd_at + t.rd_to_pre() as Cycle);
        assert!(b.is_hit(1), "non-auto-pre read keeps the row open");
    }

    #[test]
    fn write_recovery_gates_pre_longer_than_read() {
        let t = t();
        let (mut br, mut bw) = (Bank::default(), Bank::default());
        br.on_act(1, 0, &t);
        bw.on_act(1, 0, &t);
        let cas_at = br.earliest_cas;
        br.on_rd(cas_at, false, &t);
        bw.on_wr(cas_at, false, &t);
        assert!(bw.earliest_pre > br.earliest_pre, "tWR > tRTP");
    }

    #[test]
    fn auto_pre_closes_row_and_gates_next_act() {
        let t = t();
        let mut b = Bank::default();
        b.on_act(7, 0, &t);
        let rd_at = b.earliest_cas;
        b.on_rd(rd_at, true, &t);
        assert!(b.is_closed());
        // next ACT must respect the implicit precharge (≥ tRAS+tRP from ACT)
        assert!(b.earliest_act >= (t.tras + t.trp) as Cycle);
    }

    #[test]
    fn refresh_blocks_act_for_trfc() {
        let t = t();
        let mut b = Bank::default();
        b.on_refresh(1000, &t);
        assert_eq!(b.earliest_act, 1000 + t.trfc as Cycle);
    }

    #[test]
    fn next_actionable_follows_bank_state() {
        let t = t();
        let mut b = Bank::default();
        assert_eq!(b.next_actionable(), 0, "fresh closed bank: ACT now");
        b.on_act(1, 100, &t);
        // open bank: the CAS gate (tRCD) opens before the PRE gate (tRAS)
        assert_eq!(b.next_actionable(), 100 + t.trcd as Cycle);
        let cas_at = b.earliest_cas;
        b.on_rd(cas_at, false, &t);
        let pre_at = b.earliest_pre;
        b.on_pre(pre_at, &t);
        assert_eq!(b.next_actionable(), b.earliest_act, "closed again: ACT gate");
    }
}
