//! Minimal declarative CLI parser (in-tree replacement for clap —
//! DESIGN.md §9).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options
//! and positional arguments, with generated `--help` text. Used by the
//! `ddr4bench` binary and the examples.

use std::collections::BTreeMap;

/// Parsed argument bag for one (sub)command invocation.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Subcommand name, if any.
    pub command: Option<String>,
    /// `--key value` options.
    pub options: BTreeMap<String, String>,
    /// Repeatable `--key value` options, in the order given.
    pub multi: BTreeMap<String, Vec<String>>,
    /// Bare `--flag`s.
    pub flags: Vec<String>,
    /// Positional arguments.
    pub positional: Vec<String>,
}

impl Args {
    /// Option value by key.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    /// All values of a repeatable option, in the order given (empty when
    /// absent).
    pub fn get_multi(&self, key: &str) -> &[String] {
        self.multi.get(key).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Option value or default.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Parse an option into any `FromStr` type, with default.
    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: cannot parse `{v}`")),
        }
    }

    /// Is `--flag` present?
    pub fn has_flag(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

/// Declared option/flag for help text.
#[derive(Debug, Clone)]
pub struct OptSpec {
    /// Key (without dashes).
    pub key: &'static str,
    /// Does it take a value?
    pub takes_value: bool,
    /// May it be given more than once? (Values collect into
    /// [`Args::multi`] in order.)
    pub repeatable: bool,
    /// One-line description.
    pub help: &'static str,
}

/// A CLI definition: name, about, subcommands, shared options.
pub struct Cli {
    name: &'static str,
    about: &'static str,
    commands: Vec<(&'static str, &'static str)>,
    options: Vec<OptSpec>,
}

impl Cli {
    /// New CLI definition.
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, commands: Vec::new(), options: Vec::new() }
    }

    /// Register a subcommand.
    pub fn command(mut self, name: &'static str, help: &'static str) -> Self {
        self.commands.push((name, help));
        self
    }

    /// Register a `--key <value>` option.
    pub fn option(mut self, key: &'static str, help: &'static str) -> Self {
        self.options.push(OptSpec { key, takes_value: true, repeatable: false, help });
        self
    }

    /// Register a repeatable `--key <value>` option (give it several
    /// times; values collect in order).
    pub fn multi(mut self, key: &'static str, help: &'static str) -> Self {
        self.options.push(OptSpec { key, takes_value: true, repeatable: true, help });
        self
    }

    /// Register a bare `--flag`.
    pub fn flag(mut self, key: &'static str, help: &'static str) -> Self {
        self.options.push(OptSpec { key, takes_value: false, repeatable: false, help });
        self
    }

    /// Render help text.
    pub fn help(&self) -> String {
        let mut s = format!(
            "{} — {}\n\nUSAGE:\n  {} [COMMAND] [OPTIONS]\n",
            self.name, self.about, self.name
        );
        if !self.commands.is_empty() {
            s.push_str("\nCOMMANDS:\n");
            for (c, h) in &self.commands {
                s.push_str(&format!("  {c:<18} {h}\n"));
            }
        }
        if !self.options.is_empty() {
            s.push_str("\nOPTIONS:\n");
            for o in &self.options {
                let k = if o.takes_value && o.repeatable {
                    format!("--{} <v>..", o.key)
                } else if o.takes_value {
                    format!("--{} <v>", o.key)
                } else {
                    format!("--{}", o.key)
                };
                s.push_str(&format!("  {k:<18} {}\n", o.help));
            }
        }
        s.push_str("  --help             print this help\n");
        s
    }

    /// Parse an argv slice (without argv[0]). `Err` carries a message that
    /// should be printed (includes help for `--help`).
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        // subcommand = first non-dash token if declared
        if let Some(first) = it.peek() {
            if !first.starts_with('-') && self.commands.iter().any(|(c, _)| *c == first.as_str()) {
                args.command = Some(it.next().expect("peeked above").clone());
            }
        }
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(self.help());
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (key, inline) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self.options.iter().find(|o| o.key == key);
                match spec {
                    Some(OptSpec { takes_value: true, repeatable, .. }) => {
                        let val = match inline {
                            Some(v) => v,
                            None => it
                                .next()
                                .ok_or_else(|| format!("--{key} expects a value"))?
                                .clone(),
                        };
                        if *repeatable {
                            args.multi.entry(key).or_default().push(val);
                        } else {
                            args.options.insert(key, val);
                        }
                    }
                    Some(OptSpec { takes_value: false, .. }) => {
                        if inline.is_some() {
                            return Err(format!("--{key} takes no value"));
                        }
                        args.flags.push(key);
                    }
                    None => return Err(format!("unknown option --{key}\n\n{}", self.help())),
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test tool")
            .command("run", "run things")
            .option("speed", "data rate")
            .multi("ch", "per-channel spec")
            .flag("verbose", "chatty")
    }

    fn v(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags_positionals() {
        let a = cli().parse(&v(&["run", "--speed", "2400", "--verbose", "file.txt"])).unwrap();
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.get("speed"), Some("2400"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["file.txt"]);
    }

    #[test]
    fn equals_syntax() {
        let a = cli().parse(&v(&["--speed=1600"])).unwrap();
        assert_eq!(a.get("speed"), Some("1600"));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cli().parse(&v(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cli().parse(&v(&["--speed"])).is_err());
    }

    #[test]
    fn help_flag_returns_help() {
        let err = cli().parse(&v(&["--help"])).unwrap_err();
        assert!(err.contains("USAGE"));
        assert!(err.contains("run"));
    }

    #[test]
    fn parse_or_types() {
        let a = cli().parse(&v(&["--speed", "2400"])).unwrap();
        assert_eq!(a.parse_or("speed", 0u32).unwrap(), 2400);
        assert_eq!(a.parse_or("missing", 7u32).unwrap(), 7);
        let b = cli().parse(&v(&["--speed", "abc"])).unwrap();
        assert!(b.parse_or("speed", 0u32).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(cli().parse(&v(&["--verbose=1"])).is_err());
    }

    #[test]
    fn repeatable_option_collects_in_order() {
        let a = cli().parse(&v(&["--ch", "0:SEQ", "--ch=1:RND", "--ch", "2:BANK"])).unwrap();
        assert_eq!(a.get_multi("ch").to_vec(), vec!["0:SEQ", "1:RND", "2:BANK"]);
        assert_eq!(a.get("ch"), None, "repeatable values stay out of the scalar map");
        assert!(cli().parse(&v(&[])).unwrap().get_multi("ch").is_empty());
        // last-wins still holds for scalar options
        let a = cli().parse(&v(&["--speed", "1600", "--speed", "2400"])).unwrap();
        assert_eq!(a.get("speed"), Some("2400"));
        // help marks repeatables
        let help = cli().parse(&v(&["--help"])).unwrap_err();
        assert!(help.contains("--ch <v>.."), "{help}");
    }
}
