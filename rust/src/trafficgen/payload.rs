//! Payload generation and verification (§II-B, "data generation side").
//!
//! Unlike Shuhai — which writes constant zeros — the paper's TGs "generate
//! various sequences of non-zero data and can check the correctness of
//! read data against the previously written one". The data path here:
//!
//! 1. every 64-byte DRAM burst gets a 32-bit **seed** derived from its
//!    byte address and the pattern seed ([`burst_seed`]);
//! 2. the seed is expanded to the burst's 16 data words by 16 xorshift32
//!    steps ([`expand_burst`]) — this expansion is the compute hot-spot
//!    and is exactly what the Pallas kernel
//!    (`python/compile/kernels/prbs.py`) implements, so whole batches can
//!    be generated/verified with one AOT-compiled XLA call from
//!    [`crate::runtime`];
//! 3. verification recomputes the expansion and counts mismatching words.
//!
//! Seeding per *burst address* (not per transaction) is what makes mixed
//! read/write workloads verifiable: any read can reconstruct the expected
//! contents of the bursts it covers regardless of which write transaction
//! produced them.

use crate::config::DataPattern;
use crate::rng::Xorshift32;

/// 32-bit data words per 64-byte DRAM burst.
pub const WORDS_PER_BURST: usize = 16;

/// Derive the non-zero PRBS seed of the burst at `burst_addr` (byte
/// address, 64-aligned) under pattern seed `pattern_seed`.
///
/// The hash must be cheap in RTL terms (xor/shift/multiply) and match the
/// Python reference (`kernels/ref.py::burst_seed`) bit-for-bit.
pub fn burst_seed(burst_addr: u64, pattern_seed: u32) -> u32 {
    let idx = (burst_addr >> 6) as u32; // burst index
    // xorshift-multiply mix (Murmur3 finalizer style), then non-zero remap.
    let mut h = idx ^ pattern_seed.rotate_left(16);
    h ^= h >> 16;
    h = h.wrapping_mul(0x85EB_CA6B);
    h ^= h >> 13;
    h = h.wrapping_mul(0xC2B2_AE35);
    h ^= h >> 16;
    if h == 0 {
        0x9E37_79B9
    } else {
        h
    }
}

/// Expand a burst seed into its 16 payload words (xorshift32 stream).
pub fn expand_burst(seed: u32) -> [u32; WORDS_PER_BURST] {
    let mut g = Xorshift32::new(seed);
    let mut out = [0u32; WORDS_PER_BURST];
    g.fill(&mut out);
    out
}

/// Expected contents of a burst under `pattern` (what the TG writes and
/// what read-back verification compares against).
pub fn burst_payload(burst_addr: u64, pattern: DataPattern) -> [u32; WORDS_PER_BURST] {
    match pattern {
        DataPattern::Prbs { seed } => expand_burst(burst_seed(burst_addr, seed)),
        DataPattern::Zeros => [0u32; WORDS_PER_BURST],
        DataPattern::Constant(w) => [w; WORDS_PER_BURST],
    }
}

/// Count mismatching words between expected and observed burst contents.
pub fn verify_burst(expected: &[u32; WORDS_PER_BURST], got: &[u32; WORDS_PER_BURST]) -> u32 {
    expected.iter().zip(got.iter()).filter(|(a, b)| a != b).count() as u32
}

/// Batch-expand many seeds into a flat word buffer (`seeds.len() * 16`
/// words). This is the pure-Rust mirror of the `datagen` XLA artifact; the
/// integration suite asserts both produce identical buffers.
pub fn expand_batch(seeds: &[u32]) -> Vec<u32> {
    let mut out = Vec::with_capacity(seeds.len() * WORDS_PER_BURST);
    for &s in seeds {
        out.extend_from_slice(&expand_burst(s));
    }
    out
}

/// Batch-verify: mismatch count between `expand_batch(seeds)` and `data`.
/// Pure-Rust mirror of the `verify` XLA artifact.
pub fn verify_batch(seeds: &[u32], data: &[u32]) -> u64 {
    assert_eq!(data.len(), seeds.len() * WORDS_PER_BURST, "data/seed length mismatch");
    let mut mismatches = 0u64;
    for (i, &s) in seeds.iter().enumerate() {
        let exp = expand_burst(s);
        let got = &data[i * WORDS_PER_BURST..(i + 1) * WORDS_PER_BURST];
        mismatches += exp.iter().zip(got).filter(|(a, b)| a != b).count() as u64;
    }
    mismatches
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_seed_nonzero_and_deterministic() {
        for addr in (0..(1u64 << 16)).step_by(64) {
            let s = burst_seed(addr, 1);
            assert_ne!(s, 0);
            assert_eq!(s, burst_seed(addr, 1));
        }
    }

    #[test]
    fn burst_seed_varies_with_addr_and_seed() {
        let a = burst_seed(0, 1);
        let b = burst_seed(64, 1);
        let c = burst_seed(0, 2);
        assert_ne!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn burst_seed_pinned_values() {
        // Pinned constants shared with python/tests/test_kernels.py — if
        // either side changes the hash, this catches it.
        assert_eq!(burst_seed(0, 1), 245581154);
        assert_eq!(burst_seed(64, 1), 3665349440);
        assert_eq!(burst_seed(4096, 7), 2593156092);
    }

    #[test]
    fn expand_is_xorshift_stream() {
        let w = expand_burst(1);
        assert_eq!(w[0], 270369);
        assert_eq!(w[1], 67634689);
        assert!(w.iter().all(|&x| x != 0), "non-zero data requirement");
    }

    #[test]
    fn payload_patterns() {
        assert_eq!(burst_payload(0, DataPattern::Zeros), [0u32; 16]);
        assert_eq!(burst_payload(0, DataPattern::Constant(0xA5)), [0xA5; 16]);
        let p = burst_payload(128, DataPattern::Prbs { seed: 1 });
        assert_eq!(p, expand_burst(burst_seed(128, 1)));
    }

    #[test]
    fn verify_counts_word_mismatches() {
        let exp = expand_burst(42);
        let mut got = exp;
        assert_eq!(verify_burst(&exp, &got), 0);
        got[3] ^= 1;
        got[15] ^= 0xFFFF;
        assert_eq!(verify_burst(&exp, &got), 2);
    }

    #[test]
    fn batch_expand_matches_scalar() {
        let seeds = [1u32, 42, 0xDEADBEEF];
        let buf = expand_batch(&seeds);
        assert_eq!(buf.len(), 48);
        for (i, &s) in seeds.iter().enumerate() {
            assert_eq!(&buf[i * 16..(i + 1) * 16], &expand_burst(s));
        }
    }

    #[test]
    fn batch_verify_zero_on_clean_and_counts_faults() {
        let seeds = [7u32, 8, 9];
        let mut data = expand_batch(&seeds);
        assert_eq!(verify_batch(&seeds, &data), 0);
        data[0] ^= 1;
        data[47] ^= 1;
        assert_eq!(verify_batch(&seeds, &data), 2);
    }

    #[test]
    #[should_panic]
    fn batch_verify_rejects_length_mismatch() {
        verify_batch(&[1, 2], &[0u32; 16]);
    }
}
