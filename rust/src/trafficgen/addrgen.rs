//! Address generation for the traffic generator (§II-B, "address
//! generation side") — the run-time access-pattern engine.
//!
//! Modes, selected at run time (see [`AddrMode`]):
//!
//! - **Sequential** — consecutive transactions target consecutive,
//!   transaction-sized strides of the test region, wrapping at its end.
//! - **Random** — each transaction targets a uniformly random, aligned
//!   offset of the region (reproducible via the pattern seed).
//! - **Strided** — each transaction advances a fixed byte stride
//!   (rounded up to the transaction alignment), wrapping in the region.
//! - **BankConflict** — successive transactions hit the *same* DRAM bank
//!   in *different* rows. The stream is derived from the channel geometry
//!   and its active [`MappingPolicy`](crate::ddr4::MappingPolicy): a
//!   seed-picked base address is decoded into a DRAM coordinate, then the
//!   row coordinate advances while the bank and column stay pinned and
//!   each step is re-encoded through the policy. The pin survives even
//!   XOR-hashed mappings: exactly for single-burst transactions, and via
//!   fold-period row stepping for wider spans (whose alignment mask
//!   would otherwise strip the swizzle bits) — a guaranteed row miss
//!   with zero bank-level parallelism.
//! - **PointerChase** — a dependent walk over a working set: slot
//!   `s_{n+1} = (a * s_n + c) mod m` with `m` a power of two, `a ≡ 1
//!   (mod 4)` and `c` odd, which by Hull–Dobell has full period `m` — the
//!   chase visits every slot of the working set exactly once per cycle.
//!   The slot→address assignment composes an odd multiplier derived from
//!   the mapping policy's row stride, so dependent hops keep crossing row
//!   boundaries under whichever address mapping is active.
//! - **Phased** — runs each inner mode for its transaction count,
//!   cycling through the phase list.
//!
//! Addresses are aligned to the transaction span rounded up to a power of
//! two, which (a) keeps INCR bursts inside a 4 KiB page as AXI requires,
//! and (b) burst-aligns every access the way the RTL generator does.

use crate::config::{AddrMode, BurstKind, BurstSpec};
use crate::ddr4::geometry::DramGeometry;
use crate::rng::SplitMix64;

/// Full-period LCG multiplier for the pointer chase (`mod 4 == 1`, so the
/// Hull–Dobell conditions hold for every power-of-two modulus).
const CHASE_MUL: u64 = 0x9E37_79B9_7F4A_7C15;

/// Deterministic per-direction address source.
#[derive(Debug, Clone)]
pub struct AddrGen {
    start: u64,
    region: u64,
    align: u64,
    kind: Kind,
}

#[derive(Debug, Clone)]
enum Kind {
    Seq {
        next_off: u64,
    },
    Rnd {
        rng: SplitMix64,
    },
    Strided {
        next_off: u64,
        /// Stride in alignment slots (>= 1).
        step: u64,
    },
    Bank {
        /// Geometry (with its active mapping policy) the stream is
        /// re-encoded through on every step.
        geo: DramGeometry,
        /// Pinned flat bank index (seed-derived via the base decode).
        bank: u32,
        /// Pinned column address.
        col: u32,
        /// Row increment per transaction (> 1 when the transaction
        /// alignment spans multiple row steps).
        kstep: u64,
        /// Distinct row points the stream cycles through.
        m: u64,
        idx: u64,
    },
    Chase {
        cur: u64,
        /// Odd increment of the full-period LCG.
        inc: u64,
        /// `slots - 1` for the power-of-two slot count.
        mask: u64,
        /// Odd slot multiplier derived from the mapping policy's row
        /// stride (an align-preserving permutation of the working set).
        spread: u64,
    },
    Phased {
        gens: Vec<(AddrGen, u32)>,
        idx: usize,
        left: u32,
    },
}

/// Alignment for a transaction: its byte span rounded up to a power of two
/// (minimum one DRAM burst, 64 B).
pub fn txn_alignment(burst: BurstSpec, beat_bytes: u32) -> u64 {
    let span = match burst.kind {
        BurstKind::Fixed => beat_bytes as u64,
        _ => burst.len as u64 * beat_bytes as u64,
    };
    span.next_power_of_two().max(64)
}

impl AddrGen {
    /// Build an address generator for one direction of a pattern. The
    /// DRAM geometry parameterizes the bank-conflict mode (other modes
    /// ignore it).
    pub fn new(
        mode: &AddrMode,
        start: u64,
        region: u64,
        burst: BurstSpec,
        beat_bytes: u32,
        geo: &DramGeometry,
    ) -> Self {
        let align = txn_alignment(burst, beat_bytes);
        let region = region.max(align); // at least one slot
        let kind = match mode {
            AddrMode::Sequential => Kind::Seq { next_off: 0 },
            AddrMode::Random { seed } => Kind::Rnd { rng: SplitMix64::new(*seed) },
            AddrMode::Strided { stride } => {
                // div_ceil: round the byte stride up to whole alignment
                // slots without overflowing on huge strides.
                let step = stride.div_ceil(align).max(1);
                Kind::Strided { next_off: 0, step }
            }
            AddrMode::BankConflict { seed } => {
                // Same bank, next row — derived from the active mapping
                // policy. The seed picks an aligned base inside the first
                // row-step window; its decode pins the bank and column,
                // and each transaction re-encodes with the row advanced.
                let row_step = geo.row_step_bytes().max(64);
                let base_slots = (region.min(row_step) / align).max(1);
                let base = (SplitMix64::new(*seed).below(base_slots)) * align;
                let coord = geo.decode(base);
                let mut kstep = (align / row_step).max(1);
                if geo.mapping.is_xor_hashed() && align > geo.burst_bytes() as u64 {
                    // Transactions wider than one DRAM burst get their
                    // low (bank-swizzle) bits cleared by the alignment
                    // mask below; stepping rows in whole fold periods
                    // keeps the XOR fold constant so the masked stream
                    // still pins a single bank.
                    kstep = kstep.max(geo.banks() as u64);
                }
                let m = (region / (row_step * kstep)).min(geo.rows as u64 / kstep).max(1);
                Kind::Bank { geo: *geo, bank: coord.bank, col: coord.col, kstep, m, idx: 0 }
            }
            AddrMode::PointerChase { seed, working_set } => {
                let ws_slots = ((*working_set).min(region) / align).max(1);
                // largest power of two <= ws_slots
                let slots = (ws_slots + 1).next_power_of_two() / 2;
                let mask = slots - 1;
                Kind::Chase {
                    cur: (seed >> 8) & mask,
                    inc: (seed | 1) & mask.max(1),
                    mask,
                    spread: (geo.row_step_bytes() / align) | 1,
                }
            }
            AddrMode::Phased(phases) => {
                // `PatternConfig::validate` rejects empty lists and zero
                // counts at the config boundary; as a plain constructor
                // this clamps instead of panicking (empty -> sequential,
                // zero-count phases -> one transaction).
                let gens: Vec<(AddrGen, u32)> = phases
                    .iter()
                    .map(|(m, n)| {
                        (AddrGen::new(m, start, region, burst, beat_bytes, geo), (*n).max(1))
                    })
                    .collect();
                match gens.first().map(|(_, n)| *n) {
                    Some(left) => Kind::Phased { gens, idx: 0, left },
                    None => Kind::Seq { next_off: 0 },
                }
            }
        };
        Self { start: start & !(align - 1), region, align, kind }
    }

    /// Number of aligned transaction slots in the region.
    pub fn slots(&self) -> u64 {
        self.region / self.align
    }

    /// Next transaction start address.
    pub fn next_addr(&mut self) -> u64 {
        let slots = self.region / self.align;
        let (start, align) = (self.start, self.align);
        match &mut self.kind {
            Kind::Seq { next_off } => {
                let s = *next_off;
                *next_off = (s + 1) % slots;
                start + s * align
            }
            Kind::Rnd { rng } => start + rng.below(slots) * align,
            Kind::Strided { next_off, step } => {
                let s = *next_off;
                *next_off = (s + *step) % slots;
                start + s * align
            }
            Kind::Bank { geo, bank, col, kstep, m, idx } => {
                let row = (*idx * *kstep) as u32;
                *idx = (*idx + 1) % *m;
                let a = geo.encode(crate::ddr4::DramAddr { bank: *bank, row, col: *col });
                start + (a & !(align - 1))
            }
            Kind::Chase { cur, inc, mask, spread } => {
                let s = *cur;
                *cur = cur.wrapping_mul(CHASE_MUL).wrapping_add(*inc) & *mask;
                start + (s.wrapping_mul(*spread) & *mask) * align
            }
            Kind::Phased { gens, idx, left } => {
                let addr = gens[*idx].0.next_addr();
                *left -= 1;
                if *left == 0 {
                    *idx = (*idx + 1) % gens.len();
                    *left = gens[*idx].1;
                }
                addr
            }
        }
    }

    /// Alignment in force (bytes).
    pub fn alignment(&self) -> u64 {
        self.align
    }

    /// For the pointer-chase mode: the (power-of-two) number of distinct
    /// slots the chase cycles through. `None` for other modes.
    pub fn chase_slots(&self) -> Option<u64> {
        match &self.kind {
            Kind::Chase { mask, .. } => Some(mask + 1),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BurstKind;

    fn incr(len: u32) -> BurstSpec {
        BurstSpec { len, kind: BurstKind::Incr }
    }

    fn geo() -> DramGeometry {
        DramGeometry::profpga_board()
    }

    fn gen(mode: AddrMode, start: u64, region: u64, len: u32) -> AddrGen {
        AddrGen::new(&mode, start, region, incr(len), 32, &geo())
    }

    #[test]
    fn alignment_rounds_to_pow2_min_64() {
        assert_eq!(txn_alignment(incr(1), 32), 64); // 32 B span -> 64 B floor
        assert_eq!(txn_alignment(incr(4), 32), 128);
        assert_eq!(txn_alignment(incr(32), 32), 1024);
        assert_eq!(txn_alignment(incr(128), 32), 4096);
        assert_eq!(txn_alignment(incr(3), 32), 128); // 96 -> 128
        assert_eq!(txn_alignment(BurstSpec { len: 8, kind: BurstKind::Fixed }, 32), 64);
    }

    #[test]
    fn sequential_strides_and_wraps() {
        let mut g = gen(AddrMode::Sequential, 0, 256, 1);
        // 4 slots of 64 B
        let a: Vec<u64> = (0..6).map(|_| g.next_addr()).collect();
        assert_eq!(a, vec![0, 64, 128, 192, 0, 64]);
    }

    #[test]
    fn sequential_honours_start() {
        let mut g = gen(AddrMode::Sequential, 1 << 20, 256, 1);
        assert_eq!(g.next_addr(), 1 << 20);
        assert_eq!(g.next_addr(), (1 << 20) + 64);
    }

    #[test]
    fn random_stays_aligned_and_in_region() {
        let mut g = gen(AddrMode::Random { seed: 9 }, 4096, 1 << 20, 4);
        for _ in 0..10_000 {
            let a = g.next_addr();
            assert_eq!(a % 128, 0, "alignment");
            assert!(a >= 4096 && a < 4096 + (1 << 20));
        }
    }

    #[test]
    fn random_reproducible_by_seed() {
        let mut a = gen(AddrMode::Random { seed: 5 }, 0, 1 << 20, 1);
        let mut b = gen(AddrMode::Random { seed: 5 }, 0, 1 << 20, 1);
        for _ in 0..100 {
            assert_eq!(a.next_addr(), b.next_addr());
        }
        let mut c = gen(AddrMode::Random { seed: 6 }, 0, 1 << 20, 1);
        let same = (0..100).all(|_| a.next_addr() == c.next_addr());
        assert!(!same, "different seeds should diverge");
    }

    #[test]
    fn random_covers_many_slots() {
        let mut g = gen(AddrMode::Random { seed: 1 }, 0, 1 << 16, 1);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..4096 {
            seen.insert(g.next_addr());
        }
        // 1024 slots; uniform sampling should touch most of them
        assert!(seen.len() > 900, "saw only {} distinct slots", seen.len());
    }

    #[test]
    fn tiny_region_clamps_to_one_slot() {
        let mut g = gen(AddrMode::Sequential, 0, 32, 1);
        assert_eq!(g.slots(), 1);
        assert_eq!(g.next_addr(), 0);
        assert_eq!(g.next_addr(), 0);
    }

    #[test]
    fn strided_advances_by_stride_and_wraps() {
        // 4 KiB stride over a 16 KiB region, 64 B slots: offsets 0, 4096,
        // 8192, 12288, then wrap to 0.
        let mut g = gen(AddrMode::Strided { stride: 4096 }, 0, 16 << 10, 1);
        let a: Vec<u64> = (0..5).map(|_| g.next_addr()).collect();
        assert_eq!(a, vec![0, 4096, 8192, 12288, 0]);
    }

    #[test]
    fn strided_survives_huge_stride() {
        // u64::MAX stride must neither overflow nor panic; it just walks
        // some in-region cycle.
        let mut g = gen(AddrMode::Strided { stride: u64::MAX }, 0, 1 << 20, 1);
        for _ in 0..16 {
            let a = g.next_addr();
            assert!(a < 1 << 20);
            assert_eq!(a % 64, 0);
        }
    }

    #[test]
    fn strided_rounds_stride_up_to_alignment() {
        // stride 100 with 64 B alignment -> 2 slots = 128 B
        let mut g = gen(AddrMode::Strided { stride: 100 }, 0, 1 << 10, 1);
        assert_eq!(g.next_addr(), 0);
        assert_eq!(g.next_addr(), 128);
    }

    #[test]
    fn bank_conflict_same_bank_new_row_every_txn() {
        let geometry = geo();
        let mut g = gen(AddrMode::BankConflict { seed: 7 }, 0, 64 << 20, 1);
        let addrs: Vec<u64> = (0..64).map(|_| g.next_addr()).collect();
        let first = geometry.decode(addrs[0]);
        for w in addrs.windows(2) {
            let (a, b) = (geometry.decode(w[0]), geometry.decode(w[1]));
            assert_eq!(a.bank, first.bank, "stream stays on one bank");
            assert_eq!(b.bank, first.bank);
            assert_ne!(a.row, b.row, "every transaction opens a new row");
        }
        for &a in &addrs {
            assert!(a < 64 << 20, "inside region");
            assert_eq!(a % 64, 0, "burst aligned");
        }
    }

    #[test]
    fn bank_conflict_pins_bank_under_every_mapping_policy() {
        use crate::ddr4::MappingPolicy;
        let mut policies = MappingPolicy::builtins().to_vec();
        policies.push(MappingPolicy::parse("RoBaBgCo").unwrap());
        for mapping in policies {
            let mut geometry = geo();
            geometry.mapping = mapping;
            let mut g = AddrGen::new(
                &AddrMode::BankConflict { seed: 3 },
                0,
                64 << 20,
                incr(1),
                32,
                &geometry,
            );
            let addrs: Vec<u64> = (0..64).map(|_| g.next_addr()).collect();
            let first = geometry.decode(addrs[0]);
            for w in addrs.windows(2) {
                let (a, b) = (geometry.decode(w[0]), geometry.decode(w[1]));
                assert_eq!(a.bank, first.bank, "{mapping}: bank pinned");
                assert_eq!(b.bank, first.bank, "{mapping}: bank pinned");
                assert_ne!(a.row, b.row, "{mapping}: fresh row each txn");
            }
            for &a in &addrs {
                assert!(a < 64 << 20, "{mapping}: inside region");
                assert_eq!(a % 64, 0, "{mapping}: burst aligned");
            }
        }
    }

    #[test]
    fn bank_conflict_pins_bank_under_xor_hash_with_wide_transactions() {
        // burst 32 x 32 B beats = 1 KiB alignment: the mask strips the
        // XOR swizzle bits, so the generator must step rows in whole
        // fold periods to keep the decoded bank constant.
        use crate::ddr4::MappingPolicy;
        let mut geometry = geo();
        geometry.mapping = MappingPolicy::xor_hash();
        let mut g = AddrGen::new(
            &AddrMode::BankConflict { seed: 9 },
            0,
            64 << 20,
            incr(32),
            32,
            &geometry,
        );
        let addrs: Vec<u64> = (0..64).map(|_| g.next_addr()).collect();
        let first = geometry.decode(addrs[0]);
        for w in addrs.windows(2) {
            let (a, b) = (geometry.decode(w[0]), geometry.decode(w[1]));
            assert_eq!(a.bank, first.bank, "bank pinned under masked xor stream");
            assert_eq!(b.bank, first.bank);
            assert_ne!(a.row, b.row, "fresh row each txn");
        }
        for &a in &addrs {
            assert!(a < 64 << 20);
            assert_eq!(a % 1024, 0, "txn-span aligned");
        }
    }

    #[test]
    fn bank_conflict_seed_selects_different_banks() {
        let geometry = geo();
        let banks: std::collections::HashSet<u32> = (0..32)
            .map(|seed| {
                let mut g = gen(AddrMode::BankConflict { seed }, 0, 64 << 20, 1);
                geometry.decode(g.next_addr()).bank
            })
            .collect();
        assert!(banks.len() > 1, "seeds should reach more than one bank");
    }

    #[test]
    fn pointer_chase_visits_whole_working_set() {
        // 64 KiB working set, 64 B slots -> 1024 slots (power of two).
        let ws = 64 << 10;
        let mut g = gen(AddrMode::PointerChase { seed: 42, working_set: ws }, 0, 1 << 20, 1);
        let slots = g.chase_slots().unwrap();
        assert_eq!(slots, 1024);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..slots {
            let a = g.next_addr();
            assert!(a < ws, "chase stays inside the working set");
            assert_eq!(a % 64, 0);
            assert!(seen.insert(a), "full-period chase never revisits early");
        }
        assert_eq!(seen.len() as u64, slots, "every slot visited once per cycle");
    }

    #[test]
    fn pointer_chase_non_pow2_working_set_rounds_down() {
        // 3000 slots -> 2048
        let g = gen(AddrMode::PointerChase { seed: 1, working_set: 3000 * 64 }, 0, 1 << 30, 1);
        assert_eq!(g.chase_slots(), Some(2048));
    }

    #[test]
    fn pointer_chase_deterministic_per_seed() {
        let mk = |seed| gen(AddrMode::PointerChase { seed, working_set: 1 << 16 }, 0, 1 << 20, 1);
        let (mut a, mut b, mut c) = (mk(3), mk(3), mk(4));
        let mut diverged = false;
        for _ in 0..200 {
            let (x, y) = (a.next_addr(), b.next_addr());
            assert_eq!(x, y);
            diverged |= x != c.next_addr();
        }
        assert!(diverged, "different seeds should give different chases");
    }

    #[test]
    fn degenerate_phased_lists_clamp_instead_of_panicking() {
        // invalid at the config boundary, but the bare constructor must
        // stay total: empty list behaves sequentially, zero counts as 1
        let mut empty = gen(AddrMode::Phased(vec![]), 0, 1 << 10, 1);
        assert_eq!(empty.next_addr(), 0);
        assert_eq!(empty.next_addr(), 64);
        let mut zero = gen(
            AddrMode::Phased(vec![
                (AddrMode::Sequential, 0),
                (AddrMode::Strided { stride: 128 }, 1),
            ]),
            0,
            1 << 10,
            1,
        );
        for _ in 0..8 {
            let a = zero.next_addr();
            assert!(a < 1 << 10);
        }
    }

    #[test]
    fn phased_concatenates_inner_streams() {
        let mode = AddrMode::Phased(vec![
            (AddrMode::Sequential, 3),
            (AddrMode::Strided { stride: 128 }, 2),
        ]);
        let mut g = gen(mode, 0, 1 << 10, 1);
        let got: Vec<u64> = (0..7).map(|_| g.next_addr()).collect();
        // 3 sequential (0,64,128), 2 strided (0,128), then back to the
        // sequential phase where it left off (192, 256).
        assert_eq!(got, vec![0, 64, 128, 0, 128, 192, 256]);
    }
}
