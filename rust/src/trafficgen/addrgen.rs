//! Address generation for the traffic generator (§II-B, "address
//! generation side").
//!
//! Two modes, selected at run time:
//!
//! - **Sequential** — consecutive transactions target consecutive,
//!   transaction-sized strides of the test region, wrapping at its end.
//! - **Random** — each transaction targets a uniformly random, aligned
//!   offset of the region (reproducible via the pattern seed).
//!
//! Addresses are aligned to the transaction span rounded up to a power of
//! two, which (a) keeps INCR bursts inside a 4 KiB page as AXI requires,
//! and (b) burst-aligns every access the way the RTL generator does.

use crate::config::{AddrMode, BurstKind, BurstSpec};
use crate::rng::SplitMix64;

/// Deterministic per-direction address source.
#[derive(Debug, Clone)]
pub struct AddrGen {
    start: u64,
    region: u64,
    align: u64,
    kind: Kind,
}

#[derive(Debug, Clone)]
enum Kind {
    Seq { next_off: u64 },
    Rnd { rng: SplitMix64 },
}

/// Alignment for a transaction: its byte span rounded up to a power of two
/// (minimum one DRAM burst, 64 B).
pub fn txn_alignment(burst: BurstSpec, beat_bytes: u32) -> u64 {
    let span = match burst.kind {
        BurstKind::Fixed => beat_bytes as u64,
        _ => burst.len as u64 * beat_bytes as u64,
    };
    span.next_power_of_two().max(64)
}

impl AddrGen {
    /// Build an address generator for one direction of a pattern.
    pub fn new(mode: AddrMode, start: u64, region: u64, burst: BurstSpec, beat_bytes: u32) -> Self {
        let align = txn_alignment(burst, beat_bytes);
        let region = region.max(align); // at least one slot
        let kind = match mode {
            AddrMode::Sequential => Kind::Seq { next_off: 0 },
            AddrMode::Random { seed } => Kind::Rnd { rng: SplitMix64::new(seed) },
        };
        Self { start: start & !(align - 1), region, align, kind }
    }

    /// Number of aligned transaction slots in the region.
    pub fn slots(&self) -> u64 {
        self.region / self.align
    }

    /// Next transaction start address.
    pub fn next_addr(&mut self) -> u64 {
        let slots = self.slots();
        let slot = match &mut self.kind {
            Kind::Seq { next_off } => {
                let s = *next_off;
                *next_off = (*next_off + 1) % slots;
                s
            }
            Kind::Rnd { rng } => rng.below(slots),
        };
        self.start + slot * self.align
    }

    /// Alignment in force (bytes).
    pub fn alignment(&self) -> u64 {
        self.align
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BurstKind;

    fn incr(len: u32) -> BurstSpec {
        BurstSpec { len, kind: BurstKind::Incr }
    }

    #[test]
    fn alignment_rounds_to_pow2_min_64() {
        assert_eq!(txn_alignment(incr(1), 32), 64); // 32 B span -> 64 B floor
        assert_eq!(txn_alignment(incr(4), 32), 128);
        assert_eq!(txn_alignment(incr(32), 32), 1024);
        assert_eq!(txn_alignment(incr(128), 32), 4096);
        assert_eq!(txn_alignment(incr(3), 32), 128); // 96 -> 128
        assert_eq!(txn_alignment(BurstSpec { len: 8, kind: BurstKind::Fixed }, 32), 64);
    }

    #[test]
    fn sequential_strides_and_wraps() {
        let mut g = AddrGen::new(AddrMode::Sequential, 0, 256, incr(1), 32);
        // 4 slots of 64 B
        let a: Vec<u64> = (0..6).map(|_| g.next_addr()).collect();
        assert_eq!(a, vec![0, 64, 128, 192, 0, 64]);
    }

    #[test]
    fn sequential_honours_start() {
        let mut g = AddrGen::new(AddrMode::Sequential, 1 << 20, 256, incr(1), 32);
        assert_eq!(g.next_addr(), 1 << 20);
        assert_eq!(g.next_addr(), (1 << 20) + 64);
    }

    #[test]
    fn random_stays_aligned_and_in_region() {
        let mut g = AddrGen::new(AddrMode::Random { seed: 9 }, 4096, 1 << 20, incr(4), 32);
        for _ in 0..10_000 {
            let a = g.next_addr();
            assert_eq!(a % 128, 0, "alignment");
            assert!(a >= 4096 && a < 4096 + (1 << 20));
        }
    }

    #[test]
    fn random_reproducible_by_seed() {
        let mut a = AddrGen::new(AddrMode::Random { seed: 5 }, 0, 1 << 20, incr(1), 32);
        let mut b = AddrGen::new(AddrMode::Random { seed: 5 }, 0, 1 << 20, incr(1), 32);
        for _ in 0..100 {
            assert_eq!(a.next_addr(), b.next_addr());
        }
        let mut c = AddrGen::new(AddrMode::Random { seed: 6 }, 0, 1 << 20, incr(1), 32);
        let same = (0..100).all(|_| a.next_addr() == c.next_addr());
        assert!(!same, "different seeds should diverge");
    }

    #[test]
    fn random_covers_many_slots() {
        let mut g = AddrGen::new(AddrMode::Random { seed: 1 }, 0, 1 << 16, incr(1), 32);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..4096 {
            seen.insert(g.next_addr());
        }
        // 1024 slots; uniform sampling should touch most of them
        assert!(seen.len() > 900, "saw only {} distinct slots", seen.len());
    }

    #[test]
    fn tiny_region_clamps_to_one_slot() {
        let mut g = AddrGen::new(AddrMode::Sequential, 0, 32, incr(1), 32);
        assert_eq!(g.slots(), 1);
        assert_eq!(g.next_addr(), 0);
        assert_eq!(g.next_addr(), 0);
    }
}
