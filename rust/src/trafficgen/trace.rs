//! Trace-driven workloads: replay recorded memory-access traces through
//! the traffic generator instead of synthetic patterns.
//!
//! Production data-center traces are proprietary (DESIGN.md §2), so the
//! repository ships a synthetic trace *generator* for the classic shapes
//! (streaming, pointer-chasing, zipfian hot-set) plus this parser for a
//! simple portable text format, one access per line:
//!
//! ```text
//! # comment
//! R 0x1000 4        # read,  start address, burst beats
//! W 4096 32         # write, decimal addresses fine too
//! ```
//!
//! Replay maps each record onto one AXI transaction (INCR burst of the
//! recorded length). Burst lengths are *validated* to the AXI4 range
//! 1–128 — an out-of-range record is rejected with a line-numbered
//! error, never silently clamped, so a malformed trace cannot replay as
//! different traffic than it describes — and run through the exact same
//! platform executive as the synthetic patterns.

use anyhow::{bail, Context, Result};

use super::PlannedTxn;
use crate::rng::SplitMix64;

/// One parsed trace record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceRecord {
    /// Write or read?
    pub is_write: bool,
    /// Start byte address.
    pub addr: u64,
    /// Burst length in beats (1–128).
    pub beats: u32,
}

/// Parse the text trace format. Lines: `R|W <addr> [beats]`, `#` comments.
pub fn parse_trace(text: &str) -> Result<Vec<TraceRecord>> {
    let mut out = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut toks = line.split_whitespace();
        let op = toks.next().expect("line is non-empty after trim").to_ascii_uppercase();
        let is_write = match op.as_str() {
            "R" | "RD" | "READ" => false,
            "W" | "WR" | "WRITE" => true,
            other => bail!("line {}: unknown op `{other}`", lineno + 1),
        };
        let addr_tok =
            toks.next().with_context(|| format!("line {}: missing address", lineno + 1))?;
        let addr = parse_addr(addr_tok)
            .with_context(|| format!("line {}: bad address `{addr_tok}`", lineno + 1))?;
        let beats: u32 = match toks.next() {
            None => 1,
            Some(b) => b.parse().with_context(|| format!("line {}: bad beats `{b}`", lineno + 1))?,
        };
        if beats == 0 || beats > 128 {
            bail!(
                "line {}: burst length {beats} outside the AXI4 range 1..=128 \
                 (records are validated, not clamped)",
                lineno + 1
            );
        }
        out.push(TraceRecord { is_write, addr, beats });
    }
    Ok(out)
}

fn parse_addr(tok: &str) -> Result<u64> {
    if let Some(hex) = tok.strip_prefix("0x").or_else(|| tok.strip_prefix("0X")) {
        Ok(u64::from_str_radix(hex, 16)?)
    } else {
        Ok(tok.parse()?)
    }
}

/// Render records back to the text format (round-trips through
/// [`parse_trace`]).
pub fn format_trace(records: &[TraceRecord]) -> String {
    let mut s = String::new();
    for r in records {
        s.push_str(&format!(
            "{} {:#x} {}\n",
            if r.is_write { "W" } else { "R" },
            r.addr,
            r.beats
        ));
    }
    s
}

/// Convert trace records (uniform burst length required — AXI
/// transactions in one batch share the TG's burst configuration) into a
/// TG plan. Returns the plan and the common burst length.
pub fn plan_from_trace(records: &[TraceRecord]) -> Result<(Vec<PlannedTxn>, u32)> {
    let Some(first) = records.first() else { bail!("empty trace") };
    let beats = first.beats;
    if records.iter().any(|r| r.beats != beats) {
        bail!(
            "mixed burst lengths in trace; split it into per-length batches \
             (the RTL TG reconfigures between batches too)"
        );
    }
    let plan = records
        .iter()
        .map(|r| PlannedTxn { is_write: r.is_write, addr: r.addr })
        .collect();
    Ok((plan, beats))
}

/// Synthetic trace generators for the classic data-center access shapes.
pub mod synth {
    use super::*;

    /// Streaming: sequential reads over `region` with occasional strided
    /// writeback (every `wb_every` accesses).
    pub fn streaming(n: usize, beats: u32, region: u64, wb_every: usize) -> Vec<TraceRecord> {
        let stride = beats as u64 * 32;
        (0..n)
            .map(|i| TraceRecord {
                is_write: wb_every > 0 && i % wb_every == wb_every - 1,
                addr: (i as u64 * stride) % region,
                beats,
            })
            .collect()
    }

    /// Pointer chasing: dependent-looking uniform random single beats.
    pub fn pointer_chase(n: usize, region: u64, seed: u64) -> Vec<TraceRecord> {
        let mut rng = SplitMix64::new(seed);
        (0..n)
            .map(|_| TraceRecord {
                is_write: false,
                addr: rng.below(region / 64) * 64,
                beats: 1,
            })
            .collect()
    }

    /// Zipf-ish hot set: 90% of accesses hit the hot `hot_frac` of the
    /// region (approximated by two nested uniform draws), 30% writes.
    pub fn hot_set(n: usize, beats: u32, region: u64, seed: u64) -> Vec<TraceRecord> {
        let mut rng = SplitMix64::new(seed);
        let align = (beats as u64 * 32).next_power_of_two().max(64);
        let hot = (region / 10).max(align);
        (0..n)
            .map(|_| {
                let r = if rng.percent(90) { hot } else { region };
                TraceRecord {
                    is_write: rng.percent(30),
                    addr: rng.below(r / align) * align,
                    beats,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic_lines() {
        let t = parse_trace("# hdr\nR 0x1000 4\nW 4096\nread 64 128\n").unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(t[0], TraceRecord { is_write: false, addr: 0x1000, beats: 4 });
        assert_eq!(t[1], TraceRecord { is_write: true, addr: 4096, beats: 1 });
        assert_eq!(t[2].beats, 128);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_trace("X 0 1").is_err());
        assert!(parse_trace("R zz 1").is_err());
        assert!(parse_trace("R 0 200").is_err());
        assert!(parse_trace("R").is_err());
    }

    #[test]
    fn burst_length_validated_not_clamped() {
        // in-range boundaries replay as written...
        let t = parse_trace("R 0 1\nW 64 128\n").unwrap();
        assert_eq!(t[0].beats, 1);
        assert_eq!(t[1].beats, 128);
        // ...out-of-range records are rejected with a line-numbered
        // error, matching the module doc (no silent clamping)
        for (trace, line) in [("R 0 0", "line 1"), ("R 0 1\nR 64 129", "line 2")] {
            let err = parse_trace(trace).unwrap_err().to_string();
            assert!(err.contains(line), "{err}");
            assert!(err.contains("1..=128"), "{err}");
            assert!(err.contains("not clamped"), "{err}");
        }
    }

    #[test]
    fn format_roundtrip() {
        let t = synth::hot_set(200, 4, 1 << 20, 9);
        let parsed = parse_trace(&format_trace(&t)).unwrap();
        assert_eq!(t, parsed);
    }

    #[test]
    fn plan_from_uniform_trace() {
        let t = synth::streaming(100, 8, 1 << 20, 4);
        let (plan, beats) = plan_from_trace(&t).unwrap();
        assert_eq!(beats, 8);
        assert_eq!(plan.len(), 100);
        assert_eq!(plan.iter().filter(|p| p.is_write).count(), 25);
    }

    #[test]
    fn plan_rejects_mixed_lengths() {
        let t = vec![
            TraceRecord { is_write: false, addr: 0, beats: 4 },
            TraceRecord { is_write: false, addr: 64, beats: 8 },
        ];
        assert!(plan_from_trace(&t).is_err());
    }

    #[test]
    fn synth_shapes_sane() {
        let s = synth::streaming(64, 4, 1 << 16, 0);
        assert!(s.iter().all(|r| !r.is_write));
        let p = synth::pointer_chase(64, 1 << 20, 1);
        assert!(p.iter().all(|r| r.beats == 1 && r.addr % 64 == 0));
        let h = synth::hot_set(1000, 4, 1 << 24, 2);
        let writes = h.iter().filter(|r| r.is_write).count();
        assert!((200..400).contains(&writes), "~30% writes, got {writes}");
        // hot set: most accesses within the first 10% of the region
        let hot = h.iter().filter(|r| r.addr < (1 << 24) / 10).count();
        assert!(hot > 700, "hot-set concentration {hot}/1000");
    }
}
