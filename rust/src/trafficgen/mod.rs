//! The traffic generator (TG) — the paper's measurement instrument
//! (§II-B).
//!
//! One TG drives one memory channel through the five AXI4 channels it
//! manages independently: AR (read address), R (read data), AW (write
//! address), W (write data) and B (write response). Managing the read and
//! write paths separately is what enables *simultaneous* read and write
//! transactions — the property behind the paper's mixed-workload results
//! (Fig. 3, and mixed > read-only in §III-C).
//!
//! Modeled bottlenecks (each one shows up in the paper's numbers):
//!
//! - **address channels**: one transaction accepted per
//!   `addr_cmd_interval_axi` fabric cycles per direction (the MIG
//!   front-end decode pipeline) — caps single-beat throughput at ~half
//!   the data-bus rate;
//! - **data channels**: one beat per fabric cycle in each direction
//!   (256-bit fabric = 32 B/beat = 6.4 GB/s per direction at 200 MHz);
//! - **outstanding window**: `outstanding_cap` transactions in flight per
//!   direction (Blocking mode forces 1 in total);
//! - **controller queues**: back-pressure when the native queues fill.
//!
//! The TG also owns the data path (payload generation + read-back
//! verification, [`payload`]) and the hardware-style performance counters
//! ([`crate::stats::BatchCounters`]).

pub mod addrgen;
pub mod datastore;
pub mod payload;
pub mod trace;

pub use addrgen::AddrGen;
pub use datastore::DataStore;

use std::collections::{HashMap, VecDeque};

use crate::axi::{AxiTxn, TxnId};
use crate::config::{OpMix, PatternConfig, Signaling};
use crate::controller::{Completion, MemController, MemRequest};
use crate::ddr4::{DramGeometry, AXI_RATIO};
use crate::rng::SplitMix64;
use crate::stats::BatchCounters;

/// One planned transaction of a batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlannedTxn {
    /// Write or read?
    pub is_write: bool,
    /// Start byte address.
    pub addr: u64,
}

/// Deterministically expand a pattern into its transaction plan. The plan
/// is what the RTL TG generates on the fly; precomputing it lets the
/// platform batch the payload work into one XLA call. The DRAM geometry
/// parameterizes the bank-conflict address mode.
pub fn plan_batch(cfg: &PatternConfig, beat_bytes: u32, geo: &DramGeometry) -> Vec<PlannedTxn> {
    let mut rng = SplitMix64::new(cfg.addr.plan_seed());
    // One shared address walk for both directions (the RTL TG draws the
    // op type per transaction over a single generator): reads and writes
    // of a sequential mixed batch stream through the *same* open rows
    // instead of fighting over banks with conflicting rows.
    let mut gen =
        AddrGen::new(&cfg.addr, cfg.start_addr, cfg.region_bytes, cfg.burst, beat_bytes, geo);
    let read_pct = cfg.op.read_pct();
    (0..cfg.batch_len)
        .map(|_| {
            let is_write = match cfg.op {
                OpMix::ReadOnly => false,
                OpMix::WriteOnly => true,
                OpMix::Mixed { .. } => !rng.percent(read_pct),
            };
            PlannedTxn { is_write, addr: gen.next_addr() }
        })
        .collect()
}

/// A read transaction being unrolled into controller requests.
#[derive(Debug, Clone)]
struct ReadUnroll {
    txn_id: TxnId,
    /// (burst byte address, AXI beats it serves), in beat order.
    bursts: Vec<(u64, u32)>,
    next: usize,
}

/// A write transaction streaming W beats.
#[derive(Debug, Clone)]
struct WriteUnroll {
    txn_id: TxnId,
    bursts: Vec<(u64, u32)>,
    /// Current burst being filled.
    cur: usize,
    /// Beats streamed into the current burst.
    beats_in_cur: u32,
    /// A fully-streamed burst waiting for controller queue space.
    pending_push: bool,
}

/// R-channel group: beats of one completed read request awaiting drain.
#[derive(Debug, Clone, Copy)]
struct RGroup {
    txn_id: TxnId,
    beats_left: u32,
    last_of_txn: bool,
    first_beat_pending: bool,
}

/// Read-back sample for batched verification: (burst address, observed
/// words).
pub type ReadBackSample = (u64, [u32; payload::WORDS_PER_BURST]);

/// Per-channel traffic generator.
pub struct TrafficGen {
    cfg: PatternConfig,
    beat_bytes: u32,
    geo: DramGeometry,
    // plan
    plan: Vec<PlannedTxn>,
    rd_idx: Vec<usize>,
    wr_idx: Vec<usize>,
    rd_next: usize,
    wr_next: usize,
    blk_next: usize, // merged cursor for Blocking mode
    // signaling
    outstanding_cap: usize,
    addr_interval: u64,
    next_ar_at: u64,
    next_aw_at: u64,
    rd_outstanding: usize,
    wr_outstanding: usize,
    // unrolling
    rd_unroll: VecDeque<ReadUnroll>,
    wr_unroll: VecDeque<WriteUnroll>,
    // R channel
    r_queue: VecDeque<RGroup>,
    last_drained_txn: Option<TxnId>,
    serial_frontend: bool,
    // bookkeeping
    issue_axi: HashMap<TxnId, u64>,
    next_txn_id: TxnId,
    rd_done: u32,
    wr_done: u32,
    /// Counters of the current batch (AXI-cycle units, relative to 0).
    pub counters: BatchCounters,
    /// Data store for integrity checking (None = timing-only run).
    pub store: Option<DataStore>,
    /// Read-back samples collected for batched verification.
    pub readback: Vec<ReadBackSample>,
    readback_cap: usize,
    /// Pre-generated payloads (burst address → words), produced by the
    /// AOT-compiled XLA datagen kernel when a runtime is attached. Falls
    /// back to the pure-Rust expansion when absent.
    pub payload_map: Option<HashMap<u64, [u32; payload::WORDS_PER_BURST]>>,
}

/// Max controller requests unrolled per AXI cycle (4 DRAM command slots
/// per fabric cycle — the 4:1 ratio).
const UNROLL_PER_CYCLE: usize = 4;
/// Max read transactions concurrently unrolling.
const UNROLL_TXNS: usize = 4;

impl TrafficGen {
    /// Build a TG for `cfg` on a channel with the given fabric beat size
    /// and DRAM geometry. `outstanding_cap` comes from the design config.
    pub fn new(
        cfg: PatternConfig,
        beat_bytes: u32,
        geo: DramGeometry,
        outstanding_cap: usize,
        addr_cmd_interval_axi: u32,
    ) -> Self {
        Self::with_frontend(cfg, beat_bytes, geo, outstanding_cap, addr_cmd_interval_axi, true)
    }

    /// As [`Self::new`] but selecting the front-end model explicitly.
    pub fn with_frontend(
        cfg: PatternConfig,
        beat_bytes: u32,
        geo: DramGeometry,
        outstanding_cap: usize,
        addr_cmd_interval_axi: u32,
        serial_frontend: bool,
    ) -> Self {
        cfg.validate().expect("invalid pattern config");
        let plan = plan_batch(&cfg, beat_bytes, &geo);
        let rd_idx: Vec<usize> =
            plan.iter().enumerate().filter(|(_, t)| !t.is_write).map(|(i, _)| i).collect();
        let wr_idx: Vec<usize> =
            plan.iter().enumerate().filter(|(_, t)| t.is_write).map(|(i, _)| i).collect();
        let store = cfg.verify.then(DataStore::new);
        Self {
            cfg,
            beat_bytes,
            geo,
            plan,
            rd_idx,
            wr_idx,
            rd_next: 0,
            wr_next: 0,
            blk_next: 0,
            outstanding_cap,
            addr_interval: addr_cmd_interval_axi as u64,
            next_ar_at: 0,
            next_aw_at: 0,
            rd_outstanding: 0,
            wr_outstanding: 0,
            rd_unroll: VecDeque::new(),
            wr_unroll: VecDeque::new(),
            r_queue: VecDeque::new(),
            last_drained_txn: None,
            serial_frontend,
            issue_axi: HashMap::new(),
            next_txn_id: 0,
            rd_done: 0,
            wr_done: 0,
            counters: BatchCounters::default(),
            store,
            readback: Vec::new(),
            readback_cap: 1 << 16,
            payload_map: None,
        }
    }

    /// The transaction plan (read-only view; used by the platform to
    /// precompute payload blocks).
    pub fn plan(&self) -> &[PlannedTxn] {
        &self.plan
    }

    /// Replace the synthetic plan with an explicit one (trace replay).
    /// The plan length must match the pattern's `batch_len`.
    pub fn with_plan(mut self, plan: Vec<PlannedTxn>) -> Self {
        assert_eq!(plan.len(), self.cfg.batch_len as usize, "plan/batch_len mismatch");
        self.rd_idx =
            plan.iter().enumerate().filter(|(_, t)| !t.is_write).map(|(i, _)| i).collect();
        self.wr_idx =
            plan.iter().enumerate().filter(|(_, t)| t.is_write).map(|(i, _)| i).collect();
        self.plan = plan;
        self
    }

    /// Pattern in force.
    pub fn config(&self) -> &PatternConfig {
        &self.cfg
    }

    /// All transactions issued, completed and drained?
    pub fn is_done(&self) -> bool {
        (self.rd_done + self.wr_done) as usize == self.plan.len()
            && self.r_queue.is_empty()
            && self.rd_unroll.is_empty()
            && self.wr_unroll.is_empty()
    }

    /// Transactions completed so far.
    pub fn completed(&self) -> u32 {
        self.rd_done + self.wr_done
    }

    /// Decompose an AXI transaction into (burst byte address, beats)
    /// pairs, beat-order, consecutive duplicates merged.
    fn split_bursts(&self, addr: u64, is_write: bool, id: TxnId) -> Vec<(u64, u32)> {
        let txn = AxiTxn { id, is_write, addr, burst: self.cfg.burst, beat_bytes: self.beat_bytes };
        let mask = !(self.geo.burst_bytes() as u64 - 1);
        let mut out: Vec<(u64, u32)> = Vec::new();
        for i in 0..self.cfg.burst.len {
            let a = txn.beat_addr(i) & mask;
            match out.last_mut() {
                Some((last, beats)) if *last == a => *beats += 1,
                _ => out.push((a, 1)),
            }
        }
        out
    }

    fn total_outstanding(&self) -> usize {
        self.rd_outstanding + self.wr_outstanding
    }

    /// Transactions currently in flight (issued, not yet fully
    /// completed) — the telemetry sampler's queue-depth snapshot.
    pub fn in_flight(&self) -> usize {
        self.total_outstanding()
    }

    /// May a new transaction be issued under the signaling mode?
    fn may_issue(&self, is_write: bool, now: u64) -> bool {
        match self.cfg.signaling {
            Signaling::Blocking => self.total_outstanding() == 0,
            Signaling::NonBlocking | Signaling::Aggressive => {
                let (outst, gate) = if is_write {
                    (self.wr_outstanding, self.next_aw_at)
                } else {
                    (self.rd_outstanding, self.next_ar_at)
                };
                outst < self.outstanding_cap && now >= gate
            }
        }
    }

    /// Issue phase: accept new transactions onto the address channels.
    fn issue_txns(&mut self, now: u64) {
        if self.cfg.signaling == Signaling::Blocking {
            // strict plan order, one at a time
            if self.blk_next < self.plan.len() && self.total_outstanding() == 0 {
                let t = self.plan[self.blk_next];
                self.blk_next += 1;
                self.start_txn(t, now);
            }
            return;
        }
        // Independent AR / AW streams.
        if self.rd_next < self.rd_idx.len()
            && self.may_issue(false, now)
            && self.rd_unroll.len() < UNROLL_TXNS
        {
            let t = self.plan[self.rd_idx[self.rd_next]];
            self.rd_next += 1;
            self.start_txn(t, now);
            self.next_ar_at = now + self.addr_interval;
        }
        if self.wr_next < self.wr_idx.len()
            && self.may_issue(true, now)
            && self.wr_unroll.len() < UNROLL_TXNS
        {
            let t = self.plan[self.wr_idx[self.wr_next]];
            self.wr_next += 1;
            self.start_txn(t, now);
            self.next_aw_at = now + self.addr_interval;
        }
    }

    fn start_txn(&mut self, t: PlannedTxn, now: u64) {
        let id = self.next_txn_id;
        self.next_txn_id += 1;
        self.issue_axi.insert(id, now);
        let bursts = self.split_bursts(t.addr, t.is_write, id);
        if t.is_write {
            self.wr_outstanding += 1;
            self.wr_unroll.push_back(WriteUnroll {
                txn_id: id,
                bursts,
                cur: 0,
                beats_in_cur: 0,
                pending_push: false,
            });
        } else {
            self.rd_outstanding += 1;
            self.rd_unroll.push_back(ReadUnroll { txn_id: id, bursts, next: 0 });
        }
    }

    /// Unroll phase: push read requests into the controller queues.
    fn unroll_reads(&mut self, dram_now: u64, ctrl: &mut MemController) {
        let mut budget = UNROLL_PER_CYCLE;
        while budget > 0 {
            let serial = self.serial_frontend;
            let Some(head) = self.rd_unroll.front_mut() else { break };
            // Serial front end (MIG-like): a *new* transaction starts
            // unrolling only once the native read queue has drained and
            // any page-miss pipeline flush has cleared.
            if serial
                && head.next == 0
                && (!ctrl.read_queue_empty() || dram_now < ctrl.frontend_gate(false))
            {
                break;
            }
            let (burst_addr, beats) = head.bursts[head.next];
            let last = head.next + 1 == head.bursts.len();
            let req = MemRequest {
                txn_id: head.txn_id,
                is_write: false,
                addr: self.geo.decode(burst_addr),
                burst_addr,
                beats,
                arrival: dram_now,
                last_of_txn: last,
            };
            match ctrl.try_push(req) {
                Ok(()) => {
                    head.next += 1;
                    budget -= 1;
                    if last {
                        self.rd_unroll.pop_front();
                    }
                }
                Err(_) => break, // queue full: retry next cycle
            }
        }
    }

    /// W-channel phase: stream write beats in AW order and push completed
    /// bursts into the controller. The entry being streamed is the oldest
    /// not-fully-streamed transaction (older entries may still sit in the
    /// deque awaiting their B response — they don't block the W channel).
    /// Aggressive signaling pre-buffers and streams two beats per cycle;
    /// the other modes drive the physical one-beat-per-cycle rate.
    fn stream_write_beats(&mut self, dram_now: u64, ctrl: &mut MemController) {
        let serial = self.serial_frontend;
        let beats_per_cycle = if self.cfg.signaling == Signaling::Aggressive { 2 } else { 1 };
        for _ in 0..beats_per_cycle {
            let Some(idx) = self
                .wr_unroll
                .iter()
                .position(|u| u.pending_push || u.cur < u.bursts.len())
            else {
                return;
            };
            let head = &mut self.wr_unroll[idx];
            // Serial front end: a new write transaction starts streaming
            // only once the native write queue has drained.
            if serial
                && head.cur == 0
                && head.beats_in_cur == 0
                && !head.pending_push
                && (!ctrl.write_queue_empty() || dram_now < ctrl.frontend_gate(true))
            {
                return;
            }
            // Retry a burst blocked on queue space first.
            if head.pending_push {
                if !Self::push_write_burst(
                    &self.geo,
                    self.payload_map.as_ref(),
                    self.store.as_mut(),
                    &self.cfg,
                    ctrl,
                    head,
                    dram_now,
                ) {
                    return; // still blocked; W stalls this cycle
                }
                if head.cur >= head.bursts.len() {
                    continue;
                }
            }
            // Stream one beat into the current burst.
            head.beats_in_cur += 1;
            self.counters.wr_bytes += self.beat_bytes as u64;
            let (_, beats) = head.bursts[head.cur];
            if head.beats_in_cur == beats {
                head.pending_push = true;
                let _ = Self::push_write_burst(
                    &self.geo,
                    self.payload_map.as_ref(),
                    self.store.as_mut(),
                    &self.cfg,
                    ctrl,
                    head,
                    dram_now,
                );
            }
        }
    }

    /// Try to push the head write-unroll's current burst; on success
    /// advances the unroll (and retires it when complete). Returns success.
    fn push_write_burst(
        geo: &DramGeometry,
        payload_map: Option<&HashMap<u64, [u32; payload::WORDS_PER_BURST]>>,
        store: Option<&mut DataStore>,
        cfg: &PatternConfig,
        ctrl: &mut MemController,
        head: &mut WriteUnroll,
        dram_now: u64,
    ) -> bool {
        let (burst_addr, beats) = head.bursts[head.cur];
        let last = head.cur + 1 == head.bursts.len();
        let req = MemRequest {
            txn_id: head.txn_id,
            is_write: true,
            addr: geo.decode(burst_addr),
            burst_addr,
            beats,
            arrival: dram_now,
            last_of_txn: last,
        };
        match ctrl.try_push(req) {
            Ok(()) => {
                if let Some(s) = store {
                    let words = payload_map
                        .and_then(|m| m.get(&burst_addr).copied())
                        .unwrap_or_else(|| payload::burst_payload(burst_addr, cfg.data));
                    s.write(burst_addr, words);
                }
                head.cur += 1;
                head.beats_in_cur = 0;
                head.pending_push = false;
                true
            }
            Err(_) => false,
        }
    }

    /// Completion intake from the controller (platform calls this each
    /// fabric cycle with the drained completions).
    pub fn on_completions(&mut self, comps: &[Completion], now: u64) {
        for c in comps {
            if c.is_write {
                if c.last_of_txn {
                    // B response
                    self.wr_done += 1;
                    self.wr_outstanding -= 1;
                    self.counters.wr_txns += 1;
                    self.counters.wr_cycles = now;
                    if let Some(t0) = self.issue_axi.remove(&c.txn_id) {
                        self.counters.wr_latency.record(now - t0);
                    }
                    // retire the unroll entry
                    if let Some(pos) =
                        self.wr_unroll.iter().position(|u| u.txn_id == c.txn_id)
                    {
                        self.wr_unroll.remove(pos);
                    }
                }
            } else {
                // Read data: sample for verification, then queue beats.
                if self.readback.len() < self.readback_cap {
                    if let Some(store) = self.store.as_ref() {
                        let data = store.read(c.burst_addr);
                        self.readback.push((c.burst_addr, data));
                    }
                }
                self.r_queue.push_back(RGroup {
                    txn_id: c.txn_id,
                    beats_left: c.beats,
                    last_of_txn: c.last_of_txn,
                    first_beat_pending: true,
                });
            }
        }
    }

    /// R-channel drain: deliver beats to the TG at the fabric rate (one
    /// beat per cycle in every mode — `rready` differences between
    /// non-blocking and aggressive are below this model's resolution; the
    /// W-channel pre-buffering is where aggressive mode actually wins).
    fn drain_read_beats(&mut self, now: u64) {
        let Some(head) = self.r_queue.front_mut() else { return };
        head.first_beat_pending = false;
        self.last_drained_txn = Some(head.txn_id);
        head.beats_left -= 1;
        self.counters.rd_bytes += self.beat_bytes as u64;
        if head.beats_left == 0 {
            let done = *head;
            self.r_queue.pop_front();
            if done.last_of_txn {
                self.rd_done += 1;
                self.rd_outstanding -= 1;
                self.counters.rd_txns += 1;
                self.counters.rd_cycles = now;
                if let Some(t0) = self.issue_axi.remove(&done.txn_id) {
                    self.counters.rd_latency.record(now - t0);
                }
            }
        }
    }

    /// One fabric-clock tick: drain R, issue AR/AW, unroll, stream W.
    /// `now` is the batch-relative fabric cycle (counter units);
    /// `dram_now` is the controller's absolute DRAM cycle (timing units).
    pub fn tick_axi(&mut self, now: u64, dram_now: u64, ctrl: &mut MemController) {
        self.drain_read_beats(now);
        self.issue_txns(now);
        self.unroll_reads(dram_now, ctrl);
        self.stream_write_beats(dram_now, ctrl);
        if self.is_done() && self.counters.total_cycles == 0 {
            self.counters.total_cycles = now;
        }
    }

    /// Event-engine contract: the earliest batch-relative fabric cycle
    /// after `now` (the cycle [`Self::tick_axi`] just ran at, with DRAM
    /// clock `dram_now`) at which the TG could do anything, assuming no
    /// completion arrives in between (completions publish their own wake
    /// through [`MemController::next_completion_at`]). `u64::MAX` means
    /// only an external event can wake the TG. The bound is
    /// conservative — it may be earlier than the first real action
    /// (costing a no-op tick) but never later, which is what keeps the
    /// event engine bit-exact: every cycle that *could* mutate TG or
    /// controller state is executed.
    pub fn next_event(&self, now: u64, dram_now: u64, ctrl: &MemController) -> u64 {
        // R beats drain one per fabric cycle while anything is queued.
        if !self.r_queue.is_empty() {
            return now + 1;
        }
        let mut wake = u64::MAX;
        // Issue phase: when is the next AR/AW accept possible?
        match self.cfg.signaling {
            Signaling::Blocking => {
                if self.blk_next < self.plan.len() && self.total_outstanding() == 0 {
                    return now + 1;
                }
            }
            Signaling::NonBlocking | Signaling::Aggressive => {
                if self.rd_next < self.rd_idx.len()
                    && self.rd_outstanding < self.outstanding_cap
                    && self.rd_unroll.len() < UNROLL_TXNS
                {
                    wake = wake.min(self.next_ar_at.max(now + 1));
                }
                if self.wr_next < self.wr_idx.len()
                    && self.wr_outstanding < self.outstanding_cap
                    && self.wr_unroll.len() < UNROLL_TXNS
                {
                    wake = wake.min(self.next_aw_at.max(now + 1));
                }
            }
        }
        // Read unrolling: a mid-unroll head retries every cycle; a fresh
        // head under the serial front end waits for the native queue to
        // drain (a controller event) or for the pure time gate.
        if let Some(head) = self.rd_unroll.front() {
            if !self.serial_frontend || head.next > 0 || !ctrl.read_queue_empty() {
                return now + 1;
            }
            let gate = ctrl.frontend_gate(false);
            if dram_now < gate {
                wake = wake.min(now + (gate - dram_now).div_ceil(AXI_RATIO));
            } else {
                return now + 1;
            }
        }
        // Write streaming: same structure over the oldest entry that
        // still has beats to stream or a burst push to retry (entries
        // merely awaiting their B response publish no wake of their own).
        if let Some(head) =
            self.wr_unroll.iter().find(|u| u.pending_push || u.cur < u.bursts.len())
        {
            let fresh = head.cur == 0 && head.beats_in_cur == 0 && !head.pending_push;
            if !self.serial_frontend || !fresh || !ctrl.write_queue_empty() {
                return now + 1;
            }
            let gate = ctrl.frontend_gate(true);
            if dram_now < gate {
                wake = wake.min(now + (gate - dram_now).div_ceil(AXI_RATIO));
            } else {
                return now + 1;
            }
        }
        wake
    }

    /// Verify collected read-back samples against expected payloads using
    /// the pure-Rust mirror (the platform may use the XLA path instead).
    /// Returns the mismatch count and records it in the counters.
    pub fn verify_readback_rust(&mut self) -> u64 {
        let mut mism = 0u64;
        for (addr, data) in &self.readback {
            if self.store.as_ref().is_some_and(|s| s.is_written(*addr)) {
                let exp = payload::burst_payload(*addr, self.cfg.data);
                mism += payload::verify_burst(&exp, data) as u64;
            }
        }
        self.counters.mismatches += mism;
        mism
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AddrMode, BurstKind, PatternConfig, SpeedBin};
    use crate::ddr4::AXI_RATIO;
    use crate::controller::MemController;
    use crate::ddr4::TimingParams;

    fn run_tg(cfg: PatternConfig) -> (TrafficGen, u64) {
        let geo = DramGeometry::profpga_board();
        let mut ctrl = MemController::new(
            crate::config::ControllerParams::default(),
            TimingParams::for_bin(SpeedBin::Ddr4_1600),
            geo,
        );
        let mut tg = TrafficGen::new(cfg, 32, geo, 8, 2);
        let mut comps = Vec::new();
        let mut now_axi = 0u64;
        while !tg.is_done() {
            assert!(now_axi < 10_000_000, "TG deadlocked");
            comps.clear();
            ctrl.pop_completions(now_axi * AXI_RATIO, &mut comps);
            tg.on_completions(&comps, now_axi);
            tg.tick_axi(now_axi, now_axi * AXI_RATIO, &mut ctrl);
            for s in 0..AXI_RATIO {
                ctrl.tick(now_axi * AXI_RATIO + s);
            }
            now_axi += 1;
        }
        (tg, now_axi)
    }

    #[test]
    fn plan_respects_op_mix() {
        let geo = DramGeometry::profpga_board();
        let cfg = PatternConfig::mixed(AddrMode::Sequential, 4, 1000);
        let plan = plan_batch(&cfg, 32, &geo);
        let writes = plan.iter().filter(|t| t.is_write).count();
        assert!((350..=650).contains(&writes), "50% mix, got {writes} writes");
        let ro = plan_batch(&PatternConfig::seq_read_burst(4, 100), 32, &geo);
        assert!(ro.iter().all(|t| !t.is_write));
    }

    #[test]
    fn plan_deterministic() {
        let geo = DramGeometry::profpga_board();
        let cfg = PatternConfig::rnd_read_burst(4, 500, 42);
        assert_eq!(plan_batch(&cfg, 32, &geo), plan_batch(&cfg, 32, &geo));
    }

    #[test]
    fn plan_covers_new_addr_modes() {
        let geo = DramGeometry::profpga_board();
        for addr in [
            AddrMode::Strided { stride: 64 << 10 },
            AddrMode::BankConflict { seed: 5 },
            AddrMode::PointerChase { seed: 5, working_set: 1 << 20 },
            AddrMode::Phased(vec![
                (AddrMode::Sequential, 32),
                (AddrMode::Random { seed: 2 }, 32),
            ]),
        ] {
            let mut cfg = PatternConfig::seq_read_burst(1, 128);
            cfg.addr = addr.clone();
            let plan = plan_batch(&cfg, 32, &geo);
            assert_eq!(plan.len(), 128, "{addr:?}");
            assert_eq!(plan, plan_batch(&cfg, 32, &geo), "{addr:?} deterministic");
            for t in &plan {
                assert!(t.addr < cfg.region_bytes, "{addr:?}: in region");
                assert_eq!(t.addr % 64, 0, "{addr:?}: burst aligned");
            }
        }
    }

    #[test]
    fn seq_read_batch_completes_and_counts() {
        let (tg, _) = run_tg(PatternConfig::seq_read_burst(4, 64));
        assert_eq!(tg.counters.rd_txns, 64);
        assert_eq!(tg.counters.rd_bytes, 64 * 4 * 32);
        assert_eq!(tg.counters.wr_txns, 0);
        assert!(tg.counters.rd_cycles > 0);
        assert!(tg.counters.total_cycles >= tg.counters.rd_cycles);
        assert_eq!(tg.counters.rd_latency.count(), 64);
    }

    #[test]
    fn seq_write_batch_completes() {
        let (tg, _) = run_tg(PatternConfig::seq_write_burst(4, 64));
        assert_eq!(tg.counters.wr_txns, 64);
        assert_eq!(tg.counters.wr_bytes, 64 * 4 * 32);
        assert_eq!(tg.counters.wr_latency.count(), 64);
    }

    #[test]
    fn mixed_batch_runs_both_directions() {
        let (tg, _) = run_tg(PatternConfig::mixed(AddrMode::Sequential, 4, 128));
        assert_eq!(tg.counters.rd_txns + tg.counters.wr_txns, 128);
        assert!(tg.counters.rd_txns > 20);
        assert!(tg.counters.wr_txns > 20);
    }

    #[test]
    fn single_transactions_work() {
        let (tg, _) = run_tg(PatternConfig::seq_read_burst(1, 32));
        assert_eq!(tg.counters.rd_txns, 32);
        assert_eq!(tg.counters.rd_bytes, 32 * 32);
    }

    #[test]
    fn long_bursts_unroll_past_queue_depth() {
        // 128-beat bursts = 64 DRAM requests per txn >> queue depth 16:
        // must stream without deadlock.
        let (tg, _) = run_tg(PatternConfig::seq_read_burst(128, 8));
        assert_eq!(tg.counters.rd_txns, 8);
        assert_eq!(tg.counters.rd_bytes, 8 * 128 * 32);
    }

    #[test]
    fn blocking_mode_serializes() {
        let mut cfg = PatternConfig::seq_read_burst(1, 16);
        cfg.signaling = Signaling::Blocking;
        let (tg_blk, cycles_blk) = run_tg(cfg);
        let (tg_nb, cycles_nb) = run_tg(PatternConfig::seq_read_burst(1, 16));
        assert_eq!(tg_blk.counters.rd_txns, tg_nb.counters.rd_txns);
        assert!(
            cycles_blk > cycles_nb,
            "blocking ({cycles_blk}) must be slower than non-blocking ({cycles_nb})"
        );
    }

    #[test]
    fn aggressive_at_least_as_fast_as_nonblocking() {
        let mut agr = PatternConfig::seq_read_burst(4, 256);
        agr.signaling = Signaling::Aggressive;
        let (_, c_agr) = run_tg(agr);
        let (_, c_nb) = run_tg(PatternConfig::seq_read_burst(4, 256));
        assert!(c_agr <= c_nb, "aggressive {c_agr} vs non-blocking {c_nb}");
    }

    #[test]
    fn random_slower_than_sequential() {
        let (_, c_seq) = run_tg(PatternConfig::seq_read_burst(1, 256));
        let (_, c_rnd) = run_tg(PatternConfig::rnd_read_burst(1, 256, 7));
        assert!(
            c_rnd as f64 > c_seq as f64 * 2.0,
            "random singles ({c_rnd}) should be >2x slower than sequential ({c_seq})"
        );
    }

    #[test]
    fn write_then_read_verifies_clean() {
        let geo = DramGeometry::profpga_board();
        let mut ctrl = MemController::new(
            crate::config::ControllerParams::default(),
            TimingParams::for_bin(SpeedBin::Ddr4_1600),
            geo,
        );
        // write a small region
        let mut wcfg = PatternConfig::seq_write_burst(4, 32);
        wcfg.region_bytes = 32 * 4 * 32;
        wcfg.verify = true;
        let mut wtg = TrafficGen::new(wcfg, 32, geo, 8, 2);
        let mut comps = Vec::new();
        let mut now = 0u64;
        while !wtg.is_done() {
            comps.clear();
            ctrl.pop_completions(now * AXI_RATIO, &mut comps);
            wtg.on_completions(&comps, now);
            wtg.tick_axi(now, now * AXI_RATIO, &mut ctrl);
            for s in 0..AXI_RATIO {
                ctrl.tick(now * AXI_RATIO + s);
            }
            now += 1;
        }
        // read it back with the SAME store
        let mut rcfg = PatternConfig::seq_read_burst(4, 32);
        rcfg.region_bytes = 32 * 4 * 32;
        rcfg.verify = true;
        let mut rtg = TrafficGen::new(rcfg, 32, geo, 8, 2);
        rtg.store = wtg.store.take();
        while !rtg.is_done() {
            comps.clear();
            ctrl.pop_completions(now * AXI_RATIO, &mut comps);
            rtg.on_completions(&comps, now);
            rtg.tick_axi(now, now * AXI_RATIO, &mut ctrl);
            for s in 0..AXI_RATIO {
                ctrl.tick(now * AXI_RATIO + s);
            }
            now += 1;
        }
        assert!(!rtg.readback.is_empty());
        assert_eq!(rtg.verify_readback_rust(), 0, "clean memory must verify clean");
        // fault injection: corrupt and re-verify
        let addr = rtg.readback[0].0;
        rtg.readback[0].1[5] ^= 0xDEAD;
        assert!(rtg.store.as_ref().unwrap().is_written(addr));
        assert!(rtg.verify_readback_rust() > 0, "corruption must be detected");
    }

    #[test]
    fn fixed_burst_single_dram_burst() {
        let geo = DramGeometry::profpga_board();
        let tg = TrafficGen::new(
            PatternConfig {
                burst: crate::config::BurstSpec { len: 8, kind: BurstKind::Fixed },
                ..PatternConfig::seq_read_burst(8, 4)
            },
            32,
            geo,
            8,
            2,
        );
        let bursts = tg.split_bursts(256, false, 0);
        assert_eq!(bursts, vec![(256, 8)], "FIXED: one burst carrying all beats");
    }

    #[test]
    fn incr_burst_splits_in_pairs() {
        let geo = DramGeometry::profpga_board();
        let tg = TrafficGen::new(PatternConfig::seq_read_burst(4, 1), 32, geo, 8, 2);
        let bursts = tg.split_bursts(128, false, 0);
        assert_eq!(bursts, vec![(128, 2), (192, 2)]);
    }
}
