//! Sparse backing store for data-integrity checking.
//!
//! The timing model doesn't need data, but the paper's integrity feature
//! (write real non-zero payloads, read them back, compare) does. This
//! sparse store keeps only the 64-byte bursts that were actually written —
//! a 2 GiB channel costs memory proportional to the touched footprint.
//!
//! [`DataStore::corrupt_word`] flips bits behind the TG's back, which the
//! failure-injection tests use to prove the checker actually detects
//! faults (a checker that can't fail is not a checker).

use std::collections::HashMap;

use super::payload::WORDS_PER_BURST;

/// Sparse 64-byte-burst-granular memory contents.
#[derive(Debug, Clone, Default)]
pub struct DataStore {
    bursts: HashMap<u64, [u32; WORDS_PER_BURST]>,
}

impl DataStore {
    /// Empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Write the contents of the burst at (64-aligned) `burst_addr`.
    pub fn write(&mut self, burst_addr: u64, words: [u32; WORDS_PER_BURST]) {
        debug_assert_eq!(burst_addr % 64, 0);
        self.bursts.insert(burst_addr, words);
    }

    /// Read the burst at `burst_addr`; unwritten memory reads as zeros
    /// (DRAM after init — also what makes reads of never-written regions
    /// deterministic in the model).
    pub fn read(&self, burst_addr: u64) -> [u32; WORDS_PER_BURST] {
        debug_assert_eq!(burst_addr % 64, 0);
        self.bursts.get(&burst_addr).copied().unwrap_or([0; WORDS_PER_BURST])
    }

    /// Has this burst ever been written?
    pub fn is_written(&self, burst_addr: u64) -> bool {
        self.bursts.contains_key(&burst_addr)
    }

    /// Number of distinct bursts written (footprint in 64 B units).
    pub fn footprint_bursts(&self) -> usize {
        self.bursts.len()
    }

    /// Fault injection: XOR `mask` into word `word_idx` of a stored burst.
    /// Returns false if the burst was never written.
    pub fn corrupt_word(&mut self, burst_addr: u64, word_idx: usize, mask: u32) -> bool {
        match self.bursts.get_mut(&burst_addr) {
            Some(b) => {
                b[word_idx % WORDS_PER_BURST] ^= mask;
                true
            }
            None => false,
        }
    }

    /// Drop everything (batch-boundary reset).
    pub fn clear(&mut self) {
        self.bursts.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unwritten_reads_zero() {
        let s = DataStore::new();
        assert_eq!(s.read(0), [0u32; 16]);
        assert!(!s.is_written(0));
    }

    #[test]
    fn write_read_roundtrip() {
        let mut s = DataStore::new();
        let w = [7u32; 16];
        s.write(128, w);
        assert_eq!(s.read(128), w);
        assert!(s.is_written(128));
        assert_eq!(s.footprint_bursts(), 1);
    }

    #[test]
    fn overwrite_replaces() {
        let mut s = DataStore::new();
        s.write(0, [1; 16]);
        s.write(0, [2; 16]);
        assert_eq!(s.read(0), [2; 16]);
        assert_eq!(s.footprint_bursts(), 1);
    }

    #[test]
    fn corrupt_flips_bits() {
        let mut s = DataStore::new();
        s.write(64, [0xFF; 16]);
        assert!(s.corrupt_word(64, 3, 0x0F));
        let b = s.read(64);
        assert_eq!(b[3], 0xF0);
        assert_eq!(b[2], 0xFF);
        assert!(!s.corrupt_word(128, 0, 1), "can't corrupt unwritten memory");
    }

    #[test]
    fn clear_resets() {
        let mut s = DataStore::new();
        s.write(0, [1; 16]);
        s.clear();
        assert_eq!(s.footprint_bursts(), 0);
        assert_eq!(s.read(0), [0; 16]);
    }
}
