//! Power-of-two-bucketed latency histogram (hardware-friendly: the RTL
//! analogue is a priority encoder over the latency value feeding one of
//! ~32 counters, which is how such counters are actually built on FPGAs).

/// Latency histogram with log2 buckets: bucket `i` holds samples in
/// `[2^i, 2^(i+1))` (bucket 0 holds 0 and 1).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LatencyHistogram {
    buckets: [u64; 32],
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self { buckets: [0; 32], count: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    /// Record one latency sample.
    pub fn record(&mut self, v: u64) {
        let idx = (64 - v.max(1).leading_zeros() as usize - 1).min(31);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean of the samples (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Smallest sample (None when empty).
    pub fn min(&self) -> Option<u64> {
        (self.count > 0).then_some(self.min)
    }

    /// Largest sample (None when empty).
    pub fn max(&self) -> Option<u64> {
        (self.count > 0).then_some(self.max)
    }

    /// Approximate percentile (0..=100) from the bucket boundaries:
    /// returns the upper bound of the bucket containing the percentile,
    /// saturated to the recorded maximum. The saturation matters twice:
    /// a bucket's nominal upper bound can overstate the largest sample
    /// actually recorded in it, and the overflow bucket (samples at or
    /// above `2^31`, which all land in bucket 31) has no meaningful
    /// upper bound at all — its nominal `2^32` is a stale boundary that
    /// can *understate* the real tail by orders of magnitude.
    pub fn percentile(&self, p: f64) -> Option<u64> {
        if self.count == 0 {
            return None;
        }
        let target = (p / 100.0 * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                // the overflow bucket is unbounded: report the recorded
                // max, not the stale 2^32 boundary
                let bound = if i == 31 { self.max } else { 1u64 << (i + 1) };
                return Some(bound.min(self.max));
            }
        }
        Some(self.max)
    }

    /// Approximate percentile of only the samples recorded since
    /// `earlier` — an *incremental* percentile over the bucket deltas,
    /// used by the telemetry sampler to report per-window latency from
    /// two snapshots of one cumulative histogram. `earlier` must be a
    /// past state of `self` (every bucket <= the current one). Returns
    /// 0 when no samples landed in the delta. The bound saturates to
    /// the cumulative max (the per-window max isn't tracked), which is
    /// deterministic and never understates the window's tail.
    pub fn percentile_delta(&self, earlier: &LatencyHistogram, p: f64) -> u64 {
        let count = self.count - earlier.count;
        if count == 0 {
            return 0;
        }
        let target = (p / 100.0 * count as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for i in 0..32 {
            seen += self.buckets[i] - earlier.buckets[i];
            if seen >= target {
                let bound = if i == 31 { self.max } else { 1u64 << (i + 1) };
                return bound.min(self.max);
            }
        }
        self.max
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        if other.count > 0 {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
    }

    /// Non-empty `(bucket_low, bucket_high, count)` triples for reporting.
    pub fn nonzero_buckets(&self) -> Vec<(u64, u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (1u64 << i, (1u64 << (i + 1)) - 1, c))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), None);
        assert_eq!(h.max(), None);
        assert_eq!(h.percentile(50.0), None);
    }

    #[test]
    fn mean_min_max() {
        let mut h = LatencyHistogram::new();
        for v in [10, 20, 30] {
            h.record(v);
        }
        assert_eq!(h.mean(), 20.0);
        assert_eq!(h.min(), Some(10));
        assert_eq!(h.max(), Some(30));
        assert_eq!(h.count(), 3);
    }

    #[test]
    fn bucket_boundaries() {
        let mut h = LatencyHistogram::new();
        h.record(0);
        h.record(1);
        h.record(2);
        h.record(3);
        h.record(4);
        let b = h.nonzero_buckets();
        // bucket [1,1] has 0,1; [2,3] has 2,3; [4,7] has 4
        assert_eq!(b, vec![(1, 1, 2), (2, 3, 2), (4, 7, 1)]);
    }

    #[test]
    fn percentile_monotone() {
        let mut h = LatencyHistogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let p50 = h.percentile(50.0).unwrap();
        let p99 = h.percentile(99.0).unwrap();
        assert!(p50 <= p99);
        assert!(p50 >= 256 && p50 <= 1024, "p50 bucket bound {p50}");
    }

    #[test]
    fn merge_combines() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(5);
        b.record(100);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.min(), Some(5));
        assert_eq!(a.max(), Some(100));
        assert_eq!(a.mean(), 52.5);
    }

    #[test]
    fn percentile_delta_reflects_only_the_new_samples() {
        let mut h = LatencyHistogram::new();
        for _ in 0..100 {
            h.record(8);
        }
        let earlier = h.clone();
        assert_eq!(h.percentile_delta(&earlier, 99.0), 0, "empty delta");
        for _ in 0..10 {
            h.record(1000);
        }
        // the cumulative p50 is still fast-dominated, but the delta
        // contains only slow samples
        assert!(h.percentile(50.0).unwrap() <= 16);
        let d50 = h.percentile_delta(&earlier, 50.0);
        assert!(d50 >= 1000 && d50 <= 1024, "{d50}");
        // a delta covering the whole history matches the plain percentile
        let empty = LatencyHistogram::new();
        assert_eq!(h.percentile_delta(&empty, 99.0), h.percentile(99.0).unwrap());
    }

    #[test]
    fn large_values_clamp_to_last_bucket() {
        let mut h = LatencyHistogram::new();
        h.record(u64::MAX);
        assert_eq!(h.count(), 1);
        assert_eq!(h.max(), Some(u64::MAX));
    }

    #[test]
    fn percentile_saturates_to_recorded_max() {
        // the bucket upper bound can overstate the real tail: 100
        // samples of 100 all land in [64,127], whose nominal bound 128
        // exceeds every recorded value
        let mut h = LatencyHistogram::new();
        for _ in 0..100 {
            h.record(100);
        }
        assert_eq!(h.percentile(99.0), Some(100), "saturated to max, not the 128 bound");
        assert_eq!(h.percentile(50.0), Some(100));
    }

    #[test]
    fn percentile_overflow_bucket_reports_max_not_stale_bound() {
        // regression at the overflow edge: samples >= 2^31 all share
        // bucket 31, whose nominal 2^32 bound *understates* the tail —
        // p99 must saturate to the recorded max instead
        let mut h = LatencyHistogram::new();
        h.record(10);
        let huge = 1u64 << 40;
        for _ in 0..99 {
            h.record(huge);
        }
        assert_eq!(h.percentile(99.0), Some(huge), "not the stale 2^32 bucket bound");
        assert_eq!(h.percentile(100.0), Some(huge));
        // when the recorded max lies above a non-overflow bucket's bound,
        // that nominal bound is kept (it does not overstate anything)
        let mut h = LatencyHistogram::new();
        h.record(1u64 << 30); // bucket 30: [2^30, 2^31)
        h.record(1u64 << 40); // overflow bucket holds the max
        assert_eq!(h.percentile(50.0), Some(1u64 << 31), "nominal bound below max is kept");
    }
}
