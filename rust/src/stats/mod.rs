//! Performance counters and derived statistics.
//!
//! §II-B/§II-C: each traffic generator exposes hardware counters —
//! "including two counters for the clock cycles taken by batches of read
//! and write memory access transactions" — and the host computes
//! throughput by dividing batch execution time by the number of
//! transactions. This module is those counters plus the derived metrics
//! (GB/s, latency percentiles, refresh degradation).

pub mod histogram;

pub use histogram::LatencyHistogram;

use crate::config::SpeedBin;

/// Raw hardware-style counters of one TG batch (all in AXI clock cycles
/// unless stated otherwise).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BatchCounters {
    /// Read transactions completed.
    pub rd_txns: u64,
    /// Write transactions completed.
    pub wr_txns: u64,
    /// Read payload bytes moved.
    pub rd_bytes: u64,
    /// Write payload bytes moved.
    pub wr_bytes: u64,
    /// AXI cycles from batch start to the last read completion (the
    /// paper's read-batch cycle counter).
    pub rd_cycles: u64,
    /// AXI cycles from batch start to the last write completion.
    pub wr_cycles: u64,
    /// AXI cycles from batch start to full batch completion.
    pub total_cycles: u64,
    /// DRAM command slots stalled by refresh during the batch.
    pub refresh_stall_dram_cycles: u64,
    /// Data-integrity mismatches detected on read-back (0 = clean).
    pub mismatches: u64,
    /// Read-latency histogram (AXI cycles, AR accept → last R beat).
    pub rd_latency: LatencyHistogram,
    /// Write-latency histogram (AW accept → B response).
    pub wr_latency: LatencyHistogram,
}

impl BatchCounters {
    /// Merge counters of batches that ran *concurrently* (parallel
    /// channels over one wall-clock interval): work sums, cycle
    /// counters take the max — the channels shared the elapsed time,
    /// so adding their cycle counts would invent time that never
    /// passed. For batches that ran back to back use
    /// [`merge_sequential`](Self::merge_sequential); the old ambiguous
    /// `merge` name is gone precisely because max silently drops time
    /// when misapplied to sequential batches.
    pub fn merge_concurrent(&mut self, other: &BatchCounters) {
        self.merge_work(other);
        self.rd_cycles = self.rd_cycles.max(other.rd_cycles);
        self.wr_cycles = self.wr_cycles.max(other.wr_cycles);
        self.total_cycles = self.total_cycles.max(other.total_cycles);
    }

    /// Merge counters of batches that ran *sequentially* (one after the
    /// other on the same channel): work sums and cycle counters sum
    /// too, so elapsed time accumulates instead of being dropped by the
    /// concurrent max.
    pub fn merge_sequential(&mut self, other: &BatchCounters) {
        self.merge_work(other);
        self.rd_cycles += other.rd_cycles;
        self.wr_cycles += other.wr_cycles;
        self.total_cycles += other.total_cycles;
    }

    /// The merge rules shared by both time conventions: transaction,
    /// byte, stall and mismatch counts sum; histograms merge.
    fn merge_work(&mut self, other: &BatchCounters) {
        self.rd_txns += other.rd_txns;
        self.wr_txns += other.wr_txns;
        self.rd_bytes += other.rd_bytes;
        self.wr_bytes += other.wr_bytes;
        self.refresh_stall_dram_cycles += other.refresh_stall_dram_cycles;
        self.mismatches += other.mismatches;
        self.rd_latency.merge(&other.rd_latency);
        self.wr_latency.merge(&other.wr_latency);
    }
}

/// A finished batch bound to its clock configuration, yielding physical
/// metrics.
#[derive(Debug, Clone)]
pub struct BatchStats {
    /// Raw counters.
    pub counters: BatchCounters,
    /// Speed bin the batch ran at (fixes the AXI clock for GB/s).
    pub speed: SpeedBin,
    /// Channel energy over the batch window (IDD-based model, §II-C
    /// "other statistics").
    pub energy: crate::ddr4::power::EnergyBreakdown,
    /// Windowed telemetry series, when the batch ran with sampling
    /// enabled (`TELEM=`/`--telemetry`/`telemetry =`); `None` otherwise.
    pub telemetry: Option<crate::obs::TelemetrySeries>,
}

impl BatchStats {
    /// AXI clock period in nanoseconds.
    fn axi_ns(&self) -> f64 {
        1000.0 / self.speed.axi_clock_mhz()
    }

    /// Throughput of read transactions in GB/s (bytes over the read-batch
    /// cycle counter — the paper's §II-C formula).
    pub fn read_throughput_gbs(&self) -> f64 {
        if self.counters.rd_cycles == 0 {
            return 0.0;
        }
        self.counters.rd_bytes as f64 / (self.counters.rd_cycles as f64 * self.axi_ns())
    }

    /// Throughput of write transactions in GB/s.
    pub fn write_throughput_gbs(&self) -> f64 {
        if self.counters.wr_cycles == 0 {
            return 0.0;
        }
        self.counters.wr_bytes as f64 / (self.counters.wr_cycles as f64 * self.axi_ns())
    }

    /// Combined throughput in GB/s over the whole batch (mixed workloads:
    /// total bytes over total cycles).
    pub fn total_throughput_gbs(&self) -> f64 {
        if self.counters.total_cycles == 0 {
            return 0.0;
        }
        (self.counters.rd_bytes + self.counters.wr_bytes) as f64
            / (self.counters.total_cycles as f64 * self.axi_ns())
    }

    /// Mean read latency in nanoseconds.
    pub fn read_latency_ns(&self) -> f64 {
        self.counters.rd_latency.mean() * self.axi_ns()
    }

    /// Mean write latency in nanoseconds.
    pub fn write_latency_ns(&self) -> f64 {
        self.counters.wr_latency.mean() * self.axi_ns()
    }

    /// Read-latency percentile in nanoseconds (log2-bucket upper bound,
    /// saturated to the recorded maximum — see
    /// [`LatencyHistogram::percentile`]; 0.0 when no reads ran).
    pub fn read_latency_pct_ns(&self, p: f64) -> f64 {
        self.counters.rd_latency.percentile(p).map(|c| c as f64 * self.axi_ns()).unwrap_or(0.0)
    }

    /// Write-latency percentile in nanoseconds (0.0 when no writes ran).
    pub fn write_latency_pct_ns(&self, p: f64) -> f64 {
        self.counters.wr_latency.percentile(p).map(|c| c as f64 * self.axi_ns()).unwrap_or(0.0)
    }

    /// Energy per transferred bit in picojoules (None when no data moved).
    pub fn pj_per_bit(&self) -> Option<f64> {
        self.energy.pj_per_bit(self.counters.rd_bytes + self.counters.wr_bytes)
    }

    /// Average channel power over the batch, in milliwatts.
    pub fn avg_power_mw(&self) -> f64 {
        let elapsed_ns =
            self.counters.total_cycles as f64 * crate::ddr4::AXI_RATIO as f64 * self.speed.tck_ns();
        self.energy.avg_mw(elapsed_ns)
    }

    /// Fraction of DRAM command slots lost to refresh (0..1) — the
    /// "refresh-related performance degradation" statistic.
    pub fn refresh_degradation(&self) -> f64 {
        let dram_cycles = self.counters.total_cycles * crate::ddr4::AXI_RATIO;
        if dram_cycles == 0 {
            return 0.0;
        }
        self.counters.refresh_stall_dram_cycles as f64 / dram_cycles as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(rd_bytes: u64, rd_cycles: u64, speed: SpeedBin) -> BatchStats {
        BatchStats {
            counters: BatchCounters {
                rd_bytes,
                rd_cycles,
                total_cycles: rd_cycles,
                ..Default::default()
            },
            speed,
            energy: Default::default(),
            telemetry: None,
        }
    }

    #[test]
    fn throughput_formula_matches_paper_units() {
        // 6.4 GB/s = 32 B per 5 ns AXI cycle at DDR4-1600 (200 MHz).
        let s = stats(32_000, 1000, SpeedBin::Ddr4_1600);
        assert!((s.read_throughput_gbs() - 6.4).abs() < 1e-9);
    }

    #[test]
    fn throughput_scales_with_axi_clock() {
        let a = stats(32_000, 1000, SpeedBin::Ddr4_1600);
        let b = stats(32_000, 1000, SpeedBin::Ddr4_2400);
        assert!((b.read_throughput_gbs() / a.read_throughput_gbs() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn zero_cycles_is_zero_throughput() {
        let s = stats(100, 0, SpeedBin::Ddr4_1600);
        assert_eq!(s.read_throughput_gbs(), 0.0);
        assert_eq!(s.total_throughput_gbs(), 0.0);
    }

    #[test]
    fn merge_concurrent_accumulates_work_and_maxes_time() {
        let mut a =
            BatchCounters { rd_txns: 10, rd_bytes: 100, rd_cycles: 50, ..Default::default() };
        let b = BatchCounters { rd_txns: 5, rd_bytes: 70, rd_cycles: 80, ..Default::default() };
        a.merge_concurrent(&b);
        assert_eq!(a.rd_txns, 15);
        assert_eq!(a.rd_bytes, 170);
        assert_eq!(a.rd_cycles, 80, "cycle counters take the max (parallel channels)");
    }

    #[test]
    fn merge_sequential_accumulates_time_too() {
        // regression for the old single `merge`: aggregating two
        // back-to-back batches with the concurrent max silently dropped
        // the first batch's elapsed time, halving it into a 2x
        // throughput overstatement
        let base = BatchCounters {
            rd_txns: 10,
            rd_bytes: 32_000,
            rd_cycles: 1000,
            wr_cycles: 400,
            total_cycles: 1000,
            ..Default::default()
        };
        let mut seq = base.clone();
        seq.merge_sequential(&base);
        assert_eq!(seq.rd_txns, 20);
        assert_eq!(seq.rd_cycles, 2000, "sequential batches accumulate elapsed time");
        assert_eq!(seq.wr_cycles, 800);
        assert_eq!(seq.total_cycles, 2000);
        let mut conc = base.clone();
        conc.merge_concurrent(&base);
        assert_eq!(conc.total_cycles, 1000, "concurrent channels share elapsed time");
        // the derived throughput of a sequential double-run must equal
        // the single run's, not double it
        let single = BatchStats {
            counters: base,
            speed: SpeedBin::Ddr4_1600,
            energy: Default::default(),
            telemetry: None,
        };
        let doubled = BatchStats {
            counters: seq,
            speed: SpeedBin::Ddr4_1600,
            energy: Default::default(),
            telemetry: None,
        };
        assert!((single.read_throughput_gbs() - doubled.read_throughput_gbs()).abs() < 1e-12);
    }

    #[test]
    fn latency_percentiles_reach_physical_units() {
        let mut s = stats(0, 1000, SpeedBin::Ddr4_1600);
        assert_eq!(s.read_latency_pct_ns(99.0), 0.0, "empty histogram");
        for v in 1..=100u64 {
            s.counters.rd_latency.record(v);
        }
        let (p50, p95, p99) = (
            s.read_latency_pct_ns(50.0),
            s.read_latency_pct_ns(95.0),
            s.read_latency_pct_ns(99.0),
        );
        assert!(p50 > 0.0 && p50 <= p95 && p95 <= p99, "{p50}/{p95}/{p99}");
        // AXI cycle at DDR4-1600 is 5 ns: bucket bounds scale by it
        assert_eq!(p50 % 5.0, 0.0);
        assert_eq!(s.write_latency_pct_ns(99.0), 0.0, "no writes ran");
    }

    #[test]
    fn latency_percentiles_saturate_to_recorded_max() {
        // the overflow edge in physical units: a pathological sample far
        // above the top histogram bucket must surface as itself, not as
        // the stale 2^32-cycle bucket bound
        let mut s = stats(0, 1000, SpeedBin::Ddr4_1600);
        let huge = 1u64 << 40;
        s.counters.rd_latency.record(10);
        for _ in 0..99 {
            s.counters.rd_latency.record(huge);
        }
        let p99 = s.read_latency_pct_ns(99.0);
        assert!((p99 - huge as f64 * 5.0).abs() < 1e-3, "p99 {p99} vs max {}", huge * 5);
    }

    #[test]
    fn refresh_degradation_fraction() {
        let mut s = stats(0, 1000, SpeedBin::Ddr4_1600);
        s.counters.refresh_stall_dram_cycles = 400; // of 4000 DRAM cycles
        assert!((s.refresh_degradation() - 0.1).abs() < 1e-12);
    }
}
