//! MIG-like DDR4 memory controller (the paper's "memory interface" minus
//! the analog PHY).
//!
//! §II-A: *"The memory controller subcomponent receives as its inputs read
//! and write requests, possibly concurrently, buffers and reorders them to
//! boost performance while maintaining data integrity, and then passes them
//! to the PHY layer"* — this module is exactly that subcomponent:
//!
//! - separate **read and write queues** fed concurrently by the AXI front
//!   end, with configurable depths;
//! - a **runtime-selectable scheduler** behind the [`sched::SchedPolicy`]
//!   trait: FR-FCFS open page (the MIG-like default), strict FCFS, a
//!   bypass-capped FR-FCFS, closed page (auto-precharge) and an adaptive
//!   idle-timer page policy — see [`sched`] and [`sched::SchedKind`];
//! - **write draining** with high/low watermarks to batch bus turnarounds;
//! - **refresh insertion** on the tREFI cadence (PREA + REF, tRFC stall);
//! - the **PHY command serialization** model: one DDR4 command slot per
//!   DRAM clock — the 4:1 PHY:AXI clock ratio means up to four command
//!   slots per fabric cycle, matching §II-A's "issue multiple commands to
//!   DDR4 at a time".
//!
//! The controller is decomposed as front end + scheduler: this module
//! owns the queues, the read/write direction state machine, refresh and
//! the miss-flush gates, and delegates every scheduling *choice* to the
//! policy engine in [`sched`]. The default policy reproduces the
//! pre-refactor monolithic scheduler command-for-command (differential
//! proptest in `rust/tests/frfcfs_differential.rs`).
//!
//! Data integrity under reordering is preserved the same way MIG does it:
//! requests to the *same DRAM burst address* are never reordered past each
//! other, under every policy (checked by `same-address ordering` in the
//! property tests; the hazard check lives in the shared scan of [`sched`],
//! outside any policy hook, and both enforcement points share one
//! predicate — [`request::older_same_addr`]).
//!
//! Scheduling decisions run on the incrementally-indexed fast path in
//! [`sched_index`] (per-address occupancy, per-(bank,row) wanted counts,
//! epoch-memoized candidate sets maintained at the queue mutation
//! points); the scans in [`sched`] stay in-tree as the frozen oracle,
//! selected by [`ControllerParams::sched_oracle`] and pinned bit-exact
//! by `rust/tests/sched_index_differential.rs`.

pub mod request;
pub mod sched;
pub mod sched_index;

pub use request::{Completion, MemRequest};
pub use sched::{SchedEngine, SchedKind, SchedPolicy};

use std::collections::VecDeque;

use crate::check::{Auditor, StreamStart};
use crate::config::ControllerParams;
use crate::ddr4::{Cmd, Cycle, DdrDevice, DramGeometry, TimingParams};
use crate::obs::{CmdTrace, TraceCmd, TraceEvent};

/// Scheduler direction mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    Read,
    Write,
}

/// Refresh state machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RefreshState {
    Idle,
    /// PREA issued / pending; waiting to issue REF.
    Draining,
}

/// Controller-side statistics (beyond the device command counts).
#[derive(Debug, Clone, Copy, Default)]
pub struct CtrlStats {
    /// Cycles spent with the command slot blocked by refresh (PREA-to-end
    /// of tRFC). Feeds the "refresh-related performance degradation"
    /// statistic of §II-C.
    pub refresh_stall_cycles: u64,
    /// Read→write and write→read mode switches.
    pub mode_switches: u64,
    /// Requests that arrived to a full queue (back-pressure events).
    pub queue_rejects: u64,
}

/// The memory controller for one channel.
#[derive(Debug, Clone)]
pub struct MemController {
    params: ControllerParams,
    /// The scheduling/page policy in force (runtime-swappable).
    sched: SchedEngine,
    /// Incremental scheduling indexes (the tick fast path), maintained
    /// at every queue mutation point; see [`sched_index`]. Kept in sync
    /// even when `params.sched_oracle` routes decisions to the scans,
    /// so the flag can be flipped mid-run (differential tests do).
    index: sched_index::SchedIndex,
    device: DdrDevice,
    read_q: VecDeque<MemRequest>,
    write_q: VecDeque<MemRequest>,
    completions: VecDeque<Completion>, // sorted by done_at (CAS issue order)
    mode: Mode,
    refresh: RefreshState,
    refresh_started: Cycle,
    /// Page-miss pipeline-flush gates per direction (see
    /// [`ControllerParams::miss_flush`]): no new transaction of that
    /// direction is accepted by the front end before this cycle.
    read_gate_until: Cycle,
    write_gate_until: Cycle,
    /// Cycle at which the scheduler last switched direction (dwell timer).
    mode_entered: Cycle,
    /// Last CAS issue time per bank (adaptive page-policy timer).
    bank_last_use: Vec<Cycle>,
    /// External input (push) since the last full scheduler evaluation.
    dirty: bool,
    /// No internally-triggered event can occur before this cycle: between
    /// external inputs the controller is deterministic, so when a full
    /// evaluation issues nothing it computes the earliest cycle at which
    /// any candidate becomes legal and sleeps until then (the tick
    /// fast-path; §Perf).
    idle_until: Cycle,
    stats: CtrlStats,
    /// Bounded DRAM command ring, recording at every issue point when
    /// enabled at runtime (`--cmd-trace` / host `TRACEDUMP`). `None`
    /// (the default) keeps tracing entirely off the hot path.
    cmd_trace: Option<CmdTrace>,
    /// Live protocol auditor tapping the same issue funnel when armed
    /// (`--audit` / host `AUDIT`). Observation-only, like the trace
    /// ring: `None` (the default) costs one branch per issued command.
    auditor: Option<Auditor>,
}

impl MemController {
    /// Build a controller around a fresh device.
    pub fn new(params: ControllerParams, timing: TimingParams, geometry: DramGeometry) -> Self {
        let banks = geometry.banks() as usize;
        Self {
            bank_last_use: vec![0; banks],
            dirty: true,
            idle_until: 0,
            sched: SchedEngine::new(params.sched),
            index: sched_index::SchedIndex::new(banks),
            params,
            device: DdrDevice::new(timing, geometry),
            read_q: VecDeque::with_capacity(params.read_queue_depth),
            write_q: VecDeque::with_capacity(params.write_queue_depth),
            completions: VecDeque::new(),
            mode: Mode::Read,
            refresh: RefreshState::Idle,
            refresh_started: 0,
            read_gate_until: 0,
            write_gate_until: 0,
            mode_entered: 0,
            stats: CtrlStats::default(),
            cmd_trace: None,
            auditor: None,
        }
    }

    /// The underlying device model (for statistics).
    pub fn device(&self) -> &DdrDevice {
        &self.device
    }

    /// Controller statistics.
    pub fn stats(&self) -> &CtrlStats {
        &self.stats
    }

    /// Start recording DRAM commands into a bounded ring of `cap`
    /// events (replacing any previous ring). Until this is called,
    /// tracing costs one branch per issued command.
    pub fn enable_cmd_trace(&mut self, cap: usize) {
        self.cmd_trace = Some(CmdTrace::new(cap));
    }

    /// The command ring, when tracing is enabled. Reading is
    /// non-destructive: the ring keeps filling across batches until
    /// re-armed or the controller is rebuilt.
    pub fn cmd_trace(&self) -> Option<&CmdTrace> {
        self.cmd_trace.as_ref()
    }

    /// Arm the live protocol auditor (replacing any previous one). It
    /// sees every command from this point on — no ring in between. A
    /// device that has already issued commands yields a truncated
    /// stream (violations still detected, but no CLEAN certificate);
    /// arming before the first batch audits the complete stream.
    pub fn enable_audit(&mut self) {
        let s = self.device.stats();
        let issued = s.acts + s.pres + s.reads + s.writes + s.refreshes;
        let start =
            if issued == 0 { StreamStart::Complete } else { StreamStart::Truncated };
        self.auditor = Some(Auditor::new(self.device.timing(), start));
    }

    /// The live auditor, when armed. Reading is non-destructive: the
    /// auditor keeps accumulating across batches until re-armed or the
    /// controller is rebuilt.
    pub fn auditor(&self) -> Option<&Auditor> {
        self.auditor.as_ref()
    }

    /// Record `cmd` into the trace ring and/or the live auditor (when
    /// armed), then issue it to the device — the single funnel every
    /// controller issue point goes through, so neither observer can
    /// miss a command class.
    fn issue_cmd(&mut self, cmd: Cmd, now: Cycle) -> Cycle {
        if self.cmd_trace.is_some() || self.auditor.is_some() {
            let ev = self.trace_event(cmd, now);
            if let Some(auditor) = self.auditor.as_mut() {
                auditor.observe(&ev);
            }
            if let Some(trace) = self.cmd_trace.as_mut() {
                trace.record(ev);
            }
        }
        // Any issued command can change row states / timing horizons:
        // invalidate the scheduler's decision memos.
        self.index.bump();
        self.device.issue(cmd, now)
    }

    /// Build the trace record for `cmd`: ACT carries its target row;
    /// CAS/PRE are annotated with the row currently open in their bank
    /// (read *before* issue — PRE and auto-precharge close it); the
    /// all-bank commands (PREA/REF) use 0 sentinels throughout.
    fn trace_event(&self, cmd: Cmd, now: Cycle) -> TraceEvent {
        let group_of = |bank: u32| bank / self.device.geometry().banks_per_group;
        let (tcmd, bank_group, bank, row) = match cmd {
            Cmd::Act { bank, row } => (TraceCmd::Act, group_of(bank), bank, row),
            Cmd::Pre { bank } => {
                (TraceCmd::Pre, group_of(bank), bank, self.device.open_row(bank).unwrap_or(0))
            }
            Cmd::Rd { bank, auto_pre, .. } => {
                let tcmd = if auto_pre { TraceCmd::Rda } else { TraceCmd::Rd };
                (tcmd, group_of(bank), bank, self.device.open_row(bank).unwrap_or(0))
            }
            Cmd::Wr { bank, auto_pre, .. } => {
                let tcmd = if auto_pre { TraceCmd::Wra } else { TraceCmd::Wr };
                (tcmd, group_of(bank), bank, self.device.open_row(bank).unwrap_or(0))
            }
            Cmd::PreAll => (TraceCmd::PreAll, 0, 0, 0),
            Cmd::Ref => (TraceCmd::Ref, 0, 0, 0),
        };
        TraceEvent { cycle: now, cmd: tcmd, bank_group, bank, row }
    }

    /// Microarchitectural parameters in force.
    pub fn params(&self) -> &ControllerParams {
        &self.params
    }

    /// The active scheduling/page policy.
    pub fn sched_kind(&self) -> SchedKind {
        self.sched.kind()
    }

    /// Swap the scheduling/page policy at run time (a batch-level
    /// `SCHED=` override). Queued work and bank state carry over; the
    /// policy's internal state (e.g. the bypass streak) starts fresh.
    pub fn set_sched(&mut self, kind: SchedKind) {
        if self.sched.kind() != kind {
            self.sched = SchedEngine::new(kind);
            self.params.sched = kind;
            // the new policy may issue earlier than the cached wake time,
            // and memoized candidate sets assume the old policy's window
            self.dirty = true;
            self.index.bump();
        }
    }

    /// Free slots in the read queue.
    pub fn read_slots(&self) -> usize {
        self.params.read_queue_depth - self.read_q.len()
    }

    /// Free slots in the write queue.
    pub fn write_slots(&self) -> usize {
        self.params.write_queue_depth - self.write_q.len()
    }

    /// Is the read request queue empty (serial-front-end gate)?
    pub fn read_queue_empty(&self) -> bool {
        self.read_q.is_empty()
    }

    /// Is the write request queue empty (serial-front-end gate)?
    pub fn write_queue_empty(&self) -> bool {
        self.write_q.is_empty()
    }

    /// Earliest DRAM cycle at which the front end accepts a new
    /// transaction of the given direction (page-miss pipeline flush; 0
    /// when `miss_flush` is off or no miss is in flight).
    pub fn frontend_gate(&self, is_write: bool) -> Cycle {
        if is_write {
            self.write_gate_until
        } else {
            self.read_gate_until
        }
    }

    /// Is all queued work drained (queues and in-flight completions empty)?
    pub fn is_idle(&self) -> bool {
        self.read_q.is_empty() && self.write_q.is_empty() && self.completions.is_empty()
    }

    /// Enqueue a request from the AXI front end. `Err(req)` = queue full
    /// (AXI back-pressure; the front end must retry).
    pub fn try_push(&mut self, req: MemRequest) -> Result<(), MemRequest> {
        let cap =
            if req.is_write { self.params.write_queue_depth } else { self.params.read_queue_depth };
        let len = if req.is_write { self.write_q.len() } else { self.read_q.len() };
        if len >= cap {
            self.stats.queue_rejects += 1;
            return Err(req);
        }
        let q = if req.is_write { &mut self.write_q } else { &mut self.read_q };
        q.push_back(req);
        self.index.on_push(&req);
        // A new request may be issuable before the cached wake time:
        // force a full evaluation on the next tick. (A precise per-request
        // wake computation was measured slower — the evaluation happens
        // within a few cycles anyway under load; see EXPERIMENTS.md §Perf.)
        self.dirty = true;
        Ok(())
    }

    /// Earliest DRAM cycle at or after `now` at which [`Self::tick`]
    /// could do *anything* — the event-engine contract generalizing the
    /// `idle_until` single-tick fast path. Every returned tick is safe
    /// to leap to because the fast path between `now` and the returned
    /// cycle is side-effect free: ticks are skippable exactly when the
    /// last full evaluation proved no candidate (CAS, ACT/PRE prep,
    /// direction switch, idle precharge) becomes legal earlier *and*
    /// the refresh engine is parked (`idle_until` is always bounded by
    /// the tREFI deadline and the mode-dwell grace window, so a leap
    /// can never overshoot either). Returns `now` whenever a skip would
    /// change behaviour: an un-consumed external input (`dirty`), an
    /// active refresh (every drained cycle charges
    /// `refresh_stall_cycles`), or a stale/expired wake.
    pub fn next_event(&self, now: Cycle) -> Cycle {
        if self.dirty || self.refresh != RefreshState::Idle || self.idle_until <= now {
            now
        } else {
            self.idle_until
        }
    }

    /// DRAM cycle at which the oldest in-flight completion finishes its
    /// data phase (`None` when nothing is in flight). The deque is kept
    /// sorted by `done_at`, so the front is the earliest — the event
    /// engine's wake source for [`Self::pop_completions`].
    pub fn next_completion_at(&self) -> Option<Cycle> {
        self.completions.front().map(|c| c.done_at)
    }

    /// Pop completions whose data phase has finished by `now`.
    pub fn pop_completions(&mut self, now: Cycle, out: &mut Vec<Completion>) {
        while let Some(c) = self.completions.front() {
            if c.done_at <= now {
                out.push(*c);
                self.completions.pop_front();
            } else {
                break;
            }
        }
    }

    /// Advance one DRAM clock: issue at most one DDR4 command (the PHY
    /// command-slot model). Returns the issued command, if any.
    pub fn tick(&mut self, now: Cycle) -> Option<Cmd> {
        // Fast path: between external inputs the controller is
        // deterministic. If the last full evaluation issued nothing and
        // computed that no candidate becomes legal before `idle_until`,
        // skip the scan entirely (dominates random-pattern simulation,
        // where most cycles wait on row timing or the miss-flush gate).
        if !self.dirty && now < self.idle_until && self.refresh == RefreshState::Idle {
            return None;
        }
        self.dirty = false;
        let cmd = self.tick_eval(now);
        if cmd.is_some() {
            // state changed: earlier events may now be possible
            self.idle_until = 0;
        }
        cmd
    }

    /// Test-only: run a full scheduler evaluation at `now`, bypassing
    /// the `idle_until`/dirty fast path. The wake-conservatism property
    /// test drives this on cloned controllers to prove that every cycle
    /// the fast path skips is a cycle the scheduler would issue nothing.
    #[doc(hidden)]
    pub fn debug_force_eval(&mut self, now: Cycle) -> Option<Cmd> {
        self.tick_eval(now)
    }

    /// Test-only: flip between the indexed fast path and the frozen
    /// scan oracle mid-run (the indexes stay maintained either way).
    #[doc(hidden)]
    pub fn debug_set_oracle(&mut self, oracle: bool) {
        self.params.sched_oracle = oracle;
        self.dirty = true;
    }

    /// Test-only: the cycle the tick fast path sleeps to, if the next
    /// tick would take the fast path at all (`None` when a full
    /// evaluation is pending anyway — un-consumed input or an active
    /// refresh).
    #[doc(hidden)]
    pub fn debug_sleep_until(&self) -> Option<Cycle> {
        (!self.dirty && self.refresh == RefreshState::Idle).then_some(self.idle_until)
    }

    /// Test-only: validate the incremental indexes against a
    /// from-scratch recount of both queues.
    #[doc(hidden)]
    pub fn debug_assert_index_consistent(&self) {
        self.index.assert_consistent(&self.read_q, &self.write_q);
    }

    /// Full scheduler evaluation (the slow path of [`Self::tick`]); sets
    /// `idle_until` when nothing can issue.
    fn tick_eval(&mut self, now: Cycle) -> Option<Cmd> {
        // 1. Refresh has absolute priority once due (data integrity).
        if self.refresh != RefreshState::Idle || self.device.refresh_needed(now) {
            if let Some(cmd) = self.tick_refresh(now) {
                return Some(cmd);
            }
            // Refresh in progress but no command this cycle (waiting on
            // timing): the slot is a refresh stall.
            if self.refresh != RefreshState::Idle {
                self.stats.refresh_stall_cycles += 1;
                return None;
            }
        }

        // 2. Direction selection with watermark + dwell hysteresis.
        self.update_mode(now);
        let mut wake = self.device.refresh_due();
        // a pending grace (dwell/4) or dwell expiry can change the mode
        if !self.read_q.is_empty() || !self.write_q.is_empty() {
            wake = wake.min(self.mode_entered + (self.params.mode_dwell_ck / 4).max(1) as Cycle);
        }

        // 3. FR-FCFS: try a CAS in the current direction.
        match self.try_cas(now) {
            (Some(cmd), _) => return Some(cmd),
            (None, w) => wake = wake.min(w),
        }

        // 4. Prepare rows (ACT/PRE) for the current direction...
        match self.try_prep(now, self.mode) {
            (Some(cmd), _) => return Some(cmd),
            (None, w) => wake = wake.min(w),
        }
        // ...and opportunistically for the other direction on idle slots.
        let other = match self.mode {
            Mode::Read => Mode::Write,
            Mode::Write => Mode::Read,
        };
        match self.try_prep(now, other) {
            (Some(cmd), _) => return Some(cmd),
            (None, w) => wake = wake.min(w),
        }
        // 5. Adaptive page policy: speculatively close rows idle longer
        //    than the configured timer (0 = pure open-page, never close).
        match self.try_idle_precharge(now) {
            (Some(cmd), _) => return Some(cmd),
            (None, w) => wake = wake.min(w),
        }
        self.idle_until = wake.max(now + 1);
        None
    }

    /// Scheduling view over the queues of `mode` (active) and its
    /// opposite (hazards), for the policy engine.
    fn sched_view(&self, mode: Mode, now: Cycle) -> sched::SchedView<'_> {
        let (active, other) = match mode {
            Mode::Read => (&self.read_q, &self.write_q),
            Mode::Write => (&self.write_q, &self.read_q),
        };
        sched::SchedView {
            device: &self.device,
            params: &self.params,
            active,
            other,
            is_write: mode == Mode::Write,
            bank_last_use: &self.bank_last_use,
            now,
        }
    }

    /// [`sched::SchedView`] assembled from explicit field borrows, so a
    /// call site can hold `&mut self.index` alongside it (the
    /// whole-`self` borrow of [`Self::sched_view`] could not).
    fn view_parts<'a>(
        device: &'a DdrDevice,
        params: &'a ControllerParams,
        read_q: &'a VecDeque<MemRequest>,
        write_q: &'a VecDeque<MemRequest>,
        bank_last_use: &'a [Cycle],
        mode: Mode,
        now: Cycle,
    ) -> sched::SchedView<'a> {
        let (active, other) = match mode {
            Mode::Read => (read_q, write_q),
            Mode::Write => (write_q, read_q),
        };
        sched::SchedView {
            device,
            params,
            active,
            other,
            is_write: mode == Mode::Write,
            bank_last_use,
            now,
        }
    }

    /// Close an open row that has sat unused past the policy's idle
    /// timer and that no queued request still wants — turns the next
    /// access to that bank from a 2-command conflict (PRE+ACT) into a
    /// plain ACT, trading sequential locality for random-access latency
    /// (the page-policy ablation bench quantifies the trade). The timer
    /// is policy-defined: 0 (never) for open-page policies unless the
    /// `idle_precharge_cycles` knob is set, always-on for `adaptive`.
    fn try_idle_precharge(&mut self, now: Cycle) -> (Option<Cmd>, Cycle) {
        // The view direction is immaterial here (the wanted test spans
        // both queues); Mode::Read matches the oracle call convention.
        let (bank, wake) = if self.params.sched_oracle {
            self.sched.pick_idle_precharge(&self.sched_view(Mode::Read, now))
        } else {
            let v = Self::view_parts(
                &self.device,
                &self.params,
                &self.read_q,
                &self.write_q,
                &self.bank_last_use,
                Mode::Read,
                now,
            );
            sched_index::pick_idle_precharge_indexed(self.sched.policy(), &v, &self.index)
        };
        match bank {
            Some(bank) => {
                let cmd = Cmd::Pre { bank };
                self.issue_cmd(cmd, now);
                (Some(cmd), now)
            }
            None => (None, wake),
        }
    }

    fn tick_refresh(&mut self, now: Cycle) -> Option<Cmd> {
        match self.refresh {
            RefreshState::Idle => {
                self.refresh_started = now;
                if self.device.all_banks_closed() {
                    if self.device.can_issue(Cmd::Ref, now) {
                        self.issue_cmd(Cmd::Ref, now);
                        // tRFC itself stalls the command slot; account it.
                        self.stats.refresh_stall_cycles += self.device.timing().trfc as u64;
                        return Some(Cmd::Ref);
                    }
                    self.refresh = RefreshState::Draining;
                    None
                } else if self.device.can_issue(Cmd::PreAll, now) {
                    self.issue_cmd(Cmd::PreAll, now);
                    self.refresh = RefreshState::Draining;
                    Some(Cmd::PreAll)
                } else {
                    self.refresh = RefreshState::Draining;
                    None
                }
            }
            RefreshState::Draining => {
                if !self.device.all_banks_closed() {
                    if self.device.can_issue(Cmd::PreAll, now) {
                        self.issue_cmd(Cmd::PreAll, now);
                        return Some(Cmd::PreAll);
                    }
                    return None;
                }
                if self.device.can_issue(Cmd::Ref, now) {
                    self.issue_cmd(Cmd::Ref, now);
                    self.refresh = RefreshState::Idle;
                    self.stats.refresh_stall_cycles += self.device.timing().trfc as u64;
                    return Some(Cmd::Ref);
                }
                None
            }
        }
    }

    fn update_mode(&mut self, now: Cycle) {
        let wlen = self.write_q.len();
        let dwell = self.params.mode_dwell_ck as Cycle;
        // Full dwell gates fairness switches under bidirectional load; a
        // quarter-dwell grace bridges the transient empty gaps a serial
        // front end leaves between transactions (prevents per-transaction
        // turnaround thrash).
        let dwell_ok = now >= self.mode_entered + dwell;
        let grace_ok = now >= self.mode_entered + dwell / 4;
        let switch = match self.mode {
            Mode::Read => {
                wlen >= self.params.write_drain_high
                    || self.head_hazard_blocked(false)
                    || (wlen > 0 && dwell_ok && !self.read_q.is_empty())
                    || (wlen > 0 && grace_ok && self.read_q.is_empty())
            }
            Mode::Write => {
                self.head_hazard_blocked(true)
                    || (!self.read_q.is_empty()
                        && (wlen <= self.params.write_drain_low || dwell_ok))
                    || (wlen == 0 && grace_ok && !self.read_q.is_empty())
            }
        };
        if switch {
            self.mode = match self.mode {
                Mode::Read => Mode::Write,
                Mode::Write => Mode::Read,
            };
            self.mode_entered = now;
            self.stats.mode_switches += 1;
        }
    }

    /// Is the oldest request of the active queue blocked by an older
    /// same-address request in the *other* queue? (RAW/WAR hazard that
    /// only draining the other direction can clear — without this check a
    /// write-then-read to one address deadlocks read mode.)
    fn head_hazard_blocked(&self, is_write: bool) -> bool {
        let (q, other) =
            if is_write { (&self.write_q, &self.read_q) } else { (&self.read_q, &self.write_q) };
        let Some(head) = q.front() else { return false };
        request::older_same_addr(other, head.addr, head.arrival)
    }

    /// CAS issue: the policy engine picks the queue entry (row hits
    /// first inside its window for the FR-FCFS family, strict head for
    /// `fcfs`) and decides auto-precharge; the front end commits it.
    /// Same-address ordering: a request is skipped if an older queued
    /// request (either direction) targets the same DRAM burst — the
    /// hazard check lives in the shared scan, policy-independent.
    /// On failure, returns the earliest cycle a scanned candidate becomes
    /// legal (wake hint for the tick fast-path).
    fn try_cas(&mut self, now: Cycle) -> (Option<Cmd>, Cycle) {
        let is_write = self.mode == Mode::Write;
        let (pick, wake) = if self.params.sched_oracle {
            self.sched.pick_cas(&self.sched_view(self.mode, now))
        } else {
            let v = Self::view_parts(
                &self.device,
                &self.params,
                &self.read_q,
                &self.write_q,
                &self.bank_last_use,
                self.mode,
                now,
            );
            sched_index::pick_cas_indexed(self.sched.policy(), &v, &mut self.index)
        };
        let Some(pick) = pick else { return (None, wake) };
        let t = self.device.timing();
        let (cl, cwl, burst) = (t.cl, t.cwl, t.burst_cycles);
        let req = if is_write {
            self.write_q.remove(pick.index).expect("scheduler pick indexes the write queue")
        } else {
            self.read_q.remove(pick.index).expect("scheduler pick indexes the read queue")
        };
        self.index.on_remove(&req, if is_write { &self.write_q } else { &self.read_q });
        let cmd = if is_write {
            Cmd::Wr { bank: req.addr.bank, col: req.addr.col, auto_pre: pick.auto_pre }
        } else {
            Cmd::Rd { bank: req.addr.bank, col: req.addr.col, auto_pre: pick.auto_pre }
        };
        self.issue_cmd(cmd, now);
        self.sched.on_cas_issued(is_write, pick.index);
        self.bank_last_use[req.addr.bank as usize] = now;
        let done_at = now + if is_write { cwl + burst } else { cl + burst } as Cycle;
        // CAS issue order == data order on the bus (tCCD >= burst), so the
        // completion deque stays sorted by done_at per direction; merged
        // order may interleave reads and writes but each is queried by the
        // consumer with `done_at <= now`, so keep globally sorted:
        let comp = Completion {
            txn_id: req.txn_id,
            is_write,
            burst_addr: req.burst_addr,
            beats: req.beats,
            done_at,
            arrival: req.arrival,
            last_of_txn: req.last_of_txn,
        };
        let pos = self
            .completions
            .iter()
            .rposition(|c| c.done_at <= done_at)
            .map(|p| p + 1)
            .unwrap_or(0);
        self.completions.insert(pos, comp);
        (Some(cmd), now)
    }

    /// Row preparation for the oldest serviceable entries of `mode`'s
    /// queue: the policy engine chooses the ACT/PRE target inside its
    /// window; the front end commits it and applies the miss-flush gate.
    fn try_prep(&mut self, now: Cycle, mode: Mode) -> (Option<Cmd>, Cycle) {
        let (action, wake) = if self.params.sched_oracle {
            self.sched.pick_prep(&self.sched_view(mode, now))
        } else {
            let v = Self::view_parts(
                &self.device,
                &self.params,
                &self.read_q,
                &self.write_q,
                &self.bank_last_use,
                mode,
                now,
            );
            sched_index::pick_prep_indexed(self.sched.policy(), &v, &mut self.index)
        };
        match action {
            Some(sched::PrepAction::Act { bank, row }) => {
                let cmd = Cmd::Act { bank, row };
                self.issue_cmd(cmd, now);
                // Page-miss pipeline flush: hold the next transaction of
                // this direction until the miss's data phase completes
                // (+tRP refill). Misses *within* an already-accepted
                // transaction keep pipelining.
                if self.params.miss_flush {
                    let t = self.device.timing();
                    let gate = match mode {
                        Mode::Read => {
                            now + (t.trcd + t.cl + t.burst_cycles + t.trp) as Cycle
                        }
                        Mode::Write => {
                            // writes additionally pay the WR→next-access
                            // turnaround before the pipeline refills
                            now + (t.trcd + t.cwl + t.burst_cycles + t.twr + t.twtr_l)
                                as Cycle
                        }
                    };
                    match mode {
                        Mode::Read => self.read_gate_until = self.read_gate_until.max(gate),
                        Mode::Write => self.write_gate_until = self.write_gate_until.max(gate),
                    }
                }
                (Some(cmd), now)
            }
            Some(sched::PrepAction::Pre { bank }) => {
                let cmd = Cmd::Pre { bank };
                self.issue_cmd(cmd, now);
                (Some(cmd), now)
            }
            None => (None, wake),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SpeedBin;
    use crate::ddr4::DramAddr;

    fn ctrl() -> MemController {
        MemController::new(
            ControllerParams::default(),
            TimingParams::for_bin(SpeedBin::Ddr4_1600),
            DramGeometry::profpga_board(),
        )
    }

    fn rd_req(id: u64, bank: u32, row: u32, col: u32, arrival: Cycle) -> MemRequest {
        MemRequest {
            txn_id: id,
            is_write: false,
            addr: DramAddr { bank, row, col },
            burst_addr: 0,
            beats: 2,
            arrival,
            last_of_txn: true,
        }
    }

    fn wr_req(id: u64, bank: u32, row: u32, col: u32, arrival: Cycle) -> MemRequest {
        MemRequest { is_write: true, ..rd_req(id, bank, row, col, arrival) }
    }

    /// Drive the controller until `n` completions with a deadline guard.
    fn run_until_completions(c: &mut MemController, n: usize, limit: Cycle) -> Vec<Completion> {
        let mut done = Vec::new();
        for now in 0..limit {
            c.tick(now);
            c.pop_completions(now, &mut done);
            if done.len() >= n {
                return done;
            }
        }
        panic!("only {} of {n} completions after {limit} cycles", done.len());
    }

    #[test]
    fn single_read_completes_with_act_rd() {
        let mut c = ctrl();
        c.try_push(rd_req(1, 0, 5, 0, 0)).unwrap();
        let done = run_until_completions(&mut c, 1, 200);
        let t = c.device().timing();
        // ACT@0 → RD@tRCD → data at tRCD+CL+4
        assert_eq!(done[0].done_at, (t.trcd + t.cl + t.burst_cycles) as Cycle);
        assert_eq!(done[0].txn_id, 1);
        assert!(done[0].last_of_txn);
        assert_eq!(c.device().stats().acts, 1);
        assert_eq!(c.device().stats().reads, 1);
    }

    #[test]
    fn row_hits_stream_at_tccd() {
        let mut c = ctrl();
        // 4 reads to the same row: 1 ACT, 4 RDs at tCCD_L spacing.
        for i in 0..4 {
            c.try_push(rd_req(i, 0, 1, 8 * i as u32, 0)).unwrap();
        }
        let done = run_until_completions(&mut c, 4, 400);
        assert_eq!(c.device().stats().acts, 1, "one ACT serves all hits");
        let t = c.device().timing();
        for w in done.windows(2) {
            assert_eq!(w[1].done_at - w[0].done_at, t.tccd_l as Cycle);
        }
    }

    #[test]
    fn frfcfs_prefers_row_hit_over_older_miss() {
        let mut c = ctrl();
        // Open row 1 in bank 0 by completing a first read.
        c.try_push(rd_req(0, 0, 1, 0, 0)).unwrap();
        let _ = run_until_completions(&mut c, 1, 200);
        // Now an older miss (bank 0 row 2) and a younger hit (bank 0 row 1).
        c.try_push(rd_req(1, 0, 2, 0, 1000)).unwrap();
        c.try_push(rd_req(2, 0, 1, 8, 1001)).unwrap();
        let mut done = Vec::new();
        for now in 1000..2000 {
            c.tick(now);
            c.pop_completions(now, &mut done);
            if done.len() == 2 {
                break;
            }
        }
        assert_eq!(done[0].txn_id, 2, "row hit first (FR-FCFS)");
        assert_eq!(done[1].txn_id, 1);
    }

    #[test]
    fn hazard_predicate_shared_by_both_call_sites() {
        // The same-address hazard has two enforcement points — the
        // direction state machine's head test and the scheduler scans —
        // both built on `request::older_same_addr`. Crafted overlap
        // cases must get the same verdict at every call site.
        let mut c = ctrl();
        open_row(&mut c, 1); // bank 0 row 1 open, queues drained
        c.try_push(wr_req(1, 0, 1, 0, 500)).unwrap();
        c.try_push(rd_req(2, 0, 1, 0, 501)).unwrap(); // overlaps the write
        c.try_push(rd_req(3, 0, 1, 8, 502)).unwrap(); // same row, other burst
        assert!(c.head_hazard_blocked(false), "read head overlaps an older write");
        assert!(!c.head_hazard_blocked(true), "write head has no older read");
        // Scan call sites (oracle and indexed): the read-mode pick must
        // skip the blocked head and serve the non-overlapping burst.
        let now = 600;
        let oracle = c.sched.pick_cas(&c.sched_view(Mode::Read, now));
        let v = MemController::view_parts(
            &c.device,
            &c.params,
            &c.read_q,
            &c.write_q,
            &c.bank_last_use,
            Mode::Read,
            now,
        );
        let fast = sched_index::pick_cas_indexed(c.sched.policy(), &v, &mut c.index);
        assert_eq!(fast, oracle, "oracle and indexed hazard verdicts diverge");
        assert_eq!(oracle.0.map(|p| p.index), Some(1), "hazard-free row hit must be served");
        // Equal arrivals tie-break identically (neither direction blocks).
        let mut c = ctrl();
        c.try_push(wr_req(4, 2, 3, 0, 700)).unwrap();
        c.try_push(rd_req(5, 2, 3, 0, 700)).unwrap();
        assert!(!c.head_hazard_blocked(false));
        assert!(!c.head_hazard_blocked(true));
    }

    #[test]
    fn same_address_requests_never_reorder() {
        let mut c = ctrl();
        // write then read to the same burst: read must not overtake.
        c.try_push(wr_req(1, 0, 1, 0, 0)).unwrap();
        c.try_push(rd_req(2, 0, 1, 0, 1)).unwrap();
        let done = run_until_completions(&mut c, 2, 2000);
        let wr = done.iter().find(|c| c.txn_id == 1).unwrap();
        let rd = done.iter().find(|c| c.txn_id == 2).unwrap();
        // The write CAS must issue before the read CAS: write data lands
        // at cwl+4 after its CAS, read at cl+4; compare CAS-issue order.
        let t = c.device().timing();
        let wr_cas = wr.done_at - (t.cwl + t.burst_cycles) as Cycle;
        let rd_cas = rd.done_at - (t.cl + t.burst_cycles) as Cycle;
        assert!(wr_cas < rd_cas, "WAR/RAW hazard: write CAS must precede read CAS");
    }

    #[test]
    fn write_drain_watermarks_batch_writes() {
        let mut c = ctrl();
        // Fill the write queue to the high watermark with a reader present.
        c.try_push(rd_req(100, 0, 1, 0, 0)).unwrap();
        for i in 0..12 {
            c.try_push(wr_req(i, (i % 8) as u32, 3, 0, 0)).unwrap();
        }
        let done = run_until_completions(&mut c, 13, 4000);
        // All writes drained; mode switched at least twice (R->W->R).
        assert!(c.stats().mode_switches >= 1);
        assert_eq!(done.iter().filter(|c| c.is_write).count(), 12);
    }

    #[test]
    fn queue_backpressure() {
        let mut c = ctrl();
        let depth = c.params().read_queue_depth;
        for i in 0..depth as u64 {
            c.try_push(rd_req(i, 0, 1, (8 * i as u32) % 1024, 0)).unwrap();
        }
        assert!(c.try_push(rd_req(99, 0, 1, 512, 0)).is_err());
        assert_eq!(c.stats().queue_rejects, 1);
        assert_eq!(c.read_slots(), 0);
    }

    #[test]
    fn refresh_fires_on_trefi_cadence() {
        let mut c = ctrl();
        let trefi = c.device().timing().trefi as Cycle;
        // Idle controller: run 3 refresh intervals.
        for now in 0..(3 * trefi + 1000) {
            c.tick(now);
        }
        assert_eq!(c.device().stats().refreshes, 3);
        assert!(c.stats().refresh_stall_cycles >= 3 * c.device().timing().trfc as u64);
    }

    #[test]
    fn refresh_closes_open_rows_first() {
        let mut c = ctrl();
        c.try_push(rd_req(1, 0, 7, 0, 0)).unwrap();
        let _ = run_until_completions(&mut c, 1, 200);
        assert!(!c.device().all_banks_closed());
        let trefi = c.device().timing().trefi as Cycle;
        for now in 200..trefi + 2000 {
            c.tick(now);
        }
        assert_eq!(c.device().stats().refreshes, 1);
    }

    #[test]
    fn is_idle_tracks_inflight_work() {
        let mut c = ctrl();
        assert!(c.is_idle());
        c.try_push(rd_req(1, 0, 1, 0, 0)).unwrap();
        assert!(!c.is_idle());
        let _ = run_until_completions(&mut c, 1, 200);
        assert!(c.is_idle());
    }

    #[test]
    fn idle_precharge_closes_stale_rows() {
        let mut params = ControllerParams::default();
        params.idle_precharge_cycles = 64;
        let mut c = MemController::new(
            params,
            TimingParams::for_bin(SpeedBin::Ddr4_1600),
            DramGeometry::profpga_board(),
        );
        c.try_push(rd_req(1, 0, 5, 0, 0)).unwrap();
        let _ = run_until_completions(&mut c, 1, 300);
        assert!(!c.device().all_banks_closed());
        // idle long enough: the timer closes the row
        for now in 300..600 {
            c.tick(now);
        }
        assert!(c.device().all_banks_closed(), "stale row must be precharged");
        // with timer 0 (pure open page) the row would have stayed open
        let mut open = MemController::new(
            ControllerParams::default(),
            TimingParams::for_bin(SpeedBin::Ddr4_1600),
            DramGeometry::profpga_board(),
        );
        open.try_push(rd_req(1, 0, 5, 0, 0)).unwrap();
        let _ = run_until_completions(&mut open, 1, 300);
        for now in 300..600 {
            open.tick(now);
        }
        assert!(!open.device().all_banks_closed(), "open-page keeps the row");
    }

    #[test]
    fn idle_precharge_spares_wanted_rows() {
        let mut params = ControllerParams::default();
        params.idle_precharge_cycles = 16;
        params.lookahead = 1; // keep the second request un-servable prep-wise
        let mut c = MemController::new(
            params,
            TimingParams::for_bin(SpeedBin::Ddr4_1600),
            DramGeometry::profpga_board(),
        );
        // open row 5 in bank 0, then park a queued request to the same row
        // behind a full-queue stall so it lingers
        c.try_push(rd_req(1, 0, 5, 0, 0)).unwrap();
        let _ = run_until_completions(&mut c, 1, 300);
        c.try_push(rd_req(2, 0, 5, 8, 301)).unwrap();
        // the wanted row must not be speculatively closed before service
        let mut done = Vec::new();
        for now in 301..600 {
            c.tick(now);
            c.pop_completions(now, &mut done);
            if !done.is_empty() {
                break;
            }
        }
        assert_eq!(done.len(), 1, "request to the open row served");
        // one ACT total: the row was never closed under the request
        assert_eq!(c.device().stats().acts, 1);
    }

    fn ctrl_with_sched(kind: SchedKind) -> MemController {
        MemController::new(
            ControllerParams { sched: kind, ..Default::default() },
            TimingParams::for_bin(SpeedBin::Ddr4_1600),
            DramGeometry::profpga_board(),
        )
    }

    /// Open row `row` in bank 0 by completing one read through `c`.
    fn open_row(c: &mut MemController, row: u32) {
        c.try_push(rd_req(0, 0, row, 0, 0)).unwrap();
        let _ = run_until_completions(c, 1, 400);
    }

    #[test]
    fn fcfs_serves_strictly_in_order() {
        // The same scenario where FR-FCFS reorders (older miss vs younger
        // hit): strict FCFS must serve arrival order.
        let mut c = ctrl_with_sched(SchedKind::Fcfs);
        open_row(&mut c, 1);
        c.try_push(rd_req(1, 0, 2, 0, 1000)).unwrap(); // older miss
        c.try_push(rd_req(2, 0, 1, 8, 1001)).unwrap(); // younger hit
        let mut done = Vec::new();
        for now in 1000..3000 {
            c.tick(now);
            c.pop_completions(now, &mut done);
            if done.len() == 2 {
                break;
            }
        }
        assert_eq!(done[0].txn_id, 1, "fcfs serves the older miss first");
        assert_eq!(done[1].txn_id, 2);
    }

    #[test]
    fn frfcfs_cap_bounds_the_bypass_streak() {
        // An older miss parked behind a stream of younger hits: plain
        // FR-FCFS serves every hit first; the capped variant lets at most
        // `cap` hits bypass before forcing the miss through.
        let run_policy = |kind: SchedKind| -> Vec<u64> {
            let mut c = ctrl_with_sched(kind);
            open_row(&mut c, 1);
            c.try_push(rd_req(1, 0, 2, 0, 1000)).unwrap(); // the starving miss
            for i in 0..8u64 {
                c.try_push(rd_req(2 + i, 0, 1, 8 * (i as u32 + 1), 1001 + i)).unwrap();
            }
            let mut done = Vec::new();
            for now in 1000..20_000 {
                c.tick(now);
                c.pop_completions(now, &mut done);
                if done.len() == 9 {
                    break;
                }
            }
            assert_eq!(done.len(), 9, "{kind}: all requests served");
            done.iter().map(|d| d.txn_id).collect()
        };
        let frfcfs = run_policy(SchedKind::FrFcfs);
        assert_eq!(frfcfs.last(), Some(&1), "open page starves the miss to the end");
        let capped = run_policy(SchedKind::FrFcfsCap { cap: 2 });
        let pos = capped.iter().position(|&id| id == 1).unwrap();
        assert!(pos <= 2, "cap=2 bounds the bypass streak, miss at {pos} in {capped:?}");
    }

    #[test]
    fn closed_page_auto_precharges_served_rows() {
        let mut c = ctrl_with_sched(SchedKind::Closed);
        open_row(&mut c, 5);
        assert!(
            c.device().all_banks_closed(),
            "closed page: the CAS carried auto-precharge"
        );
        // a second access to the same row pays a fresh ACT
        c.try_push(rd_req(1, 0, 5, 8, 500)).unwrap();
        let mut done = Vec::new();
        for now in 500..1000 {
            c.tick(now);
            c.pop_completions(now, &mut done);
        }
        assert_eq!(done.len(), 1);
        assert_eq!(c.device().stats().acts, 2, "row reopened per visit");
        // open page keeps the row open in the same scenario
        let mut open = ctrl();
        open_row(&mut open, 5);
        assert!(!open.device().all_banks_closed());
    }

    #[test]
    fn closed_page_keeps_rows_wanted_by_queued_requests() {
        let mut c = ctrl_with_sched(SchedKind::Closed);
        // 4 back-to-back hits queued together: only the last auto-precharges
        for i in 0..4 {
            c.try_push(rd_req(i, 0, 1, 8 * i as u32, 0)).unwrap();
        }
        let done = run_until_completions(&mut c, 4, 600);
        assert_eq!(done.len(), 4);
        assert_eq!(c.device().stats().acts, 1, "one ACT serves the queued hits");
        assert!(c.device().all_banks_closed(), "last CAS closed the row");
    }

    #[test]
    fn adaptive_closes_idle_rows_without_the_knob() {
        // Default knobs (idle_precharge_cycles = 0): frfcfs keeps the row
        // open forever, adaptive falls back to its built-in timer.
        let mut c = ctrl_with_sched(SchedKind::Adaptive);
        open_row(&mut c, 5);
        assert!(!c.device().all_banks_closed());
        for now in 400..1000 {
            c.tick(now);
        }
        assert!(c.device().all_banks_closed(), "adaptive timer closed the stale row");
    }

    #[test]
    fn set_sched_swaps_policy_live() {
        let mut c = ctrl();
        assert_eq!(c.sched_kind(), SchedKind::FrFcfs);
        open_row(&mut c, 3);
        c.set_sched(SchedKind::Closed);
        assert_eq!(c.sched_kind(), SchedKind::Closed);
        assert_eq!(c.params().sched, SchedKind::Closed);
        // queued work keeps flowing under the new policy
        c.try_push(rd_req(1, 0, 3, 8, 500)).unwrap();
        let mut done = Vec::new();
        for now in 500..1000 {
            c.tick(now);
            c.pop_completions(now, &mut done);
        }
        assert_eq!(done.len(), 1);
        assert!(c.device().all_banks_closed(), "closed-page behaviour took effect");
    }

    #[test]
    fn completions_sorted_by_done_at() {
        let mut c = ctrl();
        for i in 0..8u64 {
            c.try_push(rd_req(i, (i % 8) as u32, 1, 0, 0)).unwrap();
            c.try_push(wr_req(100 + i, ((i + 3) % 8) as u32, 2, 8, 0)).unwrap();
        }
        let mut done = Vec::new();
        for now in 0..5000 {
            c.tick(now);
            c.pop_completions(now, &mut done);
        }
        assert_eq!(done.len(), 16);
        for w in done.windows(2) {
            assert!(w[0].done_at <= w[1].done_at, "completion order");
        }
    }

    #[test]
    fn cmd_trace_records_every_issue_point_with_rows() {
        let mut c = ctrl();
        c.enable_cmd_trace(1024);
        c.try_push(rd_req(1, 0, 5, 0, 0)).unwrap();
        c.try_push(wr_req(2, 1, 9, 0, 0)).unwrap();
        let _ = run_until_completions(&mut c, 2, 2000);
        // run across a refresh deadline so PREA/REF are traced too
        let trefi = c.device().timing().trefi as Cycle;
        for now in 2000..trefi + 2000 {
            c.tick(now);
        }
        let trace = c.cmd_trace().expect("tracing armed");
        let cmds: Vec<TraceCmd> = trace.events().map(|e| e.cmd).collect();
        assert!(cmds.contains(&TraceCmd::Act));
        assert!(cmds.contains(&TraceCmd::Rd));
        assert!(cmds.contains(&TraceCmd::Wr));
        assert!(cmds.contains(&TraceCmd::Ref), "{cmds:?}");
        // the device's own command counts corroborate the ring
        let s = c.device().stats();
        let traced_acts = trace.events().filter(|e| e.cmd == TraceCmd::Act).count() as u64;
        assert_eq!(traced_acts, s.acts, "one trace event per issued ACT");
        // ACT and its CAS agree on the row; cycles are non-decreasing
        let act = trace.events().find(|e| e.cmd == TraceCmd::Act && e.bank == 0).unwrap();
        let rd = trace.events().find(|e| e.cmd == TraceCmd::Rd && e.bank == 0).unwrap();
        assert_eq!((act.row, rd.row), (5, 5), "CAS annotated with the open row");
        let cycles: Vec<u64> = trace.events().map(|e| e.cycle).collect();
        assert!(cycles.windows(2).all(|w| w[0] <= w[1]));
        // untraced controller records nothing
        assert!(ctrl().cmd_trace().is_none());
    }

    #[test]
    fn cmd_trace_does_not_perturb_the_run() {
        let pushes = vec![
            (0, rd_req(1, 0, 1, 0, 0)),
            (0, wr_req(2, 3, 7, 8, 0)),
            (10, rd_req(3, 1, 2, 0, 10)),
        ];
        let (mut plain, mut traced) = (ctrl(), ctrl());
        traced.enable_cmd_trace(4);
        let (done_a, _) = drive_cycle_stepped(&mut plain, pushes.clone(), 2000);
        let (done_b, _) = drive_cycle_stepped(&mut traced, pushes, 2000);
        assert_eq!(done_a, done_b, "tracing is observation-only");
        assert_eq!(plain.device().stats(), traced.device().stats());
    }

    #[test]
    fn next_event_contract_basics() {
        let mut c = ctrl();
        assert_eq!(c.next_event(0), 0, "fresh controller must be ticked");
        assert!(c.tick(0).is_none());
        let due = c.device().refresh_due();
        assert_eq!(c.next_event(1), due, "idle wake is the tREFI deadline");
        assert_eq!(c.next_event(due + 5), due + 5, "expired wake forces a tick");
        c.try_push(rd_req(1, 0, 1, 0, 1)).unwrap();
        assert_eq!(c.next_event(1), 1, "un-consumed push (dirty) forces a tick");
        assert!(c.tick(1).is_some(), "the push turns into an ACT");
        let w = c.next_event(2);
        assert!(w >= 2 && w <= c.device().refresh_due(), "wake never overshoots tREFI");
    }

    #[test]
    fn next_completion_at_tracks_front_of_flight() {
        let mut c = ctrl();
        assert_eq!(c.next_completion_at(), None);
        c.try_push(rd_req(1, 0, 5, 0, 0)).unwrap();
        let done_at = {
            let t = c.device().timing();
            (t.trcd + t.cl + t.burst_cycles) as Cycle
        };
        for now in 0..done_at {
            c.tick(now);
            if let Some(d) = c.next_completion_at() {
                assert_eq!(d, done_at, "front of the deque is the earliest data phase");
            }
        }
        let mut out = Vec::new();
        c.pop_completions(done_at, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(c.next_completion_at(), None, "drained flight publishes no wake");
    }

    /// Drive cycle-by-cycle (the oracle): push scheduled requests, tick
    /// every DRAM cycle, pop completions. Returns ((txn, done_at) log,
    /// tick count).
    fn drive_cycle_stepped(
        c: &mut MemController,
        mut pushes: Vec<(Cycle, MemRequest)>,
        n: Cycle,
    ) -> (Vec<(u64, Cycle)>, u64) {
        let mut popped = Vec::new();
        let mut ticks = 0u64;
        for now in 0..n {
            while !pushes.is_empty() && pushes[0].0 == now {
                c.try_push(pushes.remove(0).1).unwrap();
            }
            c.tick(now);
            ticks += 1;
            c.pop_completions(now, &mut popped);
        }
        (popped.iter().map(|d| (d.txn_id, d.done_at)).collect(), ticks)
    }

    /// Drive via the event contract — the platform's time-skip loop in
    /// miniature: leap to `next_event`, clamped by pending completions
    /// and by the scheduled external pushes.
    fn drive_event_skipped(
        c: &mut MemController,
        mut pushes: Vec<(Cycle, MemRequest)>,
        n: Cycle,
    ) -> (Vec<(u64, Cycle)>, u64) {
        let mut popped = Vec::new();
        let mut ticks = 0u64;
        let mut now: Cycle = 0;
        while now < n {
            while !pushes.is_empty() && pushes[0].0 == now {
                c.try_push(pushes.remove(0).1).unwrap();
            }
            c.tick(now);
            ticks += 1;
            c.pop_completions(now, &mut popped);
            let mut next = c.next_event(now + 1).max(now + 1);
            if let Some(d) = c.next_completion_at() {
                next = next.min(d.max(now + 1));
            }
            if let Some(&(t, _)) = pushes.first() {
                next = next.min(t);
            }
            now = next;
        }
        (popped.iter().map(|d| (d.txn_id, d.done_at)).collect(), ticks)
    }

    fn refresh_timing() -> (Cycle, Cycle) {
        let c = ctrl();
        let t = c.device().timing();
        (t.trefi as Cycle, t.trfc as Cycle)
    }

    #[test]
    fn event_leap_across_trefi_matches_cycle_stepping() {
        // Traffic early, then a long idle window spanning several tREFI
        // deadlines: the event drive leaps straight to each REF and must
        // charge the identical refresh_stall_cycles lump at each one.
        let (trefi, _) = refresh_timing();
        let n = 3 * trefi + 500;
        let pushes = || {
            vec![
                (0, rd_req(1, 0, 1, 0, 0)),
                (0, rd_req(2, 3, 7, 8, 0)),
                (10, wr_req(3, 1, 2, 0, 10)),
            ]
        };
        let (mut a, mut b) = (ctrl(), ctrl());
        let (done_a, ticks_a) = drive_cycle_stepped(&mut a, pushes(), n);
        let (done_b, ticks_b) = drive_event_skipped(&mut b, pushes(), n);
        assert_eq!(done_a, done_b, "completion log identical");
        assert_eq!(a.stats().refresh_stall_cycles, b.stats().refresh_stall_cycles);
        assert!(a.stats().refresh_stall_cycles > 0, "scenario crossed refresh deadlines");
        assert_eq!(a.stats().mode_switches, b.stats().mode_switches);
        assert_eq!(a.device().stats(), b.device().stats(), "command stream identical");
        assert!(ticks_b * 5 < ticks_a, "event drive skipped: {ticks_b} vs {ticks_a} ticks");
    }

    #[test]
    fn event_landing_mid_trfc_matches_cycle_stepping() {
        // Requests arriving right before a tREFI deadline (forcing the
        // refresh engine through its drain state) and inside the tRFC
        // window: stall accounting must not drift by a single cycle.
        let (trefi, trfc) = refresh_timing();
        let n = 2 * trefi;
        let pushes = || {
            vec![
                (trefi - 2, rd_req(1, 0, 1, 0, trefi - 2)),
                (trefi + 3, wr_req(2, 1, 2, 0, trefi + 3)),
                (trefi + trfc / 2, rd_req(3, 2, 5, 0, trefi + trfc / 2)),
            ]
        };
        let (mut a, mut b) = (ctrl(), ctrl());
        let (done_a, ticks_a) = drive_cycle_stepped(&mut a, pushes(), n);
        let (done_b, ticks_b) = drive_event_skipped(&mut b, pushes(), n);
        assert_eq!(done_a, done_b, "completion log identical");
        assert_eq!(a.stats().refresh_stall_cycles, b.stats().refresh_stall_cycles);
        assert!(a.stats().refresh_stall_cycles >= trfc, "tRFC lump charged");
        assert_eq!(a.device().stats(), b.device().stats(), "command stream identical");
        assert!(ticks_b < ticks_a, "event drive skipped: {ticks_b} vs {ticks_a} ticks");
    }
}
