//! Native-interface requests and completion events of the memory
//! controller.
//!
//! The AXI front end splits each AXI transaction into BL8-sized *requests*
//! (one per 64-byte DRAM burst touched). Requests are what the FR-FCFS
//! scheduler reorders; completions carry enough context to rebuild AXI
//! beats and transaction boundaries on the way back.

use crate::axi::TxnId;
use crate::ddr4::{Cycle, DramAddr};

/// One DRAM-burst-sized unit of work in the controller queues.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemRequest {
    /// Owning AXI transaction.
    pub txn_id: TxnId,
    /// Write or read?
    pub is_write: bool,
    /// Decoded DRAM location of the BL8 burst.
    pub addr: DramAddr,
    /// 64-byte-aligned byte address of the burst (kept alongside the
    /// decoded form for the data-integrity path).
    pub burst_addr: u64,
    /// Number of AXI data beats this request carries (usually 2 on a
    /// 256-bit fabric; FIXED bursts replay up to 16 beats from one burst).
    pub beats: u32,
    /// DRAM cycle at which the request entered the controller (for
    /// latency statistics and FCFS age).
    pub arrival: Cycle,
    /// Is this the last request of its transaction? (Completion of this
    /// request completes the transaction: last R beat / B response.)
    pub last_of_txn: bool,
}

/// A completed request, reported at its data-phase completion time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Completion {
    /// Owning AXI transaction.
    pub txn_id: TxnId,
    /// Write or read?
    pub is_write: bool,
    /// 64-byte-aligned byte address of the burst.
    pub burst_addr: u64,
    /// AXI beats carried.
    pub beats: u32,
    /// DRAM cycle at which data finished on the bus (reads: last beat
    /// received; writes: write burst retired to the array timing-wise).
    pub done_at: Cycle,
    /// Arrival cycle of the underlying request (latency = done - arrival).
    pub arrival: Cycle,
    /// Completes its transaction?
    pub last_of_txn: bool,
}

impl Completion {
    /// Request latency in DRAM cycles.
    pub fn latency(&self) -> Cycle {
        self.done_at - self.arrival
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn completion_latency() {
        let c = Completion {
            txn_id: 1,
            is_write: false,
            burst_addr: 0,
            beats: 2,
            done_at: 120,
            arrival: 100,
            last_of_txn: true,
        };
        assert_eq!(c.latency(), 20);
    }
}
