//! Native-interface requests and completion events of the memory
//! controller.
//!
//! The AXI front end splits each AXI transaction into BL8-sized *requests*
//! (one per 64-byte DRAM burst touched). Requests are what the FR-FCFS
//! scheduler reorders; completions carry enough context to rebuild AXI
//! beats and transaction boundaries on the way back.

use crate::axi::TxnId;
use crate::ddr4::{Cycle, DramAddr};

/// One DRAM-burst-sized unit of work in the controller queues.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemRequest {
    /// Owning AXI transaction.
    pub txn_id: TxnId,
    /// Write or read?
    pub is_write: bool,
    /// Decoded DRAM location of the BL8 burst.
    pub addr: DramAddr,
    /// 64-byte-aligned byte address of the burst (kept alongside the
    /// decoded form for the data-integrity path).
    pub burst_addr: u64,
    /// Number of AXI data beats this request carries (usually 2 on a
    /// 256-bit fabric; FIXED bursts replay up to 16 beats from one burst).
    pub beats: u32,
    /// DRAM cycle at which the request entered the controller (for
    /// latency statistics and FCFS age).
    pub arrival: Cycle,
    /// Is this the last request of its transaction? (Completion of this
    /// request completes the transaction: last R beat / B response.)
    pub last_of_txn: bool,
}

/// A completed request, reported at its data-phase completion time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Completion {
    /// Owning AXI transaction.
    pub txn_id: TxnId,
    /// Write or read?
    pub is_write: bool,
    /// 64-byte-aligned byte address of the burst.
    pub burst_addr: u64,
    /// AXI beats carried.
    pub beats: u32,
    /// DRAM cycle at which data finished on the bus (reads: last beat
    /// received; writes: write burst retired to the array timing-wise).
    pub done_at: Cycle,
    /// Arrival cycle of the underlying request (latency = done - arrival).
    pub arrival: Cycle,
    /// Completes its transaction?
    pub last_of_txn: bool,
}

impl Completion {
    /// Request latency in DRAM cycles.
    pub fn latency(&self) -> Cycle {
        self.done_at - self.arrival
    }
}

/// Does `queue` hold a request to the same DRAM burst as (`addr`,
/// `arrival`) that arrived strictly earlier?
///
/// This is the controller's same-address data-integrity predicate,
/// shared by its two enforcement points — the head-of-queue hazard test
/// in the direction state machine (`MemController::head_hazard_blocked`)
/// and the per-candidate check in the scheduler scans
/// (`sched::reordered_past_same_addr`) — so the two call sites cannot
/// drift apart. Ties (equal arrival) do not block: the queues are FIFO
/// per direction, so an equal-arrival same-address pair can only be the
/// request itself.
pub fn older_same_addr<'a, I>(queue: I, addr: DramAddr, arrival: Cycle) -> bool
where
    I: IntoIterator<Item = &'a MemRequest>,
{
    queue.into_iter().any(|r| r.addr == addr && r.arrival < arrival)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(bank: u32, row: u32, col: u32, arrival: Cycle) -> MemRequest {
        MemRequest {
            txn_id: 0,
            is_write: false,
            addr: DramAddr { bank, row, col },
            burst_addr: 0,
            beats: 2,
            arrival,
            last_of_txn: true,
        }
    }

    #[test]
    fn older_same_addr_requires_exact_addr_and_strictly_older_arrival() {
        let a = DramAddr { bank: 1, row: 7, col: 8 };
        let queue = [req(1, 7, 8, 10), req(1, 7, 16, 5), req(2, 7, 8, 0)];
        // strictly older same-address entry blocks
        assert!(older_same_addr(&queue, a, 20));
        // equal arrival does not (can only be the request itself)
        assert!(!older_same_addr(&queue, a, 10));
        assert!(!older_same_addr(&queue, a, 9));
        // same row/col in another bank, or another col, never matches
        assert!(!older_same_addr(&queue, DramAddr { bank: 3, row: 7, col: 8 }, 100));
        assert!(older_same_addr(&queue, DramAddr { bank: 1, row: 7, col: 16 }, 100));
        // empty queue
        assert!(!older_same_addr(&[], a, 100));
    }

    #[test]
    fn completion_latency() {
        let c = Completion {
            txn_id: 1,
            is_write: false,
            burst_addr: 0,
            beats: 2,
            done_at: 120,
            arrival: 100,
            last_of_txn: true,
        };
        assert_eq!(c.latency(), 20);
    }
}
