//! Runtime-selectable command-scheduling and page-management policies —
//! the back half of the decomposed memory controller.
//!
//! "The Memory Controller Wall" (arXiv:1910.06726) shows that the
//! *scheduler*, not the access pattern alone, decides how much of the
//! DDR4 pin bandwidth survives to the fabric; the HBM benchmarking work
//! (arXiv:2005.04324) sweeps controller behaviour as a first-class axis.
//! This module makes that axis runtime-selectable here too: the
//! controller front end ([`super::MemController`]) owns the queues,
//! direction selection, refresh and the miss-flush gates, and delegates
//! every *choice* — which CAS to issue, which row to prepare, when to
//! speculatively close a row, whether a CAS carries auto-precharge — to
//! a [`SchedPolicy`] behind [`SchedEngine`].
//!
//! Policies ([`SchedKind`]):
//!
//! | name | reorders | page management | bounds |
//! |---|---|---|---|
//! | `fcfs` | nothing (window 1) | open page | strict arrival order |
//! | `frfcfs` | row hits first | open page | window = `lookahead` |
//! | `frfcfs-cap[N]` | row hits first | open page | ≤ N consecutive bypasses |
//! | `closed` | row hits first | auto-precharge (RDA/WRA) | window = `lookahead` |
//! | `adaptive` | row hits first | idle-timer precharge | window = `lookahead` |
//!
//! Every policy preserves the controller's two hard contracts:
//!
//! - **same-address ordering** — requests to one DRAM burst never
//!   reorder (the hazard check lives in the shared scan, so no policy
//!   can bypass it);
//! - **the `idle_until` fast path** — each decision function reports the
//!   earliest cycle at which any candidate could become legal, so the
//!   controller can sleep between external inputs exactly as the
//!   monolithic scheduler did (§Perf; `benches/micro_hotpath.rs` has a
//!   per-policy deep-queue benchmark).
//!
//! The `frfcfs` policy is the pre-refactor scheduler, preserved
//! command-for-command (differential-tested against a frozen copy of
//! the monolith in `rust/tests/frfcfs_differential.rs`); it is the
//! default everywhere.
//!
//! The scan implementations in this module (`pick_cas_impl` and
//! friends) are additionally the **frozen oracle** for the indexed
//! scheduler fast path in [`super::sched_index`]: production ticks run
//! the incremental indexes, and `ControllerParams::sched_oracle`
//! selects these scans instead. `rust/tests/sched_index_differential.rs`
//! pins the two command-for-command, so treat any change here as a
//! semantic change to both implementations.

use std::collections::VecDeque;

use crate::config::ControllerParams;
use crate::ddr4::{Cmd, Cycle, DdrDevice};

use super::request::{older_same_addr, MemRequest};

// The policy *identifier* is a plain config value (like `MappingPolicy`)
// and lives with the other knobs in `config`; this module implements the
// behaviour behind it.
pub use crate::config::SchedKind;

/// Idle-precharge timer (DRAM cycles) the `adaptive` policy falls back
/// to when `ControllerParams::idle_precharge_cycles` is 0 (the open-page
/// default would otherwise make `adaptive` identical to `frfcfs`).
pub const ADAPTIVE_IDLE_CK: u32 = 64;

/// Read-only scheduling context for one decision: the device (timing and
/// bank state), the knobs, the active-direction queue and its opposite
/// (same-address hazards), and the per-bank last-use clock.
pub struct SchedView<'a> {
    /// Device model (row states, `earliest_issue`, timing).
    pub device: &'a DdrDevice,
    /// Microarchitectural knobs in force.
    pub params: &'a ControllerParams,
    /// Queue of the direction being scheduled.
    pub active: &'a VecDeque<MemRequest>,
    /// The opposite direction's queue (hazard/row-wanted checks).
    pub other: &'a VecDeque<MemRequest>,
    /// Is the active direction the write direction?
    pub is_write: bool,
    /// Last CAS issue time per bank (idle-precharge timers).
    pub bank_last_use: &'a [Cycle],
    /// Current DRAM cycle.
    pub now: Cycle,
}

/// A CAS selection: which queue entry to issue and whether the CAS
/// carries auto-precharge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CasPick {
    /// Index into the active queue (pre-removal).
    pub index: usize,
    /// Issue as RDA/WRA (closed-page management).
    pub auto_pre: bool,
}

/// A row-preparation selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrepAction {
    /// Activate `row` in `bank` for a pending request.
    Act {
        /// Flat bank index.
        bank: u32,
        /// Row to open.
        row: u32,
    },
    /// Precharge `bank` to clear a row conflict.
    Pre {
        /// Flat bank index.
        bank: u32,
    },
}

/// The policy interface every scheduler implements. The shared scans
/// ([`SchedEngine::pick_cas`] et al.) consult these hooks, so a policy
/// is four decisions — window size, auto-precharge, idle timer, and a
/// bypass observer — while queue/hazard mechanics stay common (and the
/// same-address invariant cannot be opted out of).
pub trait SchedPolicy: std::fmt::Debug {
    /// Which [`SchedKind`] this policy implements.
    fn kind(&self) -> SchedKind;

    /// Reorder window for CAS selection and row preparation of the
    /// given direction at this instant (1 = strict in-order).
    fn window(&self, params: &ControllerParams, _is_write: bool) -> usize {
        params.lookahead
    }

    /// Should the CAS picked at `index` carry auto-precharge?
    fn auto_precharge(&self, _view: &SchedView<'_>, _index: usize) -> bool {
        false
    }

    /// Effective idle-precharge timer in DRAM cycles (0 = never close
    /// speculatively).
    fn idle_timer(&self, params: &ControllerParams) -> u32 {
        params.idle_precharge_cycles
    }

    /// Observe a CAS issue in the given direction; `index` is the picked
    /// position in the pre-removal queue (0 = that direction's oldest
    /// request was served).
    fn on_cas_issued(&mut self, _is_write: bool, _index: usize) {}
}

/// Strict in-order scheduling (window 1, open page).
#[derive(Debug, Clone, Copy, Default)]
pub struct Fcfs;

impl SchedPolicy for Fcfs {
    fn kind(&self) -> SchedKind {
        SchedKind::Fcfs
    }

    fn window(&self, _params: &ControllerParams, _is_write: bool) -> usize {
        1
    }
}

/// FR-FCFS, open page — the default policy (pre-refactor behaviour).
#[derive(Debug, Clone, Copy, Default)]
pub struct FrFcfs;

impl SchedPolicy for FrFcfs {
    fn kind(&self) -> SchedKind {
        SchedKind::FrFcfs
    }
}

/// FR-FCFS with a consecutive-bypass cap, tracked per direction: after
/// `cap` CAS issues that overtook that queue's oldest request, the
/// direction's window collapses to 1 until its head is served (bounded
/// starvation). Per-direction streaks keep the bound meaningful under
/// mixed traffic — serving the *write* head must not forgive bypasses
/// of a starving *read* head, and a read-side cap must not needlessly
/// strangle the write queue's reordering.
#[derive(Debug, Clone, Copy)]
pub struct FrFcfsCap {
    cap: u32,
    /// Consecutive head bypasses, indexed by `is_write`.
    streak: [u32; 2],
}

impl FrFcfsCap {
    /// New capped scheduler.
    pub fn new(cap: u32) -> Self {
        Self { cap, streak: [0; 2] }
    }

    /// Consecutive bypasses of the given direction's head since it was
    /// last served.
    pub fn streak(&self, is_write: bool) -> u32 {
        self.streak[usize::from(is_write)]
    }
}

impl SchedPolicy for FrFcfsCap {
    fn kind(&self) -> SchedKind {
        SchedKind::FrFcfsCap { cap: self.cap }
    }

    fn window(&self, params: &ControllerParams, is_write: bool) -> usize {
        if self.streak[usize::from(is_write)] >= self.cap {
            1
        } else {
            params.lookahead
        }
    }

    fn on_cas_issued(&mut self, is_write: bool, index: usize) {
        let streak = &mut self.streak[usize::from(is_write)];
        if index == 0 {
            *streak = 0;
        } else {
            *streak += 1;
        }
    }
}

/// Closed-page management: a CAS auto-precharges its row unless some
/// other queued request (either direction, whole queue) still wants it.
#[derive(Debug, Clone, Copy, Default)]
pub struct ClosedPage;

impl SchedPolicy for ClosedPage {
    fn kind(&self) -> SchedKind {
        SchedKind::Closed
    }

    fn auto_precharge(&self, view: &SchedView<'_>, index: usize) -> bool {
        let req = &view.active[index];
        let (bank, row) = (req.addr.bank, req.addr.row);
        let wanted = view
            .active
            .iter()
            .enumerate()
            .any(|(j, r)| j != index && r.addr.bank == bank && r.addr.row == row)
            || view.other.iter().any(|r| r.addr.bank == bank && r.addr.row == row);
        !wanted
    }

    fn idle_timer(&self, _params: &ControllerParams) -> u32 {
        0 // rows close themselves at CAS time
    }
}

/// Open page with an always-on idle-precharge timer: the pre-existing
/// heuristic, given a non-zero default so it differs from pure open
/// page even on an untouched knob profile.
#[derive(Debug, Clone, Copy, Default)]
pub struct AdaptiveOpen;

impl SchedPolicy for AdaptiveOpen {
    fn kind(&self) -> SchedKind {
        SchedKind::Adaptive
    }

    fn idle_timer(&self, params: &ControllerParams) -> u32 {
        if params.idle_precharge_cycles > 0 {
            params.idle_precharge_cycles
        } else {
            ADAPTIVE_IDLE_CK
        }
    }
}

/// The instantiated policy. The [`SchedPolicy`] trait is the decision
/// interface; the enum exists so the controller stays `Clone` without
/// boxed-clone machinery. Decisions dispatch through a `&dyn
/// SchedPolicy` — a handful of virtual hook calls per scheduler
/// evaluation, which the per-policy deep-queue series in
/// `benches/micro_hotpath.rs` tracks against the monolith's cost.
#[derive(Debug, Clone)]
pub enum SchedEngine {
    /// Strict in-order.
    Fcfs(Fcfs),
    /// FR-FCFS (default).
    FrFcfs(FrFcfs),
    /// FR-FCFS with a bypass cap.
    Cap(FrFcfsCap),
    /// Closed page.
    Closed(ClosedPage),
    /// Adaptive open page.
    Adaptive(AdaptiveOpen),
}

impl SchedEngine {
    /// Instantiate the policy for `kind`.
    pub fn new(kind: SchedKind) -> Self {
        match kind {
            SchedKind::Fcfs => SchedEngine::Fcfs(Fcfs),
            SchedKind::FrFcfs => SchedEngine::FrFcfs(FrFcfs),
            SchedKind::FrFcfsCap { cap } => SchedEngine::Cap(FrFcfsCap::new(cap)),
            SchedKind::Closed => SchedEngine::Closed(ClosedPage),
            SchedKind::Adaptive => SchedEngine::Adaptive(AdaptiveOpen),
        }
    }

    /// The wrapped policy as a trait object (the decision interface).
    pub fn policy(&self) -> &dyn SchedPolicy {
        match self {
            SchedEngine::Fcfs(p) => p,
            SchedEngine::FrFcfs(p) => p,
            SchedEngine::Cap(p) => p,
            SchedEngine::Closed(p) => p,
            SchedEngine::Adaptive(p) => p,
        }
    }

    fn policy_mut(&mut self) -> &mut dyn SchedPolicy {
        match self {
            SchedEngine::Fcfs(p) => p,
            SchedEngine::FrFcfs(p) => p,
            SchedEngine::Cap(p) => p,
            SchedEngine::Closed(p) => p,
            SchedEngine::Adaptive(p) => p,
        }
    }

    /// The policy's identifier.
    pub fn kind(&self) -> SchedKind {
        self.policy().kind()
    }

    /// CAS selection over the active queue: the first legal row hit in
    /// the policy window that does not overtake an older same-address
    /// request. On no pick, returns the earliest cycle a scanned
    /// candidate becomes legal (wake hint for the tick fast path).
    pub fn pick_cas(&self, v: &SchedView<'_>) -> (Option<CasPick>, Cycle) {
        pick_cas_impl(self.policy(), v)
    }

    /// Row-preparation selection (ACT closed banks, PRE conflicting
    /// rows) for the oldest serviceable entries in the policy window.
    pub fn pick_prep(&self, v: &SchedView<'_>) -> (Option<PrepAction>, Cycle) {
        pick_prep_impl(self.policy(), v)
    }

    /// Idle-timer precharge selection: a bank whose open row has sat
    /// unused past the policy's timer and that no queued request wants.
    pub fn pick_idle_precharge(&self, v: &SchedView<'_>) -> (Option<u32>, Cycle) {
        pick_idle_precharge_impl(self.policy(), v)
    }

    /// Observe a CAS issue in the given direction (index into the
    /// pre-removal queue).
    pub fn on_cas_issued(&mut self, is_write: bool, index: usize) {
        self.policy_mut().on_cas_issued(is_write, index);
    }
}

impl Default for SchedEngine {
    fn default() -> Self {
        SchedEngine::new(SchedKind::FrFcfs)
    }
}

/// Would issuing active-queue entry `i` overtake an older same-address
/// entry (same queue, or older arrival in the opposite queue)? This is
/// the data-integrity invariant; it is enforced here, outside any
/// policy hook, so no policy can reorder same-address requests. The
/// opposite-queue half shares [`older_same_addr`] with the controller's
/// head-of-queue hazard test; the same-queue half is positional (any
/// same-address entry ahead of `i` blocks, regardless of arrival tie).
fn reordered_past_same_addr(v: &SchedView<'_>, i: usize) -> bool {
    let target = v.active[i].addr;
    if v.active.iter().take(i).any(|r| r.addr == target) {
        return true;
    }
    older_same_addr(v.other, target, v.active[i].arrival)
}

fn pick_cas_impl(p: &dyn SchedPolicy, v: &SchedView<'_>) -> (Option<CasPick>, Cycle) {
    let look = p.window(v.params, v.is_write);
    let mut pick: Option<usize> = None;
    let mut wake = Cycle::MAX;
    for (i, req) in v.active.iter().take(look).enumerate() {
        if v.device.row_state(req.addr.bank, req.addr.row) == Some(true) {
            let cmd = if v.is_write {
                Cmd::Wr { bank: req.addr.bank, col: req.addr.col, auto_pre: false }
            } else {
                Cmd::Rd { bank: req.addr.bank, col: req.addr.col, auto_pre: false }
            };
            if reordered_past_same_addr(v, i) {
                continue; // hazard: cleared by a future issue (dirty)
            }
            let at = v.device.earliest_issue(cmd);
            if at <= v.now {
                pick = Some(i);
                break;
            }
            wake = wake.min(at);
        }
    }
    match pick {
        Some(i) => (Some(CasPick { index: i, auto_pre: p.auto_precharge(v, i) }), v.now),
        None => (None, wake),
    }
}

fn pick_prep_impl(p: &dyn SchedPolicy, v: &SchedView<'_>) -> (Option<PrepAction>, Cycle) {
    let look = p.window(v.params, v.is_write);
    // Collect candidate (bank,row) prep targets oldest-first; dedup
    // banks so we don't try to ACT one bank twice in a window.
    let mut seen_banks = 0u32; // bitmask over <=32 banks
    let mut act_target: Option<(u32, u32)> = None;
    let mut pre_target: Option<u32> = None;
    for req in v.active.iter().take(look) {
        let bit = 1u32 << req.addr.bank;
        if seen_banks & bit != 0 {
            continue;
        }
        seen_banks |= bit;
        match v.device.row_state(req.addr.bank, req.addr.row) {
            None => {
                if act_target.is_none() {
                    act_target = Some((req.addr.bank, req.addr.row));
                }
            }
            Some(false) => {
                // conflict: only precharge if no older queued request
                // (this window) still hits the open row of this bank
                let open = v.device.bank(req.addr.bank).open_row;
                let still_wanted = v.active.iter().take(look).any(|r| {
                    r.addr.bank == req.addr.bank
                        && Some(r.addr.row) == open
                        && r.arrival < req.arrival
                });
                if !still_wanted && pre_target.is_none() {
                    pre_target = Some(req.addr.bank);
                }
            }
            Some(true) => {}
        }
    }
    let mut wake = Cycle::MAX;
    if let Some((bank, row)) = act_target {
        let at = v.device.earliest_issue(Cmd::Act { bank, row });
        if at <= v.now {
            return (Some(PrepAction::Act { bank, row }), v.now);
        }
        wake = wake.min(at);
    }
    if let Some(bank) = pre_target {
        let cmd = Cmd::Pre { bank };
        let at = v.device.earliest_issue(cmd);
        if at <= v.now && v.device.can_issue(cmd, v.now) {
            return (Some(PrepAction::Pre { bank }), v.now);
        }
        wake = wake.min(at);
    }
    (None, wake)
}

fn pick_idle_precharge_impl(p: &dyn SchedPolicy, v: &SchedView<'_>) -> (Option<u32>, Cycle) {
    let timer = p.idle_timer(v.params);
    if timer == 0 {
        return (None, Cycle::MAX);
    }
    let mut wake = Cycle::MAX;
    for bank in 0..v.bank_last_use.len() {
        let b = v.device.bank(bank as u32);
        let Some(open_row) = b.open_row else { continue };
        let expires = v.bank_last_use[bank] + timer as Cycle;
        if v.now < expires {
            wake = wake.min(expires);
            continue;
        }
        let wanted = v
            .active
            .iter()
            .chain(v.other.iter())
            .any(|r| r.addr.bank == bank as u32 && r.addr.row == open_row);
        if wanted {
            continue;
        }
        let cmd = Cmd::Pre { bank: bank as u32 };
        let at = v.device.earliest_issue(cmd);
        if at <= v.now && v.device.can_issue(cmd, v.now) {
            return (Some(bank as u32), v.now);
        }
        wake = wake.min(at);
    }
    (None, wake)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SpeedBin;
    use crate::ddr4::{DramAddr, DramGeometry, TimingParams};

    #[test]
    fn kind_parse_name_roundtrip() {
        for kind in SchedKind::ALL {
            assert_eq!(SchedKind::parse(&kind.name()), Some(kind), "{kind}");
        }
        assert_eq!(SchedKind::parse("fr-fcfs"), Some(SchedKind::FrFcfs));
        assert_eq!(SchedKind::parse("FRFCFS_CAP"), Some(SchedKind::FrFcfsCap { cap: 4 }));
        assert_eq!(SchedKind::parse("frfcfs-cap8"), Some(SchedKind::FrFcfsCap { cap: 8 }));
        assert_eq!(SchedKind::parse("frfcfs-cap=16"), Some(SchedKind::FrFcfsCap { cap: 16 }));
        assert_eq!(SchedKind::parse("closed_page"), Some(SchedKind::Closed));
        assert_eq!(SchedKind::parse("frfcfs-cap0"), None, "zero cap is invalid");
        assert_eq!(SchedKind::parse("nope"), None);
        // non-default caps round-trip through the long name
        let k = SchedKind::FrFcfsCap { cap: 16 };
        assert_eq!(SchedKind::parse(&k.name()), Some(k));
        assert_eq!(SchedKind::default(), SchedKind::FrFcfs);
    }

    #[test]
    fn windows_follow_policy() {
        let params = ControllerParams { lookahead: 8, ..Default::default() };
        assert_eq!(Fcfs.window(&params, false), 1);
        assert_eq!(FrFcfs.window(&params, false), 8);
        let mut cap = FrFcfsCap::new(2);
        assert_eq!(cap.window(&params, false), 8);
        cap.on_cas_issued(false, 1);
        cap.on_cas_issued(false, 3);
        assert_eq!(cap.streak(false), 2);
        assert_eq!(cap.window(&params, false), 1, "cap reached: strict order");
        // streaks are per direction: read-side starvation must not
        // strangle the write queue's reordering, and serving the write
        // head must not forgive read-side bypasses
        assert_eq!(cap.streak(true), 0);
        assert_eq!(cap.window(&params, true), 8, "write direction unaffected");
        cap.on_cas_issued(true, 0);
        assert_eq!(cap.streak(false), 2, "write head service keeps the read streak");
        assert_eq!(cap.window(&params, false), 1);
        cap.on_cas_issued(false, 0);
        assert_eq!(cap.streak(false), 0, "read head service resets the read streak");
        assert_eq!(cap.window(&params, false), 8);
    }

    #[test]
    fn idle_timers_follow_policy() {
        let params = ControllerParams::default();
        assert_eq!(params.idle_precharge_cycles, 0);
        assert_eq!(FrFcfs.idle_timer(&params), 0);
        assert_eq!(ClosedPage.idle_timer(&params), 0);
        assert_eq!(AdaptiveOpen.idle_timer(&params), ADAPTIVE_IDLE_CK);
        let tuned = ControllerParams { idle_precharge_cycles: 32, ..Default::default() };
        assert_eq!(FrFcfs.idle_timer(&tuned), 32);
        assert_eq!(AdaptiveOpen.idle_timer(&tuned), 32, "explicit knob wins");
    }

    fn req(id: u64, bank: u32, row: u32, col: u32, arrival: Cycle) -> MemRequest {
        MemRequest {
            txn_id: id,
            is_write: false,
            addr: DramAddr { bank, row, col },
            burst_addr: 64 * id,
            beats: 2,
            arrival,
            last_of_txn: true,
        }
    }

    #[test]
    fn closed_page_auto_precharges_only_unwanted_rows() {
        let params = ControllerParams::default();
        let mut dev = DdrDevice::new(
            TimingParams::for_bin(SpeedBin::Ddr4_1600),
            DramGeometry::profpga_board(),
        );
        dev.issue(Cmd::Act { bank: 0, row: 1 }, 0);
        let now = dev.timing().trcd as Cycle;
        let bank_last_use = [0; 8];
        // lone request to the open row: auto-precharge
        let mut active: VecDeque<MemRequest> = VecDeque::new();
        active.push_back(req(0, 0, 1, 0, 0));
        let other = VecDeque::new();
        let view = SchedView {
            device: &dev,
            params: &params,
            active: &active,
            other: &other,
            is_write: false,
            bank_last_use: &bank_last_use,
            now,
        };
        let engine = SchedEngine::new(SchedKind::Closed);
        let (pick, _) = engine.pick_cas(&view);
        assert_eq!(pick, Some(CasPick { index: 0, auto_pre: true }));
        // a second queued request to the same row keeps it open
        active.push_back(req(1, 0, 1, 8, 1));
        let view = SchedView {
            device: &dev,
            params: &params,
            active: &active,
            other: &other,
            is_write: false,
            bank_last_use: &bank_last_use,
            now,
        };
        let (pick, _) = engine.pick_cas(&view);
        assert_eq!(pick, Some(CasPick { index: 0, auto_pre: false }));
        // frfcfs never auto-precharges
        let (pick, _) = SchedEngine::default().pick_cas(&view);
        assert_eq!(pick, Some(CasPick { index: 0, auto_pre: false }));
    }

    #[test]
    fn fcfs_window_hides_younger_hits() {
        let params = ControllerParams::default();
        let mut dev = DdrDevice::new(
            TimingParams::for_bin(SpeedBin::Ddr4_1600),
            DramGeometry::profpga_board(),
        );
        dev.issue(Cmd::Act { bank: 0, row: 1 }, 0);
        let now = dev.timing().trcd as Cycle;
        let bank_last_use = [0; 8];
        // head is a conflict (row 2), a younger hit (row 1) sits behind it
        let mut active: VecDeque<MemRequest> = VecDeque::new();
        active.push_back(req(0, 0, 2, 0, 0));
        active.push_back(req(1, 0, 1, 8, 1));
        let other = VecDeque::new();
        let view = SchedView {
            device: &dev,
            params: &params,
            active: &active,
            other: &other,
            is_write: false,
            bank_last_use: &bank_last_use,
            now,
        };
        let (pick, _) = SchedEngine::new(SchedKind::FrFcfs).pick_cas(&view);
        assert_eq!(pick.map(|p| p.index), Some(1), "frfcfs serves the younger hit");
        let (pick, _) = SchedEngine::new(SchedKind::Fcfs).pick_cas(&view);
        assert_eq!(pick, None, "fcfs waits for the head's row");
    }
}
