//! Incrementally-indexed scheduler hot path — the production twin of the
//! scan implementations in [`super::sched`].
//!
//! Under saturated traffic every DRAM tick re-runs the scheduler scans:
//! the same-address hazard check is O(i) per candidate (O(window²) per
//! `pick_cas`), `pick_prep`'s still-wanted test is another nested window
//! scan, and `pick_idle_precharge` walks *both* queues per open bank.
//! This module replaces those per-tick recomputations with indexes
//! maintained at the queue mutation points (enqueue, CAS removal):
//!
//! - **per-address occupancy** (`addr_occ`): per direction, how many
//!   queued requests target each decoded DRAM burst and the earliest
//!   arrival among them — the hazard check becomes O(1) (with an exact
//!   prefix-scan fallback only when the *same queue* holds a duplicate
//!   address, which a FIFO per direction makes rare);
//! - **per-(bank,row) wanted counts** (`row_wanted`) over both queues —
//!   the idle-precharge `wanted` scan and the closed-page
//!   auto-precharge decision become O(1) lookups;
//! - **per-bank queued-request counts** (`bank_load`) so bank-granular
//!   questions skip the hash map entirely for cold banks, and the
//!   idle-precharge path word-scans the device's SoA
//!   [`open column`](crate::ddr4::DdrDevice::open_bank_mask) instead of
//!   striding `0..banks`;
//! - **per-direction decision memos** (`cas_memo` / `prep_memo`):
//!   between queue/device mutations the controller is deterministic, so
//!   a scan that issued nothing caches its candidate set (queue index +
//!   earliest legal cycle) and consecutive ticks replay the cached
//!   candidates against the new `now` instead of re-scanning. An
//!   `epoch` counter bumped at every mutation point (enqueue, any
//!   command issue, policy swap) invalidates the memos.
//!
//! **Exactness contract:** every function here reproduces its scan
//! oracle *bit for bit* — same pick, same wake hint — for every policy.
//! The scans stay in-tree as the frozen oracle
//! (`ControllerParams::sched_oracle` selects them), and
//! `rust/tests/sched_index_differential.rs` pins the two
//! command-for-command across all policies, mappings and engines. The
//! memo replay is sound because everything a scan depends on — row
//! states, `earliest_issue`, queue contents and order, policy window
//! (including the `frfcfs-cap` streak), `bank_last_use` — only changes
//! at an epoch bump; between bumps only `now` advances, and `now`
//! enters the decision solely through `at <= now` comparisons.

use std::collections::{HashMap, VecDeque};

use crate::ddr4::{Cmd, Cycle, DramAddr};

use super::request::MemRequest;
use super::sched::{CasPick, PrepAction, SchedKind, SchedPolicy, SchedView};

/// Queue occupancy of one decoded DRAM burst address in one direction.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
struct AddrOcc {
    /// Queued requests with this exact address.
    count: u32,
    /// Earliest arrival among them (meaningless when `count == 0`;
    /// reset to 0 so the index stays canonically comparable).
    min_arrival: Cycle,
}

/// An epoch-stamped cached value; valid only while the stamp matches
/// the index's current epoch (`epoch == 0` is never current).
#[derive(Debug, Clone, Default)]
struct Memo<T> {
    epoch: u64,
    value: T,
}

/// Cached `pick_prep` scan result: the deduped ACT and PRE targets with
/// their earliest legal cycles, exactly as the oracle scan would select
/// them (the scan picks targets by queue order, then tests legality).
#[derive(Debug, Clone, Copy, Default)]
struct PrepTargets {
    /// First closed-bank target in window order: (bank, row, earliest).
    act: Option<(u32, u32, Cycle)>,
    /// First not-still-wanted conflict target: (bank, earliest).
    pre: Option<(u32, Cycle)>,
}

/// The incremental scheduling indexes of one controller. Maintained by
/// [`super::MemController`] at its queue mutation points; consulted by
/// the `pick_*_indexed` functions below.
#[derive(Debug, Clone)]
pub struct SchedIndex {
    /// Per-address occupancy, `[read, write]` per entry.
    addr_occ: HashMap<DramAddr, [AddrOcc; 2]>,
    /// Queued-request count per (bank, row), both directions combined.
    row_wanted: HashMap<(u32, u32), u32>,
    /// Queued-request count per bank, `[read, write]`.
    bank_load: Vec<[u32; 2]>,
    /// Mutation counter; memos stamped with an older epoch are stale.
    epoch: u64,
    /// Cached CAS candidates per direction: (queue index, earliest).
    cas_memo: [Memo<Vec<(usize, Cycle)>>; 2],
    /// Cached prep targets per direction.
    prep_memo: [Memo<PrepTargets>; 2],
}

impl SchedIndex {
    /// Empty index for a device with `banks` banks.
    pub fn new(banks: usize) -> Self {
        Self {
            addr_occ: HashMap::new(),
            row_wanted: HashMap::new(),
            bank_load: vec![[0; 2]; banks],
            epoch: 1,
            cas_memo: Default::default(),
            prep_memo: Default::default(),
        }
    }

    /// Invalidate the decision memos. Called for every mutation that can
    /// change a scheduling decision: enqueue, any device command issue,
    /// and a runtime policy swap. (A read↔write mode flip needs no bump:
    /// the memos are per direction and depend only on queue and device
    /// state, neither of which a flip touches.)
    pub fn bump(&mut self) {
        self.epoch += 1;
    }

    /// Account a request entering its direction's queue.
    pub fn on_push(&mut self, req: &MemRequest) {
        let dir = usize::from(req.is_write);
        let occ = &mut self.addr_occ.entry(req.addr).or_default()[dir];
        occ.count += 1;
        if occ.count == 1 || req.arrival < occ.min_arrival {
            occ.min_arrival = req.arrival;
        }
        *self.row_wanted.entry((req.addr.bank, req.addr.row)).or_insert(0) += 1;
        self.bank_load[req.addr.bank as usize][dir] += 1;
        self.bump();
    }

    /// Account a request leaving its direction's queue (CAS issue).
    /// `remaining` is that direction's queue *after* the removal — the
    /// minimum-arrival rescan (only needed when the removed request was
    /// the earliest for its address, i.e. on duplicate addresses) walks
    /// it once.
    pub fn on_remove(&mut self, req: &MemRequest, remaining: &VecDeque<MemRequest>) {
        let dir = usize::from(req.is_write);
        let mut drop_entry = false;
        match self.addr_occ.get_mut(&req.addr) {
            Some(entry) => {
                let other_count = entry[1 - dir].count;
                let occ = &mut entry[dir];
                occ.count -= 1;
                if occ.count == 0 {
                    occ.min_arrival = 0;
                    drop_entry = other_count == 0;
                } else if req.arrival <= occ.min_arrival {
                    let rescan = remaining
                        .iter()
                        .filter(|r| r.addr == req.addr)
                        .map(|r| r.arrival)
                        .min();
                    match rescan {
                        Some(m) => occ.min_arrival = m,
                        None => debug_assert!(false, "count > 0 but no same-addr entry remains"),
                    }
                }
            }
            None => debug_assert!(false, "removed request was never indexed"),
        }
        if drop_entry {
            self.addr_occ.remove(&req.addr);
        }
        match self.row_wanted.get_mut(&(req.addr.bank, req.addr.row)) {
            Some(c) if *c > 1 => *c -= 1,
            Some(_) => {
                self.row_wanted.remove(&(req.addr.bank, req.addr.row));
            }
            None => debug_assert!(false, "row_wanted underflow"),
        }
        self.bank_load[req.addr.bank as usize][dir] -= 1;
        self.bump();
    }

    /// Queued requests (either direction) targeting (bank, row).
    fn row_wanted(&self, bank: u32, row: u32) -> u32 {
        let load = self.bank_load[bank as usize];
        if load[0] + load[1] == 0 {
            return 0; // cold bank: skip the hash lookup
        }
        self.row_wanted.get(&(bank, row)).copied().unwrap_or(0)
    }

    /// O(1) same-address hazard check, bit-exact with the oracle's
    /// `reordered_past_same_addr`: would issuing active-queue entry `i`
    /// overtake an older same-address request? The same-queue half uses
    /// the occupancy count (a single entry for this address must be the
    /// candidate itself; duplicates fall back to the oracle's exact
    /// prefix scan); the other-queue half compares against the indexed
    /// minimum arrival.
    fn hazard(&self, v: &SchedView<'_>, i: usize) -> bool {
        let req = &v.active[i];
        let dir = usize::from(v.is_write);
        let Some(occ) = self.addr_occ.get(&req.addr) else {
            debug_assert!(false, "queued request missing from addr index");
            return false;
        };
        if occ[dir].count >= 2 && v.active.iter().take(i).any(|r| r.addr == req.addr) {
            return true;
        }
        let other = occ[1 - dir];
        other.count > 0 && other.min_arrival < req.arrival
    }

    /// Validate every index against a from-scratch recount of the queues
    /// (test support; panics on divergence).
    #[doc(hidden)]
    pub fn assert_consistent(&self, read_q: &VecDeque<MemRequest>, write_q: &VecDeque<MemRequest>) {
        let mut addr_occ: HashMap<DramAddr, [AddrOcc; 2]> = HashMap::new();
        let mut row_wanted: HashMap<(u32, u32), u32> = HashMap::new();
        let mut bank_load: Vec<[u32; 2]> = vec![[0; 2]; self.bank_load.len()];
        for r in read_q.iter().chain(write_q.iter()) {
            let dir = usize::from(r.is_write);
            let occ = &mut addr_occ.entry(r.addr).or_default()[dir];
            occ.count += 1;
            if occ.count == 1 || r.arrival < occ.min_arrival {
                occ.min_arrival = r.arrival;
            }
            *row_wanted.entry((r.addr.bank, r.addr.row)).or_insert(0) += 1;
            bank_load[r.addr.bank as usize][dir] += 1;
        }
        assert_eq!(self.addr_occ, addr_occ, "addr_occ diverged from queue recount");
        assert_eq!(self.row_wanted, row_wanted, "row_wanted diverged from queue recount");
        assert_eq!(self.bank_load, bank_load, "bank_load diverged from queue recount");
    }
}

/// Auto-precharge decision for the picked CAS. The closed-page policy's
/// hook scans both full queues for another same-(bank,row) request; the
/// wanted-count index answers that in O(1) (the count includes the
/// picked request itself, so "another exists" is `count >= 2`). Every
/// other policy's hook is queue-independent and dispatches unchanged.
fn auto_pre(p: &dyn SchedPolicy, v: &SchedView<'_>, idx: &SchedIndex, i: usize) -> bool {
    match p.kind() {
        SchedKind::Closed => {
            let a = v.active[i].addr;
            idx.row_wanted(a.bank, a.row) < 2
        }
        _ => p.auto_precharge(v, i),
    }
}

/// Indexed twin of the oracle's `pick_cas` scan: first legal row hit in
/// the policy window that does not overtake an older same-address
/// request; on no pick, the earliest cycle a scanned candidate becomes
/// legal. Consecutive no-pick ticks replay the memoized candidate set.
pub fn pick_cas_indexed(
    p: &dyn SchedPolicy,
    v: &SchedView<'_>,
    idx: &mut SchedIndex,
) -> (Option<CasPick>, Cycle) {
    let dir = usize::from(v.is_write);
    if idx.cas_memo[dir].epoch == idx.epoch {
        let mut wake = Cycle::MAX;
        let mut hit = None;
        for &(i, at) in &idx.cas_memo[dir].value {
            if at <= v.now {
                hit = Some(i);
                break;
            }
            wake = wake.min(at);
        }
        return match hit {
            Some(i) => (Some(CasPick { index: i, auto_pre: auto_pre(p, v, idx, i) }), v.now),
            None => (None, wake),
        };
    }
    let look = p.window(v.params, v.is_write);
    // reuse the stale memo's buffer; re-stamped below only on a no-pick
    let mut cands = std::mem::take(&mut idx.cas_memo[dir].value);
    cands.clear();
    let mut wake = Cycle::MAX;
    let mut pick = None;
    for (i, req) in v.active.iter().take(look).enumerate() {
        if v.device.row_state(req.addr.bank, req.addr.row) != Some(true) {
            continue;
        }
        if idx.hazard(v, i) {
            continue; // hazard: cleared by a future issue (epoch bump)
        }
        let cmd = if v.is_write {
            Cmd::Wr { bank: req.addr.bank, col: req.addr.col, auto_pre: false }
        } else {
            Cmd::Rd { bank: req.addr.bank, col: req.addr.col, auto_pre: false }
        };
        let at = v.device.earliest_issue(cmd);
        if at <= v.now {
            pick = Some(i);
            break;
        }
        cands.push((i, at));
        wake = wake.min(at);
    }
    // A pick leads to an issue (epoch bump), so its partial candidate
    // list must not be replayed: stamp 0 (never current) to keep only
    // the buffer capacity.
    let epoch = if pick.is_some() { 0 } else { idx.epoch };
    idx.cas_memo[dir] = Memo { epoch, value: cands };
    match pick {
        Some(i) => (Some(CasPick { index: i, auto_pre: auto_pre(p, v, idx, i) }), v.now),
        None => (None, wake),
    }
}

/// One O(window) pass replacing the oracle scan's nested still-wanted
/// test: per bank, the earliest arrival of an open-row hit inside the
/// window. "An older request still hits this bank's open row" is then
/// `hit_min_arrival[bank] < req.arrival`.
fn scan_prep_targets(p: &dyn SchedPolicy, v: &SchedView<'_>) -> PrepTargets {
    let look = p.window(v.params, v.is_write);
    let mut hit_arr = [Cycle::MAX; 64]; // device asserts banks <= 64
    for req in v.active.iter().take(look) {
        if v.device.row_state(req.addr.bank, req.addr.row) == Some(true) {
            let e = &mut hit_arr[req.addr.bank as usize];
            *e = (*e).min(req.arrival);
        }
    }
    let mut seen_banks = 0u64;
    let mut act = None;
    let mut pre = None;
    for req in v.active.iter().take(look) {
        let bit = 1u64 << req.addr.bank;
        if seen_banks & bit != 0 {
            continue;
        }
        seen_banks |= bit;
        match v.device.row_state(req.addr.bank, req.addr.row) {
            None => {
                if act.is_none() {
                    let at = v.device.earliest_issue(Cmd::Act {
                        bank: req.addr.bank,
                        row: req.addr.row,
                    });
                    act = Some((req.addr.bank, req.addr.row, at));
                }
            }
            Some(false) => {
                let still_wanted = hit_arr[req.addr.bank as usize] < req.arrival;
                if !still_wanted && pre.is_none() {
                    let at = v.device.earliest_issue(Cmd::Pre { bank: req.addr.bank });
                    pre = Some((req.addr.bank, at));
                }
            }
            Some(true) => {}
        }
    }
    PrepTargets { act, pre }
}

/// Indexed twin of the oracle's `pick_prep` scan: ACT the first closed
/// bank in the window, else PRE the first conflict whose open row no
/// older window entry still wants. The target selection is memoized
/// across no-issue ticks; legality is re-tested against the new `now`.
pub fn pick_prep_indexed(
    p: &dyn SchedPolicy,
    v: &SchedView<'_>,
    idx: &mut SchedIndex,
) -> (Option<PrepAction>, Cycle) {
    let dir = usize::from(v.is_write);
    let targets = if idx.prep_memo[dir].epoch == idx.epoch {
        idx.prep_memo[dir].value
    } else {
        let t = scan_prep_targets(p, v);
        // Safe to stamp even when an action follows: the resulting
        // issue bumps the epoch before the memo could be replayed.
        idx.prep_memo[dir] = Memo { epoch: idx.epoch, value: t };
        t
    };
    let mut wake = Cycle::MAX;
    if let Some((bank, row, at)) = targets.act {
        if at <= v.now {
            return (Some(PrepAction::Act { bank, row }), v.now);
        }
        wake = wake.min(at);
    }
    if let Some((bank, at)) = targets.pre {
        let cmd = Cmd::Pre { bank };
        if at <= v.now && v.device.can_issue(cmd, v.now) {
            return (Some(PrepAction::Pre { bank }), v.now);
        }
        wake = wake.min(at);
    }
    (None, wake)
}

/// Indexed twin of the oracle's `pick_idle_precharge` scan: word-scan
/// the device's SoA open column (ascending bank order, matching the
/// oracle's `0..banks` walk) and answer "does any queued request still
/// want this row" from the wanted-count index. Already O(open banks)
/// with O(1) per bank, so it takes no memo. Wanted rows contribute no
/// wake, exactly like the oracle (the wake source for them is the
/// enqueue/issue that changes the index, which sets `dirty`/bumps).
pub fn pick_idle_precharge_indexed(
    p: &dyn SchedPolicy,
    v: &SchedView<'_>,
    idx: &SchedIndex,
) -> (Option<u32>, Cycle) {
    let timer = p.idle_timer(v.params);
    if timer == 0 {
        return (None, Cycle::MAX);
    }
    let mut wake = Cycle::MAX;
    let mut mask = v.device.open_bank_mask();
    while mask != 0 {
        let bank = mask.trailing_zeros();
        mask &= mask - 1;
        let expires = v.bank_last_use[bank as usize] + timer as Cycle;
        if v.now < expires {
            wake = wake.min(expires);
            continue;
        }
        let open_row = match v.device.bank(bank).open_row {
            Some(row) => row,
            None => {
                debug_assert!(false, "open_bank_mask bit set on a closed bank");
                continue;
            }
        };
        if idx.row_wanted(bank, open_row) > 0 {
            continue;
        }
        let cmd = Cmd::Pre { bank };
        let at = v.device.earliest_issue(cmd);
        if at <= v.now && v.device.can_issue(cmd, v.now) {
            return (Some(bank), v.now);
        }
        wake = wake.min(at);
    }
    (None, wake)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ControllerParams, SpeedBin};
    use crate::controller::sched::SchedEngine;
    use crate::ddr4::{DdrDevice, DramGeometry, TimingParams};
    use crate::rng::SplitMix64;

    fn req(is_write: bool, bank: u32, row: u32, col: u32, arrival: Cycle) -> MemRequest {
        MemRequest {
            txn_id: arrival,
            is_write,
            addr: DramAddr { bank, row, col },
            burst_addr: (u64::from(bank) << 40) | (u64::from(row) << 20) | u64::from(col),
            beats: 2,
            arrival,
            last_of_txn: true,
        }
    }

    fn random_req(rng: &mut SplitMix64, arrival: Cycle) -> MemRequest {
        // a handful of banks/rows/cols so duplicates and conflicts occur
        req(
            rng.percent(40),
            rng.below(8) as u32,
            rng.below(4) as u32,
            (rng.below(16) * 8) as u32,
            arrival,
        )
    }

    fn rebuild_index(read_q: &VecDeque<MemRequest>, write_q: &VecDeque<MemRequest>) -> SchedIndex {
        let mut idx = SchedIndex::new(8);
        for r in read_q.iter().chain(write_q.iter()) {
            idx.on_push(r);
        }
        idx
    }

    #[test]
    fn occupancy_index_tracks_push_and_remove() {
        let mut rng = SplitMix64::new(0x5eed);
        for _ in 0..50 {
            let mut read_q: VecDeque<MemRequest> = VecDeque::new();
            let mut write_q: VecDeque<MemRequest> = VecDeque::new();
            let mut idx = SchedIndex::new(8);
            for step in 0..200u64 {
                if rng.percent(60) || (read_q.is_empty() && write_q.is_empty()) {
                    let r = random_req(&mut rng, step);
                    let q = if r.is_write { &mut write_q } else { &mut read_q };
                    q.push_back(r);
                    idx.on_push(&r);
                } else {
                    let from_write = if read_q.is_empty() {
                        true
                    } else if write_q.is_empty() {
                        false
                    } else {
                        rng.percent(50)
                    };
                    let q = if from_write { &mut write_q } else { &mut read_q };
                    let at = rng.below(q.len() as u64) as usize;
                    let r = q.remove(at).unwrap();
                    idx.on_remove(&r, if from_write { &write_q } else { &read_q });
                }
                idx.assert_consistent(&read_q, &write_q);
            }
        }
    }

    #[test]
    fn epoch_bumps_on_every_mutation() {
        let mut idx = SchedIndex::new(8);
        let e0 = idx.epoch;
        let r = req(false, 1, 2, 8, 5);
        idx.on_push(&r);
        assert!(idx.epoch > e0);
        let e1 = idx.epoch;
        idx.on_remove(&r, &VecDeque::new());
        assert!(idx.epoch > e1);
        let e2 = idx.epoch;
        idx.bump();
        assert!(idx.epoch > e2);
    }

    /// Mini-differential: on randomized device/queue states, every
    /// indexed pick function must agree with its scan oracle — pick and
    /// wake hint both — for every policy, including across memo replays
    /// and mid-state removals. (The full controller/platform pinning
    /// lives in `rust/tests/sched_index_differential.rs`.)
    #[test]
    fn indexed_picks_match_scan_oracle_on_random_states() {
        let mut rng = SplitMix64::new(0xd1ff);
        for trial in 0..120u64 {
            // device with a few open/closed banks and advanced timing state
            let mut device = DdrDevice::new(
                TimingParams::for_bin(SpeedBin::Ddr4_1600),
                DramGeometry::profpga_board(),
            );
            let mut now: Cycle = 1;
            for bank in 0..8u32 {
                if rng.percent(60) {
                    let act = Cmd::Act { bank, row: rng.below(4) as u32 };
                    now = device.earliest_issue(act).max(now + 1);
                    device.issue(act, now);
                    if rng.percent(30) {
                        let rd = Cmd::Rd { bank, col: 0, auto_pre: false };
                        now = device.earliest_issue(rd).max(now + 1);
                        device.issue(rd, now);
                    }
                }
            }
            let mut read_q: VecDeque<MemRequest> = VecDeque::new();
            let mut write_q: VecDeque<MemRequest> = VecDeque::new();
            for i in 0..(4 + rng.below(12)) {
                let r = random_req(&mut rng, now + i);
                if r.is_write {
                    write_q.push_back(r);
                } else {
                    read_q.push_back(r);
                }
            }
            let params = ControllerParams {
                lookahead: 1 + rng.below(8) as usize,
                idle_precharge_cycles: [0u32, 64][rng.below(2) as usize],
                ..Default::default()
            };
            let bank_last_use: Vec<Cycle> =
                (0..8).map(|_| now.saturating_sub(rng.below(200))).collect();
            let mut idx = rebuild_index(&read_q, &write_q);
            for kind in SchedKind::ALL {
                let engine = SchedEngine::new(kind);
                // probe a few instants, including replays of one memo
                for probe in 0..4u64 {
                    let at = now + probe * 7;
                    for is_write in [false, true] {
                        let (active, other) = if is_write {
                            (&write_q, &read_q)
                        } else {
                            (&read_q, &write_q)
                        };
                        let v = SchedView {
                            device: &device,
                            params: &params,
                            active,
                            other,
                            is_write,
                            bank_last_use: &bank_last_use,
                            now: at,
                        };
                        let oracle = engine.pick_cas(&v);
                        let fast = pick_cas_indexed(engine.policy(), &v, &mut idx);
                        assert_eq!(fast, oracle, "pick_cas {kind} trial {trial} now {at}");
                        let oracle = engine.pick_prep(&v);
                        let fast = pick_prep_indexed(engine.policy(), &v, &mut idx);
                        assert_eq!(fast, oracle, "pick_prep {kind} trial {trial} now {at}");
                        let oracle = engine.pick_idle_precharge(&v);
                        let fast = pick_idle_precharge_indexed(engine.policy(), &v, &idx);
                        assert_eq!(fast, oracle, "idle_pre {kind} trial {trial} now {at}");
                    }
                }
                // a removal must invalidate the memos and keep agreement
                if !read_q.is_empty() && !write_q.is_empty() {
                    // (separate clone per policy so policies stay independent)
                    let mut rq = read_q.clone();
                    let r = rq.remove(rng.below(rq.len() as u64) as usize).unwrap();
                    let mut idx2 = idx.clone();
                    idx2.on_remove(&r, &rq);
                    idx2.assert_consistent(&rq, &write_q);
                    let v = SchedView {
                        device: &device,
                        params: &params,
                        active: &rq,
                        other: &write_q,
                        is_write: false,
                        bank_last_use: &bank_last_use,
                        now: now + 3,
                    };
                    let oracle = engine.pick_cas(&v);
                    let fast = pick_cas_indexed(engine.policy(), &v, &mut idx2);
                    assert_eq!(fast, oracle, "post-remove pick_cas {kind} trial {trial}");
                }
            }
        }
    }
}
