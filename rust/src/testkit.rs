//! Seeded property-test kit (in-tree replacement for proptest; the
//! offline image only vendors the `xla` closure — DESIGN.md §9).
//!
//! [`check`] runs a property over `cases` random inputs drawn from a
//! generator function, reports the failing seed on the first
//! counterexample, and — for inputs that implement [`Shrink`] — greedily
//! shrinks the counterexample before reporting. Setting
//! `DDR4BENCH_PT_SEED` reproduces a failure run exactly.

use crate::rng::SplitMix64;

/// Types that can propose strictly-smaller variants of themselves.
pub trait Shrink: Sized + Clone {
    /// Candidate smaller values, most aggressive first.
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            return vec![];
        }
        let mut out = vec![0, self / 2];
        if *self > 1 {
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for u32 {
    fn shrink(&self) -> Vec<Self> {
        (*self as u64).shrink().into_iter().map(|v| v as u32).collect()
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        (*self as u64).shrink().into_iter().map(|v| v as usize).collect()
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> =
            self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<A: Shrink, B: Shrink, C: Shrink> Shrink for (A, B, C) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b, self.2.clone())));
        out.extend(self.2.shrink().into_iter().map(|c| (self.0.clone(), self.1.clone(), c)));
        out
    }
}

/// Base seed for a named property (env override, else name hash).
fn base_seed(name: &str) -> u64 {
    if let Ok(s) = std::env::var("DDR4BENCH_PT_SEED") {
        if let Ok(v) = s.parse() {
            return v;
        }
    }
    name.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3)
    })
}

/// Run `prop` over `cases` inputs drawn by `gen`. Panics with the failing
/// input and reproduction seed on the first counterexample (no shrinking).
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut SplitMix64) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let seed = base_seed(name);
    let mut rng = SplitMix64::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed on case {case}/{cases}\n  input: {input:?}\n  \
                 reason: {msg}\n  reproduce with DDR4BENCH_PT_SEED={seed}"
            );
        }
    }
}

/// As [`check`], but shrinks the counterexample before panicking.
pub fn check_shrink<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug + Shrink,
    G: FnMut(&mut SplitMix64) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let seed = base_seed(name);
    let mut rng = SplitMix64::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(first_msg) = prop(&input) {
            // greedy shrink: walk to a local minimum
            let mut cur = input;
            let mut msg = first_msg;
            let mut budget = 200;
            'outer: while budget > 0 {
                for cand in cur.shrink() {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property `{name}` failed on case {case}/{cases}\n  shrunk input: {cur:?}\n  \
                 reason: {msg}\n  reproduce with DDR4BENCH_PT_SEED={seed}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check("u64 is u64", 100, |r| r.next_u64(), |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property `always false`")]
    fn failing_property_panics_with_seed() {
        check("always false", 10, |r| r.next_u64(), |_| Err("nope".into()));
    }

    #[test]
    fn shrinking_reaches_minimum() {
        // property: v < 100 — minimal counterexample is 100
        let result = std::panic::catch_unwind(|| {
            check_shrink(
                "lt100",
                1000,
                |r| r.below(10_000),
                |v| if *v < 100 { Ok(()) } else { Err(format!("{v} >= 100")) },
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("shrunk input: 100"), "shrunk to minimum: {msg}");
    }

    #[test]
    fn tuple_shrink_covers_both_fields() {
        let cands = (4u64, 6u64).shrink();
        assert!(cands.contains(&(0, 6)));
        assert!(cands.contains(&(2, 6)));
        assert!(cands.contains(&(4, 3)));
    }

    #[test]
    fn deterministic_per_name() {
        let mut a = SplitMix64::new(base_seed("x"));
        let mut b = SplitMix64::new(base_seed("x"));
        assert_eq!(a.next_u64(), b.next_u64());
        assert_ne!(base_seed("x"), base_seed("y"));
    }
}
