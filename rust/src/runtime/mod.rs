//! PJRT runtime bridge: loads the AOT-compiled JAX/Pallas artifacts and
//! executes them from the Rust hot path. Python never runs at benchmark
//! time — `make artifacts` lowers the kernels once to HLO *text* (see
//! `python/compile/aot.py`; text rather than serialized protos because
//! jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//! rejects), and this module compiles and caches the executables on the
//! PJRT CPU client at startup.
//!
//! Three artifacts exist (fixed-shape, chunked by the wrappers here):
//!
//! | artifact          | signature                              | role |
//! |-------------------|----------------------------------------|------|
//! | `datagen.hlo.txt` | `u32[4096] seeds → u32[4096,16]`       | PRBS payload expansion (Pallas kernel) |
//! | `verify.hlo.txt`  | `u32[4096], u32[4096,16] → u32[1]`     | read-back mismatch count (Pallas kernel) |
//! | `bwmodel.hlo.txt` | `f32[64,8] features → f32[64]`         | analytic DDR4 bandwidth model (jnp) |
//!
//! Chunk padding: `datagen` pads with zero seeds and drops the padded
//! rows; `verify` pads with zero seeds *and zero data* — the kernel's
//! expansion of any seed is never zero (xorshift32), so each padded row
//! contributes exactly [`WORDS_PER_BURST`] mismatches, which the wrapper
//! subtracts deterministically.

use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

use crate::trafficgen::payload::WORDS_PER_BURST;

/// Seeds per datagen/verify executable invocation (fixed at AOT time).
pub const DATAGEN_BLOCK: usize = 4096;
/// Rows per bandwidth-model invocation (fixed at AOT time).
pub const BWMODEL_BLOCK: usize = 64;
/// Feature columns of the bandwidth model (see `python/compile/model.py`).
pub const BWMODEL_FEATURES: usize = 8;

/// Handle to the compiled AOT executables.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    datagen: xla::PjRtLoadedExecutable,
    verify: xla::PjRtLoadedExecutable,
    bwmodel: Option<xla::PjRtLoadedExecutable>,
    /// Executions performed (telemetry for the perf pass).
    pub exec_count: std::cell::Cell<u64>,
}

fn load_exe(
    client: &xla::PjRtClient,
    dir: &Path,
    name: &str,
) -> Result<xla::PjRtLoadedExecutable> {
    let path = dir.join(name);
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
    )
    .map_err(|e| anyhow!("{e:?}"))
    .with_context(|| format!("loading HLO text {path:?} (run `make artifacts`?)"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(|e| anyhow!("{e:?}")).with_context(|| format!("compiling {name}"))
}

impl XlaRuntime {
    /// Load and compile all artifacts from `dir`. The bandwidth model is
    /// optional (older artifact sets); datagen/verify are required.
    pub fn load(dir: &Path) -> Result<Self> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("{e:?}")).context("PJRT CPU client")?;
        let datagen = load_exe(&client, dir, "datagen.hlo.txt")?;
        let verify = load_exe(&client, dir, "verify.hlo.txt")?;
        let bwmodel = load_exe(&client, dir, "bwmodel.hlo.txt").ok();
        Ok(Self { client, datagen, verify, bwmodel, exec_count: std::cell::Cell::new(0) })
    }

    /// Load from the default artifacts directory.
    pub fn load_default() -> Result<Self> {
        Self::load(&default_dir())
    }

    /// Do the required artifacts exist in `dir`?
    pub fn artifacts_present(dir: &Path) -> bool {
        dir.join("datagen.hlo.txt").exists() && dir.join("verify.hlo.txt").exists()
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Is the analytic bandwidth-model artifact loaded?
    pub fn has_bwmodel(&self) -> bool {
        self.bwmodel.is_some()
    }

    fn bump(&self) {
        self.exec_count.set(self.exec_count.get() + 1);
    }

    /// Expand `seeds` into payload words (`seeds.len() * 16` u32s) via the
    /// AOT-compiled Pallas PRBS kernel. Arbitrary lengths are processed in
    /// [`DATAGEN_BLOCK`]-sized chunks.
    pub fn datagen(&self, seeds: &[u32]) -> Result<Vec<u32>> {
        let mut out = Vec::with_capacity(seeds.len() * WORDS_PER_BURST);
        for chunk in seeds.chunks(DATAGEN_BLOCK) {
            let mut padded = [0u32; DATAGEN_BLOCK];
            padded[..chunk.len()].copy_from_slice(chunk);
            let lit = xla::Literal::vec1(&padded[..]);
            let res = self.datagen.execute::<xla::Literal>(&[lit]).map_err(|e| anyhow!("{e:?}"))?
                [0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("{e:?}"))?
                .to_tuple1()
                .map_err(|e| anyhow!("{e:?}"))?;
            self.bump();
            let words: Vec<u32> = res.to_vec().map_err(|e| anyhow!("{e:?}"))?;
            if words.len() != DATAGEN_BLOCK * WORDS_PER_BURST {
                bail!("datagen artifact returned {} words", words.len());
            }
            out.extend_from_slice(&words[..chunk.len() * WORDS_PER_BURST]);
        }
        Ok(out)
    }

    /// Count mismatches between the expansion of `seeds` and `data`
    /// (`data.len() == seeds.len() * 16`) via the AOT verify kernel.
    pub fn verify(&self, seeds: &[u32], data: &[u32]) -> Result<u64> {
        if data.len() != seeds.len() * WORDS_PER_BURST {
            bail!("verify: data length {} != seeds {} * 16", data.len(), seeds.len());
        }
        let mut total = 0u64;
        for (s_chunk, d_chunk) in
            seeds.chunks(DATAGEN_BLOCK).zip(data.chunks(DATAGEN_BLOCK * WORDS_PER_BURST))
        {
            let pad = DATAGEN_BLOCK - s_chunk.len();
            let mut s = [0u32; DATAGEN_BLOCK];
            s[..s_chunk.len()].copy_from_slice(s_chunk);
            let mut d = vec![0u32; DATAGEN_BLOCK * WORDS_PER_BURST];
            d[..d_chunk.len()].copy_from_slice(d_chunk);
            let s_lit = xla::Literal::vec1(&s[..]);
            let d_lit = xla::Literal::vec1(&d)
                .reshape(&[DATAGEN_BLOCK as i64, WORDS_PER_BURST as i64])
                .map_err(|e| anyhow!("{e:?}"))?;
            let res = self
                .verify
                .execute::<xla::Literal>(&[s_lit, d_lit])
                .map_err(|e| anyhow!("{e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("{e:?}"))?
                .to_tuple1()
                .map_err(|e| anyhow!("{e:?}"))?;
            self.bump();
            let count: Vec<u32> = res.to_vec().map_err(|e| anyhow!("{e:?}"))?;
            let raw = count.first().copied().unwrap_or(0) as u64;
            // padded rows: zero data vs never-zero expansion = 16 each
            total += raw - (pad as u64 * WORDS_PER_BURST as u64);
        }
        Ok(total)
    }

    /// Evaluate the analytic bandwidth model on feature rows
    /// (`feats.len()` divisible by [`BWMODEL_FEATURES`]); returns one
    /// predicted GB/s per row. Errors if the artifact set lacks the model.
    pub fn bwmodel(&self, feats: &[f32]) -> Result<Vec<f32>> {
        let exe =
            self.bwmodel.as_ref().ok_or_else(|| anyhow!("bwmodel.hlo.txt not in artifact set"))?;
        if feats.len() % BWMODEL_FEATURES != 0 {
            bail!("feature vector length {} not a multiple of {}", feats.len(), BWMODEL_FEATURES);
        }
        let rows = feats.len() / BWMODEL_FEATURES;
        let mut out = Vec::with_capacity(rows);
        for chunk in feats.chunks(BWMODEL_BLOCK * BWMODEL_FEATURES) {
            let n = chunk.len() / BWMODEL_FEATURES;
            let mut padded = vec![0f32; BWMODEL_BLOCK * BWMODEL_FEATURES];
            padded[..chunk.len()].copy_from_slice(chunk);
            let lit = xla::Literal::vec1(&padded)
                .reshape(&[BWMODEL_BLOCK as i64, BWMODEL_FEATURES as i64])
                .map_err(|e| anyhow!("{e:?}"))?;
            let res = exe.execute::<xla::Literal>(&[lit]).map_err(|e| anyhow!("{e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("{e:?}"))?
                .to_tuple1()
                .map_err(|e| anyhow!("{e:?}"))?;
            self.bump();
            let preds: Vec<f32> = res.to_vec().map_err(|e| anyhow!("{e:?}"))?;
            out.extend_from_slice(&preds[..n]);
        }
        Ok(out)
    }
}

/// Default artifacts directory (honours `DDR4BENCH_ARTIFACTS`).
pub fn default_dir() -> PathBuf {
    crate::artifacts_dir()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full runtime tests (needing built artifacts) live in
    // rust/tests/runtime_artifacts.rs; only filesystem-free checks here.

    #[test]
    fn artifacts_present_on_missing_dir() {
        assert!(!XlaRuntime::artifacts_present(Path::new("/nonexistent/dir")));
    }

    #[test]
    fn block_constants_consistent() {
        assert_eq!(DATAGEN_BLOCK % 2, 0);
        assert_eq!(BWMODEL_BLOCK % 2, 0);
        assert!(BWMODEL_FEATURES >= 6);
    }
}
