//! Fault injection for the audit mutation tests: corrupt exactly one
//! command of a known-legal stream and assert the *specific* rule fires.
//!
//! This is how the analyzer itself is proven. A checker that never
//! fires is indistinguishable from a perfect controller; each mutation
//! case demonstrates the corresponding rule detects the violation it
//! claims to cover (see `rust/tests/audit_mutation.rs`).

use crate::obs::cmdtrace::{TraceCmd, TraceEvent};

/// One single-command corruption of a trace.
#[derive(Debug, Clone)]
pub enum Mutation {
    /// Move event `index` to `cycle` (e.g. make an ACT early).
    ShiftTo {
        /// Index into the event vector before mutation.
        index: usize,
        /// New issue cycle.
        cycle: u64,
    },
    /// Redirect event `index` to another bank.
    Retarget {
        /// Index into the event vector before mutation.
        index: usize,
        /// New bank group.
        bank_group: u32,
        /// New bank within the group.
        bank: u32,
    },
    /// Rewrite the row of event `index` (CAS row mismatch).
    SetRow {
        /// Index into the event vector before mutation.
        index: usize,
        /// New row.
        row: u32,
    },
    /// Rewrite the command kind of event `index`.
    SetCmd {
        /// Index into the event vector before mutation.
        index: usize,
        /// New command.
        cmd: TraceCmd,
    },
    /// Insert an extra event (e.g. a fifth ACT inside tFAW).
    Insert(TraceEvent),
    /// Delete event `index` (e.g. drop the PRE before a re-ACT).
    Remove {
        /// Index into the event vector before mutation.
        index: usize,
    },
}

/// Apply one mutation, then restore cycle order (the auditor consumes
/// streams in non-decreasing cycle order, as the hardware would emit
/// them). The sort is stable so equal-cycle events keep their relative
/// order.
pub fn apply(events: &mut Vec<TraceEvent>, mutation: Mutation) {
    match mutation {
        Mutation::ShiftTo { index, cycle } => events[index].cycle = cycle,
        Mutation::Retarget { index, bank_group, bank } => {
            events[index].bank_group = bank_group;
            events[index].bank = bank;
        }
        Mutation::SetRow { index, row } => events[index].row = row,
        Mutation::SetCmd { index, cmd } => events[index].cmd = cmd,
        Mutation::Insert(ev) => events.push(ev),
        Mutation::Remove { index } => {
            events.remove(index);
        }
    }
    events.sort_by_key(|e| e.cycle);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(cycle: u64) -> TraceEvent {
        TraceEvent { cycle, cmd: TraceCmd::Act, bank_group: 0, bank: 0, row: 0 }
    }

    #[test]
    fn shift_resorts_by_cycle() {
        let mut evs = vec![ev(10), ev(20), ev(30)];
        apply(&mut evs, Mutation::ShiftTo { index: 2, cycle: 5 });
        assert_eq!(evs.iter().map(|e| e.cycle).collect::<Vec<_>>(), vec![5, 10, 20]);
    }

    #[test]
    fn insert_and_remove_keep_order() {
        let mut evs = vec![ev(10), ev(30)];
        apply(&mut evs, Mutation::Insert(ev(20)));
        assert_eq!(evs.iter().map(|e| e.cycle).collect::<Vec<_>>(), vec![10, 20, 30]);
        apply(&mut evs, Mutation::Remove { index: 0 });
        assert_eq!(evs.iter().map(|e| e.cycle).collect::<Vec<_>>(), vec![20, 30]);
    }

    #[test]
    fn set_cmd_and_row_rewrite_in_place() {
        let mut evs = vec![ev(10)];
        apply(&mut evs, Mutation::SetCmd { index: 0, cmd: TraceCmd::Ref });
        apply(&mut evs, Mutation::SetRow { index: 0, row: 99 });
        assert_eq!(evs[0].cmd, TraceCmd::Ref);
        assert_eq!(evs[0].row, 99);
    }
}
