//! Independent shadow replay of a DRAM command stream against the
//! declarative rulebook.
//!
//! The auditor consumes [`crate::obs::cmdtrace::TraceEvent`]s — either
//! live off the controller's `issue_cmd` funnel or offline from a trace
//! CSV — and re-derives bank state from nothing but the events
//! themselves. It shares *no* code with `ddr4::bank` / `ddr4::device`:
//! every bound comes from [`Rulebook`], every state transition from this
//! file. A controller bug therefore has to be mirrored here, in a
//! second unrelated encoding of JEDEC, to go unreported.
//!
//! Recovery model: after reporting a violation the auditor *adopts* the
//! event's implied state (the ACT opens the row, the early CAS still
//! reads it) so one bad command yields one violation, not a cascade.
//!
//! Truncated streams: when the bounded trace ring dropped events, the
//! stream has no prefix, so banks start in an `Unknown` state and checks
//! that need unseen history are skipped (adopt-on-first-sight). A
//! truncated stream can still *fail* an audit, but it can never be
//! certified clean — see [`super::report`].

use std::collections::BTreeMap;

use crate::ddr4::timing::TimingParams;
use crate::ddr4::Cycle;
use crate::obs::cmdtrace::{TraceCmd, TraceEvent};

use super::rules::{RuleId, Rulebook};

/// How the stream being audited begins.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamStart {
    /// The stream starts at cycle 0 of the run: banks are known closed
    /// and every rule applies from the first event.
    Complete,
    /// The stream lost its prefix (trace-ring overflow): bank state is
    /// unknown until first sight and prefix-dependent checks are skipped.
    Truncated,
}

/// One detected protocol violation.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Which rule was broken.
    pub rule: RuleId,
    /// Cycle of the offending command.
    pub cycle: Cycle,
    /// Bank group of the offending command (0 for REF).
    pub bank_group: u32,
    /// Bank within the group (0 for REF).
    pub bank: u32,
    /// Human-readable specifics: observed gap vs required bound.
    pub detail: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} @{} bg{} b{}: {}",
            self.rule.id(),
            self.cycle,
            self.bank_group,
            self.bank,
            self.detail
        )
    }
}

/// Per-bank shadow row state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RowState {
    /// Truncated stream, bank not yet observed.
    Unknown,
    /// Precharged.
    Closed,
    /// Activated with this row.
    Open(u32),
}

#[derive(Debug, Clone)]
struct BankShadow {
    row: RowState,
    last_act: Option<Cycle>,
    last_rd: Option<Cycle>,
    last_wr: Option<Cycle>,
    /// When the most recent precharge of this bank *completes* issuing:
    /// the explicit PRE cycle, or the implicit precharge point of an
    /// RDA/WRA (which may lie in the future of the CAS).
    last_pre: Option<Cycle>,
}

impl BankShadow {
    fn new(start: StreamStart) -> Self {
        Self {
            row: match start {
                StreamStart::Complete => RowState::Closed,
                StreamStart::Truncated => RowState::Unknown,
            },
            last_act: None,
            last_rd: None,
            last_wr: None,
            last_pre: None,
        }
    }
}

#[derive(Debug, Clone, Default)]
struct GroupShadow {
    last_act: Option<Cycle>,
    last_cas: Option<Cycle>,
}

/// Violations stored verbatim; beyond this only the per-rule counters
/// keep counting (a broken stream can violate millions of times).
pub const MAX_STORED_VIOLATIONS: usize = 64;

/// The shadow state machine. Feed it every [`TraceEvent`] of one channel
/// in cycle order, then read the verdict.
#[derive(Debug, Clone)]
pub struct Auditor {
    rules: Rulebook,
    start: StreamStart,
    banks: BTreeMap<(u32, u32), BankShadow>,
    groups: BTreeMap<u32, GroupShadow>,
    /// Cycles of up to the last four ACTs, oldest first (tFAW window).
    act_window: Vec<Cycle>,
    last_act_any: Option<Cycle>,
    last_cas_any: Option<Cycle>,
    last_rd_cas: Option<Cycle>,
    /// Most recent WR CAS: (cycle, bank group) — group picks tWTR_S vs _L.
    last_wr_cas: Option<(Cycle, u32)>,
    last_ref: Option<Cycle>,
    first_cycle: Option<Cycle>,
    last_cycle: Option<Cycle>,
    events: u64,
    counts: [u64; RuleId::ALL.len()],
    total: u64,
    stored: Vec<Violation>,
}

impl Auditor {
    /// Build an auditor for one channel: derive the rulebook from the
    /// timing table and reset all shadow state.
    pub fn new(timing: &TimingParams, start: StreamStart) -> Self {
        Self {
            rules: Rulebook::from_timing(timing),
            start,
            banks: BTreeMap::new(),
            groups: BTreeMap::new(),
            act_window: Vec::with_capacity(4),
            last_act_any: None,
            last_cas_any: None,
            last_rd_cas: None,
            last_wr_cas: None,
            last_ref: None,
            first_cycle: None,
            last_cycle: None,
            events: 0,
            counts: [0; RuleId::ALL.len()],
            total: 0,
            stored: Vec::new(),
        }
    }

    /// The derived rulebook this auditor enforces.
    pub fn rulebook(&self) -> &Rulebook {
        &self.rules
    }

    /// How the stream was assumed to begin.
    pub fn start(&self) -> StreamStart {
        self.start
    }

    /// Events observed so far.
    pub fn events(&self) -> u64 {
        self.events
    }

    /// Total violations detected (including any beyond the storage cap).
    pub fn total_violations(&self) -> u64 {
        self.total
    }

    /// Per-rule violation count, indexed like [`RuleId::ALL`].
    pub fn counts(&self) -> &[u64; RuleId::ALL.len()] {
        &self.counts
    }

    /// Violations for one rule.
    pub fn count(&self, rule: RuleId) -> u64 {
        self.counts[rule.index()]
    }

    /// The first [`MAX_STORED_VIOLATIONS`] violations, verbatim.
    pub fn violations(&self) -> &[Violation] {
        &self.stored
    }

    /// True when no violation has been detected. Note this alone does
    /// not certify a stream: a truncated stream is never clean — see
    /// [`super::report::status`].
    pub fn is_clean(&self) -> bool {
        self.total == 0
    }

    /// Rule IDs with at least one violation, in stable order.
    pub fn violated_rules(&self) -> Vec<RuleId> {
        RuleId::ALL.iter().copied().filter(|r| self.counts[r.index()] > 0).collect()
    }

    fn record(&mut self, rule: RuleId, cycle: Cycle, bank_group: u32, bank: u32, detail: String) {
        self.counts[rule.index()] += 1;
        self.total += 1;
        if self.stored.len() < MAX_STORED_VIOLATIONS {
            self.stored.push(Violation { rule, cycle, bank_group, bank, detail });
        }
    }

    /// Check `t >= prev + bound`; on failure record a violation with the
    /// observed-vs-required gap spelled out.
    #[allow(clippy::too_many_arguments)]
    fn min_gap(
        &mut self,
        rule: RuleId,
        t: Cycle,
        prev: Cycle,
        bound: Cycle,
        bg: u32,
        bank: u32,
        what: &str,
    ) {
        if t < prev + bound {
            let gap = t.saturating_sub(prev);
            let detail = format!("{what}: gap {gap} < {} {bound} (prev @{prev})", rule.id());
            self.record(rule, t, bg, bank, detail);
        }
    }

    /// Feed one command. Events must arrive in non-decreasing cycle
    /// order (the trace ring and the CSV both guarantee it).
    pub fn observe(&mut self, ev: &TraceEvent) {
        let t = ev.cycle;
        self.events += 1;
        if self.first_cycle.is_none() {
            self.first_cycle = Some(t);
        }
        self.last_cycle = Some(t);

        // tRFC gates every command after a REF.
        if let Some(r) = self.last_ref {
            if !matches!(ev.cmd, TraceCmd::Ref) {
                self.min_gap(RuleId::Trfc, t, r, self.rules.trfc, ev.bank_group, ev.bank, "post-REF");
            }
        }

        match ev.cmd {
            TraceCmd::Act => self.on_act(t, ev.bank_group, ev.bank, ev.row),
            TraceCmd::Pre => self.on_pre(t, ev.bank_group, ev.bank),
            TraceCmd::PreAll => self.on_pre_all(t),
            TraceCmd::Rd => self.on_cas(t, ev.bank_group, ev.bank, ev.row, false, false),
            TraceCmd::Rda => self.on_cas(t, ev.bank_group, ev.bank, ev.row, false, true),
            TraceCmd::Wr => self.on_cas(t, ev.bank_group, ev.bank, ev.row, true, false),
            TraceCmd::Wra => self.on_cas(t, ev.bank_group, ev.bank, ev.row, true, true),
            TraceCmd::Ref => self.on_ref(t),
        }
    }

    fn bank_mut(banks: &mut BTreeMap<(u32, u32), BankShadow>, start: StreamStart, bg: u32, b: u32) -> &mut BankShadow {
        banks.entry((bg, b)).or_insert_with(|| BankShadow::new(start))
    }

    fn on_act(&mut self, t: Cycle, bg: u32, b: u32, row: u32) {
        let start = self.start;
        let shadow = Self::bank_mut(&mut self.banks, start, bg, b);
        if let RowState::Open(open) = shadow.row {
            let detail = format!("row {open} already open, ACT for row {row}");
            self.record(RuleId::ActOpenBank, t, bg, b, detail);
        }

        let (last_pre, last_act) = {
            let s = &self.banks[&(bg, b)];
            (s.last_pre, s.last_act)
        };
        if let Some(p) = last_pre {
            self.min_gap(RuleId::Trp, t, p, self.rules.trp, bg, b, "PRE->ACT");
        }
        if let Some(a) = last_act {
            self.min_gap(RuleId::Trc, t, a, self.rules.trc, bg, b, "ACT->ACT same bank");
        }
        if let Some(a) = self.groups.get(&bg).and_then(|g| g.last_act) {
            self.min_gap(RuleId::TrrdL, t, a, self.rules.trrd_l, bg, b, "ACT->ACT same group");
        }
        if let Some(a) = self.last_act_any {
            self.min_gap(RuleId::TrrdS, t, a, self.rules.trrd_s, bg, b, "ACT->ACT any bank");
        }
        if self.act_window.len() == 4 {
            let oldest = self.act_window[0];
            if t < oldest + self.rules.tfaw {
                let detail = format!(
                    "5th ACT {} cycles after window start @{oldest} (tFAW {})",
                    t - oldest,
                    self.rules.tfaw
                );
                self.record(RuleId::Tfaw, t, bg, b, detail);
            }
        }

        // Adopt the activate.
        let shadow = Self::bank_mut(&mut self.banks, start, bg, b);
        shadow.row = RowState::Open(row);
        shadow.last_act = Some(t);
        self.groups.entry(bg).or_default().last_act = Some(t);
        self.last_act_any = Some(t);
        if self.act_window.len() == 4 {
            self.act_window.remove(0);
        }
        self.act_window.push(t);
    }

    /// Precharge checks for one open bank; returns violations as
    /// (rule, detail) so PREA can reuse them.
    fn pre_checks(&mut self, t: Cycle, bg: u32, b: u32) {
        let (last_act, last_rd, last_wr) = {
            let s = &self.banks[&(bg, b)];
            (s.last_act, s.last_rd, s.last_wr)
        };
        if let Some(a) = last_act {
            self.min_gap(RuleId::Tras, t, a, self.rules.tras, bg, b, "ACT->PRE");
        }
        if let Some(r) = last_rd {
            self.min_gap(RuleId::Trtp, t, r, self.rules.rd_to_pre, bg, b, "RD->PRE");
        }
        if let Some(w) = last_wr {
            self.min_gap(RuleId::Twr, t, w, self.rules.wr_to_pre, bg, b, "WR->PRE");
        }
    }

    fn close_bank(&mut self, bg: u32, b: u32, pre_at: Option<Cycle>) {
        let start = self.start;
        let shadow = Self::bank_mut(&mut self.banks, start, bg, b);
        shadow.row = RowState::Closed;
        shadow.last_rd = None;
        shadow.last_wr = None;
        if pre_at.is_some() {
            shadow.last_pre = pre_at;
        }
    }

    fn on_pre(&mut self, t: Cycle, bg: u32, b: u32) {
        let start = self.start;
        let row = Self::bank_mut(&mut self.banks, start, bg, b).row;
        match row {
            // PRE to a precharged bank is a JEDEC no-op; unknown banks
            // (truncated stream) close leniently without starting tRP.
            RowState::Closed => {}
            RowState::Unknown => self.close_bank(bg, b, None),
            RowState::Open(_) => {
                self.pre_checks(t, bg, b);
                self.close_bank(bg, b, Some(t));
            }
        }
    }

    fn on_pre_all(&mut self, t: Cycle) {
        let keys: Vec<(u32, u32)> = self.banks.keys().copied().collect();
        for (bg, b) in keys {
            let row = self.banks[&(bg, b)].row;
            match row {
                RowState::Closed => {}
                RowState::Unknown => self.close_bank(bg, b, None),
                RowState::Open(_) => {
                    self.pre_checks(t, bg, b);
                    self.close_bank(bg, b, Some(t));
                }
            }
        }
    }

    fn on_cas(&mut self, t: Cycle, bg: u32, b: u32, row: u32, is_wr: bool, auto_pre: bool) {
        let start = self.start;
        let kind = if is_wr { "WR" } else { "RD" };
        let shadow_row = Self::bank_mut(&mut self.banks, start, bg, b).row;
        match shadow_row {
            RowState::Closed => {
                let detail = format!("{kind} to precharged bank (row {row})");
                self.record(RuleId::CasClosedBank, t, bg, b, detail);
            }
            RowState::Open(open) if open != row => {
                let detail = format!("{kind} row {row} but row {open} is open");
                self.record(RuleId::CasRowMismatch, t, bg, b, detail);
            }
            // Unknown: adopt-on-first-sight, no structural claim possible.
            RowState::Open(_) | RowState::Unknown => {}
        }

        // tRCD only applies when we saw the opening ACT ourselves.
        let last_act = self.banks[&(bg, b)].last_act;
        if matches!(shadow_row, RowState::Open(_)) {
            if let Some(a) = last_act {
                self.min_gap(RuleId::Trcd, t, a, self.rules.trcd, bg, b, "ACT->CAS");
            }
        }

        if let Some(c) = self.last_cas_any {
            self.min_gap(RuleId::TccdS, t, c, self.rules.tccd_s, bg, b, "CAS->CAS any group");
        }
        if let Some(c) = self.groups.get(&bg).and_then(|g| g.last_cas) {
            self.min_gap(RuleId::TccdL, t, c, self.rules.tccd_l, bg, b, "CAS->CAS same group");
        }
        if is_wr {
            if let Some(r) = self.last_rd_cas {
                self.min_gap(RuleId::Trtw, t, r, self.rules.rd_to_wr, bg, b, "RD->WR turnaround");
            }
        } else if let Some((w, wg)) = self.last_wr_cas {
            if wg == bg {
                self.min_gap(RuleId::TwtrL, t, w, self.rules.wr_to_rd_l, bg, b, "WR->RD same group");
            } else {
                self.min_gap(RuleId::TwtrS, t, w, self.rules.wr_to_rd_s, bg, b, "WR->RD cross group");
            }
        }

        // Adopt the access.
        let shadow = Self::bank_mut(&mut self.banks, start, bg, b);
        shadow.row = RowState::Open(row);
        if is_wr {
            shadow.last_wr = Some(t);
        } else {
            shadow.last_rd = Some(t);
        }
        self.groups.entry(bg).or_default().last_cas = Some(t);
        self.last_cas_any = Some(t);
        if is_wr {
            self.last_wr_cas = Some((t, bg));
        } else {
            self.last_rd_cas = Some(t);
        }

        if auto_pre {
            // The device completes the implicit precharge only once both
            // the CAS recovery and tRAS have elapsed; tRP counts from
            // that completion point.
            let recovery = if is_wr { self.rules.wr_to_pre } else { self.rules.rd_to_pre };
            let mut pre_at = t + recovery;
            if let Some(a) = self.banks[&(bg, b)].last_act {
                pre_at = pre_at.max(a + self.rules.tras);
            }
            self.close_bank(bg, b, Some(pre_at));
        }
    }

    fn on_ref(&mut self, t: Cycle) {
        if let Some(r) = self.last_ref {
            self.min_gap(RuleId::Trfc, t, r, self.rules.trfc, 0, 0, "REF->REF");
        }
        // JEDEC allows postponing up to 8 refreshes: 9 x tREFI max gap.
        let base = match (self.last_ref, self.start) {
            (Some(r), _) => Some(r),
            (None, StreamStart::Complete) => Some(0),
            // Truncated: refreshes before the window are invisible; the
            // bound only applies within the observed stream.
            (None, StreamStart::Truncated) => self.first_cycle,
        };
        if let Some(base) = base {
            if t > base + self.rules.trefi_max {
                let detail = format!(
                    "REF gap {} > 9*tREFI {} (prev @{base})",
                    t - base,
                    self.rules.trefi_max
                );
                self.record(RuleId::TrefiMax, t, 0, 0, detail);
            }
        }

        let keys: Vec<(u32, u32)> = self.banks.keys().copied().collect();
        for (bg, b) in keys {
            if let RowState::Open(open) = self.banks[&(bg, b)].row {
                let detail = format!("REF with row {open} open");
                self.record(RuleId::RefOpenBank, t, bg, b, detail);
            }
            // REF leaves every bank precharged regardless.
            self.close_bank(bg, b, None);
        }
        self.last_ref = Some(t);
    }

    /// Non-mutating end-of-stream check: a run may never leave more than
    /// 9 x tREFI without a refresh, including its tail. Returns any
    /// violations found (the stream itself is left untouched so the
    /// check can be re-run).
    pub fn end_of_stream_check(&self) -> Vec<Violation> {
        let Some(end) = self.last_cycle else { return Vec::new() };
        let base = match (self.last_ref, self.start) {
            (Some(r), _) => r,
            (None, StreamStart::Complete) => 0,
            (None, StreamStart::Truncated) => match self.first_cycle {
                Some(f) => f,
                None => return Vec::new(),
            },
        };
        if end > base + self.rules.trefi_max {
            vec![Violation {
                rule: RuleId::TrefiMax,
                cycle: end,
                bank_group: 0,
                bank: 0,
                detail: format!(
                    "stream ends {} cycles after last REF basis @{base} (9*tREFI {})",
                    end - base,
                    self.rules.trefi_max
                ),
            }]
        } else {
            Vec::new()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SpeedBin;

    fn ev(cycle: Cycle, cmd: TraceCmd, bg: u32, b: u32, row: u32) -> TraceEvent {
        TraceEvent { cycle, cmd, bank_group: bg, bank: b, row }
    }

    fn auditor() -> Auditor {
        Auditor::new(&TimingParams::for_bin(SpeedBin::Ddr4_1600), StreamStart::Complete)
    }

    #[test]
    fn legal_open_page_burst_is_clean() {
        // DDR4-1600: trcd=11, tras=28, rd_to_pre=6, trp=11, tccd_l=5.
        let mut a = auditor();
        for e in [
            ev(1000, TraceCmd::Act, 0, 0, 42),
            ev(1011, TraceCmd::Rd, 0, 0, 42),
            ev(1016, TraceCmd::Rd, 0, 0, 42),
            ev(1030, TraceCmd::Pre, 0, 0, 42),
            ev(1041, TraceCmd::Act, 0, 0, 7),
        ] {
            a.observe(&e);
        }
        assert!(a.is_clean(), "unexpected: {:?}", a.violations());
        assert_eq!(a.events(), 5);
    }

    #[test]
    fn early_cas_fires_trcd_once() {
        let mut a = auditor();
        a.observe(&ev(1000, TraceCmd::Act, 0, 0, 42));
        a.observe(&ev(1010, TraceCmd::Rd, 0, 0, 42));
        assert_eq!(a.total_violations(), 1);
        assert_eq!(a.count(RuleId::Trcd), 1);
    }

    #[test]
    fn auto_precharge_delays_next_act_by_trp_from_completion() {
        // RDA @1011: pre completes at max(1011+6, 1000+28) = 1028;
        // next ACT legal at 1039.
        let mut a = auditor();
        a.observe(&ev(1000, TraceCmd::Act, 0, 0, 42));
        a.observe(&ev(1011, TraceCmd::Rda, 0, 0, 42));
        a.observe(&ev(1038, TraceCmd::Act, 0, 0, 7));
        assert_eq!(a.count(RuleId::Trp), 1);
        let mut b = auditor();
        b.observe(&ev(1000, TraceCmd::Act, 0, 0, 42));
        b.observe(&ev(1011, TraceCmd::Rda, 0, 0, 42));
        b.observe(&ev(1039, TraceCmd::Act, 0, 0, 7));
        assert!(b.is_clean(), "unexpected: {:?}", b.violations());
    }

    #[test]
    fn truncated_start_adopts_state_without_false_positives() {
        // Mid-stream CAS to a never-seen bank: a complete stream flags
        // it, a truncated one adopts it.
        let t = TimingParams::for_bin(SpeedBin::Ddr4_1600);
        let mut complete = Auditor::new(&t, StreamStart::Complete);
        complete.observe(&ev(500, TraceCmd::Rd, 1, 2, 9));
        assert_eq!(complete.count(RuleId::CasClosedBank), 1);

        let mut truncated = Auditor::new(&t, StreamStart::Truncated);
        truncated.observe(&ev(500, TraceCmd::Rd, 1, 2, 9));
        assert!(truncated.is_clean(), "unexpected: {:?}", truncated.violations());
    }

    #[test]
    fn end_of_stream_flags_overdue_refresh() {
        let mut a = auditor();
        a.observe(&ev(1000, TraceCmd::Act, 0, 0, 1));
        a.observe(&ev(60000, TraceCmd::Pre, 0, 0, 1));
        // 9 * tREFI = 56160 at DDR4-1600; no REF ever seen.
        let eos = a.end_of_stream_check();
        assert_eq!(eos.len(), 1);
        assert_eq!(eos[0].rule, RuleId::TrefiMax);
        // Non-mutating: counters untouched, re-runnable.
        assert_eq!(a.count(RuleId::TrefiMax), 0);
        assert_eq!(a.end_of_stream_check().len(), 1);
    }

    #[test]
    fn violation_storage_caps_but_counters_keep_counting() {
        let mut a = auditor();
        a.observe(&ev(0, TraceCmd::Act, 0, 0, 1));
        for i in 0..(MAX_STORED_VIOLATIONS as u64 + 10) {
            // Same-bank back-to-back ACTs: tRC (and friends) every time.
            a.observe(&ev(1 + i, TraceCmd::Act, 0, 0, 1));
        }
        assert_eq!(a.violations().len(), MAX_STORED_VIOLATIONS);
        assert!(a.total_violations() > MAX_STORED_VIOLATIONS as u64);
    }
}
