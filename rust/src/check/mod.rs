//! Independent JEDEC protocol-legality analysis of the DRAM command
//! stream.
//!
//! The controller and device models enforce timing *prospectively* —
//! they refuse to schedule an illegal command. This module is the
//! second opinion: a declarative rulebook ([`rules`]) derived only from
//! the `ddr4::timing` tables, replayed over the emitted command stream
//! by an independent shadow state machine ([`auditor`]) that shares no
//! code with the models it audits. The differential tests prove both
//! engines agree; the auditor proves what they agree *on* is legal
//! DDR4 traffic — the distinction "The Memory Controller Wall" shows
//! matters, since both sides of a differential can be wrong together.
//!
//! Auditing is observation-only, like telemetry: arming it never
//! changes scheduling, timing, or results. Entry points:
//! - live: `run --audit` / `sweep --audit` tap the controller's
//!   `issue_cmd` funnel (zero cost when off, like `--cmd-trace`);
//! - offline: `ddr4bench audit <trace.csv>` replays a captured trace
//!   ([`offline`]);
//! - host protocol: `AUDIT <ch>` returns the one-line summary.
//!
//! The analyzer itself is proven by mutation ([`mutate`],
//! `rust/tests/audit_mutation.rs`): corrupt exactly one command of a
//! legal stream, assert the specific rule ID fires.

pub mod auditor;
pub mod mutate;
pub mod offline;
pub mod report;
pub mod rules;

pub use auditor::{Auditor, StreamStart, Violation, MAX_STORED_VIOLATIONS};
pub use report::Status;
pub use rules::{Rule, RuleId, Rulebook};
