//! Offline trace auditing: parse a `run --cmd-trace` CSV back into
//! [`TraceEvent`]s and replay each channel through the [`Auditor`].
//!
//! Two CSV dialects are accepted:
//! - the annotated export ([`crate::obs::export::trace_csv_annotated`])
//!   whose `#` comment lines carry the speed bin and per-channel
//!   `events=`/`dropped=` counts — a channel with drops is audited as a
//!   truncated stream (it can fail but never be certified clean);
//! - the plain header-only export ([`crate::obs::export::trace_csv`]),
//!   which has no metadata: the stream is assumed complete and the
//!   speed bin must be supplied by the caller (`audit --speed`).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::config::SpeedBin;
use crate::ddr4::TimingParams;
use crate::obs::cmdtrace::{TraceCmd, TraceEvent};

use super::auditor::{Auditor, StreamStart};

/// One channel's slice of a parsed trace.
#[derive(Debug, Clone, Default)]
pub struct ChannelTrace {
    /// Events in cycle order.
    pub events: Vec<TraceEvent>,
    /// Ring evictions before capture, from `# channel=.. dropped=..`
    /// metadata (0 when the CSV carries none).
    pub dropped: u64,
}

/// A parsed trace CSV: per-channel event streams plus any metadata the
/// annotated dialect carried.
#[derive(Debug, Clone, Default)]
pub struct ParsedTrace {
    /// Speed bin from `# speed=..`, if present.
    pub speed: Option<SpeedBin>,
    /// Channels in ascending order.
    pub channels: BTreeMap<usize, ChannelTrace>,
}

/// Parse a trace CSV (either dialect). Malformed lines are hard errors
/// with their line number — an auditor fed garbage must not shrug.
pub fn parse_trace_csv(text: &str) -> Result<ParsedTrace> {
    let mut parsed = ParsedTrace::default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(comment) = line.strip_prefix('#') {
            parse_comment(comment.trim(), &mut parsed)
                .map_err(|e| anyhow!("trace line {}: {e}", lineno + 1))?;
            continue;
        }
        if line.starts_with("cycle,") {
            continue; // header
        }
        let (ch, ev) = parse_row(line).map_err(|e| anyhow!("trace line {}: {e}", lineno + 1))?;
        parsed.channels.entry(ch).or_default().events.push(ev);
    }
    for trace in parsed.channels.values_mut() {
        trace.events.sort_by_key(|e| e.cycle);
    }
    Ok(parsed)
}

fn parse_comment(comment: &str, parsed: &mut ParsedTrace) -> Result<()> {
    if let Some(v) = comment.strip_prefix("speed=") {
        parsed.speed =
            Some(SpeedBin::parse(v).ok_or_else(|| anyhow!("unknown speed bin `{v}`"))?);
        return Ok(());
    }
    if comment.strip_prefix("channel=").is_some() {
        let mut ch: Option<usize> = None;
        let mut dropped: Option<u64> = None;
        for tok in comment.split_whitespace() {
            if let Some(v) = tok.strip_prefix("channel=") {
                ch = Some(v.parse().map_err(|_| anyhow!("bad channel `{v}`"))?);
            } else if let Some(v) = tok.strip_prefix("dropped=") {
                dropped = Some(v.parse().map_err(|_| anyhow!("bad dropped `{v}`"))?);
            }
        }
        let ch = ch.ok_or_else(|| anyhow!("channel metadata without channel id"))?;
        parsed.channels.entry(ch).or_default().dropped = dropped.unwrap_or(0);
    }
    // Unknown comments (e.g. the banner) are ignored.
    Ok(())
}

fn parse_row(line: &str) -> Result<(usize, TraceEvent)> {
    let fields: Vec<&str> = line.split(',').collect();
    if fields.len() != 6 {
        bail!("expected 6 fields, got {}", fields.len());
    }
    let cycle: u64 = fields[0].parse().map_err(|_| anyhow!("bad cycle `{}`", fields[0]))?;
    let ch: usize = fields[1].parse().map_err(|_| anyhow!("bad channel `{}`", fields[1]))?;
    let cmd = TraceCmd::parse(fields[2])
        .ok_or_else(|| anyhow!("unknown command `{}`", fields[2]))?;
    let bank_group: u32 =
        fields[3].parse().map_err(|_| anyhow!("bad bank_group `{}`", fields[3]))?;
    let bank: u32 = fields[4].parse().map_err(|_| anyhow!("bad bank `{}`", fields[4]))?;
    let row: u32 = fields[5].parse().map_err(|_| anyhow!("bad row `{}`", fields[5]))?;
    Ok((ch, TraceEvent { cycle, cmd, bank_group, bank, row }))
}

/// One audited channel of an offline run.
#[derive(Debug)]
pub struct ChannelAudit {
    /// Channel index from the CSV.
    pub channel: usize,
    /// The replayed auditor, ready for [`super::report`] rendering.
    pub auditor: Auditor,
    /// Drop count carried over from the CSV metadata.
    pub dropped: u64,
}

/// Replay every channel of a parsed trace. `speed_override` wins over
/// the CSV's own metadata; a trace with neither is an error (auditing
/// against a guessed rulebook would certify nothing).
pub fn audit_trace(parsed: &ParsedTrace, speed_override: Option<SpeedBin>) -> Result<Vec<ChannelAudit>> {
    let speed = speed_override.or(parsed.speed).ok_or_else(|| {
        anyhow!("trace carries no `# speed=` metadata; pass --speed <bin> explicitly")
    })?;
    let timing = TimingParams::for_bin(speed);
    let mut out = Vec::new();
    for (&channel, trace) in &parsed.channels {
        let start =
            if trace.dropped > 0 { StreamStart::Truncated } else { StreamStart::Complete };
        let mut auditor = Auditor::new(&timing, start);
        for ev in &trace.events {
            auditor.observe(ev);
        }
        out.push(ChannelAudit { channel, auditor, dropped: trace.dropped });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::report::{self, Status};
    use crate::obs::cmdtrace::CmdTrace;
    use crate::obs::export::{trace_csv, trace_csv_annotated};

    fn ring(events: &[(u64, TraceCmd)]) -> CmdTrace {
        let mut t = CmdTrace::new(64);
        for &(cycle, cmd) in events {
            t.record(TraceEvent { cycle, cmd, bank_group: 0, bank: 0, row: 5 });
        }
        t
    }

    #[test]
    fn annotated_roundtrip_audits_clean() {
        let t = ring(&[(1000, TraceCmd::Act), (1011, TraceCmd::Rd), (1030, TraceCmd::Pre)]);
        let csv = trace_csv_annotated("DDR4-1600", &[(0, &t)]);
        let parsed = parse_trace_csv(&csv).expect("parse");
        assert_eq!(parsed.speed, Some(SpeedBin::Ddr4_1600));
        let audits = audit_trace(&parsed, None).expect("audit");
        assert_eq!(audits.len(), 1);
        assert_eq!(audits[0].auditor.events(), 3);
        assert_eq!(report::status(&audits[0].auditor, audits[0].dropped), Status::Clean);
    }

    #[test]
    fn plain_csv_needs_explicit_speed() {
        let t = ring(&[(1000, TraceCmd::Act)]);
        let csv = trace_csv(0, &t);
        let parsed = parse_trace_csv(&csv).expect("parse");
        assert!(audit_trace(&parsed, None).is_err(), "no metadata and no override");
        let audits = audit_trace(&parsed, Some(SpeedBin::Ddr4_2400)).expect("audit");
        assert_eq!(audits[0].auditor.rulebook().trcd, 16, "2400-bin rulebook applied");
    }

    #[test]
    fn dropped_metadata_forces_truncated_verdict() {
        let mut t = CmdTrace::new(2);
        // Three legal commands through a 2-deep ring: first is evicted.
        for ev in [
            TraceEvent { cycle: 1000, cmd: TraceCmd::Act, bank_group: 0, bank: 0, row: 5 },
            TraceEvent { cycle: 1011, cmd: TraceCmd::Rd, bank_group: 0, bank: 0, row: 5 },
            TraceEvent { cycle: 1016, cmd: TraceCmd::Rd, bank_group: 0, bank: 0, row: 5 },
        ] {
            t.record(ev);
        }
        let csv = trace_csv_annotated("DDR4-1600", &[(0, &t)]);
        let parsed = parse_trace_csv(&csv).expect("parse");
        let audits = audit_trace(&parsed, None).expect("audit");
        assert_eq!(audits[0].dropped, 1);
        assert!(audits[0].auditor.is_clean(), "no violation in the observed tail");
        assert_eq!(
            report::status(&audits[0].auditor, audits[0].dropped),
            Status::Truncated,
            "a partial stream must not be certified clean"
        );
    }

    #[test]
    fn malformed_lines_error_with_line_numbers() {
        let err = parse_trace_csv("cycle,channel,cmd,bank_group,bank,row\n10,0,NOP,0,0,0\n")
            .expect_err("unknown mnemonic");
        assert!(err.to_string().contains("line 2"), "got: {err}");
        let err = parse_trace_csv("10,0,ACT,0,0\n").expect_err("short row");
        assert!(err.to_string().contains("6 fields"), "got: {err}");
    }
}
