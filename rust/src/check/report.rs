//! Rendering of audit verdicts: one-line summaries (CLI, host protocol,
//! sweep CSV) and full multi-line reports (artifacts, `ddr4bench audit`).
//!
//! The verdict model is deliberately conservative: a stream is CLEAN
//! only when *every* command was observed (no ring drops, complete
//! prefix) and zero rules fired, end-of-stream checks included. A
//! truncated stream that shows no violation is reported TRUNCATED, not
//! CLEAN — the auditor cannot certify commands it never saw.

use super::auditor::{Auditor, StreamStart, Violation};

/// Final verdict for one audited channel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    /// Complete stream, zero violations: certified legal.
    Clean,
    /// Zero violations, but part of the stream was never observed.
    Truncated,
    /// At least one rule fired.
    Violations,
}

impl Status {
    /// Stable token used in summaries and CI logs.
    pub fn as_str(self) -> &'static str {
        match self {
            Status::Clean => "CLEAN",
            Status::Truncated => "TRUNCATED",
            Status::Violations => "VIOLATIONS",
        }
    }
}

impl std::fmt::Display for Status {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Violations including the non-mutating end-of-stream checks.
pub fn total_violations(auditor: &Auditor) -> u64 {
    auditor.total_violations() + auditor.end_of_stream_check().len() as u64
}

/// Compute the verdict for one channel. `dropped` is the trace-ring
/// drop count for offline audits (0 for live audits, which tap every
/// command).
pub fn status(auditor: &Auditor, dropped: u64) -> Status {
    if total_violations(auditor) > 0 {
        Status::Violations
    } else if dropped > 0 || auditor.start() == StreamStart::Truncated {
        Status::Truncated
    } else {
        Status::Clean
    }
}

/// One-line machine-greppable summary:
/// `channel=0 events=1234 dropped=0 violations=0 status=CLEAN`.
pub fn summary(auditor: &Auditor, channel: usize, dropped: u64) -> String {
    format!(
        "channel={channel} events={} dropped={dropped} violations={} status={}",
        auditor.events(),
        total_violations(auditor),
        status(auditor, dropped)
    )
}

/// Full multi-line report: summary, per-rule counts with their derived
/// bounds, and the first stored violations verbatim.
pub fn render(auditor: &Auditor, channel: usize, dropped: u64) -> String {
    let mut out = String::new();
    out.push_str(&format!("AUDIT {}\n", summary(auditor, channel, dropped)));
    let eos = auditor.end_of_stream_check();
    let rb = auditor.rulebook();
    for rule in auditor.violated_rules() {
        let bound = rb
            .bound_ck(rule)
            .map(|b| format!(" (bound {b} ck)"))
            .unwrap_or_default();
        out.push_str(&format!("  rule {} x{}{bound}\n", rule.id(), auditor.count(rule)));
    }
    for v in auditor.violations() {
        out.push_str(&format!("  {v}\n"));
    }
    let stored = auditor.violations().len() as u64;
    if auditor.total_violations() > stored {
        out.push_str(&format!(
            "  ... {} further violations not stored\n",
            auditor.total_violations() - stored
        ));
    }
    for v in &eos {
        out.push_str(&format!("  end-of-stream {v}\n"));
    }
    if dropped > 0 {
        out.push_str(&format!(
            "  note: {dropped} events dropped before capture; stream cannot be certified\n"
        ));
    }
    out
}

/// Render every violation (stored + end-of-stream) as display lines —
/// used by CI gates to print why a sweep job failed.
pub fn violation_lines(auditor: &Auditor) -> Vec<String> {
    auditor
        .violations()
        .iter()
        .map(Violation::to_string)
        .chain(auditor.end_of_stream_check().iter().map(|v| format!("end-of-stream {v}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SpeedBin;
    use crate::ddr4::TimingParams;
    use crate::obs::cmdtrace::{TraceCmd, TraceEvent};

    fn ev(cycle: u64, cmd: TraceCmd, bg: u32, b: u32, row: u32) -> TraceEvent {
        TraceEvent { cycle, cmd, bank_group: bg, bank: b, row }
    }

    #[test]
    fn clean_stream_reports_clean() {
        let t = TimingParams::for_bin(SpeedBin::Ddr4_1600);
        let mut a = Auditor::new(&t, StreamStart::Complete);
        a.observe(&ev(100, TraceCmd::Act, 0, 0, 3));
        a.observe(&ev(111, TraceCmd::Rd, 0, 0, 3));
        assert_eq!(status(&a, 0), Status::Clean);
        let line = summary(&a, 2, 0);
        assert!(line.contains("channel=2"));
        assert!(line.contains("violations=0"));
        assert!(line.contains("status=CLEAN"));
    }

    #[test]
    fn dropped_events_demote_clean_to_truncated() {
        let t = TimingParams::for_bin(SpeedBin::Ddr4_1600);
        let mut a = Auditor::new(&t, StreamStart::Truncated);
        a.observe(&ev(100, TraceCmd::Act, 0, 0, 3));
        assert_eq!(status(&a, 7), Status::Truncated);
        assert!(render(&a, 0, 7).contains("cannot be certified"));
    }

    #[test]
    fn violations_render_with_rule_counts() {
        let t = TimingParams::for_bin(SpeedBin::Ddr4_1600);
        let mut a = Auditor::new(&t, StreamStart::Complete);
        a.observe(&ev(100, TraceCmd::Rd, 0, 0, 3));
        assert_eq!(status(&a, 0), Status::Violations);
        let rep = render(&a, 0, 0);
        assert!(rep.contains("CAS_CLOSED_BANK"), "report was: {rep}");
        assert!(rep.contains("status=VIOLATIONS"));
        assert_eq!(violation_lines(&a).len(), 1);
    }
}
