//! The declarative JEDEC rulebook: every protocol-legality rule the
//! auditor enforces, as *data* derived exclusively from
//! [`crate::ddr4::timing::TimingParams`].
//!
//! The rulebook deliberately knows nothing about the bank/device state
//! machines it audits ([`crate::ddr4::bank`] / [`crate::ddr4::device`]):
//! those enforce legality *prospectively* while scheduling, this module
//! states the same JEDEC bounds *declaratively* so an independent shadow
//! replay ([`super::auditor`]) can certify an emitted command stream. A
//! bug that slips through both therefore has to be wrong twice, in two
//! unrelated encodings of the standard.
//!
//! Every rule carries a stable ID string (the `rule_id` surfaced in
//! violation reports, CI artifacts, and the README's rule table — the
//! repo lint `scripts/lint_repo.py` keeps the three in sync).

use crate::ddr4::timing::TimingParams;
use crate::ddr4::Cycle;

/// Stable identifier of one protocol rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum RuleId {
    /// ACT to RD/WR CAS, same bank: >= tRCD.
    Trcd,
    /// PRE (explicit or auto-precharge completion) to ACT, same bank: >= tRP.
    Trp,
    /// ACT to PRE, same bank: >= tRAS.
    Tras,
    /// ACT to ACT, same bank: >= tRC.
    Trc,
    /// CAS to CAS, any bank group: >= tCCD_S.
    TccdS,
    /// CAS to CAS, same bank group: >= tCCD_L.
    TccdL,
    /// ACT to ACT, any bank group: >= tRRD_S.
    TrrdS,
    /// ACT to ACT, same bank group: >= tRRD_L.
    TrrdL,
    /// At most 4 ACTs in any rolling tFAW window.
    Tfaw,
    /// WR CAS to PRE, same bank: >= CWL + BL/2 + tWR (write recovery).
    Twr,
    /// RD CAS to PRE, same bank: >= tRTP.
    Trtp,
    /// WR CAS to RD CAS, different bank group: >= CWL + BL/2 + tWTR_S.
    TwtrS,
    /// WR CAS to RD CAS, same bank group: >= CWL + BL/2 + tWTR_L.
    TwtrL,
    /// RD CAS to WR CAS, any bank: >= CL + BL/2 + 2 - CWL (bus turnaround).
    Trtw,
    /// REF to any command: >= tRFC.
    Trfc,
    /// REF to REF (or end of stream): <= 9 x tREFI (JEDEC allows
    /// postponing at most 8 refreshes).
    TrefiMax,
    /// Structural: ACT to a bank whose row is already open.
    ActOpenBank,
    /// Structural: RD/WR to a precharged (closed) bank.
    CasClosedBank,
    /// Structural: RD/WR row differs from the row the shadow state has open.
    CasRowMismatch,
    /// Structural: REF while any bank is open.
    RefOpenBank,
}

impl RuleId {
    /// Every rule, in the stable rendering order of the rulebook.
    pub const ALL: [RuleId; 20] = [
        RuleId::Trcd,
        RuleId::Trp,
        RuleId::Tras,
        RuleId::Trc,
        RuleId::TccdS,
        RuleId::TccdL,
        RuleId::TrrdS,
        RuleId::TrrdL,
        RuleId::Tfaw,
        RuleId::Twr,
        RuleId::Trtp,
        RuleId::TwtrS,
        RuleId::TwtrL,
        RuleId::Trtw,
        RuleId::Trfc,
        RuleId::TrefiMax,
        RuleId::ActOpenBank,
        RuleId::CasClosedBank,
        RuleId::CasRowMismatch,
        RuleId::RefOpenBank,
    ];

    /// The stable ID string (violation reports, CI summaries, README
    /// table; never change an existing string — downstream tooling keys
    /// on them).
    pub fn id(self) -> &'static str {
        match self {
            RuleId::Trcd => "tRCD",
            RuleId::Trp => "tRP",
            RuleId::Tras => "tRAS",
            RuleId::Trc => "tRC",
            RuleId::TccdS => "tCCD_S",
            RuleId::TccdL => "tCCD_L",
            RuleId::TrrdS => "tRRD_S",
            RuleId::TrrdL => "tRRD_L",
            RuleId::Tfaw => "tFAW",
            RuleId::Twr => "tWR",
            RuleId::Trtp => "tRTP",
            RuleId::TwtrS => "tWTR_S",
            RuleId::TwtrL => "tWTR_L",
            RuleId::Trtw => "tRTW",
            RuleId::Trfc => "tRFC",
            RuleId::TrefiMax => "tREFI_MAX",
            RuleId::ActOpenBank => "ACT_OPEN_BANK",
            RuleId::CasClosedBank => "CAS_CLOSED_BANK",
            RuleId::CasRowMismatch => "CAS_ROW_MISMATCH",
            RuleId::RefOpenBank => "REF_OPEN_BANK",
        }
    }

    /// Index into [`Self::ALL`] (per-rule counters in the auditor).
    pub fn index(self) -> usize {
        Self::ALL.iter().position(|r| *r == self).expect("RuleId::ALL covers every variant")
    }
}

impl std::fmt::Display for RuleId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.id())
    }
}

/// One rule of the rulebook: its ID, the derived cycle bound (`None` for
/// purely structural rules), and a one-line description.
#[derive(Debug, Clone, Copy)]
pub struct Rule {
    /// Stable rule identifier.
    pub id: RuleId,
    /// Minimum spacing in DRAM cycles (maximum, for `tREFI_MAX`); `None`
    /// for structural rules with no timing component.
    pub bound_ck: Option<Cycle>,
    /// What the rule constrains, for reports and docs.
    pub desc: &'static str,
}

/// The complete rule set for one speed bin, every bound pre-derived from
/// the JEDEC timing table (and nothing else).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Rulebook {
    /// ACT -> CAS, same bank.
    pub trcd: Cycle,
    /// PRE -> ACT, same bank.
    pub trp: Cycle,
    /// ACT -> PRE, same bank.
    pub tras: Cycle,
    /// ACT -> ACT, same bank.
    pub trc: Cycle,
    /// CAS -> CAS, cross-group.
    pub tccd_s: Cycle,
    /// CAS -> CAS, same group.
    pub tccd_l: Cycle,
    /// ACT -> ACT, cross-group.
    pub trrd_s: Cycle,
    /// ACT -> ACT, same group.
    pub trrd_l: Cycle,
    /// Rolling four-activate window.
    pub tfaw: Cycle,
    /// WR CAS -> PRE, same bank (CWL + BL/2 + tWR).
    pub wr_to_pre: Cycle,
    /// RD CAS -> PRE, same bank (tRTP).
    pub rd_to_pre: Cycle,
    /// WR CAS -> RD CAS, cross-group (CWL + BL/2 + tWTR_S).
    pub wr_to_rd_s: Cycle,
    /// WR CAS -> RD CAS, same group (CWL + BL/2 + tWTR_L).
    pub wr_to_rd_l: Cycle,
    /// RD CAS -> WR CAS (CL + BL/2 + 2 - CWL).
    pub rd_to_wr: Cycle,
    /// REF -> any command.
    pub trfc: Cycle,
    /// Maximum REF -> REF gap (9 x tREFI: up to 8 postponed refreshes).
    pub trefi_max: Cycle,
}

impl Rulebook {
    /// Derive every bound from a JEDEC timing table. This constructor is
    /// the *only* place the analyzer touches `ddr4::` — the auditor
    /// replays streams against these numbers alone.
    pub fn from_timing(t: &TimingParams) -> Self {
        Self {
            trcd: t.trcd as Cycle,
            trp: t.trp as Cycle,
            tras: t.tras as Cycle,
            trc: t.trc as Cycle,
            tccd_s: t.tccd_s as Cycle,
            tccd_l: t.tccd_l as Cycle,
            trrd_s: t.trrd_s as Cycle,
            trrd_l: t.trrd_l as Cycle,
            tfaw: t.tfaw as Cycle,
            wr_to_pre: t.wr_to_pre() as Cycle,
            rd_to_pre: t.rd_to_pre() as Cycle,
            wr_to_rd_s: t.wr_to_rd(false) as Cycle,
            wr_to_rd_l: t.wr_to_rd(true) as Cycle,
            rd_to_wr: t.rd_to_wr() as Cycle,
            trfc: t.trfc as Cycle,
            trefi_max: 9 * t.trefi as Cycle,
        }
    }

    /// The data-driven rule table, in stable [`RuleId::ALL`] order.
    pub fn rules(&self) -> Vec<Rule> {
        RuleId::ALL
            .iter()
            .map(|&id| Rule { id, bound_ck: self.bound_ck(id), desc: Self::describe(id) })
            .collect()
    }

    /// The derived cycle bound of `id` (`None` for structural rules).
    pub fn bound_ck(&self, id: RuleId) -> Option<Cycle> {
        match id {
            RuleId::Trcd => Some(self.trcd),
            RuleId::Trp => Some(self.trp),
            RuleId::Tras => Some(self.tras),
            RuleId::Trc => Some(self.trc),
            RuleId::TccdS => Some(self.tccd_s),
            RuleId::TccdL => Some(self.tccd_l),
            RuleId::TrrdS => Some(self.trrd_s),
            RuleId::TrrdL => Some(self.trrd_l),
            RuleId::Tfaw => Some(self.tfaw),
            RuleId::Twr => Some(self.wr_to_pre),
            RuleId::Trtp => Some(self.rd_to_pre),
            RuleId::TwtrS => Some(self.wr_to_rd_s),
            RuleId::TwtrL => Some(self.wr_to_rd_l),
            RuleId::Trtw => Some(self.rd_to_wr),
            RuleId::Trfc => Some(self.trfc),
            RuleId::TrefiMax => Some(self.trefi_max),
            RuleId::ActOpenBank
            | RuleId::CasClosedBank
            | RuleId::CasRowMismatch
            | RuleId::RefOpenBank => None,
        }
    }

    fn describe(id: RuleId) -> &'static str {
        match id {
            RuleId::Trcd => "ACT to RD/WR CAS, same bank",
            RuleId::Trp => "PRE (or auto-precharge completion) to ACT, same bank",
            RuleId::Tras => "ACT to PRE, same bank",
            RuleId::Trc => "ACT to ACT, same bank",
            RuleId::TccdS => "CAS to CAS, different bank group",
            RuleId::TccdL => "CAS to CAS, same bank group",
            RuleId::TrrdS => "ACT to ACT, different bank group",
            RuleId::TrrdL => "ACT to ACT, same bank group",
            RuleId::Tfaw => "at most 4 ACTs per rolling tFAW window",
            RuleId::Twr => "WR CAS to PRE, same bank (CWL + BL/2 + tWR)",
            RuleId::Trtp => "RD CAS to PRE, same bank",
            RuleId::TwtrS => "WR CAS to RD CAS, different bank group (CWL + BL/2 + tWTR_S)",
            RuleId::TwtrL => "WR CAS to RD CAS, same bank group (CWL + BL/2 + tWTR_L)",
            RuleId::Trtw => "RD CAS to WR CAS bus turnaround (CL + BL/2 + 2 - CWL)",
            RuleId::Trfc => "REF to any command",
            RuleId::TrefiMax => "REF to REF at most 9 x tREFI (8 postponed refreshes)",
            RuleId::ActOpenBank => "ACT to a bank with an open row",
            RuleId::CasClosedBank => "RD/WR to a precharged bank",
            RuleId::CasRowMismatch => "RD/WR row differs from the open row",
            RuleId::RefOpenBank => "REF while a bank is open",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SpeedBin;

    #[test]
    fn every_rule_has_a_unique_stable_id() {
        let mut seen = std::collections::HashSet::new();
        for id in RuleId::ALL {
            assert!(seen.insert(id.id()), "duplicate rule id {}", id.id());
            assert_eq!(RuleId::ALL[id.index()], id);
        }
        assert_eq!(seen.len(), RuleId::ALL.len());
    }

    #[test]
    fn bounds_derive_from_the_timing_table() {
        let t = TimingParams::for_bin(SpeedBin::Ddr4_1600);
        let rb = Rulebook::from_timing(&t);
        assert_eq!(rb.trcd, 11);
        assert_eq!(rb.trc, rb.tras + rb.trp);
        assert_eq!(rb.wr_to_pre, (t.cwl + t.burst_cycles + t.twr) as Cycle);
        assert_eq!(rb.wr_to_rd_l, t.wr_to_rd(true) as Cycle);
        assert_eq!(rb.trefi_max, 9 * t.trefi as Cycle);
    }

    #[test]
    fn rule_table_is_complete_and_ordered() {
        let rb = Rulebook::from_timing(&TimingParams::for_bin(SpeedBin::Ddr4_2400));
        let rules = rb.rules();
        assert_eq!(rules.len(), RuleId::ALL.len());
        for (rule, id) in rules.iter().zip(RuleId::ALL) {
            assert_eq!(rule.id, id);
            assert!(!rule.desc.is_empty());
            // timing rules carry their derived bound; structural rules none
            assert_eq!(rule.bound_ck.is_none(), matches!(
                id,
                RuleId::ActOpenBank
                    | RuleId::CasClosedBank
                    | RuleId::CasRowMismatch
                    | RuleId::RefOpenBank
            ));
        }
    }

    #[test]
    fn bounds_scale_with_speed_bin() {
        let a = Rulebook::from_timing(&TimingParams::for_bin(SpeedBin::Ddr4_1600));
        let b = Rulebook::from_timing(&TimingParams::for_bin(SpeedBin::Ddr4_2400));
        assert!(b.trfc > a.trfc);
        assert!(b.trefi_max > a.trefi_max);
        assert!(b.trcd > a.trcd);
    }
}
