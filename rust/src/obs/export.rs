//! Export formats for telemetry: the command-trace CSV and the
//! `ddr4bench.timeline.v1` JSON artifact, plus the bandwidth conversion
//! shared by the report table and the enriched `STREAM` heartbeats.

use super::cmdtrace::CmdTrace;
use super::sampler::{TelemetrySeries, TelemetryWindow};

/// Schema tag of the per-job timeline artifact.
pub const TIMELINE_SCHEMA: &str = "ddr4bench.timeline.v1";

/// Header line of the command-trace CSV.
pub const TRACE_CSV_HEADER: &str = "cycle,channel,cmd,bank_group,bank,row";

/// Render a whole run's command rings as one CSV with `#` metadata
/// comments carrying what the offline auditor (`ddr4bench audit`) needs
/// to reconstruct context: the speed bin the bounds derive from, and
/// each channel's event/drop counts so a ring that overflowed is
/// audited as a truncated stream instead of being certified clean.
pub fn trace_csv_annotated(speed: &str, channels: &[(usize, &CmdTrace)]) -> String {
    let mut out = String::new();
    out.push_str("# ddr4bench cmd-trace\n");
    out.push_str(&format!("# speed={speed}\n"));
    for (ch, trace) in channels {
        out.push_str(&format!(
            "# channel={ch} events={} dropped={}\n",
            trace.len(),
            trace.dropped()
        ));
    }
    out.push_str(TRACE_CSV_HEADER);
    out.push('\n');
    for (ch, trace) in channels {
        for ev in trace.events() {
            out.push_str(&format!(
                "{},{},{},{},{},{}\n",
                ev.cycle,
                ch,
                ev.cmd.name(),
                ev.bank_group,
                ev.bank,
                ev.row
            ));
        }
    }
    out
}

/// Render a channel's command ring as compact CSV (header + one line
/// per event, oldest first). The channel id is stamped at export time —
/// the ring itself is per-controller and doesn't know its channel.
pub fn trace_csv(channel: usize, trace: &CmdTrace) -> String {
    let mut out = String::with_capacity(32 + trace.len() * 24);
    out.push_str(TRACE_CSV_HEADER);
    out.push('\n');
    for ev in trace.events() {
        out.push_str(&format!(
            "{},{},{},{},{},{}\n",
            ev.cycle,
            channel,
            ev.cmd.name(),
            ev.bank_group,
            ev.bank,
            ev.row
        ));
    }
    out
}

/// Bandwidth of one window in GB/s: bytes over the window's span in
/// nanoseconds (`axi_ns` = AXI clock period). Degenerate zero-width
/// windows report 0.0.
pub fn window_bw_gbs(w: &TelemetryWindow, axi_ns: f64) -> f64 {
    let span = w.end.saturating_sub(w.start);
    if span == 0 {
        return 0.0;
    }
    (w.rd_bytes + w.wr_bytes) as f64 / (span as f64 * axi_ns)
}

/// Render per-channel telemetry series as a `ddr4bench.timeline.v1`
/// JSON document. Everything but the derived `bw_gbs` is an integer
/// copied straight from the series, and `bw_gbs` is computed from those
/// integers — the document is byte-identical across engines and runs.
pub fn timeline_json(label: &str, axi_ns: f64, channels: &[(usize, &TelemetrySeries)]) -> String {
    let window = channels.first().map(|(_, s)| s.window).unwrap_or(0);
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"schema\": \"{TIMELINE_SCHEMA}\",\n"));
    out.push_str(&format!("  \"label\": \"{}\",\n", label.replace('"', "'")));
    out.push_str(&format!("  \"axi_ns\": {axi_ns},\n"));
    out.push_str(&format!("  \"window_axi_cycles\": {window},\n"));
    out.push_str("  \"channels\": [\n");
    for (i, (ch, series)) in channels.iter().enumerate() {
        out.push_str("    {\n");
        out.push_str(&format!("      \"channel\": {ch},\n"));
        out.push_str(&format!("      \"window_axi_cycles\": {},\n", series.window));
        out.push_str(&format!("      \"dropped\": {},\n", series.dropped));
        out.push_str("      \"windows\": [\n");
        for (j, w) in series.windows.iter().enumerate() {
            out.push_str(&format!(
                "        {{\"start\": {}, \"end\": {}, \"rd_bytes\": {}, \"wr_bytes\": {}, \
                 \"queue_depth\": {}, \"open_banks\": {}, \"acts\": {}, \"pres\": {}, \
                 \"refresh_stall\": {}, \"rd_p50\": {}, \"rd_p99\": {}, \"wr_p50\": {}, \
                 \"wr_p99\": {}, \"bw_gbs\": {:.6}}}{}\n",
                w.start,
                w.end,
                w.rd_bytes,
                w.wr_bytes,
                w.queue_depth,
                w.open_banks,
                w.acts,
                w.pres,
                w.refresh_stall,
                w.rd_p50,
                w.rd_p99,
                w.wr_p50,
                w.wr_p99,
                window_bw_gbs(w, axi_ns),
                if j + 1 == series.windows.len() { "" } else { "," }
            ));
        }
        out.push_str("      ]\n");
        out.push_str(&format!("    }}{}\n", if i + 1 == channels.len() { "" } else { "," }));
    }
    out.push_str("  ]\n");
    out.push_str("}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::cmdtrace::{TraceCmd, TraceEvent};

    fn window(start: u64, end: u64, rd: u64, wr: u64) -> TelemetryWindow {
        TelemetryWindow {
            start,
            end,
            rd_bytes: rd,
            wr_bytes: wr,
            queue_depth: 2,
            open_banks: 1,
            acts: 3,
            pres: 2,
            refresh_stall: 0,
            rd_p50: 8,
            rd_p99: 16,
            wr_p50: 0,
            wr_p99: 0,
        }
    }

    #[test]
    fn trace_csv_shape() {
        let mut t = CmdTrace::new(4);
        t.record(TraceEvent { cycle: 10, cmd: TraceCmd::Act, bank_group: 1, bank: 5, row: 42 });
        t.record(TraceEvent { cycle: 14, cmd: TraceCmd::Rd, bank_group: 1, bank: 5, row: 42 });
        let csv = trace_csv(2, &t);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], TRACE_CSV_HEADER);
        assert_eq!(lines[1], "10,2,ACT,1,5,42");
        assert_eq!(lines[2], "14,2,RD,1,5,42");
    }

    #[test]
    fn annotated_csv_carries_speed_and_drop_metadata() {
        let mut a = CmdTrace::new(1);
        a.record(TraceEvent { cycle: 10, cmd: TraceCmd::Act, bank_group: 0, bank: 1, row: 3 });
        a.record(TraceEvent { cycle: 14, cmd: TraceCmd::Rda, bank_group: 0, bank: 1, row: 3 });
        let b = CmdTrace::new(4);
        let csv = trace_csv_annotated("DDR4-1600", &[(0, &a), (1, &b)]);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "# ddr4bench cmd-trace");
        assert_eq!(lines[1], "# speed=DDR4-1600");
        assert_eq!(lines[2], "# channel=0 events=1 dropped=1");
        assert_eq!(lines[3], "# channel=1 events=0 dropped=0");
        assert_eq!(lines[4], TRACE_CSV_HEADER);
        assert_eq!(lines[5], "14,0,RDA,0,1,3");
        assert_eq!(lines.len(), 6);
    }

    #[test]
    fn bandwidth_formula() {
        // 64 bytes over 100 cycles of 5 ns = 0.128 GB/s
        let w = window(0, 100, 32, 32);
        assert!((window_bw_gbs(&w, 5.0) - 0.128).abs() < 1e-12);
        assert_eq!(window_bw_gbs(&window(100, 100, 1, 1), 5.0), 0.0);
    }

    #[test]
    fn timeline_json_is_well_formed_and_deterministic() {
        let series = TelemetrySeries {
            window: 100,
            windows: vec![window(0, 100, 64, 0), window(100, 200, 32, 32)],
            dropped: 1,
        };
        let a = timeline_json("seq", 5.0, &[(0, &series)]);
        let b = timeline_json("seq", 5.0, &[(0, &series)]);
        assert_eq!(a, b, "byte-identical render");
        assert!(a.contains(&format!("\"schema\": \"{TIMELINE_SCHEMA}\"")));
        assert!(a.contains("\"window_axi_cycles\": 100"));
        assert!(a.contains("\"dropped\": 1"));
        assert!(a.contains("\"start\": 0, \"end\": 100"));
        assert!(a.contains("\"bw_gbs\": 0.128000"));
        // crude but effective balance check on the hand-rolled render
        assert_eq!(a.matches('{').count(), a.matches('}').count());
        assert_eq!(a.matches('[').count(), a.matches(']').count());
    }
}
