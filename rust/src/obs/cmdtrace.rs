//! Bounded DRAM command tracing.
//!
//! A [`CmdTrace`] is a fixed-capacity ring of [`TraceEvent`]s recorded
//! at the memory controller's command-issue points when tracing is
//! enabled at runtime (`ddr4bench run --cmd-trace`, host `TRACEDUMP`).
//! The ring allocates once up front and evicts oldest-first when full
//! (evictions counted), so steady-state recording never allocates and a
//! long trace-enabled run holds the *tail* of the command stream — the
//! part a post-mortem wants.

use std::collections::VecDeque;

/// Default ring capacity, in events.
pub const DEFAULT_TRACE_EVENTS: usize = 65536;

/// The DDR4 command classes the controller issues.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceCmd {
    /// Activate (open) a row.
    Act,
    /// Precharge (close) one bank.
    Pre,
    /// Precharge all banks.
    PreAll,
    /// Column read.
    Rd,
    /// Column read with auto-precharge.
    Rda,
    /// Column write.
    Wr,
    /// Column write with auto-precharge.
    Wra,
    /// Refresh.
    Ref,
}

impl TraceCmd {
    /// Compact wire/CSV name.
    pub fn name(&self) -> &'static str {
        match self {
            TraceCmd::Act => "ACT",
            TraceCmd::Pre => "PRE",
            TraceCmd::PreAll => "PREA",
            TraceCmd::Rd => "RD",
            TraceCmd::Rda => "RDA",
            TraceCmd::Wr => "WR",
            TraceCmd::Wra => "WRA",
            TraceCmd::Ref => "REF",
        }
    }

    /// Parse a compact name back into a command (trace-CSV ingestion).
    pub fn parse(name: &str) -> Option<TraceCmd> {
        match name {
            "ACT" => Some(TraceCmd::Act),
            "PRE" => Some(TraceCmd::Pre),
            "PREA" => Some(TraceCmd::PreAll),
            "RD" => Some(TraceCmd::Rd),
            "RDA" => Some(TraceCmd::Rda),
            "WR" => Some(TraceCmd::Wr),
            "WRA" => Some(TraceCmd::Wra),
            "REF" => Some(TraceCmd::Ref),
            _ => None,
        }
    }
}

/// One issued DRAM command. `row` is the open/target row where the
/// command addresses one (ACT's target, RD/WR/PRE's open row) and 0 for
/// the all-bank commands (PREA/REF), whose `bank_group`/`bank` are 0
/// too.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// DRAM clock cycle the command issued at.
    pub cycle: u64,
    /// Command class.
    pub cmd: TraceCmd,
    /// Bank group of the addressed bank.
    pub bank_group: u32,
    /// Flat bank index within the device.
    pub bank: u32,
    /// Row (see type docs for per-command meaning).
    pub row: u32,
}

/// The bounded command ring.
#[derive(Debug, Clone)]
pub struct CmdTrace {
    events: VecDeque<TraceEvent>,
    cap: usize,
    dropped: u64,
}

impl CmdTrace {
    /// Ring with capacity `cap` events (clamped to >= 1); allocates the
    /// full capacity up front.
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        Self { events: VecDeque::with_capacity(cap), cap, dropped: 0 }
    }

    /// Record one event, evicting the oldest when full.
    pub fn record(&mut self, ev: TraceEvent) {
        if self.events.len() == self.cap {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(ev);
    }

    /// Events currently held, oldest first.
    pub fn events(&self) -> impl Iterator<Item = &TraceEvent> {
        self.events.iter()
    }

    /// Number of events currently held.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True when nothing has been recorded (or everything evicted).
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events evicted because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Ring capacity.
    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(cycle: u64) -> TraceEvent {
        TraceEvent { cycle, cmd: TraceCmd::Act, bank_group: 0, bank: 0, row: 7 }
    }

    #[test]
    fn ring_keeps_the_tail() {
        let mut t = CmdTrace::new(3);
        for c in 0..5 {
            t.record(ev(c));
        }
        assert_eq!(t.len(), 3);
        assert_eq!(t.dropped(), 2);
        let cycles: Vec<u64> = t.events().map(|e| e.cycle).collect();
        assert_eq!(cycles, vec![2, 3, 4], "oldest evicted first");
    }

    #[test]
    fn capacity_never_exceeded_and_no_realloc() {
        let mut t = CmdTrace::new(8);
        let cap_before = t.events.capacity();
        for c in 0..1000 {
            t.record(ev(c));
        }
        assert_eq!(t.len(), 8);
        assert_eq!(t.events.capacity(), cap_before, "steady state must not reallocate");
    }

    #[test]
    fn cmd_names_are_compact_and_roundtrip() {
        let all = [
            TraceCmd::Act,
            TraceCmd::Pre,
            TraceCmd::PreAll,
            TraceCmd::Rd,
            TraceCmd::Rda,
            TraceCmd::Wr,
            TraceCmd::Wra,
            TraceCmd::Ref,
        ];
        let names: Vec<&str> = all.iter().map(|c| c.name()).collect();
        assert_eq!(names, vec!["ACT", "PRE", "PREA", "RD", "RDA", "WR", "WRA", "REF"]);
        for c in all {
            assert_eq!(TraceCmd::parse(c.name()), Some(c));
        }
        assert_eq!(TraceCmd::parse("NOP"), None);
    }
}
