//! Windowed time-series telemetry sampling for the batch loop.
//!
//! A [`TelemetrySampler`] divides a batch's AXI-cycle timeline into
//! fixed-width windows and, at each boundary, closes a
//! [`TelemetryWindow`] holding the *delta* of the monotone counters
//! (bytes moved, activates, precharges, refresh-stall cycles, latency
//! histogram) since the previous boundary plus point-in-time snapshots
//! (in-flight queue depth, open banks). Windows land in a bounded ring
//! (oldest evicted first, eviction counted in `dropped`), so a
//! telemetry-enabled run can never grow without bound.
//!
//! ## Engine-identity contract
//!
//! The sampler is driven from the top of the canonical batch loop,
//! *before* any state mutation of that iteration, with `now` = AXI
//! cycles since batch start. The event engine only leaps across cycles
//! whose loop body is provably a no-op, so when a leap lands past one
//! or more window boundaries the machine state is exactly what it was
//! at every skipped boundary: [`TelemetrySampler::observe`] closes all
//! overdue windows against the same probe — the first takes the whole
//! delta since its baseline, the rest record zero deltas — which is
//! precisely the series the cycle engine produces by crossing each
//! boundary one at a time. The differential tests pin this.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use crate::stats::LatencyHistogram;

/// Default bounded-ring capacity, in windows.
pub const DEFAULT_RING_WINDOWS: usize = 4096;

/// A point-in-time reading of everything the sampler observes. Built by
/// the batch loop only when a window boundary has actually been crossed
/// (the [`TelemetrySampler::due`] fast path gates it), so the histogram
/// clones stay off the hot path.
#[derive(Debug, Clone)]
pub struct Probe {
    /// Read payload bytes completed so far (monotone).
    pub rd_bytes: u64,
    /// Write payload bytes completed so far (monotone).
    pub wr_bytes: u64,
    /// Transactions currently in flight (point snapshot).
    pub in_flight: u64,
    /// Banks currently open across the device (point snapshot).
    pub open_banks: u32,
    /// ACT commands issued so far (monotone).
    pub acts: u64,
    /// PRE/PREA commands issued so far (monotone).
    pub pres: u64,
    /// DRAM cycles stalled by refresh so far (monotone).
    pub refresh_stall: u64,
    /// Cumulative read-latency histogram (AXI cycles).
    pub rd_latency: LatencyHistogram,
    /// Cumulative write-latency histogram (AXI cycles).
    pub wr_latency: LatencyHistogram,
}

/// One closed sample window. Every field is an integer so series
/// compare bit-exactly across engines and runs; bandwidth in GB/s is
/// derived at export time ([`crate::obs::export::window_bw_gbs`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TelemetryWindow {
    /// Window start, AXI cycles since batch start (inclusive).
    pub start: u64,
    /// Window end, AXI cycles since batch start (exclusive).
    pub end: u64,
    /// Read bytes completed within the window.
    pub rd_bytes: u64,
    /// Write bytes completed within the window.
    pub wr_bytes: u64,
    /// In-flight transactions at window close.
    pub queue_depth: u64,
    /// Open banks at window close.
    pub open_banks: u32,
    /// ACT commands issued within the window (bank-open churn).
    pub acts: u64,
    /// PRE/PREA commands issued within the window (bank-close churn).
    pub pres: u64,
    /// DRAM cycles stalled by refresh within the window.
    pub refresh_stall: u64,
    /// p50 of read latencies recorded within the window (AXI cycles,
    /// log2-bucket bound; 0 when no reads completed in the window).
    pub rd_p50: u64,
    /// p99 of read latencies recorded within the window (AXI cycles).
    pub rd_p99: u64,
    /// p50 of write latencies recorded within the window (AXI cycles).
    pub wr_p50: u64,
    /// p99 of write latencies recorded within the window (AXI cycles).
    pub wr_p99: u64,
}

/// A batch's complete telemetry series: the ring contents at batch end.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TelemetrySeries {
    /// Window width in AXI cycles.
    pub window: u64,
    /// Closed windows, oldest first (ring-bounded).
    pub windows: Vec<TelemetryWindow>,
    /// Windows evicted from the ring because it was full.
    pub dropped: u64,
}

/// The live view a running batch publishes for `METRICS` / enriched
/// `STREAM` heartbeats: ring totals plus the most recently closed
/// window.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TelemetrySnapshot {
    /// Window width in AXI cycles.
    pub window: u64,
    /// Windows closed so far (including any evicted from the ring).
    pub closed: u64,
    /// Windows evicted from the ring.
    pub dropped: u64,
    /// Whether the batch has finished.
    pub done: bool,
    /// Most recently closed window, if any.
    pub last: Option<TelemetryWindow>,
}

/// Shared handle a pooled batch publishes its live snapshot through.
pub type SharedTelemetry = Arc<Mutex<TelemetrySnapshot>>;

/// Reconstruct the end-of-run snapshot from a finished series (what
/// `METRICS` answers when no live handle exists — the inline execution
/// path — kept identical to what the live publisher leaves behind).
pub fn snapshot_from_series(series: &TelemetrySeries) -> TelemetrySnapshot {
    TelemetrySnapshot {
        window: series.window,
        closed: series.windows.len() as u64 + series.dropped,
        dropped: series.dropped,
        done: true,
        last: series.windows.last().cloned(),
    }
}

/// The windowed sampler. Owned by the batch executive, driven by the
/// canonical loop: [`begin`](Self::begin) once, [`due`](Self::due) /
/// [`observe`](Self::observe) at loop top, [`finalize`](Self::finalize)
/// after the loop, then [`take_series`](Self::take_series).
#[derive(Debug)]
pub struct TelemetrySampler {
    window: u64,
    cap: usize,
    win_start: u64,
    next_end: u64,
    baseline: Option<Probe>,
    windows: VecDeque<TelemetryWindow>,
    closed: u64,
    dropped: u64,
    publisher: Option<SharedTelemetry>,
}

impl TelemetrySampler {
    /// Sampler with `window` AXI cycles per sample and the default ring
    /// capacity. `window` must be >= 1 (validated upstream by config).
    pub fn new(window: u64) -> Self {
        Self::with_capacity(window, DEFAULT_RING_WINDOWS)
    }

    /// Sampler with an explicit ring capacity (clamped to >= 1).
    pub fn with_capacity(window: u64, cap: usize) -> Self {
        let cap = cap.max(1);
        Self {
            window: window.max(1),
            cap,
            win_start: 0,
            next_end: window.max(1),
            baseline: None,
            windows: VecDeque::with_capacity(cap),
            closed: 0,
            dropped: 0,
            publisher: None,
        }
    }

    /// Attach a live publisher: every boundary crossing (and the final
    /// close) updates the shared snapshot under its lock.
    pub fn with_publisher(mut self, shared: SharedTelemetry) -> Self {
        self.publisher = Some(shared);
        self
    }

    /// Window width in AXI cycles.
    pub fn window(&self) -> u64 {
        self.window
    }

    /// Capture the time-zero baseline. Device and controller counters
    /// persist across batches, so the first window's deltas must be
    /// measured against the values at batch start, not zero.
    pub fn begin(&mut self, probe: &Probe) {
        self.baseline = Some(probe.clone());
        self.win_start = 0;
        self.next_end = self.window;
        self.publish(false);
    }

    /// Cheap hot-path gate: has at least one window boundary passed?
    #[inline]
    pub fn due(&self, now: u64) -> bool {
        now >= self.next_end
    }

    /// Close every window whose end is `<= now` against `probe`. Called
    /// from the top of the batch loop once [`due`](Self::due) fires; the
    /// event engine may close several windows at once here (see the
    /// module docs for why that yields the cycle engine's exact series).
    pub fn observe(&mut self, now: u64, probe: &Probe) {
        let mut any = false;
        while self.next_end <= now {
            let end = self.next_end;
            self.close_window(end, probe);
            self.win_start = end;
            self.next_end = end + self.window;
            any = true;
        }
        if any {
            self.publish(false);
        }
    }

    /// Close all remaining full windows plus the final partial window
    /// `[win_start, now)` and publish the done snapshot. `now` is the
    /// batch's final AXI cycle (`total_cycles`) — identical on both
    /// engines, so so is the final partial window.
    pub fn finalize(&mut self, now: u64, probe: &Probe) {
        while self.next_end <= now {
            let end = self.next_end;
            self.close_window(end, probe);
            self.win_start = end;
            self.next_end = end + self.window;
        }
        if now > self.win_start {
            self.close_window(now, probe);
            self.win_start = now;
        }
        self.publish(true);
    }

    /// Drain the finished series out of the sampler.
    pub fn take_series(&mut self) -> TelemetrySeries {
        TelemetrySeries {
            window: self.window,
            windows: std::mem::take(&mut self.windows).into(),
            dropped: self.dropped,
        }
    }

    fn close_window(&mut self, end: u64, probe: &Probe) {
        let base = self.baseline.as_ref().expect("TelemetrySampler::begin not called");
        let w = TelemetryWindow {
            start: self.win_start,
            end,
            rd_bytes: probe.rd_bytes - base.rd_bytes,
            wr_bytes: probe.wr_bytes - base.wr_bytes,
            queue_depth: probe.in_flight,
            open_banks: probe.open_banks,
            acts: probe.acts - base.acts,
            pres: probe.pres - base.pres,
            refresh_stall: probe.refresh_stall - base.refresh_stall,
            rd_p50: probe.rd_latency.percentile_delta(&base.rd_latency, 50.0),
            rd_p99: probe.rd_latency.percentile_delta(&base.rd_latency, 99.0),
            wr_p50: probe.wr_latency.percentile_delta(&base.wr_latency, 50.0),
            wr_p99: probe.wr_latency.percentile_delta(&base.wr_latency, 99.0),
        };
        if self.windows.len() == self.cap {
            self.windows.pop_front();
            self.dropped += 1;
        }
        self.windows.push_back(w);
        self.closed += 1;
        self.baseline = Some(probe.clone());
    }

    fn publish(&self, done: bool) {
        if let Some(shared) = &self.publisher {
            if let Ok(mut snap) = shared.lock() {
                snap.window = self.window;
                snap.closed = self.closed;
                snap.dropped = self.dropped;
                snap.done = done;
                snap.last = self.windows.back().cloned();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn probe(rd_bytes: u64, in_flight: u64) -> Probe {
        Probe {
            rd_bytes,
            wr_bytes: 0,
            in_flight,
            open_banks: 1,
            acts: 0,
            pres: 0,
            refresh_stall: 0,
            rd_latency: LatencyHistogram::new(),
            wr_latency: LatencyHistogram::new(),
        }
    }

    #[test]
    fn windows_record_deltas_and_point_snapshots() {
        let mut s = TelemetrySampler::new(100);
        s.begin(&probe(1000, 0)); // nonzero baseline: counters persist
        assert!(!s.due(99));
        assert!(s.due(100));
        s.observe(100, &probe(1064, 3));
        s.finalize(250, &probe(1096, 1));
        let series = s.take_series();
        assert_eq!(series.window, 100);
        assert_eq!(series.windows.len(), 3);
        let w0 = &series.windows[0];
        assert_eq!((w0.start, w0.end, w0.rd_bytes, w0.queue_depth), (0, 100, 64, 3));
        let w1 = &series.windows[1];
        assert_eq!((w1.start, w1.end, w1.rd_bytes), (100, 200, 32));
        // the trailing partial window is kept
        let w2 = &series.windows[2];
        assert_eq!((w2.start, w2.end, w2.rd_bytes), (200, 250, 0));
        assert_eq!(series.dropped, 0);
    }

    #[test]
    fn leap_landing_splits_overdue_windows_like_single_steps() {
        // the event-engine case: nothing happened between cycle 10 and a
        // leap landing at 350 — three windows close at once, the first
        // takes the whole delta, the rest are zero
        let mut leap = TelemetrySampler::new(100);
        leap.begin(&probe(0, 0));
        leap.observe(350, &probe(64, 2));
        let mut step = TelemetrySampler::new(100);
        step.begin(&probe(0, 0));
        step.observe(100, &probe(64, 2)); // cycle engine crossed here with
        step.observe(200, &probe(64, 2)); // ...state already frozen
        step.observe(300, &probe(64, 2));
        let (a, b) = (leap.take_series(), step.take_series());
        assert_eq!(a, b);
        assert_eq!(a.windows[0].rd_bytes, 64);
        assert_eq!(a.windows[1].rd_bytes, 0);
        assert_eq!(a.windows[2].rd_bytes, 0);
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let mut s = TelemetrySampler::with_capacity(10, 3);
        s.begin(&probe(0, 0));
        s.observe(55, &probe(100, 0)); // closes windows ending 10..=50
        let series = s.take_series();
        assert_eq!(series.windows.len(), 3);
        assert_eq!(series.dropped, 2);
        assert_eq!(series.windows.last().unwrap().end, 50);
    }

    #[test]
    fn percentiles_are_per_window_deltas() {
        let mut s = TelemetrySampler::new(100);
        let mut p0 = probe(0, 0);
        for _ in 0..100 {
            p0.rd_latency.record(8);
        }
        s.begin(&p0);
        // second window adds only slow samples: its p50 must reflect
        // them, not the cumulative (fast-dominated) distribution
        let mut p1 = p0.clone();
        for _ in 0..10 {
            p1.rd_latency.record(1000);
        }
        s.observe(100, &p1);
        let series = s.take_series();
        assert!(series.windows[0].rd_p50 >= 1000 || series.windows[0].rd_p50 == 1024);
    }

    #[test]
    fn publisher_sees_live_and_done_snapshots() {
        let shared: SharedTelemetry = Arc::new(Mutex::new(TelemetrySnapshot::default()));
        let mut s = TelemetrySampler::new(100).with_publisher(Arc::clone(&shared));
        s.begin(&probe(0, 0));
        assert!(!shared.lock().unwrap().done);
        s.observe(120, &probe(64, 1));
        {
            let snap = shared.lock().unwrap();
            assert_eq!(snap.closed, 1);
            assert_eq!(snap.last.as_ref().unwrap().rd_bytes, 64);
        }
        s.finalize(150, &probe(64, 0));
        let snap = shared.lock().unwrap();
        assert!(snap.done);
        assert_eq!(snap.closed, 2);
        // and the inline reconstruction matches the live leftovers
        drop(snap);
        let series = s.take_series();
        assert_eq!(snapshot_from_series(&series), shared.lock().unwrap().clone());
    }
}
