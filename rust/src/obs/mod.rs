//! Runtime observability: windowed telemetry sampling, DRAM command
//! tracing, and export helpers.
//!
//! The paper's platform reads its hardware counters only after a batch
//! completes (§II-B/§II-C), so every reported figure is an end-of-run
//! aggregate. This module adds the in-flight view the ROADMAP's
//! fleet-facing north star needs, without perturbing the thing being
//! measured:
//!
//! - [`sampler`] — a [`TelemetrySampler`] hooked into the canonical
//!   batch loop (`platform::drive_batch`, both engines) that closes
//!   fixed-width windows of per-window read/write bytes, queue depth,
//!   bank open/close churn, refresh stalls and incremental latency
//!   percentiles into a bounded ring. Sampling is observation-only:
//!   telemetry on vs off leaves every counter bit-identical
//!   (property-tested), and the cycle and event engines emit identical
//!   series because windows are closed at loop-top before any state
//!   mutation and event-mode leaps only skip provably idle cycles.
//! - [`cmdtrace`] — a bounded, zero-alloc-in-steady-state ring of
//!   `(cycle, cmd, bank_group, bank, row)` events recorded at the
//!   memory controller's command-issue points behind a runtime enable
//!   (`ddr4bench run --cmd-trace`, host `TRACEDUMP`).
//! - [`export`] — the compact CSV trace format, the
//!   `ddr4bench.timeline.v1` JSON artifact the sweep executive writes
//!   next to each job, and the bandwidth conversion shared by the
//!   report table and the enriched `STREAM` heartbeats.
//!
//! Everything a window records is an integer (bytes, cycles, counts);
//! GB/s only appears at export/render time, so the series — and the
//! timeline artifacts derived from it — are byte-identical across
//! engines and run-to-run.

pub mod cmdtrace;
pub mod export;
pub mod sampler;

pub use cmdtrace::{CmdTrace, TraceCmd, TraceEvent, DEFAULT_TRACE_EVENTS};
pub use sampler::{
    snapshot_from_series, Probe, SharedTelemetry, TelemetrySampler, TelemetrySeries,
    TelemetrySnapshot, TelemetryWindow, DEFAULT_RING_WINDOWS,
};
