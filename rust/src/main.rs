//! `ddr4bench` — CLI launcher for the DDR4 benchmarking platform.
//!
//! ```text
//! ddr4bench info                         # design summary + XLA artifact status
//! ddr4bench run --speed 1600 --op R --addr seq --burst 32 --batch 4096
//! ddr4bench run --addr chase --wset 4m --sig BLK --burst 1   # pattern engine
//! ddr4bench run --addr bank --map xor_hash           # address-mapping engine
//! ddr4bench run --addr seq --sched closed            # scheduler/page-policy engine
//! ddr4bench run --addr chase --engine event          # event-driven time-skip core
//! ddr4bench run --addr seq --telemetry 4096          # windowed time-series report
//! ddr4bench run --addr bank --cmd-trace trace.csv    # DRAM command trace dump
//! ddr4bench run --addr bank --audit                  # live JEDEC protocol audit
//! ddr4bench audit trace.csv                          # offline audit of a trace CSV
//! ddr4bench sweep --speeds 1600,2400 --channels 1,2 \
//!                 --patterns strided,bank,chase --jobs 4 --out sweep-out
//! ddr4bench sweep --maps row_col_bank,xor_hash --knobs lookahead=1,lookahead=8
//! ddr4bench sweep --scheds fcfs,frfcfs,frfcfs-cap,closed --patterns seq,bank
//! ddr4bench sweep --mixes "0:SEQ,BURST=32+1:CHASE,WSET=1m"  # heterogeneous axis
//! ddr4bench sweep --telemetry 4096 --out sweep-out  # + {stem}_timeline.json artifacts
//! ddr4bench sweep --scheds fcfs,frfcfs --audit      # legality-gated sweep (CI gate)
//! ddr4bench run --ch 0:SEQ,BURST=32 --ch 1:CHASE,WSET=1m   # per-channel mix
//! ddr4bench interference --ch 0:SEQ --ch 1:CHASE --ch 2:BANK # solo-vs-co-run
//! ddr4bench compare a/BENCH_sweep.json b/BENCH_sweep.json   # cross-sweep deltas
//! ddr4bench table3 | table4 | fig2 | fig3 | scaling | analysis | modelcheck
//! ddr4bench serve --listen 127.0.0.1:5557 --workers 4 --max-sessions 8
//! ddr4bench serve --serial --addr-bind 127.0.0.1:5557  # legacy one-client loop
//! ```

use anyhow::{anyhow, Result};

use ddr4bench::cli::Cli;
use ddr4bench::config::{
    parse_channel_mix, parse_mix_file, parse_pattern_config, ChannelMix, DesignConfig,
    EngineKind, PatternConfig, SpeedBin,
};
use ddr4bench::hostctrl::{serve_tcp, BenchServer, HostController, ServerConfig};
use ddr4bench::platform::{interference_matrix, sweep, Platform};
use ddr4bench::report::{campaign, compare};
use ddr4bench::resource;
use ddr4bench::runtime::XlaRuntime;

fn cli() -> Cli {
    Cli::new("ddr4bench", "DDR4 memory benchmarking platform (simulated substrate)")
        .command("info", "print design + artifact status")
        .command("run", "run one traffic pattern and print its statistics")
        .command("table3", "reproduce Table III (FPGA resource utilization)")
        .command("table4", "reproduce Table IV (single-channel DDR4-1600 throughput)")
        .command("fig2", "reproduce Fig. 2 (DDR4-1600 vs DDR4-2400 sweeps)")
        .command("fig3", "reproduce Fig. 3 (mixed R/W breakdown)")
        .command("scaling", "channel-scaling experiment (1-3 channels)")
        .command("analysis", "paper-claim vs measured ratio table (SIII-C)")
        .command("modelcheck", "analytic model vs simulator cross-check")
        .command("serve", "concurrent multi-session bench server (host protocol over TCP)")
        .command("dse", "design-space exploration (analytic model; XLA-batched if present)")
        .command("trace", "replay a memory-access trace file (see trafficgen::trace)")
        .command("sweep", "parallel campaign sweep (speeds x channels x maps x knobs x patterns)")
        .command("interference", "solo-vs-co-run channel-interference matrix for a --ch mix")
        .command("compare", "cross-sweep delta report from two or more BENCH_sweep.json files")
        .command("audit", "offline JEDEC protocol audit of a `run --cmd-trace` CSV")
        .option("speed", "data rate: 1600|1866|2133|2400 (default 1600)")
        .option("channels", "memory channels 1-3 (default 1); comma list for sweep")
        .option("op", "R|W|M (default R)")
        .option("addr", "seq|rnd|stride|bank|chase|phased (default seq)")
        .option("seed", "pattern seed for rnd/bank/chase")
        .option("stride", "stride bytes for --addr stride (default 4096; suffixes k/m/g)")
        .option("wset", "working-set bytes for --addr chase (default 1m)")
        .option("phases", "phase list for --addr phased, e.g. SEQ@512,RND@512")
        .option("map", "address mapping: row_col_bank|row_bank_col|bank_row_col|xor_hash|RoBaBgCo")
        .option("sched", "scheduler/page policy: fcfs|frfcfs|frfcfs-cap[N]|closed|adaptive")
        .option("engine", "simulation engine: cycle|event (default cycle; event = time-skip core)")
        .option("telemetry", "telemetry window in AXI cycles: run prints a timeline table, sweep \
                              adds {stem}_timeline.json artifacts")
        .option("cmd-trace", "run: record the DRAM command trace and write it to this CSV path")
        .flag("audit", "run/sweep: arm the independent JEDEC protocol auditor (a violation \
                        fails the command); audit: n/a (always on)")
        .multi("ch", "per-channel workload N:TOKENS,.. (repeat per channel; e.g. 0:SEQ,BURST=32)")
        .option("mix-file", "read the per-channel mix from a [channel.N]-sectioned config file")
        .option("burst", "burst length 1-128 (default 32)")
        .option("btype", "burst type FIXED|INCR|WRAP (default INCR)")
        .option("sig", "signaling NB|BLK|AGR (default NB)")
        .option("batch", "transactions per batch (default 4096)")
        .option("scale", "campaign scale factor (default 1.0)")
        .option("listen", "serve: TCP bind address (default 127.0.0.1:5557)")
        .option("addr-bind", "serve: legacy alias of --listen")
        .option("workers", "serve: shared executor-pool threads (default: parallelism - 1)")
        .option("max-sessions", "serve: concurrent sessions (default 8); with --serial, total")
        .option("max-batch", "serve: per-session BATCH ceiling (default 1048576)")
        .option("max-queued", "serve: per-session queued-run ceiling (default 8)")
        .option("stream-interval-ms", "serve: STREAM heartbeat/poll interval in ms (default 100)")
        .flag("serial", "serve: legacy one-client-at-a-time loop (inline execution)")
        .option("csv", "write table/figure CSV to this path")
        .option("file", "trace file for the trace command")
        .option("speeds", "sweep: comma list of data rates (default 1600,2400)")
        .option("patterns", "sweep: comma list of presets (seq,rnd,strided,bank,chase,phased)")
        .option("maps", "sweep: comma list of address-mapping policies")
        .option("knobs", "sweep: controller-knob variants, e.g. lookahead=1,lookahead=8+wq=32")
        .option("scheds", "sweep: comma list of scheduler policies, e.g. fcfs,frfcfs-cap,closed")
        .option("mixes", "sweep: ;-separated mixes of +-joined N:TOKENS channel specs")
        .option("spec", "sweep: read the sweep spec from this config file")
        .option("jobs", "sweep: worker threads (default: available parallelism)")
        .option("out", "sweep: write per-job JSON/CSV artifacts + BENCH_sweep.json here")
        .option("threshold", "compare: regression threshold in percent (default 2.0)")
        .flag("strict", "compare: exit non-zero when regressions exceed the threshold")
        .flag("verify", "enable data-integrity checking")
        .flag("xla", "require the XLA runtime (error if artifacts missing)")
        .flag("no-xla", "skip loading the XLA runtime")
}

fn pattern_from_args(args: &ddr4bench::cli::Args) -> Result<PatternConfig> {
    let mut toks: Vec<String> = vec![
        format!("OP={}", args.get_or("op", "R")),
        format!("ADDR={}", args.get_or("addr", "SEQ")),
        format!("BURST={}", args.get_or("burst", "32")),
        format!("TYPE={}", args.get_or("btype", "INCR")),
        format!("SIG={}", args.get_or("sig", "NB")),
        format!("BATCH={}", args.get_or("batch", "4096")),
    ];
    // pattern-engine parameters (order-independent in the token syntax)
    for (opt, key) in [
        ("seed", "SEED"),
        ("stride", "STRIDE"),
        ("wset", "WSET"),
        ("phases", "PHASES"),
        ("map", "MAP"),
        ("sched", "SCHED"),
        ("telemetry", "TELEM"),
    ] {
        if let Some(v) = args.get(opt) {
            toks.push(format!("{key}={v}"));
        }
    }
    if args.has_flag("verify") {
        toks.push("VERIFY=1".into());
    }
    let refs: Vec<&str> = toks.iter().map(String::as_str).collect();
    parse_pattern_config(&refs).map_err(|e| anyhow!("{e}"))
}

/// Build the heterogeneous mix from `--ch` specs or `--mix-file` (None
/// when neither is given — the homogeneous path; giving both is
/// ambiguous and rejected).
fn mix_from_args(args: &ddr4bench::cli::Args) -> Result<Option<ChannelMix>> {
    let specs = args.get_multi("ch");
    let file = args.get("mix-file");
    match (specs.is_empty(), file) {
        (true, None) => Ok(None),
        (false, Some(_)) => Err(anyhow!("--ch and --mix-file are mutually exclusive")),
        (false, None) => {
            let refs: Vec<&str> = specs.iter().map(String::as_str).collect();
            Ok(Some(parse_channel_mix(&refs).map_err(|e| anyhow!("{e}"))?))
        }
        (true, Some(path)) => {
            let text =
                std::fs::read_to_string(path).map_err(|e| anyhow!("cannot read {path}: {e}"))?;
            Ok(Some(parse_mix_file(&text).map_err(|e| anyhow!("{path}: {e}"))?))
        }
    }
}

/// The scalar per-pattern options of `run` — every option whose value
/// lands in a single [`PatternConfig`] (plus `channels`, which a mix
/// fixes itself). Registering a new pattern option in [`cli`] means
/// adding it here too, or it will be silently ignored next to `--ch`.
const SCALAR_PATTERN_OPTS: [&str; 14] = [
    "op", "addr", "seed", "stride", "wset", "phases", "map", "sched", "telemetry", "burst",
    "btype", "sig", "batch", "channels",
];

/// A mix carries every pattern parameter per channel and fixes the
/// channel count, so the scalar pattern flags would be silently ignored
/// next to `--ch`/`--mix-file` — reject the combination instead (used by
/// both `run` and `interference`).
fn reject_scalar_pattern_flags(args: &ddr4bench::cli::Args) -> Result<()> {
    for key in SCALAR_PATTERN_OPTS {
        if args.get(key).is_some() {
            return Err(anyhow!(
                "--{key} conflicts with --ch/--mix-file: put the parameter in the \
                 per-channel specs instead (e.g. --ch 0:SEQ,BURST=32)"
            ));
        }
    }
    if args.has_flag("verify") {
        return Err(anyhow!(
            "--verify conflicts with --ch/--mix-file: use a VERIFY=1 token in the \
             per-channel specs instead"
        ));
    }
    Ok(())
}

fn design_from_args(args: &ddr4bench::cli::Args) -> Result<DesignConfig> {
    let speed = SpeedBin::parse(args.get_or("speed", "1600"))
        .ok_or_else(|| anyhow!("unknown --speed"))?;
    let channels: usize = args.parse_or("channels", 1usize).map_err(|e| anyhow!(e))?;
    let mut d = DesignConfig::with_channels(channels, speed);
    if let Some(v) = args.get("engine") {
        d.engine = EngineKind::parse(v)
            .ok_or_else(|| anyhow!("--engine: unknown engine `{v}` (expected cycle|event)"))?;
    }
    d.validate().map_err(|e| anyhow!("{e}"))?;
    Ok(d)
}

fn sweep_spec_from_args(args: &ddr4bench::cli::Args) -> Result<sweep::SweepSpec> {
    // Base = the spec file when given, else the paper grid; explicit
    // --speeds/--channels/--patterns then override the base's axes.
    let mut spec = if let Some(path) = args.get("spec") {
        let text = std::fs::read_to_string(path)?;
        sweep::SweepSpec::parse(&text)?
    } else {
        sweep::SweepSpec::paper_grid()
    };
    if let Some(v) = args.get("speeds") {
        spec.speeds = sweep::parse_speed_list(v)?;
    }
    if let Some(v) = args.get("channels") {
        spec.channels = sweep::parse_channel_list(v)?;
    }
    if let Some(v) = args.get("patterns") {
        spec.patterns = v
            .split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(|name| sweep::preset(name).ok_or_else(|| anyhow!("unknown pattern `{name}`")))
            .collect::<Result<_>>()?;
    }
    if let Some(v) = args.get("maps") {
        spec.mappings = sweep::parse_mapping_list(v)?;
    }
    if let Some(v) = args.get("knobs") {
        spec.knobs = sweep::parse_knob_list(v)?;
    }
    if let Some(v) = args.get("scheds") {
        spec.scheds = sweep::parse_sched_list(v)?;
    }
    if let Some(v) = args.get("mixes") {
        spec.mixes = sweep::parse_mix_list(v)?;
    }
    if let Some(v) = args.get("engine") {
        spec.engine = EngineKind::parse(v)
            .ok_or_else(|| anyhow!("--engine: unknown engine `{v}` (expected cycle|event)"))?;
    }
    if let Some(v) = args.get("telemetry") {
        let w = ddr4bench::config::parse_u64_with_suffix(v)
            .ok_or_else(|| anyhow!("--telemetry: expected window cycles, got `{v}`"))?;
        if w == 0 {
            return Err(anyhow!("--telemetry: window must be >= 1 AXI cycle"));
        }
        spec.telemetry = Some(w);
    }
    if args.has_flag("audit") {
        spec.audit = true;
    }
    Ok(spec)
}

fn maybe_runtime(args: &ddr4bench::cli::Args) -> Result<Option<XlaRuntime>> {
    if args.has_flag("no-xla") {
        return Ok(None);
    }
    let dir = ddr4bench::artifacts_dir();
    if XlaRuntime::artifacts_present(&dir) {
        Ok(Some(XlaRuntime::load(&dir)?))
    } else if args.has_flag("xla") {
        Err(anyhow!("--xla requested but artifacts missing in {dir:?}; run `make artifacts`"))
    } else {
        Ok(None)
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match cli().parse(&argv) {
        Ok(a) => a,
        Err(help) => {
            println!("{help}");
            return Ok(());
        }
    };
    let scale: f64 = args.parse_or("scale", 1.0).map_err(|e| anyhow!(e))?;
    let csv_path = args.get("csv").map(std::path::PathBuf::from);

    // only `run` and `interference` consume a per-channel mix; anywhere
    // else --ch/--mix-file would be silently ignored — reject instead
    // (sweeps take mixes through --mixes / a [mixes] spec section)
    if !matches!(args.command.as_deref(), Some("run") | Some("interference"))
        && (!args.get_multi("ch").is_empty() || args.get("mix-file").is_some())
    {
        return Err(anyhow!(
            "--ch/--mix-file only apply to `run` and `interference`; sweep mixes go through \
             --mixes or a [mixes] spec section"
        ));
    }

    match args.command.as_deref() {
        None | Some("info") => {
            let d = design_from_args(&args)?;
            println!("ddr4bench v{}", ddr4bench::VERSION);
            println!(
                "design: {} channel(s) @ {} (PHY {:.0} MHz / AXI {:.0} MHz, {}-bit AXI)",
                d.channels,
                d.speed,
                d.speed.phy_clock_mhz(),
                d.speed.axi_clock_mhz(),
                d.axi_data_width_bits
            );
            let r = resource::design_cost(&d);
            println!(
                "modeled utilization: {:.0} LUT / {:.0} FF / {} BRAM / {} DSP",
                r.lut, r.ff, r.bram, r.dsp
            );
            let dir = ddr4bench::artifacts_dir();
            match maybe_runtime(&args)? {
                Some(rt) => println!("XLA artifacts: loaded from {dir:?} ({})", rt.platform()),
                None => println!("XLA artifacts: not loaded (dir {dir:?})"),
            }
        }
        Some("run") => {
            let mix = mix_from_args(&args)?;
            let mut design = design_from_args(&args)?;
            if let Some(mix) = &mix {
                reject_scalar_pattern_flags(&args)?;
                // the mix fixes the channel count (one config per channel)
                design.channels = mix.len();
                design.validate().map_err(|e| anyhow!("{e}"))?;
            }
            let mix = match mix {
                Some(m) => m,
                None => ChannelMix::uniform(&pattern_from_args(&args)?, design.channels)
                    .map_err(|e| anyhow!("{e}"))?,
            };
            let axi_ns = 1000.0 / design.speed.axi_clock_mhz();
            let trace_path = args.get("cmd-trace").map(std::path::PathBuf::from);
            let mut platform = Platform::new(design);
            if let Some(rt) = maybe_runtime(&args)? {
                platform = platform.with_runtime(rt);
            }
            if trace_path.is_some() {
                for ch in 0..platform.channels() {
                    platform.enable_cmd_trace(ch, ddr4bench::obs::DEFAULT_TRACE_EVENTS)?;
                }
            }
            let audit = args.has_flag("audit");
            if audit {
                for ch in 0..platform.channels() {
                    platform.enable_audit(ch)?;
                }
            }
            let results = platform.run_batch_mix_results(&mix)?;
            let mut survivors = Vec::new();
            let mut failed = 0usize;
            for (ch, result) in results.iter().enumerate() {
                let label = mix.channel_label(ch);
                let s = match result {
                    Ok(s) => s,
                    Err(e) => {
                        failed += 1;
                        println!("ch{ch} [{label}]: ERROR {e}");
                        continue;
                    }
                };
                println!(
                    "ch{ch} [{label}]: rd {:.2} GB/s  wr {:.2} GB/s  total {:.2} GB/s  \
                     (rd lat {:.0} ns, wr lat {:.0} ns, refresh stall {} ck, mismatches {})",
                    s.read_throughput_gbs(),
                    s.write_throughput_gbs(),
                    s.total_throughput_gbs(),
                    s.read_latency_ns(),
                    s.write_latency_ns(),
                    s.counters.refresh_stall_dram_cycles,
                    s.counters.mismatches
                );
                println!(
                    "ch{ch} [{label}]: rd p50/p95/p99 {:.0}/{:.0}/{:.0} ns  \
                     wr p50/p95/p99 {:.0}/{:.0}/{:.0} ns",
                    s.read_latency_pct_ns(50.0),
                    s.read_latency_pct_ns(95.0),
                    s.read_latency_pct_ns(99.0),
                    s.write_latency_pct_ns(50.0),
                    s.write_latency_pct_ns(95.0),
                    s.write_latency_pct_ns(99.0),
                );
                if let Some(series) = &s.telemetry {
                    let title = format!("ch{ch} {label}");
                    let t = ddr4bench::report::timeline_table(&title, series, axi_ns);
                    println!("{}", t.ascii());
                }
                survivors.push(s.clone());
            }
            if survivors.len() > 1 {
                let agg = Platform::aggregate(&survivors);
                println!("aggregate: {:.2} GB/s", agg.total_throughput_gbs());
            }
            if let Some(path) = &trace_path {
                let speed = platform.design().speed.name();
                let channels: Vec<(usize, &ddr4bench::obs::CmdTrace)> = (0..platform.channels())
                    .filter_map(|ch| platform.cmd_trace(ch).map(|t| (ch, t)))
                    .collect();
                let out = ddr4bench::obs::export::trace_csv_annotated(speed, &channels);
                let dropped: u64 = channels.iter().map(|(_, t)| t.dropped()).sum();
                std::fs::write(path, &out)?;
                println!("wrote DRAM command trace to {}", path.display());
                if dropped > 0 {
                    println!(
                        "note: {dropped} event(s) dropped by the trace ring; \
                         an offline audit of this CSV will report TRUNCATED"
                    );
                }
            }
            if audit {
                let mut violated = false;
                for ch in 0..platform.channels() {
                    if let Some(auditor) = platform.auditor(ch) {
                        print!("{}", ddr4bench::check::report::render(auditor, ch, 0));
                        violated |= matches!(
                            ddr4bench::check::report::status(auditor, 0),
                            ddr4bench::check::Status::Violations
                        );
                    }
                }
                if violated {
                    return Err(anyhow!("protocol audit detected JEDEC timing violations"));
                }
            }
            if failed > 0 {
                return Err(anyhow!(
                    "{failed} of {} channel(s) failed (surviving channels reported above)",
                    results.len()
                ));
            }
        }
        Some("interference") => {
            let mix = mix_from_args(&args)?
                .ok_or_else(|| anyhow!("interference requires --ch specs or --mix-file"))?;
            reject_scalar_pattern_flags(&args)?;
            let design = design_from_args(&args)?;
            let workloads: Vec<(String, PatternConfig)> = mix
                .iter()
                .enumerate()
                .map(|(ch, cfg)| (format!("ch{ch}:{}", mix.channel_label(ch)), cfg.clone()))
                .collect();
            let m = interference_matrix(&design, &workloads)?;
            let (bw, lat) = ddr4bench::report::interference_tables(&m);
            println!("{}", bw.ascii());
            println!("{}", lat.ascii());
            if let Some(p) = csv_path {
                bw.write_csv(&p)?;
                lat.write_csv(&p.with_extension("p99.csv"))?;
            }
        }
        Some("table3") => {
            let mut t = ddr4bench::report::Table::new(
                "Table III: FPGA resource utilization (modeled)",
                &["Component/Design", "LUT", "FF", "BRAM", "DSP", "LUT %"],
            );
            for row in resource::table3() {
                let u = resource::utilization(row.res);
                t.row(vec![
                    row.name,
                    format!("{:.0}", row.res.lut),
                    format!("{:.0}", row.res.ff),
                    format!("{}", row.res.bram),
                    format!("{:.0}", row.res.dsp),
                    format!("{:.2}%", u[0] * 100.0),
                ]);
            }
            println!("{}", t.ascii());
            if let Some(p) = csv_path {
                t.write_csv(&p)?;
            }
        }
        Some("table4") => {
            let (t, _) = campaign::table4(scale);
            println!("{}", t.ascii());
            if let Some(p) = csv_path {
                t.write_csv(&p)?;
            }
        }
        Some("fig2") => {
            for fig in campaign::fig2(scale) {
                println!("{}", fig.ascii());
                if let Some(p) = &csv_path {
                    let name = p.with_extension(format!(
                        "{}.csv",
                        fig.title.chars().filter(char::is_ascii_digit).collect::<String>()
                    ));
                    std::fs::write(name, fig.csv())?;
                }
            }
        }
        Some("fig3") => {
            let t = campaign::fig3(scale);
            println!("{}", t.ascii());
            if let Some(p) = csv_path {
                t.write_csv(&p)?;
            }
        }
        Some("scaling") => {
            let t = campaign::scaling(scale);
            println!("{}", t.ascii());
            if let Some(p) = csv_path {
                t.write_csv(&p)?;
            }
        }
        Some("analysis") => {
            let t = campaign::analysis(scale);
            println!("{}", t.ascii());
            if let Some(p) = csv_path {
                t.write_csv(&p)?;
            }
        }
        Some("modelcheck") => {
            let (t, mae) = campaign::model_check(scale);
            println!("{}", t.ascii());
            println!("mean absolute relative error: {:.1}%", mae * 100.0);
            if let Some(p) = csv_path {
                t.write_csv(&p)?;
            }
        }
        Some("dse") => {
            let rt = maybe_runtime(&args)?;
            let points = ddr4bench::analytic::dse::explore(rt.as_ref())?;
            let mut t = ddr4bench::report::Table::new(
                format!(
                    "Design-space exploration ({} predictions)",
                    if rt.as_ref().is_some_and(|r| r.has_bwmodel()) {
                        "XLA bwmodel"
                    } else {
                        "rust model"
                    }
                ),
                &["Ch", "Rate", "Workload", "GB/s", "LUT", "GB/s per kLUT"],
            );
            for p in &points {
                t.row(vec![
                    p.channels.to_string(),
                    p.speed.to_string(),
                    p.workload.clone(),
                    format!("{:.2}", p.gbs),
                    format!("{:.0}", p.lut),
                    format!("{:.3}", p.gbs_per_klut),
                ]);
            }
            println!("{}", t.ascii());
            for wl in ["seq-read-128", "rnd-read-4", "mixed-32"] {
                let front = ddr4bench::analytic::dse::pareto(&points, wl);
                let desc: Vec<String> = front
                    .iter()
                    .map(|p| {
                        let (c, s) = (p.channels, p.speed);
                        format!("{c}ch@{s} ({:.1} GB/s, {:.0} LUT)", p.gbs, p.lut)
                    })
                    .collect();
                println!("pareto[{wl}]: {}", desc.join(" -> "));
            }
            if let Some(p) = csv_path {
                t.write_csv(&p)?;
            }
        }
        Some("trace") => {
            let path = args.get("file").ok_or_else(|| anyhow!("trace requires --file"))?;
            let text = std::fs::read_to_string(path)?;
            let records = ddr4bench::trafficgen::trace::parse_trace(&text)?;
            let design = design_from_args(&args)?;
            let mut platform = Platform::new(design);
            if let Some(rt) = maybe_runtime(&args)? {
                platform = platform.with_runtime(rt);
            }
            let s = platform.run_trace(0, &records, args.has_flag("verify"))?;
            println!(
                "trace: {} records  rd {:.2} GB/s  wr {:.2} GB/s  total {:.2} GB/s  \
                 energy {:.1} uJ ({:.1} pJ/bit)  mismatches {}",
                records.len(),
                s.read_throughput_gbs(),
                s.write_throughput_gbs(),
                s.total_throughput_gbs(),
                s.energy.total_nj() / 1e3,
                s.pj_per_bit().unwrap_or(0.0),
                s.counters.mismatches
            );
        }
        Some("sweep") => {
            let spec = sweep_spec_from_args(&args)?;
            let jobs = spec.expand();
            let workers = match args.get("jobs") {
                Some(v) => v.parse().map_err(|_| anyhow!("--jobs: bad integer `{v}`"))?,
                None => {
                    // each job itself runs one thread per channel, so
                    // scale the default pool down to avoid oversubscription
                    let par =
                        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
                    let max_ch = spec
                        .channels
                        .iter()
                        .copied()
                        .chain(spec.mixes.iter().map(|(_, m)| m.len()))
                        .max()
                        .unwrap_or(1);
                    (par / max_ch).max(1)
                }
            };
            println!(
                "sweep: {} jobs ({} speeds x {} channel counts x {} mappings x {} knob \
                 profiles x {} scheds x {} patterns, + {} mixes) on {} workers",
                jobs.len(),
                spec.speeds.len(),
                spec.channels.len(),
                spec.mappings.len(),
                spec.knobs.len(),
                spec.scheds.len(),
                spec.patterns.len(),
                spec.mixes.len(),
                workers.min(jobs.len().max(1))
            );
            let outcomes = sweep::run_sweep(jobs, workers)?;
            println!("{}", sweep::summary_table(&outcomes).ascii());
            if let Some(dir) = args.get("out") {
                let summary = sweep::write_artifacts(&outcomes, std::path::Path::new(dir))?;
                let timelines = outcomes
                    .iter()
                    .filter(|o| o.per_channel.iter().any(|s| s.telemetry.is_some()))
                    .count();
                let audits = outcomes.iter().filter(|o| o.audit.is_some()).count();
                println!(
                    "wrote {} JSON + {} CSV artifacts ({} timelines, {} audit certificates) \
                     and {}",
                    outcomes.len(),
                    outcomes.len(),
                    timelines,
                    audits,
                    summary.display()
                );
            }
        }
        Some("audit") => {
            if args.positional.is_empty() {
                return Err(anyhow!(
                    "audit needs a command-trace CSV, e.g. `ddr4bench audit trace.csv` \
                     (produce one with `ddr4bench run --cmd-trace trace.csv`)"
                ));
            }
            let speed_override = match args.get("speed") {
                Some(v) => Some(SpeedBin::parse(v).ok_or_else(|| {
                    anyhow!("--speed: unknown bin `{v}` (expected one of 1600/1866/2133/2400)")
                })?),
                None => None,
            };
            let mut violated = false;
            for path in &args.positional {
                let text = std::fs::read_to_string(path)
                    .map_err(|e| anyhow!("audit: cannot read {path}: {e}"))?;
                let parsed = ddr4bench::check::offline::parse_trace_csv(&text)
                    .map_err(|e| anyhow!("audit: {path}: {e}"))?;
                let audits = ddr4bench::check::offline::audit_trace(&parsed, speed_override)
                    .map_err(|e| anyhow!("audit: {path}: {e}"))?;
                if audits.is_empty() {
                    println!("{path}: no command events found");
                    continue;
                }
                let speed = speed_override
                    .or(parsed.speed)
                    .map(|s| s.name())
                    .unwrap_or("?");
                println!("{path}: {speed}, {} channel(s)", audits.len());
                for a in &audits {
                    print!("{}", ddr4bench::check::report::render(&a.auditor, a.channel, a.dropped));
                    violated |= matches!(
                        ddr4bench::check::report::status(&a.auditor, a.dropped),
                        ddr4bench::check::Status::Violations
                    );
                }
            }
            if violated {
                return Err(anyhow!("protocol audit detected JEDEC timing violations"));
            }
        }
        Some("compare") => {
            if args.positional.len() < 2 {
                return Err(anyhow!(
                    "compare needs at least two sweep summaries, e.g. \
                     `ddr4bench compare BENCH_sweep.json sweep-out/BENCH_sweep.json`"
                ));
            }
            let threshold: f64 = args.parse_or("threshold", 2.0).map_err(|e| anyhow!(e))?;
            let files = args
                .positional
                .iter()
                .map(|p| compare::load_sweep(std::path::Path::new(p)))
                .collect::<Result<Vec<_>>>()?;
            for f in &files {
                println!("loaded {}: {} jobs (source: {})", f.label, f.records.len(), f.source);
            }
            let report = compare::compare(&files, threshold);
            println!("{}", report.delta.ascii());
            println!("{}", report.axes.ascii());
            if report.regressions.is_empty() {
                println!("no regressions beyond {threshold}% vs baseline {}", files[0].label);
            } else {
                for r in &report.regressions {
                    println!("REGRESSION: {r}");
                }
            }
            if let Some(p) = csv_path {
                report.delta.write_csv(&p)?;
            }
            if args.has_flag("strict") && !report.regressions.is_empty() {
                return Err(anyhow!(
                    "{} regression(s) beyond {threshold}%",
                    report.regressions.len()
                ));
            }
        }
        Some("serve") => {
            let design = design_from_args(&args)?;
            let addr = args.get("listen").or(args.get("addr-bind")).unwrap_or("127.0.0.1:5557");
            if args.has_flag("serial") {
                // legacy single-master loop: one client at a time, inline
                // execution on this thread (the only mode that can carry
                // the XLA runtime)
                let mut platform = Platform::new(design);
                if let Some(rt) = maybe_runtime(&args)? {
                    platform = platform.with_runtime(rt);
                }
                let max = match args.get("max-sessions") {
                    Some(v) => {
                        Some(v.parse().map_err(|_| anyhow!("--max-sessions: bad integer `{v}`"))?)
                    }
                    None => None,
                };
                serve_tcp(HostController::new(platform), addr, max)?;
            } else {
                if args.has_flag("xla") {
                    return Err(anyhow!(
                        "--xla requires --serial: pooled server sessions use the pure-Rust \
                         data path"
                    ));
                }
                let mut cfg = ServerConfig::default();
                if let Some(v) = args.get("workers") {
                    cfg.workers = v.parse().map_err(|_| anyhow!("--workers: bad integer `{v}`"))?;
                }
                cfg.max_sessions =
                    args.parse_or("max-sessions", cfg.max_sessions).map_err(|e| anyhow!(e))?;
                cfg.limits.max_batch =
                    args.parse_or("max-batch", cfg.limits.max_batch).map_err(|e| anyhow!(e))?;
                cfg.limits.max_queued_runs = args
                    .parse_or("max-queued", cfg.limits.max_queued_runs)
                    .map_err(|e| anyhow!(e))?;
                if let Some(v) = args.get("stream-interval-ms") {
                    let ms: u64 = v
                        .parse()
                        .map_err(|_| anyhow!("--stream-interval-ms: bad integer `{v}`"))?;
                    cfg.stream_interval = std::time::Duration::from_millis(ms.max(1));
                }
                BenchServer::bind(design, cfg, addr)?.run()?;
            }
        }
        Some(other) => return Err(anyhow!("unknown command {other}")),
    }
    Ok(())
}
