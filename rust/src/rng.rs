//! Deterministic PRNGs shared across the platform.
//!
//! Two generators live here:
//!
//! - [`Xorshift32`] — the *data-path* PRBS generator. This is the exact
//!   sequence the Pallas kernel (`python/compile/kernels/prbs.py`)
//!   implements on the XLA side; the integration test
//!   `rust/tests/runtime_artifacts.rs` asserts bit-for-bit equality between
//!   this Rust mirror and the AOT-compiled kernel. The RTL analogue is the
//!   traffic generator's per-lane LFSR that produces non-zero write data
//!   (the paper's §II-B differentiator vs. Shuhai).
//! - [`SplitMix64`] — the *control-path* generator used for random
//!   addressing, operation mixing, and the property-test kit. It is never
//!   compared against the kernels, so it can be a different (faster,
//!   better-distributed) algorithm.

/// xorshift32 PRBS generator (Marsaglia). Period 2^32 - 1; never yields 0,
/// which conveniently satisfies the paper's "non-zero data" requirement.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Xorshift32 {
    state: u32,
}

impl Xorshift32 {
    /// Create a generator from a seed. A zero seed would lock the sequence
    /// at zero, so it is mapped to a fixed non-zero constant — the same
    /// remapping the Pallas kernel applies.
    pub fn new(seed: u32) -> Self {
        Self { state: if seed == 0 { 0x9E37_79B9 } else { seed } }
    }

    /// Advance one step and return the new state (a non-zero 32-bit word).
    #[inline(always)]
    pub fn next_u32(&mut self) -> u32 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 17;
        x ^= x << 5;
        self.state = x;
        x
    }

    /// Current internal state without advancing.
    pub fn state(&self) -> u32 {
        self.state
    }

    /// Fill a slice with successive outputs.
    pub fn fill(&mut self, out: &mut [u32]) {
        for w in out {
            *w = self.next_u32();
        }
    }
}

/// SplitMix64: fast 64-bit generator with excellent avalanche behaviour.
/// Used for address randomization and test-case generation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from any 64-bit seed (zero is fine).
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    #[inline(always)]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Next 32-bit output (upper half of the 64-bit output).
    #[inline(always)]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform value in `[0, bound)` via Lemire's multiply-shift reduction.
    /// `bound` must be non-zero.
    #[inline(always)]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform value in the inclusive range `[lo, hi]`.
    pub fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli draw: true with probability `pct / 100`.
    pub fn percent(&mut self, pct: u32) -> bool {
        debug_assert!(pct <= 100);
        self.below(100) < pct as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xorshift32_known_sequence() {
        // First outputs from seed 1 — the canonical xorshift32 sequence.
        // These constants are also asserted by python/tests/test_kernels.py
        // against the Pallas kernel, pinning both sides to the same PRBS.
        let mut g = Xorshift32::new(1);
        assert_eq!(g.next_u32(), 270369);
        assert_eq!(g.next_u32(), 67634689);
        assert_eq!(g.next_u32(), 2647435461);
        assert_eq!(g.next_u32(), 307599695);
    }

    #[test]
    fn xorshift32_zero_seed_remapped() {
        let mut g = Xorshift32::new(0);
        assert_ne!(g.state(), 0);
        // and it still produces non-zero outputs
        for _ in 0..1000 {
            assert_ne!(g.next_u32(), 0);
        }
    }

    #[test]
    fn xorshift32_never_zero() {
        let mut g = Xorshift32::new(0xDEAD_BEEF);
        for _ in 0..100_000 {
            assert_ne!(g.next_u32(), 0, "xorshift32 must never emit zero");
        }
    }

    #[test]
    fn splitmix_below_in_bounds() {
        let mut g = SplitMix64::new(42);
        for bound in [1u64, 2, 3, 7, 100, 1 << 33] {
            for _ in 0..200 {
                assert!(g.below(bound) < bound);
            }
        }
    }

    #[test]
    fn splitmix_range_inclusive_hits_endpoints() {
        let mut g = SplitMix64::new(7);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..10_000 {
            let v = g.range_inclusive(3, 6);
            assert!((3..=6).contains(&v));
            lo_seen |= v == 3;
            hi_seen |= v == 6;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn splitmix_deterministic() {
        let mut a = SplitMix64::new(123);
        let mut b = SplitMix64::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn percent_extremes() {
        let mut g = SplitMix64::new(5);
        for _ in 0..100 {
            assert!(!g.percent(0));
            assert!(g.percent(100));
        }
    }
}
