//! One client's protocol session: the single place [`Request`]s are
//! mapped to [`Response`]s.
//!
//! A [`Session`] owns an isolated [`Platform`] (staged per-channel
//! configs, last-run stats) plus per-session [`SessionLimits`], and
//! executes batches either inline on the calling thread (the historical
//! single-user transports) or by dispatching to a shared
//! [`RunPool`] (the concurrent bench server) — protocol behaviour is
//! identical either way, byte for byte. [`serve_stream`] is the one
//! transport loop: the in-memory UART stand-in, `serve_tcp` and every
//! bench-server connection all push their byte streams through it.
//!
//! Limit violations answer named `ERR` diagnostics — `LIMIT_CHANNELS`,
//! `LIMIT_BATCH`, `LIMIT_QUEUE` — so scripted clients can distinguish a
//! quota rejection from a malformed command. With `STREAM ON`, pooled
//! runs emit `STREAM <label> MS=<elapsed>` heartbeat lines while a long
//! batch executes, before the final `OK`/`ERR` reply; when the pending
//! pattern sets a telemetry window (`TELEM=`), single-channel heartbeats
//! are enriched in place with the live window (`bw= qd= p99=`) read off
//! the batch's [`SharedTelemetry`](crate::obs::SharedTelemetry) handle.
//! `METRICS <ch>` answers the last run's telemetry snapshot and
//! `TRACEDUMP <ch>` arms (first call) then dumps the channel's DRAM
//! command trace.

use std::io::{BufRead, Write};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::{ChannelMix, PatternConfig, SessionLimits};
use crate::obs::export::window_bw_gbs;
use crate::obs::{snapshot_from_series, DEFAULT_TRACE_EVENTS};
use crate::platform::{Platform, RunPool};
use crate::stats::BatchStats;

use super::proto::{parse_request, render_response, MixCell, ProgressLive, Request, Response};

/// How often a pooled run wakes up to emit a `STREAM` heartbeat (when
/// the session has streaming on) and re-poll the pool.
const STREAM_INTERVAL: Duration = Duration::from_millis(100);

/// How the session executes batches.
enum Exec {
    /// On the calling thread, via [`Platform::run_batch`] — the serial
    /// transports (in-memory REPL, `serve_tcp`).
    Inline,
    /// Dispatched to a shared worker pool — bench-server sessions. K
    /// sessions share the pool's bounded worker threads, so they cannot
    /// oversubscribe the machine.
    Pool(Arc<RunPool>),
}

/// One client's session state over its own isolated [`Platform`].
pub struct Session {
    id: u64,
    platform: Platform,
    pending: Vec<PatternConfig>,
    last: Vec<Option<BatchStats>>,
    limits: SessionLimits,
    exec: Exec,
    stream: bool,
    stream_interval: Duration,
}

impl Session {
    /// A serial single-user session: inline execution, no limits —
    /// exactly the historical `HostController` behaviour.
    pub fn inline(platform: Platform) -> Self {
        Self::build(platform, SessionLimits::UNLIMITED, Exec::Inline, 0)
    }

    /// A bench-server session: batches dispatch to the shared `pool`,
    /// bounded by `limits`, identified by `id` (used in server logs and
    /// thread names).
    pub fn pooled(platform: Platform, pool: Arc<RunPool>, limits: SessionLimits, id: u64) -> Self {
        Self::build(platform, limits, Exec::Pool(pool), id)
    }

    fn build(platform: Platform, limits: SessionLimits, exec: Exec, id: u64) -> Self {
        let n = platform.channels();
        Self {
            id,
            platform,
            pending: vec![PatternConfig::default(); n],
            last: vec![None; n],
            limits,
            exec,
            stream: false,
            stream_interval: STREAM_INTERVAL,
        }
    }

    /// The session's handle/id (0 for serial sessions).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Borrow the session's platform.
    pub fn platform(&self) -> &Platform {
        &self.platform
    }

    /// Take the platform back (end of session).
    pub fn into_platform(self) -> Platform {
        self.platform
    }

    /// The limits in force.
    pub fn limits(&self) -> SessionLimits {
        self.limits
    }

    /// Override the heartbeat/poll cadence of pooled runs (tuning/test
    /// hook; the default is 100 ms).
    pub fn set_stream_interval(&mut self, interval: Duration) {
        self.stream_interval = interval.max(Duration::from_millis(1));
    }

    /// Handle one typed request (no streaming sink — progress heartbeats
    /// are dropped).
    pub fn handle(&mut self, req: &Request) -> Response {
        self.handle_with_progress(req, &mut |_| {})
    }

    /// Handle one typed request, forwarding any mid-run
    /// [`Response::Progress`] heartbeats to `progress`.
    pub fn handle_with_progress(
        &mut self,
        req: &Request,
        progress: &mut dyn FnMut(Response),
    ) -> Response {
        match self.dispatch(req, progress) {
            Ok(resp) => resp,
            Err(e) => Response::Err(e),
        }
    }

    /// Parse + handle + render in one step — the line-oriented surface
    /// the byte-compat tests pin.
    pub fn handle_line(&mut self, line: &str) -> String {
        let resp = match parse_request(line) {
            Ok(req) => self.handle(&req),
            Err(e) => Response::Err(e),
        };
        render_response(&resp)
    }

    fn dispatch(
        &mut self,
        req: &Request,
        progress: &mut dyn FnMut(Response),
    ) -> Result<Response, String> {
        match req {
            Request::Help => Ok(Response::Help),
            Request::Patterns => Ok(Response::Patterns),
            Request::Scheds => {
                let names = crate::controller::SchedKind::ALL
                    .iter()
                    .map(|k| k.name().to_ascii_uppercase())
                    .collect();
                Ok(Response::Scheds { names })
            }
            Request::Mappings => {
                // custom bit orders like MAP=RoBaBgCo are also accepted
                let mut names: Vec<String> = crate::ddr4::MappingPolicy::builtins()
                    .iter()
                    .map(|m| m.name().to_ascii_uppercase())
                    .collect();
                names.push("CUSTOM".into());
                Ok(Response::Mappings { names })
            }
            Request::Info => {
                let d = self.platform.design();
                Ok(Response::Info {
                    channels: d.channels,
                    speed: d.speed,
                    axi_mhz: d.speed.axi_clock_mhz(),
                    phy_mhz: d.speed.phy_clock_mhz(),
                    axi_bits: d.axi_data_width_bits,
                    xla: self.platform.has_runtime(),
                })
            }
            Request::Cfg { ch, cfg } => {
                self.check_channel(*ch)?;
                self.check_batch(cfg)?;
                self.pending[*ch] = (**cfg).clone();
                Ok(Response::Cfg { ch: *ch, cfg: cfg.clone() })
            }
            Request::ChCfg { specs } => {
                // atomic: every spec is vetted before any channel is
                // re-staged, so a bad spec can't half-apply the command
                for (ch, cfg) in specs {
                    self.check_channel(*ch)?;
                    self.check_batch(cfg)?;
                }
                for (ch, cfg) in specs {
                    self.pending[*ch] = cfg.clone();
                }
                Ok(Response::ChCfg { specs: specs.clone() })
            }
            Request::Run { ch } => {
                self.check_channel(*ch)?;
                let cfg = self.pending[*ch].clone();
                let label = format!("RUN CH={ch}");
                let stats = self.execute_single(*ch, &cfg, &label, progress)?;
                let resp = Response::Run {
                    ch: *ch,
                    txns: stats.counters.rd_txns + stats.counters.wr_txns,
                    cycles: stats.counters.total_cycles,
                };
                self.last[*ch] = Some(stats);
                Ok(resp)
            }
            Request::RunAll => {
                let channels = self.platform.channels();
                if channels > self.limits.max_channels {
                    return Err(format!(
                        "LIMIT_CHANNELS: RUNALL touches {channels} channel(s), exceeding \
                         this session's max_channels {}",
                        self.limits.max_channels
                    ));
                }
                // run each channel's own pending pattern, serially
                let mut stats = Vec::with_capacity(channels);
                for ch in 0..channels {
                    let cfg = self.pending[ch].clone();
                    let label = format!("RUNALL CH={ch}");
                    let s = self.execute_single(ch, &cfg, &label, progress)?;
                    self.last[ch] = Some(s.clone());
                    stats.push(s);
                }
                // the legacy rate-sum convention, kept wire-compatible
                let agg_gbs = Platform::aggregate_gbs(&stats, true);
                Ok(Response::RunAll { channels, agg_gbs })
            }
            Request::RunMix => {
                let channels = self.platform.channels();
                if channels > self.limits.max_channels {
                    return Err(format!(
                        "LIMIT_CHANNELS: RUNMIX touches {channels} channel(s), exceeding \
                         this session's max_channels {}",
                        self.limits.max_channels
                    ));
                }
                self.check_queued(channels)?;
                let mix = ChannelMix::new(self.pending.clone()).map_err(|e| e.to_string())?;
                let results = self.execute_mix(&mix, progress)?;
                let mut survivors = Vec::new();
                let mut cells = Vec::with_capacity(results.len());
                for (ch, result) in results.into_iter().enumerate() {
                    match result {
                        Ok(stats) => {
                            cells.push(MixCell::Ok { ch, gbs: stats.total_throughput_gbs() });
                            survivors.push(stats.clone());
                            self.last[ch] = Some(stats);
                        }
                        Err(e) => {
                            cells.push(MixCell::Err { ch, reason: e.to_string() });
                            self.last[ch] = None;
                        }
                    }
                }
                if survivors.is_empty() {
                    let rendered: Vec<String> = cells.iter().map(MixCell::render).collect();
                    return Err(format!("every channel failed: {}", rendered.join(" ")));
                }
                // platform aggregate (bytes sum over max cycles), the
                // same convention as `run` and the sweep artifacts —
                // per-rate sums diverge once channels are heterogeneous
                let agg_gbs = Platform::aggregate_gbs(&survivors, false);
                Ok(Response::RunMix { channels, ok: survivors.len(), agg_gbs, cells })
            }
            Request::Stats { ch } => {
                self.check_channel(*ch)?;
                let s = self.last[*ch].as_ref().ok_or("no batch has run on this channel")?;
                Ok(Response::Stats { ch: *ch, stats: Box::new(s.clone()) })
            }
            Request::Reset { ch } => {
                self.check_channel(*ch)?;
                self.pending[*ch] = PatternConfig::default();
                self.last[*ch] = None;
                Ok(Response::Reset)
            }
            Request::Stream { on } => {
                self.stream = *on;
                Ok(Response::Stream { on: *on })
            }
            Request::Metrics { ch } => {
                self.check_channel(*ch)?;
                let series = self.last[*ch]
                    .as_ref()
                    .and_then(|s| s.telemetry.as_ref())
                    .ok_or("no telemetry recorded (run with TELEM= or the telemetry key)")?;
                Ok(Response::Metrics { ch: *ch, snapshot: snapshot_from_series(series) })
            }
            Request::TraceDump { ch } => {
                self.check_channel(*ch)?;
                // first call arms the ring (and answers EVENTS=0); later
                // calls dump it non-destructively — enable_cmd_trace is
                // idempotent, so re-arming never clears captured events
                self.platform
                    .enable_cmd_trace(*ch, DEFAULT_TRACE_EVENTS)
                    .map_err(|e| e.to_string())?;
                let trace = self.platform.cmd_trace(*ch).expect("trace armed above");
                Ok(Response::TraceDump {
                    ch: *ch,
                    events: trace.events().copied().collect(),
                    dropped: trace.dropped(),
                })
            }
            Request::Audit { ch } => {
                self.check_channel(*ch)?;
                // first call arms the auditor (observation-only; commands
                // issued before arming make the verdict TRUNCATED, never a
                // false CLEAN) — enable_audit is idempotent like the trace
                self.platform.enable_audit(*ch).map_err(|e| e.to_string())?;
                let auditor = self.platform.auditor(*ch).expect("auditor armed above");
                Ok(Response::Audit {
                    ch: *ch,
                    events: auditor.events(),
                    dropped: 0,
                    violations: crate::check::report::total_violations(auditor),
                    status: crate::check::report::status(auditor, 0).as_str().to_string(),
                })
            }
            Request::Quit => Ok(Response::Bye),
        }
    }

    fn check_channel(&self, ch: usize) -> Result<(), String> {
        if ch >= self.platform.channels() {
            return Err(format!(
                "channel {ch} out of range (design has {})",
                self.platform.channels()
            ));
        }
        if ch >= self.limits.max_channels {
            return Err(format!(
                "LIMIT_CHANNELS: channel {ch} exceeds this session's max_channels {}",
                self.limits.max_channels
            ));
        }
        Ok(())
    }

    fn check_batch(&self, cfg: &PatternConfig) -> Result<(), String> {
        if cfg.batch_len > self.limits.max_batch {
            return Err(format!(
                "LIMIT_BATCH: BATCH={} exceeds this session's max_batch {}",
                cfg.batch_len, self.limits.max_batch
            ));
        }
        Ok(())
    }

    fn check_queued(&self, runs: usize) -> Result<(), String> {
        if runs > self.limits.max_queued_runs {
            return Err(format!(
                "LIMIT_QUEUE: {runs} queued run(s) exceed this session's max_queued_runs {}",
                self.limits.max_queued_runs
            ));
        }
        Ok(())
    }

    /// Run one channel's batch: inline, or dispatched to the shared pool
    /// with heartbeat polling.
    fn execute_single(
        &mut self,
        ch: usize,
        cfg: &PatternConfig,
        label: &str,
        progress: &mut dyn FnMut(Response),
    ) -> Result<BatchStats, String> {
        let pool = match &self.exec {
            Exec::Inline => None,
            Exec::Pool(p) => Some(Arc::clone(p)),
        };
        match pool {
            None => self.platform.run_batch(ch, cfg).map_err(|e| e.to_string()),
            Some(pool) => {
                let pending =
                    self.platform.start_batch_on(&pool, ch, cfg).map_err(|e| e.to_string())?;
                let axi_ns = 1000.0 / self.platform.design().speed.axi_clock_mhz();
                let started = Instant::now();
                loop {
                    if let Some(result) = self.platform.poll_batch(&pending, self.stream_interval)
                    {
                        return result.map_err(|e| e.to_string());
                    }
                    if self.stream {
                        // enrich the heartbeat with the most recently
                        // closed telemetry window, when the run has one
                        let live = pending.live_telemetry().and_then(|shared| {
                            let snap = shared.lock().expect("telemetry mutex poisoned");
                            snap.last.as_ref().map(|w| ProgressLive {
                                bw_gbs: window_bw_gbs(w, axi_ns),
                                qd: w.queue_depth,
                                p99_ns: w.rd_p99.max(w.wr_p99) as f64 * axi_ns,
                            })
                        });
                        progress(Response::Progress {
                            label: label.to_string(),
                            ms: started.elapsed().as_millis() as u64,
                            live,
                        });
                    }
                }
            }
        }
    }

    /// Run a whole channel mix: inline (the scoped-thread executive), or
    /// one pool job per channel with heartbeat polling.
    fn execute_mix(
        &mut self,
        mix: &ChannelMix,
        progress: &mut dyn FnMut(Response),
    ) -> Result<Vec<anyhow::Result<BatchStats>>, String> {
        let pool = match &self.exec {
            Exec::Inline => None,
            Exec::Pool(p) => Some(Arc::clone(p)),
        };
        match pool {
            None => self.platform.run_batch_mix_results(mix).map_err(|e| e.to_string()),
            Some(pool) => {
                let mut pending =
                    self.platform.start_mix_on(&pool, mix).map_err(|e| e.to_string())?;
                let started = Instant::now();
                while !self.platform.poll_mix(&mut pending, self.stream_interval) {
                    if self.stream {
                        progress(Response::Progress {
                            label: "RUNMIX".into(),
                            ms: started.elapsed().as_millis() as u64,
                            live: None,
                        });
                    }
                }
                Ok(self.platform.finish_mix(pending))
            }
        }
    }
}

/// Drive a whole session over reader/writer byte streams — the single
/// transport loop behind the in-memory UART stand-in,
/// [`crate::hostctrl::serve_tcp`] and every bench-server connection.
/// Blank lines are skipped; each command line answers exactly one
/// `OK`/`ERR` line (preceded by `STREAM` heartbeat lines when the
/// session streams); `QUIT`'s `OK BYE` ends the loop.
pub fn serve_stream<R: BufRead, W: Write>(
    session: &mut Session,
    reader: R,
    mut writer: W,
) -> std::io::Result<()> {
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = match parse_request(&line) {
            Ok(req) => {
                // heartbeats go down the same wire, flushed immediately
                // so a streaming client sees them during the run
                let mut werr: Option<std::io::Error> = None;
                let resp = session.handle_with_progress(&req, &mut |p| {
                    if werr.is_none() {
                        let attempt = writeln!(writer, "{}", render_response(&p))
                            .and_then(|()| writer.flush());
                        if let Err(e) = attempt {
                            werr = Some(e);
                        }
                    }
                });
                if let Some(e) = werr {
                    return Err(e);
                }
                resp
            }
            Err(e) => Response::Err(e),
        };
        writeln!(writer, "{}", render_response(&resp))?;
        if matches!(resp, Response::Bye) {
            break;
        }
    }
    writer.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DesignConfig, SpeedBin};

    fn pooled(channels: usize, workers: usize, limits: SessionLimits) -> Session {
        let platform = Platform::new(DesignConfig::with_channels(channels, SpeedBin::Ddr4_1600));
        Session::pooled(platform, Arc::new(RunPool::new(workers)), limits, 7)
    }

    #[test]
    fn pooled_session_answers_byte_identically_to_inline() {
        let script = [
            "INFO",
            "HELP",
            "CFG 0 OP=R ADDR=SEQ BURST=32 BATCH=256",
            "CHCFG 1:CHASE,WSET=64k,BURST=1,BATCH=64 2:BANK,SEED=1,BURST=1,BATCH=64",
            "RUN 0",
            "STATS 0",
            "RUNALL",
            "RUNMIX",
            "STATS 1",
            "CFG 0 OP=R ADDR=SEQ BURST=8 BATCH=128 TELEM=64",
            "TRACEDUMP 0",
            "RUN 0",
            "METRICS 0",
            "TRACEDUMP 0",
            "METRICS 2",
            "RESET 0",
            "STATS 0",
            "RUN 9",
            "QUIT",
        ];
        let mut inline = Session::inline(Platform::new(DesignConfig::with_channels(
            3,
            SpeedBin::Ddr4_1600,
        )));
        let mut pooled = pooled(3, 2, SessionLimits::UNLIMITED);
        for line in script {
            assert_eq!(
                inline.handle_line(line),
                pooled.handle_line(line),
                "`{line}` diverges between inline and pooled execution"
            );
        }
    }

    #[test]
    fn limit_violations_answer_named_diagnostics() {
        let limits = SessionLimits { max_channels: 2, max_batch: 1000, max_queued_runs: 2 };
        let mut s = pooled(3, 1, limits);
        // channel 2 exists in the design but exceeds the session quota
        let r = s.handle_line("CFG 2 OP=R BATCH=64");
        assert!(r.starts_with("ERR LIMIT_CHANNELS:"), "{r}");
        // out-of-design range keeps the legacy (non-limit) diagnostic
        let r = s.handle_line("CFG 9 OP=R BATCH=64");
        assert!(r.starts_with("ERR channel 9 out of range"), "{r}");
        let r = s.handle_line("CFG 0 OP=R BATCH=2000");
        assert!(r.starts_with("ERR LIMIT_BATCH:"), "{r}");
        let r = s.handle_line("CHCFG 0:SEQ,BATCH=2000");
        assert!(r.starts_with("ERR LIMIT_BATCH:"), "{r}");
        // RUNALL/RUNMIX touch all 3 channels; the quota allows 2
        let r = s.handle_line("RUNALL");
        assert!(r.starts_with("ERR LIMIT_CHANNELS:"), "{r}");
        let r = s.handle_line("RUNMIX");
        assert!(r.starts_with("ERR LIMIT_CHANNELS:"), "{r}");
        // within quota everything still works
        let r = s.handle_line("CFG 1 OP=R BURST=4 BATCH=64");
        assert!(r.starts_with("OK CFG CH=1"), "{r}");
        let r = s.handle_line("RUN 1");
        assert!(r.starts_with("OK RUN CH=1 TXNS=64"), "{r}");
    }

    #[test]
    fn runmix_queue_limit_counts_one_run_per_channel() {
        let limits = SessionLimits { max_queued_runs: 2, ..SessionLimits::default() };
        let mut s = pooled(3, 1, limits);
        let r = s.handle_line("RUNMIX");
        assert!(r.starts_with("ERR LIMIT_QUEUE:"), "{r}");
        assert!(r.contains("3 queued run(s)"), "{r}");
        // a 2-channel session under the same limit is fine
        let mut s = pooled(2, 1, limits);
        let r = s.handle_line("CHCFG 0:SEQ,BURST=4,BATCH=64 1:SEQ,BURST=4,BATCH=64");
        assert!(r.starts_with("OK CHCFG"), "{r}");
        let r = s.handle_line("RUNMIX");
        assert!(r.starts_with("OK RUNMIX CHANNELS=2 OK=2"), "{r}");
    }

    #[test]
    fn pooled_runmix_isolates_a_panicking_channel() {
        let mut p = Platform::new(DesignConfig::with_channels(3, SpeedBin::Ddr4_1600));
        p.inject_channel_panic(1);
        let mut s =
            Session::pooled(p, Arc::new(RunPool::new(2)), SessionLimits::default(), 1);
        let r = s.handle_line("CHCFG 0:SEQ,BURST=4,BATCH=32 1:SEQ,BURST=4,BATCH=32 \
                               2:SEQ,BURST=4,BATCH=32");
        assert!(r.starts_with("OK CHCFG"), "{r}");
        let r = s.handle_line("RUNMIX");
        assert!(r.starts_with("OK RUNMIX CHANNELS=3 OK=2"), "{r}");
        assert!(r.contains("CH1=ERR[") && r.contains("panicked"), "{r}");
        assert!(s.handle_line("STATS 0").starts_with("OK"), "survivor stats readable");
        assert!(s.handle_line("STATS 1").starts_with("ERR"), "failed channel has no stats");
        // the channel was reset; the next mix is fully clean
        assert!(s.handle_line("RUNMIX").contains("OK=3"));
    }

    #[test]
    fn streaming_emits_heartbeats_on_pooled_runs_only_when_enabled() {
        let mut s = pooled(1, 1, SessionLimits::UNLIMITED);
        s.set_stream_interval(Duration::from_millis(1));
        s.handle_line("CFG 0 OP=R ADDR=RND SEED=3 BURST=1 BATCH=60000");
        // streaming off: no heartbeats
        let mut beats = Vec::new();
        let resp = s.handle_with_progress(&parse_request("RUN 0").unwrap(), &mut |p| {
            beats.push(render_response(&p));
        });
        assert!(render_response(&resp).starts_with("OK RUN CH=0"), "run succeeded");
        assert!(beats.is_empty(), "no heartbeats without STREAM ON: {beats:?}");
        // streaming on: heartbeat lines precede the reply
        assert_eq!(s.handle_line("STREAM ON"), "OK STREAM ON");
        let mut beats = Vec::new();
        let resp = s.handle_with_progress(&parse_request("RUN 0").unwrap(), &mut |p| {
            beats.push(render_response(&p));
        });
        assert!(render_response(&resp).starts_with("OK RUN CH=0"), "run succeeded");
        assert!(!beats.is_empty(), "a 1ms cadence must tick during a 60k-txn batch");
        assert!(beats[0].starts_with("STREAM RUN CH=0 MS="), "{}", beats[0]);
        assert_eq!(s.handle_line("STREAM OFF"), "OK STREAM OFF");
    }

    #[test]
    fn metrics_and_tracedump_flow_over_a_pooled_session() {
        let mut s = pooled(2, 1, SessionLimits::UNLIMITED);
        // before any run (or without a window) METRICS is a named error
        assert!(s.handle_line("METRICS 0").starts_with("ERR no telemetry"));
        s.handle_line("CFG 0 OP=R ADDR=SEQ BURST=8 BATCH=256 TELEM=64");
        // first TRACEDUMP arms the ring and answers EVENTS=0
        assert_eq!(s.handle_line("TRACEDUMP 0"), "OK TRACEDUMP CH=0 EVENTS=0 DROPPED=0");
        assert!(s.handle_line("RUN 0").starts_with("OK RUN CH=0 TXNS=256"));
        let r = s.handle_line("METRICS 0");
        assert!(r.starts_with("OK METRICS CH=0 WINDOW=64"), "{r}");
        assert!(r.contains("DONE=1") && r.contains("LAST_START="), "{r}");
        // a run without a telemetry window leaves the channel series-less
        s.handle_line("CFG 1 OP=R ADDR=SEQ BURST=8 BATCH=64");
        s.handle_line("RUN 1");
        assert!(s.handle_line("METRICS 1").starts_with("ERR no telemetry"));
        // the armed trace captured the run; the dump is non-destructive
        let dump = s.handle_line("TRACEDUMP 0");
        assert!(dump.lines().next().unwrap().starts_with("TRACE "), "{dump}");
        let last = dump.lines().last().unwrap();
        assert!(last.starts_with("OK TRACEDUMP CH=0 EVENTS="), "{dump}");
        assert!(!last.contains("EVENTS=0"), "{last}");
        assert_eq!(s.handle_line("TRACEDUMP 0"), dump, "dump must be non-destructive");
        assert!(s.handle_line("METRICS 9").starts_with("ERR channel 9 out of range"));
        assert!(s.handle_line("TRACEDUMP 9").starts_with("ERR channel 9 out of range"));
    }

    #[test]
    fn audit_flow_certifies_clean_runs_and_flags_mid_session_arming() {
        let mut s = pooled(2, 1, SessionLimits::UNLIMITED);
        s.handle_line("CFG 0 OP=R ADDR=SEQ BURST=8 BATCH=256");
        // arming before any command issues: complete stream, vacuously clean
        assert_eq!(
            s.handle_line("AUDIT 0"),
            "OK AUDIT CH=0 EVENTS=0 DROPPED=0 VIOLATIONS=0 STATUS=CLEAN"
        );
        assert!(s.handle_line("RUN 0").starts_with("OK RUN CH=0"));
        let r = s.handle_line("AUDIT 0");
        assert!(r.starts_with("OK AUDIT CH=0 EVENTS="), "{r}");
        assert!(!r.contains("EVENTS=0 "), "{r}");
        assert!(r.ends_with("VIOLATIONS=0 STATUS=CLEAN"), "{r}");
        // arming a channel that already issued commands can never certify
        // clean: the auditor saw a truncated prefix, so it says so
        s.handle_line("CFG 1 OP=R ADDR=SEQ BURST=8 BATCH=64");
        assert!(s.handle_line("RUN 1").starts_with("OK RUN CH=1"));
        let r = s.handle_line("AUDIT 1");
        assert!(r.ends_with("STATUS=TRUNCATED"), "{r}");
        assert!(s.handle_line("AUDIT 9").starts_with("ERR channel 9 out of range"));
    }

    #[test]
    fn streaming_heartbeats_carry_live_telemetry_when_window_set() {
        let mut s = pooled(1, 1, SessionLimits::UNLIMITED);
        s.set_stream_interval(Duration::from_millis(1));
        s.handle_line("CFG 0 OP=R ADDR=RND SEED=3 BURST=1 BATCH=60000 TELEM=64");
        assert_eq!(s.handle_line("STREAM ON"), "OK STREAM ON");
        let mut beats = Vec::new();
        let resp = s.handle_with_progress(&parse_request("RUN 0").unwrap(), &mut |p| {
            beats.push(render_response(&p));
        });
        assert!(render_response(&resp).starts_with("OK RUN CH=0"), "run succeeded");
        assert!(!beats.is_empty(), "a 1ms cadence must tick during a 60k-txn batch");
        for b in &beats {
            assert!(b.starts_with("STREAM RUN CH=0 MS="), "pinned prefix survives: {b}");
        }
        assert!(
            beats.iter().any(|b| b.contains(" bw=") && b.contains(" qd=") && b.contains(" p99=")),
            "at least one heartbeat carries the live window: {beats:?}"
        );
        // the same series is then queryable as a METRICS snapshot
        let r = s.handle_line("METRICS 0");
        assert!(r.starts_with("OK METRICS CH=0 WINDOW=64"), "{r}");
        assert!(r.contains("DONE=1"), "{r}");
    }

    #[test]
    fn serve_stream_interleaves_heartbeats_before_the_reply() {
        let mut s = pooled(1, 1, SessionLimits::UNLIMITED);
        s.set_stream_interval(Duration::from_millis(1));
        let input = b"STREAM ON\nCFG 0 OP=R ADDR=RND SEED=3 BURST=1 BATCH=60000\nRUN 0\nQUIT\n"
            .to_vec();
        let mut out = Vec::new();
        serve_stream(&mut s, std::io::Cursor::new(input), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "OK STREAM ON");
        let beats = lines.iter().filter(|l| l.starts_with("STREAM RUN CH=0 MS=")).count();
        assert!(beats > 0, "heartbeats on the wire: {text}");
        // the heartbeats sit between CFG's reply and RUN's reply
        assert!(lines[1].starts_with("OK CFG CH=0"), "{}", lines[1]);
        assert!(lines[2 + beats].starts_with("OK RUN CH=0"), "{text}");
        assert_eq!(*lines.last().unwrap(), "OK BYE");
    }

    #[test]
    fn chcfg_is_atomic_under_limits() {
        let limits = SessionLimits { max_batch: 100, ..SessionLimits::default() };
        let mut s = pooled(2, 1, limits);
        let r = s.handle_line("CHCFG 0:SEQ,BURST=4,BATCH=50 1:SEQ,BURST=4,BATCH=2000");
        assert!(r.starts_with("ERR LIMIT_BATCH:"), "{r}");
        // channel 0 kept its default staging (batch 1024), proving the
        // rejected command didn't half-apply
        let r = s.handle_line("RUN 0");
        assert!(r.starts_with("OK RUN CH=0 TXNS=1024"), "{r}");
    }
}
