//! The concurrent multi-session bench server.
//!
//! [`BenchServer`] turns the host protocol into a service many clients
//! can hammer simultaneously: a TCP accept loop hands each connection a
//! [`Session`] with its own isolated [`Platform`] (config, staged channel
//! mixes, last-run stats — one client's commands can never perturb
//! another's counters), while actual batch execution dispatches to one
//! shared bounded [`RunPool`], so K sessions compete for a fixed number
//! of executor threads instead of spawning K×channels of their own.
//!
//! Admission control is strict: at most `max_sessions` concurrent
//! sessions; a connection beyond that is answered with one
//! `ERR SERVER_FULL: ...` line and closed, so a scripted client can
//! back off and retry. Each admitted session gets a monotonically
//! increasing id (the thread name and log label), per-session
//! [`SessionLimits`], and its own session thread. Cleanup is
//! guard-based: a client disconnect, an I/O error or even a panic in
//! the session thread releases the admission slot, and a panicking
//! *batch* is already contained one level lower (the pool's
//! `catch_unwind`) — it fails that session's run, never the server.
//!
//! Shutdown is cooperative: [`BenchServer::shutdown_handle`] yields a
//! [`ShutdownHandle`] whose `signal()` flips a flag and self-connects to
//! unblock the accept loop; SIGTERM works too (the CI smoke gate kills
//! the process directly).

use std::io::{self, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::config::{DesignConfig, SessionLimits};
use crate::platform::{Platform, RunPool};

use super::session::{serve_stream, Session};

/// Server-level knobs (`ddr4bench serve --workers --max-sessions ...`).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Executor threads in the shared [`RunPool`].
    pub workers: usize,
    /// Most concurrent client sessions admitted; further connections are
    /// answered `ERR SERVER_FULL` and closed.
    pub max_sessions: usize,
    /// Resource limits handed to every session.
    pub limits: SessionLimits,
    /// How often a session's pooled run re-polls the pool and (with
    /// `STREAM ON`) emits a heartbeat line. Smoke tests lower this so a
    /// short run still produces observable heartbeats.
    pub stream_interval: Duration,
}

impl Default for ServerConfig {
    /// Workers default to the machine's parallelism minus one (the
    /// accept loop and session threads need a core too), sessions to 8,
    /// the heartbeat/poll interval to 100 ms.
    fn default() -> Self {
        let workers = std::thread::available_parallelism()
            .map(|n| n.get().saturating_sub(1).max(1))
            .unwrap_or(2);
        Self {
            workers,
            max_sessions: 8,
            limits: SessionLimits::default(),
            stream_interval: Duration::from_millis(100),
        }
    }
}

/// Cooperative shutdown for a running [`BenchServer`].
pub struct ShutdownHandle {
    flag: Arc<AtomicBool>,
    addr: SocketAddr,
}

impl ShutdownHandle {
    /// Ask the server to stop: sets the flag, then self-connects so the
    /// blocking accept wakes up and observes it. Already-admitted
    /// sessions run to completion on their own threads.
    pub fn signal(&self) {
        self.flag.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
    }
}

/// Decrements the active-session count when the session thread exits —
/// by any path, including a panic — so a dying session always releases
/// its admission slot.
struct ActiveGuard(Arc<AtomicUsize>);

impl Drop for ActiveGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The concurrent bench server: one isolated platform per client
/// session, one shared worker pool for execution.
pub struct BenchServer {
    listener: TcpListener,
    design: DesignConfig,
    cfg: ServerConfig,
    pool: Arc<RunPool>,
    active: Arc<AtomicUsize>,
    next_id: AtomicU64,
    shutdown: Arc<AtomicBool>,
}

impl BenchServer {
    /// Bind to `addr` (e.g. `127.0.0.1:5557`, or port 0 for an ephemeral
    /// port) after validating the design and limits up front, and spawn
    /// the shared worker pool.
    pub fn bind(design: DesignConfig, cfg: ServerConfig, addr: &str) -> io::Result<Self> {
        let invalid = |e: String| io::Error::new(io::ErrorKind::InvalidInput, e);
        design.validate().map_err(|e| invalid(e.to_string()))?;
        cfg.limits.validate().map_err(|e| invalid(e.to_string()))?;
        if cfg.max_sessions == 0 {
            return Err(invalid("max_sessions must be >= 1".into()));
        }
        let listener = TcpListener::bind(addr)?;
        let pool = Arc::new(RunPool::new(cfg.workers));
        Ok(Self {
            listener,
            design,
            cfg,
            pool,
            active: Arc::new(AtomicUsize::new(0)),
            next_id: AtomicU64::new(1),
            shutdown: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// A handle that can stop the accept loop from another thread.
    pub fn shutdown_handle(&self) -> io::Result<ShutdownHandle> {
        Ok(ShutdownHandle { flag: Arc::clone(&self.shutdown), addr: self.local_addr()? })
    }

    /// Currently admitted sessions.
    pub fn active_sessions(&self) -> usize {
        self.active.load(Ordering::SeqCst)
    }

    /// Run the accept loop until shut down. Per-connection failures
    /// (accept errors, session I/O errors, panicking batches) are logged
    /// and never tear the listener down.
    pub fn run(self) -> io::Result<()> {
        eprintln!(
            "ddr4bench bench server listening on {} ({} worker(s), max {} session(s))",
            self.local_addr()?,
            self.pool.workers(),
            self.cfg.max_sessions
        );
        for stream in self.listener.incoming() {
            if self.shutdown.load(Ordering::SeqCst) {
                break;
            }
            match stream {
                Ok(s) => self.spawn_session(s),
                Err(e) => eprintln!("ddr4bench: accept error: {e}"),
            }
        }
        Ok(())
    }

    fn spawn_session(&self, stream: TcpStream) {
        // optimistic admission: claim a slot, give it back if over
        let prev = self.active.fetch_add(1, Ordering::SeqCst);
        if prev >= self.cfg.max_sessions {
            self.active.fetch_sub(1, Ordering::SeqCst);
            let mut stream = stream;
            let _ = writeln!(
                stream,
                "ERR SERVER_FULL: {prev} session(s) active (max {})",
                self.cfg.max_sessions
            );
            return;
        }
        let guard = ActiveGuard(Arc::clone(&self.active));
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let design = self.design.clone();
        let limits = self.cfg.limits;
        let interval = self.cfg.stream_interval;
        let pool = Arc::clone(&self.pool);
        let spawned = std::thread::Builder::new().name(format!("session-{id}")).spawn(move || {
            // the guard rides the session thread: any exit releases the
            // admission slot
            let _guard = guard;
            let mut session = Session::pooled(Platform::new(design), pool, limits, id);
            session.set_stream_interval(interval);
            if let Err(e) = serve_session(&mut session, &stream) {
                eprintln!("ddr4bench: session {id} ended with error: {e}");
            }
        });
        // a failed spawn drops the (moved) closure — and with it the
        // guard — so the slot is still released
        if let Err(e) = spawned {
            eprintln!("ddr4bench: failed to spawn session thread: {e}");
        }
    }
}

fn serve_session(session: &mut Session, stream: &TcpStream) -> io::Result<()> {
    let reader = io::BufReader::new(stream.try_clone()?);
    let writer = stream.try_clone()?;
    serve_stream(session, reader, writer)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SpeedBin;
    use std::io::{BufRead, BufReader};
    use std::time::Duration;

    fn connect(addr: SocketAddr) -> (BufReader<TcpStream>, TcpStream) {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        (BufReader::new(stream.try_clone().unwrap()), stream)
    }

    fn roundtrip(r: &mut BufReader<TcpStream>, w: &mut TcpStream, line: &str) -> String {
        writeln!(w, "{line}").unwrap();
        let mut resp = String::new();
        r.read_line(&mut resp).unwrap();
        resp.trim_end().to_string()
    }

    #[test]
    fn server_admits_isolates_and_rejects_beyond_capacity() {
        let design = DesignConfig::with_channels(2, SpeedBin::Ddr4_1600);
        let cfg = ServerConfig { workers: 1, max_sessions: 1, ..ServerConfig::default() };
        let server = BenchServer::bind(design, cfg, "127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap();
        let shutdown = server.shutdown_handle().unwrap();
        let serving = std::thread::spawn(move || server.run().unwrap());

        let (mut r1, mut w1) = connect(addr);
        // reading the reply proves session 1 is admitted before the
        // second connection races in
        let info = roundtrip(&mut r1, &mut w1, "INFO");
        assert!(info.starts_with("OK CHANNELS=2"), "{info}");

        let (mut r2, _w2) = connect(addr);
        let mut line = String::new();
        r2.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR SERVER_FULL:"), "{line}");

        // session 1 still works end to end while 2 was bounced
        let run = roundtrip(&mut r1, &mut w1, "CFG 0 OP=R BURST=4 BATCH=64");
        assert!(run.starts_with("OK CFG CH=0"), "{run}");
        let run = roundtrip(&mut r1, &mut w1, "RUN 0");
        assert!(run.starts_with("OK RUN CH=0 TXNS=64"), "{run}");
        assert_eq!(roundtrip(&mut r1, &mut w1, "QUIT"), "OK BYE");
        drop((r1, w1));

        // once the slot frees, a new client gets in (poll for the
        // session thread's guard to release)
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            let (mut r3, mut w3) = connect(addr);
            let mut line = String::new();
            writeln!(w3, "INFO").unwrap();
            r3.read_line(&mut line).unwrap();
            if line.starts_with("OK CHANNELS=2") {
                break;
            }
            assert!(line.starts_with("ERR SERVER_FULL:"), "{line}");
            assert!(std::time::Instant::now() < deadline, "slot never released");
            std::thread::sleep(Duration::from_millis(20));
        }

        shutdown.signal();
        serving.join().unwrap();
    }

    #[test]
    fn bind_validates_design_limits_and_capacity() {
        let bad_design = DesignConfig::with_channels(4, SpeedBin::Ddr4_1600);
        assert!(BenchServer::bind(bad_design, ServerConfig::default(), "127.0.0.1:0").is_err());
        let design = DesignConfig::single_channel(SpeedBin::Ddr4_1600);
        let cfg = ServerConfig {
            limits: SessionLimits { max_batch: 0, ..SessionLimits::default() },
            ..ServerConfig::default()
        };
        assert!(BenchServer::bind(design.clone(), cfg, "127.0.0.1:0").is_err());
        let cfg = ServerConfig { max_sessions: 0, ..ServerConfig::default() };
        assert!(BenchServer::bind(design, cfg, "127.0.0.1:0").is_err());
    }
}
