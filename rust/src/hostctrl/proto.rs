//! The typed host-protocol surface: one parse path, one render path.
//!
//! Every transport — the in-memory UART loop, [`crate::hostctrl::serve_tcp`],
//! and the concurrent [`crate::hostctrl::server`] — speaks the same
//! line-oriented ASCII wire format, but none of them interprets command
//! strings themselves: a line parses into a [`Request`] here
//! ([`parse_request`]), the session core maps it to a [`Response`], and
//! [`render_response`] produces the exact reply bytes. Protocol behaviour
//! is therefore specified (and tested) exactly once; the transports are
//! thin byte shovels.
//!
//! [`COMMANDS`] is the machine-readable command reference — one entry per
//! [`Request`] variant with syntax, reply shape and error cases. The
//! `HELP` reply and the README's protocol table are both derived from it
//! (a test pins the README rows to the table), so the three cannot drift
//! apart.
//!
//! Wire compatibility is a contract: the rendered `OK`/`ERR` lines are
//! byte-identical to the pre-typed `handle_line` implementation, pinned
//! by `rust/tests/host_protocol.rs`.

use crate::config::{
    format_channel_spec, format_pattern_config, parse_channel_spec, parse_pattern_config,
    PatternConfig, SpeedBin,
};
use crate::obs::{TelemetrySnapshot, TraceEvent};
use crate::stats::BatchStats;

/// A parsed protocol command. Channel *syntax* is validated here; channel
/// *range* (and per-session resource limits) are session state and are
/// checked by [`crate::hostctrl::Session`].
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// `INFO` — design summary.
    Info,
    /// `CFG <ch> KEY=VALUE ...` — stage a pattern on one channel.
    Cfg { ch: usize, cfg: Box<PatternConfig> },
    /// `CHCFG <N:TOKENS,...> ...` — stage a heterogeneous mix in one line.
    ChCfg { specs: Vec<(usize, PatternConfig)> },
    /// `RUN <ch>` — run one channel's staged pattern.
    Run { ch: usize },
    /// `RUNALL` — run every channel's staged pattern, serially.
    RunAll,
    /// `RUNMIX` — run every channel's staged pattern concurrently.
    RunMix,
    /// `STATS <ch>` — full counters of the channel's last batch.
    Stats { ch: usize },
    /// `PATTERNS` — list the access-pattern engine's address modes.
    Patterns,
    /// `MAPPINGS` — list the address-mapping policies.
    Mappings,
    /// `SCHEDS` — list the scheduler/page policies.
    Scheds,
    /// `RESET <ch>` — clear one channel's staged config and stats.
    Reset { ch: usize },
    /// `STREAM ON|OFF` — opt into `STREAM` progress lines during runs.
    Stream { on: bool },
    /// `METRICS <ch>` — telemetry snapshot of the channel's last run.
    Metrics { ch: usize },
    /// `TRACEDUMP <ch>` — arm (first call) / dump the DRAM command trace.
    TraceDump { ch: usize },
    /// `AUDIT <ch>` — arm (first call) / summarize the JEDEC protocol audit.
    Audit { ch: usize },
    /// `HELP` — list the commands (derived from [`COMMANDS`]).
    Help,
    /// `QUIT` — end the session.
    Quit,
}

impl Request {
    /// The wire-format command word (the key into [`COMMANDS`]).
    pub fn name(&self) -> &'static str {
        match self {
            Request::Info => "INFO",
            Request::Cfg { .. } => "CFG",
            Request::ChCfg { .. } => "CHCFG",
            Request::Run { .. } => "RUN",
            Request::RunAll => "RUNALL",
            Request::RunMix => "RUNMIX",
            Request::Stats { .. } => "STATS",
            Request::Patterns => "PATTERNS",
            Request::Mappings => "MAPPINGS",
            Request::Scheds => "SCHEDS",
            Request::Reset { .. } => "RESET",
            Request::Stream { .. } => "STREAM",
            Request::Metrics { .. } => "METRICS",
            Request::TraceDump { .. } => "TRACEDUMP",
            Request::Audit { .. } => "AUDIT",
            Request::Help => "HELP",
            Request::Quit => "QUIT",
        }
    }
}

/// One channel's cell in a `RUNMIX` reply.
#[derive(Debug, Clone)]
pub enum MixCell {
    /// The channel's batch succeeded.
    Ok { ch: usize, gbs: f64 },
    /// The channel's batch failed; `reason` is rendered with its
    /// whitespace collapsed to `_` so the reply stays one token per cell.
    Err { ch: usize, reason: String },
}

impl MixCell {
    /// The cell's wire token (`CH<i>_GBS=<f>` / `CH<i>=ERR[reason]`) —
    /// also used to fold the all-channels-failed case into one `ERR`
    /// line.
    pub fn render(&self) -> String {
        match self {
            MixCell::Ok { ch, gbs } => format!("CH{ch}_GBS={gbs:.3}"),
            MixCell::Err { ch, reason } => {
                // single-line protocol: collapse the reason's whitespace
                // so the cell stays one token
                let reason = reason.split_whitespace().collect::<Vec<_>>().join("_");
                format!("CH{ch}=ERR[{reason}]")
            }
        }
    }
}

/// A typed protocol reply. [`render_response`] is the single place the
/// wire bytes are produced.
#[derive(Debug, Clone)]
pub enum Response {
    /// `OK CHANNELS=.. SPEED=.. ...`
    Info {
        channels: usize,
        speed: SpeedBin,
        axi_mhz: f64,
        phy_mhz: f64,
        axi_bits: u32,
        xla: bool,
    },
    /// `OK CFG CH=<ch> <echo>`
    Cfg { ch: usize, cfg: Box<PatternConfig> },
    /// `OK CHCFG <echo> ...`
    ChCfg { specs: Vec<(usize, PatternConfig)> },
    /// `OK RUN CH=<ch> TXNS=<n> CYCLES=<n>`
    Run { ch: usize, txns: u64, cycles: u64 },
    /// `OK RUNALL CHANNELS=<n> AGG_GBS=<f>`
    RunAll { channels: usize, agg_gbs: f64 },
    /// `OK RUNMIX CHANNELS=<n> OK=<n> AGG_GBS=<f> <cells>`
    RunMix { channels: usize, ok: usize, agg_gbs: f64, cells: Vec<MixCell> },
    /// `OK CH=<ch> RD_TXNS=.. ..` — the full counter dump.
    Stats { ch: usize, stats: Box<BatchStats> },
    /// `OK PATTERNS ...` (the fixed address-mode list).
    Patterns,
    /// `OK MAPPINGS <names>` (the session appends `CUSTOM`).
    Mappings { names: Vec<String> },
    /// `OK SCHEDS <names>`
    Scheds { names: Vec<String> },
    /// `OK RESET`
    Reset,
    /// `OK STREAM ON|OFF`
    Stream { on: bool },
    /// `OK METRICS CH=<ch> WINDOW=<w> CLOSED=<n> DROPPED=<n> DONE=<0|1>`
    /// plus the last closed window's fields when one exists. All raw
    /// integers (bytes, AXI cycles, counts) — unit conversion is a
    /// client concern, and integers keep the line engine-identical.
    Metrics { ch: usize, snapshot: TelemetrySnapshot },
    /// `TRACE <cycle> <ch> <cmd> <bg> <bank> <row>` data lines followed
    /// by the `OK TRACEDUMP CH=<ch> EVENTS=<n> DROPPED=<n>` terminal —
    /// like heartbeats, data lines precede the reply so clients read
    /// until the `OK`/`ERR` line.
    TraceDump { ch: usize, events: Vec<TraceEvent>, dropped: u64 },
    /// `OK AUDIT CH=<ch> EVENTS=<n> DROPPED=<n> VIOLATIONS=<n> STATUS=<s>`
    /// — one-line verdict of the channel's armed JEDEC protocol auditor
    /// (first call arms it and answers `EVENTS=0 ... STATUS=CLEAN` or
    /// `STATUS=TRUNCATED` when armed after commands already issued).
    Audit { ch: usize, events: u64, dropped: u64, violations: u64, status: String },
    /// `OK COMMANDS: ...` (derived from [`COMMANDS`]).
    Help,
    /// `OK BYE`
    Bye,
    /// `STREAM <label> MS=<n>` — mid-run progress heartbeat (only emitted
    /// while the session has `STREAM ON`; never `OK`/`ERR`-prefixed, so
    /// streaming clients skip `STREAM `-prefixed lines until the reply).
    /// With live telemetry attached the line is enriched in place:
    /// `STREAM <label> MS=<n> bw=<gbs> qd=<n> p99=<ns>` — appended after
    /// the pinned prefix, so pre-telemetry clients keep parsing.
    Progress { label: String, ms: u64, live: Option<ProgressLive> },
    /// `ERR <reason>`
    Err(String),
}

/// Live telemetry payload of an enriched `STREAM` heartbeat, derived
/// from the running batch's most recently closed window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProgressLive {
    /// Window bandwidth, GB/s.
    pub bw_gbs: f64,
    /// In-flight transactions at window close.
    pub qd: u64,
    /// Worse of the window's read/write p99 latencies, nanoseconds.
    pub p99_ns: f64,
}

/// One row of the command reference: syntax, reply shape, error cases.
/// The `HELP` reply and the README protocol table derive from this.
#[derive(Debug, Clone, Copy)]
pub struct CommandInfo {
    /// Command word (matches [`Request::name`]).
    pub name: &'static str,
    /// Invocation syntax.
    pub syntax: &'static str,
    /// Reply shape on success.
    pub reply: &'static str,
    /// Error cases (`ERR <reason>` lines).
    pub errors: &'static str,
}

/// The command reference — exactly one entry per [`Request`] variant, in
/// `HELP` listing order (pinned by a test against [`Request::name`]).
pub const COMMANDS: &[CommandInfo] = &[
    CommandInfo {
        name: "INFO",
        syntax: "INFO",
        reply: "OK CHANNELS=<n> SPEED=<bin> AXI_MHZ=<f> PHY_MHZ=<f> AXI_BITS=<n> XLA=<0|1>",
        errors: "none",
    },
    CommandInfo {
        name: "CFG",
        syntax: "CFG <ch> KEY=VALUE ...",
        reply: "OK CFG CH=<ch> <canonical echo>",
        errors: "bad/missing channel; invalid pattern tokens; LIMIT_CHANNELS / LIMIT_BATCH",
    },
    CommandInfo {
        name: "CHCFG",
        syntax: "CHCFG <N:TOKENS,...> ...",
        reply: "OK CHCFG <N:echo> ...",
        errors: "no specs; duplicate/bad channel; invalid tokens; LIMIT_CHANNELS / LIMIT_BATCH",
    },
    CommandInfo {
        name: "RUN",
        syntax: "RUN <ch>",
        reply: "OK RUN CH=<ch> TXNS=<n> CYCLES=<n>",
        errors: "bad/missing channel; batch failure (deadlock guard, panic); LIMIT_QUEUE",
    },
    CommandInfo {
        name: "RUNALL",
        syntax: "RUNALL",
        reply: "OK RUNALL CHANNELS=<n> AGG_GBS=<f>  (legacy per-channel rate sum)",
        errors: "first failing channel aborts the loop; LIMIT_CHANNELS / LIMIT_QUEUE",
    },
    CommandInfo {
        name: "RUNMIX",
        syntax: "RUNMIX",
        reply: "OK RUNMIX CHANNELS=<n> OK=<n> AGG_GBS=<f> CH<i>_GBS=<f>|CH<i>=ERR[reason] ...",
        errors: "every channel failed; LIMIT_CHANNELS / LIMIT_QUEUE",
    },
    CommandInfo {
        name: "STATS",
        syntax: "STATS <ch>",
        reply: "OK CH=<ch> RD_TXNS=.. WR_TXNS=.. .. PWR_MW=<f>",
        errors: "bad/missing channel; no batch has run on this channel",
    },
    CommandInfo {
        name: "PATTERNS",
        syntax: "PATTERNS",
        reply: "OK PATTERNS SEQ RND STRIDE BANK CHASE PHASED",
        errors: "none",
    },
    CommandInfo {
        name: "MAPPINGS",
        syntax: "MAPPINGS",
        reply: "OK MAPPINGS <builtin policies> CUSTOM",
        errors: "none",
    },
    CommandInfo {
        name: "SCHEDS",
        syntax: "SCHEDS",
        reply: "OK SCHEDS <policies>",
        errors: "none",
    },
    CommandInfo {
        name: "RESET",
        syntax: "RESET <ch>",
        reply: "OK RESET",
        errors: "bad/missing channel",
    },
    CommandInfo {
        name: "STREAM",
        syntax: "STREAM ON|OFF",
        reply: "OK STREAM ON|OFF  (then STREAM <label> MS=<n> heartbeats during runs)",
        errors: "missing/unknown mode",
    },
    CommandInfo {
        name: "METRICS",
        syntax: "METRICS <ch>",
        reply: "OK METRICS CH=<ch> WINDOW=<w> CLOSED=<n> DROPPED=<n> DONE=<0|1> [LAST_START=.. \
                LAST_END=.. RD_BYTES=.. WR_BYTES=.. QD=.. OPEN_BANKS=.. ACTS=.. PRES=.. \
                REF_STALL=.. RD_P99=.. WR_P99=..]",
        errors: "bad/missing channel; no telemetry recorded (run with TELEM= or telemetry key)",
    },
    CommandInfo {
        name: "TRACEDUMP",
        syntax: "TRACEDUMP <ch>",
        reply: "TRACE <cycle> <ch> <cmd> <bg> <bank> <row> lines, then OK TRACEDUMP CH=<ch> \
                EVENTS=<n> DROPPED=<n>  (first call arms tracing and returns EVENTS=0)",
        errors: "bad/missing channel",
    },
    CommandInfo {
        name: "AUDIT",
        syntax: "AUDIT <ch>",
        reply: "OK AUDIT CH=<ch> EVENTS=<n> DROPPED=<n> VIOLATIONS=<n> STATUS=<CLEAN|TRUNCATED|\
                VIOLATIONS>  (first call arms the JEDEC protocol auditor; observation-only)",
        errors: "bad/missing channel",
    },
    CommandInfo {
        name: "HELP",
        syntax: "HELP",
        reply: "OK COMMANDS: <command list>",
        errors: "none",
    },
    CommandInfo {
        name: "QUIT",
        syntax: "QUIT",
        reply: "OK BYE  (the transport then closes the session)",
        errors: "none",
    },
];

fn parse_channel_tok(tok: Option<&str>) -> Result<usize, String> {
    tok.ok_or("missing channel index")?
        .parse()
        .map_err(|_| "channel must be an integer".to_string())
}

/// Parse one command line into a [`Request`]. The single parse path:
/// every transport feeds lines through here.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let mut toks = line.split_whitespace();
    let cmd = toks.next().unwrap_or("").to_ascii_uppercase();
    match cmd.as_str() {
        "" => Err("empty command".into()),
        "HELP" => Ok(Request::Help),
        "INFO" => Ok(Request::Info),
        "PATTERNS" => Ok(Request::Patterns),
        "MAPPINGS" => Ok(Request::Mappings),
        "SCHEDS" => Ok(Request::Scheds),
        "RUNALL" => Ok(Request::RunAll),
        "RUNMIX" => Ok(Request::RunMix),
        "QUIT" => Ok(Request::Quit),
        "CFG" => {
            let ch = parse_channel_tok(toks.next())?;
            let rest: Vec<&str> = toks.collect();
            let cfg = parse_pattern_config(&rest).map_err(|e| e.to_string())?;
            Ok(Request::Cfg { ch, cfg: Box::new(cfg) })
        }
        "CHCFG" => {
            let raw: Vec<&str> = toks.collect();
            if raw.is_empty() {
                return Err("CHCFG needs at least one N:TOKENS,... channel spec".into());
            }
            let mut specs = Vec::with_capacity(raw.len());
            for spec in raw {
                let (ch, cfg) = parse_channel_spec(spec).map_err(|e| e.to_string())?;
                if specs.iter().any(|(c, _)| *c == ch) {
                    return Err(format!("channel {ch} configured twice in one CHCFG"));
                }
                specs.push((ch, cfg));
            }
            Ok(Request::ChCfg { specs })
        }
        "RUN" => Ok(Request::Run { ch: parse_channel_tok(toks.next())? }),
        "STATS" => Ok(Request::Stats { ch: parse_channel_tok(toks.next())? }),
        "RESET" => Ok(Request::Reset { ch: parse_channel_tok(toks.next())? }),
        "METRICS" => Ok(Request::Metrics { ch: parse_channel_tok(toks.next())? }),
        "TRACEDUMP" => Ok(Request::TraceDump { ch: parse_channel_tok(toks.next())? }),
        "AUDIT" => Ok(Request::Audit { ch: parse_channel_tok(toks.next())? }),
        "STREAM" => match toks.next().map(str::to_ascii_uppercase).as_deref() {
            Some("ON") | Some("1") => Ok(Request::Stream { on: true }),
            Some("OFF") | Some("0") => Ok(Request::Stream { on: false }),
            _ => Err("STREAM needs ON or OFF".into()),
        },
        other => Err(format!("unknown command `{other}` (try HELP)")),
    }
}

/// Render a [`Request`] back to its canonical wire line (used by clients,
/// scripted drivers and the round-trip tests; `parse_request` of the
/// output reproduces the request).
pub fn render_request(req: &Request) -> String {
    match req {
        Request::Info
        | Request::RunAll
        | Request::RunMix
        | Request::Patterns
        | Request::Mappings
        | Request::Scheds
        | Request::Help
        | Request::Quit => req.name().to_string(),
        Request::Cfg { ch, cfg } => format!("CFG {ch} {}", format_pattern_config(cfg)),
        Request::ChCfg { specs } => {
            let cells: Vec<String> =
                specs.iter().map(|(ch, cfg)| format_channel_spec(*ch, cfg)).collect();
            format!("CHCFG {}", cells.join(" "))
        }
        Request::Run { ch } => format!("RUN {ch}"),
        Request::Stats { ch } => format!("STATS {ch}"),
        Request::Reset { ch } => format!("RESET {ch}"),
        Request::Stream { on } => format!("STREAM {}", if *on { "ON" } else { "OFF" }),
        Request::Metrics { ch } => format!("METRICS {ch}"),
        Request::TraceDump { ch } => format!("TRACEDUMP {ch}"),
        Request::Audit { ch } => format!("AUDIT {ch}"),
    }
}

/// Render a [`Response`] to its exact wire line. The single render path:
/// `OK`/`ERR` prefixes, field order and float precision all live here and
/// nowhere else.
pub fn render_response(resp: &Response) -> String {
    match resp {
        Response::Info { channels, speed, axi_mhz, phy_mhz, axi_bits, xla } => format!(
            "OK CHANNELS={channels} SPEED={speed} AXI_MHZ={axi_mhz:.0} PHY_MHZ={phy_mhz:.0} \
             AXI_BITS={axi_bits} XLA={}",
            u8::from(*xla)
        ),
        Response::Cfg { ch, cfg } => format!("OK CFG CH={ch} {}", format_pattern_config(cfg)),
        Response::ChCfg { specs } => {
            let cells: Vec<String> =
                specs.iter().map(|(ch, cfg)| format_channel_spec(*ch, cfg)).collect();
            format!("OK CHCFG {}", cells.join(" "))
        }
        Response::Run { ch, txns, cycles } => format!("OK RUN CH={ch} TXNS={txns} CYCLES={cycles}"),
        Response::RunAll { channels, agg_gbs } => {
            format!("OK RUNALL CHANNELS={channels} AGG_GBS={agg_gbs:.3}")
        }
        Response::RunMix { channels, ok, agg_gbs, cells } => {
            let cells: Vec<String> = cells.iter().map(MixCell::render).collect();
            format!(
                "OK RUNMIX CHANNELS={channels} OK={ok} AGG_GBS={agg_gbs:.3} {}",
                cells.join(" ")
            )
        }
        Response::Stats { ch, stats } => {
            let s = stats;
            let c = &s.counters;
            format!(
                "OK CH={ch} RD_TXNS={} WR_TXNS={} RD_BYTES={} WR_BYTES={} RD_CYCLES={} \
                 WR_CYCLES={} TOTAL_CYCLES={} RD_GBS={:.3} WR_GBS={:.3} TOT_GBS={:.3} \
                 RD_LAT_NS={:.1} WR_LAT_NS={:.1} RD_P50_NS={:.1} RD_P95_NS={:.1} \
                 RD_P99_NS={:.1} WR_P50_NS={:.1} WR_P95_NS={:.1} WR_P99_NS={:.1} \
                 REFRESH_STALL={} MISMATCHES={} ENERGY_NJ={:.0} PJ_BIT={:.2} PWR_MW={:.1}",
                c.rd_txns,
                c.wr_txns,
                c.rd_bytes,
                c.wr_bytes,
                c.rd_cycles,
                c.wr_cycles,
                c.total_cycles,
                s.read_throughput_gbs(),
                s.write_throughput_gbs(),
                s.total_throughput_gbs(),
                s.read_latency_ns(),
                s.write_latency_ns(),
                s.read_latency_pct_ns(50.0),
                s.read_latency_pct_ns(95.0),
                s.read_latency_pct_ns(99.0),
                s.write_latency_pct_ns(50.0),
                s.write_latency_pct_ns(95.0),
                s.write_latency_pct_ns(99.0),
                c.refresh_stall_dram_cycles,
                c.mismatches,
                s.energy.total_nj(),
                s.pj_per_bit().unwrap_or(0.0),
                s.avg_power_mw(),
            )
        }
        Response::Patterns => "OK PATTERNS SEQ RND STRIDE BANK CHASE PHASED".into(),
        Response::Mappings { names } => format!("OK MAPPINGS {}", names.join(" ")),
        Response::Scheds { names } => format!("OK SCHEDS {}", names.join(" ")),
        Response::Reset => "OK RESET".into(),
        Response::Stream { on } => format!("OK STREAM {}", if *on { "ON" } else { "OFF" }),
        Response::Metrics { ch, snapshot } => {
            let s = snapshot;
            let mut line = format!(
                "OK METRICS CH={ch} WINDOW={} CLOSED={} DROPPED={} DONE={}",
                s.window,
                s.closed,
                s.dropped,
                u8::from(s.done)
            );
            if let Some(w) = &s.last {
                line.push_str(&format!(
                    " LAST_START={} LAST_END={} RD_BYTES={} WR_BYTES={} QD={} OPEN_BANKS={} \
                     ACTS={} PRES={} REF_STALL={} RD_P99={} WR_P99={}",
                    w.start,
                    w.end,
                    w.rd_bytes,
                    w.wr_bytes,
                    w.queue_depth,
                    w.open_banks,
                    w.acts,
                    w.pres,
                    w.refresh_stall,
                    w.rd_p99,
                    w.wr_p99
                ));
            }
            line
        }
        Response::TraceDump { ch, events, dropped } => {
            let mut out = String::new();
            for ev in events {
                out.push_str(&format!(
                    "TRACE {} {ch} {} {} {} {}\n",
                    ev.cycle,
                    ev.cmd.name(),
                    ev.bank_group,
                    ev.bank,
                    ev.row
                ));
            }
            out.push_str(&format!(
                "OK TRACEDUMP CH={ch} EVENTS={} DROPPED={dropped}",
                events.len()
            ));
            out
        }
        Response::Audit { ch, events, dropped, violations, status } => format!(
            "OK AUDIT CH={ch} EVENTS={events} DROPPED={dropped} VIOLATIONS={violations} \
             STATUS={status}"
        ),
        Response::Help => {
            let names: Vec<&str> = COMMANDS.iter().map(|c| c.name).collect();
            format!("OK COMMANDS: {}", names.join(" "))
        }
        Response::Bye => "OK BYE".into(),
        Response::Progress { label, ms, live } => {
            let mut line = format!("STREAM {label} MS={ms}");
            if let Some(l) = live {
                line.push_str(&format!(" bw={:.2} qd={} p99={:.0}", l.bw_gbs, l.qd, l.p99_ns));
            }
            line
        }
        Response::Err(reason) => format!("ERR {reason}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One sample per [`Request`] variant (the exhaustiveness anchor:
    /// extending the enum without extending this list fails the
    /// `commands_table_is_exhaustive` test via `Request::name`).
    fn samples() -> Vec<Request> {
        let cfg = Box::new(PatternConfig::seq_read_burst(8, 256));
        vec![
            Request::Info,
            Request::Cfg { ch: 0, cfg: cfg.clone() },
            Request::ChCfg {
                specs: vec![
                    (0, PatternConfig::seq_read_burst(32, 512)),
                    (2, PatternConfig::bank_conflict_read(1, 64, 1)),
                ],
            },
            Request::Run { ch: 1 },
            Request::RunAll,
            Request::RunMix,
            Request::Stats { ch: 2 },
            Request::Patterns,
            Request::Mappings,
            Request::Scheds,
            Request::Reset { ch: 0 },
            Request::Stream { on: true },
            Request::Metrics { ch: 0 },
            Request::TraceDump { ch: 1 },
            Request::Audit { ch: 0 },
            Request::Help,
            Request::Quit,
        ]
    }

    #[test]
    fn every_request_round_trips_through_the_wire_format() {
        for req in samples() {
            let line = render_request(&req);
            let back = parse_request(&line).unwrap_or_else(|e| panic!("`{line}`: {e}"));
            assert_eq!(back, req, "round trip of `{line}`");
        }
    }

    #[test]
    fn commands_table_is_exhaustive_and_in_help_order() {
        let names: Vec<&str> = samples().iter().map(Request::name).collect();
        assert_eq!(names.len(), COMMANDS.len(), "one COMMANDS row per Request variant");
        for name in &names {
            assert!(COMMANDS.iter().any(|c| c.name == *name), "{name} missing from COMMANDS");
        }
        let help = render_response(&Response::Help);
        for c in COMMANDS {
            assert!(help.contains(c.name), "HELP omits {}: {help}", c.name);
        }
        // the table's syntax column starts with the command word, so the
        // generated docs cannot mislabel a row
        for c in COMMANDS {
            assert!(c.syntax.starts_with(c.name), "{}: syntax `{}`", c.name, c.syntax);
        }
    }

    #[test]
    fn parse_rejects_malformed_lines_with_the_legacy_reasons() {
        assert_eq!(parse_request("").unwrap_err(), "empty command");
        assert_eq!(parse_request("   ").unwrap_err(), "empty command");
        assert_eq!(parse_request("FROB 1").unwrap_err(), "unknown command `FROB` (try HELP)");
        assert_eq!(parse_request("RUN").unwrap_err(), "missing channel index");
        assert_eq!(parse_request("RUN x").unwrap_err(), "channel must be an integer");
        assert_eq!(
            parse_request("CHCFG").unwrap_err(),
            "CHCFG needs at least one N:TOKENS,... channel spec"
        );
        assert_eq!(
            parse_request("CHCFG 0:SEQ 0:RND").unwrap_err(),
            "channel 0 configured twice in one CHCFG"
        );
        assert!(parse_request("CFG 0 BURST=4000").is_err(), "invalid pattern tokens");
        assert!(parse_request("STREAM").is_err());
        assert!(parse_request("STREAM maybe").is_err());
    }

    #[test]
    fn commands_are_case_insensitive() {
        assert_eq!(parse_request("info").unwrap(), Request::Info);
        assert_eq!(parse_request("Quit").unwrap(), Request::Quit);
        assert_eq!(parse_request("stream off").unwrap(), Request::Stream { on: false });
    }

    #[test]
    fn render_response_produces_the_exact_wire_lines() {
        assert_eq!(
            render_response(&Response::Run { ch: 0, txns: 512, cycles: 9000 }),
            "OK RUN CH=0 TXNS=512 CYCLES=9000"
        );
        assert_eq!(
            render_response(&Response::RunAll { channels: 3, agg_gbs: 12.3456 }),
            "OK RUNALL CHANNELS=3 AGG_GBS=12.346"
        );
        assert_eq!(render_response(&Response::Err("boom".into())), "ERR boom");
        assert_eq!(render_response(&Response::Bye), "OK BYE");
        assert_eq!(render_response(&Response::Reset), "OK RESET");
        assert_eq!(
            render_response(&Response::Progress { label: "RUN CH=0".into(), ms: 250, live: None }),
            "STREAM RUN CH=0 MS=250"
        );
        // live telemetry appends after the pinned prefix, never reorders it
        let live = ProgressLive { bw_gbs: 6.275, qd: 8, p99_ns: 211.4 };
        assert_eq!(
            render_response(&Response::Progress {
                label: "RUN CH=0".into(),
                ms: 250,
                live: Some(live),
            }),
            "STREAM RUN CH=0 MS=250 bw=6.28 qd=8 p99=211"
        );
        let mix = Response::RunMix {
            channels: 2,
            ok: 1,
            agg_gbs: 1.0,
            cells: vec![
                MixCell::Ok { ch: 0, gbs: 1.0 },
                MixCell::Err { ch: 1, reason: "it went  very wrong".into() },
            ],
        };
        assert_eq!(
            render_response(&mix),
            "OK RUNMIX CHANNELS=2 OK=1 AGG_GBS=1.000 CH0_GBS=1.000 CH1=ERR[it_went_very_wrong]"
        );
    }

    #[test]
    fn metrics_and_tracedump_render_the_documented_wire_shapes() {
        use crate::obs::{TelemetryWindow, TraceCmd};
        // empty snapshot: headline fields only
        let empty = TelemetrySnapshot { window: 4096, ..TelemetrySnapshot::default() };
        assert_eq!(
            render_response(&Response::Metrics { ch: 1, snapshot: empty }),
            "OK METRICS CH=1 WINDOW=4096 CLOSED=0 DROPPED=0 DONE=0"
        );
        // with a last window: every field lands, raw integers
        let snap = TelemetrySnapshot {
            window: 100,
            closed: 3,
            dropped: 1,
            done: true,
            last: Some(TelemetryWindow {
                start: 200,
                end: 300,
                rd_bytes: 4096,
                wr_bytes: 128,
                queue_depth: 5,
                open_banks: 2,
                acts: 7,
                pres: 6,
                refresh_stall: 40,
                rd_p50: 16,
                rd_p99: 64,
                wr_p50: 0,
                wr_p99: 0,
            }),
        };
        assert_eq!(
            render_response(&Response::Metrics { ch: 0, snapshot: snap }),
            "OK METRICS CH=0 WINDOW=100 CLOSED=3 DROPPED=1 DONE=1 LAST_START=200 LAST_END=300 \
             RD_BYTES=4096 WR_BYTES=128 QD=5 OPEN_BANKS=2 ACTS=7 PRES=6 REF_STALL=40 RD_P99=64 \
             WR_P99=0"
        );
        // trace dump: data lines precede the OK terminal
        let events = vec![
            TraceEvent { cycle: 40, cmd: TraceCmd::Act, bank_group: 1, bank: 5, row: 9 },
            TraceEvent { cycle: 44, cmd: TraceCmd::Rd, bank_group: 1, bank: 5, row: 9 },
        ];
        assert_eq!(
            render_response(&Response::TraceDump { ch: 2, events, dropped: 3 }),
            "TRACE 40 2 ACT 1 5 9\nTRACE 44 2 RD 1 5 9\nOK TRACEDUMP CH=2 EVENTS=2 DROPPED=3"
        );
        // arming call: no events yet, still a well-formed OK line
        let armed = render_response(&Response::TraceDump { ch: 0, events: vec![], dropped: 0 });
        assert_eq!(armed, "OK TRACEDUMP CH=0 EVENTS=0 DROPPED=0");
    }

    #[test]
    fn audit_response_renders_one_verdict_line() {
        let r = Response::Audit {
            ch: 1,
            events: 512,
            dropped: 0,
            violations: 2,
            status: "VIOLATIONS".into(),
        };
        assert_eq!(
            render_response(&r),
            "OK AUDIT CH=1 EVENTS=512 DROPPED=0 VIOLATIONS=2 STATUS=VIOLATIONS"
        );
    }

    #[test]
    fn readme_protocol_table_documents_every_command() {
        // doc-sync: the README's host-protocol reference must carry one
        // table row per command, so adding a Request variant without
        // documenting it fails here
        let readme =
            std::fs::read_to_string(concat!(env!("CARGO_MANIFEST_DIR"), "/README.md")).unwrap();
        for cmd in COMMANDS {
            let row = format!("| `{}` |", cmd.name);
            assert!(readme.contains(&row), "README protocol table is missing a `{}` row", cmd.name);
        }
    }
}
