//! Host controller (§II-C): the run-time command interface.
//!
//! In the paper, a host PC drives the platform over UART: it configures
//! each traffic generator independently, launches batches, and reads the
//! performance counters back. This module implements that protocol over
//! byte-stream transports, layered so every transport speaks the *same*
//! API:
//!
//! - [`proto`] — the typed protocol surface: [`Request`] / [`Response`]
//!   enums with exactly one parse path ([`parse_request`]) and one
//!   render path ([`render_response`]), plus the [`COMMANDS`] reference
//!   table that `HELP` and the README are generated from.
//! - [`session`] — [`Session`]: per-client state (staged configs,
//!   last-run stats, [`SessionLimits`](crate::config::SessionLimits))
//!   and the dispatch from `Request` to `Response`. Sessions execute
//!   inline (the serial REPL) or on a shared
//!   [`RunPool`](crate::platform::RunPool), and can stream
//!   `STREAM <label> MS=<n>` heartbeats during long pooled runs
//!   (`STREAM ON|OFF`).
//! - [`server`] — [`BenchServer`]: the concurrent multi-session TCP
//!   front end. Each client gets an isolated platform; all batches
//!   execute on one bounded worker pool so K sessions cannot
//!   oversubscribe the machine.
//!
//! [`HostController`] is the historical single-user façade — an inline
//! [`Session`] behind the original `new`/`handle_line`/`serve` API —
//! and [`serve_tcp`] the one-session-at-a-time TCP loop (the physical
//! UART is single-master too). Both are now thin shims over the typed
//! core, so the wire format below is byte-identical to what they always
//! spoke.
//!
//! ## Protocol (line-oriented, ASCII)
//!
//! ```text
//! INFO                         → OK CHANNELS=3 SPEED=DDR4-1600 ...
//! CFG <ch> KEY=VALUE ...       → OK CFG <echo>     (see config::parse)
//! CHCFG <N:TOK,..> ...         → OK CHCFG 0:<echo> 1:<echo>  (per-channel mix)
//! RUN <ch>                     → OK RUN CH=0 TXNS=4096 CYCLES=...
//! RUNALL                      → OK RUNALL CHANNELS=3 AGG_GBS=...
//! RUNMIX                      → OK RUNMIX CHANNELS=3 OK=3 AGG_GBS=... CH0_GBS=...
//! STATS <ch>                   → OK RD_TXNS=.. RD_GBS=.. WR_GBS=.. ...
//! PATTERNS                     → OK PATTERNS SEQ RND STRIDE BANK ...
//! MAPPINGS                     → OK MAPPINGS ROW_COL_BANK ... (MAP= names)
//! SCHEDS                       → OK SCHEDS FCFS FRFCFS ... (SCHED= names)
//! RESET <ch>                   → OK RESET
//! STREAM ON|OFF                → OK STREAM ON   (heartbeats on pooled runs)
//! METRICS <ch>                 → OK METRICS CH=0 WINDOW=.. CLOSED=.. [LAST_START=..]
//! TRACEDUMP <ch>               → TRACE <cycle> <ch> <cmd> ... lines, then OK TRACEDUMP
//! AUDIT <ch>                   → OK AUDIT CH=0 EVENTS=.. VIOLATIONS=.. STATUS=CLEAN
//! HELP                         → OK <command list>
//! QUIT                         → OK BYE (closes the session)
//! ```
//!
//! The whole access-pattern engine is reachable at run time through
//! `CFG`: `ADDR=SEQ|RND|STRIDE|BANK|CHASE|PHASED` with `SEED=`,
//! `STRIDE=`, `WSET=` and `PHASES=` parameters — exactly the syntax of
//! [`parse_pattern_config`](crate::config::parse_pattern_config), so
//! host sessions can reconfigure a live platform onto strided,
//! bank-conflict, pointer-chase or phased traffic between batches
//! without reinstantiation. The same goes for the address-mapping
//! engine: `MAP=<policy>` re-maps the channel for the batches that
//! follow (see [`crate::ddr4::MappingPolicy`]) — and for the scheduler
//! engine: `SCHED=<policy>` swaps the controller's command-scheduling/
//! page policy live (see [`crate::controller::sched::SchedKind`]) — and
//! for the simulation engine: `ENGINE=cycle|event` picks the
//! cycle-stepped oracle or the event-driven time-skip core for the
//! batches that follow (bit-exact by contract, so a host can switch
//! freely for speed).
//!
//! Heterogeneous per-channel workloads configure in one `CHCFG` command
//! (whitespace-separated `N:TOKENS,...` channel specs — the
//! [`crate::config::parse_channel_spec`] syntax, so every per-channel
//! pattern, op mix, `MAP=` and `SCHED=` is reachable) and launch
//! concurrently with `RUNMIX`, which runs every channel's pending
//! pattern on parallel threads; a failing channel answers
//! `CHx=ERR[reason]` (whitespace collapsed to keep the line one token)
//! while the surviving channels' stats stay readable via `STATS`.
//! `RUNMIX`'s `AGG_GBS` is the platform aggregate (bytes sum over max
//! cycles — [`Platform::aggregate_gbs`] with `legacy = false`, the same
//! convention as `run` and the sweep artifacts), *not* `RUNALL`'s sum
//! of per-channel rates: the two coincide for homogeneous traffic but
//! diverge once channels run heterogeneous workloads of different
//! durations.
//!
//! The telemetry layer (see [`crate::obs`]) is reachable over the wire
//! too: a `TELEM=<window>` token in `CFG`/`CHCFG` records windowed
//! time-series counters during the batches that follow, `METRICS <ch>`
//! answers the last run's snapshot (all raw integers — bytes, AXI
//! cycles, counts — so the line is engine-identical), and with
//! `STREAM ON` a pooled single-channel run enriches its heartbeats in
//! place to `STREAM <label> MS=<n> bw=<gbs> qd=<n> p99=<ns>`.
//! `TRACEDUMP <ch>` arms the channel's DRAM command trace on first call
//! and dumps it non-destructively thereafter (`TRACE` data lines before
//! the terminal `OK`, so clients read until the `OK`/`ERR` reply).
//!
//! Errors answer `ERR <reason>`; the session stays open. Sessions with
//! resource limits name the violated limit in the diagnostic
//! (`LIMIT_CHANNELS:` / `LIMIT_BATCH:` / `LIMIT_QUEUE:`).

use std::io::BufRead;
use std::io::BufReader;
use std::io::Write;

use crate::platform::Platform;

pub mod proto;
pub mod server;
pub mod session;

pub use proto::{
    parse_request, render_request, render_response, CommandInfo, MixCell, Request, Response,
    COMMANDS,
};
pub use server::{BenchServer, ServerConfig, ShutdownHandle};
pub use session::{serve_stream, Session};

/// Host-controller session state over a [`Platform`] — the historical
/// single-user façade: an inline, unlimited [`Session`] behind the
/// original API.
pub struct HostController {
    session: Session,
}

impl HostController {
    /// Wrap a platform.
    pub fn new(platform: Platform) -> Self {
        Self { session: Session::inline(platform) }
    }

    /// Borrow the wrapped platform.
    pub fn platform(&self) -> &Platform {
        self.session.platform()
    }

    /// Take the platform back (end of session).
    pub fn into_platform(self) -> Platform {
        self.session.into_platform()
    }

    /// Handle one command line; returns the response line (without
    /// newline). `QUIT` returns `OK BYE` — transports treat it as EOF.
    pub fn handle_line(&mut self, line: &str) -> String {
        self.session.handle_line(line)
    }

    /// Drive a whole session over reader/writer streams (the UART loop).
    pub fn serve<R: BufRead, W: Write>(&mut self, reader: R, writer: W) -> std::io::Result<()> {
        serve_stream(&mut self.session, reader, writer)
    }
}

/// Serve the host protocol on a TCP socket (one session at a time — the
/// physical UART is single-master too; use [`BenchServer`] for
/// concurrent clients). Binds to `addr` (e.g. "127.0.0.1:5557");
/// returns after `max_sessions` sessions (None = run forever). A
/// failing connection (I/O error mid-session) is logged and counted,
/// never tears the listener down.
pub fn serve_tcp(
    mut host: HostController,
    addr: &str,
    max_sessions: Option<usize>,
) -> std::io::Result<HostController> {
    let listener = std::net::TcpListener::bind(addr)?;
    eprintln!("ddr4bench host controller listening on {addr}");
    let mut served = 0;
    for stream in listener.incoming() {
        let outcome = stream.and_then(|s| {
            let reader = BufReader::new(s.try_clone()?);
            host.serve(reader, s)
        });
        if let Err(e) = outcome {
            eprintln!("ddr4bench: session error: {e}");
        }
        served += 1;
        if max_sessions.is_some_and(|m| served >= m) {
            break;
        }
    }
    Ok(host)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DesignConfig, SpeedBin};

    fn host() -> HostController {
        HostController::new(Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600)))
    }

    #[test]
    fn info_reports_design() {
        let mut h = host();
        let r = h.handle_line("INFO");
        assert!(r.starts_with("OK CHANNELS=1 SPEED=DDR4-1600"), "{r}");
    }

    #[test]
    fn cfg_run_stats_flow() {
        let mut h = host();
        let r = h.handle_line("CFG 0 OP=R ADDR=SEQ BURST=32 BATCH=512");
        assert!(r.starts_with("OK CFG CH=0"), "{r}");
        let r = h.handle_line("RUN 0");
        assert!(r.starts_with("OK RUN CH=0 TXNS=512"), "{r}");
        let r = h.handle_line("STATS 0");
        assert!(r.contains("RD_TXNS=512"), "{r}");
        assert!(r.contains("RD_GBS="), "{r}");
    }

    #[test]
    fn stats_before_run_is_error() {
        let mut h = host();
        assert!(h.handle_line("STATS 0").starts_with("ERR"));
    }

    #[test]
    fn bad_channel_and_command_errors() {
        let mut h = host();
        assert!(h.handle_line("RUN 5").starts_with("ERR"));
        assert!(h.handle_line("FROB 0").starts_with("ERR"));
        assert!(h.handle_line("CFG 0 BURST=4000").starts_with("ERR"));
    }

    #[test]
    fn reset_clears_stats() {
        let mut h = host();
        h.handle_line("CFG 0 OP=R BATCH=256");
        h.handle_line("RUN 0");
        assert!(h.handle_line("STATS 0").starts_with("OK"));
        assert_eq!(h.handle_line("RESET 0"), "OK RESET");
        assert!(h.handle_line("STATS 0").starts_with("ERR"));
    }

    #[test]
    fn patterns_command_lists_engine_modes() {
        let mut h = host();
        let r = h.handle_line("PATTERNS");
        for mode in ["SEQ", "RND", "STRIDE", "BANK", "CHASE", "PHASED"] {
            assert!(r.contains(mode), "{r}");
        }
        assert!(h.handle_line("HELP").contains("PATTERNS"));
    }

    #[test]
    fn mappings_command_and_map_token_reconfigure_live() {
        let mut h = host();
        let r = h.handle_line("MAPPINGS");
        for name in ["ROW_COL_BANK", "ROW_BANK_COL", "BANK_ROW_COL", "XOR_HASH", "CUSTOM"] {
            assert!(r.contains(name), "{r}");
        }
        assert!(h.handle_line("HELP").contains("MAPPINGS"));
        // every built-in policy (and a custom order) is selectable live
        for map in ["row_col_bank", "row_bank_col", "bank_row_col", "xor_hash", "RoBaBgCo"] {
            let cfg = format!("CFG 0 OP=R ADDR=BANK SEED=1 BURST=1 BATCH=64 MAP={map}");
            let r = h.handle_line(&cfg);
            assert!(r.starts_with("OK CFG CH=0"), "`{cfg}` -> {r}");
            assert!(r.contains("MAP="), "echo carries the policy: {r}");
            let r = h.handle_line("RUN 0");
            assert!(r.starts_with("OK RUN CH=0 TXNS=64"), "`{cfg}` -> {r}");
        }
        assert!(h.handle_line("CFG 0 MAP=frobnicate").starts_with("ERR"));
    }

    #[test]
    fn scheds_command_and_sched_token_reconfigure_live() {
        let mut h = host();
        let r = h.handle_line("SCHEDS");
        for name in ["FCFS", "FRFCFS", "FRFCFS-CAP", "CLOSED", "ADAPTIVE"] {
            assert!(r.contains(name), "{r}");
        }
        assert!(h.handle_line("HELP").contains("SCHEDS"));
        // every policy is selectable live through CFG
        for sched in ["fcfs", "frfcfs", "frfcfs-cap8", "closed", "adaptive"] {
            let cfg = format!("CFG 0 OP=R ADDR=SEQ BURST=4 BATCH=64 SCHED={sched}");
            let r = h.handle_line(&cfg);
            assert!(r.starts_with("OK CFG CH=0"), "`{cfg}` -> {r}");
            assert!(r.contains("SCHED="), "echo carries the policy: {r}");
            let r = h.handle_line("RUN 0");
            assert!(r.starts_with("OK RUN CH=0 TXNS=64"), "`{cfg}` -> {r}");
        }
        assert!(h.handle_line("CFG 0 SCHED=frobnicate").starts_with("ERR"));
    }

    #[test]
    fn engine_token_selects_engine_live() {
        // ENGINE= swaps the simulation engine per batch over the wire;
        // both engines must report identical counters (bit-exactness is
        // part of the protocol contract — a host can flip for speed)
        let mut h = host();
        let mut cycles = Vec::new();
        for engine in ["cycle", "event"] {
            let cfg = format!("CFG 0 OP=R ADDR=SEQ BURST=8 BATCH=128 ENGINE={engine}");
            let r = h.handle_line(&cfg);
            assert!(r.starts_with("OK CFG CH=0"), "`{cfg}` -> {r}");
            assert!(r.contains("ENGINE="), "echo carries the engine: {r}");
            let r = h.handle_line("RUN 0");
            assert!(r.starts_with("OK RUN CH=0 TXNS=128"), "`{cfg}` -> {r}");
            let s = h.handle_line("STATS 0");
            let total = s
                .split_whitespace()
                .find_map(|t| t.strip_prefix("TOTAL_CYCLES="))
                .unwrap()
                .to_string();
            cycles.push(total);
        }
        assert_eq!(cycles[0], cycles[1], "engines diverge over the protocol");
        assert!(h.handle_line("CFG 0 ENGINE=frobnicate").starts_with("ERR"));
    }

    #[test]
    fn new_pattern_modes_configurable_over_protocol() {
        let mut h = host();
        for cfg in [
            "CFG 0 OP=R ADDR=STRIDE STRIDE=64k BURST=4 BATCH=64",
            "CFG 0 OP=R ADDR=BANK SEED=2 BURST=1 BATCH=64",
            "CFG 0 OP=R ADDR=CHASE SEED=9 WSET=64k SIG=BLK BURST=1 BATCH=64",
            "CFG 0 OP=R ADDR=PHASED PHASES=SEQ@32,RND@32 BATCH=64",
        ] {
            let r = h.handle_line(cfg);
            assert!(r.starts_with("OK CFG CH=0"), "`{cfg}` -> {r}");
            let r = h.handle_line("RUN 0");
            assert!(r.starts_with("OK RUN CH=0 TXNS=64"), "`{cfg}` -> {r}");
        }
        // echo carries the mode so a host can read back what it set
        let r = h.handle_line("CFG 0 ADDR=BANK SEED=77");
        assert!(r.contains("ADDR=BANK") && r.contains("SEED=77"), "{r}");
    }

    fn host3() -> HostController {
        HostController::new(Platform::new(DesignConfig::with_channels(3, SpeedBin::Ddr4_1600)))
    }

    #[test]
    fn chcfg_configures_channels_and_runmix_runs_them_concurrently() {
        let mut h = host3();
        let r = h.handle_line(
            "CHCFG 0:SEQ,BURST=32,BATCH=256 1:CHASE,WSET=64k,BURST=1,BATCH=64 \
             2:BANK,SEED=1,BURST=1,BATCH=64",
        );
        assert!(r.starts_with("OK CHCFG 0:"), "{r}");
        assert!(r.contains("1:OP=R,ADDR=CHASE"), "per-channel echo: {r}");
        assert!(r.contains("2:OP=R,ADDR=BANK"), "{r}");
        let r = h.handle_line("RUNMIX");
        assert!(r.starts_with("OK RUNMIX CHANNELS=3 OK=3"), "{r}");
        assert!(r.contains("CH0_GBS=") && r.contains("CH2_GBS="), "{r}");
        // per-channel stats readable afterwards, and they are distinct
        let s0 = h.handle_line("STATS 0");
        let s1 = h.handle_line("STATS 1");
        assert!(s0.contains("RD_TXNS=256"), "{s0}");
        assert!(s1.contains("RD_TXNS=64"), "{s1}");
        // partial CHCFG updates only the named channel
        let r = h.handle_line("CHCFG 1:SEQ,BURST=4,BATCH=32");
        assert!(r.starts_with("OK CHCFG 1:"), "{r}");
        let r = h.handle_line("RUNMIX");
        assert!(r.contains("OK=3"), "{r}");
        assert!(h.handle_line("STATS 1").contains("RD_TXNS=32"));
        assert!(h.handle_line("STATS 0").contains("RD_TXNS=256"), "channel 0 kept its cfg");
    }

    #[test]
    fn runmix_reports_failed_channel_with_reason_and_spares_survivors() {
        let mut p = Platform::new(DesignConfig::with_channels(3, SpeedBin::Ddr4_1600));
        p.inject_channel_panic(1);
        let mut h = HostController::new(p);
        let r = h.handle_line(
            "CHCFG 0:SEQ,BURST=4,BATCH=32 1:SEQ,BURST=4,BATCH=32 2:SEQ,BURST=4,BATCH=32",
        );
        assert!(r.starts_with("OK CHCFG"), "{r}");
        let r = h.handle_line("RUNMIX");
        assert!(r.starts_with("OK RUNMIX CHANNELS=3 OK=2"), "{r}");
        assert!(r.contains("CH1=ERR[") && r.contains("panicked"), "reason surfaces: {r}");
        assert!(h.handle_line("STATS 0").starts_with("OK"), "survivor stats readable");
        assert!(h.handle_line("STATS 1").starts_with("ERR"), "failed channel has no stats");
        // the failed channel was reset: the next RUNMIX is fully clean
        assert!(h.handle_line("RUNMIX").contains("OK=3"));
    }

    #[test]
    fn chcfg_rejects_bad_specs() {
        let mut h = host3();
        assert!(h.handle_line("CHCFG").starts_with("ERR"), "no specs");
        assert!(h.handle_line("CHCFG 5:SEQ").starts_with("ERR"), "channel out of range");
        assert!(h.handle_line("CHCFG 0:SEQ 0:RND").starts_with("ERR"), "duplicate channel");
        assert!(h.handle_line("CHCFG 0:NOPE").starts_with("ERR"), "unknown mode");
        assert!(h.handle_line("CHCFG 0:BURST=4000").starts_with("ERR"), "invalid config");
        // per-channel MAP=/SCHED= are allowed live (unlike in sweeps)
        let r = h.handle_line("CHCFG 0:SEQ,MAP=xor_hash,SCHED=closed,BATCH=64");
        assert!(r.starts_with("OK CHCFG"), "{r}");
        assert!(r.contains("MAP=xor_hash") && r.contains("SCHED=closed"), "{r}");
        // ...and so are phased patterns (comma-separated PHASES= entries)
        let r = h.handle_line("CHCFG 1:PHASED,PHASES=SEQ@32,RND@32,BATCH=64");
        assert!(r.starts_with("OK CHCFG 1:"), "{r}");
        assert!(r.contains("PHASES=SEQ@32,RND@32"), "{r}");
        assert!(h.handle_line("RUNMIX").contains("OK=3"));
        assert!(h.handle_line("HELP").contains("CHCFG"));
        assert!(h.handle_line("HELP").contains("RUNMIX"));
    }

    #[test]
    fn serve_loop_over_streams() {
        let mut h = host();
        let input = b"INFO\nCFG 0 OP=W BURST=4 BATCH=256\nRUN 0\nSTATS 0\nQUIT\n".to_vec();
        let mut out = Vec::new();
        h.serve(std::io::Cursor::new(input), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 5);
        assert!(lines[0].starts_with("OK CHANNELS"));
        assert!(lines[2].starts_with("OK RUN"));
        assert!(lines[3].contains("WR_TXNS=256"));
        assert_eq!(lines[4], "OK BYE");
    }
}
