//! Analytical FPGA resource-utilization model (Table III).
//!
//! Vivado synthesis is not available in this environment (DESIGN.md §2),
//! so Table III is reproduced from a compositional model: each component
//! carries the per-instance LUT/FF/BRAM/DSP cost the paper reports, a
//! design instantiates one memory interface + one traffic generator per
//! channel plus one host controller, and a small glue term (clock/reset
//! distribution, interconnect trees) grows mildly with channel count —
//! exactly the composition the paper's own table exhibits. The model also
//! scales TG/host FF cost with the instantiated counter set and reports
//! utilization percentages against the XCKU115 fabric.

use crate::config::{CounterSet, DesignConfig};

/// Resource vector: LUTs, flip-flops, BRAM36 tiles (fractional = BRAM18),
/// DSP slices.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Resources {
    /// Look-up tables.
    pub lut: f64,
    /// Flip-flops.
    pub ff: f64,
    /// Block RAM (36 Kb tiles; .5 = one 18 Kb half).
    pub bram: f64,
    /// DSP48 slices.
    pub dsp: f64,
}

impl Resources {
    /// Component sum.
    pub fn add(self, o: Resources) -> Resources {
        Resources {
            lut: self.lut + o.lut,
            ff: self.ff + o.ff,
            bram: self.bram + o.bram,
            dsp: self.dsp + o.dsp,
        }
    }

    /// Scale by an instance count.
    pub fn times(self, n: f64) -> Resources {
        Resources { lut: self.lut * n, ff: self.ff * n, bram: self.bram * n, dsp: self.dsp * n }
    }
}

/// AMD Kintex UltraScale 115 (xcku115-flvb2014-2e) fabric capacity.
pub const XCKU115: Resources =
    Resources { lut: 663_360.0, ff: 1_326_720.0, bram: 2160.0, dsp: 5520.0 };

/// Per-instance cost of one DDR4 memory interface (MIG controller + PHY),
/// as measured post-implementation in the paper's Table III.
pub const MEM_INTERFACE: Resources = Resources { lut: 12793.0, ff: 17173.0, bram: 25.5, dsp: 3.0 };

/// Per-instance cost of one traffic generator with the full counter set.
pub const TRAFFIC_GEN: Resources = Resources { lut: 108.0, ff: 268.0, bram: 0.0, dsp: 0.0 };

/// Cost of the (single) host controller.
pub const HOST_CTRL: Resources = Resources { lut: 70.0, ff: 116.0, bram: 0.0, dsp: 0.0 };

/// FF cost of the optional counters inside [`TRAFFIC_GEN`]'s budget: the
/// design-time counter selection removes them when disabled
/// (batch-cycle counters are always present).
const LATENCY_COUNTER_FF: f64 = 96.0; // histogram bucket registers
const REFRESH_COUNTER_FF: f64 = 32.0;
const INTEGRITY_FF: f64 = 64.0; // compare tree + mismatch counter
const INTEGRITY_LUT: f64 = 40.0;

/// Fabric glue (clocking, reset trees, AXI interconnect) per design —
/// fitted exactly to the deltas in Table III: LUT 4/12/24 ⇒ 2n² + 2n,
/// FF 2/8/18 ⇒ 2n².
fn glue(channels: usize) -> Resources {
    let n = channels as f64;
    Resources { lut: 2.0 * n * n + 2.0 * n, ff: 2.0 * n * n, bram: 0.0, dsp: 0.0 }
}

/// TG cost under a counter selection.
pub fn traffic_gen_cost(counters: &CounterSet) -> Resources {
    let mut r = TRAFFIC_GEN;
    if !counters.latency {
        r.ff -= LATENCY_COUNTER_FF;
    }
    if !counters.refresh {
        r.ff -= REFRESH_COUNTER_FF;
    }
    if !counters.integrity {
        r.ff -= INTEGRITY_FF;
        r.lut -= INTEGRITY_LUT;
    }
    r
}

/// Full-design utilization under the compositional model.
pub fn design_cost(design: &DesignConfig) -> Resources {
    let n = design.channels as f64;
    MEM_INTERFACE
        .times(n)
        .add(traffic_gen_cost(&design.counters).times(n))
        .add(HOST_CTRL)
        .add(glue(design.channels))
}

/// One row of the reproduced Table III.
#[derive(Debug, Clone)]
pub struct TableRow {
    /// Component or design name (paper's row labels).
    pub name: String,
    /// Modeled resources.
    pub res: Resources,
}

/// Reproduce Table III for the paper's configuration (full counters).
pub fn table3() -> Vec<TableRow> {
    let full = CounterSet::full();
    let mut rows = vec![
        TableRow { name: "Memory interface".into(), res: MEM_INTERFACE },
        TableRow { name: "Traffic generator".into(), res: traffic_gen_cost(&full) },
        TableRow { name: "Host controller".into(), res: HOST_CTRL },
    ];
    for n in 1..=3 {
        let design = DesignConfig::with_channels(n, crate::config::SpeedBin::Ddr4_1600);
        let label = match n {
            1 => "Single-channel design",
            2 => "Dual-channel design",
            _ => "Triple-channel design",
        };
        rows.push(TableRow { name: label.into(), res: design_cost(&design) });
    }
    rows
}

/// Utilization fraction of the XCKU115 (0..1) per resource class.
pub fn utilization(res: Resources) -> [f64; 4] {
    [res.lut / XCKU115.lut, res.ff / XCKU115.ff, res.bram / XCKU115.bram, res.dsp / XCKU115.dsp]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SpeedBin;

    /// The paper's Table III ground truth: (LUT, FF, BRAM, DSP).
    const PAPER: [(&str, f64, f64, f64, f64); 6] = [
        ("Memory interface", 12793.0, 17173.0, 25.5, 3.0),
        ("Traffic generator", 108.0, 268.0, 0.0, 0.0),
        ("Host controller", 70.0, 116.0, 0.0, 0.0),
        ("Single-channel design", 12975.0, 17559.0, 25.5, 3.0),
        ("Dual-channel design", 25884.0, 35006.0, 51.0, 6.0),
        ("Triple-channel design", 38797.0, 52457.0, 76.5, 9.0),
    ];

    #[test]
    fn table3_matches_paper_within_tolerance() {
        let rows = table3();
        for (row, (name, lut, ff, bram, dsp)) in rows.iter().zip(PAPER.iter()) {
            assert_eq!(&row.name, name);
            let lut_err = (row.res.lut - lut).abs() / lut.max(1.0);
            let ff_err = (row.res.ff - ff).abs() / ff.max(1.0);
            assert!(lut_err < 0.001, "{name}: LUT {} vs paper {lut}", row.res.lut);
            assert!(ff_err < 0.001, "{name}: FF {} vs paper {ff}", row.res.ff);
            assert_eq!(row.res.bram, *bram, "{name}: BRAM");
            assert_eq!(row.res.dsp, *dsp, "{name}: DSP");
        }
    }

    #[test]
    fn channel_scaling_is_linear_in_components() {
        let d1 = design_cost(&DesignConfig::with_channels(1, SpeedBin::Ddr4_1600));
        let d3 = design_cost(&DesignConfig::with_channels(3, SpeedBin::Ddr4_1600));
        // BRAM and DSP scale exactly 3x (only the memory interface uses them)
        assert_eq!(d3.bram, 3.0 * d1.bram);
        assert_eq!(d3.dsp, 3.0 * d1.dsp);
    }

    #[test]
    fn counter_pruning_reduces_ff() {
        let full = traffic_gen_cost(&CounterSet::full());
        let min = traffic_gen_cost(&CounterSet::minimal());
        assert!(min.ff < full.ff);
        assert!(min.lut < full.lut);
        assert!(min.ff > 0.0);
    }

    #[test]
    fn triple_channel_fits_xcku115_comfortably() {
        let d3 = design_cost(&DesignConfig::with_channels(3, SpeedBin::Ddr4_1600));
        let u = utilization(d3);
        assert!(u[0] < 0.06, "LUT utilization {:.3}", u[0]);
        assert!(u.iter().all(|&x| x < 0.06), "all classes under 6%: {u:?}");
    }
}
