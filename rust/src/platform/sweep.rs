//! Campaign sweep executive: expand a cartesian sweep specification
//! (speed bins × channel counts × address mappings × controller knobs ×
//! scheduler policies × traffic patterns, plus heterogeneous per-channel
//! mixes that bring their own channel count) into a deduplicated job
//! list and execute it on a
//! work-stealing thread pool, one isolated [`Platform`] per job, emitting
//! per-job JSON/CSV artifacts plus a machine-readable summary
//! (`BENCH_sweep.json` schema; cross-sweep deltas render through
//! [`crate::report::compare`] / `ddr4bench compare`).
//!
//! This is the scale/speed/scenario-diversity executive the ROADMAP asks
//! for: where [`Platform::run_batch_all`] parallelizes the *channels of
//! one design*, the sweep executive parallelizes *whole designs* — every
//! (speed, channels, pattern) point of a campaign runs concurrently,
//! bounded only by worker count.
//!
//! ```no_run
//! use ddr4bench::platform::sweep::{run_sweep, SweepSpec};
//!
//! let jobs = SweepSpec::paper_grid().expand();
//! let outcomes = run_sweep(jobs, 4).unwrap();
//! for o in &outcomes {
//!     println!("{}: {:.2} GB/s", o.job.label, o.agg.total_throughput_gbs());
//! }
//! ```

use std::collections::{HashSet, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use crate::config::{
    format_channel_mix, parse_channel_mix, parse_controller_tokens, parse_kv_text,
    parse_pattern_config, parse_u64_with_suffix, ChannelMix, ControllerParams, DesignConfig,
    EngineKind, PatternConfig, SchedKind, SpeedBin,
};
use crate::ddr4::MappingPolicy;
use crate::obs::TelemetrySeries;
use crate::platform::Platform;
use crate::report::Table;
use crate::stats::BatchStats;

/// Schema identifier stamped into every sweep artifact. `v4` adds the
/// heterogeneous-mix axis (`mix` field: the per-channel workload spec,
/// empty for uniform jobs); `v3` (sched axis + latency percentiles),
/// `v2` (mapping and knob axes, no percentiles) and `v1` artifacts are
/// still accepted by [`crate::report::compare`], with missing axis
/// fields defaulted.
pub const SWEEP_SCHEMA: &str = "ddr4bench.sweep.v4";

/// A cartesian sweep specification.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Speed bins to sweep.
    pub speeds: Vec<SpeedBin>,
    /// Channel counts to sweep (1..=3 on the XCKU115).
    pub channels: Vec<usize>,
    /// Address-mapping policies to sweep.
    pub mappings: Vec<MappingPolicy>,
    /// Labeled controller-knob profiles to sweep.
    pub knobs: Vec<(String, ControllerParams)>,
    /// Scheduler/page policies to sweep.
    pub scheds: Vec<SchedKind>,
    /// Labeled traffic patterns to sweep.
    pub patterns: Vec<(String, PatternConfig)>,
    /// Labeled heterogeneous channel mixes to sweep. Each mix fixes its
    /// own channel count (= the number of channels it configures), so
    /// mix jobs do not multiply with the `channels` axis.
    pub mixes: Vec<(String, ChannelMix)>,
    /// Simulation engine every job runs under. Not a cartesian axis: the
    /// engines are bit-identical by contract (only wall-clock differs),
    /// so sweeping both would double the grid for measurement-free jobs.
    /// It is also deliberately absent from the artifact stems and
    /// JSON/CSV labels — a cycle sweep and an event sweep of the same
    /// spec produce identically-named, `compare`-able artifacts.
    pub engine: EngineKind,
    /// Telemetry sampling window (AXI cycles) every job records under
    /// (`telemetry =` spec key / CLI `--telemetry`). Like `engine`, not
    /// a cartesian axis: telemetry is observation-only by contract, so
    /// sweeping it would multiply the grid without changing any
    /// measurement. When set, each job additionally emits a
    /// `{stem}_timeline.json` per-channel time-series artifact.
    pub telemetry: Option<u64>,
    /// Arm the independent protocol auditor ([`crate::check`]) on every
    /// channel of every job (`audit =` spec key / CLI `--audit`). Like
    /// `telemetry`, not a cartesian axis: auditing is observation-only
    /// by contract. Any detected violation fails the job (this is the
    /// CI legality gate); clean jobs attach a `{stem}_audit.txt`
    /// certificate artifact.
    pub audit: bool,
}

/// Named pattern preset, by the names the CLI accepts
/// (`--patterns seq,rnd,strided,bank,chase,phased`). Aliases map onto the
/// canonical label so `strided,stride` dedups to one job, not two.
pub fn preset(name: &str) -> Option<(String, PatternConfig)> {
    let (label, cfg) = match name.to_ascii_lowercase().as_str() {
        "seq" => ("seq", PatternConfig::seq_read_burst(32, 4096)),
        "rnd" => ("rnd", PatternConfig::rnd_read_burst(1, 2048, 0xF00D)),
        "strided" | "stride" => ("strided", PatternConfig::strided_read(64 << 10, 4, 2048)),
        "bank" | "bankconflict" => ("bank", PatternConfig::bank_conflict_read(1, 1024, 1)),
        "chase" | "pointerchase" => {
            ("chase", PatternConfig::pointer_chase_read(4 << 20, 1024, 7))
        }
        "phased" => ("phased", {
            let mut p = PatternConfig::seq_read_burst(4, 2048);
            p.addr = crate::config::AddrMode::Phased(vec![
                (crate::config::AddrMode::Sequential, 512),
                (crate::config::AddrMode::Random { seed: 0xF00D }, 512),
            ]);
            p
        }),
        _ => return None,
    };
    Some((label.to_string(), cfg))
}

impl SweepSpec {
    /// The default campaign: the Fig. 2 data-rate grid (DDR4-1600 and
    /// DDR4-2400) × {1, 2} channels × the three adversarial patterns —
    /// 12 jobs.
    pub fn paper_grid() -> Self {
        Self {
            speeds: vec![SpeedBin::Ddr4_1600, SpeedBin::Ddr4_2400],
            channels: vec![1, 2],
            mappings: vec![MappingPolicy::row_col_bank()],
            knobs: vec![("mig".to_string(), ControllerParams::default())],
            scheds: vec![SchedKind::FrFcfs],
            patterns: ["strided", "bank", "chase"]
                .iter()
                .map(|n| preset(n).expect("builtin preset"))
                .collect(),
            mixes: Vec::new(),
            engine: EngineKind::default(),
            telemetry: None,
            audit: false,
        }
    }

    /// Parse a sweep spec from config text:
    ///
    /// ```text
    /// speeds = 1600, 2400
    /// channels = 1, 2
    /// mappings = row_col_bank, xor_hash
    /// scheds = fcfs, frfcfs, frfcfs-cap, closed
    /// engine = event
    /// [patterns]
    /// strided = OP=R ADDR=STRIDE STRIDE=64k BURST=4 BATCH=2048
    /// chase   = OP=R ADDR=CHASE SEED=7 WSET=4m SIG=BLK BATCH=1024 BURST=1
    /// [knobs]
    /// mig  = lookahead=4
    /// deep = lookahead=8 rq=32 wq=32 whi=24 wlo=8
    /// [mixes]
    /// hetero = 0:SEQ,BURST=32,BATCH=2048 1:CHASE,WSET=1m,BURST=1,BATCH=1024
    /// ```
    ///
    /// Omitted sections fall back to the [`Self::paper_grid`] values.
    /// `[mixes]` entries are whitespace-separated `N:TOKENS,...` channel
    /// specs ([`parse_channel_mix`]); like patterns, their per-channel
    /// `MAP=`/`SCHED=` overrides are rejected — the `mappings`/`scheds`
    /// axes stay authoritative over the artifact labels.
    pub fn parse(text: &str) -> Result<Self> {
        let map = parse_kv_text(text).map_err(|e| anyhow!("{e}"))?;
        for key in map.keys() {
            if key != "speeds"
                && key != "channels"
                && key != "mappings"
                && key != "scheds"
                && key != "engine"
                && key != "telemetry"
                && key != "audit"
                && !key.starts_with("patterns.")
                && !key.starts_with("knobs.")
                && !key.starts_with("mixes.")
            {
                bail!(
                    "unknown sweep spec key `{key}` (expected `speeds`, `channels`, \
                     `mappings`, `scheds`, `engine`, `telemetry`, `audit`, or \
                     `[patterns]`/`[knobs]`/`[mixes]` entries)"
                );
            }
        }
        let mut spec = Self::paper_grid();
        if let Some(v) = map.get("speeds") {
            spec.speeds = parse_speed_list(v)?;
        }
        if let Some(v) = map.get("channels") {
            spec.channels = parse_channel_list(v)?;
        }
        if let Some(v) = map.get("mappings") {
            spec.mappings = parse_mapping_list(v)?;
        }
        if let Some(v) = map.get("scheds") {
            spec.scheds = parse_sched_list(v)?;
        }
        if let Some(v) = map.get("engine") {
            spec.engine = EngineKind::parse(v)
                .ok_or_else(|| anyhow!("engine: unknown engine `{v}` (expected cycle|event)"))?;
        }
        if let Some(v) = map.get("telemetry") {
            let w = parse_u64_with_suffix(v)
                .ok_or_else(|| anyhow!("telemetry: expected window cycles, got `{v}`"))?;
            if w == 0 {
                bail!("telemetry: window must be >= 1 AXI cycle");
            }
            spec.telemetry = Some(w);
        }
        if let Some(v) = map.get("audit") {
            spec.audit = match v.trim().to_ascii_lowercase().as_str() {
                "true" | "on" | "1" | "yes" => true,
                "false" | "off" | "0" | "no" => false,
                other => bail!("audit: expected on/off, got `{other}`"),
            };
        }
        let knobs: Vec<(String, ControllerParams)> = map
            .iter()
            .filter_map(|(k, v)| {
                k.strip_prefix("knobs.").map(|label| (label.to_string(), v.as_str()))
            })
            .map(|(label, tokens)| {
                let toks: Vec<&str> = tokens.split_whitespace().collect();
                reject_sched_knob(&label, &toks)?;
                let params = parse_controller_tokens(ControllerParams::default(), &toks)
                    .map_err(|e| anyhow!("knob profile `{label}`: {e}"))?;
                validate_knob_profile(&label, params)?;
                Ok((label, params))
            })
            .collect::<Result<_>>()?;
        if !knobs.is_empty() {
            spec.knobs = knobs;
        }
        let patterns: Vec<(String, PatternConfig)> = map
            .iter()
            .filter_map(|(k, v)| {
                k.strip_prefix("patterns.").map(|label| (label.to_string(), v.as_str()))
            })
            .map(|(label, tokens)| {
                let toks: Vec<&str> = tokens.split_whitespace().collect();
                let cfg = parse_pattern_config(&toks)
                    .map_err(|e| anyhow!("pattern `{label}`: {e}"))?;
                if cfg.mapping.is_some() {
                    bail!(
                        "pattern `{label}`: MAP= is not allowed in sweep patterns — \
                         sweep the address mapping via the `mappings` axis instead"
                    );
                }
                if cfg.sched.is_some() {
                    bail!(
                        "pattern `{label}`: SCHED= is not allowed in sweep patterns — \
                         sweep the scheduler via the `scheds` axis instead"
                    );
                }
                if cfg.telemetry.is_some() {
                    bail!(
                        "pattern `{label}`: TELEM= is not allowed in sweep patterns — \
                         set the sweep-level `telemetry` key instead"
                    );
                }
                Ok((label, cfg))
            })
            .collect::<Result<_>>()?;
        if !patterns.is_empty() {
            spec.patterns = patterns;
        }
        let mixes: Vec<(String, ChannelMix)> = map
            .iter()
            .filter_map(|(k, v)| {
                k.strip_prefix("mixes.").map(|label| (label.to_string(), v.as_str()))
            })
            .map(|(label, specs)| {
                let parts: Vec<&str> = specs.split_whitespace().collect();
                let mix = parse_channel_mix(&parts).map_err(|e| anyhow!("mix `{label}`: {e}"))?;
                reject_mix_overrides(&label, &mix)?;
                Ok((label, mix))
            })
            .collect::<Result<_>>()?;
        spec.mixes = mixes;
        Ok(spec)
    }

    /// Expand the cartesian product into a deduplicated, deterministic
    /// job list (duplicate (speed, channels, mapping, knobs, sched,
    /// pattern/mix) points collapse). Heterogeneous mixes expand against
    /// every axis except `channels` — each mix brings its own channel
    /// count.
    pub fn expand(&self) -> Vec<SweepJob> {
        let mut seen: HashSet<(u32, usize, String, String, String, String, String)> =
            HashSet::new();
        let mut jobs = Vec::new();
        for &speed in &self.speeds {
            for &channels in &self.channels {
                for &mapping in &self.mappings {
                    for (knob, params) in &self.knobs {
                        for &sched in &self.scheds {
                            for (label, cfg) in &self.patterns {
                                let key = (
                                    speed.data_rate_mts(),
                                    channels,
                                    mapping.name(),
                                    knob.clone(),
                                    sched.name(),
                                    label.clone(),
                                    String::new(),
                                );
                                if !seen.insert(key) {
                                    continue;
                                }
                                jobs.push(SweepJob {
                                    id: jobs.len(),
                                    speed,
                                    channels,
                                    mapping,
                                    knob: knob.clone(),
                                    params: *params,
                                    sched,
                                    engine: self.engine,
                                    telemetry: self.telemetry,
                                    audit: self.audit,
                                    label: label.clone(),
                                    cfg: cfg.clone(),
                                    mix: None,
                                });
                            }
                        }
                    }
                }
            }
            for &mapping in &self.mappings {
                for (knob, params) in &self.knobs {
                    for &sched in &self.scheds {
                        for (label, mix) in &self.mixes {
                            let key = (
                                speed.data_rate_mts(),
                                mix.len(),
                                mapping.name(),
                                knob.clone(),
                                sched.name(),
                                label.clone(),
                                format_channel_mix(mix),
                            );
                            if !seen.insert(key) {
                                continue;
                            }
                            jobs.push(SweepJob {
                                id: jobs.len(),
                                speed,
                                channels: mix.len(),
                                mapping,
                                knob: knob.clone(),
                                params: *params,
                                sched,
                                engine: self.engine,
                                telemetry: self.telemetry,
                                audit: self.audit,
                                label: label.clone(),
                                cfg: mix.get(0).expect("mix covers channel 0").clone(),
                                mix: Some(mix.clone()),
                            });
                        }
                    }
                }
            }
        }
        jobs
    }
}

/// Mixes may not smuggle in per-channel `MAP=`/`SCHED=` overrides inside
/// a sweep: the `mappings`/`scheds` axes are authoritative and `run_job`
/// would strip the override anyway, leaving the artifact labels lying
/// about what ran (same rationale as the pattern-level rejection).
fn reject_mix_overrides(label: &str, mix: &ChannelMix) -> Result<()> {
    for (ch, cfg) in mix.iter().enumerate() {
        if cfg.mapping.is_some() {
            bail!(
                "mix `{label}` channel {ch}: MAP= is not allowed in sweep mixes — \
                 sweep the address mapping via the `mappings` axis instead"
            );
        }
        if cfg.sched.is_some() {
            bail!(
                "mix `{label}` channel {ch}: SCHED= is not allowed in sweep mixes — \
                 sweep the scheduler via the `scheds` axis instead"
            );
        }
        if cfg.telemetry.is_some() {
            bail!(
                "mix `{label}` channel {ch}: TELEM= is not allowed in sweep mixes — \
                 set the sweep-level `telemetry` key instead"
            );
        }
    }
    Ok(())
}

/// Parse a CLI `--mixes` axis: semicolon-separated heterogeneous mixes,
/// each a `+`-joined list of `N:TOKENS,...` channel specs, e.g.
/// `0:SEQ,BURST=32+1:CHASE,WSET=1m;0:BANK+1:RND`. Labels derive from the
/// per-channel address modes (`seq+chase`), de-duplicated with a numeric
/// suffix when two mixes share one.
pub fn parse_mix_list(s: &str) -> Result<Vec<(String, ChannelMix)>> {
    let mut out: Vec<(String, ChannelMix)> = Vec::new();
    for variant in s.split(';').map(str::trim).filter(|t| !t.is_empty()) {
        let specs: Vec<&str> = variant.split('+').map(str::trim).collect();
        let mix = parse_channel_mix(&specs).map_err(|e| anyhow!("--mixes `{variant}`: {e}"))?;
        let base = mix.label();
        let mut label = base.clone();
        let mut n = 2;
        while out.iter().any(|(l, _)| *l == label) {
            label = format!("{base}_{n}");
            n += 1;
        }
        reject_mix_overrides(&label, &mix)?;
        out.push((label, mix));
    }
    if out.is_empty() {
        bail!("--mixes: no mixes given");
    }
    Ok(out)
}

/// Reject knob profiles that cannot instantiate a valid design (watermark
/// ordering, zero windows, …) before the sweep spends any work on them.
fn validate_knob_profile(label: &str, params: ControllerParams) -> Result<()> {
    let probe = DesignConfig { controller: params, ..DesignConfig::default() };
    probe.validate().map_err(|e| anyhow!("knob profile `{label}`: {e}"))?;
    Ok(())
}

/// Knob profiles may not smuggle in a scheduler: the `scheds` axis is
/// authoritative and `run_job` would silently overwrite the knob's
/// choice, leaving the artifact labels lying about what ran (the same
/// reason pattern-level `SCHED=`/`MAP=` are rejected).
fn reject_sched_knob(label: &str, tokens: &[&str]) -> Result<()> {
    for tok in tokens {
        let key = tok.split('=').next().unwrap_or("").trim().to_ascii_lowercase();
        if key == "sched" || key == "policy" {
            bail!(
                "knob profile `{label}`: sched= is not allowed in sweep knob profiles — \
                 sweep the scheduler via the `scheds` axis instead"
            );
        }
    }
    Ok(())
}

/// Parse "1600, 2400" style speed lists.
pub fn parse_speed_list(s: &str) -> Result<Vec<SpeedBin>> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| SpeedBin::parse(t).ok_or_else(|| anyhow!("unknown speed bin `{t}`")))
        .collect()
}

/// Parse "row_col_bank, xor_hash" style mapping-policy lists.
pub fn parse_mapping_list(s: &str) -> Result<Vec<MappingPolicy>> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| MappingPolicy::parse(t).ok_or_else(|| anyhow!("unknown mapping policy `{t}`")))
        .collect()
}

/// Parse a CLI `--knobs` axis: comma-separated knob variants, each a
/// `+`-joined list of `KEY=VALUE` controller overrides applied on top of
/// the MIG-like defaults, e.g. `lookahead=1,lookahead=8+wq=32`. The
/// variant's label is its spec with the separators compacted
/// (`lookahead8_wq32`).
pub fn parse_knob_list(s: &str) -> Result<Vec<(String, ControllerParams)>> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|variant| {
            let toks: Vec<&str> = variant.split('+').collect();
            reject_sched_knob(variant, &toks)?;
            let params = parse_controller_tokens(ControllerParams::default(), &toks)
                .map_err(|e| anyhow!("--knobs `{variant}`: {e}"))?;
            let label = variant.replace('=', "").replace('+', "_").replace(' ', "");
            validate_knob_profile(&label, params)?;
            Ok((label, params))
        })
        .collect()
}

/// Parse "fcfs, frfcfs-cap8, closed" style scheduler-policy lists (the
/// CLI `--scheds` axis and the spec `scheds =` key).
pub fn parse_sched_list(s: &str) -> Result<Vec<SchedKind>> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| SchedKind::parse(t).ok_or_else(|| anyhow!("unknown scheduler policy `{t}`")))
        .collect()
}

/// Parse "1, 2, 3" style channel lists.
pub fn parse_channel_list(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| {
            let n: usize = t.parse().map_err(|_| anyhow!("bad channel count `{t}`"))?;
            if !(1..=3).contains(&n) {
                bail!("channel count must be 1..=3, got {n}");
            }
            Ok(n)
        })
        .collect()
}

/// One expanded sweep job.
#[derive(Debug, Clone)]
pub struct SweepJob {
    /// Stable index in the expanded job list.
    pub id: usize,
    /// Data rate of the design.
    pub speed: SpeedBin,
    /// Channel count of the design.
    pub channels: usize,
    /// Address-mapping policy of the design's geometry.
    pub mapping: MappingPolicy,
    /// Controller-knob profile label (artifact naming).
    pub knob: String,
    /// The controller-knob profile itself.
    pub params: ControllerParams,
    /// Scheduler/page policy of the design's controller.
    pub sched: SchedKind,
    /// Simulation engine the job runs under (absent from artifact
    /// labels: both engines produce bit-identical measurements).
    pub engine: EngineKind,
    /// Telemetry sampling window, AXI cycles (absent from artifact
    /// labels: telemetry is observation-only by contract).
    pub telemetry: Option<u64>,
    /// Arm the protocol auditor on every channel (absent from artifact
    /// labels: auditing is observation-only by contract). A violation
    /// fails the job.
    pub audit: bool,
    /// Pattern/mix label (artifact naming).
    pub label: String,
    /// The traffic pattern to run (for mix jobs: channel 0's pattern;
    /// the full mix is in `mix`).
    pub cfg: PatternConfig,
    /// Heterogeneous per-channel workloads (None = uniform job running
    /// `cfg` on every channel).
    pub mix: Option<ChannelMix>,
}

/// A completed sweep job with its measurements.
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    /// The job that ran.
    pub job: SweepJob,
    /// Per-channel statistics.
    pub per_channel: Vec<BatchStats>,
    /// Channel-aggregated statistics.
    pub agg: BatchStats,
    /// Wall-clock job duration in milliseconds.
    pub wall_ms: f64,
    /// Rendered protocol-audit certificate (every channel CLEAN) when
    /// the job ran with auditing armed; `None` otherwise. A job with
    /// violations never produces an outcome — it fails instead.
    pub audit: Option<String>,
}

fn run_job(job: &SweepJob) -> Result<SweepOutcome> {
    let t0 = std::time::Instant::now();
    let mut design = DesignConfig::with_channels(job.channels, job.speed);
    design.geometry.mapping = job.mapping;
    design.controller = job.params;
    design.controller.sched = job.sched;
    design.engine = job.engine;
    design.telemetry = job.telemetry;
    design.validate().map_err(|e| anyhow!("{e}"))?;
    let mut platform = Platform::new(design);
    // The job's mapping and scheduler axes are authoritative: a stray
    // pattern-level (or per-channel) MAP=/SCHED= override would run a
    // different policy than the artifact labels claim (SweepSpec::parse
    // rejects them; this guards programmatic specs too, and keeps the
    // echo truthful). ENGINE= is stripped for the same reason: the
    // job-level engine choice is what ran — and TELEM= likewise: the
    // sweep-level window is what every channel recorded under.
    let mut job = job.clone();
    job.cfg.mapping = None;
    job.cfg.sched = None;
    job.cfg.engine = None;
    job.cfg.telemetry = None;
    if let Some(mix) = &job.mix {
        job.mix = Some(mix.without_overrides());
    }
    let mix = match &job.mix {
        Some(mix) => mix.clone(),
        None => ChannelMix::uniform(&job.cfg, job.channels).map_err(|e| anyhow!("{e}"))?,
    };
    if job.audit {
        for ch in 0..platform.channels() {
            platform.enable_audit(ch)?;
        }
    }
    let per_channel = platform.run_batch_mix(&mix)?;
    let agg = Platform::aggregate(&per_channel);
    let audit = if job.audit { Some(audit_verdict(&platform)?) } else { None };
    Ok(SweepOutcome { job, per_channel, agg, wall_ms: t0.elapsed().as_secs_f64() * 1e3, audit })
}

/// Collect every channel's audit verdict after an armed job. Any
/// violation (end-of-stream checks included) fails the job with the
/// offending rule IDs and the first violations spelled out — this is
/// what the CI sweep gate trips on. All-clean returns the rendered
/// per-channel certificate for the `{stem}_audit.txt` artifact.
fn audit_verdict(platform: &Platform) -> Result<String> {
    use crate::check::report;
    let mut rendered = String::new();
    let mut failures: Vec<String> = Vec::new();
    for ch in 0..platform.channels() {
        let auditor = platform
            .auditor(ch)
            .ok_or_else(|| anyhow!("audit armed but channel {ch} has no auditor"))?;
        rendered.push_str(&report::render(auditor, ch, 0));
        if report::total_violations(auditor) > 0 {
            let mut rules: Vec<&str> =
                auditor.violated_rules().iter().map(|r| r.id()).collect();
            // End-of-stream findings are not in the per-event counters.
            if !auditor.end_of_stream_check().is_empty()
                && !rules.contains(&crate::check::RuleId::TrefiMax.id())
            {
                rules.push(crate::check::RuleId::TrefiMax.id());
            }
            let mut lines = report::violation_lines(auditor);
            lines.truncate(3);
            failures.push(format!(
                "channel {ch} violated [{}]: {}",
                rules.join(", "),
                lines.join("; ")
            ));
        }
    }
    if !failures.is_empty() {
        bail!("protocol audit failed: {}", failures.join(" | "));
    }
    Ok(rendered)
}

/// Execute `jobs` on a work-stealing pool of `workers` threads. Each
/// worker owns a deque seeded round-robin; an idle worker first drains
/// its own queue from the front, then steals from the back of a victim's.
/// Results are returned in job-id order; any job failure fails the sweep
/// (after the pool drains).
pub fn run_sweep(jobs: Vec<SweepJob>, workers: usize) -> Result<Vec<SweepOutcome>> {
    if jobs.is_empty() {
        return Ok(Vec::new());
    }
    let workers = workers.clamp(1, jobs.len());
    let queues: Vec<Mutex<VecDeque<SweepJob>>> =
        (0..workers).map(|_| Mutex::new(VecDeque::new())).collect();
    for (i, job) in jobs.into_iter().enumerate() {
        queues[i % workers].lock().expect("queue mutex poisoned").push_back(job);
    }
    let results: Mutex<Vec<SweepOutcome>> = Mutex::new(Vec::new());
    let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        for w in 0..workers {
            let queues = &queues;
            let results = &results;
            let errors = &errors;
            scope.spawn(move || loop {
                // Take from the own queue first; the guard must drop
                // before stealing so two stealers can never hold-and-wait
                // on each other's locks.
                let own = queues[w].lock().expect("queue mutex poisoned").pop_front();
                let job = match own {
                    Some(job) => Some(job),
                    None => (0..queues.len()).filter(|&q| q != w).find_map(|q| {
                        queues[q].lock().expect("queue mutex poisoned").pop_back()
                    }),
                };
                let Some(job) = job else { break };
                match run_job(&job) {
                    Ok(outcome) => {
                        results.lock().expect("results mutex poisoned").push(outcome)
                    }
                    Err(e) => errors
                        .lock()
                        .expect("errors mutex poisoned")
                        .push(format!("job {} ({}): {e}", job.id, job.label)),
                }
            });
        }
    });
    let errors = errors.into_inner().expect("errors mutex poisoned");
    if !errors.is_empty() {
        bail!("{} sweep job(s) failed: {}", errors.len(), errors.join("; "));
    }
    let mut outcomes = results.into_inner().expect("results mutex poisoned");
    outcomes.sort_by_key(|o| o.job.id);
    Ok(outcomes)
}

/// Make a user-supplied pattern label safe as a file-name component:
/// anything outside `[A-Za-z0-9._-]` becomes `_` (so path separators and
/// `..` tricks from spec files cannot escape the artifact directory).
fn sanitize_label(label: &str) -> String {
    let safe: String = label
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-') { c } else { '_' })
        .collect();
    if safe.chars().all(|c| c == '.') {
        "pattern".to_string()
    } else {
        safe
    }
}

/// Minimal CSV field escaping (quotes fields containing `,` or `"`).
fn csv_escape(s: &str) -> String {
    if s.contains(',') || s.contains('"') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render one outcome as a self-describing JSON object.
pub fn job_json(o: &SweepOutcome) -> String {
    let per_channel: Vec<String> = o
        .per_channel
        .iter()
        .map(|s| format!("{:.6}", s.total_throughput_gbs()))
        .collect();
    format!(
        concat!(
            "{{\n",
            "  \"schema\": \"{schema}\",\n",
            "  \"id\": {id},\n",
            "  \"speed\": \"{speed}\",\n",
            "  \"data_rate_mts\": {rate},\n",
            "  \"channels\": {channels},\n",
            "  \"pattern\": \"{label}\",\n",
            "  \"mapping\": \"{mapping}\",\n",
            "  \"knobs\": \"{knob}\",\n",
            "  \"sched\": \"{sched}\",\n",
            "  \"mix\": \"{mix}\",\n",
            "  \"cfg\": \"{cfg}\",\n",
            "  \"rd_gbs\": {rd:.6},\n",
            "  \"wr_gbs\": {wr:.6},\n",
            "  \"total_gbs\": {tot:.6},\n",
            "  \"rd_lat_ns\": {rdlat:.3},\n",
            "  \"wr_lat_ns\": {wrlat:.3},\n",
            "  \"rd_p50_ns\": {rdp50:.3},\n",
            "  \"rd_p95_ns\": {rdp95:.3},\n",
            "  \"rd_p99_ns\": {rdp99:.3},\n",
            "  \"wr_p50_ns\": {wrp50:.3},\n",
            "  \"wr_p95_ns\": {wrp95:.3},\n",
            "  \"wr_p99_ns\": {wrp99:.3},\n",
            "  \"refresh_stall_ck\": {refresh},\n",
            "  \"mismatches\": {mism},\n",
            "  \"energy_nj\": {energy:.3},\n",
            "  \"pj_per_bit\": {pjb:.4},\n",
            "  \"wall_ms\": {wall:.3},\n",
            "  \"per_channel_total_gbs\": [{per}]\n",
            "}}"
        ),
        schema = SWEEP_SCHEMA,
        id = o.job.id,
        speed = o.job.speed,
        rate = o.job.speed.data_rate_mts(),
        channels = o.job.channels,
        label = json_escape(&o.job.label),
        mapping = json_escape(&o.job.mapping.name()),
        knob = json_escape(&o.job.knob),
        sched = json_escape(&o.job.sched.name()),
        mix = json_escape(&o.job.mix.as_ref().map(format_channel_mix).unwrap_or_default()),
        cfg = json_escape(&crate::config::format_pattern_config(&o.job.cfg)),
        rd = o.agg.read_throughput_gbs(),
        wr = o.agg.write_throughput_gbs(),
        tot = o.agg.total_throughput_gbs(),
        rdlat = o.agg.read_latency_ns(),
        wrlat = o.agg.write_latency_ns(),
        rdp50 = o.agg.read_latency_pct_ns(50.0),
        rdp95 = o.agg.read_latency_pct_ns(95.0),
        rdp99 = o.agg.read_latency_pct_ns(99.0),
        wrp50 = o.agg.write_latency_pct_ns(50.0),
        wrp95 = o.agg.write_latency_pct_ns(95.0),
        wrp99 = o.agg.write_latency_pct_ns(99.0),
        refresh = o.agg.counters.refresh_stall_dram_cycles,
        mism = o.agg.counters.mismatches,
        energy = o.agg.energy.total_nj(),
        pjb = o.agg.pj_per_bit().unwrap_or(0.0),
        wall = o.wall_ms,
        per = per_channel.join(", "),
    )
}

/// Render one outcome as a single-row CSV (header + row). Every
/// free-form string column — pattern/mix labels, mapping, knob profile,
/// sched, the mix spec — passes through [`csv_escape`]: per-channel mix
/// specs and labels can legitimately contain commas.
pub fn job_csv(o: &SweepOutcome) -> String {
    format!(
        "id,speed,data_rate_mts,channels,pattern,mapping,knobs,sched,mix,rd_gbs,wr_gbs,\
         total_gbs,rd_lat_ns,wr_lat_ns,rd_p50_ns,rd_p95_ns,rd_p99_ns,wr_p50_ns,wr_p95_ns,\
         wr_p99_ns,refresh_stall_ck,mismatches,energy_nj,pj_per_bit,wall_ms\n\
         {},{},{},{},{},{},{},{},{},{:.6},{:.6},{:.6},{:.3},{:.3},{:.3},{:.3},{:.3},{:.3},\
         {:.3},{:.3},{},{},{:.3},{:.4},{:.3}\n",
        o.job.id,
        o.job.speed,
        o.job.speed.data_rate_mts(),
        o.job.channels,
        csv_escape(&o.job.label),
        csv_escape(&o.job.mapping.name()),
        csv_escape(&o.job.knob),
        csv_escape(&o.job.sched.name()),
        csv_escape(&o.job.mix.as_ref().map(format_channel_mix).unwrap_or_default()),
        o.agg.read_throughput_gbs(),
        o.agg.write_throughput_gbs(),
        o.agg.total_throughput_gbs(),
        o.agg.read_latency_ns(),
        o.agg.write_latency_ns(),
        o.agg.read_latency_pct_ns(50.0),
        o.agg.read_latency_pct_ns(95.0),
        o.agg.read_latency_pct_ns(99.0),
        o.agg.write_latency_pct_ns(50.0),
        o.agg.write_latency_pct_ns(95.0),
        o.agg.write_latency_pct_ns(99.0),
        o.agg.counters.refresh_stall_dram_cycles,
        o.agg.counters.mismatches,
        o.agg.energy.total_nj(),
        o.agg.pj_per_bit().unwrap_or(0.0),
        o.wall_ms,
    )
}

/// Render the whole-campaign summary JSON (the `BENCH_sweep.json` format).
pub fn summary_json(outcomes: &[SweepOutcome], source: &str) -> String {
    let jobs: Vec<String> = outcomes
        .iter()
        .map(|o| {
            let body: String =
                job_json(o).lines().map(|l| format!("    {l}\n")).collect::<String>();
            body.trim_end().to_string()
        })
        .collect();
    format!(
        "{{\n  \"schema\": \"{}\",\n  \"source\": \"{}\",\n  \"jobs\": [\n{}\n  ]\n}}\n",
        SWEEP_SCHEMA,
        json_escape(source),
        jobs.join(",\n"),
    )
}

/// Artifact file stem of one outcome. Deliberately engine-free: a cycle
/// sweep and an event sweep of the same spec must label their artifacts
/// identically so `compare` lines them up job for job.
pub fn artifact_stem(o: &SweepOutcome) -> String {
    format!(
        "{:03}_{}_{}ch_{}_{}_{}_{}",
        o.job.id,
        o.job.speed.data_rate_mts(),
        o.job.channels,
        sanitize_label(&o.job.mapping.name()),
        sanitize_label(&o.job.knob),
        sanitize_label(&o.job.sched.name()),
        sanitize_label(&o.job.label)
    )
}

/// Render one outcome's per-channel telemetry series as the
/// `{stem}_timeline.json` artifact body — `None` when the sweep ran
/// without a telemetry window. Engine-free like the stem: both engines
/// record identical series, so timelines line up byte for byte too.
pub fn timeline_artifact(o: &SweepOutcome) -> Option<String> {
    let series: Vec<(usize, &TelemetrySeries)> = o
        .per_channel
        .iter()
        .enumerate()
        .filter_map(|(ch, s)| s.telemetry.as_ref().map(|t| (ch, t)))
        .collect();
    if series.is_empty() {
        return None;
    }
    let axi_ns = 1000.0 / o.job.speed.axi_clock_mhz();
    Some(crate::obs::export::timeline_json(&o.job.label, axi_ns, &series))
}

/// Write per-job JSON + CSV artifacts (plus `{stem}_timeline.json`
/// time-series artifacts when the jobs recorded telemetry) and the
/// campaign summary into `dir` (created if missing). Returns the
/// summary path.
pub fn write_artifacts(outcomes: &[SweepOutcome], dir: &Path) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    for o in outcomes {
        let stem = artifact_stem(o);
        std::fs::write(dir.join(format!("{stem}.json")), job_json(o))?;
        std::fs::write(dir.join(format!("{stem}.csv")), job_csv(o))?;
        if let Some(timeline) = timeline_artifact(o) {
            std::fs::write(dir.join(format!("{stem}_timeline.json")), timeline)?;
        }
        if let Some(audit) = &o.audit {
            std::fs::write(dir.join(format!("{stem}_audit.txt")), audit)?;
        }
    }
    let summary = dir.join("BENCH_sweep.json");
    std::fs::write(&summary, summary_json(outcomes, "ddr4bench sweep executive (simulator)"))?;
    Ok(summary)
}

/// Human-readable summary table of a finished sweep.
pub fn summary_table(outcomes: &[SweepOutcome]) -> Table {
    let mut t = Table::new(
        "Campaign sweep summary",
        &[
            "Job", "Rate", "Ch", "Pattern", "Map", "Knobs", "Sched", "RD GB/s", "WR GB/s",
            "Total GB/s", "p99 ns", "Wall ms",
        ],
    );
    for o in outcomes {
        let p99 = o.agg.read_latency_pct_ns(99.0).max(o.agg.write_latency_pct_ns(99.0));
        t.row(vec![
            o.job.id.to_string(),
            o.job.speed.to_string(),
            o.job.channels.to_string(),
            o.job.label.clone(),
            o.job.mapping.name(),
            o.job.knob.clone(),
            o.job.sched.name(),
            format!("{:.2}", o.agg.read_throughput_gbs()),
            format!("{:.2}", o.agg.write_throughput_gbs()),
            format!("{:.2}", o.agg.total_throughput_gbs()),
            format!("{:.0}", p99),
            format!("{:.1}", o.wall_ms),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_grid_expands_to_12_unique_jobs() {
        let jobs = SweepSpec::paper_grid().expand();
        assert_eq!(jobs.len(), 12, "2 speeds x 2 channel counts x 3 patterns");
        let keys: HashSet<_> =
            jobs.iter().map(|j| (j.speed.data_rate_mts(), j.channels, j.label.clone())).collect();
        assert_eq!(keys.len(), 12, "all unique");
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.id, i, "ids are dense and ordered");
            assert!(j.cfg.validate().is_ok());
        }
    }

    #[test]
    fn expand_dedups_repeated_axes() {
        let mut spec = SweepSpec::paper_grid();
        spec.speeds = vec![SpeedBin::Ddr4_1600, SpeedBin::Ddr4_1600];
        spec.channels = vec![1, 1];
        spec.mappings = vec![MappingPolicy::row_col_bank(), MappingPolicy::row_col_bank()];
        assert_eq!(spec.expand().len(), 3, "duplicates collapse");
    }

    #[test]
    fn mapping_and_knob_axes_multiply_the_grid() {
        let mut spec = SweepSpec::paper_grid();
        spec.speeds = vec![SpeedBin::Ddr4_1600];
        spec.channels = vec![1];
        spec.mappings = vec![MappingPolicy::row_col_bank(), MappingPolicy::xor_hash()];
        spec.knobs = parse_knob_list("lookahead=1,lookahead=8").unwrap();
        let jobs = spec.expand();
        assert_eq!(jobs.len(), 2 * 2 * 3, "2 mappings x 2 knob profiles x 3 patterns");
        let maps: HashSet<String> = jobs.iter().map(|j| j.mapping.name()).collect();
        assert_eq!(maps, HashSet::from(["row_col_bank".into(), "xor_hash".into()]));
        let knobs: HashSet<&str> = jobs.iter().map(|j| j.knob.as_str()).collect();
        assert_eq!(knobs, HashSet::from(["lookahead1", "lookahead8"]));
        assert!(jobs.iter().any(|j| j.params.lookahead == 1));
        assert!(jobs.iter().any(|j| j.params.lookahead == 8));
    }

    #[test]
    fn spec_parse_overrides_and_defaults() {
        let spec = SweepSpec::parse(
            "speeds = 1866\nchannels = 3\nmappings = bank_row_col, xor\n\
             [patterns]\nmine = OP=W ADDR=BANK SEED=2 BATCH=64\n\
             [knobs]\ndeep = lookahead=8 rq=32 wq=32 whi=24 wlo=8\n",
        )
        .unwrap();
        assert_eq!(spec.speeds, vec![SpeedBin::Ddr4_1866]);
        assert_eq!(spec.channels, vec![3]);
        assert_eq!(
            spec.mappings,
            vec![MappingPolicy::bank_row_col(), MappingPolicy::xor_hash()]
        );
        assert_eq!(spec.patterns.len(), 1);
        assert_eq!(spec.patterns[0].0, "mine");
        assert_eq!(spec.knobs.len(), 1);
        assert_eq!(spec.knobs[0].0, "deep");
        assert_eq!(spec.knobs[0].1.lookahead, 8);
        assert_eq!(spec.knobs[0].1.write_drain_high, 24);
        let jobs = spec.expand();
        assert_eq!(jobs.len(), 2, "1 speed x 1 ch x 2 mappings x 1 knob x 1 pattern");
        assert_eq!(jobs[0].label, "mine");
    }

    #[test]
    fn spec_parse_rejects_bad_axes() {
        assert!(SweepSpec::parse("speeds = 9999\n").is_err());
        assert!(SweepSpec::parse("channels = 4\n").is_err());
        assert!(SweepSpec::parse("mappings = nope\n").is_err());
        assert!(SweepSpec::parse("[patterns]\nx = ADDR=NOPE\n").is_err());
        assert!(SweepSpec::parse("[knobs]\nx = frobnicate=1\n").is_err());
        // knob profiles that cannot build a valid design fail at parse
        assert!(SweepSpec::parse("[knobs]\nbad = whi=4 wlo=12\n").is_err());
        // typo'd keys must fail loudly, not silently run the default grid
        assert!(SweepSpec::parse("speed = 1866\n").is_err());
        assert!(SweepSpec::parse("[pattern]\nx = OP=R\n").is_err());
        // a pattern-level MAP= would shadow the mappings axis and
        // mislabel every artifact — rejected at parse time
        assert!(SweepSpec::parse("[patterns]\nx = OP=R MAP=xor_hash\n").is_err());
    }

    #[test]
    fn run_job_strips_pattern_level_mapping_overrides() {
        // programmatic specs bypass parse(): the job axis must still win
        let mut spec = SweepSpec::paper_grid();
        spec.speeds = vec![SpeedBin::Ddr4_1600];
        spec.channels = vec![1];
        spec.patterns = vec![preset("bank").unwrap()];
        spec.patterns[0].1.batch_len = 32;
        spec.patterns[0].1.mapping = Some(MappingPolicy::xor_hash());
        let baseline = {
            let mut s = spec.clone();
            s.patterns[0].1.mapping = None;
            run_sweep(s.expand(), 1).unwrap()
        };
        let outcomes = run_sweep(spec.expand(), 1).unwrap();
        assert_eq!(outcomes[0].job.cfg.mapping, None, "override stripped from the echo");
        assert_eq!(
            outcomes[0].agg.counters.total_cycles, baseline[0].agg.counters.total_cycles,
            "job ran under the axis policy, not the stray override"
        );
    }

    #[test]
    fn sched_axis_multiplies_the_grid_and_labels_jobs() {
        let mut spec = SweepSpec::paper_grid();
        spec.speeds = vec![SpeedBin::Ddr4_1600];
        spec.channels = vec![1];
        spec.scheds = parse_sched_list("fcfs, frfcfs, frfcfs-cap, closed, adaptive").unwrap();
        let jobs = spec.expand();
        assert_eq!(jobs.len(), 5 * 3, "5 policies x 3 patterns");
        let scheds: HashSet<String> = jobs.iter().map(|j| j.sched.name()).collect();
        assert_eq!(scheds.len(), 5);
        assert!(scheds.contains("frfcfs-cap"));
        // spec files drive the same axis
        let spec = SweepSpec::parse("scheds = fcfs, closed\n").unwrap();
        assert_eq!(spec.scheds, vec![SchedKind::Fcfs, SchedKind::Closed]);
        assert!(SweepSpec::parse("scheds = nope\n").is_err());
        // a pattern-level SCHED= would shadow the axis — rejected
        assert!(SweepSpec::parse("[patterns]\nx = OP=R SCHED=fcfs\n").is_err());
        assert!(parse_sched_list("frfcfs-cap0").is_err());
        // ...and so would a knob-profile sched=: the axis would silently
        // overwrite it and mislabel every artifact — rejected too
        assert!(SweepSpec::parse("[knobs]\nx = sched=closed\n").is_err());
        assert!(parse_knob_list("sched=closed").is_err());
        assert!(parse_knob_list("lookahead=8+policy=fcfs").is_err());
    }

    #[test]
    fn run_job_strips_pattern_level_sched_overrides() {
        // programmatic specs bypass parse(): the job axis must still win
        let mut spec = SweepSpec::paper_grid();
        spec.speeds = vec![SpeedBin::Ddr4_1600];
        spec.channels = vec![1];
        spec.scheds = vec![SchedKind::Closed];
        spec.patterns = vec![preset("seq").unwrap()];
        spec.patterns[0].1.batch_len = 64;
        spec.patterns[0].1.sched = Some(SchedKind::Fcfs);
        let outcomes = run_sweep(spec.expand(), 1).unwrap();
        assert_eq!(outcomes[0].job.cfg.sched, None, "override stripped from the echo");
        assert_eq!(outcomes[0].job.sched, SchedKind::Closed);
    }

    #[test]
    fn knob_list_parses_compound_variants() {
        let knobs = parse_knob_list("lookahead=8+wq=32, dwell=0").unwrap();
        assert_eq!(knobs.len(), 2);
        assert_eq!(knobs[0].0, "lookahead8_wq32");
        assert_eq!(knobs[0].1.lookahead, 8);
        assert_eq!(knobs[0].1.write_queue_depth, 32);
        assert_eq!(knobs[1].0, "dwell0");
        assert_eq!(knobs[1].1.mode_dwell_ck, 0);
        assert!(parse_knob_list("nope=1").is_err());
        assert!(parse_knob_list("whi=4+wlo=12").is_err(), "invalid watermark profile");
    }

    #[test]
    fn mapping_list_parses_builtins_and_customs() {
        let maps = parse_mapping_list("row_col_bank, xor, RoBaBgCo").unwrap();
        assert_eq!(maps.len(), 3);
        assert_eq!(maps[1], MappingPolicy::xor_hash());
        assert!(parse_mapping_list("frob").is_err());
    }

    #[test]
    fn presets_cover_all_pattern_names() {
        for name in ["seq", "rnd", "strided", "bank", "chase", "phased"] {
            let (label, cfg) = preset(name).unwrap();
            assert_eq!(label, name);
            assert!(cfg.validate().is_ok(), "{name}");
        }
        assert!(preset("nope").is_none());
        // aliases canonicalize, so alias pairs dedup to one job
        assert_eq!(preset("stride").unwrap().0, "strided");
        assert_eq!(preset("bankconflict").unwrap().0, "bank");
        assert_eq!(preset("pointerchase").unwrap().0, "chase");
    }

    #[test]
    fn small_sweep_runs_in_parallel_and_orders_results() {
        let mut spec = SweepSpec::paper_grid();
        spec.speeds = vec![SpeedBin::Ddr4_1600];
        spec.channels = vec![1];
        // shrink the presets so the unit test stays fast
        for (_, cfg) in &mut spec.patterns {
            cfg.batch_len = 64;
        }
        let jobs = spec.expand();
        let n = jobs.len();
        let outcomes = run_sweep(jobs, 4).unwrap();
        assert_eq!(outcomes.len(), n);
        for (i, o) in outcomes.iter().enumerate() {
            assert_eq!(o.job.id, i, "results sorted by job id");
            let c = &o.agg.counters;
            assert_eq!(c.rd_txns + c.wr_txns, 64, "{}: counters conserve", o.job.label);
            assert!(o.agg.total_throughput_gbs() > 0.0);
            assert!(o.wall_ms >= 0.0);
        }
    }

    #[test]
    fn json_and_csv_artifacts_well_formed() {
        let mut spec = SweepSpec::paper_grid();
        spec.speeds = vec![SpeedBin::Ddr4_1600];
        spec.channels = vec![1];
        spec.patterns = vec![preset("bank").unwrap()];
        spec.patterns[0].1.batch_len = 32;
        let outcomes = run_sweep(spec.expand(), 1).unwrap();
        let j = job_json(&outcomes[0]);
        assert!(j.contains("\"schema\": \"ddr4bench.sweep.v4\""));
        assert!(j.contains("\"pattern\": \"bank\""));
        assert!(j.contains("\"mapping\": \"row_col_bank\""));
        assert!(j.contains("\"knobs\": \"mig\""));
        assert!(j.contains("\"sched\": \"frfcfs\""));
        assert!(j.contains("\"mix\": \"\""), "uniform jobs carry an empty mix: {j}");
        assert!(j.contains("\"total_gbs\""));
        assert!(j.contains("\"rd_p99_ns\""), "percentiles reach the artifact: {j}");
        let c = job_csv(&outcomes[0]);
        let lines: Vec<&str> = c.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0].split(',').count(), lines[1].split(',').count());
        let s = summary_json(&outcomes, "test");
        assert!(s.contains("\"jobs\": ["));
        assert!(s.trim_end().ends_with('}'));
    }

    #[test]
    fn json_escape_controls_and_quotes() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }

    fn mini_mix() -> ChannelMix {
        parse_channel_mix(&["0:SEQ,BURST=32,BATCH=64", "1:CHASE,WSET=64k,BURST=1,BATCH=32"])
            .unwrap()
    }

    #[test]
    fn mixes_axis_expands_with_own_channel_count() {
        let mut spec = SweepSpec::paper_grid();
        spec.speeds = vec![SpeedBin::Ddr4_1600, SpeedBin::Ddr4_2400];
        spec.channels = vec![1, 2, 3];
        spec.patterns = vec![preset("seq").unwrap()];
        spec.mixes = vec![("hetero".to_string(), mini_mix())];
        let jobs = spec.expand();
        // 2 speeds x (3 channel counts x 1 pattern + 1 mix): mixes do NOT
        // multiply with the channels axis
        assert_eq!(jobs.len(), 2 * (3 + 1));
        let mix_jobs: Vec<_> = jobs.iter().filter(|j| j.mix.is_some()).collect();
        assert_eq!(mix_jobs.len(), 2);
        for j in &mix_jobs {
            assert_eq!(j.channels, 2, "mix fixes its own channel count");
            assert_eq!(j.label, "hetero");
        }
        // duplicate mixes collapse
        spec.mixes.push(("hetero".to_string(), mini_mix()));
        assert_eq!(spec.expand().len(), jobs.len());
    }

    #[test]
    fn mix_jobs_run_and_emit_v4_artifacts() {
        let mut spec = SweepSpec::paper_grid();
        spec.speeds = vec![SpeedBin::Ddr4_1600];
        spec.channels = vec![1];
        spec.patterns = vec![preset("seq").unwrap()];
        spec.patterns[0].1.batch_len = 32;
        spec.mixes = vec![("hetero".to_string(), mini_mix())];
        let outcomes = run_sweep(spec.expand(), 2).unwrap();
        let mix_outcome = outcomes.iter().find(|o| o.job.mix.is_some()).unwrap();
        assert_eq!(mix_outcome.per_channel.len(), 2);
        assert_eq!(mix_outcome.per_channel[0].counters.rd_txns, 64, "seq channel");
        assert_eq!(mix_outcome.per_channel[1].counters.rd_txns, 32, "chase channel");
        let j = job_json(mix_outcome);
        assert!(j.contains("\"mix\": \"0:"), "mix spec reaches the artifact: {j}");
        assert!(j.contains("\"channels\": 2"), "{j}");
        let c = job_csv(mix_outcome);
        assert!(c.contains("\"0:"), "comma-bearing mix spec is quoted in CSV: {c}");
    }

    #[test]
    fn spec_and_cli_mixes_parse_and_reject_overrides() {
        let spec = SweepSpec::parse(
            "speeds = 1600\n[mixes]\nhetero = 0:SEQ,BURST=32,BATCH=64 \
             1:BANK,SEED=2,BURST=1,BATCH=32\n",
        )
        .unwrap();
        assert_eq!(spec.mixes.len(), 1);
        assert_eq!(spec.mixes[0].0, "hetero");
        assert_eq!(spec.mixes[0].1.len(), 2);
        // per-channel MAP=/SCHED= would shadow the axes — rejected
        assert!(SweepSpec::parse("[mixes]\nx = 0:SEQ 1:RND,MAP=xor_hash\n").is_err());
        assert!(SweepSpec::parse("[mixes]\nx = 0:SEQ,SCHED=fcfs 1:RND\n").is_err());
        assert!(SweepSpec::parse("[mixes]\nx = 1:SEQ\n").is_err(), "sparse channels");
        // CLI --mixes: ;-separated mixes of +-joined channel specs
        let mixes =
            parse_mix_list("0:SEQ,BURST=32+1:CHASE,WSET=64k;0:SEQ+1:CHASE,WSET=1m").unwrap();
        assert_eq!(mixes.len(), 2);
        assert_eq!(mixes[0].0, "seq+chase");
        assert_eq!(mixes[1].0, "seq+chase_2", "label collision gets a suffix");
        assert!(parse_mix_list("0:SEQ+1:RND,SCHED=closed").is_err());
        assert!(parse_mix_list("").is_err());
        assert!(parse_mix_list("0:NOPE").is_err());
    }

    #[test]
    fn engine_key_parses_and_rejects_unknown() {
        let spec = SweepSpec::parse("engine = event\n").unwrap();
        assert_eq!(spec.engine, EngineKind::Event);
        assert!(spec.expand().iter().all(|j| j.engine == EngineKind::Event));
        assert_eq!(SweepSpec::parse("speeds = 1600\n").unwrap().engine, EngineKind::Cycle);
        let err = SweepSpec::parse("engine = wheel\n").unwrap_err().to_string();
        assert!(err.contains("unknown engine `wheel`"), "{err}");
    }

    #[test]
    fn engines_produce_identical_artifacts_modulo_wall_clock() {
        // The whole point of the event core: same spec, same artifact
        // stems, bit-identical measurements — only wall_ms may differ.
        let mut spec = SweepSpec::paper_grid();
        spec.speeds = vec![SpeedBin::Ddr4_1600];
        spec.channels = vec![1];
        spec.patterns = vec![preset("bank").unwrap(), preset("chase").unwrap()];
        for (_, cfg) in &mut spec.patterns {
            cfg.batch_len = 64;
        }
        spec.mixes = vec![("hetero".to_string(), mini_mix())];
        spec.telemetry = Some(128);
        let cycle = run_sweep(spec.expand(), 1).unwrap();
        spec.engine = EngineKind::Event;
        let event = run_sweep(spec.expand(), 1).unwrap();
        assert_eq!(cycle.len(), event.len());
        for (a, b) in cycle.iter().zip(&event) {
            assert_eq!(artifact_stem(a), artifact_stem(b), "stems label identically");
            assert_eq!(a.per_channel.len(), b.per_channel.len());
            for (ca, cb) in a.per_channel.iter().zip(&b.per_channel) {
                assert_eq!(ca.counters, cb.counters, "{}: counters diverge", a.job.label);
                assert_eq!(ca.telemetry, cb.telemetry, "{}: series diverge", a.job.label);
            }
            // artifact JSON is byte-identical except the wall_ms line
            let strip = |o: &SweepOutcome| -> String {
                job_json(o).lines().filter(|l| !l.contains("\"wall_ms\"")).collect()
            };
            assert_eq!(strip(a), strip(b), "{}: artifact JSON diverges", a.job.label);
            // ...and the timeline artifact is byte-identical, full stop
            let ta = timeline_artifact(a).expect("telemetry sweep emits timelines");
            assert_eq!(ta, timeline_artifact(b).unwrap(), "{}: timelines", a.job.label);
        }
    }

    #[test]
    fn telemetry_key_records_timelines_without_perturbing_measurements() {
        let mut spec = SweepSpec::paper_grid();
        spec.speeds = vec![SpeedBin::Ddr4_1600];
        spec.channels = vec![1];
        spec.patterns = vec![preset("bank").unwrap()];
        spec.patterns[0].1.batch_len = 64;
        let baseline = run_sweep(spec.expand(), 1).unwrap();
        assert!(timeline_artifact(&baseline[0]).is_none(), "no window, no timeline");
        spec.telemetry = Some(128);
        let outcomes = run_sweep(spec.expand(), 1).unwrap();
        assert_eq!(
            baseline[0].agg.counters, outcomes[0].agg.counters,
            "telemetry is observation-only across the sweep executive"
        );
        let timeline = timeline_artifact(&outcomes[0]).unwrap();
        assert!(timeline.contains("\"schema\": \"ddr4bench.timeline.v1\""), "{timeline}");
        assert!(timeline.contains("\"window_axi_cycles\": 128"), "{timeline}");
        assert!(timeline.contains("\"bw_gbs\""), "{timeline}");
        // the spec key parses (with suffixes) and rejects a zero window
        let spec = SweepSpec::parse("telemetry = 4k\n").unwrap();
        assert_eq!(spec.telemetry, Some(4096));
        assert!(spec.expand().iter().all(|j| j.telemetry == Some(4096)));
        assert_eq!(SweepSpec::parse("speeds = 1600\n").unwrap().telemetry, None);
        assert!(SweepSpec::parse("telemetry = 0\n").is_err());
        assert!(SweepSpec::parse("telemetry = abc\n").is_err());
        // a pattern- or mix-level TELEM= would shadow the sweep-level
        // window and mislabel the timelines — rejected like MAP=/SCHED=
        assert!(SweepSpec::parse("[patterns]\nx = OP=R TELEM=64\n").is_err());
        assert!(SweepSpec::parse("[mixes]\nx = 0:SEQ 1:RND,TELEM=64\n").is_err());
        assert!(parse_mix_list("0:SEQ+1:RND,TELEM=64").is_err());
    }

    #[test]
    fn run_job_strips_pattern_level_telemetry_overrides() {
        // programmatic specs bypass parse(): the job-level window wins
        let mut spec = SweepSpec::paper_grid();
        spec.speeds = vec![SpeedBin::Ddr4_1600];
        spec.channels = vec![1];
        spec.patterns = vec![preset("seq").unwrap()];
        spec.patterns[0].1.batch_len = 32;
        spec.patterns[0].1.telemetry = Some(64);
        let outcomes = run_sweep(spec.expand(), 1).unwrap();
        assert_eq!(outcomes[0].job.cfg.telemetry, None, "override stripped from the echo");
        assert!(timeline_artifact(&outcomes[0]).is_none(), "spec-level window was unset");
    }

    #[test]
    fn run_job_strips_pattern_level_engine_overrides() {
        let mut spec = SweepSpec::paper_grid();
        spec.speeds = vec![SpeedBin::Ddr4_1600];
        spec.channels = vec![1];
        spec.patterns = vec![preset("seq").unwrap()];
        spec.patterns[0].1.batch_len = 32;
        spec.patterns[0].1.engine = Some(EngineKind::Event);
        let outcomes = run_sweep(spec.expand(), 1).unwrap();
        assert_eq!(outcomes[0].job.cfg.engine, None, "override stripped from the echo");
        assert_eq!(outcomes[0].job.engine, EngineKind::Cycle);
    }

    #[test]
    fn job_csv_escapes_every_string_column() {
        // labels with commas and quotes must not shift CSV columns once
        // per-channel mixes are labeled
        let mut spec = SweepSpec::paper_grid();
        spec.speeds = vec![SpeedBin::Ddr4_1600];
        spec.channels = vec![1];
        spec.knobs = vec![("mig,\"deep\"".to_string(), ControllerParams::default())];
        spec.patterns = vec![("a,b\"c".to_string(), {
            let mut p = preset("seq").unwrap().1;
            p.batch_len = 16;
            p
        })];
        let outcomes = run_sweep(spec.expand(), 1).unwrap();
        let c = job_csv(&outcomes[0]);
        let lines: Vec<&str> = c.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[1].contains("\"a,b\"\"c\""), "label quoted+doubled: {}", lines[1]);
        assert!(lines[1].contains("\"mig,\"\"deep\"\"\""), "knob quoted: {}", lines[1]);
        // parse the row with a minimal quote-aware splitter: the column
        // count must match the header exactly
        let split = |line: &str| {
            let mut fields = 1;
            let mut in_quotes = false;
            for ch in line.chars() {
                match ch {
                    '"' => in_quotes = !in_quotes,
                    ',' if !in_quotes => fields += 1,
                    _ => {}
                }
            }
            fields
        };
        assert_eq!(split(lines[0]), split(lines[1]), "column counts agree");
    }

    #[test]
    fn labels_sanitized_for_files_and_escaped_for_csv() {
        assert_eq!(sanitize_label("chase"), "chase");
        assert_eq!(sanitize_label("../../etc/evil"), ".._.._etc_evil");
        assert_eq!(sanitize_label("a b/c"), "a_b_c");
        assert_eq!(sanitize_label(".."), "pattern");
        assert_eq!(csv_escape("plain"), "plain");
        assert_eq!(csv_escape("a,b"), "\"a,b\"");
        assert_eq!(csv_escape("q\"q"), "\"q\"\"q\"");
    }
}
