//! Shared bounded worker pool for batch execution.
//!
//! The bench server hands every client session its own isolated
//! [`super::Platform`], but lets *execution* go through one process-wide
//! [`RunPool`] so K concurrent sessions cannot oversubscribe the machine:
//! the pool owns `workers` OS threads, full stop, no matter how many
//! sessions are queueing batches. The executor idiom is the same
//! work-stealing shape as [`super::sweep::run_sweep`] — per-worker deques,
//! round-robin submission, steal-from-the-back when idle — but the pool
//! is persistent (the server owns it for its whole lifetime) rather than
//! scoped to one campaign, so idle workers park on a condvar and a
//! `Drop`-driven shutdown flag replaces scope exit.
//!
//! A job ships the channel's *state* (not the whole `Platform` — the
//! platform's PJRT handles are not `Send`, its channel states are) plus
//! the design and pattern, and runs the same
//! [`super::run_batch_on_state`] body as the mix executive's scoped
//! threads, wrapped in `catch_unwind`: a panicking batch becomes that
//! job's error, the worker thread survives, and a client that
//! disconnected mid-run (dropping its reply receiver) is simply ignored —
//! a dead session can never poison the pool.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::config::{DesignConfig, PatternConfig};
use crate::obs::SharedTelemetry;
use crate::stats::BatchStats;

use super::{panic_msg, run_batch_on_state, ChannelState};

/// One dispatched batch: the channel's moved-out state plus everything
/// needed to run it. Created by [`super::Platform::start_batch_on`].
pub(super) struct Job {
    pub ch: usize,
    pub design: DesignConfig,
    pub state: ChannelState,
    pub cfg: PatternConfig,
    /// Shared handle the batch publishes live telemetry snapshots
    /// through (present when the effective telemetry window is set).
    pub live: Option<SharedTelemetry>,
    pub reply: Sender<JobOutcome>,
}

/// What comes back over a job's reply channel. `state` is `Some` only on
/// success — a failed or panicked batch abandons its (torn) state, and
/// the submitting platform keeps the fresh power-on placeholder it
/// installed at dispatch time, which is exactly the reset-on-failure
/// contract of [`super::Platform::run_batch`].
pub(super) struct JobOutcome {
    pub state: Option<ChannelState>,
    pub result: Result<BatchStats>,
}

struct PoolShared {
    /// One deque per worker; submitters round-robin, idle workers steal.
    queues: Vec<Mutex<VecDeque<Job>>>,
    /// Parking lot for idle workers.
    idle: Mutex<()>,
    wake: Condvar,
    shutdown: AtomicBool,
    /// Round-robin submission cursor.
    next: AtomicUsize,
}

/// A persistent bounded pool of batch-executor threads, shared by every
/// session of a bench server. Dropping the pool drains the queues and
/// joins the workers.
pub struct RunPool {
    shared: Arc<PoolShared>,
    workers: Vec<JoinHandle<()>>,
}

impl RunPool {
    /// Spawn a pool with `workers` executor threads (at least one).
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let shared = Arc::new(PoolShared {
            queues: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            idle: Mutex::new(()),
            wake: Condvar::new(),
            shutdown: AtomicBool::new(false),
            next: AtomicUsize::new(0),
        });
        let handles = (0..workers)
            .map(|idx| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("runpool-{idx}"))
                    .spawn(move || worker_loop(&shared, idx))
                    .expect("spawn pool worker")
            })
            .collect();
        Self { shared, workers: handles }
    }

    /// Number of executor threads.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job (round-robin over the worker deques) and wake a
    /// parked worker.
    pub(super) fn submit(&self, job: Job) {
        let idx = self.shared.next.fetch_add(1, Ordering::Relaxed) % self.shared.queues.len();
        self.shared.queues[idx].lock().expect("worker queue mutex poisoned").push_back(job);
        self.shared.wake.notify_all();
    }
}

impl Drop for RunPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.wake.notify_all();
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

fn worker_loop(shared: &PoolShared, idx: usize) {
    loop {
        // Take work before honouring shutdown, so dropping the pool
        // drains already-queued jobs instead of orphaning their replies.
        if let Some(job) = take_job(shared, idx) {
            execute(job);
            continue;
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let guard = shared.idle.lock().expect("idle mutex poisoned");
        let _ = shared
            .wake
            .wait_timeout(guard, Duration::from_millis(50))
            .expect("idle mutex poisoned");
    }
}

/// Pop from the worker's own deque front; steal from other deques' backs.
fn take_job(shared: &PoolShared, idx: usize) -> Option<Job> {
    let n = shared.queues.len();
    for offset in 0..n {
        let qi = (idx + offset) % n;
        let mut q = shared.queues[qi].lock().expect("worker queue mutex poisoned");
        let job = if offset == 0 { q.pop_front() } else { q.pop_back() };
        if job.is_some() {
            return job;
        }
    }
    None
}

fn execute(job: Job) {
    let Job { ch, design, mut state, cfg, live, reply } = job;
    let caught =
        catch_unwind(AssertUnwindSafe(|| run_batch_on_state(&design, &mut state, &cfg, live)));
    let outcome = match caught {
        Ok(Ok(stats)) => JobOutcome { state: Some(state), result: Ok(stats) },
        // failed batch: abandon the torn state (the platform keeps its
        // power-on placeholder — run_batch's reset-on-failure contract)
        Ok(Err(e)) => JobOutcome { state: None, result: Err(e) },
        Err(payload) => JobOutcome {
            state: None,
            result: Err(anyhow!("channel {ch} panicked: {}", panic_msg(payload.as_ref()))),
        },
    };
    // A disconnected receiver means the client went away mid-run: the
    // result is simply dropped; the worker lives on.
    let _ = reply.send(outcome);
}

#[cfg(test)]
mod tests {
    use super::super::Platform;
    use super::*;
    use crate::config::{DesignConfig, SpeedBin};

    #[test]
    fn pooled_batch_matches_inline_counters_bit_for_bit() {
        let design = DesignConfig::single_channel(SpeedBin::Ddr4_1600);
        let cfg = PatternConfig::seq_read_burst(8, 300);
        let mut inline = Platform::new(design.clone());
        let a = inline.run_batch(0, &cfg).unwrap();
        let pool = RunPool::new(2);
        let mut pooled = Platform::new(design);
        let b = pooled.run_batch_on(&pool, 0, &cfg).unwrap();
        assert_eq!(a.counters, b.counters, "pool executor must not perturb the simulation");
    }

    #[test]
    fn warm_state_survives_across_pooled_batches() {
        // The moved-out state is reinstalled on success: memory contents
        // written by batch 1 verify cleanly in batch 2, exactly like the
        // inline path.
        let pool = RunPool::new(1);
        let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        let region = 64 * 4 * 32;
        let mut w = PatternConfig::seq_write_burst(4, 64);
        w.verify = true;
        w.region_bytes = region;
        assert_eq!(p.run_batch_on(&pool, 0, &w).unwrap().counters.mismatches, 0);
        let mut r = PatternConfig::seq_read_burst(4, 64);
        r.verify = true;
        r.region_bytes = region;
        assert_eq!(p.run_batch_on(&pool, 0, &r).unwrap().counters.mismatches, 0);
    }

    #[test]
    fn panicking_job_fails_only_its_batch_and_resets_the_channel() {
        let pool = RunPool::new(1);
        let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        p.inject_channel_panic(0);
        let cfg = PatternConfig::seq_read_burst(4, 64);
        let err = p.run_batch_on(&pool, 0, &cfg).unwrap_err().to_string();
        assert!(err.contains("channel 0 panicked"), "{err}");
        assert!(err.contains("injected channel fault"), "{err}");
        // the worker survived and the channel is back at power-on state
        let s = p.run_batch_on(&pool, 0, &cfg).unwrap();
        assert_eq!(s.counters.rd_txns, 64, "pool keeps serving after a panicked job");
    }

    #[test]
    fn dropped_pending_batch_never_poisons_the_pool() {
        // A client disconnecting mid-run drops its PendingBatch (and with
        // it the reply receiver); the worker's send fails silently and
        // the next submission runs normally.
        let pool = RunPool::new(1);
        let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        let cfg = PatternConfig::seq_read_burst(4, 64);
        let pending = p.start_batch_on(&pool, 0, &cfg).unwrap();
        drop(pending);
        let s = p.run_batch_on(&pool, 0, &cfg).unwrap();
        assert_eq!(s.counters.rd_txns, 64);
    }

    #[test]
    fn drop_drains_queued_jobs_before_joining() {
        let pool = RunPool::new(1);
        let mut p = Platform::new(DesignConfig::with_channels(3, SpeedBin::Ddr4_1600));
        let cfg = PatternConfig::seq_read_burst(4, 64);
        let pendings: Vec<_> =
            (0..3).map(|ch| p.start_batch_on(&pool, ch, &cfg).unwrap()).collect();
        drop(pool);
        for pending in pendings {
            let s = p.finish_batch(pending).unwrap();
            assert_eq!(s.counters.rd_txns, 64, "queued job still ran to completion");
        }
    }
}
