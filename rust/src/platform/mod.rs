//! Design-time composition of the benchmarking platform (Fig. 1 of the
//! paper): per channel one memory interface + one traffic generator,
//! plus the shared host controller on top.
//!
//! [`Platform::run_batch`] is the executive the host controller drives: it
//! instantiates a TG for the requested pattern, runs the two-clock-domain
//! simulation loop (fabric tick : DRAM tick = 1 : 4), and returns the
//! hardware counters as [`BatchStats`]. Channels are fully independent —
//! [`Platform::run_batch_all`] runs the same pattern on every channel (one
//! OS thread each, mirroring the physically parallel channels) and reports
//! per-channel plus aggregate statistics. Whole *campaigns* — cartesian
//! (speed × channels × pattern) grids — run through the [`sweep`]
//! executive's work-stealing pool, one platform instance per job.

pub mod sweep;

pub use sweep::{SweepJob, SweepOutcome, SweepSpec};

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::config::{DesignConfig, PatternConfig};
use crate::controller::MemController;
use crate::ddr4::{TimingParams, AXI_RATIO};
use crate::runtime::XlaRuntime;
use crate::stats::{BatchCounters, BatchStats};
use crate::trafficgen::{payload, DataStore, TrafficGen};

/// Persistent state of one memory channel across batches.
struct ChannelState {
    controller: MemController,
    /// Memory contents survive between batches so write-then-read
    /// verification flows work (the DRAM keeps its data).
    store: Option<DataStore>,
    /// Fabric-cycle clock, monotone across batches.
    axi_now: u64,
}

/// The instantiated benchmarking platform.
pub struct Platform {
    design: DesignConfig,
    channels: Vec<ChannelState>,
    runtime: Option<XlaRuntime>,
}

impl Platform {
    /// Instantiate the design (validates it first).
    pub fn new(design: DesignConfig) -> Self {
        design.validate().expect("invalid design config");
        let timing = TimingParams::for_bin(design.speed);
        let channels = (0..design.channels)
            .map(|_| ChannelState {
                controller: MemController::new(design.controller, timing, design.geometry),
                store: Some(DataStore::new()),
                axi_now: 0,
            })
            .collect();
        Self { design, channels, runtime: None }
    }

    /// Attach the AOT-compiled XLA runtime: payload generation and
    /// verification then run through the PJRT executables instead of the
    /// pure-Rust mirror.
    pub fn with_runtime(mut self, runtime: XlaRuntime) -> Self {
        self.runtime = Some(runtime);
        self
    }

    /// Is an XLA runtime attached?
    pub fn has_runtime(&self) -> bool {
        self.runtime.is_some()
    }

    /// The design in force.
    pub fn design(&self) -> &DesignConfig {
        &self.design
    }

    /// Number of instantiated channels.
    pub fn channels(&self) -> usize {
        self.channels.len()
    }

    /// Inject a fault into channel `ch`'s memory (test/debug hook; proves
    /// the integrity checker detects real corruption).
    pub fn corrupt(&mut self, ch: usize, burst_addr: u64, word: usize, mask: u32) -> bool {
        self.channels[ch]
            .store
            .as_mut()
            .map(|s| s.corrupt_word(burst_addr, word, mask))
            .unwrap_or(false)
    }

    /// Run one batch of `cfg` on channel `ch` and return its statistics.
    pub fn run_batch(&mut self, ch: usize, cfg: &PatternConfig) -> Result<BatchStats> {
        self.run_batch_with_plan(ch, cfg, None)
    }

    fn run_batch_with_plan(
        &mut self,
        ch: usize,
        cfg: &PatternConfig,
        plan: Option<Vec<crate::trafficgen::PlannedTxn>>,
    ) -> Result<BatchStats> {
        if ch >= self.channels.len() {
            bail!("channel {ch} out of range (design has {})", self.channels.len());
        }
        cfg.validate()?;
        let design = self.design.clone();
        // The pattern's MAP= override re-maps the channel for this batch:
        // the TG's request decode and the geometry-derived adversarial
        // streams both follow the effective policy.
        let mut geometry = design.geometry;
        if let Some(m) = cfg.mapping {
            geometry.mapping = m;
        }
        // Likewise SCHED=: swap the controller's scheduling policy for
        // this batch (always set, so an earlier batch's override cannot
        // leak into a batch that didn't ask for one).
        self.channels[ch]
            .controller
            .set_sched(cfg.sched.unwrap_or(design.controller.sched));
        let mut tg = TrafficGen::with_frontend(
            cfg.clone(),
            design.axi_beat_bytes(),
            geometry,
            design.controller.outstanding_cap,
            design.controller.addr_cmd_interval_axi,
            design.controller.serial_frontend,
        );
        if let Some(plan) = plan {
            tg = tg.with_plan(plan);
        }
        // Carry the channel's memory contents into the TG.
        if cfg.verify {
            tg.store = self.channels[ch].store.take().or_else(|| Some(DataStore::new()));
            // Pre-generate write payloads through the XLA data path.
            if self.runtime.is_some() {
                let map = self.datagen_for_plan(&tg)?;
                tg.payload_map = Some(map);
            }
        }

        let state = &mut self.channels[ch];
        let refresh_before = state.controller.stats().refresh_stall_cycles;
        let dev_before = *state.controller.device().stats();
        let start_axi = state.axi_now;
        // Deadlock guard: generous upper bound on the batch runtime.
        let limit = start_axi
            + 2_000_000
            + cfg.batch_len as u64 * (cfg.burst.len as u64 + 4) * 64;
        let mut comps = Vec::with_capacity(16);
        while !tg.is_done() {
            if state.axi_now >= limit {
                bail!(
                    "batch deadlock: {}/{} txns after {} fabric cycles",
                    tg.completed(),
                    cfg.batch_len,
                    state.axi_now - start_axi
                );
            }
            let now = state.axi_now - start_axi; // TG counts batch-relative
            comps.clear();
            state.controller.pop_completions(state.axi_now * AXI_RATIO, &mut comps);
            tg.on_completions(&comps, now);
            tg.tick_axi(now, state.axi_now * AXI_RATIO, &mut state.controller);
            let dram_base = state.axi_now * AXI_RATIO;
            for s in 0..AXI_RATIO {
                state.controller.tick(dram_base + s);
            }
            state.axi_now += 1;
        }
        let mut counters = std::mem::take(&mut tg.counters);
        counters.refresh_stall_dram_cycles =
            state.controller.stats().refresh_stall_cycles - refresh_before;
        let energy = crate::ddr4::power::channel_energy(
            &state.controller.device().stats().delta(&dev_before),
            (state.axi_now - start_axi) * AXI_RATIO,
            design.speed,
            state.controller.device().timing(),
            &crate::ddr4::power::IddSpec::micron_4gb_x16(),
        );

        // Verification: XLA path when attached, Rust mirror otherwise.
        if cfg.verify {
            counters.mismatches += self.verify_readback(&mut tg, cfg)?;
            self.channels[ch].store = tg.store.take();
        }
        Ok(BatchStats { counters, speed: design.speed, energy })
    }

    /// Replay a memory-access trace on channel `ch` (one AXI transaction
    /// per record; uniform burst length — see `trafficgen::trace`).
    pub fn run_trace(
        &mut self,
        ch: usize,
        records: &[crate::trafficgen::trace::TraceRecord],
        verify: bool,
    ) -> Result<BatchStats> {
        let (plan, beats) = crate::trafficgen::trace::plan_from_trace(records)?;
        let mut cfg = PatternConfig::seq_read_burst(beats, plan.len() as u32);
        cfg.op = crate::config::OpMix::Mixed { read_pct: 50 }; // plan overrides
        cfg.verify = verify;
        self.run_batch_with_plan(ch, &cfg, Some(plan))
    }

    /// Run `cfg` on every channel (one thread per channel, mirroring the
    /// physical parallelism) and return per-channel stats.
    pub fn run_batch_all(&mut self, cfg: &PatternConfig) -> Result<Vec<BatchStats>> {
        cfg.validate()?;
        // Channels are architecturally independent; run them one at a
        // time when a runtime is attached (the PJRT client is shared),
        // in parallel threads otherwise.
        if self.runtime.is_some() || self.channels.len() == 1 {
            return (0..self.channels.len()).map(|ch| self.run_batch(ch, cfg)).collect();
        }
        let design = self.design.clone();
        let states: Vec<&mut ChannelState> = self.channels.iter_mut().collect();
        std::thread::scope(|scope| {
            let mut joins = Vec::new();
            for state in states {
                let cfg = cfg.clone();
                let design = design.clone();
                joins.push(scope.spawn(move || run_batch_on_state(&design, state, &cfg)));
            }
            joins
                .into_iter()
                .map(|j| j.join().expect("channel thread panicked"))
                .collect::<Result<Vec<_>>>()
        })
    }

    /// Aggregate per-channel stats: bytes sum, cycles max — the paper's
    /// "dual- and triple-channel setups deliver twice and three times the
    /// throughput" composition.
    pub fn aggregate(stats: &[BatchStats]) -> BatchStats {
        assert!(!stats.is_empty());
        let mut counters = BatchCounters::default();
        let mut energy = crate::ddr4::power::EnergyBreakdown::default();
        for s in stats {
            counters.merge(&s.counters);
            energy.activate_nj += s.energy.activate_nj;
            energy.read_nj += s.energy.read_nj;
            energy.write_nj += s.energy.write_nj;
            energy.refresh_nj += s.energy.refresh_nj;
            energy.background_nj += s.energy.background_nj;
        }
        BatchStats { counters, speed: stats[0].speed, energy }
    }

    /// Pre-generate payload words for every write burst in the TG's plan
    /// via the XLA datagen executable.
    fn datagen_for_plan(
        &self,
        tg: &TrafficGen,
    ) -> Result<HashMap<u64, [u32; payload::WORDS_PER_BURST]>> {
        let rt = self.runtime.as_ref().expect("runtime required");
        let cfg = tg.config();
        let beat_bytes = self.design.axi_beat_bytes();
        let burst_bytes = self.design.geometry.burst_bytes() as u64;
        let pattern_seed = match cfg.data {
            crate::config::DataPattern::Prbs { seed } => seed,
            // Non-PRBS patterns don't use the kernel.
            _ => return Ok(HashMap::new()),
        };
        let mask = !(burst_bytes - 1);
        let mut addrs: Vec<u64> = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for t in tg.plan().iter().filter(|t| t.is_write) {
            let txn = crate::axi::AxiTxn {
                id: 0,
                is_write: true,
                addr: t.addr,
                burst: cfg.burst,
                beat_bytes,
            };
            for i in 0..cfg.burst.len {
                let a = txn.beat_addr(i) & mask;
                if seen.insert(a) {
                    addrs.push(a);
                }
            }
        }
        if addrs.is_empty() {
            return Ok(HashMap::new());
        }
        let seeds: Vec<u32> =
            addrs.iter().map(|&a| payload::burst_seed(a, pattern_seed)).collect();
        let words = rt.datagen(&seeds)?;
        let mut map = HashMap::with_capacity(addrs.len());
        for (i, &a) in addrs.iter().enumerate() {
            let mut w = [0u32; payload::WORDS_PER_BURST];
            w.copy_from_slice(&words[i * 16..(i + 1) * 16]);
            map.insert(a, w);
        }
        Ok(map)
    }

    /// Verify collected read-back samples (XLA verify executable when
    /// attached, Rust mirror otherwise). Returns the mismatch count.
    fn verify_readback(&self, tg: &mut TrafficGen, cfg: &PatternConfig) -> Result<u64> {
        let pattern_seed = match cfg.data {
            crate::config::DataPattern::Prbs { seed } => seed,
            _ => {
                return Ok(tg.verify_readback_rust());
            }
        };
        // Only bursts that were actually written are checkable.
        let store = tg.store.as_ref().expect("verify requires a store");
        let samples: Vec<_> =
            tg.readback.iter().filter(|(a, _)| store.is_written(*a)).collect();
        if samples.is_empty() {
            return Ok(0);
        }
        match &self.runtime {
            Some(rt) => {
                let seeds: Vec<u32> =
                    samples.iter().map(|(a, _)| payload::burst_seed(*a, pattern_seed)).collect();
                let mut data = Vec::with_capacity(samples.len() * 16);
                for (_, words) in &samples {
                    data.extend_from_slice(words);
                }
                rt.verify(&seeds, &data)
            }
            None => Ok({
                let m = tg.verify_readback_rust();
                m
            }),
        }
    }
}

/// Free-function batch runner over a borrowed channel state (thread body
/// of [`Platform::run_batch_all`]; Rust-mirror data path only).
fn run_batch_on_state(
    design: &DesignConfig,
    state: &mut ChannelState,
    cfg: &PatternConfig,
) -> Result<BatchStats> {
    let mut geometry = design.geometry;
    if let Some(m) = cfg.mapping {
        geometry.mapping = m;
    }
    state.controller.set_sched(cfg.sched.unwrap_or(design.controller.sched));
    let mut tg = TrafficGen::with_frontend(
        cfg.clone(),
        design.axi_beat_bytes(),
        geometry,
        design.controller.outstanding_cap,
        design.controller.addr_cmd_interval_axi,
        design.controller.serial_frontend,
    );
    if cfg.verify {
        tg.store = state.store.take().or_else(|| Some(DataStore::new()));
    }
    let refresh_before = state.controller.stats().refresh_stall_cycles;
    let dev_before = *state.controller.device().stats();
    let start_axi = state.axi_now;
    let limit =
        start_axi + 2_000_000 + cfg.batch_len as u64 * (cfg.burst.len as u64 + 4) * 64;
    let mut comps = Vec::with_capacity(16);
    while !tg.is_done() {
        if state.axi_now >= limit {
            bail!("batch deadlock on threaded channel");
        }
        let now = state.axi_now - start_axi;
        comps.clear();
        state.controller.pop_completions(state.axi_now * AXI_RATIO, &mut comps);
        tg.on_completions(&comps, now);
        tg.tick_axi(now, state.axi_now * AXI_RATIO, &mut state.controller);
        let dram_base = state.axi_now * AXI_RATIO;
        for s in 0..AXI_RATIO {
            state.controller.tick(dram_base + s);
        }
        state.axi_now += 1;
    }
    let mut counters = std::mem::take(&mut tg.counters);
    counters.refresh_stall_dram_cycles =
        state.controller.stats().refresh_stall_cycles - refresh_before;
    let energy = crate::ddr4::power::channel_energy(
        &state.controller.device().stats().delta(&dev_before),
        (state.axi_now - start_axi) * AXI_RATIO,
        design.speed,
        state.controller.device().timing(),
        &crate::ddr4::power::IddSpec::micron_4gb_x16(),
    );
    if cfg.verify {
        counters.mismatches += tg.verify_readback_rust();
        state.store = tg.store.take();
    }
    Ok(BatchStats { counters, speed: design.speed, energy })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AddrMode, SpeedBin};

    #[test]
    fn single_channel_seq_read_throughput_sane() {
        let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        let stats = p.run_batch(0, &PatternConfig::seq_read_burst(32, 2000)).unwrap();
        let gbs = stats.read_throughput_gbs();
        // Bus ceiling is 6.4 GB/s; paper measures 6.27 for MB reads.
        assert!(gbs > 5.0 && gbs <= 6.4, "seq MB read = {gbs:.2} GB/s");
    }

    #[test]
    fn random_single_much_slower_than_seq() {
        let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        let seq = p.run_batch(0, &PatternConfig::seq_read_burst(1, 2000)).unwrap();
        let rnd = p.run_batch(0, &PatternConfig::rnd_read_burst(1, 2000, 3)).unwrap();
        let ratio = seq.read_throughput_gbs() / rnd.read_throughput_gbs();
        assert!(ratio > 3.0, "seq/rnd singles ratio = {ratio:.2} (paper: 5.5x)");
    }

    #[test]
    fn channel_out_of_range_rejected() {
        let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        assert!(p.run_batch(1, &PatternConfig::default()).is_err());
    }

    #[test]
    fn multi_channel_scales_throughput() {
        let mut p = Platform::new(DesignConfig::with_channels(3, SpeedBin::Ddr4_1600));
        let per = p.run_batch_all(&PatternConfig::seq_read_burst(32, 1000)).unwrap();
        assert_eq!(per.len(), 3);
        let agg = Platform::aggregate(&per);
        let single = per[0].read_throughput_gbs();
        let total = agg.read_throughput_gbs();
        assert!(
            (total / single - 3.0).abs() < 0.2,
            "triple-channel scaling: {total:.2} vs 3x{single:.2}"
        );
    }

    #[test]
    fn write_then_read_verify_clean_and_fault_detected() {
        let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        let region = 64 * 4 * 32; // small region fully covered
        let mut w = PatternConfig::seq_write_burst(4, 64);
        w.verify = true;
        w.region_bytes = region;
        let ws = p.run_batch(0, &w).unwrap();
        assert_eq!(ws.counters.mismatches, 0);
        let mut r = PatternConfig::seq_read_burst(4, 64);
        r.verify = true;
        r.region_bytes = region;
        let rs = p.run_batch(0, &r).unwrap();
        assert_eq!(rs.counters.mismatches, 0, "clean read-back");
        // corrupt one word and read again
        assert!(p.corrupt(0, 0, 3, 0xFFFF_0000));
        let rs2 = p.run_batch(0, &r).unwrap();
        assert_eq!(rs2.counters.mismatches, 1, "fault detected");
    }

    #[test]
    fn mapping_override_runs_and_never_beats_bank_interleave_on_seq() {
        use crate::ddr4::MappingPolicy;
        let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        let mut gbs = std::collections::BTreeMap::new();
        for policy in MappingPolicy::builtins() {
            let mut cfg = PatternConfig::seq_read_burst(32, 1000);
            cfg.mapping = Some(policy);
            let s = p.run_batch(0, &cfg).unwrap();
            assert_eq!(s.counters.rd_txns, 1000, "{policy}: txns conserve");
            assert!(s.read_throughput_gbs() > 0.0, "{policy}: moved data");
            gbs.insert(policy.name(), s.read_throughput_gbs());
        }
        // bank-interleaved MIG order pipelines ACTs that the row-major
        // orders serialize: it can't lose to them on a sequential stream
        assert!(
            gbs["row_col_bank"] >= gbs["row_bank_col"] - 1e-9,
            "row_col_bank {} vs row_bank_col {}",
            gbs["row_col_bank"],
            gbs["row_bank_col"]
        );
        assert!(gbs["row_col_bank"] >= gbs["bank_row_col"] - 1e-9);
    }

    #[test]
    fn sched_override_runs_and_orders_policies_sanely() {
        use crate::config::SchedKind;
        let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        let mut gbs = std::collections::BTreeMap::new();
        for kind in SchedKind::ALL {
            // serial-front-end singles: row hits across transactions are
            // what separates the page policies
            let mut cfg = PatternConfig::seq_read_burst(1, 800);
            cfg.sched = Some(kind);
            let s = p.run_batch(0, &cfg).unwrap();
            assert_eq!(s.counters.rd_txns, 800, "{kind}: txns conserve");
            assert!(s.read_throughput_gbs() > 0.0, "{kind}: moved data");
            gbs.insert(kind.name(), s.read_throughput_gbs());
        }
        // closed page pays an ACT per transaction on a sequential stream
        // of singles; the open-page FR-FCFS default cannot lose to it
        assert!(
            gbs["frfcfs"] > gbs["closed"],
            "frfcfs {} vs closed {}",
            gbs["frfcfs"],
            gbs["closed"]
        );
        // on pure sequential traffic the reorder window finds no work to
        // reorder: fcfs and the capped variant track the default closely
        assert!(
            gbs["fcfs"] >= gbs["frfcfs"] * 0.95,
            "fcfs {} vs frfcfs {}",
            gbs["fcfs"],
            gbs["frfcfs"]
        );
        // and the override is per batch: the next default batch is frfcfs
        let s = p.run_batch(0, &PatternConfig::seq_read_burst(1, 100)).unwrap();
        assert_eq!(s.counters.rd_txns, 100);
    }

    #[test]
    fn mixed_beats_pure_read_throughput() {
        // Mixed R+W uses both data channels: combined > read-only max.
        let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        let read = p.run_batch(0, &PatternConfig::seq_read_burst(32, 2000)).unwrap();
        let mixed =
            p.run_batch(0, &PatternConfig::mixed(AddrMode::Sequential, 32, 2000)).unwrap();
        assert!(
            mixed.total_throughput_gbs() > read.read_throughput_gbs(),
            "mixed {:.2} vs read {:.2}",
            mixed.total_throughput_gbs(),
            read.read_throughput_gbs()
        );
    }

    #[test]
    fn refresh_degradation_observable() {
        let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        // long enough batch to span several tREFI (6240 DRAM cycles each)
        let stats = p.run_batch(0, &PatternConfig::seq_read_burst(32, 20_000)).unwrap();
        assert!(stats.counters.refresh_stall_dram_cycles > 0);
        let deg = stats.refresh_degradation();
        assert!(deg > 0.0 && deg < 0.2, "refresh degradation {deg:.4}");
    }
}
