//! Design-time composition of the benchmarking platform (Fig. 1 of the
//! paper): per channel one memory interface + one traffic generator,
//! plus the shared host controller on top.
//!
//! [`Platform::run_batch`] is the executive the host controller drives: it
//! instantiates a TG for the requested pattern, runs the two-clock-domain
//! simulation loop (fabric tick : DRAM tick = 1 : 4), and returns the
//! hardware counters as [`BatchStats`]. Channels are fully independent —
//! [`Platform::run_batch_mix`] runs a heterogeneous [`ChannelMix`] (one
//! independent pattern per channel, one OS thread each, mirroring the
//! physically parallel channels) and reports per-channel plus aggregate
//! statistics; [`Platform::run_batch_all`] is the homogeneous special
//! case (the same pattern cloned onto every channel). A panicking channel
//! thread surfaces as that channel's error — the surviving channels'
//! results are still reported ([`Platform::run_batch_mix_results`]).
//! [`interference_matrix`] runs each workload of a mix solo and then
//! co-scheduled pairwise, quantifying cross-channel bandwidth/latency
//! degradation. Whole *campaigns* — cartesian (speed × channels ×
//! pattern/mix) grids — run through the [`sweep`] executive's
//! work-stealing pool, one platform instance per job.
//!
//! For the multi-session bench server, batch execution can instead be
//! dispatched to a shared persistent [`pool::RunPool`]:
//! [`Platform::start_batch_on`] moves the channel's state into a pool
//! job (installing a power-on placeholder meanwhile) and returns a
//! [`PendingBatch`] handle; [`Platform::poll_batch`] /
//! [`Platform::finish_batch`] reinstall the state on success and
//! surface failures with the same reset-on-failure semantics as
//! [`Platform::run_batch`]. [`Platform::start_mix_on`] is the
//! heterogeneous-mix counterpart.

pub mod pool;
pub mod sweep;

pub use pool::RunPool;
pub use sweep::{SweepJob, SweepOutcome, SweepSpec};

use std::collections::HashMap;
use std::sync::mpsc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::check::Auditor;
use crate::config::{ChannelMix, DesignConfig, EngineKind, PatternConfig};
use crate::controller::MemController;
use crate::ddr4::{TimingParams, AXI_RATIO};
use crate::obs::{CmdTrace, Probe, SharedTelemetry, TelemetrySampler};
use crate::runtime::XlaRuntime;
use crate::stats::{BatchCounters, BatchStats};
use crate::trafficgen::{payload, DataStore, TrafficGen};

/// Persistent state of one memory channel across batches.
struct ChannelState {
    controller: MemController,
    /// Memory contents survive between batches so write-then-read
    /// verification flows work (the DRAM keeps its data).
    store: Option<DataStore>,
    /// Fabric-cycle clock, monotone across batches.
    axi_now: u64,
    /// Fault-injection hook: panic at the start of the next threaded
    /// batch on this channel (proves a dying channel thread cannot take
    /// the process — or the other channels' results — down with it).
    panic_inject: bool,
}

/// The instantiated benchmarking platform.
pub struct Platform {
    design: DesignConfig,
    channels: Vec<ChannelState>,
    runtime: Option<XlaRuntime>,
}

impl Platform {
    /// Instantiate the design (validates it first).
    pub fn new(design: DesignConfig) -> Self {
        design.validate().expect("invalid design config");
        let timing = TimingParams::for_bin(design.speed);
        let channels = (0..design.channels)
            .map(|_| ChannelState {
                controller: MemController::new(design.controller, timing, design.geometry),
                store: Some(DataStore::new()),
                axi_now: 0,
                panic_inject: false,
            })
            .collect();
        Self { design, channels, runtime: None }
    }

    /// Attach the AOT-compiled XLA runtime: payload generation and
    /// verification then run through the PJRT executables instead of the
    /// pure-Rust mirror.
    pub fn with_runtime(mut self, runtime: XlaRuntime) -> Self {
        self.runtime = Some(runtime);
        self
    }

    /// Is an XLA runtime attached?
    pub fn has_runtime(&self) -> bool {
        self.runtime.is_some()
    }

    /// The design in force.
    pub fn design(&self) -> &DesignConfig {
        &self.design
    }

    /// Number of instantiated channels.
    pub fn channels(&self) -> usize {
        self.channels.len()
    }

    /// Fault-injection hook (test/debug): channel `ch`'s next batch
    /// panics at entry — proves the mix executive (threaded *and*
    /// serial/runtime paths) converts a dying channel into that
    /// channel's error instead of aborting the process. A direct
    /// [`Self::run_batch`] call outside the mix executive propagates the
    /// panic to its caller.
    pub fn inject_channel_panic(&mut self, ch: usize) {
        self.channels[ch].panic_inject = true;
    }

    /// Arm DRAM command tracing on channel `ch`: from now on every
    /// controller command issue lands in a bounded ring of `cap` events
    /// (oldest evicted first, evictions counted). Arming is idempotent —
    /// a second call keeps the existing ring, so a dump request cannot
    /// clear what an earlier one armed. The ring rides the channel state
    /// (including through pool dispatch) but is lost when the channel is
    /// reset after a failed batch.
    pub fn enable_cmd_trace(&mut self, ch: usize, cap: usize) -> Result<()> {
        if ch >= self.channels.len() {
            bail!("channel {ch} out of range (design has {})", self.channels.len());
        }
        let controller = &mut self.channels[ch].controller;
        if controller.cmd_trace().is_none() {
            controller.enable_cmd_trace(cap);
        }
        Ok(())
    }

    /// Channel `ch`'s command-trace ring, when tracing is armed
    /// (non-destructive read).
    pub fn cmd_trace(&self, ch: usize) -> Option<&CmdTrace> {
        self.channels.get(ch).and_then(|c| c.controller.cmd_trace())
    }

    /// Arm the live protocol auditor on channel `ch`: from now on every
    /// controller command issue is replayed through the independent
    /// JEDEC shadow state machine ([`crate::check`]). Observation-only,
    /// like tracing, and idempotent for the same reason as
    /// [`Self::enable_cmd_trace`] — a summary request cannot clear what
    /// an earlier arming accumulated.
    pub fn enable_audit(&mut self, ch: usize) -> Result<()> {
        if ch >= self.channels.len() {
            bail!("channel {ch} out of range (design has {})", self.channels.len());
        }
        let controller = &mut self.channels[ch].controller;
        if controller.auditor().is_none() {
            controller.enable_audit();
        }
        Ok(())
    }

    /// Channel `ch`'s live auditor, when armed (non-destructive read).
    pub fn auditor(&self, ch: usize) -> Option<&Auditor> {
        self.channels.get(ch).and_then(|c| c.controller.auditor())
    }

    /// Inject a fault into channel `ch`'s memory (test/debug hook; proves
    /// the integrity checker detects real corruption).
    pub fn corrupt(&mut self, ch: usize, burst_addr: u64, word: usize, mask: u32) -> bool {
        self.channels[ch]
            .store
            .as_mut()
            .map(|s| s.corrupt_word(burst_addr, word, mask))
            .unwrap_or(false)
    }

    /// Run one batch of `cfg` on channel `ch` and return its statistics.
    /// A failed batch (e.g. the deadlock guard) resets the channel to
    /// power-on state before returning — the error can abandon the
    /// channel mid-simulation, and reusing that torn state would corrupt
    /// later batches. Config errors are rejected up front, before any
    /// state mutation, so they do *not* clear the channel's memory.
    pub fn run_batch(&mut self, ch: usize, cfg: &PatternConfig) -> Result<BatchStats> {
        if ch >= self.channels.len() {
            bail!("channel {ch} out of range (design has {})", self.channels.len());
        }
        cfg.validate()?;
        let result = self.run_batch_with_plan(ch, cfg, None);
        if result.is_err() {
            self.reset_channel(ch);
        }
        result
    }

    fn run_batch_with_plan(
        &mut self,
        ch: usize,
        cfg: &PatternConfig,
        plan: Option<Vec<crate::trafficgen::PlannedTxn>>,
    ) -> Result<BatchStats> {
        if ch >= self.channels.len() {
            bail!("channel {ch} out of range (design has {})", self.channels.len());
        }
        if self.channels[ch].panic_inject {
            self.channels[ch].panic_inject = false;
            panic!("injected channel fault (Platform::inject_channel_panic test hook)");
        }
        cfg.validate()?;
        let design = self.design.clone();
        // The pattern's MAP= override re-maps the channel for this batch:
        // the TG's request decode and the geometry-derived adversarial
        // streams both follow the effective policy.
        let mut geometry = design.geometry;
        if let Some(m) = cfg.mapping {
            geometry.mapping = m;
        }
        // Likewise SCHED=: swap the controller's scheduling policy for
        // this batch (always set, so an earlier batch's override cannot
        // leak into a batch that didn't ask for one).
        self.channels[ch]
            .controller
            .set_sched(cfg.sched.unwrap_or(design.controller.sched));
        let mut tg = TrafficGen::with_frontend(
            cfg.clone(),
            design.axi_beat_bytes(),
            geometry,
            design.controller.outstanding_cap,
            design.controller.addr_cmd_interval_axi,
            design.controller.serial_frontend,
        );
        if let Some(plan) = plan {
            tg = tg.with_plan(plan);
        }
        // Carry the channel's memory contents into the TG.
        if cfg.verify {
            tg.store = self.channels[ch].store.take().or_else(|| Some(DataStore::new()));
            // Pre-generate write payloads through the XLA data path.
            if self.runtime.is_some() {
                let map = self.datagen_for_plan(&tg)?;
                tg.payload_map = Some(map);
            }
        }

        let engine = cfg.engine.unwrap_or(design.engine);
        let mut sampler = cfg.telemetry.or(design.telemetry).map(TelemetrySampler::new);
        let state = &mut self.channels[ch];
        let refresh_before = state.controller.stats().refresh_stall_cycles;
        let dev_before = *state.controller.device().stats();
        let start_axi = state.axi_now;
        drive_batch(engine, state, &mut tg, cfg, batch_limit(start_axi, cfg), sampler.as_mut())?;
        let telemetry = sampler.as_mut().map(|s| s.take_series());
        let mut counters = std::mem::take(&mut tg.counters);
        counters.refresh_stall_dram_cycles =
            state.controller.stats().refresh_stall_cycles - refresh_before;
        let energy = crate::ddr4::power::channel_energy(
            &state.controller.device().stats().delta(&dev_before),
            (state.axi_now - start_axi) * AXI_RATIO,
            design.speed,
            state.controller.device().timing(),
            &crate::ddr4::power::IddSpec::micron_4gb_x16(),
        );

        // Verification: XLA path when attached, Rust mirror otherwise.
        if cfg.verify {
            counters.mismatches += self.verify_readback(&mut tg, cfg)?;
            self.channels[ch].store = tg.store.take();
        }
        Ok(BatchStats { counters, speed: design.speed, energy, telemetry })
    }

    /// Replay a memory-access trace on channel `ch` (one AXI transaction
    /// per record; uniform burst length — see `trafficgen::trace`).
    pub fn run_trace(
        &mut self,
        ch: usize,
        records: &[crate::trafficgen::trace::TraceRecord],
        verify: bool,
    ) -> Result<BatchStats> {
        let (plan, beats) = crate::trafficgen::trace::plan_from_trace(records)?;
        let mut cfg = PatternConfig::seq_read_burst(beats, plan.len() as u32);
        cfg.op = crate::config::OpMix::Mixed { read_pct: 50 }; // plan overrides
        cfg.verify = verify;
        let result = self.run_batch_with_plan(ch, &cfg, Some(plan));
        if result.is_err() && ch < self.channels.len() {
            self.reset_channel(ch);
        }
        result
    }

    /// Run `cfg` on every channel (one thread per channel, mirroring the
    /// physical parallelism) and return per-channel stats — the
    /// homogeneous special case of [`Self::run_batch_mix`].
    pub fn run_batch_all(&mut self, cfg: &PatternConfig) -> Result<Vec<BatchStats>> {
        let mix = ChannelMix::uniform(cfg, self.channels.len())?;
        self.run_batch_mix(&mix)
    }

    /// Run a heterogeneous [`ChannelMix`] — one independent pattern per
    /// channel, concurrently — and return per-channel stats. Fails if any
    /// channel fails; use [`Self::run_batch_mix_results`] to keep the
    /// surviving channels' results when one errors out.
    pub fn run_batch_mix(&mut self, mix: &ChannelMix) -> Result<Vec<BatchStats>> {
        let results = self.run_batch_mix_results(mix)?;
        let mut stats = Vec::with_capacity(results.len());
        let mut failures = Vec::new();
        for (ch, r) in results.into_iter().enumerate() {
            match r {
                Ok(s) => stats.push(s),
                Err(e) => failures.push(format!("channel {ch}: {e}")),
            }
        }
        if !failures.is_empty() {
            bail!(
                "{} of {} channel(s) failed: {}",
                failures.len(),
                mix.len(),
                failures.join("; ")
            );
        }
        Ok(stats)
    }

    /// Reset channel `ch` to power-on state (fresh controller, cleared
    /// memory, zeroed clock). The mix executive applies this to every
    /// channel whose batch failed: a panic or `bail!` can abandon the
    /// channel mid-simulation (half-mutated queues, a taken store), and
    /// silently reusing that torn state would corrupt later batches.
    fn reset_channel(&mut self, ch: usize) {
        self.channels[ch] = self.fresh_state();
    }

    /// A power-on channel state for this design (fresh controller,
    /// cleared memory, zeroed clock).
    fn fresh_state(&self) -> ChannelState {
        let timing = TimingParams::for_bin(self.design.speed);
        ChannelState {
            controller: MemController::new(self.design.controller, timing, self.design.geometry),
            store: Some(DataStore::new()),
            axi_now: 0,
            panic_inject: false,
        }
    }

    /// Run a heterogeneous [`ChannelMix`] and return each channel's
    /// individual outcome. A panic or error in one channel's thread is
    /// returned as that channel's `Err` — it no longer aborts the process
    /// or discards the other channels' results — and the failed channel
    /// is reset to power-on state so its torn mid-batch state cannot
    /// leak into later batches. The outer `Err` is only for mix-level
    /// configuration problems (width mismatch, invalid per-channel
    /// configs).
    pub fn run_batch_mix_results(&mut self, mix: &ChannelMix) -> Result<Vec<Result<BatchStats>>> {
        let results = self.run_batch_mix_inner(mix)?;
        for (ch, r) in results.iter().enumerate() {
            if r.is_err() {
                self.reset_channel(ch);
            }
        }
        Ok(results)
    }

    fn run_batch_mix_inner(&mut self, mix: &ChannelMix) -> Result<Vec<Result<BatchStats>>> {
        if mix.len() != self.channels.len() {
            bail!(
                "channel mix configures {} channel(s) but the design has {}",
                mix.len(),
                self.channels.len()
            );
        }
        mix.validate()?;
        // Channels are architecturally independent; run them one at a
        // time when a runtime is attached (the PJRT client is shared),
        // in parallel threads otherwise. Panic containment covers both
        // paths: a panicking channel batch becomes that channel's error
        // here too, so a serve session on a 1-channel (or XLA-backed)
        // design survives exactly like the threaded executive.
        if self.runtime.is_some() || self.channels.len() == 1 {
            return Ok((0..self.channels.len())
                .map(|ch| {
                    let cfg = mix.get(ch).expect("mix covers channel");
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        self.run_batch(ch, cfg)
                    }))
                    .unwrap_or_else(|payload| {
                        Err(anyhow!("channel {ch} panicked: {}", panic_msg(payload.as_ref())))
                    })
                })
                .collect());
        }
        let design = self.design.clone();
        let states: Vec<&mut ChannelState> = self.channels.iter_mut().collect();
        Ok(std::thread::scope(|scope| {
            let mut joins = Vec::new();
            for (ch, state) in states.into_iter().enumerate() {
                let cfg = mix.get(ch).expect("mix covers channel").clone();
                let design = design.clone();
                joins.push(scope.spawn(move || {
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        run_batch_on_state(&design, state, &cfg, None)
                    }))
                }));
            }
            joins
                .into_iter()
                .enumerate()
                .map(|(ch, j)| match j.join() {
                    Ok(Ok(result)) => result,
                    Ok(Err(payload)) | Err(payload) => Err(anyhow!(
                        "channel {ch} thread panicked: {}",
                        panic_msg(payload.as_ref())
                    )),
                })
                .collect()
        }))
    }

    /// Aggregate per-channel stats: bytes sum, cycles max — the paper's
    /// "dual- and triple-channel setups deliver twice and three times the
    /// throughput" composition.
    pub fn aggregate(stats: &[BatchStats]) -> BatchStats {
        assert!(!stats.is_empty());
        let mut counters = BatchCounters::default();
        let mut energy = crate::ddr4::power::EnergyBreakdown::default();
        for s in stats {
            counters.merge_concurrent(&s.counters);
            energy.activate_nj += s.energy.activate_nj;
            energy.read_nj += s.energy.read_nj;
            energy.write_nj += s.energy.write_nj;
            energy.refresh_nj += s.energy.refresh_nj;
            energy.background_nj += s.energy.background_nj;
        }
        BatchStats { counters, speed: stats[0].speed, energy, telemetry: None }
    }

    /// The one documented aggregate-throughput accessor, reconciling the
    /// platform's two historical conventions:
    ///
    /// * `legacy = false` (run/sweep/`RUNMIX`): merge the counters first
    ///   ([`Self::aggregate`]: bytes sum, cycles max) and take the merged
    ///   throughput — channels overlap in time, so this is the paper's
    ///   "N channels deliver N× the bandwidth" composition.
    /// * `legacy = true` (the `RUNALL` wire value since PR 1): sum the
    ///   per-channel rates in channel order. For equal-length batches the
    ///   two agree; for skewed mixes the rate sum over-credits short
    ///   batches. Kept — explicitly, not as a silently different code
    ///   path — because `RUNALL AGG_GBS=` is wire-compatible output.
    ///
    /// The float additions happen in channel order in both modes, so each
    /// mode is bit-stable run to run.
    pub fn aggregate_gbs(stats: &[BatchStats], legacy: bool) -> f64 {
        if stats.is_empty() {
            return 0.0;
        }
        if legacy {
            let mut agg = 0.0;
            for s in stats {
                agg += s.total_throughput_gbs();
            }
            agg
        } else {
            Self::aggregate(stats).total_throughput_gbs()
        }
    }

    /// Dispatch one batch to a shared [`RunPool`]: channel `ch`'s state
    /// moves into the job (a power-on placeholder takes its seat until
    /// the result is collected) and the returned [`PendingBatch`] is
    /// redeemed with [`Self::poll_batch`] / [`Self::finish_batch`].
    /// Config and range errors are rejected here, before any state moves,
    /// with the same diagnostics as [`Self::run_batch`]. Pool execution
    /// uses the pure-Rust data path — the PJRT handles of an attached XLA
    /// runtime are not `Send`, so a runtime-attached platform is
    /// rejected.
    pub fn start_batch_on(
        &mut self,
        pool: &RunPool,
        ch: usize,
        cfg: &PatternConfig,
    ) -> Result<PendingBatch> {
        if ch >= self.channels.len() {
            bail!("channel {ch} out of range (design has {})", self.channels.len());
        }
        if self.runtime.is_some() {
            bail!("pooled execution uses the pure-Rust data path; detach the XLA runtime");
        }
        cfg.validate()?;
        let fresh = self.fresh_state();
        let state = std::mem::replace(&mut self.channels[ch], fresh);
        let (tx, rx) = mpsc::channel();
        let live = cfg.telemetry.or(self.design.telemetry).map(|_| SharedTelemetry::default());
        pool.submit(pool::Job {
            ch,
            design: self.design.clone(),
            state,
            cfg: cfg.clone(),
            live: live.clone(),
            reply: tx,
        });
        Ok(PendingBatch { ch, rx, live })
    }

    /// Wait up to `timeout` for a dispatched batch. `None` means still
    /// running (poll again — e.g. after emitting a streaming heartbeat);
    /// `Some(result)` is terminal: the channel state is reinstalled on
    /// success, and on failure the channel keeps the power-on placeholder
    /// installed at dispatch time (the [`Self::run_batch`]
    /// reset-on-failure contract). Don't call again after `Some`.
    pub fn poll_batch(
        &mut self,
        pending: &PendingBatch,
        timeout: Duration,
    ) -> Option<Result<BatchStats>> {
        match pending.rx.recv_timeout(timeout) {
            Ok(outcome) => Some(self.install_outcome(pending.ch, outcome)),
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Some(Err(anyhow!("worker pool shut down mid-batch")))
            }
        }
    }

    /// Block until a dispatched batch completes and return its result
    /// (same terminal semantics as [`Self::poll_batch`]).
    pub fn finish_batch(&mut self, pending: PendingBatch) -> Result<BatchStats> {
        match pending.rx.recv() {
            Ok(outcome) => self.install_outcome(pending.ch, outcome),
            Err(_) => Err(anyhow!("worker pool shut down mid-batch")),
        }
    }

    /// Blocking convenience: [`Self::start_batch_on`] +
    /// [`Self::finish_batch`] — the pooled equivalent of
    /// [`Self::run_batch`].
    pub fn run_batch_on(
        &mut self,
        pool: &RunPool,
        ch: usize,
        cfg: &PatternConfig,
    ) -> Result<BatchStats> {
        let pending = self.start_batch_on(pool, ch, cfg)?;
        self.finish_batch(pending)
    }

    fn install_outcome(&mut self, ch: usize, outcome: pool::JobOutcome) -> Result<BatchStats> {
        if let Some(state) = outcome.state {
            self.channels[ch] = state;
        }
        outcome.result
    }

    /// Dispatch a whole [`ChannelMix`] to the pool, one job per channel
    /// (the pooled counterpart of [`Self::run_batch_mix_results`]).
    /// Mix-level configuration errors (width mismatch, invalid
    /// per-channel configs) are rejected up front with the same
    /// diagnostics as the inline executive.
    pub fn start_mix_on(&mut self, pool: &RunPool, mix: &ChannelMix) -> Result<PendingMix> {
        if mix.len() != self.channels.len() {
            bail!(
                "channel mix configures {} channel(s) but the design has {}",
                mix.len(),
                self.channels.len()
            );
        }
        mix.validate()?;
        let mut slots = Vec::with_capacity(mix.len());
        for ch in 0..mix.len() {
            let cfg = mix.get(ch).expect("mix covers channel");
            slots.push(Some(self.start_batch_on(pool, ch, cfg)?));
        }
        Ok(PendingMix { done: (0..mix.len()).map(|_| None).collect(), slots })
    }

    /// Wait up to `timeout` for the mix's first unfinished channel (the
    /// rest are polled without blocking). Returns `true` once every
    /// channel has its result — then redeem with [`Self::finish_mix`].
    pub fn poll_mix(&mut self, pending: &mut PendingMix, timeout: Duration) -> bool {
        let mut wait = timeout;
        for ch in 0..pending.slots.len() {
            let result = match pending.slots[ch].as_ref() {
                Some(p) => self.poll_batch(p, wait),
                None => continue,
            };
            wait = Duration::ZERO;
            if let Some(r) = result {
                pending.done[ch] = Some(r);
                pending.slots[ch] = None;
            }
        }
        pending.done.iter().all(|d| d.is_some())
    }

    /// Block until every channel of the mix completes and return the
    /// per-channel outcomes in channel order (failed channels keep their
    /// power-on reset, like [`Self::run_batch_mix_results`]).
    pub fn finish_mix(&mut self, mut pending: PendingMix) -> Vec<Result<BatchStats>> {
        for ch in 0..pending.slots.len() {
            if let Some(p) = pending.slots[ch].take() {
                pending.done[ch] = Some(self.finish_batch(p));
            }
        }
        pending.done.into_iter().map(|d| d.expect("all slots finished")).collect()
    }

    /// Pre-generate payload words for every write burst in the TG's plan
    /// via the XLA datagen executable.
    fn datagen_for_plan(
        &self,
        tg: &TrafficGen,
    ) -> Result<HashMap<u64, [u32; payload::WORDS_PER_BURST]>> {
        let rt = self.runtime.as_ref().expect("runtime required");
        let cfg = tg.config();
        let beat_bytes = self.design.axi_beat_bytes();
        let burst_bytes = self.design.geometry.burst_bytes() as u64;
        let pattern_seed = match cfg.data {
            crate::config::DataPattern::Prbs { seed } => seed,
            // Non-PRBS patterns don't use the kernel.
            _ => return Ok(HashMap::new()),
        };
        let mask = !(burst_bytes - 1);
        let mut addrs: Vec<u64> = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for t in tg.plan().iter().filter(|t| t.is_write) {
            let txn = crate::axi::AxiTxn {
                id: 0,
                is_write: true,
                addr: t.addr,
                burst: cfg.burst,
                beat_bytes,
            };
            for i in 0..cfg.burst.len {
                let a = txn.beat_addr(i) & mask;
                if seen.insert(a) {
                    addrs.push(a);
                }
            }
        }
        if addrs.is_empty() {
            return Ok(HashMap::new());
        }
        let seeds: Vec<u32> =
            addrs.iter().map(|&a| payload::burst_seed(a, pattern_seed)).collect();
        let words = rt.datagen(&seeds)?;
        let mut map = HashMap::with_capacity(addrs.len());
        for (i, &a) in addrs.iter().enumerate() {
            let mut w = [0u32; payload::WORDS_PER_BURST];
            w.copy_from_slice(&words[i * 16..(i + 1) * 16]);
            map.insert(a, w);
        }
        Ok(map)
    }

    /// Verify collected read-back samples (XLA verify executable when
    /// attached, Rust mirror otherwise). Returns the mismatch count.
    fn verify_readback(&self, tg: &mut TrafficGen, cfg: &PatternConfig) -> Result<u64> {
        let pattern_seed = match cfg.data {
            crate::config::DataPattern::Prbs { seed } => seed,
            _ => {
                return Ok(tg.verify_readback_rust());
            }
        };
        // Only bursts that were actually written are checkable.
        let store = tg.store.as_ref().expect("verify requires a store");
        let samples: Vec<_> =
            tg.readback.iter().filter(|(a, _)| store.is_written(*a)).collect();
        if samples.is_empty() {
            return Ok(0);
        }
        match &self.runtime {
            Some(rt) => {
                let seeds: Vec<u32> =
                    samples.iter().map(|(a, _)| payload::burst_seed(*a, pattern_seed)).collect();
                let mut data = Vec::with_capacity(samples.len() * 16);
                for (_, words) in &samples {
                    data.extend_from_slice(words);
                }
                rt.verify(&seeds, &data)
            }
            None => Ok({
                let m = tg.verify_readback_rust();
                m
            }),
        }
    }
}

/// Handle to one batch dispatched to a [`RunPool`] via
/// [`Platform::start_batch_on`]. Dropping it abandons the run: the
/// worker's reply is discarded and the channel stays at the power-on
/// placeholder — safe (that's a plain reset), which is what makes a
/// mid-run client disconnect harmless.
pub struct PendingBatch {
    ch: usize,
    rx: mpsc::Receiver<pool::JobOutcome>,
    live: Option<SharedTelemetry>,
}

impl PendingBatch {
    /// The channel the batch was dispatched for.
    pub fn channel(&self) -> usize {
        self.ch
    }

    /// Live telemetry handle of the running batch — present when the
    /// effective telemetry window is set; the pool worker publishes its
    /// current snapshot through it mid-run (the `METRICS`/heartbeat
    /// data source).
    pub fn live_telemetry(&self) -> Option<&SharedTelemetry> {
        self.live.as_ref()
    }
}

/// Handle to a [`ChannelMix`] dispatched to a [`RunPool`] via
/// [`Platform::start_mix_on`] — one [`PendingBatch`] per channel plus
/// the already-collected results.
pub struct PendingMix {
    slots: Vec<Option<PendingBatch>>,
    done: Vec<Option<Result<BatchStats>>>,
}

impl PendingMix {
    /// Number of channels in the mix.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True for the zero-channel mix (cannot actually be constructed —
    /// `ChannelMix` rejects empty mixes — but clippy insists `len` has an
    /// `is_empty` partner).
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Channels whose results are already in.
    pub fn finished(&self) -> usize {
        self.done.iter().filter(|d| d.is_some()).count()
    }
}

/// Extract a printable message from a caught panic payload.
fn panic_msg(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Deadlock guard: generous upper bound on one batch's fabric-cycle
/// runtime, measured from `start_axi`.
fn batch_limit(start_axi: u64, cfg: &PatternConfig) -> u64 {
    start_axi + 2_000_000 + cfg.batch_len as u64 * (cfg.burst.len as u64 + 4) * 64
}

/// The batch time-advance loop, shared by both batch runners and both
/// simulation engines.
///
/// Every executed fabric cycle runs the canonical body — pop DRAM
/// completions, feed them to the TG, tick the TG's AXI side, then tick
/// the controller for the [`AXI_RATIO`] DRAM sub-cycles — so the cycle
/// engine here *is* the historical hot loop, bit for bit.
///
/// The event engine runs the identical body but then leaps the fabric
/// clock straight to the earliest cycle at which anything can happen:
/// the minimum of the TG's next injection ([`TrafficGen::next_event`]),
/// the fabric cycle that pops the oldest in-flight completion
/// ([`MemController::next_completion_at`]), and the controller's own
/// wake contract ([`MemController::next_event`], which refuses to skip
/// while its queues are dirty or a refresh is draining). Each of those
/// bounds is conservative — never later than the first real action — and
/// every skipped cycle is one where the canonical body is provably a
/// no-op, so counters, latencies and per-device command stats are
/// bit-identical across engines (pinned by `tests/engine_differential`).
/// The controller's bound holds for both of its scheduler
/// implementations — the incremental indexes and the frozen scan oracle
/// compute identical wake hints, and the wake-conservatism property
/// test in `tests/sched_index_differential` probes every skipped sleep
/// window against a scan-oracle clone.
///
/// The leap is clamped to `limit` so a wedged batch still trips the
/// deadlock guard at exactly the same fabric-cycle reading — and with
/// the same diagnostic — as the cycle engine.
///
/// When a [`TelemetrySampler`] is attached it is driven from the loop
/// top, *before* any of that iteration's state mutations — the one
/// point both engines pass through with identical machine state, which
/// is what makes the sampled series engine-identical (a leap landing
/// closes every overdue window against the same frozen state the cycle
/// engine saw at each boundary; see `obs::sampler`). Telemetry is
/// observation-only: with `sampler == None` this is byte-for-byte the
/// historical loop.
fn drive_batch(
    engine: EngineKind,
    state: &mut ChannelState,
    tg: &mut TrafficGen,
    cfg: &PatternConfig,
    limit: u64,
    mut sampler: Option<&mut TelemetrySampler>,
) -> Result<()> {
    let start_axi = state.axi_now;
    if let Some(s) = sampler.as_deref_mut() {
        s.begin(&probe_channel(state, tg));
    }
    let mut comps = Vec::with_capacity(16);
    while !tg.is_done() {
        if state.axi_now >= limit {
            bail!(
                "batch deadlock: {}/{} txns after {} fabric cycles",
                tg.completed(),
                cfg.batch_len,
                state.axi_now - start_axi
            );
        }
        let now = state.axi_now - start_axi; // TG counts batch-relative
        if let Some(s) = sampler.as_deref_mut() {
            if s.due(now) {
                s.observe(now, &probe_channel(state, tg));
            }
        }
        comps.clear();
        state.controller.pop_completions(state.axi_now * AXI_RATIO, &mut comps);
        tg.on_completions(&comps, now);
        let dram_base = state.axi_now * AXI_RATIO;
        tg.tick_axi(now, dram_base, &mut state.controller);
        for s in 0..AXI_RATIO {
            state.controller.tick(dram_base + s);
        }
        state.axi_now += 1;
        if engine == EngineKind::Event && !tg.is_done() {
            // Earliest absolute fabric cycle anyone needs to run again:
            // the TG's next injection (u64::MAX = woken by completions
            // only), the pop cycle of the oldest in-flight completion,
            // and the controller's own wake (refresh deadline / mode
            // dwell; `now` itself while dirty or draining a refresh).
            let mut wake = tg
                .next_event(now, dram_base, &state.controller)
                .checked_add(start_axi)
                .unwrap_or(u64::MAX);
            if let Some(done_at) = state.controller.next_completion_at() {
                wake = wake.min(done_at.div_ceil(AXI_RATIO));
            }
            wake = wake.min(state.controller.next_event(state.axi_now * AXI_RATIO) / AXI_RATIO);
            if wake > state.axi_now {
                state.axi_now = wake.min(limit);
            }
        }
    }
    if let Some(s) = sampler.as_deref_mut() {
        // Close the trailing partial window at the batch clock reading —
        // `total_cycles` is a counter, so it is engine-identical.
        s.finalize(tg.counters.total_cycles, &probe_channel(state, tg));
    }
    Ok(())
}

/// Point-in-time probe of everything the telemetry sampler observes:
/// batch byte/latency counters, device command stats, refresh stalls,
/// and the queue/bank occupancy snapshots. Only built when a window
/// boundary has actually been crossed (the histogram clones stay off
/// the telemetry-off hot path entirely).
fn probe_channel(state: &ChannelState, tg: &TrafficGen) -> Probe {
    let dev = state.controller.device().stats();
    Probe {
        rd_bytes: tg.counters.rd_bytes,
        wr_bytes: tg.counters.wr_bytes,
        in_flight: tg.in_flight() as u64,
        open_banks: state.controller.device().open_banks(),
        acts: dev.acts,
        pres: dev.pres,
        refresh_stall: state.controller.stats().refresh_stall_cycles,
        rd_latency: tg.counters.rd_latency.clone(),
        wr_latency: tg.counters.wr_latency.clone(),
    }
}

/// Free-function batch runner over a borrowed channel state (thread body
/// of [`Platform::run_batch_mix`] and the pool worker; Rust-mirror data
/// path only). `live` is the optional shared handle a pooled batch
/// publishes its telemetry snapshot through mid-run (for `METRICS` and
/// enriched `STREAM` heartbeats); it does nothing unless the effective
/// telemetry window is set.
fn run_batch_on_state(
    design: &DesignConfig,
    state: &mut ChannelState,
    cfg: &PatternConfig,
    live: Option<SharedTelemetry>,
) -> Result<BatchStats> {
    if state.panic_inject {
        state.panic_inject = false;
        panic!("injected channel fault (Platform::inject_channel_panic test hook)");
    }
    let mut geometry = design.geometry;
    if let Some(m) = cfg.mapping {
        geometry.mapping = m;
    }
    state.controller.set_sched(cfg.sched.unwrap_or(design.controller.sched));
    let mut tg = TrafficGen::with_frontend(
        cfg.clone(),
        design.axi_beat_bytes(),
        geometry,
        design.controller.outstanding_cap,
        design.controller.addr_cmd_interval_axi,
        design.controller.serial_frontend,
    );
    if cfg.verify {
        tg.store = state.store.take().or_else(|| Some(DataStore::new()));
    }
    let engine = cfg.engine.unwrap_or(design.engine);
    let mut sampler = cfg.telemetry.or(design.telemetry).map(|w| {
        let s = TelemetrySampler::new(w);
        match live {
            Some(shared) => s.with_publisher(shared),
            None => s,
        }
    });
    let refresh_before = state.controller.stats().refresh_stall_cycles;
    let dev_before = *state.controller.device().stats();
    let start_axi = state.axi_now;
    drive_batch(engine, state, &mut tg, cfg, batch_limit(start_axi, cfg), sampler.as_mut())?;
    let telemetry = sampler.as_mut().map(|s| s.take_series());
    let mut counters = std::mem::take(&mut tg.counters);
    counters.refresh_stall_dram_cycles =
        state.controller.stats().refresh_stall_cycles - refresh_before;
    let energy = crate::ddr4::power::channel_energy(
        &state.controller.device().stats().delta(&dev_before),
        (state.axi_now - start_axi) * AXI_RATIO,
        design.speed,
        state.controller.device().timing(),
        &crate::ddr4::power::IddSpec::micron_4gb_x16(),
    );
    if cfg.verify {
        counters.mismatches += tg.verify_readback_rust();
        state.store = tg.store.take();
    }
    Ok(BatchStats { counters, speed: design.speed, energy, telemetry })
}

/// Solo-vs-co-run interference measurements for K workloads (the
/// channel-interference report mode). `co_gbs[i][j]` is workload `i`'s
/// throughput when co-scheduled with workload `j` on the neighbouring
/// channel; `solo_gbs[i]` is its throughput running alone on a
/// single-channel design of the same speed/knobs. Rendered by
/// [`crate::report::interference_tables`].
#[derive(Debug, Clone)]
pub struct InterferenceMatrix {
    /// Workload labels, in mix order.
    pub labels: Vec<String>,
    /// Solo total throughput per workload (GB/s).
    pub solo_gbs: Vec<f64>,
    /// Solo p99 latency per workload (ns; max of read/write p99).
    pub solo_p99_ns: Vec<f64>,
    /// `co_gbs[i][j]`: workload i's throughput co-run with workload j.
    pub co_gbs: Vec<Vec<f64>>,
    /// `co_p99_ns[i][j]`: workload i's p99 latency co-run with j.
    pub co_p99_ns: Vec<Vec<f64>>,
}

/// The p99 summary latency of a batch: the worse of read and write p99.
fn p99_ns(s: &BatchStats) -> f64 {
    s.read_latency_pct_ns(99.0).max(s.write_latency_pct_ns(99.0))
}

/// Run the interference campaign for `workloads` under `base`'s speed,
/// geometry and controller knobs: each workload solo on a 1-channel
/// design, then every pair co-scheduled on a 2-channel design (fresh
/// platforms throughout, so batches cannot contaminate each other). One
/// pair run yields *both* ordered cells — channel 0 is `i` co-run with
/// `j`, channel 1 is `j` co-run with `i` — so K workloads cost K solo
/// runs + K·(K+1)/2 co-runs.
pub fn interference_matrix(
    base: &DesignConfig,
    workloads: &[(String, PatternConfig)],
) -> Result<InterferenceMatrix> {
    let k = workloads.len();
    if k < 2 {
        bail!("interference matrix needs at least two workloads, got {k}");
    }
    let design_with = |channels: usize| {
        let mut d = base.clone();
        d.channels = channels;
        d
    };
    let mut labels = Vec::with_capacity(k);
    let mut solo_gbs = Vec::with_capacity(k);
    let mut solo_p99_ns = Vec::with_capacity(k);
    for (label, cfg) in workloads {
        let mut p = Platform::new(design_with(1));
        let s = p.run_batch(0, cfg)?;
        labels.push(label.clone());
        solo_gbs.push(s.total_throughput_gbs());
        solo_p99_ns.push(p99_ns(&s));
    }
    let mut co_gbs = vec![vec![0.0; k]; k];
    let mut co_p99_ns = vec![vec![0.0; k]; k];
    for i in 0..k {
        for j in i..k {
            let mix = ChannelMix::new(vec![workloads[i].1.clone(), workloads[j].1.clone()])?;
            let mut p = Platform::new(design_with(2));
            let per = p.run_batch_mix(&mix)?;
            co_gbs[i][j] = per[0].total_throughput_gbs();
            co_p99_ns[i][j] = p99_ns(&per[0]);
            co_gbs[j][i] = per[1].total_throughput_gbs();
            co_p99_ns[j][i] = p99_ns(&per[1]);
        }
    }
    Ok(InterferenceMatrix { labels, solo_gbs, solo_p99_ns, co_gbs, co_p99_ns })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AddrMode, SpeedBin};

    #[test]
    fn single_channel_seq_read_throughput_sane() {
        let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        let stats = p.run_batch(0, &PatternConfig::seq_read_burst(32, 2000)).unwrap();
        let gbs = stats.read_throughput_gbs();
        // Bus ceiling is 6.4 GB/s; paper measures 6.27 for MB reads.
        assert!(gbs > 5.0 && gbs <= 6.4, "seq MB read = {gbs:.2} GB/s");
    }

    #[test]
    fn random_single_much_slower_than_seq() {
        let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        let seq = p.run_batch(0, &PatternConfig::seq_read_burst(1, 2000)).unwrap();
        let rnd = p.run_batch(0, &PatternConfig::rnd_read_burst(1, 2000, 3)).unwrap();
        let ratio = seq.read_throughput_gbs() / rnd.read_throughput_gbs();
        assert!(ratio > 3.0, "seq/rnd singles ratio = {ratio:.2} (paper: 5.5x)");
    }

    #[test]
    fn channel_out_of_range_rejected() {
        let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        assert!(p.run_batch(1, &PatternConfig::default()).is_err());
    }

    #[test]
    fn multi_channel_scales_throughput() {
        let mut p = Platform::new(DesignConfig::with_channels(3, SpeedBin::Ddr4_1600));
        let per = p.run_batch_all(&PatternConfig::seq_read_burst(32, 1000)).unwrap();
        assert_eq!(per.len(), 3);
        let agg = Platform::aggregate(&per);
        let single = per[0].read_throughput_gbs();
        let total = agg.read_throughput_gbs();
        assert!(
            (total / single - 3.0).abs() < 0.2,
            "triple-channel scaling: {total:.2} vs 3x{single:.2}"
        );
    }

    #[test]
    fn heterogeneous_mix_runs_distinct_per_channel_workloads() {
        // The acceptance scenario: three different patterns, one per
        // channel, produce distinct per-channel stats plus an aggregate.
        let mut p = Platform::new(DesignConfig::with_channels(3, SpeedBin::Ddr4_1600));
        let mix = ChannelMix::new(vec![
            PatternConfig::seq_read_burst(32, 800),
            PatternConfig::pointer_chase_read(1 << 20, 400, 7),
            PatternConfig::bank_conflict_read(1, 400, 1),
        ])
        .unwrap();
        let per = p.run_batch_mix(&mix).unwrap();
        assert_eq!(per.len(), 3);
        assert_eq!(per[0].counters.rd_txns, 800, "seq channel ran its own batch");
        assert_eq!(per[1].counters.rd_txns, 400, "chase channel ran its own batch");
        let (seq, chase, bank) = (
            per[0].read_throughput_gbs(),
            per[1].read_throughput_gbs(),
            per[2].read_throughput_gbs(),
        );
        assert!(
            seq > 4.0 * chase && seq > 4.0 * bank,
            "distinct per-channel stats: seq {seq:.2} vs chase {chase:.2} / bank {bank:.2}"
        );
        let agg = Platform::aggregate(&per);
        assert_eq!(agg.counters.rd_txns, 1600, "aggregate sums the channels");
        assert!(
            agg.total_throughput_gbs() > chase.max(bank),
            "aggregate (incl. the fast channel's bytes) beats the slow channels: {:.2}",
            agg.total_throughput_gbs()
        );
    }

    #[test]
    fn mix_width_must_match_design() {
        let mut p = Platform::new(DesignConfig::with_channels(2, SpeedBin::Ddr4_1600));
        let mix = ChannelMix::uniform(&PatternConfig::seq_read_burst(4, 32), 3).unwrap();
        assert!(p.run_batch_mix(&mix).is_err());
    }

    #[test]
    fn panicking_channel_thread_reports_error_and_spares_survivors() {
        // Regression for the old `j.join().expect("channel thread
        // panicked")`: a dying channel thread must not abort the process
        // or discard the other channels' results.
        let mut p = Platform::new(DesignConfig::with_channels(3, SpeedBin::Ddr4_1600));
        p.inject_channel_panic(1);
        let mix = ChannelMix::uniform(&PatternConfig::seq_read_burst(4, 64), 3).unwrap();
        let results = p.run_batch_mix_results(&mix).unwrap();
        assert_eq!(results.len(), 3);
        assert!(results[0].is_ok(), "channel 0 survives");
        assert!(results[2].is_ok(), "channel 2 survives");
        let err = results[1].as_ref().unwrap_err().to_string();
        assert!(err.contains("channel 1") && err.contains("panicked"), "{err}");
        assert!(err.contains("injected channel fault"), "payload surfaces: {err}");
        assert_eq!(results[0].as_ref().unwrap().counters.rd_txns, 64);
        // the strict variant folds the failure into one error
        p.inject_channel_panic(1);
        let err = p.run_batch_mix(&mix).unwrap_err().to_string();
        assert!(err.contains("1 of 3 channel(s) failed"), "{err}");
        // the hook is one-shot and the failed channel was reset to
        // power-on state: the next mix is clean and the channel's memory
        // store is usable (verify flow works end to end)
        let per = p.run_batch_mix(&mix).unwrap();
        assert_eq!(per.len(), 3);
        let mut w = PatternConfig::seq_write_burst(4, 32);
        w.verify = true;
        w.region_bytes = 64 * 4 * 32;
        let s = p.run_batch(1, &w).unwrap();
        assert_eq!(s.counters.mismatches, 0, "reset channel verifies cleanly");
    }

    #[test]
    fn serial_path_panic_contained_too() {
        // 1-channel designs take the sequential executive path: a
        // panicking batch must still degrade to the channel's error
        // (and reset the channel) instead of aborting the process
        let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        p.inject_channel_panic(0);
        let mix = ChannelMix::uniform(&PatternConfig::seq_read_burst(4, 32), 1).unwrap();
        let results = p.run_batch_mix_results(&mix).unwrap();
        let err = results[0].as_ref().unwrap_err().to_string();
        assert!(err.contains("channel 0 panicked"), "{err}");
        assert!(err.contains("injected channel fault"), "{err}");
        let per = p.run_batch_mix(&mix).unwrap();
        assert_eq!(per[0].counters.rd_txns, 32, "reset channel runs clean");
    }

    #[test]
    fn aggregate_gbs_reconciles_legacy_and_merged_conventions() {
        let mut p = Platform::new(DesignConfig::with_channels(2, SpeedBin::Ddr4_1600));
        // equal-length batches: rate sum and merged throughput agree
        let per = p.run_batch_all(&PatternConfig::seq_read_burst(8, 400)).unwrap();
        let legacy = Platform::aggregate_gbs(&per, true);
        let merged = Platform::aggregate_gbs(&per, false);
        assert!((legacy - merged).abs() < 1e-9, "equal batches: {legacy} vs {merged}");
        assert_eq!(
            legacy,
            per[0].total_throughput_gbs() + per[1].total_throughput_gbs(),
            "legacy mode is the ordered per-channel rate sum"
        );
        assert_eq!(
            merged,
            Platform::aggregate(&per).total_throughput_gbs(),
            "merged mode is the counters-merge throughput"
        );
        // skewed batches: the rate sum over-credits the short batch, so
        // legacy strictly exceeds merged (cycles max ≥ each channel's)
        let mix = ChannelMix::new(vec![
            PatternConfig::seq_read_burst(8, 1200),
            PatternConfig::seq_read_burst(8, 100),
        ])
        .unwrap();
        let per = p.run_batch_mix(&mix).unwrap();
        let legacy = Platform::aggregate_gbs(&per, true);
        let merged = Platform::aggregate_gbs(&per, false);
        assert!(legacy > merged, "skewed batches diverge: {legacy} vs {merged}");
        assert_eq!(Platform::aggregate_gbs(&[], true), 0.0);
        assert_eq!(Platform::aggregate_gbs(&[], false), 0.0);
    }

    #[test]
    fn pooled_mix_matches_threaded_mix_and_isolates_panics() {
        let design = DesignConfig::with_channels(3, SpeedBin::Ddr4_1600);
        let mix = ChannelMix::new(vec![
            PatternConfig::seq_read_burst(32, 400),
            PatternConfig::pointer_chase_read(1 << 20, 200, 7),
            PatternConfig::bank_conflict_read(1, 200, 1),
        ])
        .unwrap();
        let mut threaded = Platform::new(design.clone());
        let expect = threaded.run_batch_mix(&mix).unwrap();

        let pool = RunPool::new(2);
        let mut pooled = Platform::new(design);
        let mut pending = pooled.start_mix_on(&pool, &mix).unwrap();
        assert_eq!(pending.len(), 3);
        let mut polls = 0;
        while !pooled.poll_mix(&mut pending, Duration::from_millis(20)) {
            polls += 1;
            assert!(polls < 10_000, "mix never completed");
        }
        assert_eq!(pending.finished(), 3);
        let results = pooled.finish_mix(pending);
        for (ch, r) in results.iter().enumerate() {
            let s = r.as_ref().unwrap();
            assert_eq!(s.counters, expect[ch].counters, "channel {ch} diverges from threads");
        }

        // a panicking channel fails alone; the survivors' results land
        pooled.inject_channel_panic(1);
        let pending = pooled.start_mix_on(&pool, &mix).unwrap();
        let results = pooled.finish_mix(pending);
        assert!(results[0].is_ok() && results[2].is_ok(), "survivors spared");
        let err = results[1].as_ref().unwrap_err().to_string();
        assert!(err.contains("channel 1 panicked"), "{err}");
        // width mismatch diagnosed up front, like the inline executive
        let wide = ChannelMix::uniform(&PatternConfig::seq_read_burst(4, 32), 4).unwrap();
        let err = pooled.start_mix_on(&pool, &wide).unwrap_err().to_string();
        assert!(err.contains("but the design has 3"), "{err}");
    }

    #[test]
    fn interference_matrix_compares_solo_and_corun() {
        let workloads = vec![
            ("seq".to_string(), PatternConfig::seq_read_burst(32, 400)),
            ("bank".to_string(), PatternConfig::bank_conflict_read(1, 200, 1)),
        ];
        let base = DesignConfig::single_channel(SpeedBin::Ddr4_1600);
        let m = interference_matrix(&base, &workloads).unwrap();
        assert_eq!(m.labels, vec!["seq", "bank"]);
        assert_eq!(m.co_gbs.len(), 2);
        assert!(m.solo_gbs.iter().all(|&g| g > 0.0));
        for i in 0..2 {
            assert_eq!(m.co_gbs[i].len(), 2);
            for j in 0..2 {
                // simulated channels are architecturally independent, so
                // co-run throughput must match solo exactly — the matrix
                // machinery itself is what's under test here
                let rel = (m.co_gbs[i][j] - m.solo_gbs[i]).abs() / m.solo_gbs[i];
                assert!(rel < 1e-9, "co[{i}][{j}] {} vs solo {}", m.co_gbs[i][j], m.solo_gbs[i]);
                assert!((m.co_p99_ns[i][j] - m.solo_p99_ns[i]).abs() < 1e-9);
            }
        }
        // a single workload has nothing to interfere with
        assert!(interference_matrix(&base, &workloads[..1]).is_err());
    }

    #[test]
    fn write_then_read_verify_clean_and_fault_detected() {
        let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        let region = 64 * 4 * 32; // small region fully covered
        let mut w = PatternConfig::seq_write_burst(4, 64);
        w.verify = true;
        w.region_bytes = region;
        let ws = p.run_batch(0, &w).unwrap();
        assert_eq!(ws.counters.mismatches, 0);
        let mut r = PatternConfig::seq_read_burst(4, 64);
        r.verify = true;
        r.region_bytes = region;
        let rs = p.run_batch(0, &r).unwrap();
        assert_eq!(rs.counters.mismatches, 0, "clean read-back");
        // corrupt one word and read again
        assert!(p.corrupt(0, 0, 3, 0xFFFF_0000));
        let rs2 = p.run_batch(0, &r).unwrap();
        assert_eq!(rs2.counters.mismatches, 1, "fault detected");
    }

    #[test]
    fn mapping_override_runs_and_never_beats_bank_interleave_on_seq() {
        use crate::ddr4::MappingPolicy;
        let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        let mut gbs = std::collections::BTreeMap::new();
        for policy in MappingPolicy::builtins() {
            let mut cfg = PatternConfig::seq_read_burst(32, 1000);
            cfg.mapping = Some(policy);
            let s = p.run_batch(0, &cfg).unwrap();
            assert_eq!(s.counters.rd_txns, 1000, "{policy}: txns conserve");
            assert!(s.read_throughput_gbs() > 0.0, "{policy}: moved data");
            gbs.insert(policy.name(), s.read_throughput_gbs());
        }
        // bank-interleaved MIG order pipelines ACTs that the row-major
        // orders serialize: it can't lose to them on a sequential stream
        assert!(
            gbs["row_col_bank"] >= gbs["row_bank_col"] - 1e-9,
            "row_col_bank {} vs row_bank_col {}",
            gbs["row_col_bank"],
            gbs["row_bank_col"]
        );
        assert!(gbs["row_col_bank"] >= gbs["bank_row_col"] - 1e-9);
    }

    #[test]
    fn sched_override_runs_and_orders_policies_sanely() {
        use crate::config::SchedKind;
        let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        let mut gbs = std::collections::BTreeMap::new();
        for kind in SchedKind::ALL {
            // serial-front-end singles: row hits across transactions are
            // what separates the page policies
            let mut cfg = PatternConfig::seq_read_burst(1, 800);
            cfg.sched = Some(kind);
            let s = p.run_batch(0, &cfg).unwrap();
            assert_eq!(s.counters.rd_txns, 800, "{kind}: txns conserve");
            assert!(s.read_throughput_gbs() > 0.0, "{kind}: moved data");
            gbs.insert(kind.name(), s.read_throughput_gbs());
        }
        // closed page pays an ACT per transaction on a sequential stream
        // of singles; the open-page FR-FCFS default cannot lose to it
        assert!(
            gbs["frfcfs"] > gbs["closed"],
            "frfcfs {} vs closed {}",
            gbs["frfcfs"],
            gbs["closed"]
        );
        // on pure sequential traffic the reorder window finds no work to
        // reorder: fcfs and the capped variant track the default closely
        assert!(
            gbs["fcfs"] >= gbs["frfcfs"] * 0.95,
            "fcfs {} vs frfcfs {}",
            gbs["fcfs"],
            gbs["frfcfs"]
        );
        // and the override is per batch: the next default batch is frfcfs
        let s = p.run_batch(0, &PatternConfig::seq_read_burst(1, 100)).unwrap();
        assert_eq!(s.counters.rd_txns, 100);
    }

    #[test]
    fn mixed_beats_pure_read_throughput() {
        // Mixed R+W uses both data channels: combined > read-only max.
        let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        let read = p.run_batch(0, &PatternConfig::seq_read_burst(32, 2000)).unwrap();
        let mixed =
            p.run_batch(0, &PatternConfig::mixed(AddrMode::Sequential, 32, 2000)).unwrap();
        assert!(
            mixed.total_throughput_gbs() > read.read_throughput_gbs(),
            "mixed {:.2} vs read {:.2}",
            mixed.total_throughput_gbs(),
            read.read_throughput_gbs()
        );
    }

    #[test]
    fn refresh_degradation_observable() {
        let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        // long enough batch to span several tREFI (6240 DRAM cycles each)
        let stats = p.run_batch(0, &PatternConfig::seq_read_burst(32, 20_000)).unwrap();
        assert!(stats.counters.refresh_stall_dram_cycles > 0);
        let deg = stats.refresh_degradation();
        assert!(deg > 0.0 && deg < 0.2, "refresh degradation {deg:.4}");
    }

    #[test]
    fn event_engine_matches_cycle_engine_on_basic_patterns() {
        // The event engine only skips provably dead fabric cycles, so
        // every counter — including the batch clock — must match the
        // cycle oracle exactly (tests/engine_differential fuzzes this
        // property; here we pin three representative shapes).
        let mut event_design = DesignConfig::single_channel(SpeedBin::Ddr4_1600);
        event_design.engine = EngineKind::Event;
        for cfg in [
            PatternConfig::seq_read_burst(8, 400),
            PatternConfig::pointer_chase_read(1 << 20, 200, 7),
            PatternConfig::mixed(AddrMode::Sequential, 4, 300),
        ] {
            let mut cycle = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
            let mut event = Platform::new(event_design.clone());
            let a = cycle.run_batch(0, &cfg).unwrap();
            let b = event.run_batch(0, &cfg).unwrap();
            assert_eq!(a.counters, b.counters, "{:?} counters diverge", cfg.addr);
            assert_eq!(
                cycle.channels[0].axi_now, event.channels[0].axi_now,
                "{:?}: channel clocks diverge",
                cfg.addr
            );
        }
        // and the per-batch ENGINE= override selects the engine too
        let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        let mut cfg = PatternConfig::seq_read_burst(8, 400);
        let base = p.run_batch(0, &cfg).unwrap();
        cfg.engine = Some(EngineKind::Event);
        let ovr = p.run_batch(0, &cfg).unwrap();
        assert_eq!(base.counters, ovr.counters, "ENGINE= override diverges");
    }

    #[test]
    fn telemetry_series_is_engine_identical_and_observation_only() {
        let mut cfg = PatternConfig::seq_read_burst(8, 600);
        cfg.telemetry = Some(256);
        let mut plain_cfg = cfg.clone();
        plain_cfg.telemetry = None;
        let mut cycle = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        let a = cycle.run_batch(0, &cfg).unwrap();
        let mut event_design = DesignConfig::single_channel(SpeedBin::Ddr4_1600);
        event_design.engine = EngineKind::Event;
        let mut event = Platform::new(event_design);
        let b = event.run_batch(0, &cfg).unwrap();
        // observation only: counters with telemetry on equal telemetry off
        let mut plain = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        let p = plain.run_batch(0, &plain_cfg).unwrap();
        assert_eq!(a.counters, p.counters, "telemetry must not perturb the run");
        assert!(p.telemetry.is_none(), "no window configured, no series");
        // identical series across engines, field for field
        let sa = a.telemetry.as_ref().expect("TELEM= produces a series");
        let sb = b.telemetry.as_ref().unwrap();
        assert_eq!(sa, sb, "engines must produce identical telemetry series");
        assert!(!sa.windows.is_empty());
        // contiguous, monotone window stamps starting at batch time zero
        assert_eq!(sa.windows[0].start, 0);
        for w in sa.windows.windows(2) {
            assert_eq!(w[0].end, w[1].start, "windows tile the timeline");
        }
        // the deltas sum back to the batch totals
        let rd: u64 = sa.windows.iter().map(|w| w.rd_bytes).sum();
        assert_eq!(rd, a.counters.rd_bytes, "window deltas conserve bytes");
        let stall: u64 = sa.windows.iter().map(|w| w.refresh_stall).sum();
        assert_eq!(stall, a.counters.refresh_stall_dram_cycles, "stall deltas conserve");
        // the design-level key enables the same sampler
        let mut d = DesignConfig::single_channel(SpeedBin::Ddr4_1600);
        d.telemetry = Some(256);
        let mut p2 = Platform::new(d);
        let s2 = p2.run_batch(0, &plain_cfg).unwrap();
        assert_eq!(s2.telemetry.as_ref().unwrap(), sa, "design key matches TELEM= override");
    }

    #[test]
    fn platform_cmd_trace_arms_idempotently() {
        let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        assert!(p.cmd_trace(0).is_none(), "tracing starts disarmed");
        p.enable_cmd_trace(0, 1024).unwrap();
        p.run_batch(0, &PatternConfig::seq_read_burst(4, 100)).unwrap();
        let n = p.cmd_trace(0).unwrap().len();
        assert!(n > 0, "armed ring captured commands");
        // re-arming keeps the existing ring instead of clearing it
        p.enable_cmd_trace(0, 16).unwrap();
        assert_eq!(p.cmd_trace(0).unwrap().len(), n);
        assert!(p.enable_cmd_trace(9, 16).is_err(), "range-checked");
    }

    #[test]
    fn pooled_live_telemetry_publishes_and_matches_series() {
        let pool = RunPool::new(1);
        let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        let mut cfg = PatternConfig::seq_read_burst(8, 400);
        cfg.telemetry = Some(128);
        let pending = p.start_batch_on(&pool, 0, &cfg).unwrap();
        let live = std::sync::Arc::clone(pending.live_telemetry().expect("live handle"));
        let stats = p.finish_batch(pending).unwrap();
        let series = stats.telemetry.as_ref().unwrap();
        let snap = live.lock().unwrap().clone();
        assert!(snap.done, "final publish marks the run done");
        assert_eq!(snap, crate::obs::snapshot_from_series(series));
        // pooled series matches the inline executive's bit for bit
        let mut inline = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
        let expect = inline.run_batch(0, &cfg).unwrap();
        assert_eq!(stats.telemetry, expect.telemetry);
        // no telemetry window -> no live handle
        let plain = PatternConfig::seq_read_burst(8, 50);
        let pending = p.start_batch_on(&pool, 0, &plain).unwrap();
        assert!(pending.live_telemetry().is_none());
        p.finish_batch(pending).unwrap();
    }

    #[test]
    fn deadlock_guard_fires_identically_across_engines() {
        // Regression (event-core introduction): a time-skip past `limit`
        // must not overshoot silently — the leap is clamped so both
        // engines bail at exactly the limit with the same diagnostic.
        let mut design = DesignConfig::single_channel(SpeedBin::Ddr4_1600);
        // a sparse injection schedule makes the event engine *want* to
        // leap far beyond the tiny limit below
        design.controller.addr_cmd_interval_axi = 64;
        let cfg = PatternConfig::seq_read_burst(8, 400);
        let mut errs = Vec::new();
        for engine in EngineKind::ALL {
            let mut p = Platform::new(design.clone());
            let state = &mut p.channels[0];
            let mut tg = TrafficGen::with_frontend(
                cfg.clone(),
                design.axi_beat_bytes(),
                design.geometry,
                design.controller.outstanding_cap,
                design.controller.addr_cmd_interval_axi,
                design.controller.serial_frontend,
            );
            let err = drive_batch(engine, state, &mut tg, &cfg, 10, None).unwrap_err();
            assert_eq!(state.axi_now, 10, "{engine}: must stop at exactly the limit");
            errs.push(err.to_string());
        }
        assert_eq!(errs[0], errs[1], "engines must report the same diagnostic");
        assert!(errs[0].contains("batch deadlock"), "{}", errs[0]);
        assert!(errs[0].contains("after 10 fabric cycles"), "{}", errs[0]);
    }
}
